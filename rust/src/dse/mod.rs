//! Top-level co-exploration driver (paper Fig. 6): ties the workload
//! instantiation, the GA mapping generation engine, the BO hardware
//! sampling engine, and the evaluation engine into the loop
//!
//!   hardware sample -> mapping search -> (L, E, MC) -> surrogate update
//!
//! `compass_dse` is the framework entrypoint; `search_mappings` is the
//! inner mapping search reused by the baselines and benches.

use crate::arch::{HwConfig, HwSpace};
use crate::bo::{self, BoConfig, Gp};
use crate::cost::engine::{default_threads, par_map};
use crate::cost::{group_params, EvalResult, Evaluator, MappingEvaluator};
use crate::ga::{self, GaConfig};
use crate::mapping::Mapping;
use crate::sim::{
    self, DrainSpec, FaultSchedule, FleetConfig, FleetMetrics, Frontend, KvSpec, MappingPolicy,
    RequestStream, ResilienceSpec, RetryPolicy, RouterPolicy, ServingMetrics, SimConfig,
};
use crate::workload::serving::Scenario;
use crate::workload::{build_workload, ModelSpec};

/// Full co-exploration budget.
#[derive(Debug, Clone, Copy)]
pub struct DseConfig {
    pub ga: GaConfig,
    pub bo: BoConfig,
    /// Transformer blocks instantiated explicitly (0 = full depth).
    pub eval_blocks: usize,
}

impl DseConfig {
    pub fn reduced() -> Self {
        DseConfig {
            ga: GaConfig::reduced(),
            bo: BoConfig::reduced(),
            eval_blocks: 2,
        }
    }

    pub fn paper() -> Self {
        DseConfig {
            ga: GaConfig::paper(),
            bo: BoConfig::paper(),
            eval_blocks: 4,
        }
    }

    pub fn tiny() -> Self {
        DseConfig {
            ga: GaConfig::tiny(),
            bo: BoConfig::tiny(),
            eval_blocks: 1,
        }
    }
}

/// Outcome of a co-exploration run.
#[derive(Debug, Clone)]
pub struct DseOutcome {
    pub hw: HwConfig,
    pub mappings: Vec<Mapping>,
    pub eval: EvalResult,
    /// Best-objective trajectory over BO rounds.
    pub bo_history: Vec<f64>,
    pub backend: &'static str,
}

/// Mapping-search result for a fixed hardware configuration.
#[derive(Debug, Clone)]
pub struct MappingSearch {
    pub mappings: Vec<Mapping>,
    pub eval: EvalResult,
}

/// Run the GA mapping search for every batch group of `scenario` on
/// hardware `hw`, then evaluate the scenario end-to-end.
///
/// Each group's search runs through a [`MappingEvaluator`]: the
/// search-invariant workload state is prepared once, generations are
/// scored batch-parallel across threads, and duplicate individuals hit
/// the fitness memo (EXPERIMENTS.md #Perf). Results are bit-identical to
/// the serial closure path for a given seed.
pub fn search_mappings(
    scenario: &Scenario,
    model: &ModelSpec,
    hw: &HwConfig,
    ga_cfg: &GaConfig,
    eval_blocks: usize,
) -> MappingSearch {
    let ev = Evaluator::new();
    let chips = hw.num_chiplets();
    let mut mappings = Vec::with_capacity(scenario.groups.len());
    for (gi, group) in scenario.groups.iter().enumerate() {
        let params = group_params(hw, group.has_prefill, eval_blocks);
        let w = build_workload(model, &group.batch, &params);
        let rows = w.num_micro_batches();
        let cols = w.layers_per_mb;
        let mut cfg = *ga_cfg;
        cfg.seed = ga_cfg.seed.wrapping_add(gi as u64);
        let res = ga::search(rows, cols, chips, &cfg, &MappingEvaluator::new(&w, hw));
        mappings.push(res.best);
    }
    let eval = ev.eval_scenario(scenario, model, hw, &mappings, eval_blocks);
    MappingSearch { mappings, eval }
}

/// The Compass framework: BO over hardware, GA over mappings, the
/// evaluation engine inside. `gp` selects the surrogate backend
/// (PJRT artifacts or the native mirror).
pub fn compass_dse(
    scenario: &Scenario,
    model: &ModelSpec,
    space: &HwSpace,
    cfg: &DseConfig,
    gp: &mut dyn Gp,
) -> DseOutcome {
    let result = bo::optimize(space, &cfg.bo, gp, |hw| {
        search_mappings(scenario, model, hw, &cfg.ga, cfg.eval_blocks)
            .eval
            .total_cost()
    });
    // re-derive the winning mappings for reporting
    let best = search_mappings(scenario, model, &result.best.hw, &cfg.ga, cfg.eval_blocks);
    DseOutcome {
        hw: result.best.hw.clone(),
        mappings: best.mappings,
        eval: best.eval,
        bo_history: result.history,
        backend: result.backend,
    }
}

/// Outcome of a serving-simulator-backed co-exploration run.
#[derive(Debug, Clone)]
pub struct ServingDseOutcome {
    pub hw: HwConfig,
    pub metrics: ServingMetrics,
    /// Best-objective trajectory over BO rounds (negated SLO-constrained
    /// goodput; lower is better).
    pub bo_history: Vec<f64>,
    pub backend: &'static str,
}

/// Sim-backed mapping search for a fixed hardware configuration: replay
/// `stream` through the continuous-batching scheduler with a GA mapping
/// search per distinct batch shape (`MappingPolicy::Searched`, memoized
/// so each shape is searched exactly once), and return the resulting
/// serving metrics. The dynamic counterpart of [`search_mappings`].
pub fn search_serving(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    ga_cfg: &GaConfig,
    sim_cfg: &SimConfig,
) -> ServingMetrics {
    let cfg = sim_cfg.with_policy(MappingPolicy::Searched(*ga_cfg));
    sim::simulate_serving(stream, model, hw, &cfg)
}

/// Compass with the time-domain objective (paper north star: serving
/// quality, not static-group latency): BO over hardware, GA over
/// per-shape mappings, the serving simulator inside. Maximizes
/// SLO-constrained goodput via [`ServingMetrics::objective`].
pub fn compass_dse_serving(
    stream: &RequestStream,
    model: &ModelSpec,
    space: &HwSpace,
    cfg: &DseConfig,
    sim_cfg: &SimConfig,
    gp: &mut dyn Gp,
) -> ServingDseOutcome {
    let result = bo::optimize(space, &cfg.bo, gp, |hw| {
        search_serving(stream, model, hw, &cfg.ga, sim_cfg).objective()
    });
    let metrics = search_serving(stream, model, &result.best.hw, &cfg.ga, sim_cfg);
    ServingDseOutcome {
        hw: result.best.hw.clone(),
        metrics,
        bo_history: result.history,
        backend: result.backend,
    }
}

/// Sweep KV-cache layouts (block size x dtype x sharing x eviction) on
/// fixed hardware, scoring each by the serving objective, and return
/// the winner plus every candidate's metrics. The KV analogue of the
/// shape loop in [`compass_dse_fleet`]: capacity-side design choices
/// change which configurations win before any hardware is re-searched.
pub fn search_kv(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    sim_cfg: &SimConfig,
    specs: &[KvSpec],
) -> (KvSpec, Vec<(KvSpec, ServingMetrics)>) {
    // Candidate-parallel, rows assembled in spec order: the winner scan
    // below sees exactly the sequence the serial loop produced.
    let rows: Vec<(KvSpec, ServingMetrics)> =
        par_map(specs, sim::profile::outer_threads(), &|_, &spec| {
            let cfg = sim_cfg.with_kv(spec);
            (spec, sim::simulate_serving(stream, model, hw, &cfg))
        });
    let best = rows
        .iter()
        .min_by(|a, b| a.1.objective().total_cmp(&b.1.objective()))
        .map(|(s, _)| *s)
        .unwrap_or(sim_cfg.kv);
    (best, rows)
}

// ---------------------------------------------------------------------
// Fleet co-exploration (multi-replica / disaggregated serving)
// ---------------------------------------------------------------------

/// Fleet design space under a total compute budget: candidate replica
/// counts x router policies, even disaggregated prefill/decode splits
/// (each replica sized to `total_tops / total_replicas`), heterogeneous
/// splits (prefill pool sized to an explicit share of the budget), and
/// SLO-shed admission margins — the co-search axes of the front-end
/// control plane.
#[derive(Debug, Clone)]
pub struct FleetSpace {
    /// Total compute budget across the fleet (TOPS).
    pub total_tops: f64,
    /// Homogeneous fleet sizes to consider.
    pub replica_counts: Vec<usize>,
    /// Router policies applied to each homogeneous replica count.
    pub routers: Vec<RouterPolicy>,
    /// Even disaggregated (prefill, decode) splits to consider.
    pub splits: Vec<(usize, usize)>,
    /// Heterogeneous disaggregated splits: `(n_prefill, n_decode,
    /// prefill share of total_tops)`. Pool-proportional would be
    /// `p / (p + d)`; shares below that favor the decode pool, which
    /// carries the token volume of decode-heavy serving traffic.
    pub hetero_splits: Vec<(usize, usize, f64)>,
    /// SLO-shed admission margins (TTFT multiples) to co-search; every
    /// shape is also scored under plain arrival-time rejection.
    pub shed_margins: Vec<f64>,
    /// KV handoff cost per migrated token for the splits (s/token).
    pub handoff_s_per_token: f64,
}

/// One scored point of the fleet co-search: a shape plus a front-end
/// admission setting.
#[derive(Debug, Clone)]
pub struct FleetCandidate {
    pub fleet: FleetConfig,
    /// SLO-shed margin (None = arrival-time rejection only).
    pub shed_margin: Option<f64>,
}

impl FleetCandidate {
    pub fn describe(&self) -> String {
        match self.shed_margin {
            Some(m) => format!("{} + shed x{m:.2}", self.fleet.describe()),
            None => self.fleet.describe(),
        }
    }

    /// The front end this candidate runs; `probe` calibrates the
    /// shedding estimator for the hardware under evaluation.
    pub fn frontend(&self, probe: sim::SimProbe) -> Frontend {
        match self.shed_margin {
            Some(m) => Frontend::with_shedding(probe, m),
            None => Frontend::baseline(),
        }
    }
}

impl FleetSpace {
    pub fn new(total_tops: f64) -> Self {
        FleetSpace {
            total_tops,
            replica_counts: vec![1, 2, 4],
            routers: vec![RouterPolicy::JoinShortestQueue],
            splits: vec![(1, 1), (1, 3)],
            hetero_splits: vec![(1, 3, 0.15)],
            shed_margins: Vec::new(),
            handoff_s_per_token: 1e-8,
        }
    }

    /// All fleet shapes the search scores.
    pub fn shapes(&self) -> Vec<FleetConfig> {
        let mut out: Vec<FleetConfig> = Vec::new();
        for &router in &self.routers {
            out.extend(
                self.replica_counts
                    .iter()
                    .map(|&n| FleetConfig::homogeneous(n, router)),
            );
        }
        out.extend(
            self.splits
                .iter()
                .map(|&(p, d)| FleetConfig::disaggregated(p, d, self.handoff_s_per_token)),
        );
        out.extend(self.hetero_splits.iter().map(|&(p, d, share)| {
            FleetConfig::disaggregated_hetero(p, d, self.handoff_s_per_token, share)
        }));
        out
    }

    /// The shape x admission-margin grid the co-search scores.
    pub fn candidates(&self) -> Vec<FleetCandidate> {
        let mut out = Vec::new();
        for fleet in self.shapes() {
            out.push(FleetCandidate {
                fleet: fleet.clone(),
                shed_margin: None,
            });
            for &m in &self.shed_margins {
                out.push(FleetCandidate {
                    fleet: fleet.clone(),
                    shed_margin: Some(m),
                });
            }
        }
        out
    }

    /// Per-replica TOPS share the BO search samples for one shape: the
    /// even per-replica split, except for heterogeneous splits where
    /// the search budget goes to the decode pool (it dominates serving
    /// goodput on decode-heavy traffic).
    fn searched_tops(&self, fleet: &FleetConfig) -> f64 {
        if fleet.router == RouterPolicy::PrefillDecode && fleet.prefill_tops_share > 0.0 {
            ((1.0 - fleet.prefill_tops_share) * self.total_tops / fleet.n_decode.max(1) as f64)
                .max(1.0)
        } else {
            (self.total_tops / fleet.total_replicas() as f64).max(1.0)
        }
    }

    /// Per-replica hardware space for one fleet shape: the paper's
    /// Table-IV space at the shape's searched per-replica share.
    pub fn space_for(&self, fleet: &FleetConfig) -> HwSpace {
        HwSpace::paper(self.searched_tops(fleet))
    }

    /// The per-replica hardware vector for one shape given the
    /// BO-searched configuration: every replica runs it, except a
    /// heterogeneous prefill pool, whose replicas get a representative
    /// package at their own TOPS share ([`HwSpace::representative`]).
    pub fn replica_hws(&self, fleet: &FleetConfig, searched: &HwConfig) -> Vec<HwConfig> {
        if fleet.router == RouterPolicy::PrefillDecode && fleet.prefill_tops_share > 0.0 {
            let p = fleet.n_prefill.max(1);
            let pre_tops = (fleet.prefill_tops_share * self.total_tops / p as f64).max(1.0);
            let mut hws = vec![HwSpace::representative(pre_tops); p];
            hws.extend(std::iter::repeat(searched.clone()).take(fleet.n_decode.max(1)));
            hws
        } else {
            vec![searched.clone(); fleet.total_replicas()]
        }
    }
}

/// Outcome of a fleet co-exploration run.
#[derive(Debug, Clone)]
pub struct FleetDseOutcome {
    /// Winning fleet shape.
    pub fleet: FleetConfig,
    /// Winning front-end admission margin (None = arrival rejection).
    pub shed_margin: Option<f64>,
    /// Winning BO-searched per-replica hardware configuration.
    pub hw: HwConfig,
    /// The full per-replica hardware vector actually simulated
    /// (differs from `vec![hw; n]` for heterogeneous shapes).
    pub hws: Vec<HwConfig>,
    pub metrics: FleetMetrics,
    /// Best-objective trajectory of the winning candidate's BO run.
    pub bo_history: Vec<f64>,
    /// Best objective reached per fleet-shape x admission candidate.
    pub per_shape: Vec<(FleetCandidate, f64)>,
    pub backend: &'static str,
}

/// Sim-backed fleet evaluation for a fixed per-replica hardware
/// configuration: replay `stream` across the fleet with a GA mapping
/// search per distinct batch shape on every replica (memoized per
/// replica, exactly like [`search_serving`]).
pub fn search_fleet(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    ga_cfg: &GaConfig,
    sim_cfg: &SimConfig,
    fleet: &FleetConfig,
) -> FleetMetrics {
    let cfg = sim_cfg.with_policy(MappingPolicy::Searched(*ga_cfg));
    sim::simulate_fleet(stream, model, hw, &cfg, fleet)
}

/// [`search_fleet`] with per-replica hardware and an explicit front
/// end (heterogeneous pools, SLO-shed admission, rebalancing).
pub fn search_fleet_frontend(
    stream: &RequestStream,
    model: &ModelSpec,
    hws: &[HwConfig],
    ga_cfg: &GaConfig,
    sim_cfg: &SimConfig,
    fleet: &FleetConfig,
    fe: &Frontend,
) -> FleetMetrics {
    let cfg = sim_cfg.with_policy(MappingPolicy::Searched(*ga_cfg));
    sim::simulate_fleet_frontend(stream, model, hws, &cfg, fleet, fe)
}

/// GP constructor handed to [`compass_dse_fleet`]: fleet candidates are
/// scored on scoped worker threads, so each candidate's BO loop builds
/// its own surrogate instead of sharing one `&mut dyn Gp`. Equivalent to
/// the old shared-GP signature bit for bit — every `Gp::fit` retrains
/// from scratch on its own observations, so a fresh surrogate per
/// candidate sees exactly the data the reused one did.
pub type GpFactory<'g> = dyn Fn() -> Box<dyn Gp + 'g> + Sync + 'g;

/// Compass scaled out: BO over per-replica hardware *per fleet
/// candidate* (replica count x router, even or heterogeneous
/// prefill/decode split, and SLO-shed admission margin, all under the
/// shared total-TOPS budget), the fleet simulator inside, maximizing
/// fleet SLO-constrained goodput via [`FleetMetrics::objective`]. The
/// shedding estimator is re-calibrated per hardware sample from the
/// stream itself ([`sim::probe_stream`]).
///
/// Candidates are evaluated in parallel (narrow outer width — each BO
/// loop already fans its GA evaluations across threads) and collected in
/// candidate-index order, so the strict-`<` argmin below tie-breaks to
/// the earliest candidate exactly as the serial loop did.
pub fn compass_dse_fleet(
    stream: &RequestStream,
    model: &ModelSpec,
    fspace: &FleetSpace,
    cfg: &DseConfig,
    sim_cfg: &SimConfig,
    make_gp: &GpFactory<'_>,
) -> FleetDseOutcome {
    let cands = fspace.candidates();
    let outer = if sim::profile::enabled() {
        1
    } else {
        (default_threads() / 4).max(1)
    };
    let results: Vec<bo::BoResult> = par_map(&cands, outer, &|_, cand| {
        let mut gp = make_gp();
        let space = fspace.space_for(&cand.fleet);
        bo::optimize(&space, &cfg.bo, gp.as_mut(), |hw| {
            let hws = fspace.replica_hws(&cand.fleet, hw);
            // probe calibration is only paid by shedding candidates,
            // and runs against the pool that produces the TTFT — the
            // prefill pool for disaggregated shapes (hws[0]), which
            // under hetero sizing is *not* the BO-searched package
            let fe = match cand.shed_margin {
                Some(_) => cand.frontend(sim::probe_stream(model, &hws[0], sim_cfg, stream)),
                None => Frontend::baseline(),
            };
            search_fleet_frontend(stream, model, &hws, &cfg.ga, sim_cfg, &cand.fleet, &fe)
                .objective()
        })
    });
    let per_shape: Vec<(FleetCandidate, f64)> = cands
        .iter()
        .zip(&results)
        .map(|(c, r)| (c.clone(), r.best.objective))
        .collect();
    let mut best_i = 0usize;
    for i in 1..results.len() {
        if results[i].best.objective < results[best_i].best.objective {
            best_i = i;
        }
    }
    let result = results
        .into_iter()
        .nth(best_i)
        .expect("fleet space yields at least one candidate");
    let cand = &cands[best_i];
    let hws = fspace.replica_hws(&cand.fleet, &result.best.hw);
    let fe = match cand.shed_margin {
        Some(_) => cand.frontend(sim::probe_stream(model, &hws[0], sim_cfg, stream)),
        None => Frontend::baseline(),
    };
    let metrics =
        search_fleet_frontend(stream, model, &hws, &cfg.ga, sim_cfg, &cand.fleet, &fe);
    FleetDseOutcome {
        fleet: cand.fleet.clone(),
        shed_margin: cand.shed_margin,
        hw: result.best.hw.clone(),
        hws,
        metrics,
        bo_history: result.history,
        per_shape,
        backend: result.backend,
    }
}

// ---------------------------------------------------------------------
// Resilience co-search (redundancy headroom x retry x drain)
// ---------------------------------------------------------------------

/// Resilience design space under a fixed fault schedule: how much
/// redundancy headroom (N+k replicas), which retry policy, and whether
/// to proactively drain ahead of scheduled crashes. Every candidate is
/// priced per replica, so spare capacity must buy enough goodput under
/// faults to justify its cost.
#[derive(Debug, Clone)]
pub struct ResilienceSpace {
    /// Fleet size the workload was provisioned for.
    pub base_replicas: usize,
    /// Spare-replica counts to consider (0 = no headroom).
    pub extra_replicas: Vec<usize>,
    /// Retry policies to consider.
    pub retries: Vec<RetryPolicy>,
    /// Whether to score the proactive pre-crash drain path.
    pub drain_options: Vec<bool>,
    /// Drain lead time ahead of each scheduled crash (s).
    pub drain_lead_s: f64,
    /// KV handoff cost per drained token (s/token).
    pub drain_handoff_s_per_token: f64,
}

impl ResilienceSpace {
    pub fn new(base_replicas: usize) -> Self {
        ResilienceSpace {
            base_replicas: base_replicas.max(1),
            extra_replicas: vec![0, 1],
            retries: vec![RetryPolicy::disabled(), RetryPolicy::capped(3, 0.25, 2.0)],
            drain_options: vec![false, true],
            drain_lead_s: 1.0,
            drain_handoff_s_per_token: 1e-8,
        }
    }
}

/// One scored point of the resilience search.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResilienceCandidate {
    pub extra_replicas: usize,
    pub retry: RetryPolicy,
    pub drain: bool,
}

impl ResilienceCandidate {
    pub fn describe(&self) -> String {
        format!(
            "N+{} | {}{}",
            self.extra_replicas,
            self.retry.describe(),
            if self.drain { " + drain" } else { "" }
        )
    }
}

/// Sweep redundancy headroom x retry policy x drain policy against one
/// seeded fault schedule on identical per-replica hardware, scoring each
/// candidate by cost-normalized SLO goodput under faults
/// (`slo_goodput_tps / n_replicas`, so a spare replica must earn its
/// keep). Returns the winner plus every candidate's metrics; ties keep
/// the earliest (cheapest-listed) candidate. Deterministic: the same
/// schedule gives the same sweep bit for bit.
pub fn search_resilience(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    sim_cfg: &SimConfig,
    fe: &Frontend,
    space: &ResilienceSpace,
    schedule: &FaultSchedule,
) -> (ResilienceCandidate, Vec<(ResilienceCandidate, FleetMetrics)>) {
    // Flatten the nested grid in its serial iteration order, then score
    // the candidates in parallel with index-ordered row assembly: the
    // strict-`>` argmax scan below tie-breaks to the earliest (cheapest-
    // listed) candidate exactly as the serial triple loop did.
    let mut cands: Vec<ResilienceCandidate> = Vec::new();
    for &extra in &space.extra_replicas {
        for &retry in &space.retries {
            for &drain in &space.drain_options {
                cands.push(ResilienceCandidate {
                    extra_replicas: extra,
                    retry,
                    drain,
                });
            }
        }
    }
    let rows: Vec<(ResilienceCandidate, FleetMetrics)> =
        par_map(&cands, sim::profile::outer_threads(), &|_, &cand| {
            let n = space.base_replicas + cand.extra_replicas;
            let fleet = FleetConfig::homogeneous(n, RouterPolicy::JoinShortestQueue);
            let hws = vec![hw.clone(); n];
            let res = ResilienceSpec {
                schedule: schedule.clone(),
                retry: cand.retry,
                drain: cand.drain.then(|| {
                    DrainSpec::new(
                        space.drain_lead_s,
                        space.drain_handoff_s_per_token,
                        sim_cfg.max_batch,
                    )
                }),
                failover: true,
            };
            let m = sim::simulate_fleet_faults(stream, model, &hws, sim_cfg, &fleet, fe, &res);
            (cand, m)
        });
    let score = |c: &ResilienceCandidate, m: &FleetMetrics| {
        m.slo_goodput_tps / (space.base_replicas + c.extra_replicas) as f64
    };
    let mut best = 0usize;
    for i in 1..rows.len() {
        if score(&rows[i].0, &rows[i].1) > score(&rows[best].0, &rows[best].1) {
            best = i;
        }
    }
    (rows[best].0, rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::NativeGp;
    use crate::workload::trace::{Trace, TraceSpec};

    fn tiny_scenario() -> (Scenario, ModelSpec) {
        let trace = Trace::new(&TraceSpec::sharegpt(), 64, 3);
        (Scenario::prefill(&trace, 2, 1), ModelSpec::tiny())
    }

    #[test]
    fn mapping_search_improves_over_first_generation() {
        let (scen, model) = tiny_scenario();
        let hw = crate::arch::HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let r = search_mappings(&scen, &model, &hw, &GaConfig::tiny(), 1);
        assert_eq!(r.mappings.len(), 1);
        assert!(r.mappings[0].is_valid(4));
        assert!(r.eval.latency_cycles > 0.0);
    }

    #[test]
    fn full_dse_runs_end_to_end_and_hits_target_tops() {
        let (scen, model) = tiny_scenario();
        let space = HwSpace::paper(64.0);
        let cfg = DseConfig::tiny();
        let mut gp = NativeGp::new();
        let out = compass_dse(&scen, &model, &space, &cfg, &mut gp);
        assert_eq!(out.backend, "native");
        let tops = out.hw.total_tops();
        assert!((tops - 64.0).abs() / 64.0 < 0.5, "tops {tops}");
        assert_eq!(out.mappings.len(), scen.groups.len());
        assert!(out.eval.total_cost() > 0.0);
        // history covers every BO round and never regresses
        assert_eq!(out.bo_history.len(), cfg.bo.rounds);
        for w in out.bo_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    fn tiny_sim_setup() -> (RequestStream, ModelSpec, SimConfig) {
        let spec = TraceSpec {
            mean_in: 48.0,
            mean_out: 6.0,
            sigma_in: 0.4,
            sigma_out: 0.3,
            max_len: 2048,
            shared_prefix_tokens: 0,
        };
        let mut cfg = SimConfig::new(crate::workload::serving::ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.chunk_tokens = 32;
        cfg.kv_budget_tokens = 2048;
        cfg.ctx_bucket = 64;
        cfg.eval_blocks = 1;
        cfg.slo = crate::sim::SloSpec::new(1.0, 0.5);
        (
            RequestStream::poisson(&spec, 50.0, 6, 13),
            ModelSpec::tiny(),
            cfg,
        )
    }

    #[test]
    fn search_serving_is_deterministic_and_conserves() {
        let (stream, model, cfg) = tiny_sim_setup();
        let hw = crate::arch::HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let a = search_serving(&stream, &model, &hw, &GaConfig::tiny(), &cfg);
        let b = search_serving(&stream, &model, &hw, &GaConfig::tiny(), &cfg);
        assert_eq!(a.n_completed + a.n_rejected, a.n_arrived);
        assert!(a.n_completed > 0);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.throughput_tps.to_bits(), b.throughput_tps.to_bits());
        assert_eq!(a.ttft.p99.to_bits(), b.ttft.p99.to_bits());
        assert!(a.distinct_shapes > 0);
    }

    #[test]
    fn search_resilience_sweeps_the_grid_and_is_deterministic() {
        let (stream, model, cfg) = tiny_sim_setup();
        let hw = crate::arch::HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let space = ResilienceSpace::new(2);
        let schedule = FaultSchedule::none().crash(0, 0.05, 0.2);
        let fe = Frontend::baseline();
        let (best, rows) =
            search_resilience(&stream, &model, &hw, &cfg, &fe, &space, &schedule);
        assert_eq!(
            rows.len(),
            space.extra_replicas.len() * space.retries.len() * space.drain_options.len()
        );
        for (c, m) in &rows {
            assert_eq!(m.n_completed + m.n_rejected, m.n_arrived, "{}", c.describe());
            assert_eq!(m.faults.n_crashes, 1, "{}", c.describe());
        }
        assert!(best.extra_replicas <= 1);
        let (best2, rows2) =
            search_resilience(&stream, &model, &hw, &cfg, &fe, &space, &schedule);
        assert_eq!(best.describe(), best2.describe());
        for (a, b) in rows.iter().zip(&rows2) {
            assert_eq!(a.1.slo_goodput_tps.to_bits(), b.1.slo_goodput_tps.to_bits());
        }
    }

    #[test]
    fn search_fleet_is_deterministic_and_conserves() {
        let (stream, model, cfg) = tiny_sim_setup();
        let hw = crate::arch::HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let fleet = FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue);
        let a = search_fleet(&stream, &model, &hw, &GaConfig::tiny(), &cfg, &fleet);
        let b = search_fleet(&stream, &model, &hw, &GaConfig::tiny(), &cfg, &fleet);
        assert_eq!(a.n_completed + a.n_rejected, a.n_arrived);
        assert_eq!(a.per_replica.len(), 2);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.slo_goodput_tps.to_bits(), b.slo_goodput_tps.to_bits());
    }

    #[test]
    fn fleet_dse_runs_end_to_end_over_candidates() {
        let (stream, model, cfg) = tiny_sim_setup();
        let mut fspace = FleetSpace::new(64.0);
        fspace.replica_counts = vec![2];
        fspace.routers = vec![RouterPolicy::JoinShortestQueue];
        fspace.splits = vec![];
        fspace.hetero_splits = vec![(1, 1, 0.3)];
        fspace.shed_margins = vec![1.5];
        // shapes: 1 homogeneous + 1 hetero split; x {no-shed, shed}
        assert_eq!(fspace.shapes().len(), 2);
        assert_eq!(fspace.candidates().len(), 4);
        let dse_cfg = DseConfig::tiny();
        let make_gp = || -> Box<dyn Gp> { Box::new(NativeGp::new()) };
        let out = compass_dse_fleet(&stream, &model, &fspace, &dse_cfg, &cfg, &make_gp);
        assert_eq!(out.backend, "native");
        assert_eq!(out.per_shape.len(), 4);
        assert_eq!(out.bo_history.len(), dse_cfg.bo.rounds);
        assert_eq!(out.hws.len(), out.fleet.total_replicas());
        assert_eq!(
            out.metrics.n_completed + out.metrics.n_rejected,
            out.metrics.n_arrived
        );
        // the winner's objective is the minimum over candidates
        let min = out
            .per_shape
            .iter()
            .map(|(_, o)| *o)
            .fold(f64::INFINITY, f64::min);
        let winner_label = FleetCandidate {
            fleet: out.fleet.clone(),
            shed_margin: out.shed_margin,
        }
        .describe();
        assert_eq!(
            out.per_shape
                .iter()
                .find(|(c, _)| c.describe() == winner_label)
                .map(|(_, o)| *o),
            Some(min)
        );
        for w in out.bo_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    /// Heterogeneous sizing really produces differently-sized pools:
    /// the prefill replica's package is smaller than the searched
    /// decode replica's budget when the prefill share is small.
    #[test]
    fn hetero_replica_hws_split_the_budget() {
        let fspace = FleetSpace::new(512.0);
        let hetero = FleetConfig::disaggregated_hetero(1, 3, 1e-8, 0.25);
        // searched (decode) share: 0.75 * 512 / 3 = 128 TOPS
        assert!((fspace.searched_tops(&hetero) - 128.0).abs() < 1e-9);
        let searched = crate::arch::HwSpace::representative(128.0);
        let hws = fspace.replica_hws(&hetero, &searched);
        assert_eq!(hws.len(), 4);
        // a small prefill share yields a smaller prefill package than
        // the searched decode replicas
        let skewed = FleetConfig::disaggregated_hetero(1, 3, 1e-8, 0.05);
        let hws2 = fspace.replica_hws(&skewed, &searched);
        assert!(
            hws2[0].total_tops() < hws2[1].total_tops(),
            "prefill {} vs decode {}",
            hws2[0].total_tops(),
            hws2[1].total_tops()
        );
        // even shapes replicate the searched config on every replica
        let even = FleetConfig::disaggregated(1, 3, 1e-8);
        let hws3 = fspace.replica_hws(&even, &searched);
        assert!(hws3.iter().all(|h| h == &searched));
    }

    #[test]
    fn kv_search_scores_every_spec_and_picks_the_best() {
        let (stream, model, mut cfg) = tiny_sim_setup();
        cfg.policy = MappingPolicy::Pipeline;
        let hw = crate::arch::HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let specs = [
            KvSpec::token_granular(),
            KvSpec::paged(16),
            KvSpec::token_granular().with_dtype(crate::sim::KvDtype::Int4),
        ];
        let (best, rows) = search_kv(&stream, &model, &hw, &cfg, &specs);
        assert_eq!(rows.len(), specs.len());
        let best_obj = rows
            .iter()
            .map(|(_, m)| m.objective())
            .fold(f64::INFINITY, f64::min);
        let found = rows
            .iter()
            .find(|(s, _)| s.describe() == best.describe())
            .expect("winner is one of the candidates");
        assert_eq!(found.1.objective().to_bits(), best_obj.to_bits());
        for (_, m) in &rows {
            assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
        }
    }

    #[test]
    fn serving_dse_runs_end_to_end() {
        let (stream, model, cfg) = tiny_sim_setup();
        let space = HwSpace::paper(64.0);
        let dse_cfg = DseConfig::tiny();
        let mut gp = NativeGp::new();
        let out = compass_dse_serving(&stream, &model, &space, &dse_cfg, &cfg, &mut gp);
        assert_eq!(out.backend, "native");
        assert_eq!(out.bo_history.len(), dse_cfg.bo.rounds);
        assert_eq!(
            out.metrics.n_completed + out.metrics.n_rejected,
            out.metrics.n_arrived
        );
        for w in out.bo_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }
}

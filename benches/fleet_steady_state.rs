//! Fleet-simulator throughput bench: how many simulated seconds of
//! multi-replica traffic one wall-clock second buys, per router policy
//! (EXPERIMENTS.md "Fleet serving"). Complements `sim_steady_state`,
//! which measures one package.
//!
//! With `--large` (or `COMPASS_BENCH_LARGE=1`) it additionally runs
//! the PR 8 steady-state scale cell — 100 000 requests across 32
//! replicas through the allocation-free hot path with parallel
//! replica stepping — and reports simulated-seconds-per-wall-second
//! against the budget recorded in `BENCH_engine_micro.json`
//! (`fleet_large_sim_s_per_wall_s`). The large cell runs twice — decode
//! fast-forward on (the default) and off (`COMPASS_COALESCE=0`) — and
//! prints the wall-clock speedup against the
//! `fleet_large_coalesce_speedup >= 3.0` budget, asserting the two runs
//! bitwise-agree first. The default run stays small so CI's
//! non-blocking sanity step finishes in seconds.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{self, FleetConfig, Frontend, RouterPolicy, SimConfig};
use compass::util::Bench;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

/// The PR 8 scale cell: one measured run (no repetition — the stream
/// itself amortizes) of 1e5 requests over 32 replicas, tiny model so
/// the bench measures the simulator, not the cost model.
fn run_large() {
    let model = ModelSpec::tiny();
    let hw = HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    );
    let spec = TraceSpec {
        mean_in: 128.0,
        mean_out: 32.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 8192,
        shared_prefix_tokens: 0,
    };
    let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
    cfg.max_batch = 16;
    cfg.eval_blocks = 1;
    cfg.ctx_bucket = 256;
    cfg.max_iterations = usize::MAX;
    let probe = sim::probe(&model, &hw, &cfg, &spec);
    cfg.slo = probe.slo(3.0, 4.0);
    let n_replicas = 32usize;
    let n_requests = 100_000usize;
    let rate = 0.85 * n_replicas as f64 * probe.capacity_rps();
    let stream = sim::RequestStream::poisson(&spec, rate, n_requests, 7);
    let fleet = FleetConfig::homogeneous(n_replicas, RouterPolicy::JoinShortestQueue);
    let hws = vec![hw.clone(); n_replicas];
    println!(
        "fleet_steady_state/large: {n_requests} requests @ {rate:.1} req/s \
         over {n_replicas} replicas ({} threads)",
        compass::cost::engine::default_threads()
    );
    // One measured run per coalescing mode. The schedulers read
    // COMPASS_COALESCE at construction, so forcing it here (and
    // restoring the caller's value after) pins the mode per run.
    let run_once = |coalesce_on: bool| {
        let old = std::env::var("COMPASS_COALESCE").ok();
        std::env::set_var("COMPASS_COALESCE", if coalesce_on { "1" } else { "0" });
        let t0 = std::time::Instant::now();
        let m =
            sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &Frontend::baseline());
        let wall = t0.elapsed().as_secs_f64();
        match old {
            Some(v) => std::env::set_var("COMPASS_COALESCE", v),
            None => std::env::remove_var("COMPASS_COALESCE"),
        }
        (m, wall)
    };
    let (m_on, wall_on) = run_once(true);
    let (m_off, wall_off) = run_once(false);
    for (label, m, wall) in [
        ("coalesce=on ", &m_on, wall_on),
        ("coalesce=off", &m_off, wall_off),
    ] {
        let iters: usize = m.per_replica.iter().map(|r| r.n_iterations).sum();
        println!(
            "    large cell [{label}]: sim {:.1}s / wall {:.1}s -> {:.1} sim-s per wall-s | \
             {} completed / {} arrived | {} iterations | {:.0} iters/wall-s",
            m.makespan_s,
            wall,
            m.makespan_s / wall.max(1e-12),
            m.n_completed,
            m.n_arrived,
            iters,
            iters as f64 / wall.max(1e-12),
        );
    }
    // Fast-forward is a pure perf transform: refuse to report a speedup
    // for runs that disagree anywhere it would show.
    assert_eq!(
        m_on.makespan_s.to_bits(),
        m_off.makespan_s.to_bits(),
        "coalesce on/off diverged (makespan)"
    );
    assert_eq!(m_on.n_completed, m_off.n_completed, "coalesce on/off diverged (completed)");
    assert_eq!(
        m_on.energy_pj.to_bits(),
        m_off.energy_pj.to_bits(),
        "coalesce on/off diverged (energy)"
    );
    println!(
        "    coalesce speedup: {:.2}x wall (budget fleet_large_coalesce_speedup >= 3.0)",
        wall_off / wall_on.max(1e-12),
    );
}

fn main() {
    let large = std::env::args().any(|a| a == "--large")
        || std::env::var("COMPASS_BENCH_LARGE").map_or(false, |v| v == "1");
    if large {
        run_large();
        return;
    }
    let model = ModelSpec::gpt3_7b();
    let hw = HwConfig::homogeneous(
        2,
        4,
        ChipletClass::M,
        Dataflow::WeightStationary,
        64.0,
        32.0,
    );
    let spec = TraceSpec {
        mean_in: 256.0,
        mean_out: 64.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 16_384,
        shared_prefix_tokens: 0,
    };
    let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
    cfg.max_batch = 16;
    cfg.eval_blocks = 1;
    cfg.ctx_bucket = 256;
    let probe = sim::probe(&model, &hw, &cfg, &spec);
    cfg.slo = probe.slo(3.0, 4.0);
    let n_replicas = 4usize;
    let rate = 0.9 * n_replicas as f64 * probe.capacity_rps();
    let stream = sim::RequestStream::poisson(&spec, rate, 96, 7);
    let fleets = [
        FleetConfig::homogeneous(n_replicas, RouterPolicy::RoundRobin),
        FleetConfig::homogeneous(n_replicas, RouterPolicy::JoinShortestQueue),
        FleetConfig::disaggregated(1, n_replicas - 1, 1e-8),
    ];

    println!(
        "fleet_steady_state: 96 requests @ {:.3} req/s (0.9x fleet capacity), \
         model {}, {} replicas of {}",
        rate,
        model.name,
        n_replicas,
        hw.describe()
    );
    for fleet in &fleets {
        // one cold run for the shape/iteration counts
        let cold = sim::simulate_fleet(&stream, &model, &hw, &cfg, fleet);
        let iters: usize = cold.per_replica.iter().map(|m| m.n_iterations).sum();
        let wall = Bench::new(&format!("fleet_steady_state/{}", fleet.router.name()))
            .budget_ms(2000)
            .run(|| sim::simulate_fleet(&stream, &model, &hw, &cfg, fleet));
        println!(
            "    {:<22} sim {:>9.3}s / wall -> {:>10.1} sim-s per wall-s | \
             {} iterations total | {:.0} iters/wall-s | imbalance {:.3} | kv-handoff {} tok",
            fleet.describe(),
            cold.makespan_s,
            cold.makespan_s / wall.max(1e-12),
            iters,
            iters as f64 / wall.max(1e-12),
            cold.load_imbalance,
            cold.kv_transfer_tokens,
        );
    }
}

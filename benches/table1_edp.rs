//! Bench T1: regenerate paper Table I (EDP ratio OS/WS per phase x
//! sequence length) and time the single-GEMM EDP probe.
use compass::arch::{Chiplet, ChipletClass, Dataflow};
use compass::cost::{edp_of, edp_probe};
use compass::util::Bench;
use compass::workload::Phase;

fn main() {
    compass::experiments::table1(64.0).print();
    let chip = Chiplet { class: ChipletClass::M, dataflow: Dataflow::WeightStationary };
    Bench::new("edp_probe/qkv@5120").run(|| {
        edp_of(edp_probe(Phase::QkvGen, 5120, 4096, 16384, 128, chip, 64.0))
    });
    Bench::new("edp_probe/full-table").run(|| compass::experiments::table1(64.0));
}

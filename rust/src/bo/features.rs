//! Featurisation of hardware configurations for the GP composite kernel
//! (paper Eq. 2-4), padded to the fixed AOT artifact shapes.
//!
//! * `z_sys`    → an `SYS_D` feature vector (log2-scaled discrete knobs);
//! * `z_shape`  → the `(H, W)` pair for the indicator term;
//! * `z_layout` → a one-hot `(SLOTS, TYPES)` grid. The actual `H x W`
//!   grid is embedded top-left into the padded 16x16 slot grid so that
//!   Manhattan distances (Eq. 4) are preserved; empty slots are all-zero
//!   rows and contribute nothing to the layout kernel.

use crate::arch::{ChipletClass, Dataflow, HwConfig};
use crate::runtime::shapes::{SLOTS, SYS_D, TYPES};

/// Side of the padded slot grid (`PAD_SIDE^2 == SLOTS`).
pub const PAD_SIDE: usize = 16;

/// Featurised hardware configuration.
#[derive(Debug, Clone)]
pub struct HwFeatures {
    pub sys: [f32; SYS_D],
    pub shape: [f32; 2],
    /// Row-major `(SLOTS, TYPES)` one-hot layout.
    pub layout: Vec<f32>,
}

/// Type index of a dataflow in the one-hot vocabulary.
pub fn type_index(df: Dataflow) -> usize {
    match df {
        Dataflow::WeightStationary => 0,
        Dataflow::OutputStationary => 1,
    }
}

fn class_index(c: ChipletClass) -> f32 {
    match c {
        ChipletClass::S => 0.0,
        ChipletClass::M => 1.0,
        ChipletClass::L => 2.0,
    }
}

/// Featurise one configuration.
pub fn featurize(hw: &HwConfig) -> HwFeatures {
    let mut sys = [0f32; SYS_D];
    sys[0] = (hw.nop_bw_gbs as f32).log2();
    sys[1] = (hw.dram_bw_gbs as f32).log2();
    sys[2] = (hw.micro_batch_prefill.max(1) as f32).log2();
    sys[3] = (hw.micro_batch_decode.max(1) as f32).log2();
    sys[4] = (hw.tensor_parallel.max(1) as f32).log2();
    sys[5] = class_index(hw.class);
    // sys[6], sys[7] reserved (zero; disabled via zero inverse lengthscale)

    let mut layout = vec![0f32; SLOTS * TYPES];
    for y in 0..hw.grid_h.min(PAD_SIDE) {
        for x in 0..hw.grid_w.min(PAD_SIDE) {
            let src = y * hw.grid_w + x;
            let dst = y * PAD_SIDE + x;
            layout[dst * TYPES + type_index(hw.layout[src])] = 1.0;
        }
    }
    HwFeatures {
        sys,
        shape: [hw.grid_h as f32, hw.grid_w as f32],
        layout,
    }
}

/// Inverse lengthscales for the sys-RBF kernel: a single learned scale
/// applied to the active dims, zero on padding.
pub fn inv_lengthscales(ls: f32) -> [f32; SYS_D] {
    let mut out = [0f32; SYS_D];
    for item in out.iter_mut().take(6) {
        *item = 1.0 / ls.max(1e-3);
    }
    out
}

/// Manhattan positional-similarity weights over the padded grid
/// (Eq. 4): `W[u, v] = exp(-(|x_u - x_v| + |y_u - y_v|) / lambda)`.
pub fn manhattan_weights(lambda: f32) -> Vec<f32> {
    let mut w = vec![0f32; SLOTS * SLOTS];
    for u in 0..SLOTS {
        let (xu, yu) = ((u % PAD_SIDE) as i32, (u / PAD_SIDE) as i32);
        for v in 0..SLOTS {
            let (xv, yv) = ((v % PAD_SIDE) as i32, (v / PAD_SIDE) as i32);
            let d = (xu - xv).abs() + (yu - yv).abs();
            w[u * SLOTS + v] = (-(d as f32) / lambda.max(1e-3)).exp();
        }
    }
    w
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::HwConfig;

    fn hw() -> HwConfig {
        let mut h = HwConfig::homogeneous(2, 4, ChipletClass::M, Dataflow::WeightStationary, 64.0, 32.0);
        h.layout[3] = Dataflow::OutputStationary;
        h.layout[5] = Dataflow::OutputStationary;
        h
    }

    #[test]
    fn one_hot_layout_counts_match() {
        let f = featurize(&hw());
        let total: f32 = f.layout.iter().sum();
        assert_eq!(total, 8.0); // 8 occupied slots
        let os: f32 = (0..SLOTS).map(|u| f.layout[u * TYPES + 1]).sum();
        assert_eq!(os, 2.0);
    }

    #[test]
    fn layout_preserves_grid_geometry() {
        let f = featurize(&hw());
        // grid (2,4): slot (x=3, y=0) -> padded index 3; (x=1, y=1) -> 17
        assert_eq!(f.layout[3 * TYPES + 1], 1.0); // OS at x=3,y=0
        assert_eq!(f.layout[(PAD_SIDE + 1) * TYPES + 1], 1.0); // OS at x=1,y=1
        // everything outside the 2x4 block is empty
        for y in 2..PAD_SIDE {
            for x in 0..PAD_SIDE {
                let u = y * PAD_SIDE + x;
                assert!(f.layout[u * TYPES..(u + 1) * TYPES].iter().all(|&v| v == 0.0));
            }
        }
    }

    #[test]
    fn sys_features_log_scaled() {
        let f = featurize(&hw());
        assert_eq!(f.sys[0], 6.0); // log2 64
        assert_eq!(f.sys[1], 5.0); // log2 32
        assert_eq!(f.sys[5], 1.0); // class M
        assert_eq!(f.sys[6], 0.0);
        assert_eq!(f.shape, [2.0, 4.0]);
    }

    #[test]
    fn manhattan_weights_match_eq4() {
        let w = manhattan_weights(2.0);
        assert_eq!(w.len(), SLOTS * SLOTS);
        assert_eq!(w[0], 1.0); // self distance 0
        let d1 = w[1]; // (0,0) -> (1,0): distance 1
        assert!((d1 - (-0.5f32).exp()).abs() < 1e-6);
        // symmetric
        for u in [0usize, 17, 100] {
            for v in [3usize, 40, 255] {
                assert_eq!(w[u * SLOTS + v], w[v * SLOTS + u]);
            }
        }
    }

    #[test]
    fn inv_lengthscales_disable_padding() {
        let ils = inv_lengthscales(2.0);
        assert!(ils[..6].iter().all(|&x| (x - 0.5).abs() < 1e-6));
        assert_eq!(ils[6], 0.0);
        assert_eq!(ils[7], 0.0);
    }
}

//! GA variation operators (paper §V-A + Table III).

use crate::mapping::Mapping;
use crate::util::Rng;

/// Uniformly random valid mapping.
pub fn random_mapping(rows: usize, cols: usize, num_chips: usize, rng: &mut Rng) -> Mapping {
    let mut m = Mapping::new(rows, cols);
    for g in m.layer_to_chip.iter_mut() {
        *g = rng.gen_index(num_chips) as u16;
    }
    for s in m.segmentation.iter_mut() {
        *s = rng.gen_bool(0.15);
    }
    m
}

/// Crossover: bitwise for `segmentation` (each bit from a random parent);
/// subgraph-level for `layer_to_chip` — subgraphs are determined by the
/// *child's* crossed segmentation, and each (micro-batch, segment) block
/// is inherited wholesale from one parent ("balances randomness and local
/// stability of the computation graph").
pub fn crossover(a: &Mapping, b: &Mapping, rng: &mut Rng) -> Mapping {
    debug_assert_eq!((a.rows, a.cols), (b.rows, b.cols));
    let mut child = Mapping::new(a.rows, a.cols);
    for i in 0..child.segmentation.len() {
        child.segmentation[i] = if rng.gen_bool(0.5) {
            a.segmentation[i]
        } else {
            b.segmentation[i]
        };
    }
    for (s, e) in child.segments() {
        for mb in 0..child.rows {
            let parent = if rng.gen_bool(0.5) { a } else { b };
            for l in s..e {
                child.set_chip(mb, l, parent.chip(mb, l));
            }
        }
    }
    child
}

/// Segmentation mutations: bit-flip or bit-swap (adjacent).
pub fn mutate_segmentation(m: &mut Mapping, rng: &mut Rng) {
    if m.segmentation.is_empty() {
        return;
    }
    let i = rng.gen_index(m.segmentation.len());
    if rng.gen_bool(0.5) {
        // bit-flip
        m.segmentation[i] = !m.segmentation[i];
    } else {
        // bit-swap with previous or next
        let j = if i == 0 {
            1.min(m.segmentation.len() - 1)
        } else if i + 1 == m.segmentation.len() {
            i - 1
        } else if rng.gen_bool(0.5) {
            i - 1
        } else {
            i + 1
        };
        m.segmentation.swap(i, j);
    }
}

/// The seven `layer_to_chip` mutation operators of Table III.
///
/// `phase` in [0, 1) adapts the operator distribution: early phases favour
/// the graph-level operators (6-7), late phases the layer-level ones (1-3).
pub fn mutate_layer_to_chip(m: &mut Mapping, num_chips: usize, phase: f64, rng: &mut Rng) {
    let op = pick_operator(phase, rng);
    apply_operator(m, num_chips, op, rng);
}

/// Sample a Table-III operator id (1..=7) for the given phase.
pub fn pick_operator(phase: f64, rng: &mut Rng) -> u8 {
    // weights linearly interpolate between an exploration profile
    // (graph-level heavy) and a fine-tuning profile (layer-level heavy)
    let explore = [1.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0];
    let tune = [4.0, 3.0, 3.0, 1.5, 1.5, 0.5, 0.5];
    let t = phase.clamp(0.0, 1.0);
    let w: Vec<f64> = (0..7)
        .map(|i| explore[i] * (1.0 - t) + tune[i] * t)
        .collect();
    let total: f64 = w.iter().sum();
    let mut x = rng.gen_f64() * total;
    for (i, wi) in w.iter().enumerate() {
        x -= wi;
        if x <= 0.0 {
            return (i + 1) as u8;
        }
    }
    7
}

/// Apply one Table-III operator.
pub fn apply_operator(m: &mut Mapping, num_chips: usize, op: u8, rng: &mut Rng) {
    let rows = m.rows;
    let cols = m.cols;
    match op {
        // 1: replace one position with a new random chiplet
        1 => {
            let mb = rng.gen_index(rows);
            let l = rng.gen_index(cols);
            m.set_chip(mb, l, rng.gen_index(num_chips) as u16);
        }
        // 2: swap with the adjacent position along the layer dimension
        2 => {
            if cols < 2 {
                return apply_operator(m, num_chips, 1, rng);
            }
            let mb = rng.gen_index(rows);
            let l = rng.gen_index(cols - 1);
            let (a, b) = (m.chip(mb, l), m.chip(mb, l + 1));
            m.set_chip(mb, l, b);
            m.set_chip(mb, l + 1, a);
        }
        // 3: swap with the adjacent position along the batch dimension
        3 => {
            if rows < 2 {
                return apply_operator(m, num_chips, 1, rng);
            }
            let mb = rng.gen_index(rows - 1);
            let l = rng.gen_index(cols);
            let (a, b) = (m.chip(mb, l), m.chip(mb + 1, l));
            m.set_chip(mb, l, b);
            m.set_chip(mb + 1, l, a);
        }
        // 4: randomly permute the entries of one subgraph
        4 => {
            let segs = m.segments();
            let (s, e) = *rng.choose(&segs);
            let mb = rng.gen_index(rows);
            let mut vals: Vec<u16> = (s..e).map(|l| m.chip(mb, l)).collect();
            rng.shuffle(&mut vals);
            for (l, v) in (s..e).zip(vals) {
                m.set_chip(mb, l, v);
            }
        }
        // 5: replace every entry of one subgraph with random chiplets
        5 => {
            let segs = m.segments();
            let (s, e) = *rng.choose(&segs);
            let mb = rng.gen_index(rows);
            for l in s..e {
                m.set_chip(mb, l, rng.gen_index(num_chips) as u16);
            }
        }
        // 6: swap one column of subgraphs with another column
        6 => {
            let segs = m.segments();
            if segs.len() < 2 {
                // no second column: degrade to a multiset-preserving op
                return apply_operator(m, num_chips, 4, rng);
            }
            let i = rng.gen_index(segs.len());
            let j = rng.gen_index(segs.len());
            if i == j {
                return apply_operator(m, num_chips, 4, rng);
            }
            let (s0, e0) = segs[i];
            let (s1, e1) = segs[j];
            let w = (e0 - s0).min(e1 - s1);
            for mb in 0..rows {
                for off in 0..w {
                    let (a, b) = (m.chip(mb, s0 + off), m.chip(mb, s1 + off));
                    m.set_chip(mb, s0 + off, b);
                    m.set_chip(mb, s1 + off, a);
                }
            }
        }
        // 7: swap the entries of one batch row with another
        _ => {
            if rows < 2 {
                return apply_operator(m, num_chips, 4, rng);
            }
            let i = rng.gen_index(rows);
            let mut j = rng.gen_index(rows);
            if i == j {
                j = (j + 1) % rows;
            }
            for l in 0..cols {
                let (a, b) = (m.chip(i, l), m.chip(j, l));
                m.set_chip(i, l, b);
                m.set_chip(j, l, a);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(rows: usize, cols: usize, chips: usize, seed: u64) -> (Mapping, Rng) {
        let mut rng = Rng::seed_from_u64(seed);
        (random_mapping(rows, cols, chips, &mut rng), rng)
    }

    #[test]
    fn random_mapping_valid() {
        let (m, _) = mk(4, 12, 6, 0);
        assert!(m.is_valid(6));
    }

    #[test]
    fn crossover_inherits_from_parents_only() {
        let mut rng = Rng::seed_from_u64(1);
        let a = random_mapping(3, 9, 5, &mut rng);
        let b = random_mapping(3, 9, 5, &mut rng);
        for _ in 0..20 {
            let c = crossover(&a, &b, &mut rng);
            assert!(c.is_valid(5));
            for mb in 0..3 {
                for l in 0..9 {
                    let v = c.chip(mb, l);
                    assert!(
                        v == a.chip(mb, l) || v == b.chip(mb, l),
                        "child gene not from a parent"
                    );
                }
            }
            for i in 0..c.segmentation.len() {
                assert!(
                    c.segmentation[i] == a.segmentation[i]
                        || c.segmentation[i] == b.segmentation[i]
                );
            }
        }
    }

    #[test]
    fn crossover_subgraph_blocks_are_contiguous() {
        // with distinct parent alphabets, each (mb, segment) block of the
        // child must be uniformly from one parent
        let mut rng = Rng::seed_from_u64(2);
        let mut a = Mapping::new(2, 8);
        let mut b = Mapping::new(2, 8);
        for g in a.layer_to_chip.iter_mut() {
            *g = 0;
        }
        for g in b.layer_to_chip.iter_mut() {
            *g = 1;
        }
        for _ in 0..10 {
            let c = crossover(&a, &b, &mut rng);
            for (s, e) in c.segments() {
                for mb in 0..2 {
                    let first = c.chip(mb, s);
                    assert!(
                        (s..e).all(|l| c.chip(mb, l) == first),
                        "block not inherited wholesale"
                    );
                }
            }
        }
    }

    #[test]
    fn every_operator_preserves_validity() {
        for op in 1..=7u8 {
            let (mut m, mut rng) = mk(4, 10, 6, op as u64);
            for _ in 0..50 {
                apply_operator(&mut m, 6, op, &mut rng);
                assert!(m.is_valid(6), "operator {op} broke validity");
            }
        }
    }

    #[test]
    fn operators_2_3_4_6_7_preserve_multiset() {
        // swap/permute operators must not create or destroy chip ids
        for op in [2u8, 3, 4, 6, 7] {
            let (mut m, mut rng) = mk(4, 10, 6, 100 + op as u64);
            let mut before = m.layer_to_chip.clone();
            before.sort();
            for _ in 0..25 {
                apply_operator(&mut m, 6, op, &mut rng);
            }
            let mut after = m.layer_to_chip.clone();
            after.sort();
            assert_eq!(before, after, "operator {op} changed the multiset");
        }
    }

    #[test]
    fn segmentation_mutations_flip_or_swap() {
        let mut rng = Rng::seed_from_u64(7);
        let mut m = Mapping::new(2, 10);
        m.segmentation = vec![true, false, true, false, false, true, false, true, false];
        let count = |m: &Mapping| m.segmentation.iter().filter(|&&s| s).count();
        for _ in 0..100 {
            let before = count(&m);
            mutate_segmentation(&mut m, &mut rng);
            let after = count(&m);
            assert!((before as i64 - after as i64).abs() <= 1);
        }
    }

    #[test]
    fn operator_schedule_shifts_with_phase() {
        let mut rng = Rng::seed_from_u64(11);
        let sample = |phase: f64, rng: &mut Rng| {
            let mut counts = [0usize; 7];
            for _ in 0..4000 {
                counts[(pick_operator(phase, rng) - 1) as usize] += 1;
            }
            counts
        };
        let early = sample(0.0, &mut rng);
        let late = sample(0.95, &mut rng);
        let graph_early = early[5] + early[6];
        let graph_late = late[5] + late[6];
        let layer_early = early[0] + early[1] + early[2];
        let layer_late = late[0] + late[1] + late[2];
        assert!(
            graph_early > graph_late,
            "graph-level ops must fade: {graph_early} -> {graph_late}"
        );
        assert!(
            layer_late > layer_early,
            "layer-level ops must grow: {layer_early} -> {layer_late}"
        );
    }
}

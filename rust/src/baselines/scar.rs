//! SCAR-style heuristic mapping (paper §VI-G Fig. 11 ablation): the
//! multi-model scheduling heuristic of SCAR migrated onto the Compass
//! mapping representation — greedy load-balanced placement of layer
//! segments with locality clustering (consecutive layers of a micro-batch
//! stay on the same chiplet; chiplets are picked by
//! least-accumulated-load first).

use crate::arch::HwConfig;
use crate::cost::dataflow::layer_cost;
use crate::cost::{group_params, Evaluator};
use crate::dse::MappingSearch;
use crate::mapping::Mapping;
use crate::workload::serving::Scenario;
use crate::workload::{build_workload, ModelSpec, Workload};

/// Build the SCAR-style mapping for one workload: split each micro-batch
/// column into `num_chips`-sized contiguous segments and place each
/// segment on the currently least-loaded chiplet (load measured by the
/// intra-chiplet cost model).
pub fn scar_mapping(workload: &Workload, hw: &HwConfig) -> Mapping {
    let rows = workload.num_micro_batches();
    let cols = workload.layers_per_mb;
    let chips = hw.num_chiplets();
    let mut m = Mapping::new(rows, cols);
    // segment the model into chip-count-sized slabs (SCAR schedules at
    // sub-model granularity); mark the boundaries in the encoding
    let seg_len = cols.div_ceil(chips).max(1);
    for i in 0..cols.saturating_sub(1) {
        if (i + 1) % seg_len == 0 {
            m.segmentation[i] = true;
        }
    }
    let mut load = vec![0f64; chips];
    for mb in 0..rows {
        let layers = &workload.micro_batches[mb].layers;
        let mut l = 0usize;
        while l < cols {
            let end = (l + seg_len).min(cols);
            // cheapest-loaded chiplet takes the whole segment
            let chip = (0..chips)
                .min_by(|&a, &b| load[a].total_cmp(&load[b]))
                .unwrap();
            for li in l..end {
                m.set_chip(mb, li, chip as u16);
                let c = layer_cost(
                    &layers[li].kind,
                    layers[li].vec_ops,
                    hw.chiplet(chip),
                    true,
                );
                load[chip] += c.cycles;
            }
            l = end;
        }
    }
    m
}

/// SCAR mappings for a whole scenario (fixed hardware).
pub fn scar_mappings(
    scenario: &Scenario,
    model: &ModelSpec,
    hw: &HwConfig,
    eval_blocks: usize,
) -> MappingSearch {
    let ev = Evaluator::new();
    let mappings: Vec<Mapping> = scenario
        .groups
        .iter()
        .map(|g| {
            let w = build_workload(model, &g.batch, &group_params(hw, g.has_prefill, eval_blocks));
            scar_mapping(&w, hw)
        })
        .collect();
    let eval = ev.eval_scenario(scenario, model, hw, &mappings, eval_blocks);
    MappingSearch { mappings, eval }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::workload::{Request, WorkloadParams};

    fn setup() -> (Workload, HwConfig) {
        let model = ModelSpec::tiny();
        let batch = vec![Request::prefill(64); 4];
        let w = build_workload(
            &model,
            &batch,
            &WorkloadParams {
                micro_batch_size: 2,
                tensor_parallel: 2,
                eval_blocks: 2,
            },
        );
        let hw = HwConfig::homogeneous(2, 2, ChipletClass::S, Dataflow::WeightStationary, 32.0, 16.0);
        (w, hw)
    }

    #[test]
    fn scar_mapping_is_valid_and_uses_multiple_chips() {
        let (w, hw) = setup();
        let m = scar_mapping(&w, &hw);
        assert!(m.is_valid(4));
        assert!(m.chips_used() > 1, "load balancing must spread work");
    }

    #[test]
    fn segments_are_contiguous_on_one_chip() {
        let (w, hw) = setup();
        let m = scar_mapping(&w, &hw);
        for mb in 0..m.rows {
            for (s, e) in m.segments() {
                let c = m.chip(mb, s);
                assert!(
                    (s..e).all(|l| m.chip(mb, l) == c),
                    "segment [{s},{e}) split across chips"
                );
            }
        }
    }

    #[test]
    fn load_is_roughly_balanced() {
        let (w, hw) = setup();
        let m = scar_mapping(&w, &hw);
        let mut load = vec![0f64; 4];
        for mb in 0..m.rows {
            for l in 0..m.cols {
                let node = &w.micro_batches[mb].layers[l];
                let c = layer_cost(&node.kind, node.vec_ops, hw.chiplet(0), true);
                load[m.chip(mb, l) as usize] += c.cycles;
            }
        }
        let max = load.iter().cloned().fold(0.0, f64::max);
        let min = load.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(min > 0.0, "every chip must get work: {load:?}");
        assert!(max / min < 20.0, "gross imbalance: {load:?}");
    }
}

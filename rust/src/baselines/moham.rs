//! MOHaM-style baseline (paper §VI-A): multi-model hardware-mapping
//! co-optimisation by a *joint* genetic algorithm, with every micro-batch
//! treated as an independent model — i.e. `micro_batch_size = 1`, so the
//! QKV-generation and FFN stages can never merge requests into one GEMM
//! (the restriction the paper identifies as MOHaM's key limitation on
//! LLM workloads).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::arch::{HwConfig, HwSpace};
use crate::bo::sa::{inner_move, outer_move, random_config};
use crate::cost::engine::{default_threads, par_map_f64};
use crate::cost::Evaluator;
use crate::dse::MappingSearch;
use crate::ga::{self, ops, GaConfig};
use crate::mapping::Mapping;
use crate::util::Rng;
use crate::workload::serving::Scenario;
use crate::workload::{build_workload, ModelSpec, Workload, WorkloadParams};

/// A joint individual: hardware genes + one mapping per scenario group.
#[derive(Clone)]
struct Individual {
    hw: HwConfig,
    maps: Vec<Mapping>,
}

/// MOHaM workload view: micro-batch size forced to 1 for every group.
fn moham_params(hw: &HwConfig, eval_blocks: usize) -> WorkloadParams {
    WorkloadParams {
        micro_batch_size: 1,
        tensor_parallel: hw.tensor_parallel,
        eval_blocks,
    }
}

/// Joint GA over (hardware, mappings). The budget is
/// `population x (generations + 1)` full evaluations, comparable to
/// Compass' BO rounds x GA budget scaled down (paper matches wall-clock).
///
/// Children of a generation are bred serially from the seeded RNG, then
/// scored as one parallel batch; workloads are cached per tensor-parallel
/// degree (the only hardware knob they depend on under the micro-batch-1
/// restriction), so repeated hardware genes never rebuild the execution
/// graph.
pub fn moham_dse(
    scenario: &Scenario,
    model: &ModelSpec,
    space: &HwSpace,
    cfg: &GaConfig,
    eval_blocks: usize,
) -> (HwConfig, MappingSearch) {
    let ev = Evaluator::new();
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0x4d4f_4841_4d00);
    let threads = default_threads();

    // search-invariant workload cache: under micro_batch_size = 1 the
    // instantiated workloads depend only on hw.tensor_parallel
    let wl_cache: Mutex<HashMap<usize, Arc<Vec<Workload>>>> = Mutex::new(HashMap::new());
    let workloads_for = |hw: &HwConfig| -> Arc<Vec<Workload>> {
        let tp = hw.tensor_parallel;
        if let Some(ws) = wl_cache.lock().unwrap().get(&tp) {
            return ws.clone();
        }
        let ws: Vec<Workload> = scenario
            .groups
            .iter()
            .map(|g| build_workload(model, &g.batch, &moham_params(hw, eval_blocks)))
            .collect();
        wl_cache
            .lock()
            .unwrap()
            .entry(tp)
            .or_insert_with(|| Arc::new(ws))
            .clone()
    };

    let shapes = |hw: &HwConfig| -> Vec<(usize, usize)> {
        workloads_for(hw)
            .iter()
            .map(|w| (w.num_micro_batches(), w.layers_per_mb))
            .collect()
    };

    let fitness = |ind: &Individual| -> f64 {
        let mut latency = 0.0;
        let mut energy = 0.0;
        let ws = workloads_for(&ind.hw);
        for ((g, m), w) in scenario.groups.iter().zip(&ind.maps).zip(ws.iter()) {
            let r = ev.eval_batch(w, &ind.hw, m);
            latency += r.latency_cycles * g.weight;
            energy += r.energy_pj * g.weight;
        }
        let mc = crate::cost::money::monetary_cost(&ind.hw).total;
        (latency / crate::arch::constants::CLOCK_HZ) * (energy * 1e-12) * mc
    };

    let spawn = |rng: &mut Rng| -> Individual {
        let hw = random_config(space, rng);
        let maps = shapes(&hw)
            .into_iter()
            .map(|(r, c)| ops::random_mapping(r, c, hw.num_chiplets(), rng))
            .collect();
        Individual { hw, maps }
    };

    let mut pop: Vec<Individual> = (0..cfg.population).map(|_| spawn(&mut rng)).collect();
    let mut fits: Vec<f64> = par_map_f64(&pop, threads, &fitness);

    for gen in 0..cfg.generations {
        let phase = gen as f64 / cfg.generations.max(1) as f64;
        let (mut next, mut next_fits) = ga::select_elites(&pop, &fits, cfg.elites);
        let mut children: Vec<Individual> =
            Vec::with_capacity(cfg.population.saturating_sub(next.len()));
        while next.len() + children.len() < cfg.population {
            let pa = ga::tournament(&fits, cfg.tournament_k, &mut rng);
            let pb = ga::tournament(&fits, cfg.tournament_k, &mut rng);
            let mut child = pop[pa].clone();
            // hardware genes: uniform crossover on sys, layout from one
            // parent when shapes agree; then a mutation move
            if pop[pb].hw.class == child.hw.class && rng.gen_bool(0.5) {
                child.hw.layout = pop[pb].hw.layout.clone();
            }
            if rng.gen_bool(0.5) {
                child.hw.nop_bw_gbs = pop[pb].hw.nop_bw_gbs;
                child.hw.dram_bw_gbs = pop[pb].hw.dram_bw_gbs;
            }
            if rng.gen_bool(cfg.mutation_prob) {
                child.hw = if rng.gen_bool(0.5) {
                    outer_move(&child.hw, space, &mut rng)
                } else {
                    inner_move(&child.hw, space, &mut rng)
                };
            }
            // mapping genes: crossover per group when shapes agree,
            // else re-randomise to the new shape
            let sh = shapes(&child.hw);
            let chips = child.hw.num_chiplets();
            let mut maps = Vec::with_capacity(sh.len());
            for (gi, (r, c)) in sh.iter().enumerate() {
                let a_ok = pop[pa].maps[gi].rows == *r && pop[pa].maps[gi].cols == *c;
                let b_ok = pop[pb].maps[gi].rows == *r && pop[pb].maps[gi].cols == *c;
                let mut m = match (a_ok, b_ok) {
                    (true, true) => ops::crossover(&pop[pa].maps[gi], &pop[pb].maps[gi], &mut rng),
                    (true, false) => pop[pa].maps[gi].clone(),
                    (false, true) => pop[pb].maps[gi].clone(),
                    (false, false) => ops::random_mapping(*r, *c, chips, &mut rng),
                };
                // clamp chip ids to the (possibly smaller) chip count
                for g in m.layer_to_chip.iter_mut() {
                    if *g as usize >= chips {
                        *g = (*g as usize % chips) as u16;
                    }
                }
                if rng.gen_bool(cfg.mutation_prob) {
                    ops::mutate_layer_to_chip(&mut m, chips, phase, &mut rng);
                }
                maps.push(m);
            }
            child.maps = maps;
            children.push(child);
        }
        // score the brood as one parallel batch
        let mut child_fits = par_map_f64(&children, threads, &fitness);
        next.append(&mut children);
        next_fits.append(&mut child_fits);
        pop = next;
        fits = next_fits;
    }

    let bi = (0..pop.len())
        .min_by(|&a, &b| fits[a].total_cmp(&fits[b]))
        .unwrap();
    let best = pop[bi].clone();
    let eval = {
        // evaluate through the scenario path for a consistent report
        let ev = Evaluator::new();
        let mut hw1 = best.hw.clone();
        hw1.micro_batch_prefill = 1;
        hw1.micro_batch_decode = 1;
        ev.eval_scenario(scenario, model, &hw1, &best.maps, eval_blocks)
    };
    (
        best.hw.clone(),
        MappingSearch {
            mappings: best.maps,
            eval,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{Trace, TraceSpec};

    #[test]
    fn moham_runs_and_respects_space() {
        let trace = Trace::new(&TraceSpec::sharegpt(), 32, 4);
        let scen = Scenario::prefill(&trace, 2, 1);
        let model = ModelSpec::tiny();
        let space = HwSpace::paper(64.0);
        let cfg = GaConfig {
            population: 6,
            generations: 4,
            ..GaConfig::tiny()
        };
        let (hw, ms) = moham_dse(&scen, &model, &space, &cfg, 1);
        assert!(space.nop_bw_gbs.contains(&hw.nop_bw_gbs));
        assert!(ms.eval.total_cost() > 0.0);
        // every mapping row count equals the batch size (micro-batch 1)
        assert_eq!(ms.mappings[0].rows, 2);
    }

    #[test]
    fn moham_micro_batch_is_always_one() {
        // the defining restriction: each request is an independent model
        let hw = HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let p = moham_params(&hw, 1);
        assert_eq!(p.micro_batch_size, 1);
    }
}

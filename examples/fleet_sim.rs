//! Fleet-serving sweep: arrival rate x router policy x fleet shape on
//! one request stream (the scale-out counterpart of `serving_sim`).
//!
//! The default configuration replays GovReport-style traffic across a
//! 4-replica fleet carved from a 512-TOPS budget and compares
//! round-robin, join-shortest-queue and disaggregated prefill/decode
//! routing at three arrival rates (under / near / over the fleet's
//! estimated capacity), then checks the qualitative orderings:
//!
//! * reruns are bit-identical (the whole fleet is deterministic);
//! * join-shortest-queue achieves SLO goodput >= round-robin at the
//!   overload rate (backlog-aware routing beats blind rotation when
//!   replicas saturate);
//! * the disaggregated fleet reports nonzero KV-handoff traffic.
//!
//! Run:   cargo run --release --example fleet_sim
//! CI:    cargo run --example fleet_sim -- --tiny
//!
//! Output is deterministic for the fixed seed baked in below.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::experiments as exp;
use compass::report::Table;
use compass::sim::{self, FleetMetrics, RouterPolicy, SimConfig};
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

const SEED: u64 = 17;
const HANDOFF_S_PER_TOKEN: f64 = 1e-8;

struct Setup {
    label: &'static str,
    model: ModelSpec,
    spec: TraceSpec,
    /// Per-replica package.
    hw: HwConfig,
    cfg: SimConfig,
    n_replicas: usize,
    n_requests: usize,
}

fn setup(tiny: bool) -> Setup {
    if tiny {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.chunk_tokens = 32;
        cfg.kv_budget_tokens = 2048;
        cfg.ctx_bucket = 64;
        cfg.eval_blocks = 1;
        Setup {
            label: "tiny-fleet",
            model: ModelSpec::tiny(),
            spec: TraceSpec {
                mean_in: 96.0,
                mean_out: 12.0,
                sigma_in: 0.5,
                sigma_out: 0.4,
                max_len: 4096,
                shared_prefix_tokens: 0,
            },
            hw: HwConfig::homogeneous(
                2,
                2,
                ChipletClass::S,
                Dataflow::WeightStationary,
                32.0,
                16.0,
            ),
            cfg,
            n_replicas: 3,
            n_requests: 24,
        }
    } else {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.ctx_bucket = 1024; // GovReport contexts are ~10k tokens
        Setup {
            label: "govreport-512T-fleet4",
            model: exp::model_for_tops(512.0),
            spec: TraceSpec::govreport(),
            hw: exp::sim_default_hw(128.0), // 512 TOPS / 4 replicas
            cfg,
            n_replicas: 4,
            n_requests: 36,
        }
    }
}

fn main() {
    let tiny = std::env::args().skip(1).any(|a| a == "--tiny");
    let s = setup(tiny);
    let t0 = std::time::Instant::now();

    let probe = sim::probe(&s.model, &s.hw, &s.cfg, &s.spec);
    let mut cfg = s.cfg;
    cfg.slo = probe.slo(3.0, 4.0);
    let fleet_mu = s.n_replicas as f64 * probe.capacity_rps();
    let rates = [0.4 * fleet_mu, 0.8 * fleet_mu, 1.3 * fleet_mu];
    let fleets = exp::default_fleet_shapes(s.n_replicas, HANDOFF_S_PER_TOKEN);
    println!(
        "fleet_sim [{}] model={} | {} replicas of: {}",
        s.label,
        s.model.name,
        s.n_replicas,
        s.hw.describe()
    );
    println!(
        "probe (per replica): prefill {:.4}s | decode iter {:.5}s | fleet capacity ~{:.3} req/s \
         | SLO ttft<={:.3}s tpot<={:.4}s",
        probe.t_prefill_s,
        probe.t_decode_iter_s,
        fleet_mu,
        cfg.slo.ttft_s,
        cfg.slo.tpot_s,
    );

    // --- arrival rate x fleet shape sweep ---
    let mut table = Table::new(
        "Fleet sweep - goodput / tails / imbalance per router policy and rate",
        &[
            "Rate (r/s)",
            "Fleet",
            "Tok/s",
            "Goodput (tok/s)",
            "TTFT p99 (s)",
            "TPOT p99 (s)",
            "SLO %",
            "Imbalance",
            "KV-handoff",
            "Rej",
        ],
    );
    let mut by_cell: Vec<(usize, f64, FleetMetrics)> = Vec::new();
    for &rate in &rates {
        let stream = sim::RequestStream::poisson(&s.spec, rate, s.n_requests, SEED);
        for (fi, fleet) in fleets.iter().enumerate() {
            let m = sim::simulate_fleet(&stream, &s.model, &s.hw, &cfg, fleet);
            table.row(vec![
                format!("{:.3}", rate),
                fleet.describe(),
                format!("{:.1}", m.throughput_tps),
                format!("{:.1}", m.slo_goodput_tps),
                format!("{:.4}", m.ttft.p99),
                format!("{:.5}", m.tpot.p99),
                format!("{:.1}", 100.0 * m.slo_attainment),
                format!("{:.3}", m.load_imbalance),
                m.kv_transfer_tokens.to_string(),
                m.n_rejected.to_string(),
            ]);
            by_cell.push((fi, rate, m));
        }
    }
    table.print();

    // --- determinism: a rerun of the overload JSQ cell is bit-identical ---
    let hi = rates[rates.len() - 1];
    let get = |fi: usize, rate: f64| {
        by_cell
            .iter()
            .find(|(i, r, _)| *i == fi && *r == rate)
            .map(|(_, _, m)| m)
            .expect("cell present")
    };
    let jsq_idx = fleets
        .iter()
        .position(|f| f.router == RouterPolicy::JoinShortestQueue)
        .expect("jsq shape");
    let rr_idx = fleets
        .iter()
        .position(|f| f.router == RouterPolicy::RoundRobin)
        .expect("rr shape");
    let pd_idx = fleets
        .iter()
        .position(|f| f.router == RouterPolicy::PrefillDecode)
        .expect("disagg shape");
    {
        let stream = sim::RequestStream::poisson(&s.spec, hi, s.n_requests, SEED);
        let rerun = sim::simulate_fleet(&stream, &s.model, &s.hw, &cfg, &fleets[jsq_idx]);
        let first = get(jsq_idx, hi);
        assert_eq!(
            rerun.makespan_s.to_bits(),
            first.makespan_s.to_bits(),
            "fleet rerun not bit-identical"
        );
        assert_eq!(rerun.slo_goodput_tps.to_bits(), first.slo_goodput_tps.to_bits());
        assert_eq!(rerun.ttft.p99.to_bits(), first.ttft.p99.to_bits());
        assert_eq!(rerun.energy_pj.to_bits(), first.energy_pj.to_bits());
        println!("\ndeterminism: overload JSQ cell rerun is bit-identical: PASS");

        // the trait-based front end with the baseline control plane is
        // the legacy router, bit for bit (the PR 5 refactor anchor)
        let hws = vec![s.hw.clone(); fleets[jsq_idx].total_replicas()];
        let fe = sim::simulate_fleet_frontend(
            &stream,
            &s.model,
            &hws,
            &cfg,
            &fleets[jsq_idx],
            &sim::Frontend::baseline(),
        );
        assert_eq!(
            fe.makespan_s.to_bits(),
            first.makespan_s.to_bits(),
            "trait front end drifted from the legacy router"
        );
        assert_eq!(fe.energy_pj.to_bits(), first.energy_pj.to_bits());
        println!("refactor anchor: baseline front end == legacy router: PASS");
    }

    // --- disaggregation must actually migrate KV ---
    for &rate in &rates {
        let m = get(pd_idx, rate);
        assert!(
            m.kv_transfer_tokens > 0,
            "disaggregated fleet reported zero KV-handoff traffic at {rate:.3} req/s"
        );
    }
    println!(
        "disaggregation: nonzero KV-handoff traffic at every rate \
         (overload: {} tokens): PASS",
        get(pd_idx, hi).kv_transfer_tokens
    );

    // --- qualitative ordering at overload: JSQ >= round-robin ---
    let (jsq, rr) = (get(jsq_idx, hi), get(rr_idx, hi));
    println!("\nordering check @ {hi:.3} req/s (overload):");
    println!(
        "  SLO goodput: jsq {:.1} tok/s | round-robin {:.1} tok/s | disagg {:.1} tok/s",
        jsq.slo_goodput_tps,
        rr.slo_goodput_tps,
        get(pd_idx, hi).slo_goodput_tps,
    );
    println!(
        "  imbalance:   jsq {:.3} | round-robin {:.3}",
        jsq.load_imbalance, rr.load_imbalance
    );
    let ok = jsq.slo_goodput_tps >= rr.slo_goodput_tps;
    println!(
        "  jsq >= round-robin on SLO goodput: {}",
        if ok { "PASS" } else { "FAIL" }
    );
    // the full GovReport run is the acceptance gate for the ordering;
    // the tiny smoke only proves the subsystem runs end-to-end (toy
    // scale need not be in the regime where routing dominates)
    if !tiny && !ok {
        eprintln!("[fleet_sim] FAIL: JSQ < round-robin SLO goodput at overload");
        std::process::exit(1);
    }
    eprintln!("[fleet_sim] done in {:.1}s", t0.elapsed().as_secs_f64());
}

"""Fixed AOT shapes for the GP surrogate artifacts.

The rust BO engine pads every hardware configuration to these sizes so a
single compiled PJRT executable serves the whole search (no shape-dependent
recompilation on the hot path).

Padding conventions:
  * layouts  -> one-hot (SLOTS, TYPES) grids; empty slots are all-zero rows
                (they match nothing in the layout kernel, Eq. 3).
  * sys par. -> (SYS_D,) feature vectors; unused dims are zero with an
                effectively-infinite lengthscale supplied by rust.
  * train set-> TRAIN_N rows with a {0,1} mask; masked rows are replaced by
                identity rows in the Cholesky factorisation.
"""

SLOTS = 256  # max chiplets on the package substrate (16 x 16 grid)
TYPES = 4  # dataflow-type vocabulary (WS, OS + 2 reserved)
TRAIN_N = 128  # max BO observations (init design + 100 rounds + slack)
CAND_Q = 64  # EI candidate batch proposed by the two-tier SA
SYS_D = 8  # padded system-parameter feature dimension

# Pallas block sizes (MXU-aligned on the q/n grid; W stays VMEM-resident)
BLOCK_Q = 32
BLOCK_N = 32

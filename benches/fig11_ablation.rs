//! Bench F11: paper Fig. 11 — component ablations under the
//! chunked-prefill configuration: full Compass vs GA->random,
//! BO->random, and SCAR-style mapping.
use compass::dse::DseConfig;
use compass::experiments as exp;
use compass::runtime::Runtime;

fn main() {
    let mut cfg = DseConfig::reduced();
    cfg.ga.population = 12;
    cfg.ga.generations = 8;
    cfg.bo.rounds = 8;
    cfg.bo.init = 4;
    let rt = Runtime::from_env().ok();
    let t0 = std::time::Instant::now();
    exp::fig11_ablation(&cfg, rt.as_ref(), 13).print();
    println!("ablation wall-clock: {:.1}s", t0.elapsed().as_secs_f64());
}

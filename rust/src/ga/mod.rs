//! Mapping generation engine: genetic algorithm over the mapping encoding
//! (paper §V-A).
//!
//! * **Selection** — tournament selection (robust to multi-objective
//!   fitness scales, avoids population degradation).
//! * **Crossover** — bitwise for `segmentation`; subgraph-level for
//!   `layer_to_chip` (subgraphs follow the child's crossed segmentation,
//!   each inherited wholesale from one parent).
//! * **Mutation** — `segmentation`: bit-flip and bit-swap;
//!   `layer_to_chip`: the seven operators of Table III, with the
//!   probability mass shifted from graph-level operators (6-7) early in
//!   the run to layer-level operators (1-3) late (exploration ->
//!   fine-tuning).

pub mod ops;

use crate::mapping::Mapping;
use crate::util::Rng;

pub use crate::cost::engine::BatchEvaluator;

/// GA hyperparameters (paper §VI-A: population 120, 100 iterations;
/// defaults here are the reduced single-core budget, see DESIGN.md).
#[derive(Debug, Clone, Copy)]
pub struct GaConfig {
    pub population: usize,
    pub generations: usize,
    pub tournament_k: usize,
    pub crossover_prob: f64,
    pub mutation_prob: f64,
    /// Elites copied unchanged each generation.
    pub elites: usize,
    pub seed: u64,
}

impl GaConfig {
    pub fn reduced() -> Self {
        GaConfig {
            population: 24,
            generations: 20,
            tournament_k: 3,
            crossover_prob: 0.9,
            mutation_prob: 0.35,
            elites: 2,
            seed: 0xC0FFEE,
        }
    }

    /// The paper's search budget.
    pub fn paper() -> Self {
        GaConfig {
            population: 120,
            generations: 100,
            ..Self::reduced()
        }
    }

    /// Tiny budget for unit tests.
    pub fn tiny() -> Self {
        GaConfig {
            population: 10,
            generations: 8,
            ..Self::reduced()
        }
    }
}

/// Search statistics per generation (for convergence reporting).
#[derive(Debug, Clone, Copy)]
pub struct GenStat {
    pub generation: usize,
    pub best: f64,
    pub mean: f64,
}

/// Result of a GA run: the best mapping, its fitness (lower = better),
/// and the convergence history.
#[derive(Debug, Clone)]
pub struct GaResult {
    pub best: Mapping,
    pub best_fitness: f64,
    pub history: Vec<GenStat>,
    pub evaluations: usize,
}

/// Run the GA against a batch evaluator (see
/// [`crate::cost::engine::MappingEvaluator`] for the parallel,
/// allocation-free production implementation; any
/// `Fn(&Mapping) -> f64 + Sync` closure also works, serially).
///
/// Children of a generation are produced serially from the seeded RNG
/// and only then scored as one batch, so `GaResult` is bit-identical for
/// a given `GaConfig::seed` whether the evaluator runs on 1 or N
/// threads.
pub fn search<E: BatchEvaluator + ?Sized>(
    rows: usize,
    cols: usize,
    num_chips: usize,
    cfg: &GaConfig,
    evaluator: &E,
) -> GaResult {
    assert!(rows > 0 && cols > 0 && num_chips > 0);
    let mut rng = Rng::seed_from_u64(cfg.seed);

    // --- initial population: random + parallelism-preset seeds ---
    let mut pop: Vec<Mapping> = Vec::with_capacity(cfg.population);
    pop.push(crate::mapping::presets::data_parallel(rows, cols, num_chips));
    pop.push(crate::mapping::presets::pipeline_parallel(rows, cols, num_chips));
    {
        // model-parallel pattern broadcast to all rows
        let mp = crate::mapping::presets::model_parallel(cols, num_chips);
        let mut m = Mapping::new(rows, cols);
        for mb in 0..rows {
            for l in 0..cols {
                m.set_chip(mb, l, mp.chip(0, l));
            }
        }
        pop.push(m);
    }
    while pop.len() < cfg.population {
        pop.push(ops::random_mapping(rows, cols, num_chips, &mut rng));
    }
    pop.truncate(cfg.population);

    let mut fits: Vec<f64> = Vec::with_capacity(cfg.population);
    evaluator.eval_batch(&pop, &mut fits);
    let mut evaluations = pop.len();

    let mut child_fits: Vec<f64> = Vec::with_capacity(cfg.population);
    let mut history = Vec::with_capacity(cfg.generations);
    for gen in 0..cfg.generations {
        // phase in [0,1): early -> impactful mutations, late -> fine ones
        let phase = gen as f64 / cfg.generations.max(1) as f64;

        let (mut next, mut next_fits) = select_elites(&pop, &fits, cfg.elites);

        // generate the whole brood serially (deterministic RNG stream) ...
        let mut children: Vec<Mapping> =
            Vec::with_capacity(cfg.population.saturating_sub(next.len()));
        while next.len() + children.len() < cfg.population {
            let pa = tournament(&fits, cfg.tournament_k, &mut rng);
            let pb = tournament(&fits, cfg.tournament_k, &mut rng);
            let mut child = if rng.gen_bool(cfg.crossover_prob) {
                ops::crossover(&pop[pa], &pop[pb], &mut rng)
            } else {
                pop[pa].clone()
            };
            if rng.gen_bool(cfg.mutation_prob) {
                ops::mutate_segmentation(&mut child, &mut rng);
            }
            if rng.gen_bool(cfg.mutation_prob) {
                ops::mutate_layer_to_chip(&mut child, num_chips, phase, &mut rng);
            }
            debug_assert!(child.is_valid(num_chips));
            children.push(child);
        }

        // ... then score the generation as one (parallel) batch
        evaluations += children.len();
        evaluator.eval_batch(&children, &mut child_fits);
        next_fits.append(&mut child_fits);
        next.append(&mut children);
        pop = next;
        fits = next_fits;

        let best = fits.iter().cloned().fold(f64::INFINITY, f64::min);
        let mean = fits.iter().sum::<f64>() / fits.len() as f64;
        history.push(GenStat {
            generation: gen,
            best,
            mean,
        });
    }

    let (bi, bf) = fits
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, f)| (i, *f))
        .unwrap();
    GaResult {
        best: pop[bi].clone(),
        best_fitness: bf,
        history,
        evaluations,
    }
}

/// Elitism: clone the `elites` fittest individuals (ties broken by
/// population order) together with their fitness. Shared between the GA
/// and the joint hardware+mapping baseline.
pub fn select_elites<T: Clone>(pop: &[T], fits: &[f64], elites: usize) -> (Vec<T>, Vec<f64>) {
    let mut order: Vec<usize> = (0..pop.len()).collect();
    order.sort_by(|&a, &b| fits[a].total_cmp(&fits[b]));
    let next = order.iter().take(elites).map(|&i| pop[i].clone()).collect();
    let next_fits = order.iter().take(elites).map(|&i| fits[i]).collect();
    (next, next_fits)
}

/// Tournament selection: k uniform picks, return the fittest index.
pub fn tournament(fits: &[f64], k: usize, rng: &mut Rng) -> usize {
    let mut best = rng.gen_index(fits.len());
    for _ in 1..k.max(1) {
        let c = rng.gen_index(fits.len());
        if fits[c] < fits[best] {
            best = c;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Toy fitness: prefer chip == (layer % chips) and no segmentation --
    /// the GA must drive toward the known optimum.
    fn toy_fitness(m: &Mapping, chips: usize) -> f64 {
        let mut cost = 0.0;
        for mb in 0..m.rows {
            for l in 0..m.cols {
                if m.chip(mb, l) as usize != l % chips {
                    cost += 1.0;
                }
            }
        }
        cost + m.segmentation.iter().filter(|&&s| s).count() as f64 * 0.25
    }

    #[test]
    fn converges_on_toy_problem() {
        let chips = 4;
        let cfg = GaConfig {
            population: 30,
            generations: 40,
            ..GaConfig::reduced()
        };
        let r = search(2, 12, chips, &cfg, &|m: &Mapping| toy_fitness(m, chips));
        assert!(
            r.best_fitness <= 3.0,
            "GA should approach optimum, got {}",
            r.best_fitness
        );
        let first = r.history.first().unwrap().best;
        let last = r.history.last().unwrap().best;
        assert!(last <= first);
    }

    #[test]
    fn deterministic_under_seed() {
        let cfg = GaConfig::tiny();
        let a = search(2, 8, 4, &cfg, &|m: &Mapping| toy_fitness(m, 4));
        let b = search(2, 8, 4, &cfg, &|m: &Mapping| toy_fitness(m, 4));
        assert_eq!(a.best, b.best);
        assert_eq!(a.best_fitness, b.best_fitness);
    }

    #[test]
    fn all_individuals_valid() {
        let cfg = GaConfig::tiny();
        let r = search(3, 10, 5, &cfg, &|m: &Mapping| {
            assert!(m.is_valid(5), "invalid individual reached fitness");
            toy_fitness(m, 5)
        });
        assert!(r.best.is_valid(5));
        // initial pop + (pop - elites) new children per generation
        assert_eq!(
            r.evaluations,
            cfg.population + cfg.generations * (cfg.population - cfg.elites)
        );
    }

    #[test]
    fn elites_never_regress() {
        let cfg = GaConfig {
            population: 16,
            generations: 25,
            ..GaConfig::tiny()
        };
        let r = search(2, 10, 4, &cfg, &|m: &Mapping| toy_fitness(m, 4));
        let mut prev = f64::INFINITY;
        for st in &r.history {
            assert!(st.best <= prev + 1e-12, "best regressed at gen {}", st.generation);
            prev = st.best;
        }
    }

    #[test]
    fn beats_random_search_same_budget() {
        let chips = 6;
        let rows = 2;
        let cols = 16;
        let cfg = GaConfig {
            population: 20,
            generations: 15,
            ..GaConfig::reduced()
        };
        let ga = search(rows, cols, chips, &cfg, &|m: &Mapping| toy_fitness(m, chips));
        // random baseline with identical evaluation budget
        let mut rng = Rng::seed_from_u64(1);
        let budget = ga.evaluations;
        let mut best = f64::INFINITY;
        for _ in 0..budget {
            let m = ops::random_mapping(rows, cols, chips, &mut rng);
            best = best.min(toy_fitness(&m, chips));
        }
        assert!(
            ga.best_fitness <= best,
            "GA {} must beat random {best}",
            ga.best_fitness
        );
    }
}

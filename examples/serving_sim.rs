//! Serving-simulator sweep: arrival rate x serving strategy x mapping
//! policy on mixed traffic (paper Fig. 9/10 made dynamic).
//!
//! The default configuration replays GovReport-style traffic (long
//! prompts, decode-heavy token mix) on a 512-TOPS package and reports
//! TTFT p99, TPOT p99 and SLO attainment for vLLM-style, Orca-style and
//! Sarathi-style chunked prefill at three arrival rates (under / near /
//! over estimated capacity), finishing with a mapping-policy comparison
//! and the qualitative Fig. 9/10 ordering check: chunked prefill should
//! beat vLLM-style separation at high decode load.
//!
//! Run:   cargo run --release --example serving_sim
//! CI:    cargo run --example serving_sim -- --tiny
//!
//! Output is deterministic for the fixed seed baked in below.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::experiments as exp;
use compass::ga::GaConfig;
use compass::report::{ascii_occupancy, Table};
use compass::sim::{self, MappingPolicy, ServingMetrics, SimConfig};
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

const SEED: u64 = 11;

struct Setup {
    label: &'static str,
    model: ModelSpec,
    spec: TraceSpec,
    hw: HwConfig,
    cfg: SimConfig,
    n_requests: usize,
}

fn setup(tiny: bool) -> Setup {
    if tiny {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.chunk_tokens = 32;
        cfg.kv_budget_tokens = 2048;
        cfg.ctx_bucket = 64;
        cfg.eval_blocks = 1;
        Setup {
            label: "tiny-mixed",
            model: ModelSpec::tiny(),
            spec: TraceSpec {
                mean_in: 96.0,
                mean_out: 12.0,
                sigma_in: 0.5,
                sigma_out: 0.4,
                max_len: 4096,
                shared_prefix_tokens: 0,
            },
            hw: HwConfig::homogeneous(
                2,
                2,
                ChipletClass::S,
                Dataflow::WeightStationary,
                32.0,
                16.0,
            ),
            cfg,
            n_requests: 8,
        }
    } else {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.ctx_bucket = 1024; // GovReport contexts are ~10k tokens
        Setup {
            label: "govreport-512T",
            model: exp::model_for_tops(512.0),
            spec: TraceSpec::govreport(),
            hw: exp::sim_default_hw(512.0),
            cfg,
            n_requests: 24,
        }
    }
}

fn main() {
    let tiny = std::env::args().skip(1).any(|a| a == "--tiny");
    let s = setup(tiny);
    let t0 = std::time::Instant::now();

    let probe = sim::probe(&s.model, &s.hw, &s.cfg, &s.spec);
    let mut cfg = s.cfg;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = probe.sweep_rates();
    println!(
        "serving_sim [{}] model={} hw={}",
        s.label,
        s.model.name,
        s.hw.describe()
    );
    println!(
        "probe: prefill {:.4}s | decode iter {:.5}s | kv concurrency {} | \
         capacity ~{:.3} req/s | SLO ttft<={:.3}s tpot<={:.4}s",
        probe.t_prefill_s,
        probe.t_decode_iter_s,
        probe.concurrency,
        probe.capacity_rps(),
        cfg.slo.ttft_s,
        cfg.slo.tpot_s,
    );

    // --- arrival rate x strategy sweep (pipeline mapping policy) ---
    let mut table = Table::new(
        "Serving sweep - TTFT p99 / TPOT p99 / SLO attainment per strategy and rate",
        &[
            "Rate (r/s)",
            "Strategy",
            "Tok/s",
            "TTFT p99 (s)",
            "TPOT p99 (s)",
            "SLO %",
            "Goodput (tok/s)",
            "Preempt",
            "Queue max",
        ],
    );
    let mut by_cell: Vec<(ServingStrategy, f64, ServingMetrics)> = Vec::new();
    for &rate in &rates {
        let stream = sim::RequestStream::poisson(&s.spec, rate, s.n_requests, SEED);
        for strategy in ServingStrategy::ALL {
            let m = sim::simulate_serving(&stream, &s.model, &s.hw, &cfg.with_strategy(strategy));
            table.row(vec![
                format!("{:.3}", rate),
                strategy.name().to_string(),
                format!("{:.1}", m.throughput_tps),
                format!("{:.4}", m.ttft.p99),
                format!("{:.5}", m.tpot.p99),
                format!("{:.1}", 100.0 * m.slo_attainment),
                format!("{:.1}", m.slo_goodput_tps),
                m.n_preemptions.to_string(),
                m.max_queue_depth.to_string(),
            ]);
            by_cell.push((strategy, rate, m));
        }
    }
    table.print();

    // --- qualitative Fig. 9/10 ordering at the highest rate ---
    let hi = rates[rates.len() - 1];
    let get = |strategy: ServingStrategy| {
        by_cell
            .iter()
            .find(|(st, r, _)| *st == strategy && *r == hi)
            .map(|(_, _, m)| m)
            .expect("cell present")
    };
    let (vllm, orca, chunked) = (
        get(ServingStrategy::Vllm),
        get(ServingStrategy::Orca),
        get(ServingStrategy::ChunkedPrefill),
    );
    println!("\nFig 9/10 qualitative check @ {hi:.3} req/s (high decode load):");
    let score = |m: &ServingMetrics| (m.slo_attainment, m.slo_goodput_tps);
    println!(
        "  SLO attainment: chunked {:.1}% | orca {:.1}% | vllm {:.1}%",
        100.0 * chunked.slo_attainment,
        100.0 * orca.slo_attainment,
        100.0 * vllm.slo_attainment,
    );
    println!(
        "  TPOT p99: chunked {:.5}s | orca {:.5}s | vllm {:.5}s",
        chunked.tpot.p99, orca.tpot.p99, vllm.tpot.p99,
    );
    let ok = score(chunked) >= score(vllm);
    println!(
        "  chunked prefill >= vLLM-style separation on (SLO, goodput): {}",
        if ok { "PASS" } else { "FAIL" }
    );
    // the full GovReport run is the acceptance gate for the paper's
    // qualitative ordering; the tiny smoke only proves the subsystem
    // runs end-to-end (toy scale is not in the high-decode-load regime)
    if !tiny && !ok {
        eprintln!("[serving_sim] FAIL: qualitative Fig 9/10 ordering did not hold");
        std::process::exit(1);
    }

    // --- occupancy plot: chunked prefill at the highest rate ---
    println!("\noccupancy [ChunkedPrefill @ {hi:.3} req/s]");
    print!("{}", ascii_occupancy(&chunked.iters, cfg.max_batch, 96));

    // --- mapping-policy comparison at the middle rate ---
    let mid = rates[rates.len() / 2];
    let stream = sim::RequestStream::poisson(&s.spec, mid, s.n_requests, SEED);
    println!("\nmapping policies [ChunkedPrefill @ {mid:.3} req/s]:");
    let mut ga_cfg = GaConfig::tiny();
    ga_cfg.seed = SEED;
    for policy in [
        MappingPolicy::Pipeline,
        MappingPolicy::DataParallel,
        MappingPolicy::Searched(ga_cfg),
    ] {
        let m = sim::simulate_serving(
            &stream,
            &s.model,
            &s.hw,
            &cfg.with_strategy(ServingStrategy::ChunkedPrefill).with_policy(policy),
        );
        println!(
            "  {:<13} {} | shapes {}",
            policy.name(),
            m.summary(),
            m.distinct_shapes
        );
    }
    eprintln!("[serving_sim] done in {:.1}s", t0.elapsed().as_secs_f64());
}

//! Engine microbenchmarks (the §Perf hot paths): per-layer kernel cost,
//! Algorithm-2 access analysis, timeline simulation, GA generation, and
//! a full mapping-search fitness evaluation.
use compass::arch::{Chiplet, ChipletClass, Dataflow, HwConfig};
use compass::cost::{access, dataflow::layer_cost, Evaluator};
use compass::ga::{self, GaConfig};
use compass::mapping::presets;
use compass::util::Bench;
use compass::workload::{build_workload, LayerKind, ModelSpec, Request, WorkloadParams};

fn main() {
    let chip = Chiplet { class: ChipletClass::M, dataflow: Dataflow::WeightStationary };
    let gemm = LayerKind::Gemm { m: 4096, k: 4096, n: 16384 };
    Bench::new("layer_cost/gemm-4kx4kx16k").run(|| layer_cost(&gemm, 0, chip, true));
    let att = LayerKind::Attention {
        heads: 32,
        head_dim: 128,
        reqs: (0..128).map(|i| (1u64, 256 + 8 * i as u64)).collect(),
    };
    Bench::new("layer_cost/attention-128req").run(|| layer_cost(&att, 0, chip, false));

    let model = ModelSpec::gpt3_7b();
    let w = build_workload(
        &model,
        &vec![Request::decode(512); 128],
        &WorkloadParams { micro_batch_size: 64, tensor_parallel: 8, eval_blocks: 2 },
    );
    let hw = HwConfig::homogeneous(2, 4, ChipletClass::M, Dataflow::WeightStationary, 32.0, 16.0);
    let m = presets::pipeline_parallel(w.num_micro_batches(), w.layers_per_mb, 8);
    Bench::new("access_analysis/decode-128").run(|| access::analyze(&w, &m));
    let ev = Evaluator::new();
    Bench::new("eval_batch/decode-128").run(|| ev.eval_batch(&w, &hw, &m));
    Bench::new("workload_build/decode-128").run(|| {
        build_workload(
            &model,
            &vec![Request::decode(512); 128],
            &WorkloadParams { micro_batch_size: 64, tensor_parallel: 8, eval_blocks: 2 },
        )
    });
    Bench::new("ga_search/pop12-gen8").budget_ms(1200).run(|| {
        ga::search(
            w.num_micro_batches(),
            w.layers_per_mb,
            8,
            &GaConfig { population: 12, generations: 8, ..GaConfig::reduced() },
            |m| {
                let r = ev.eval_batch(&w, &hw, m);
                r.latency_cycles * r.energy_pj
            },
        )
    });
}

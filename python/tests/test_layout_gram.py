"""L1 layout-Gram Pallas kernel vs pure-jnp oracle (Eq. 3/4)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import layout_gram, layout_gram_diag
from compile.kernels.ref import (
    layout_gram_diag_ref,
    layout_gram_ref,
    manhattan_weights_ref,
)

RNG = np.random.default_rng(0)


def random_onehot(q, s, t, fill=0.7, rng=RNG):
    """Random padded one-hot layouts: ~fill fraction of slots occupied."""
    out = np.zeros((q, s, t), dtype=np.float32)
    for i in range(q):
        occ = rng.random(s) < fill
        types = rng.integers(0, t, size=s)
        out[i, np.arange(s)[occ], types[occ]] = 1.0
    return out


def grid_weights(h, w, lam=2.0):
    coords = np.array([(x, y) for y in range(h) for x in range(w)], np.float32)
    return np.asarray(manhattan_weights_ref(jnp.asarray(coords), lam))


@settings(max_examples=20, deadline=None)
@given(
    q=st.sampled_from([1, 2, 4, 8]),
    n=st.sampled_from([1, 2, 4, 8]),
    s=st.sampled_from([4, 9, 16]),
    t=st.sampled_from([2, 3, 4]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_hypothesis(q, n, s, t, seed):
    rng = np.random.default_rng(seed)
    a = random_onehot(q, s, t, rng=rng)
    b = random_onehot(n, s, t, rng=rng)
    side = int(np.sqrt(s))
    w = grid_weights(side, s // side, lam=1.5)
    got = layout_gram(jnp.asarray(a), jnp.asarray(b), jnp.asarray(w))
    want = layout_gram_ref(jnp.asarray(a), jnp.asarray(b), jnp.asarray(w))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("bq,bn", [(2, 2), (4, 8), (8, 4)])
def test_blocking_invariance(bq, bn):
    """Result is independent of the BlockSpec tiling."""
    a = random_onehot(8, 16, 4)
    b = random_onehot(8, 16, 4)
    w = grid_weights(4, 4)
    full = layout_gram(jnp.asarray(a), jnp.asarray(b), jnp.asarray(w))
    tiled = layout_gram(
        jnp.asarray(a), jnp.asarray(b), jnp.asarray(w), block_q=bq, block_n=bn
    )
    np.testing.assert_allclose(full, tiled, rtol=1e-6)


def test_sigma2_scales_linearly():
    a = random_onehot(4, 16, 4)
    w = grid_weights(4, 4)
    k1 = layout_gram(jnp.asarray(a), jnp.asarray(a), jnp.asarray(w), sigma2=1.0)
    k3 = layout_gram(jnp.asarray(a), jnp.asarray(a), jnp.asarray(w), sigma2=3.0)
    np.testing.assert_allclose(3.0 * np.asarray(k1), k3, rtol=1e-5)


def test_symmetry_self_gram():
    a = random_onehot(6, 16, 4)
    w = grid_weights(4, 4)  # symmetric by construction
    k = np.asarray(layout_gram(jnp.asarray(a), jnp.asarray(a), jnp.asarray(w)))
    np.testing.assert_allclose(k, k.T, rtol=1e-5)


def test_empty_slots_contribute_nothing():
    """All-zero one-hot rows (padding) must not affect the Gram."""
    a = random_onehot(4, 16, 4)
    b = random_onehot(4, 16, 4)
    w = grid_weights(4, 4)
    base = layout_gram(jnp.asarray(a), jnp.asarray(b), jnp.asarray(w))
    # grow S with empty padding slots
    ap = np.concatenate([a, np.zeros((4, 8, 4), np.float32)], axis=1)
    bp = np.concatenate([b, np.zeros((4, 8, 4), np.float32)], axis=1)
    wp = np.zeros((24, 24), np.float32)
    wp[:16, :16] = w
    wp[16:, 16:] = 1.0  # junk weights on padded slots
    padded = layout_gram(jnp.asarray(ap), jnp.asarray(bp), jnp.asarray(wp))
    np.testing.assert_allclose(base, padded, rtol=1e-5)


def test_identical_layouts_maximize_similarity():
    """For identity W, K(a,a) counts occupied slots; mismatches score less."""
    s, t = 16, 2
    a = np.zeros((1, s, t), np.float32)
    a[0, :, 0] = 1.0  # all WS
    b = np.array(a)
    b[0, :8, 0] = 0.0
    b[0, :8, 1] = 1.0  # half flipped to OS
    w = np.eye(s, dtype=np.float32)
    kaa = float(layout_gram(jnp.asarray(a), jnp.asarray(a), jnp.asarray(w))[0, 0])
    kab = float(layout_gram(jnp.asarray(a), jnp.asarray(b), jnp.asarray(w))[0, 0])
    assert kaa == pytest.approx(16.0)
    assert kab == pytest.approx(8.0)
    assert kab < kaa


@settings(max_examples=15, deadline=None)
@given(
    q=st.sampled_from([1, 4, 8]),
    s=st.sampled_from([4, 16]),
    t=st.sampled_from([2, 4]),
    seed=st.integers(0, 2**16),
)
def test_diag_matches_ref(q, s, t, seed):
    rng = np.random.default_rng(seed)
    a = random_onehot(q, s, t, rng=rng)
    side = int(np.sqrt(s))
    w = grid_weights(side, s // side)
    got = layout_gram_diag(jnp.asarray(a), jnp.asarray(w), sigma2=2.0)
    want = layout_gram_diag_ref(jnp.asarray(a), jnp.asarray(w), sigma2=2.0)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_diag_consistent_with_full_gram():
    a = random_onehot(8, 16, 4)
    w = grid_weights(4, 4)
    full = np.asarray(layout_gram(jnp.asarray(a), jnp.asarray(a), jnp.asarray(w)))
    diag = np.asarray(layout_gram_diag(jnp.asarray(a), jnp.asarray(w)))
    np.testing.assert_allclose(np.diag(full), diag, rtol=1e-5)


def test_manhattan_weights_properties():
    w = grid_weights(4, 4, lam=2.0)
    assert w.shape == (16, 16)
    np.testing.assert_allclose(np.diag(w), 1.0)  # zero distance
    assert (w > 0).all() and (w <= 1.0).all()
    # adjacent slots weigh more than diagonal neighbours
    assert w[0, 1] > w[0, 5]

//! Multi-replica fleet serving: a front-end router replays one
//! [`RequestStream`] across N per-replica continuous-batching
//! schedulers ([`Scheduler`]), the first layer where the framework
//! answers "how many packages, and split how?" rather than "which
//! mapping?".
//!
//! Three router policies:
//!
//! * **round-robin** — requests cycle replica 0, 1, ..., N-1 regardless
//!   of load;
//! * **join-shortest-queue** — each request goes to the replica with the
//!   fewest outstanding tokens ([`Scheduler::backlog_tokens`]; ties to
//!   the lowest index);
//! * **disaggregated prefill/decode** — P prefill replicas run prompts
//!   to the first token, then the request's KV cache migrates to one of
//!   D decode replicas (JSQ within each pool) over a handoff link costed
//!   per migrated token. Decode-side preemptions re-materialize the KV
//!   (counted again as transfer traffic) instead of recomputing.
//!
//! Replicas advance their clocks independently; the router interleaves
//! them at arrival (and migration) events in global time order, so a
//! fixed stream gives bit-identical fleet metrics on every run — and a
//! one-replica fleet is bitwise-equal to `simulate_serving`.

use std::cell::RefCell;
use std::rc::Rc;

use crate::arch::HwConfig;
use crate::workload::ModelSpec;

use super::coster::BatchCoster;
use super::kv::KvCache;
use super::metrics::{outcome_stats, LatencyStats, RequestOutcome, ServingMetrics};
use super::sched::Scheduler;
use super::stream::RequestStream;
use super::SimConfig;

/// Front-end routing policy of the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    JoinShortestQueue,
    /// Disaggregated prefill/decode pools with KV handoff.
    PrefillDecode,
}

impl RouterPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::JoinShortestQueue => "jsq",
            RouterPolicy::PrefillDecode => "prefill/decode",
        }
    }
}

/// Fleet shape: N identical replicas, or a disaggregated P+D split.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub router: RouterPolicy,
    /// Replica count for the homogeneous routers (round-robin / JSQ).
    pub n_replicas: usize,
    /// Prefill-pool size (PrefillDecode router).
    pub n_prefill: usize,
    /// Decode-pool size (PrefillDecode router).
    pub n_decode: usize,
    /// KV handoff cost per migrated token (s/token): the per-request
    /// migration delay is `context * handoff_s_per_token`.
    pub handoff_s_per_token: f64,
}

impl FleetConfig {
    pub fn homogeneous(n_replicas: usize, router: RouterPolicy) -> Self {
        debug_assert!(router != RouterPolicy::PrefillDecode);
        FleetConfig {
            router,
            n_replicas: n_replicas.max(1),
            n_prefill: 0,
            n_decode: 0,
            handoff_s_per_token: 0.0,
        }
    }

    pub fn disaggregated(n_prefill: usize, n_decode: usize, handoff_s_per_token: f64) -> Self {
        FleetConfig {
            router: RouterPolicy::PrefillDecode,
            n_replicas: 0,
            n_prefill: n_prefill.max(1),
            n_decode: n_decode.max(1),
            handoff_s_per_token,
        }
    }

    /// Total packages in the fleet (the TOPS-budget denominator).
    pub fn total_replicas(&self) -> usize {
        match self.router {
            RouterPolicy::PrefillDecode => self.n_prefill.max(1) + self.n_decode.max(1),
            _ => self.n_replicas.max(1),
        }
    }

    pub fn describe(&self) -> String {
        match self.router {
            RouterPolicy::PrefillDecode => format!(
                "{}P+{}D disagg ({:.1e} s/tok handoff)",
                self.n_prefill.max(1),
                self.n_decode.max(1),
                self.handoff_s_per_token
            ),
            r => format!("{}x {}", self.n_replicas.max(1), r.name()),
        }
    }
}

/// Fleet-wide serving quality: per-replica metrics plus request-level
/// TTFT/TPOT tails stitched across replica boundaries (for the
/// disaggregated router a request's first token and completion land on
/// different replicas, so per-replica tails alone would be wrong).
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    pub per_replica: Vec<ServingMetrics>,
    pub n_arrived: usize,
    pub n_completed: usize,
    pub n_rejected: usize,
    pub n_in_flight: usize,
    /// End-to-end TTFT over stitched outcomes (arrival -> first token).
    pub ttft: LatencyStats,
    /// End-to-end TPOT; for disaggregated fleets this includes the KV
    /// handoff delay between the prefill and decode stages.
    pub tpot: LatencyStats,
    pub slo_attainment: f64,
    pub goodput_rps: f64,
    /// SLO-constrained goodput (tok/s) over the fleet makespan — the
    /// fleet DSE objective.
    pub slo_goodput_tps: f64,
    pub throughput_tps: f64,
    /// Latest replica clock (the fleet drains when its last replica does).
    pub makespan_s: f64,
    pub energy_pj: f64,
    pub edp_under_load: f64,
    /// KV tokens migrated prefill -> decode (0 for homogeneous routers;
    /// block-granular for paged caches — whole blocks move).
    pub kv_transfer_tokens: u64,
    /// Busy-time-weighted mean KV-block internal fragmentation across
    /// replicas (0 for token-granular caches).
    pub kv_fragmentation: f64,
    /// Fleet-wide prefill tokens served from shared prefixes.
    pub kv_shared_tokens: u64,
    /// Fleet-wide sharing hit rate: shared tokens / prefill demand.
    pub kv_sharing_hit_rate: f64,
    /// Busy-time imbalance across replicas: `(max - min) / mean` of
    /// per-replica busy seconds (0 = perfectly balanced).
    pub load_imbalance: f64,
    pub truncated: bool,
}

impl FleetMetrics {
    /// Scalar objective for the fleet DSE (lower is better), mirroring
    /// [`ServingMetrics::objective`].
    pub fn objective(&self) -> f64 {
        if self.truncated {
            return 0.0;
        }
        -(self.slo_goodput_tps + 1e-3 * self.throughput_tps)
    }

    pub fn summary(&self) -> String {
        format!(
            "done {}/{} (rej {}) | {:.1} tok/s | goodput {:.1} tok/s | \
             ttft p99 {:.3}s | tpot p99 {:.4}s | SLO {:.0}% | imbalance {:.2} | kv-handoff {} tok",
            self.n_completed,
            self.n_arrived,
            self.n_rejected,
            self.throughput_tps,
            self.slo_goodput_tps,
            self.ttft.p99,
            self.tpot.p99,
            100.0 * self.slo_attainment,
            self.load_imbalance,
            self.kv_transfer_tokens,
        )
    }
}

/// One cost memo for the whole fleet: every replica shares the same
/// (model, hw, policy), so a batch shape costed — or GA-searched —
/// anywhere is never re-simulated elsewhere. Sharing is bit-exact: the
/// memo is composition-keyed and each entry is order-independent.
fn shared_coster<'a>(
    model: &'a ModelSpec,
    hw: &'a HwConfig,
    cfg: &SimConfig,
) -> Rc<RefCell<BatchCoster<'a>>> {
    Rc::new(RefCell::new(BatchCoster::new(
        model,
        hw,
        cfg.policy,
        cfg.eval_blocks,
        cfg.ctx_bucket,
        cfg.kv.dtype,
    )))
}

/// Pick the least-loaded replica by outstanding tokens (ties -> lowest
/// index, keeping routing deterministic).
fn jsq_pick(reps: &[Scheduler]) -> usize {
    let mut best = 0usize;
    let mut best_backlog = u64::MAX;
    for (i, s) in reps.iter().enumerate() {
        let b = s.backlog_tokens();
        if b < best_backlog {
            best_backlog = b;
            best = i;
        }
    }
    best
}

/// Replay `stream` across the fleet and aggregate. Deterministic:
/// identical inputs give bit-identical output.
pub fn simulate_fleet(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    fleet: &FleetConfig,
) -> FleetMetrics {
    match fleet.router {
        RouterPolicy::PrefillDecode => simulate_disaggregated(stream, model, hw, cfg, fleet),
        _ => simulate_homogeneous(stream, model, hw, cfg, fleet),
    }
}

fn simulate_homogeneous(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    fleet: &FleetConfig,
) -> FleetMetrics {
    let n_rep = fleet.n_replicas.max(1);
    let coster = shared_coster(model, hw, cfg);
    let mut reps: Vec<Scheduler> = (0..n_rep)
        .map(|_| Scheduler::with_coster(model, hw, cfg, coster.clone()))
        .collect();
    let mut rr_next = 0usize;
    for r in &stream.requests {
        for s in reps.iter_mut() {
            s.advance_to(r.arrival_s);
        }
        let k = match fleet.router {
            RouterPolicy::RoundRobin => {
                let k = rr_next % n_rep;
                rr_next += 1;
                k
            }
            _ => jsq_pick(&reps),
        };
        reps[k].inject(r.id, r.arrival_s, r.input_len, r.output_len);
    }
    for s in reps.iter_mut() {
        s.run_to_end();
    }
    let mut per_replica = Vec::with_capacity(n_rep);
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(stream.requests.len());
    for s in reps {
        let r = s.finish();
        outcomes.extend(r.outcomes.iter().map(|&(_, o)| o));
        per_replica.push(r.metrics);
    }
    aggregate(per_replica, outcomes, cfg)
}

/// A prefill-complete request waiting on its KV transfer.
struct Migration {
    t: f64,
    id: usize,
    /// Context tokens to materialize at the decode replica (prompt plus
    /// the first generated token).
    ctx: u64,
    /// Output tokens still to decode.
    rest: u64,
}

fn simulate_disaggregated(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    fleet: &FleetConfig,
) -> FleetMetrics {
    let (n_pre, n_dec) = (fleet.n_prefill.max(1), fleet.n_decode.max(1));
    let coster = shared_coster(model, hw, cfg);
    // spec-aware footprint probe (paging + sharing + dtype), the same
    // test every scheduler applies at arrival
    let fit_probe = KvCache::new(cfg.kv, cfg.kv_budget(model).max(2));
    // --- stage 1: prompts JSQ-routed over the prefill pool, truncated
    // to a single output token (emitted at prefill completion). A
    // request whose *full* footprint can never fit is injected with its
    // real output length so the scheduler rejects it at arrival with
    // zero compute — the same arrival-time rejection the homogeneous
    // routers apply, keeping the policies comparable on one stream ---
    let mut pre: Vec<Scheduler> = (0..n_pre)
        .map(|_| Scheduler::with_coster(model, hw, cfg, coster.clone()))
        .collect();
    for r in &stream.requests {
        for s in pre.iter_mut() {
            s.advance_to(r.arrival_s);
        }
        let k = jsq_pick(&pre);
        let out = r.output_len.max(1);
        if !fit_probe.can_ever_fit(r.input_len.max(1), out) {
            pre[k].inject(r.id, r.arrival_s, r.input_len, out);
        } else {
            pre[k].inject(r.id, r.arrival_s, r.input_len, 1);
        }
    }
    for s in pre.iter_mut() {
        s.run_to_end();
    }
    let mut per_replica = Vec::with_capacity(n_pre + n_dec);
    let mut pre_outcomes: Vec<(usize, RequestOutcome)> = Vec::with_capacity(stream.requests.len());
    for s in pre {
        let r = s.finish();
        pre_outcomes.extend(r.outcomes);
        per_replica.push(r.metrics);
    }

    // --- KV handoff: completed prefills migrate to the decode pool
    // after `ctx * handoff_s_per_token` seconds, in global time order ---
    let out_len_of: std::collections::HashMap<usize, u64> = stream
        .requests
        .iter()
        .map(|r| (r.id, r.output_len.max(1)))
        .collect();
    let mut migs: Vec<Migration> = Vec::new();
    for &(id, o) in &pre_outcomes {
        let (Some(finish), false) = (o.finish_s, o.rejected) else {
            continue;
        };
        let rest = out_len_of.get(&id).copied().unwrap_or(1).saturating_sub(1);
        if rest == 0 {
            continue; // single-token request: done at prefill
        }
        let ctx = o.input_len + 1;
        // whole blocks migrate: the link moves the context rounded up to
        // the KV block size (exact at block_tokens = 1)
        let link_tokens = cfg.kv.block_round(ctx);
        migs.push(Migration {
            t: finish + link_tokens as f64 * fleet.handoff_s_per_token.max(0.0),
            id,
            ctx,
            rest,
        });
    }
    migs.sort_by(|a, b| a.t.total_cmp(&b.t).then(a.id.cmp(&b.id)));

    // --- stage 2: migrations JSQ-routed over the decode pool (sharing
    // the prefill pool's cost memo: same model/hw/policy) ---
    let mut dec: Vec<Scheduler> = (0..n_dec)
        .map(|_| Scheduler::with_coster(model, hw, cfg, coster.clone()))
        .collect();
    for m in &migs {
        for s in dec.iter_mut() {
            s.advance_to(m.t);
        }
        let k = jsq_pick(&dec);
        dec[k].inject_migrated(m.id, m.t, m.ctx, m.rest);
    }
    for s in dec.iter_mut() {
        s.run_to_end();
    }
    let mut dec_outcomes: Vec<(usize, RequestOutcome)> = Vec::with_capacity(migs.len());
    for s in dec {
        let r = s.finish();
        dec_outcomes.extend(r.outcomes);
        per_replica.push(r.metrics);
    }

    // --- stitch per-request outcomes across the two stages ---
    let dec_by_id: std::collections::HashMap<usize, RequestOutcome> =
        dec_outcomes.into_iter().collect();
    let outcomes: Vec<RequestOutcome> = pre_outcomes
        .iter()
        .map(|&(id, p)| {
            let out_len = out_len_of.get(&id).copied().unwrap_or(1);
            let mut o = RequestOutcome {
                arrival_s: p.arrival_s,
                input_len: p.input_len,
                output_len: out_len,
                first_token_s: p.first_token_s,
                finish_s: if out_len == 1 { p.finish_s } else { None },
                rejected: p.rejected,
            };
            if let Some(d) = dec_by_id.get(&id) {
                // decode-stage rejection (context can never fit there)
                // makes the whole request rejected at fleet level
                o.rejected = p.rejected || d.rejected;
                o.finish_s = d.finish_s;
            }
            o
        })
        .collect();
    aggregate(per_replica, outcomes, cfg)
}

fn aggregate(
    per_replica: Vec<ServingMetrics>,
    outcomes: Vec<RequestOutcome>,
    cfg: &SimConfig,
) -> FleetMetrics {
    let s = outcome_stats(&outcomes, &cfg.slo);
    let makespan_s = per_replica.iter().map(|m| m.makespan_s).fold(0.0, f64::max);
    let span = makespan_s.max(1e-12);
    let gen_tokens: u64 = per_replica.iter().map(|m| m.gen_tokens).sum();
    let energy_pj: f64 = per_replica.iter().map(|m| m.energy_pj).sum();
    let kv_transfer_tokens: u64 = per_replica.iter().map(|m| m.kv_transfer_tokens).sum();
    let kv_shared_tokens: u64 = per_replica.iter().map(|m| m.kv_shared_tokens).sum();
    let kv_demand_tokens: u64 = per_replica.iter().map(|m| m.kv_demand_tokens).sum();
    let truncated = per_replica.iter().any(|m| m.truncated);
    let busy: Vec<f64> = per_replica.iter().map(|m| m.busy_s).collect();
    let busy_sum: f64 = busy.iter().sum();
    // per-replica fragmentation is already busy-weighted, so the fleet
    // mean re-weights by each replica's busy time
    let kv_fragmentation = if busy_sum > 1e-12 {
        per_replica
            .iter()
            .map(|m| m.kv_fragmentation * m.busy_s)
            .sum::<f64>()
            / busy_sum
    } else {
        0.0
    };
    let mean_busy = busy_sum / busy.len().max(1) as f64;
    let load_imbalance = if mean_busy > 1e-12 {
        let max = busy.iter().cloned().fold(f64::MIN, f64::max);
        let min = busy.iter().cloned().fold(f64::MAX, f64::min);
        (max - min) / mean_busy
    } else {
        0.0
    };
    FleetMetrics {
        n_arrived: outcomes.len(),
        n_completed: s.n_completed,
        n_rejected: s.n_rejected,
        n_in_flight: s.n_in_flight,
        ttft: LatencyStats::from(&s.ttfts),
        tpot: LatencyStats::from(&s.tpots),
        slo_attainment: if s.n_completed > 0 {
            s.slo_ok as f64 / s.n_completed as f64
        } else {
            0.0
        },
        goodput_rps: s.slo_ok as f64 / span,
        slo_goodput_tps: s.slo_ok_tokens as f64 / span,
        throughput_tps: gen_tokens as f64 / span,
        makespan_s,
        energy_pj,
        edp_under_load: (energy_pj * 1e-12) * makespan_s,
        kv_transfer_tokens,
        kv_fragmentation,
        kv_shared_tokens,
        kv_sharing_hit_rate: if kv_demand_tokens > 0 {
            kv_shared_tokens as f64 / kv_demand_tokens as f64
        } else {
            0.0
        },
        load_imbalance,
        truncated,
        per_replica,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::sim::coster::MappingPolicy;
    use crate::sim::metrics::SloSpec;
    use crate::sim::simulate_serving;
    use crate::workload::serving::ServingStrategy;
    use crate::workload::trace::TraceSpec;

    fn tiny_hw() -> HwConfig {
        HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        )
    }

    fn tiny_spec() -> TraceSpec {
        TraceSpec {
            mean_in: 48.0,
            mean_out: 8.0,
            sigma_in: 0.5,
            sigma_out: 0.4,
            max_len: 4096,
            shared_prefix_tokens: 0,
        }
    }

    fn tiny_cfg() -> SimConfig {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.policy = MappingPolicy::Pipeline;
        cfg.max_batch = 6;
        cfg.chunk_tokens = 24;
        cfg.kv_budget_tokens = 1024;
        cfg.ctx_bucket = 32;
        cfg.eval_blocks = 1;
        cfg.slo = SloSpec::new(0.5, 0.1);
        cfg
    }

    fn tiny_stream(rate_scale: f64, n: usize, seed: u64) -> RequestStream {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let probe = crate::sim::probe(&model, &hw, &cfg, &tiny_spec());
        RequestStream::poisson(&tiny_spec(), rate_scale * probe.capacity_rps(), n, seed)
    }

    #[test]
    fn one_replica_fleet_matches_single_package() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let stream = tiny_stream(1.1, 10, 7);
        let single = simulate_serving(&stream, &model, &hw, &cfg);
        for router in [RouterPolicy::RoundRobin, RouterPolicy::JoinShortestQueue] {
            let fleet = FleetConfig::homogeneous(1, router);
            let f = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(f.per_replica.len(), 1);
            let m = &f.per_replica[0];
            assert_eq!(m.makespan_s.to_bits(), single.makespan_s.to_bits());
            assert_eq!(m.energy_pj.to_bits(), single.energy_pj.to_bits());
            assert_eq!(m.n_iterations, single.n_iterations);
            assert_eq!(f.n_completed, single.n_completed);
            assert_eq!(f.ttft.p99.to_bits(), single.ttft.p99.to_bits());
        }
    }

    #[test]
    fn fleet_conserves_and_is_deterministic_per_policy() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let stream = tiny_stream(2.5, 14, 3);
        for fleet in [
            FleetConfig::homogeneous(3, RouterPolicy::RoundRobin),
            FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue),
            FleetConfig::disaggregated(1, 2, 1e-7),
        ] {
            let a = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(
                a.n_completed + a.n_rejected,
                a.n_arrived,
                "{}",
                fleet.describe()
            );
            assert_eq!(a.per_replica.len(), fleet.total_replicas());
            assert!(a.n_completed > 0, "{}", fleet.describe());
            let b = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.ttft.p99.to_bits(), b.ttft.p99.to_bits());
            assert_eq!(a.kv_transfer_tokens, b.kv_transfer_tokens);
        }
    }

    #[test]
    fn disaggregation_migrates_kv_and_pays_handoff() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let stream = tiny_stream(1.5, 12, 9);
        let cheap = FleetConfig::disaggregated(1, 1, 0.0);
        let a = simulate_fleet(&stream, &model, &hw, &cfg, &cheap);
        assert!(
            a.kv_transfer_tokens > 0,
            "disaggregation must report KV handoff traffic"
        );
        // every multi-token request migrates at least its prompt + 1
        let multi: u64 = stream
            .requests
            .iter()
            .filter(|r| r.output_len > 1)
            .map(|r| r.input_len + 1)
            .sum();
        assert!(a.kv_transfer_tokens >= multi);
        // a costly handoff link can only stretch completion times
        let slow = FleetConfig::disaggregated(1, 1, 1e-3);
        let b = simulate_fleet(&stream, &model, &hw, &cfg, &slow);
        assert_eq!(a.n_completed, b.n_completed);
        assert!(
            b.makespan_s >= a.makespan_s - 1e-9,
            "handoff cost shortened the run: {} < {}",
            b.makespan_s,
            a.makespan_s
        );
        assert!(b.tpot.p99 >= a.tpot.p99 - 1e-12, "handoff must tax TPOT");
    }

    #[test]
    fn jsq_balances_no_worse_than_round_robin() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        // overload: imbalance shows up when replicas saturate
        let stream = tiny_stream(3.9, 24, 5);
        let rr = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(3, RouterPolicy::RoundRobin),
        );
        let jsq = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue),
        );
        // backlog-aware routing must beat blind rotation on at least one
        // of: work balance, or drain time (both, typically)
        assert!(
            jsq.load_imbalance <= rr.load_imbalance + 1e-9
                || jsq.makespan_s <= rr.makespan_s + 1e-9,
            "jsq (imbalance {}, makespan {}) worse than rr ({}, {})",
            jsq.load_imbalance,
            jsq.makespan_s,
            rr.load_imbalance,
            rr.makespan_s
        );
    }

    /// Paged + prefix-sharing caches across a fleet: runs conserve,
    /// handoff traffic is block-rounded, and the aggregated sharing /
    /// fragmentation stats are populated.
    #[test]
    fn paged_shared_fleet_conserves_and_rounds_handoff() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg();
        cfg.kv_budget_tokens = 1024;
        cfg.kv = crate::sim::KvSpec::paged(16).with_prefix(32);
        let spec = tiny_spec().with_prefix(32);
        let probe = crate::sim::probe(&model, &hw, &cfg, &spec);
        // heavy overload: admissions overlap, so the materialized prefix
        // is referenced by co-resident requests (sharing hits)
        let stream = RequestStream::poisson(&spec, 2.5 * probe.capacity_rps(), 12, 9);
        for fleet in [
            FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue),
            FleetConfig::disaggregated(1, 1, 1e-7),
        ] {
            let m = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(
                m.n_completed + m.n_rejected,
                m.n_arrived,
                "{}",
                fleet.describe()
            );
            assert!(m.kv_shared_tokens > 0, "{}: no sharing hits", fleet.describe());
            assert!(m.kv_sharing_hit_rate > 0.0);
            assert!(m.kv_fragmentation >= 0.0 && m.kv_fragmentation <= 1.0);
            if fleet.router == RouterPolicy::PrefillDecode {
                // whole 16-token blocks migrate
                assert!(m.kv_transfer_tokens > 0);
                assert_eq!(m.kv_transfer_tokens % 16, 0, "handoff not block-granular");
            }
            // deterministic
            let b = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(m.makespan_s.to_bits(), b.makespan_s.to_bits());
        }
    }

    #[test]
    fn empty_stream_yields_zeroed_fleet() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let stream = RequestStream {
            name: "empty".into(),
            requests: Vec::new(),
            rate_rps: 1.0,
            seed: 0,
        };
        let f = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue),
        );
        assert_eq!(f.n_arrived, 0);
        assert_eq!(f.n_completed, 0);
        assert!(!f.truncated);
        assert_eq!(f.makespan_s, 0.0);
    }
}

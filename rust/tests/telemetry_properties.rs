//! Telemetry properties: the sink-attachment bitwise anchor, span ↔
//! outcome conservation under randomized fault storms, and trace
//! export determinism.
//!
//! The anchor is the contract that makes telemetry safe to keep wired
//! through the whole serving stack: attaching a sink — the disabled
//! [`NullSink`] or the recording [`SpanCollector`] — must leave every
//! entry point (`simulate_serving`, `simulate_fleet`,
//! `simulate_fleet_frontend` homogeneous and disaggregated,
//! `simulate_fleet_faults`) bitwise-identical in per-replica metrics
//! *and* per-request timings. Emission happens strictly after each
//! step's arithmetic, so the anchor holds by construction; these tests
//! keep it honest across randomized strategies, fleets, front ends
//! and seeded crash/straggler schedules.
//!
//! On top of the anchor: every recorded request lane tiles its
//! lifetime contiguously (durations sum to the lane window), lane
//! populations reproduce the run totals, lane windows bound (exactly,
//! without faults) the stitched outcome latencies, and the Chrome
//! trace JSON serializes to the identical byte string on rerun.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{
    self, FaultSchedule, FleetConfig, Frontend, MappingPolicy, NullSink, RequestStream,
    ResilienceSpec, RetryPolicy, RouterPolicy, SimConfig, SloSpec, SpanCollector,
};
use compass::util::Rng;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

fn tiny_hw() -> HwConfig {
    HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    )
}

fn tiny_spec() -> TraceSpec {
    TraceSpec {
        mean_in: 48.0,
        mean_out: 8.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 4096,
        shared_prefix_tokens: 0,
    }
}

fn cfg_for(strategy: ServingStrategy, kv_tokens: u64) -> SimConfig {
    let mut cfg = SimConfig::new(strategy);
    cfg.policy = MappingPolicy::Pipeline;
    cfg.max_batch = 6;
    cfg.chunk_tokens = 24;
    cfg.kv_budget_tokens = kv_tokens;
    cfg.ctx_bucket = 32;
    cfg.eval_blocks = 1;
    cfg.slo = SloSpec::new(0.5, 0.1);
    cfg.max_iterations = 500_000;
    cfg
}

fn null_sink() -> sim::SharedSink {
    std::sync::Arc::new(std::sync::Mutex::new(NullSink))
}

fn collector() -> (std::sync::Arc<std::sync::Mutex<SpanCollector>>, sim::SharedSink) {
    let c = SpanCollector::shared();
    let sink: sim::SharedSink = c.clone();
    (c, sink)
}

/// Full bitwise comparison of two single-replica results.
fn assert_serving_bitwise(a: &sim::ServingMetrics, b: &sim::ServingMetrics, ctx: &str) {
    assert_eq!(a.n_arrived, b.n_arrived, "{ctx}: arrived");
    assert_eq!(a.n_completed, b.n_completed, "{ctx}: completed");
    assert_eq!(a.n_rejected, b.n_rejected, "{ctx}: rejected");
    assert_eq!(a.n_in_flight, b.n_in_flight, "{ctx}: in flight");
    assert_eq!(a.n_preemptions, b.n_preemptions, "{ctx}: preemptions");
    assert_eq!(a.n_iterations, b.n_iterations, "{ctx}: iterations");
    assert_eq!(a.truncated, b.truncated, "{ctx}: truncated");
    assert_eq!(a.distinct_shapes, b.distinct_shapes, "{ctx}: shapes");
    assert_eq!(a.gen_tokens, b.gen_tokens, "{ctx}: gen tokens");
    assert_eq!(a.kv_transfer_tokens, b.kv_transfer_tokens, "{ctx}: kv transfer");
    assert_eq!(a.max_queue_depth, b.max_queue_depth, "{ctx}: max queue");
    for (name, x, y) in [
        ("makespan", a.makespan_s, b.makespan_s),
        ("busy", a.busy_s, b.busy_s),
        ("throughput", a.throughput_tps, b.throughput_tps),
        ("goodput", a.goodput_rps, b.goodput_rps),
        ("slo goodput", a.slo_goodput_tps, b.slo_goodput_tps),
        ("ttft mean", a.ttft.mean, b.ttft.mean),
        ("ttft p99", a.ttft.p99, b.ttft.p99),
        ("tpot mean", a.tpot.mean, b.tpot.mean),
        ("tpot p99", a.tpot.p99, b.tpot.p99),
        ("slo attainment", a.slo_attainment, b.slo_attainment),
        ("mean queue", a.mean_queue_depth, b.mean_queue_depth),
        ("occupancy", a.mean_batch_occupancy, b.mean_batch_occupancy),
        ("utilization", a.utilization, b.utilization),
        ("energy", a.energy_pj, b.energy_pj),
        ("edp", a.edp_under_load, b.edp_under_load),
        ("frag", a.kv_fragmentation, b.kv_fragmentation),
        ("concurrency", a.effective_concurrency, b.effective_concurrency),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}");
    }
}

/// Full bitwise comparison of two fleet results: per-replica metrics
/// and per-request outcome timings.
fn assert_fleet_bitwise(a: &sim::FleetMetrics, b: &sim::FleetMetrics, ctx: &str) {
    assert_eq!(a.per_replica.len(), b.per_replica.len(), "{ctx}: replica count");
    for (i, (x, y)) in a.per_replica.iter().zip(&b.per_replica).enumerate() {
        assert_serving_bitwise(x, y, &format!("{ctx}: replica {i}"));
    }
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{ctx}: outcome count");
    for (i, (x, y)) in a.outcomes.iter().zip(&b.outcomes).enumerate() {
        assert_eq!(
            x.arrival_s.to_bits(),
            y.arrival_s.to_bits(),
            "{ctx}: outcome {i} arrival"
        );
        assert_eq!(
            x.first_token_s.map(f64::to_bits),
            y.first_token_s.map(f64::to_bits),
            "{ctx}: outcome {i} first token"
        );
        assert_eq!(
            x.finish_s.map(f64::to_bits),
            y.finish_s.map(f64::to_bits),
            "{ctx}: outcome {i} finish"
        );
        assert_eq!(x.rejected, y.rejected, "{ctx}: outcome {i} rejected");
    }
    assert_eq!(a.n_shed, b.n_shed, "{ctx}: shed");
    assert_eq!(a.n_rebalanced, b.n_rebalanced, "{ctx}: rebalanced");
    assert_eq!(a.kv_transfer_tokens, b.kv_transfer_tokens, "{ctx}: kv transfer");
    assert_eq!(a.faults.n_failed, b.faults.n_failed, "{ctx}: failed");
    assert_eq!(a.faults.n_retried, b.faults.n_retried, "{ctx}: retried");
    assert_eq!(a.faults.n_lost, b.faults.n_lost, "{ctx}: lost");
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{ctx}: makespan");
    assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{ctx}: energy");
    assert_eq!(a.ttft.p99.to_bits(), b.ttft.p99.to_bits(), "{ctx}: ttft p99");
    assert_eq!(a.tpot.p99.to_bits(), b.tpot.p99.to_bits(), "{ctx}: tpot p99");
}

/// Lane-level conservation: every lane tiles its window, and the lane
/// population reproduces the run totals.
fn assert_lanes_conserve(
    c: &SpanCollector,
    n_arrived: usize,
    n_completed: usize,
    n_rejected: usize,
    ctx: &str,
) {
    let lanes = c.waterfall();
    for lane in &lanes {
        let window = lane.last_close_s - lane.first_open_s;
        let sum = lane.total_s();
        assert!(
            (sum - window).abs() <= 1e-6 * window.abs().max(1e-9),
            "{ctx}: req {} spans sum {sum:.12} != window {window:.12}",
            lane.ext_id
        );
        let mut cursor = lane.first_open_s;
        for sp in &lane.spans {
            assert_eq!(
                sp.start_s.to_bits(),
                cursor.to_bits(),
                "{ctx}: req {} spans are not contiguous",
                lane.ext_id
            );
            assert!(sp.end_s >= sp.start_s, "{ctx}: req {} negative span", lane.ext_id);
            cursor = sp.end_s;
        }
    }
    assert_eq!(lanes.len(), n_arrived, "{ctx}: lanes != arrivals");
    assert_eq!(
        lanes.iter().filter(|l| l.finished).count(),
        n_completed,
        "{ctx}: finished lanes != completed"
    );
    assert_eq!(
        lanes.iter().filter(|l| l.rejected).count(),
        n_rejected,
        "{ctx}: rejected lanes != rejections"
    );
    assert_eq!(c.n_finished(), n_completed, "{ctx}: n_finished");
}

/// Attaching a sink to the single-replica simulator — null or
/// recording — is bitwise-free across strategies and load levels.
#[test]
fn serving_sinks_are_bitwise_free() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut rng = Rng::seed_from_u64(0x7E1E);
    for trial in 0..9 {
        let strategy = ServingStrategy::ALL[trial % 3];
        let cfg = cfg_for(strategy, *rng.choose(&[4096u64, 768]));
        let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
        let rate = (0.5 + rng.gen_f64() * 1.5) * probe.capacity_rps();
        let stream =
            RequestStream::poisson(&tiny_spec(), rate, 8 + rng.gen_index(8), rng.next_u64());
        let ctx = format!("trial {trial} {strategy:?}");
        let plain = sim::simulate_serving(&stream, &model, &hw, &cfg);
        let nulled = sim::simulate_serving_traced(&stream, &model, &hw, &cfg, &null_sink());
        assert_serving_bitwise(&plain, &nulled, &format!("{ctx} null"));
        let (c, sink) = collector();
        let traced = sim::simulate_serving_traced(&stream, &model, &hw, &cfg, &sink);
        assert_serving_bitwise(&plain, &traced, &format!("{ctx} recording"));
        let c = c.lock().unwrap();
        assert!(
            c.events().is_empty() == (traced.n_arrived == 0),
            "{ctx}: recording sink saw nothing"
        );
        assert_lanes_conserve(
            &c,
            traced.n_arrived,
            traced.n_completed,
            traced.n_rejected,
            &ctx,
        );
    }
}

/// The fleet front end — homogeneous under every front-end policy, and
/// disaggregated with KV handoff — is bitwise-free under recording
/// sinks, and the recorded lanes conserve.
#[test]
fn fleet_frontend_sinks_are_bitwise_free() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut rng = Rng::seed_from_u64(0xBEE5);
    for trial in 0..6 {
        let strategy = ServingStrategy::ALL[trial % 3];
        let cfg = cfg_for(strategy, 4096);
        let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
        let n_rep = 2 + trial % 2;
        let fleet = if trial % 3 == 2 {
            FleetConfig::disaggregated(1, n_rep - 1, 1e-7)
        } else {
            FleetConfig::homogeneous(n_rep, RouterPolicy::JoinShortestQueue)
        };
        let fe = if trial % 2 == 0 {
            Frontend::baseline()
        } else {
            Frontend::with_shedding(probe, 1.0)
        };
        let rate = (0.6 + rng.gen_f64() * 1.2) * n_rep as f64 * probe.capacity_rps();
        let stream =
            RequestStream::poisson(&tiny_spec(), rate, 10 + rng.gen_index(6), rng.next_u64());
        let hws = vec![hw.clone(); fleet.total_replicas()];
        let ctx = format!("trial {trial} {strategy:?} {}", fleet.describe());
        let plain = sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, &fe);
        let nulled = sim::simulate_fleet_frontend_traced(
            &stream,
            &model,
            &hws,
            &cfg,
            &fleet,
            &fe,
            &null_sink(),
        );
        assert_fleet_bitwise(&plain, &nulled, &format!("{ctx} null"));
        let (c, sink) = collector();
        let traced =
            sim::simulate_fleet_frontend_traced(&stream, &model, &hws, &cfg, &fleet, &fe, &sink);
        assert_fleet_bitwise(&plain, &traced, &format!("{ctx} recording"));
        assert_lanes_conserve(
            &c.lock().unwrap(),
            traced.n_arrived,
            traced.n_completed,
            traced.n_rejected,
            &ctx,
        );
    }
}

/// `simulate_fleet_traced` (the legacy wrapper) inherits the anchor.
#[test]
fn fleet_wrapper_sink_is_bitwise_free() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 4096);
    let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
    let fleet = FleetConfig::homogeneous(2, RouterPolicy::RoundRobin);
    let stream = RequestStream::poisson(
        &tiny_spec(),
        1.4 * probe.capacity_rps(),
        12,
        0xF1EE7,
    );
    let plain = sim::simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
    let (c, sink) = collector();
    let traced = sim::simulate_fleet_traced(&stream, &model, &hw, &cfg, &fleet, &sink);
    assert_fleet_bitwise(&plain, &traced, "fleet wrapper");
    assert_lanes_conserve(
        &c.lock().unwrap(),
        traced.n_arrived,
        traced.n_completed,
        traced.n_rejected,
        "fleet wrapper",
    );
}

/// The fault layer is bitwise-free under recording sinks across
/// randomized crash/straggler storms with retries, the recorded lanes
/// conserve, and lane windows bound the stitched outcome latencies
/// from above (crash clocks can overshoot, never undershoot).
#[test]
fn fault_storm_sinks_are_bitwise_free_and_lanes_conserve() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut rng = Rng::seed_from_u64(0x57012);
    for trial in 0..8 {
        let strategy = ServingStrategy::ALL[trial % 3];
        let cfg = cfg_for(strategy, *rng.choose(&[4096u64, 768]));
        let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
        let n_rep = 2 + trial % 2;
        let fleet = FleetConfig::homogeneous(n_rep, RouterPolicy::JoinShortestQueue);
        let rate = (0.6 + rng.gen_f64() * 1.8) * n_rep as f64 * probe.capacity_rps();
        let stream =
            RequestStream::poisson(&tiny_spec(), rate, 10 + rng.gen_index(8), rng.next_u64());
        let schedule = FaultSchedule::seeded(
            n_rep,
            stream.horizon_s(),
            1 + trial % 2,
            trial % 3,
            rng.next_u64(),
        );
        let retry = if trial % 2 == 0 {
            RetryPolicy::capped(3, 0.2 * probe.t_prefill_s, 2.0)
        } else {
            RetryPolicy::disabled()
        };
        let res = ResilienceSpec::none()
            .with_schedule(schedule.clone())
            .with_retry(retry)
            .with_failover(trial % 3 != 2);
        let hws = vec![hw.clone(); n_rep];
        let ctx = format!(
            "trial {trial} {strategy:?} {} under {}",
            res.describe(),
            schedule.describe()
        );
        let plain = sim::simulate_fleet_faults(
            &stream,
            &model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
            &res,
        );
        let (c, sink) = collector();
        let traced = sim::simulate_fleet_faults_traced(
            &stream,
            &model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
            &res,
            &sink,
        );
        assert_fleet_bitwise(&plain, &traced, &ctx);
        let c = c.lock().unwrap();
        assert_lanes_conserve(
            &c,
            traced.n_arrived,
            traced.n_completed,
            traced.n_rejected,
            &ctx,
        );
        // sorted lane windows dominate sorted outcome latencies: the
        // pointwise bound (lane opens at arrival, closes at or after
        // finish) survives taking k-th order statistics
        let mut lane_lat: Vec<f64> = c
            .waterfall()
            .iter()
            .filter(|l| l.finished)
            .map(|l| l.last_close_s - l.first_open_s)
            .collect();
        let mut out_lat: Vec<f64> = traced
            .outcomes
            .iter()
            .filter_map(|o| o.finish_s.map(|f| f - o.arrival_s))
            .collect();
        lane_lat.sort_by(f64::total_cmp);
        out_lat.sort_by(f64::total_cmp);
        assert_eq!(lane_lat.len(), out_lat.len(), "{ctx}: latency sample count");
        for (l, o) in lane_lat.iter().zip(&out_lat) {
            assert!(
                l + 1e-6 * o.abs().max(1.0) >= *o,
                "{ctx}: lane window {l:.12} below outcome latency {o:.12}"
            );
        }
        // without recorded failures the bound is an equality
        if traced.faults.n_failed == 0 {
            for (l, o) in lane_lat.iter().zip(&out_lat) {
                assert!(
                    (l - o).abs() <= 1e-6 * o.abs().max(1e-9),
                    "{ctx}: faultless lane window {l:.12} != latency {o:.12}"
                );
            }
        }
    }
}

/// The Chrome trace export serializes the same run to the identical
/// byte string, and the JSONL run-record line is stable too.
#[test]
fn trace_exports_are_deterministic() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 4096);
    let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
    let fleet = FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue);
    let stream = RequestStream::poisson(
        &tiny_spec(),
        1.3 * 2.0 * probe.capacity_rps(),
        14,
        0xD0C5,
    );
    let schedule = FaultSchedule::seeded(2, stream.horizon_s(), 1, 1, 23);
    let res = ResilienceSpec::none()
        .with_schedule(schedule)
        .with_retry(RetryPolicy::capped(2, 0.2 * probe.t_prefill_s, 2.0))
        .with_failover(true);
    let hws = vec![hw.clone(); 2];
    let run = || {
        let (c, sink) = collector();
        let m = sim::simulate_fleet_faults_traced(
            &stream,
            &model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
            &res,
            &sink,
        );
        (c.lock().unwrap().chrome_trace_json(), m)
    };
    let (j1, m1) = run();
    let (j2, _) = run();
    assert_eq!(j1, j2, "trace JSON differs between identical reruns");
    assert!(j1.starts_with("{\"traceEvents\":["));
    assert!(j1.ends_with("\"displayTimeUnit\":\"ms\"}\n"));
    assert!(j1.contains("\"run_summary\""));
    assert!(j1.contains("\"cat\":\"request\""));

    let rec = sim::RunRecord {
        study: "fault-study".to_string(),
        cell: "fault+failover+retry".to_string(),
        rate_rps: 3.25,
        n_arrived: m1.n_arrived,
        n_completed: m1.n_completed,
        n_rejected: m1.n_rejected,
        slo_attainment: m1.slo_attainment,
        slo_goodput_tps: m1.slo_goodput_tps,
        throughput_tps: m1.throughput_tps,
        ttft_p99_s: m1.ttft.p99,
        tpot_p99_s: m1.tpot.p99,
        makespan_s: m1.makespan_s,
        energy_pj: m1.energy_pj,
        truncated: m1.truncated,
        degraded: false,
    };
    assert_eq!(rec.to_json(), rec.to_json(), "run record line unstable");
    assert!(rec.to_json().starts_with("{\"study\":\"fault-study\""));
    assert!(rec.to_json().contains("\"degraded\":false"));
}

"""AOT lowering: every artifact lowers to parseable HLO text with the
fixed shapes the Rust runtime expects, and the lowered graphs compute the
same numbers as direct jax evaluation (artifact <-> eager equivalence)."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.constants import CAND_Q, SLOTS, SYS_D, TRAIN_N, TYPES


@pytest.mark.parametrize("name", list(aot.ARTIFACTS))
def test_artifact_lowers_to_hlo_text(name):
    text = aot.lower_artifact(name)
    assert "ENTRY" in text and "HloModule" in text
    # no Mosaic custom-calls may leak in (CPU PJRT cannot execute them)
    assert "tpu_custom_call" not in text
    assert "mosaic" not in text.lower()


def test_gram_train_shapes_roundtrip():
    fn, specs = aot.ARTIFACTS["gram_train"]
    out = jax.eval_shape(fn, *specs)
    assert out[0].shape == (TRAIN_N, TRAIN_N)


def test_gram_cross_shapes_roundtrip():
    fn, specs = aot.ARTIFACTS["gram_cross"]
    out = jax.eval_shape(fn, *specs)
    assert out[0].shape == (CAND_Q, TRAIN_N)


def test_gp_fit_shapes():
    fn, specs = aot.ARTIFACTS["gp_fit"]
    alpha, chol, mll = jax.eval_shape(fn, *specs)
    assert alpha.shape == (TRAIN_N,)
    assert chol.shape == (TRAIN_N, TRAIN_N)
    assert mll.shape == ()


def test_gp_ei_shapes():
    fn, specs = aot.ARTIFACTS["gp_ei"]
    mean, var, ei = jax.eval_shape(fn, *specs)
    assert mean.shape == var.shape == ei.shape == (CAND_Q,)


def test_cli_writes_manifest(tmp_path):
    out = str(tmp_path)
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", out, "--only", "gram_diag"],
        check=True,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    manifest = json.load(open(os.path.join(out, "manifest.json")))
    assert manifest["shapes"]["SLOTS"] == SLOTS
    assert "gram_diag" in manifest["artifacts"]
    hlo = open(os.path.join(out, "gram_diag.hlo.txt")).read()
    assert "ENTRY" in hlo


def test_full_padded_pipeline_numerics():
    """End-to-end at artifact shapes: gram -> fit -> ei stays finite and
    reproduces a small-scale eager computation embedded in the padding."""
    rng = np.random.default_rng(42)
    n_act = 10
    xsys = np.zeros((TRAIN_N, SYS_D), np.float32)
    xsys[:n_act] = rng.normal(size=(n_act, SYS_D))
    ils = np.full(SYS_D, 0.5, np.float32)
    a = np.zeros((TRAIN_N, SLOTS, TYPES), np.float32)
    for i in range(n_act):
        occ = rng.random(SLOTS) < 0.2
        a[i, occ, rng.integers(0, 2, occ.sum())] = 1.0
    w = np.exp(-rng.random((SLOTS, SLOTS)).astype(np.float32))
    w = (w + w.T) / 2
    np.fill_diagonal(w, 1.0)
    sa = np.tile(np.array([[4.0, 4.0]], np.float32), (TRAIN_N, 1))
    k = model.composite_gram(
        *map(jnp.asarray, (xsys, xsys, ils, a, a, w, sa, sa)), jnp.float32(0.1)
    )[0]
    y = np.zeros(TRAIN_N, np.float32)
    y[:n_act] = rng.normal(size=n_act)
    mask = np.zeros(TRAIN_N, np.float32)
    mask[:n_act] = 1.0
    alpha, chol, mll = model.gp_fit(
        k, jnp.asarray(y), jnp.asarray(mask), jnp.float32(0.01)
    )
    assert np.isfinite(float(mll))
    assert np.isfinite(np.asarray(alpha)).all()
    kc = model.composite_gram(
        jnp.asarray(xsys[:CAND_Q]),
        jnp.asarray(xsys),
        jnp.asarray(ils),
        jnp.asarray(a[:CAND_Q]),
        jnp.asarray(a),
        jnp.asarray(w),
        jnp.asarray(sa[:CAND_Q]),
        jnp.asarray(sa),
        jnp.float32(0.1),
    )[0]
    kd = model.gram_diag(jnp.asarray(a[:CAND_Q]), jnp.asarray(w), jnp.float32(0.1))[0]
    mean, var, ei = model.gp_ei(
        kc, kd, chol, alpha, jnp.asarray(mask), jnp.float32(float(y[:n_act].min()))
    )
    assert np.isfinite(np.asarray(mean)).all()
    assert (np.asarray(var) >= 0).all()
    assert (np.asarray(ei) >= 0).all()

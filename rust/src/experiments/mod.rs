//! Experiment generators: one function per paper table/figure, shared by
//! the `repro` CLI, the examples, and the `cargo bench` harnesses.
//! DESIGN.md's experiment index maps each paper artifact to the function
//! here that regenerates it.

pub mod scenes;

use crate::arch::{Chiplet, ChipletClass, Dataflow, HwConfig, HwSpace};
use crate::baselines::{fixed_length_scenario, gemini, moham, random, scar};
#[cfg(feature = "xla")]
use crate::bo::PjrtGp;
use crate::bo::{Gp, NativeGp};
use crate::cost::engine::par_map;
use crate::cost::{edp_of, edp_probe, Evaluator, SimOptions};
use crate::dse::{self, DseConfig};
use crate::ga::GaConfig;
use crate::report::{ascii_occupancy, ascii_timeline, normalize_max, Table};
use crate::runtime::Runtime;
use crate::sim;
use crate::workload::serving::{Scenario, ServingStrategy};
use crate::workload::trace::{Trace, TraceSpec};
use crate::workload::{ModelSpec, Phase};

use std::sync::{Arc, Mutex};

pub use scenes::{model_for_tops, FleetScene, Scene, SimScene};

/// Select a GP backend: PJRT artifacts when available (and the `xla`
/// feature is compiled in), else the native mirror (prints which one was
/// picked).
pub fn make_gp(rt: Option<&Runtime>) -> Box<dyn Gp + '_> {
    #[cfg(feature = "xla")]
    if let Some(rt) = rt {
        if rt.artifacts_available() {
            if let Err(e) = rt.check_manifest() {
                eprintln!("[compass] artifact manifest check failed: {e}; using native GP");
            } else {
                return Box::new(PjrtGp::new(rt));
            }
        } else {
            eprintln!(
                "[compass] artifacts not found under {} (run `make artifacts`); using native GP",
                rt.artifacts_dir().display()
            );
        }
    }
    #[cfg(not(feature = "xla"))]
    let _ = rt;
    Box::new(NativeGp::new())
}

// ---------------------------------------------------------------------
// Table I — EDP ratio (OS / WS) across phases and sequence lengths
// ---------------------------------------------------------------------

/// Regenerate Table I on GPT3-7B shapes with an M-class chiplet probe.
pub fn table1(dram_bw_gbs: f64) -> Table {
    let model = ModelSpec::gpt3_7b();
    let mut t = Table::new(
        "Table I - EDP ratio (OS/WS) on GPT3-7B (>1: WS superior, <1: OS superior)",
        &["Lens", "QKV Gen", "QK^T", "FFN1", "FFN2"],
    );
    let chip = |df| Chiplet {
        class: ChipletClass::M,
        dataflow: df,
    };
    for seq in [128u64, 1024, 5120, 10240] {
        let mut row = vec![seq.to_string()];
        for phase in [Phase::QkvGen, Phase::QkT, Phase::Ffn1, Phase::Ffn2] {
            let os = edp_of(edp_probe(
                phase,
                seq,
                model.hidden,
                model.ffn_hidden,
                model.head_dim,
                chip(Dataflow::OutputStationary),
                dram_bw_gbs,
            ));
            let ws = edp_of(edp_probe(
                phase,
                seq,
                model.hidden,
                model.ffn_hidden,
                model.head_dim,
                chip(Dataflow::WeightStationary),
                dram_bw_gbs,
            ));
            row.push(format!("{:.2}x", os / ws));
        }
        t.row(row);
    }
    t
}

// ---------------------------------------------------------------------
// Table V — validation against a Gemini-style reference
// ---------------------------------------------------------------------

/// Validation (paper Table V): the Compass evaluation engine vs an
/// independent steady-state reference model on a Simba-like
/// configuration running GPT3-7B under a layer-pipeline mapping.
///
/// The reference mirrors Gemini's methodology: cost one micro-batch in
/// steady state (weights resident, activations on-chip) and extrapolate
/// by the pipeline depth — computed *without* the timeline simulator.
pub fn table5(eval_blocks: usize) -> Table {
    let model = ModelSpec::gpt3_7b();
    // Simba-like: 6x6 S-class chiplets (~64 TOPS aggregate)
    let hw = HwConfig::homogeneous(6, 6, ChipletClass::S, Dataflow::WeightStationary, 32.0, 16.0);
    let ev = Evaluator::new();
    let mut t = Table::new(
        "Table V - verification vs steady-state reference (Simba-like HW, GPT3-7B)",
        &["", "MC ($)", "Prefill L (cyc)", "Prefill E (pJ)", "Decode L (cyc)", "Decode E (pJ)"],
    );
    let mut ref_row = vec!["Reference".to_string()];
    let mut cps_row = vec!["Compass".to_string()];
    let mut err_row = vec!["Error".to_string()];
    let mc = crate::cost::money::monetary_cost(&hw).total;
    ref_row.push(format!("{mc:.1}"));
    cps_row.push(format!("{mc:.1}"));
    err_row.push("0.00%".to_string());

    for prefill in [true, false] {
        let batch: Vec<crate::workload::Request> = if prefill {
            vec![crate::workload::Request::prefill(128); 4]
        } else {
            vec![crate::workload::Request::decode(512); 128]
        };
        let params = crate::workload::WorkloadParams {
            micro_batch_size: if prefill { 1 } else { 32 },
            tensor_parallel: 8,
            eval_blocks,
        };
        let w = crate::workload::build_workload(&model, &batch, &params);
        let mapping = crate::mapping::presets::pipeline_parallel(
            w.num_micro_batches(),
            w.layers_per_mb,
            hw.num_chiplets(),
        );
        let r = ev.eval_batch(&w, &hw, &mapping);
        let (lref, eref) = steady_state_reference(&w, &hw, &mapping);
        ref_row.push(format!("{lref:.3e}"));
        ref_row.push(format!("{eref:.3e}"));
        cps_row.push(format!("{:.3e}", r.latency_cycles));
        cps_row.push(format!("{:.3e}", r.energy_pj));
        err_row.push(format!("{:.2}%", 100.0 * (r.latency_cycles - lref).abs() / lref));
        err_row.push(format!("{:.2}%", 100.0 * (r.energy_pj - eref).abs() / eref));
    }
    t.row(ref_row);
    t.row(cps_row);
    t.row(err_row);
    t
}

/// Independent steady-state model (Gemini methodology): per-chip busy
/// time of one micro-batch wave + pipeline fill, energies summed
/// analytically from the same per-layer kernel costs.
pub fn steady_state_reference(
    w: &crate::workload::Workload,
    hw: &HwConfig,
    mapping: &crate::mapping::Mapping,
) -> (f64, f64) {
    use crate::arch::constants::*;
    use crate::cost::access::{self, InputSrc};
    let flags = access::analyze(w, mapping);
    let dram_bpc = hw.dram_bw_gbs * 1e9 / CLOCK_HZ;
    let nop_bpc = hw.nop_bw_gbs * 1e9 / CLOCK_HZ;
    let mut chip_busy = vec![0.0f64; hw.num_chiplets()];
    let mut mb0_proc = vec![0.0f64; mapping.cols]; // per-layer T_proc of mb0
    let mut energy = 0.0f64;
    for mb in 0..mapping.rows {
        for l in 0..mapping.cols {
            let t = mb * mapping.cols + l;
            let node = &w.micro_batches[mb].layers[l];
            let chip_id = mapping.chip(mb, l) as usize;
            let chip = hw.chiplet(chip_id);
            let load = flags.is_load_wei[t]
                || node.weight_bytes > (chip.class.glb_bytes() as f64 * 0.9) as u64;
            let c = crate::cost::dataflow::layer_cost(&node.kind, node.vec_ops, chip, load);
            // classify activation traffic identically to the timeline
            let n_preds = node.preds.len().max(1) as f64;
            let per_pred = node.in_bytes as f64 / n_preds;
            let mut dram = c.weight_dram
                + c.spill_dram
                + (node.kv_read_bytes + node.kv_write_bytes) as f64
                + if flags.is_write_out[t] { node.out_bytes as f64 } else { 0.0 };
            let mut nop_hop_bytes = 0.0;
            let mut nop_bytes = 0.0;
            if node.preds.is_empty() {
                dram += node.in_bytes as f64;
            } else {
                for s in flags.srcs(t) {
                    match *s {
                        InputSrc::Local => {}
                        InputSrc::Nop { chip: c0 } => {
                            nop_bytes += per_pred;
                            nop_hop_bytes += per_pred * hw.hops(c0 as usize, chip_id).max(1) as f64;
                        }
                        InputSrc::Dram => dram += per_pred,
                    }
                }
            }
            let t_dram = if dram > 0.0 { dram / dram_bpc + DRAM_LAT_CYCLES } else { 0.0 };
            let t_nop = if nop_bytes > 0.0 { nop_bytes / nop_bpc } else { 0.0 };
            let t_proc = c.cycles.max(t_dram).max(t_nop);
            chip_busy[chip_id] += t_proc;
            if mb == 0 {
                mb0_proc[l] = t_proc;
            }
            let hops = hw.dram_hops(chip_id, hw.nearest_dram(chip_id)) as f64;
            energy += c.onchip_energy_pj()
                + dram * E_DRAM_PJ_BYTE
                + dram * hops * E_NOP_PJ_BYTE_HOP
                + nop_hop_bytes * E_NOP_PJ_BYTE_HOP;
        }
    }
    // steady state: the bottleneck chip processes every wave; the first
    // wave fills the pipeline along mb0's dependency critical path
    // (Gemini's micro-batch steady-state extrapolation)
    let bottleneck = chip_busy.iter().cloned().fold(0.0, f64::max);
    let bn_chip = chip_busy
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap_or(0);
    // DAG critical path of mb0 (parallel branches overlap)
    let mut path = vec![0.0f64; mapping.cols];
    for l in 0..mapping.cols {
        let pred_max = w.micro_batches[0].layers[l]
            .preds
            .iter()
            .map(|&p| path[p])
            .fold(0.0f64, f64::max);
        path[l] = pred_max + mb0_proc[l];
    }
    let critical = path.iter().cloned().fold(0.0, f64::max);
    // fill = mb0 critical path minus mb0's share already counted in the
    // bottleneck chip's busy sum
    let mb0_on_bn: f64 = (0..mapping.cols)
        .filter(|&l| mapping.chip(0, l) as usize == bn_chip)
        .map(|l| mb0_proc[l])
        .sum();
    let latency = bottleneck + (critical - mb0_on_bn).max(0.0);
    (latency * w.block_scale, energy * w.block_scale)
}

// ---------------------------------------------------------------------
// Fig. 7 — Gemini vs MOHaM vs Compass across scenarios
// ---------------------------------------------------------------------

/// One scenario's three-way comparison.
#[derive(Debug, Clone)]
pub struct CompareRow {
    pub scene: Scene,
    /// (latency cyc, energy pJ, MC $, total cost) per method.
    pub gemini: [f64; 4],
    pub moham: [f64; 4],
    pub compass: [f64; 4],
    pub compass_hw: HwConfig,
}

/// Run the Fig. 7 comparison for a set of scenes.
pub fn fig7_compare(
    scenes: &[Scene],
    cfg: &DseConfig,
    rt: Option<&Runtime>,
    seed: u64,
) -> Vec<CompareRow> {
    let mut rows = Vec::new();
    for scene in scenes {
        let (scenario, test_scenario, trace, model) = scene.build(seed);
        let space = scene.space();

        // --- Compass ---
        let mut gp = make_gp(rt);
        let out = dse::compass_dse(&scenario, &model, &space, cfg, gp.as_mut());
        let compass_eval =
            dse::search_mappings(&test_scenario, &model, &out.hw, &cfg.ga, cfg.eval_blocks).eval;

        // --- Gemini (fixed-length search view, homogeneous grid) ---
        let fixed = fixed_length_scenario(&scenario, &trace);
        let sa = gemini::SaConfig::matched_to(&cfg.ga);
        // grid stride keeps Gemini's hardware-evaluation budget comparable
        // to Compass' BO rounds (3 classes x 2 dataflows x ~2x2 bandwidths)
        let (ghw, _) = gemini::gemini_dse(&fixed, &model, &space, &sa, cfg.eval_blocks, 3);
        let gmaps = gemini::gemini_mappings(
            &fixed_length_scenario(&test_scenario, &trace),
            &model,
            &ghw,
            &sa,
            cfg.eval_blocks,
        );
        let gem_eval =
            gemini::reevaluate(&test_scenario, &model, &ghw, &gmaps.mappings, cfg.eval_blocks);

        // --- MOHaM (joint GA, micro-batch = 1) ---
        let mut mo_cfg = cfg.ga;
        // budget parity with BO rounds x GA: scale population
        mo_cfg.population = (cfg.ga.population / 2).max(6);
        let (mhw, _) = moham::moham_dse(&scenario, &model, &space, &mo_cfg, cfg.eval_blocks);
        let mo_test = {
            let mut hw1 = mhw.clone();
            hw1.micro_batch_prefill = 1;
            hw1.micro_batch_decode = 1;
            let ms = moham::moham_dse(
                &test_scenario,
                &model,
                &space_fixed_to(&space, &mhw),
                &GaConfig {
                    population: 6,
                    generations: 3,
                    ..mo_cfg
                },
                cfg.eval_blocks,
            );
            ms.1.eval
        };

        let pack =
            |e: &crate::cost::EvalResult| [e.latency_cycles, e.energy_pj, e.mc_usd, e.total_cost()];
        rows.push(CompareRow {
            scene: scene.clone(),
            gemini: pack(&gem_eval),
            moham: pack(&mo_test),
            compass: pack(&compass_eval),
            compass_hw: out.hw,
        });
    }
    rows
}

/// Restrict a space so MOHaM's test-time re-derivation keeps the found
/// hardware fixed (mapping-only adaptation).
fn space_fixed_to(space: &HwSpace, hw: &HwConfig) -> HwSpace {
    let mut s = space.clone();
    s.classes = vec![hw.class];
    s.nop_bw_gbs = vec![hw.nop_bw_gbs];
    s.dram_bw_gbs = vec![hw.dram_bw_gbs];
    s.tensor_parallel = vec![hw.tensor_parallel];
    s
}

/// Format Fig. 7 rows as the paper's normalized table + average savings.
pub fn fig7_table(rows: &[CompareRow]) -> Table {
    let mut t = Table::new(
        "Fig 7 - normalized latency / energy / MC / total (max within scenario = 1)",
        &["Scenario", "Method", "Latency", "Energy", "MC", "Total"],
    );
    for r in rows {
        for (mi, (name, _)) in [("Gemini", &r.gemini), ("MOHaM", &r.moham), ("Compass", &r.compass)]
            .iter()
            .enumerate()
        {
            let mut cells = vec![
                if mi == 0 { r.scene.label() } else { String::new() },
                name.to_string(),
            ];
            for k in 0..4 {
                let series = [r.gemini[k], r.moham[k], r.compass[k]];
                let norm = normalize_max(&series);
                cells.push(format!("{:.3}", norm[mi]));
            }
            t.row(cells);
        }
    }
    t
}

/// Average relative savings of Compass vs each baseline (paper headline:
/// -63.92% latency, -40.32% energy vs MOHaM; +3.11% MC).
pub fn fig7_savings(rows: &[CompareRow]) -> Table {
    let mut t = Table::new(
        "Fig 7 - average change of Compass vs baselines (negative = reduction)",
        &["Baseline", "dLatency", "dEnergy", "dMC", "dTotal"],
    );
    for (name, get) in [
        ("Gemini", (|r: &CompareRow| r.gemini) as fn(&CompareRow) -> [f64; 4]),
        ("MOHaM", |r: &CompareRow| r.moham),
    ] {
        let mut deltas = [0.0f64; 4];
        for r in rows {
            let base = get(r);
            for k in 0..4 {
                deltas[k] += (r.compass[k] - base[k]) / base[k];
            }
        }
        let n = rows.len().max(1) as f64;
        t.row(vec![
            name.to_string(),
            format!("{:+.2}%", 100.0 * deltas[0] / n),
            format!("{:+.2}%", 100.0 * deltas[1] / n),
            format!("{:+.2}%", 100.0 * deltas[2] / n),
            format!("{:+.2}%", 100.0 * deltas[3] / n),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Table VI — optimal hardware configurations found by Compass
// ---------------------------------------------------------------------

pub fn table6(rows: &[CompareRow]) -> Table {
    let mut t = Table::new(
        "Table VI - optimal hardware configurations searched by Compass",
        &[
            "Scenario", "DRAM_BW", "NoP_BW", "Micro_batch", "Tensor_Parall", "Chiplet Spec",
            "WS Number", "OS Number",
        ],
    );
    for r in rows {
        let hw = &r.compass_hw;
        let (ws, os) = crate::bo::sa::dataflow_mix(hw);
        let mb = if r.scene.prefill {
            hw.micro_batch_prefill
        } else {
            hw.micro_batch_decode
        };
        t.row(vec![
            r.scene.label(),
            format!("{}", hw.dram_bw_gbs),
            format!("{}", hw.nop_bw_gbs),
            mb.to_string(),
            hw.tensor_parallel.to_string(),
            hw.class.short().to_string(),
            ws.to_string(),
            os.to_string(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Fig. 8 — execution latency timeline
// ---------------------------------------------------------------------

/// ASCII spatio-temporal diagram of the found mapping for one scene
/// (paper Fig. 8: ShareGPT-64TOPS, one LLM block).
pub fn fig8_timeline(scene: &Scene, cfg: &DseConfig, rt: Option<&Runtime>, seed: u64) -> String {
    let (scenario, _, _, model) = scene.build(seed);
    let space = scene.space();
    let mut gp = make_gp(rt);
    let mut one_block = *cfg;
    one_block.eval_blocks = 1; // Fig 8 shows a single LLM block
    let out = dse::compass_dse(&scenario, &model, &space, &one_block, gp.as_mut());
    let ev = Evaluator {
        opts: SimOptions {
            record_timeline: true,
            ..Default::default()
        },
    };
    let group = &scenario.groups[0];
    let params = crate::cost::group_params(&out.hw, group.has_prefill, 1);
    let w = crate::workload::build_workload(&model, &group.batch, &params);
    let r = ev.eval_batch(&w, &out.hw, &out.mappings[0]);
    let mut s = format!(
        "Fig 8 - execution timeline [{}], hw: {}\n",
        scene.label(),
        out.hw.describe()
    );
    s.push_str(&ascii_timeline(
        r.timeline.as_deref().unwrap_or(&[]),
        out.hw.num_chiplets(),
        96,
    ));
    s
}

// ---------------------------------------------------------------------
// Fig. 10 + Table VII — serving strategies; homo vs hetero
// ---------------------------------------------------------------------

#[derive(Debug, Clone)]
pub struct ServingResult {
    pub strategy: ServingStrategy,
    pub hw: HwConfig,
    pub latency: f64,
    pub energy: f64,
    pub mc: f64,
    /// (first-batch latency, other-batch latency, first E, other E)
    pub first_other: [f64; 4],
}

/// DSE under the three serving strategies (paper §VI-F:
/// GovReport-512TOPS, 1 prefill + `decode_groups` x 128 decodes).
pub fn fig10_serving(
    cfg: &DseConfig,
    rt: Option<&Runtime>,
    seed: u64,
    decode_groups: usize,
) -> Vec<ServingResult> {
    let trace = Trace::new(&TraceSpec::govreport(), 512, seed);
    let model = model_for_tops(512.0);
    let space = HwSpace::paper(512.0);
    let prefill_len = trace.mean_in().round() as u64;
    let chunk = 2048u64;
    let mut out = Vec::new();
    for strat in ServingStrategy::ALL {
        let scen = Scenario::serving(strat, &trace, prefill_len, 128, decode_groups, chunk);
        let mut gp = make_gp(rt);
        let r = dse::compass_dse(&scen, &model, &space, cfg, gp.as_mut());
        let per = &r.eval.per_group;
        let (first_l, first_e) = per.first().copied().unwrap_or((0.0, 0.0));
        let others: Vec<(f64, f64)> = per.iter().skip(1).copied().collect();
        let other_l = others.iter().map(|x| x.0).sum::<f64>() / others.len().max(1) as f64;
        let other_e = others.iter().map(|x| x.1).sum::<f64>() / others.len().max(1) as f64;
        out.push(ServingResult {
            strategy: strat,
            hw: r.hw,
            latency: r.eval.latency_cycles,
            energy: r.eval.energy_pj,
            mc: r.eval.mc_usd,
            first_other: [first_l, other_l, first_e, other_e],
        });
    }
    out
}

pub fn table7(results: &[ServingResult]) -> Table {
    let mut t = Table::new(
        "Table VII - optimal hardware under three serving strategies",
        &["Strategy", "DR BW", "NoP BW", "Spec", "WS", "OS"],
    );
    for r in results {
        let (ws, os) = crate::bo::sa::dataflow_mix(&r.hw);
        t.row(vec![
            r.strategy.name().to_string(),
            format!("{}", r.hw.dram_bw_gbs),
            format!("{}", r.hw.nop_bw_gbs),
            r.hw.class.short().to_string(),
            ws.to_string(),
            os.to_string(),
        ]);
    }
    t
}

pub fn fig10a_table(results: &[ServingResult]) -> Table {
    let mut t = Table::new(
        "Fig 10(a) - serving strategies: totals and first/other batch breakdown",
        &[
            "Strategy", "Latency (cyc)", "Energy (pJ)", "MC ($)", "L first", "L other",
            "E first", "E other",
        ],
    );
    for r in results {
        t.row(vec![
            r.strategy.name().to_string(),
            format!("{:.3e}", r.latency),
            format!("{:.3e}", r.energy),
            format!("{:.1}", r.mc),
            format!("{:.3e}", r.first_other[0]),
            format!("{:.3e}", r.first_other[1]),
            format!("{:.3e}", r.first_other[2]),
            format!("{:.3e}", r.first_other[3]),
        ]);
    }
    t
}

/// Fig. 10(b): replace the chunked-prefill winner's layout with all-OS /
/// all-WS and compare EDP against the heterogeneous original.
pub fn fig10b_homo_hetero(
    cfg: &DseConfig,
    hetero: &HwConfig,
    seed: u64,
    decode_groups: usize,
) -> Table {
    let trace = Trace::new(&TraceSpec::govreport(), 512, seed);
    let model = model_for_tops(512.0);
    let prefill_len = trace.mean_in().round() as u64;
    let scen = Scenario::serving(
        ServingStrategy::ChunkedPrefill,
        &trace,
        prefill_len,
        128,
        decode_groups,
        2048,
    );
    let mut t = Table::new(
        "Fig 10(b) - homogeneous vs heterogeneous (chunked-prefill winner)",
        &["Layout", "WS", "OS", "Latency (cyc)", "Energy (pJ)", "EDP (s*J)", "vs hetero"],
    );
    let eval_of = |hw: &HwConfig| {
        dse::search_mappings(&scen, &model, hw, &cfg.ga, cfg.eval_blocks).eval
    };
    let hetero_eval = eval_of(hetero);
    let hetero_edp = hetero_eval.edp();
    for (name, layout) in [
        ("hetero", None),
        ("all-WS", Some(Dataflow::WeightStationary)),
        ("all-OS", Some(Dataflow::OutputStationary)),
    ] {
        let mut hw = hetero.clone();
        if let Some(df) = layout {
            hw.layout = vec![df; hw.num_chiplets()];
        }
        let e = if layout.is_none() {
            hetero_eval.clone()
        } else {
            eval_of(&hw)
        };
        let (ws, os) = crate::bo::sa::dataflow_mix(&hw);
        t.row(vec![
            name.to_string(),
            ws.to_string(),
            os.to_string(),
            format!("{:.3e}", e.latency_cycles),
            format!("{:.3e}", e.energy_pj),
            format!("{:.3e}", e.edp()),
            format!("{:+.1}%", 100.0 * (e.edp() - hetero_edp) / hetero_edp),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Serving-simulator study — arrival rate x strategy (EXPERIMENTS.md
// "Serving simulator")
// ---------------------------------------------------------------------

/// One cell of the serving-simulator sweep.
#[derive(Debug, Clone)]
pub struct SimStudyRow {
    pub strategy: ServingStrategy,
    pub rate_rps: f64,
    pub metrics: sim::ServingMetrics,
}

/// A representative fixed hardware configuration for a compute target:
/// the largest feasible chiplet class (fewest chiplets), a near-square
/// grid, median Table-IV bandwidths. Used when the study sweeps serving
/// dynamics rather than searching hardware. (Now a thin alias of
/// [`HwSpace::representative`], which the fleet DSE also uses to size
/// heterogeneous non-searched pools.)
pub fn sim_default_hw(tops: f64) -> HwConfig {
    HwSpace::representative(tops)
}

/// Sweep arrival rate x serving strategy on one [`SimScene`] with fixed
/// hardware. SLO targets are calibrated once from the unloaded probe
/// (TTFT <= 3x solo prefill, TPOT <= 4x an unloaded decode iteration)
/// and shared by every cell, so attainment is comparable across
/// strategies and rates. Deterministic for a fixed `seed`.
pub fn sim_serving_study(
    scene: &SimScene,
    hw: &HwConfig,
    base: &sim::SimConfig,
    seed: u64,
) -> Vec<SimStudyRow> {
    let model = scene.model();
    let spec = scene.spec();
    let probe = sim::probe(&model, hw, base, &spec);
    let mut cfg = *base;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = if scene.rates_rps.is_empty() {
        probe.sweep_rates()
    } else {
        scene.rates_rps.clone()
    };
    // Streams are built serially (seeded, rate-indexed), then the
    // rate x strategy grid runs cell-parallel with rows assembled in
    // the serial loop's (rate-major) order.
    let streams: Vec<sim::RequestStream> =
        rates.iter().map(|&r| scene.stream(r, seed)).collect();
    let cells: Vec<(usize, ServingStrategy)> = rates
        .iter()
        .enumerate()
        .flat_map(|(ri, _)| ServingStrategy::ALL.into_iter().map(move |s| (ri, s)))
        .collect();
    par_map(&cells, sim::profile::outer_threads(), &|_, &(ri, strategy)| {
        let metrics =
            sim::simulate_serving(&streams[ri], &model, hw, &cfg.with_strategy(strategy));
        SimStudyRow {
            strategy,
            rate_rps: rates[ri],
            metrics,
        }
    })
}

/// Format the sweep as the study table (TTFT/TPOT tails, SLO
/// attainment, goodput, utilization, EDP-under-load).
pub fn sim_study_table(scene: &SimScene, rows: &[SimStudyRow]) -> Table {
    let title = format!(
        "Serving simulator [{}] - arrival rate x strategy (continuous batching)",
        scene.label()
    );
    let mut t = Table::new(
        &title,
        &[
            "Rate (r/s)",
            "Strategy",
            "Tok/s",
            "TTFT p50 (s)",
            "TTFT p99 (s)",
            "TPOT p99 (s)",
            "SLO %",
            "Goodput (tok/s)",
            "Util %",
            "EDP load (sJ)",
            "KV frag %",
            "Share %",
            "Preempt",
            "Queue max",
        ],
    );
    for r in rows {
        let m = &r.metrics;
        t.row(vec![
            format!("{:.3}", r.rate_rps),
            r.strategy.name().to_string(),
            format!("{:.1}", m.throughput_tps),
            format!("{:.4}", m.ttft.p50),
            format!("{:.4}", m.ttft.p99),
            format!("{:.5}", m.tpot.p99),
            format!("{:.1}", 100.0 * m.slo_attainment),
            format!("{:.1}", m.slo_goodput_tps),
            format!("{:.1}", 100.0 * m.utilization),
            format!("{:.3e}", m.edp_under_load),
            format!("{:.1}", 100.0 * m.kv_fragmentation),
            format!("{:.1}", 100.0 * m.kv_sharing_hit_rate),
            m.n_preemptions.to_string(),
            m.max_queue_depth.to_string(),
        ]);
    }
    t
}

/// ASCII occupancy plot for one strategy at the highest swept rate.
pub fn sim_study_occupancy(
    rows: &[SimStudyRow],
    strategy: ServingStrategy,
    max_batch: usize,
) -> String {
    let row = rows
        .iter()
        .filter(|r| r.strategy == strategy)
        .max_by(|a, b| a.rate_rps.total_cmp(&b.rate_rps));
    match row {
        Some(r) => format!(
            "occupancy [{} @ {:.3} req/s]\n{}",
            strategy.name(),
            r.rate_rps,
            ascii_occupancy(&r.metrics.iters, max_batch, 96)
        ),
        None => String::new(),
    }
}

// ---------------------------------------------------------------------
// KV paging & quantization study — cache layout x arrival rate
// (EXPERIMENTS.md "KV paging & quantization")
// ---------------------------------------------------------------------

/// One cell of the KV-cache layout sweep.
#[derive(Debug, Clone)]
pub struct KvStudyRow {
    pub kv: sim::KvSpec,
    pub rate_rps: f64,
    /// Token capacity this layout gets from the same DRAM budget.
    pub capacity_tokens: u64,
    pub metrics: sim::ServingMetrics,
}

/// The default candidate set: the fp16 token-granular baseline (the
/// pre-paging semantics), quantized token-granular caches, paged fp16
/// and paged-int4, and — when the trace carries a shared system prompt —
/// a paged + prefix-sharing + cost-based-eviction layout.
pub fn default_kv_specs(block_tokens: u64, prefix_tokens: u64) -> Vec<sim::KvSpec> {
    use crate::sim::{EvictionPolicy, KvDtype, KvSpec};
    let bt = block_tokens.max(2);
    let mut specs = vec![
        KvSpec::token_granular(),
        KvSpec::token_granular().with_dtype(KvDtype::Fp8),
        KvSpec::token_granular().with_dtype(KvDtype::Int4),
        KvSpec::paged(bt),
        KvSpec::paged(bt).with_dtype(KvDtype::Int4),
    ];
    if prefix_tokens > 0 {
        specs.push(
            KvSpec::paged(bt)
                .with_prefix(prefix_tokens)
                .with_eviction(EvictionPolicy::CostBased),
        );
        specs.push(
            KvSpec::paged(bt)
                .with_dtype(KvDtype::Int4)
                .with_prefix(prefix_tokens)
                .with_eviction(EvictionPolicy::CostBased),
        );
    }
    specs
}

/// Sweep KV-cache layouts x arrival rates on one [`SimScene`] with
/// fixed hardware. Every request carries a `prefix_tokens`-token shared
/// system prompt (inflating all prompts identically, so sharing-off
/// layouts pay for it and sharing-on layouts deduplicate it). SLO
/// targets and rates are calibrated once from the fp16 token-granular
/// baseline and shared by every cell; rates default to {0.8, 1.3} x
/// the baseline capacity so the overload point is always swept.
/// Deterministic for a fixed `seed`.
pub fn kv_paging_study(
    scene: &SimScene,
    hw: &HwConfig,
    base: &sim::SimConfig,
    specs: &[sim::KvSpec],
    prefix_tokens: u64,
    seed: u64,
) -> Vec<KvStudyRow> {
    kv_paging_study_with_model(scene, &scene.model(), hw, base, specs, prefix_tokens, seed)
}

/// [`kv_paging_study`] with an explicit model override (the CI tiny
/// smoke swaps in `ModelSpec::tiny`; everything else about the
/// protocol — calibration, rates, streams — is shared, so the smoke
/// and the acceptance run can never drift apart).
pub fn kv_paging_study_with_model(
    scene: &SimScene,
    model: &ModelSpec,
    hw: &HwConfig,
    base: &sim::SimConfig,
    specs: &[sim::KvSpec],
    prefix_tokens: u64,
    seed: u64,
) -> Vec<KvStudyRow> {
    let trace_spec = scene.spec().with_prefix(prefix_tokens);
    let mut base_cfg = *base;
    base_cfg.kv = sim::KvSpec::token_granular();
    let probe = sim::probe(model, hw, &base_cfg, &trace_spec);
    let mut cfg = *base;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = if scene.rates_rps.is_empty() {
        let mu = probe.capacity_rps();
        vec![0.8 * mu, 1.3 * mu]
    } else {
        scene.rates_rps.clone()
    };
    let streams: Vec<sim::RequestStream> = rates
        .iter()
        .map(|&r| scene_stream(&trace_spec, scene, r, seed))
        .collect();
    let cells: Vec<(usize, sim::KvSpec)> = rates
        .iter()
        .enumerate()
        .flat_map(|(ri, _)| specs.iter().map(move |&kv| (ri, kv)))
        .collect();
    par_map(&cells, sim::profile::outer_threads(), &|_, &(ri, kv)| {
        let c = cfg.with_kv(kv);
        let metrics = sim::simulate_serving(&streams[ri], model, hw, &c);
        KvStudyRow {
            kv,
            rate_rps: rates[ri],
            // the block-floored capacity the run actually used, so
            // the table never disagrees with the metrics
            capacity_tokens: metrics.kv_capacity_tokens,
            metrics,
        }
    })
}

/// Build the study stream from an already-prefixed trace spec.
fn scene_stream(
    trace_spec: &TraceSpec,
    scene: &SimScene,
    rate_rps: f64,
    seed: u64,
) -> sim::RequestStream {
    sim::RequestStream::poisson(trace_spec, rate_rps, scene.n_requests, seed)
}

/// Format the KV sweep as the study table.
pub fn kv_study_table(scene: &SimScene, rows: &[KvStudyRow]) -> Table {
    let title = format!(
        "KV paging & quantization [{}] - cache layout x arrival rate (fixed hw)",
        scene.label()
    );
    let mut t = Table::new(
        &title,
        &[
            "Rate (r/s)",
            "KV layout",
            "Cap (tok)",
            "Tok/s",
            "Goodput (tok/s)",
            "TTFT p99 (s)",
            "TPOT p99 (s)",
            "SLO %",
            "Frag %",
            "Share %",
            "EffConc",
            "Preempt",
            "Rej",
        ],
    );
    for r in rows {
        let m = &r.metrics;
        t.row(vec![
            format!("{:.3}", r.rate_rps),
            r.kv.describe(),
            r.capacity_tokens.to_string(),
            format!("{:.1}", m.throughput_tps),
            format!("{:.1}", m.slo_goodput_tps),
            format!("{:.4}", m.ttft.p99),
            format!("{:.5}", m.tpot.p99),
            format!("{:.1}", 100.0 * m.slo_attainment),
            format!("{:.1}", 100.0 * m.kv_fragmentation),
            format!("{:.1}", 100.0 * m.kv_sharing_hit_rate),
            format!("{:.1}", m.effective_concurrency),
            m.n_preemptions.to_string(),
            m.n_rejected.to_string(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Fleet serving study — arrival rate x router policy x fleet shape
// (EXPERIMENTS.md "Fleet serving")
// ---------------------------------------------------------------------

/// One cell of the fleet-serving sweep.
#[derive(Debug, Clone)]
pub struct FleetStudyRow {
    pub fleet: sim::FleetConfig,
    pub rate_rps: f64,
    pub metrics: sim::FleetMetrics,
}

/// The default fleet shapes for an N-replica study: round-robin and
/// join-shortest-queue over N identical replicas, plus a disaggregated
/// split of ceil(N/4) prefill + rest decode replicas with a handoff
/// link costed per migrated KV token. N is clamped to >= 2 (a
/// one-replica "fleet comparison" has nothing to compare) — keep the
/// caller's scene in lockstep, as `repro fleet-study` does.
pub fn default_fleet_shapes(n_replicas: usize, handoff_s_per_token: f64) -> Vec<sim::FleetConfig> {
    let n = n_replicas.max(2);
    let p = n.div_ceil(4);
    vec![
        sim::FleetConfig::homogeneous(n, sim::RouterPolicy::RoundRobin),
        sim::FleetConfig::homogeneous(n, sim::RouterPolicy::JoinShortestQueue),
        sim::FleetConfig::disaggregated(p, n - p, handoff_s_per_token),
    ]
}

/// Sweep arrival rate x fleet shape on one [`FleetScene`] with fixed
/// per-replica hardware. SLO targets are calibrated once from the
/// unloaded single-replica probe (as in [`sim_serving_study`]) and
/// shared by every cell; rates default to {0.4, 0.8, 1.3} x the fleet
/// capacity (n_replicas x per-replica capacity). Deterministic for a
/// fixed `seed`.
pub fn fleet_study(
    scene: &FleetScene,
    hw: &HwConfig,
    base: &sim::SimConfig,
    fleets: &[sim::FleetConfig],
    seed: u64,
) -> Vec<FleetStudyRow> {
    let model = scene.model();
    let spec = scene.spec();
    let probe = sim::probe(&model, hw, base, &spec);
    let mut cfg = *base;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = if scene.rates_rps.is_empty() {
        let fleet_mu = scene.n_replicas as f64 * probe.capacity_rps();
        vec![0.4 * fleet_mu, 0.8 * fleet_mu, 1.3 * fleet_mu]
    } else {
        scene.rates_rps.clone()
    };
    let streams: Vec<sim::RequestStream> =
        rates.iter().map(|&r| scene.stream(r, seed)).collect();
    let cells: Vec<(usize, usize)> = rates
        .iter()
        .enumerate()
        .flat_map(|(ri, _)| (0..fleets.len()).map(move |fi| (ri, fi)))
        .collect();
    par_map(&cells, sim::profile::outer_threads(), &|_, &(ri, fi)| {
        let fleet = &fleets[fi];
        let metrics = sim::simulate_fleet(&streams[ri], &model, hw, &cfg, fleet);
        FleetStudyRow {
            fleet: fleet.clone(),
            rate_rps: rates[ri],
            metrics,
        }
    })
}

/// Format the fleet sweep as the study table.
pub fn fleet_study_table(scene: &FleetScene, rows: &[FleetStudyRow]) -> Table {
    let title = format!(
        "Fleet serving [{}] - arrival rate x router policy ({} replicas, {} TOPS total)",
        scene.label(),
        scene.n_replicas,
        scene.total_tops as u64,
    );
    let mut t = Table::new(
        &title,
        &[
            "Rate (r/s)",
            "Fleet",
            "Tok/s",
            "Goodput (tok/s)",
            "TTFT p99 (s)",
            "TPOT p99 (s)",
            "SLO %",
            "Imbalance",
            "KV-handoff (tok)",
            "KV frag %",
            "Share %",
            "Energy (pJ)",
            "Rej",
        ],
    );
    for r in rows {
        let m = &r.metrics;
        t.row(vec![
            format!("{:.3}", r.rate_rps),
            r.fleet.describe(),
            format!("{:.1}", m.throughput_tps),
            format!("{:.1}", m.slo_goodput_tps),
            format!("{:.4}", m.ttft.p99),
            format!("{:.5}", m.tpot.p99),
            format!("{:.1}", 100.0 * m.slo_attainment),
            format!("{:.3}", m.load_imbalance),
            m.kv_transfer_tokens.to_string(),
            format!("{:.1}", 100.0 * m.kv_fragmentation),
            format!("{:.1}", 100.0 * m.kv_sharing_hit_rate),
            format!("{:.3e}", m.energy_pj),
            m.n_rejected.to_string(),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Front-end control-plane study — admission x rebalancing x fleet
// sizing (EXPERIMENTS.md "Front-end control plane")
// ---------------------------------------------------------------------

/// One cell of the front-end study.
#[derive(Debug, Clone)]
pub struct FrontendStudyRow {
    /// Stable cell key: one of `jsq`, `jsq+shed`, `jsq+rebal`,
    /// `jsq+shed+rebal`, `even-disagg`, `hetero-disagg`.
    pub key: &'static str,
    pub fleet: sim::FleetConfig,
    pub frontend_label: String,
    pub rate_rps: f64,
    pub metrics: sim::FleetMetrics,
}

/// Knobs of the front-end study sweep.
#[derive(Debug, Clone, Copy)]
pub struct FrontendKnobs {
    /// SLO-shed margin (TTFT multiples; shed when the estimate exceeds
    /// `margin * slo.ttft_s`).
    pub shed_margin: f64,
    /// Rebalancer trigger threshold on busy-time imbalance.
    pub rebalance_threshold: f64,
    /// KV handoff cost per migrated token (s) — disaggregation and
    /// rebalancing pay the same link.
    pub handoff_s_per_token: f64,
    /// Prefill-pool share of the total TOPS for the hetero fleet.
    pub prefill_share: f64,
}

impl Default for FrontendKnobs {
    fn default() -> Self {
        FrontendKnobs {
            shed_margin: 1.0,
            rebalance_threshold: 0.5,
            handoff_s_per_token: 1e-8,
            prefill_share: 0.15,
        }
    }
}

/// Rescale a package to a TOPS target keeping its chiplet class,
/// dataflow and bandwidths: only the chiplet count (and grid) change,
/// so heterogeneous pools built from it stay silicon-comparable to the
/// original instead of inheriting `sim_default_hw`'s fixed bandwidths.
fn scaled_package(hw: &HwConfig, target_tops: f64) -> HwConfig {
    let n = hw.class.chiplets_for(target_tops).max(1);
    let (h, w) = HwSpace::grid_dims(n);
    HwConfig::homogeneous(
        h,
        w,
        hw.class,
        hw.chiplet(0).dataflow,
        hw.nop_bw_gbs,
        hw.dram_bw_gbs,
    )
}

/// The study's cell set for one [`FleetScene`]: the PR 3 baseline
/// (JSQ over N even replicas, arrival-time rejection), SLO-aware
/// shedding, decode-pool rebalancing, their combination, and even vs
/// heterogeneous disaggregated sizing. Every cell spends the same
/// total silicon: the fleet budget is `n * hw.total_tops()` of the
/// *caller's* per-replica package (not the scene's nominal TOPS, which
/// an hw override may not match), and the hetero cell re-partitions
/// exactly that budget between its pools.
#[allow(clippy::type_complexity)]
fn frontend_cells(
    scene: &FleetScene,
    hw: &HwConfig,
    probe: &sim::SimProbe,
    knobs: &FrontendKnobs,
) -> Vec<(&'static str, sim::FleetConfig, Vec<HwConfig>, sim::Frontend)> {
    let n = scene.n_replicas.max(2);
    let p = n.div_ceil(4);
    let jsq = sim::FleetConfig::homogeneous(n, sim::RouterPolicy::JoinShortestQueue);
    let even = sim::FleetConfig::disaggregated(p, n - p, knobs.handoff_s_per_token);
    let hetero = sim::FleetConfig::disaggregated_hetero(
        p,
        n - p,
        knobs.handoff_s_per_token,
        knobs.prefill_share,
    );
    let hws_even = vec![hw.clone(); n];
    // budget-matched hetero pools: repartition the even fleet's actual
    // silicon (n x the supplied package, same chiplet class, dataflow
    // and bandwidths — only the chiplet count changes), not the
    // scene's nominal TOPS or the representative package's bandwidths
    let fleet_tops = n as f64 * hw.total_tops();
    let pre = scaled_package(hw, (knobs.prefill_share * fleet_tops / p as f64).max(1.0));
    let dec = scaled_package(
        hw,
        ((1.0 - knobs.prefill_share) * fleet_tops / (n - p) as f64).max(1.0),
    );
    let mut hws_hetero = vec![pre; p];
    hws_hetero.extend(vec![dec; n - p]);
    let rebal = sim::RebalanceSpec::new(knobs.rebalance_threshold, knobs.handoff_s_per_token);
    let base = sim::Frontend::baseline();
    let shed = sim::Frontend::with_shedding(*probe, knobs.shed_margin);
    vec![
        ("jsq", jsq.clone(), hws_even.clone(), base.clone()),
        ("jsq+shed", jsq.clone(), hws_even.clone(), shed.clone()),
        (
            "jsq+rebal",
            jsq.clone(),
            hws_even.clone(),
            base.clone().with_rebalance(rebal),
        ),
        (
            "jsq+shed+rebal",
            jsq,
            hws_even.clone(),
            shed.with_rebalance(rebal),
        ),
        ("even-disagg", even, hws_even, base.clone()),
        ("hetero-disagg", hetero, hws_hetero, base),
    ]
}

/// Run the front-end cell set on one explicit stream (used directly
/// for timestamped trace replays; [`frontend_study`] drives it over
/// synthetic rate sweeps).
pub fn frontend_study_stream(
    scene: &FleetScene,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &sim::SimConfig,
    knobs: &FrontendKnobs,
    probe: &sim::SimProbe,
    stream: &sim::RequestStream,
) -> Vec<FrontendStudyRow> {
    let cells = frontend_cells(scene, hw, probe, knobs);
    par_map(
        &cells,
        sim::profile::outer_threads(),
        &|_, (key, fleet, hws, fe)| {
            let metrics = sim::simulate_fleet_frontend(stream, model, hws, cfg, fleet, fe);
            FrontendStudyRow {
                key: *key,
                fleet: fleet.clone(),
                frontend_label: fe.describe(),
                rate_rps: stream.rate_rps,
                metrics,
            }
        },
    )
}

/// Sweep the front-end control plane on one [`FleetScene`] with fixed
/// per-replica hardware. SLO targets are calibrated once from the
/// unloaded single-replica probe and shared by every cell; rates
/// default to {0.8, 1.3} x fleet capacity — the overload point is
/// where admission and rebalancing act. Deterministic for a fixed
/// `seed`.
pub fn frontend_study(
    scene: &FleetScene,
    base: &sim::SimConfig,
    knobs: &FrontendKnobs,
    seed: u64,
) -> Vec<FrontendStudyRow> {
    frontend_study_with_model(
        scene,
        &scene.model(),
        &sim_default_hw(scene.tops_per_replica()),
        base,
        knobs,
        seed,
    )
}

/// [`frontend_study`] with explicit model/hardware overrides (the CI
/// tiny smoke swaps in `ModelSpec::tiny`; the protocol — calibration,
/// rates, streams, cells — is shared so the smoke and the acceptance
/// run can never drift apart).
pub fn frontend_study_with_model(
    scene: &FleetScene,
    model: &ModelSpec,
    hw: &HwConfig,
    base: &sim::SimConfig,
    knobs: &FrontendKnobs,
    seed: u64,
) -> Vec<FrontendStudyRow> {
    let spec = scene.spec();
    let probe = sim::probe(model, hw, base, &spec);
    let mut cfg = *base;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = if scene.rates_rps.is_empty() {
        let mu = scene.n_replicas.max(2) as f64 * probe.capacity_rps();
        vec![0.8 * mu, 1.3 * mu]
    } else {
        scene.rates_rps.clone()
    };
    let mut rows = Vec::new();
    for &rate in &rates {
        let stream =
            sim::RequestStream::poisson(&spec, rate, scene.n_requests, seed);
        rows.extend(frontend_study_stream(
            scene, model, hw, &cfg, knobs, &probe, &stream,
        ));
    }
    rows
}

/// Format the front-end sweep as the study table.
pub fn frontend_study_table(scene: &FleetScene, rows: &[FrontendStudyRow]) -> Table {
    let title = format!(
        "Front-end control plane [{}] - admission x rebalancing x sizing ({} TOPS total)",
        scene.label(),
        scene.total_tops as u64,
    );
    let mut t = Table::new(
        &title,
        &[
            "Rate (r/s)",
            "Fleet",
            "Frontend",
            "Tok/s",
            "Goodput (tok/s)",
            "TTFT p99 (s)",
            "TPOT p99 (s)",
            "SLO %",
            "Shed %",
            "Rebal",
            "Imbalance",
            "Rej",
        ],
    );
    for r in rows {
        let m = &r.metrics;
        t.row(vec![
            format!("{:.3}", r.rate_rps),
            r.fleet.describe(),
            r.frontend_label.clone(),
            format!("{:.1}", m.throughput_tps),
            format!("{:.1}", m.slo_goodput_tps),
            format!("{:.4}", m.ttft.p99),
            format!("{:.5}", m.tpot.p99),
            format!("{:.1}", 100.0 * m.slo_attainment),
            format!("{:.1}", 100.0 * m.shed_rate),
            m.n_rebalanced.to_string(),
            format!("{:.3}", m.load_imbalance),
            m.n_rejected.to_string(),
        ]);
    }
    t
}

/// Headline comparison at the highest swept rate (overload): SLO-aware
/// shedding vs the arrival-time-rejection baseline, and heterogeneous
/// vs even disaggregated sizing, on SLO goodput.
pub fn frontend_study_headline(rows: &[FrontendStudyRow]) -> String {
    let hi = rows
        .iter()
        .map(|r| r.rate_rps)
        .fold(f64::NEG_INFINITY, f64::max);
    let at = |key: &str| {
        rows.iter()
            .find(|r| r.rate_rps == hi && r.key == key)
            .map(|r| &r.metrics)
    };
    let mut s = format!("front-end headline @ {hi:.3} req/s (overload):\n");
    if let (Some(base), Some(shed)) = (at("jsq"), at("jsq+shed")) {
        s.push_str(&format!(
            "  slo-shed goodput {:.1} tok/s vs arrival-reject {:.1} tok/s ({:+.1}%), \
             shed rate {:.1}%\n",
            shed.slo_goodput_tps,
            base.slo_goodput_tps,
            100.0 * (shed.slo_goodput_tps - base.slo_goodput_tps)
                / base.slo_goodput_tps.max(1e-9),
            100.0 * shed.shed_rate,
        ));
    }
    if let (Some(rb), Some(base)) = (at("jsq+rebal"), at("jsq")) {
        s.push_str(&format!(
            "  rebalance: {} migrations, imbalance {:.3} vs {:.3}\n",
            rb.n_rebalanced, rb.load_imbalance, base.load_imbalance,
        ));
    }
    if let (Some(het), Some(even)) = (at("hetero-disagg"), at("even-disagg")) {
        s.push_str(&format!(
            "  hetero-disagg goodput {:.1} tok/s vs even-disagg {:.1} tok/s ({:+.1}%)\n",
            het.slo_goodput_tps,
            even.slo_goodput_tps,
            100.0 * (het.slo_goodput_tps - even.slo_goodput_tps)
                / even.slo_goodput_tps.max(1e-9),
        ));
    }
    s
}

// ---------------------------------------------------------------------
// Fault injection & resilience study — crashes x failover x retry x
// drain (EXPERIMENTS.md "Fault injection & resilience")
// ---------------------------------------------------------------------

/// One cell of the fault study.
#[derive(Debug, Clone)]
pub struct FaultStudyRow {
    /// Stable cell key: `no-fault`, `fault`, `fault+failover`,
    /// `fault+failover+retry`, `fault+failover+retry+drain`,
    /// `fault+failover+retry+drain+spare`.
    pub key: &'static str,
    pub rate_rps: f64,
    pub resilience_label: String,
    pub n_replicas: usize,
    pub metrics: sim::FleetMetrics,
}

/// Knobs of the fault study sweep.
#[derive(Debug, Clone, Copy)]
pub struct FaultKnobs {
    /// Crashes per seeded schedule.
    pub n_crashes: usize,
    /// Straggler windows per seeded schedule.
    pub n_stragglers: usize,
    /// Seed of the fault schedule (separate from the stream seed: the
    /// same faults strike every cell of a rate).
    pub fault_seed: u64,
    /// Total offers per request under the retry cells.
    pub retry_attempts: usize,
    /// Retry backoff base as a multiple of the probe's unloaded prefill
    /// time (the cap is 10x the base).
    pub retry_base_prefills: f64,
    /// Drain lead ahead of each scheduled crash, as a fraction of the
    /// stream horizon (scene-relative so tiny smokes still drain).
    pub drain_lead_frac: f64,
    /// KV handoff cost per drained token (s/token).
    pub handoff_s_per_token: f64,
}

impl Default for FaultKnobs {
    fn default() -> Self {
        FaultKnobs {
            n_crashes: 1,
            n_stragglers: 1,
            fault_seed: 17,
            retry_attempts: 3,
            retry_base_prefills: 4.0,
            drain_lead_frac: 0.05,
            handoff_s_per_token: 1e-8,
        }
    }
}

/// The study's cell ladder for one schedule: the fault-free reference,
/// then the same faults with resilience knobs turned on one at a time —
/// failover off (JSQ black-holes into the crashed replica's empty
/// queue), health-aware failover, +retry, +proactive drain, +one spare
/// replica. Every faulted cell replays the *same* schedule, so deltas
/// are attributable to the posture, not to fault luck.
fn fault_cells(
    n: usize,
    retry: sim::RetryPolicy,
    drain: sim::DrainSpec,
    schedule: &sim::FaultSchedule,
) -> Vec<(&'static str, usize, sim::ResilienceSpec)> {
    let s = schedule.clone();
    vec![
        ("no-fault", n, sim::ResilienceSpec::none()),
        (
            "fault",
            n,
            sim::ResilienceSpec::none()
                .with_schedule(s.clone())
                .with_failover(false),
        ),
        (
            "fault+failover",
            n,
            sim::ResilienceSpec::none().with_schedule(s.clone()),
        ),
        (
            "fault+failover+retry",
            n,
            sim::ResilienceSpec::none()
                .with_schedule(s.clone())
                .with_retry(retry),
        ),
        (
            "fault+failover+retry+drain",
            n,
            sim::ResilienceSpec::none()
                .with_schedule(s.clone())
                .with_retry(retry)
                .with_drain(drain),
        ),
        (
            "fault+failover+retry+drain+spare",
            n + 1,
            sim::ResilienceSpec::none()
                .with_schedule(s)
                .with_retry(retry)
                .with_drain(drain),
        ),
    ]
}

/// Run the fault cell ladder on one explicit stream and schedule:
/// `n` base replicas of `hw` behind a baseline JSQ front end. `cfg`
/// must already carry calibrated SLO targets.
#[allow(clippy::too_many_arguments)]
pub fn fault_study_stream(
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &sim::SimConfig,
    n: usize,
    retry: sim::RetryPolicy,
    drain: sim::DrainSpec,
    schedule: &sim::FaultSchedule,
    stream: &sim::RequestStream,
) -> Vec<FaultStudyRow> {
    let cells = fault_cells(n, retry, drain, schedule);
    par_map(
        &cells,
        sim::profile::outer_threads(),
        &|_, (key, n_cell, res)| {
            let fleet =
                sim::FleetConfig::homogeneous(*n_cell, sim::RouterPolicy::JoinShortestQueue);
            let hws = vec![hw.clone(); *n_cell];
            let metrics = sim::simulate_fleet_faults(
                stream,
                model,
                &hws,
                cfg,
                &fleet,
                &sim::Frontend::baseline(),
                res,
            );
            FaultStudyRow {
                key: *key,
                rate_rps: stream.rate_rps,
                resilience_label: res.describe(),
                n_replicas: *n_cell,
                metrics,
            }
        },
    )
}

/// Sweep the fault cell ladder on one [`FleetScene`] with fixed
/// per-replica hardware: per rate, one seeded schedule shared by every
/// cell. SLO targets are probe-calibrated like the front-end study;
/// rates default to {0.8, 1.3} x fleet capacity. Deterministic for
/// fixed `(seed, knobs.fault_seed)`.
pub fn fault_study(
    scene: &FleetScene,
    base: &sim::SimConfig,
    knobs: &FaultKnobs,
    seed: u64,
) -> Vec<FaultStudyRow> {
    fault_study_with_model(
        scene,
        &scene.model(),
        &sim_default_hw(scene.tops_per_replica()),
        base,
        knobs,
        seed,
    )
}

/// [`fault_study`] with explicit model/hardware overrides (the CI tiny
/// smoke swaps in `ModelSpec::tiny`; protocol shared with the full run).
pub fn fault_study_with_model(
    scene: &FleetScene,
    model: &ModelSpec,
    hw: &HwConfig,
    base: &sim::SimConfig,
    knobs: &FaultKnobs,
    seed: u64,
) -> Vec<FaultStudyRow> {
    let spec = scene.spec();
    let probe = sim::probe(model, hw, base, &spec);
    let mut cfg = *base;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = if scene.rates_rps.is_empty() {
        let mu = scene.n_replicas.max(2) as f64 * probe.capacity_rps();
        vec![0.8 * mu, 1.3 * mu]
    } else {
        scene.rates_rps.clone()
    };
    let backoff = knobs.retry_base_prefills * probe.t_prefill_s;
    let retry = sim::RetryPolicy::capped(knobs.retry_attempts.max(1), backoff, 10.0 * backoff);
    let n = scene.n_replicas.max(2);
    let mut rows = Vec::new();
    for &rate in &rates {
        let stream = sim::RequestStream::poisson(&spec, rate, scene.n_requests, seed);
        let schedule = sim::FaultSchedule::seeded(
            n,
            stream.horizon_s(),
            knobs.n_crashes,
            knobs.n_stragglers,
            knobs.fault_seed,
        );
        let drain = sim::DrainSpec::new(
            knobs.drain_lead_frac.max(0.0) * stream.horizon_s(),
            knobs.handoff_s_per_token,
            cfg.max_batch,
        );
        rows.extend(fault_study_stream(
            model, hw, &cfg, n, retry, drain, &schedule, &stream,
        ));
    }
    rows
}

/// Format the fault sweep as the study table.
pub fn fault_study_table(scene: &FleetScene, rows: &[FaultStudyRow]) -> Table {
    let title = format!(
        "Fault injection & resilience [{}] - crashes x failover x retry x drain \
         ({} TOPS total)",
        scene.label(),
        scene.total_tops as u64,
    );
    let mut t = Table::new(
        &title,
        &[
            "Rate (r/s)",
            "Cell",
            "Reps",
            "Goodput (tok/s)",
            "TTFT p99 (s)",
            "SLO %",
            "Avail %",
            "Failed",
            "Retried",
            "Lost",
            "Drained",
            "Rej",
        ],
    );
    for r in rows {
        let m = &r.metrics;
        t.row(vec![
            format!("{:.3}", r.rate_rps),
            r.key.to_string(),
            r.n_replicas.to_string(),
            format!("{:.1}", m.slo_goodput_tps),
            format!("{:.4}", m.ttft.p99),
            format!("{:.1}", 100.0 * m.slo_attainment),
            format!("{:.2}", 100.0 * m.faults.availability),
            m.faults.n_failed.to_string(),
            m.faults.n_retried.to_string(),
            m.faults.n_lost.to_string(),
            m.faults.n_drained.to_string(),
            m.n_rejected.to_string(),
        ]);
    }
    t
}

/// Headline at the highest swept rate: graceful degradation
/// (failover+retry+drain vs failover-disabled on the same schedule),
/// the cost of the faults vs the fault-free reference, and what one
/// spare replica buys back.
pub fn fault_study_headline(rows: &[FaultStudyRow]) -> String {
    let hi = rows
        .iter()
        .map(|r| r.rate_rps)
        .fold(f64::NEG_INFINITY, f64::max);
    let at = |key: &str| {
        rows.iter()
            .find(|r| r.rate_rps == hi && r.key == key)
            .map(|r| &r.metrics)
    };
    let mut s = format!("fault headline @ {hi:.3} req/s:\n");
    if let (Some(none), Some(blind)) = (at("no-fault"), at("fault")) {
        s.push_str(&format!(
            "  faults cost {:.1} -> {:.1} tok/s goodput with failover off \
             ({} lost, availability {:.1}%)\n",
            none.slo_goodput_tps,
            blind.slo_goodput_tps,
            blind.faults.n_lost,
            100.0 * blind.faults.availability,
        ));
    }
    if let (Some(blind), Some(full)) = (at("fault"), at("fault+failover+retry+drain")) {
        s.push_str(&format!(
            "  failover+retry+drain: goodput {:.1} vs {:.1} tok/s ({:+.1}%), \
             lost {} vs {}, {} drained\n",
            full.slo_goodput_tps,
            blind.slo_goodput_tps,
            100.0 * (full.slo_goodput_tps - blind.slo_goodput_tps)
                / blind.slo_goodput_tps.max(1e-9),
            full.faults.n_lost,
            blind.faults.n_lost,
            full.faults.n_drained,
        ));
    }
    if let (Some(full), Some(spare)) = (
        at("fault+failover+retry+drain"),
        at("fault+failover+retry+drain+spare"),
    ) {
        s.push_str(&format!(
            "  one spare replica: goodput {:.1} -> {:.1} tok/s, \
             SLO {:.1}% -> {:.1}%\n",
            full.slo_goodput_tps,
            spare.slo_goodput_tps,
            100.0 * full.slo_attainment,
            100.0 * spare.slo_attainment,
        ));
    }
    s
}

// ---------------------------------------------------------------------
// Telemetry: CLI validation, structured run records and traced
// representative cells (EXPERIMENTS.md "Telemetry & profiling")
// ---------------------------------------------------------------------

/// Validate a `--replicas` value for a fleet-shaped study. The studies
/// compare at least two replicas (round-robin vs JSQ vs a P+D split has
/// nothing to compare on one), so anything smaller is a hard CLI error
/// rather than a silent clamp.
pub fn require_replicas(n: usize, study: &str) -> Result<usize, String> {
    if n >= 2 {
        Ok(n)
    } else {
        Err(format!(
            "{study} needs >= 2 replicas (got {n}); pass --replicas 2 or more"
        ))
    }
}

/// Validate a parsed `--rates` list: every arrival rate must be a
/// finite, strictly positive req/s value (a zero or negative rate makes
/// the Poisson stream degenerate; NaN/inf poison every downstream sort).
pub fn validate_rates(rates: &[f64]) -> Result<(), String> {
    for &r in rates {
        if !r.is_finite() || r <= 0.0 {
            return Err(format!(
                "--rates values must be finite and > 0 req/s (got {r})"
            ));
        }
    }
    Ok(())
}

/// Collapse one single-replica study cell into a structured run record.
pub fn serving_run_record(
    study: &str,
    cell: &str,
    rate_rps: f64,
    m: &sim::ServingMetrics,
) -> sim::RunRecord {
    sim::RunRecord {
        study: study.to_string(),
        cell: cell.to_string(),
        rate_rps,
        n_arrived: m.n_arrived,
        n_completed: m.n_completed,
        n_rejected: m.n_rejected,
        slo_attainment: m.slo_attainment,
        slo_goodput_tps: m.slo_goodput_tps,
        throughput_tps: m.throughput_tps,
        ttft_p99_s: m.ttft.p99,
        tpot_p99_s: m.tpot.p99,
        makespan_s: m.makespan_s,
        energy_pj: m.energy_pj,
        truncated: m.truncated,
        degraded: false,
    }
}

/// Collapse one fleet-level study cell into a structured run record.
pub fn fleet_run_record(
    study: &str,
    cell: &str,
    rate_rps: f64,
    m: &sim::FleetMetrics,
) -> sim::RunRecord {
    sim::RunRecord {
        study: study.to_string(),
        cell: cell.to_string(),
        rate_rps,
        n_arrived: m.n_arrived,
        n_completed: m.n_completed,
        n_rejected: m.n_rejected,
        slo_attainment: m.slo_attainment,
        slo_goodput_tps: m.slo_goodput_tps,
        throughput_tps: m.throughput_tps,
        ttft_p99_s: m.ttft.p99,
        tpot_p99_s: m.tpot.p99,
        makespan_s: m.makespan_s,
        energy_pj: m.energy_pj,
        truncated: m.truncated,
        degraded: false,
    }
}

/// One run record per [`sim_serving_study`] cell.
pub fn sim_study_records(rows: &[SimStudyRow]) -> Vec<sim::RunRecord> {
    rows.iter()
        .map(|r| serving_run_record("sim-study", r.strategy.name(), r.rate_rps, &r.metrics))
        .collect()
}

/// One run record per [`kv_paging_study`] cell.
pub fn kv_study_records(rows: &[KvStudyRow]) -> Vec<sim::RunRecord> {
    rows.iter()
        .map(|r| serving_run_record("kv-study", &r.kv.describe(), r.rate_rps, &r.metrics))
        .collect()
}

/// One run record per [`fleet_study`] cell.
pub fn fleet_study_records(rows: &[FleetStudyRow]) -> Vec<sim::RunRecord> {
    rows.iter()
        .map(|r| fleet_run_record("fleet-study", &r.fleet.describe(), r.rate_rps, &r.metrics))
        .collect()
}

/// One run record per [`frontend_study`] cell.
pub fn frontend_study_records(rows: &[FrontendStudyRow]) -> Vec<sim::RunRecord> {
    rows.iter()
        .map(|r| fleet_run_record("frontend-study", r.key, r.rate_rps, &r.metrics))
        .collect()
}

/// One run record per [`fault_study`] cell.
pub fn fault_study_records(rows: &[FaultStudyRow]) -> Vec<sim::RunRecord> {
    rows.iter()
        .map(|r| fleet_run_record("fault-study", r.key, r.rate_rps, &r.metrics))
        .collect()
}

/// Re-run [`sim_serving_study`]'s representative cell (chunked prefill
/// at the highest swept rate) with a recording telemetry sink, under
/// exactly the study's protocol (same probe calibration, SLOs and
/// stream), and return `(cell label, rate, collector)`. The traced
/// replay is bitwise-identical to the study cell, so the trace describes
/// precisely the run the study reported.
pub fn sim_study_traced_cell(
    scene: &SimScene,
    hw: &HwConfig,
    base: &sim::SimConfig,
    seed: u64,
) -> (String, f64, Arc<Mutex<sim::SpanCollector>>) {
    let model = scene.model();
    let spec = scene.spec();
    let probe = sim::probe(&model, hw, base, &spec);
    let mut cfg = *base;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = if scene.rates_rps.is_empty() {
        probe.sweep_rates()
    } else {
        scene.rates_rps.clone()
    };
    let rate = rates.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let strategy = ServingStrategy::ChunkedPrefill;
    let stream = scene.stream(rate, seed);
    let sink = sim::SpanCollector::shared();
    let shared: sim::SharedSink = sink.clone();
    sim::simulate_serving_traced(&stream, &model, hw, &cfg.with_strategy(strategy), &shared);
    (strategy.name().to_string(), rate, sink)
}

/// Re-run [`fleet_study`]'s representative cell (the last fleet shape —
/// the disaggregated split in [`default_fleet_shapes`] — at the highest
/// swept rate) with a recording telemetry sink, under exactly the
/// study's protocol. Returns `(cell label, rate, collector)`.
pub fn fleet_study_traced_cell(
    scene: &FleetScene,
    hw: &HwConfig,
    base: &sim::SimConfig,
    fleets: &[sim::FleetConfig],
    seed: u64,
) -> (String, f64, Arc<Mutex<sim::SpanCollector>>) {
    let model = scene.model();
    let spec = scene.spec();
    let probe = sim::probe(&model, hw, base, &spec);
    let mut cfg = *base;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = if scene.rates_rps.is_empty() {
        let fleet_mu = scene.n_replicas as f64 * probe.capacity_rps();
        vec![0.4 * fleet_mu, 0.8 * fleet_mu, 1.3 * fleet_mu]
    } else {
        scene.rates_rps.clone()
    };
    let rate = rates.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let fleet = fleets.last().expect("at least one fleet shape").clone();
    let stream = scene.stream(rate, seed);
    let sink = sim::SpanCollector::shared();
    let shared: sim::SharedSink = sink.clone();
    sim::simulate_fleet_traced(&stream, &model, hw, &cfg, &fleet, &shared);
    (fleet.describe(), rate, sink)
}

/// Re-run [`frontend_study`]'s representative cell (`jsq+shed+rebal` —
/// the cell exercising both shed and rebalance telemetry — at the
/// highest swept rate) with a recording sink, under exactly the study's
/// protocol. Returns `(cell label, rate, collector)`.
pub fn frontend_study_traced_cell(
    scene: &FleetScene,
    model: &ModelSpec,
    hw: &HwConfig,
    base: &sim::SimConfig,
    knobs: &FrontendKnobs,
    seed: u64,
) -> (String, f64, Arc<Mutex<sim::SpanCollector>>) {
    let spec = scene.spec();
    let probe = sim::probe(model, hw, base, &spec);
    let mut cfg = *base;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = if scene.rates_rps.is_empty() {
        let mu = scene.n_replicas.max(2) as f64 * probe.capacity_rps();
        vec![0.8 * mu, 1.3 * mu]
    } else {
        scene.rates_rps.clone()
    };
    let rate = rates.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let stream = sim::RequestStream::poisson(&spec, rate, scene.n_requests, seed);
    let (key, fleet, hws, fe) = frontend_cells(scene, hw, &probe, knobs)
        .into_iter()
        .find(|c| c.0 == "jsq+shed+rebal")
        .expect("cell set contains jsq+shed+rebal");
    let sink = sim::SpanCollector::shared();
    let shared: sim::SharedSink = sink.clone();
    sim::simulate_fleet_frontend_traced(&stream, model, &hws, &cfg, &fleet, &fe, &shared);
    (key.to_string(), rate, sink)
}

/// Re-run [`fault_study`]'s representative cell
/// (`fault+failover+retry+drain` — the cell exercising crash, drain,
/// failure and retry telemetry — at the highest swept rate) with a
/// recording sink, under exactly the study's protocol. Returns
/// `(cell label, rate, collector)`.
pub fn fault_study_traced_cell(
    scene: &FleetScene,
    model: &ModelSpec,
    hw: &HwConfig,
    base: &sim::SimConfig,
    knobs: &FaultKnobs,
    seed: u64,
) -> (String, f64, Arc<Mutex<sim::SpanCollector>>) {
    let spec = scene.spec();
    let probe = sim::probe(model, hw, base, &spec);
    let mut cfg = *base;
    cfg.slo = probe.slo(3.0, 4.0);
    let rates = if scene.rates_rps.is_empty() {
        let mu = scene.n_replicas.max(2) as f64 * probe.capacity_rps();
        vec![0.8 * mu, 1.3 * mu]
    } else {
        scene.rates_rps.clone()
    };
    let rate = rates.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let backoff = knobs.retry_base_prefills * probe.t_prefill_s;
    let retry = sim::RetryPolicy::capped(knobs.retry_attempts.max(1), backoff, 10.0 * backoff);
    let n = scene.n_replicas.max(2);
    let stream = sim::RequestStream::poisson(&spec, rate, scene.n_requests, seed);
    let schedule = sim::FaultSchedule::seeded(
        n,
        stream.horizon_s(),
        knobs.n_crashes,
        knobs.n_stragglers,
        knobs.fault_seed,
    );
    let drain = sim::DrainSpec::new(
        knobs.drain_lead_frac.max(0.0) * stream.horizon_s(),
        knobs.handoff_s_per_token,
        cfg.max_batch,
    );
    let (key, n_cell, res) = fault_cells(n, retry, drain, &schedule)
        .into_iter()
        .find(|c| c.0 == "fault+failover+retry+drain")
        .expect("cell ladder contains fault+failover+retry+drain");
    let fleet = sim::FleetConfig::homogeneous(n_cell, sim::RouterPolicy::JoinShortestQueue);
    let hws = vec![hw.clone(); n_cell];
    let sink = sim::SpanCollector::shared();
    let shared: sim::SharedSink = sink.clone();
    sim::simulate_fleet_faults_traced(
        &stream,
        model,
        &hws,
        &cfg,
        &fleet,
        &sim::Frontend::baseline(),
        &res,
        &shared,
    );
    (key.to_string(), rate, sink)
}

// ---------------------------------------------------------------------
// Fig. 11 — ablations
// ---------------------------------------------------------------------

/// Ablation study under the chunked-prefill configuration (paper §VI-G):
/// full Compass vs GA->random, BO->random, and SCAR-style mapping.
pub fn fig11_ablation(cfg: &DseConfig, rt: Option<&Runtime>, seed: u64) -> Table {
    let trace = Trace::new(&TraceSpec::govreport(), 256, seed);
    let model = model_for_tops(512.0);
    let space = HwSpace::paper(512.0);
    let prefill_len = trace.mean_in().round() as u64;
    let scen =
        Scenario::serving(ServingStrategy::ChunkedPrefill, &trace, prefill_len, 128, 2, 2048);

    let mut t = Table::new(
        "Fig 11 - ablation (chunked-prefill scenario), lower total = better",
        &["Variant", "Latency (cyc)", "Energy (pJ)", "MC ($)", "Total (s*J*$)"],
    );
    let mut push = |name: &str, e: &crate::cost::EvalResult| {
        t.row(vec![
            name.to_string(),
            format!("{:.3e}", e.latency_cycles),
            format!("{:.3e}", e.energy_pj),
            format!("{:.1}", e.mc_usd),
            format!("{:.3e}", e.total_cost()),
        ]);
    };

    // full Compass
    let mut gp = make_gp(rt);
    let full = dse::compass_dse(&scen, &model, &space, cfg, gp.as_mut());
    push("Compass (GA + BO)", &full.eval);

    // GA -> random mapping at the same evaluation budget, on the same
    // hardware Compass found (paper: "we replace the GA ... with a
    // random search method with the same number of iterations")
    let rm_eval =
        random::random_mappings(&scen, &model, &full.hw, &cfg.ga, cfg.eval_blocks).eval;
    push("GA -> random", &rm_eval);

    // BO -> random hardware (same rounds), GA intact
    let (rhw, _) = random::random_hardware(&space, &cfg.bo, |hw| {
        dse::search_mappings(&scen, &model, hw, &cfg.ga, cfg.eval_blocks)
            .eval
            .total_cost()
    });
    let rh_eval = dse::search_mappings(&scen, &model, &rhw, &cfg.ga, cfg.eval_blocks).eval;
    push("BO -> random", &rh_eval);

    // SCAR-style mapping on the Compass-found hardware
    let scar_eval = scar::scar_mappings(&scen, &model, &full.hw, cfg.eval_blocks).eval;
    push("SCAR-style mapping", &scar_eval);

    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_expected_shape_and_crossover() {
        let t = table1(64.0);
        assert_eq!(t.rows.len(), 4);
        assert_eq!(t.headers.len(), 5);
        let parse = |s: &str| s.trim_end_matches('x').parse::<f64>().unwrap();
        // short sequences: WS superior on the weight GEMMs
        assert!(parse(&t.rows[0][1]) > 1.0, "qkv@128 {}", t.rows[0][1]);
        // long sequences: OS superior
        assert!(parse(&t.rows[3][1]) < 1.0, "qkv@10240 {}", t.rows[3][1]);
        assert!(parse(&t.rows[3][3]) < 1.0, "ffn1@10240 {}", t.rows[3][3]);
    }

    #[test]
    fn table5_errors_small() {
        let t = table5(1);
        let err_row = &t.rows[2];
        for cell in &err_row[2..] {
            let v: f64 = cell.trim_end_matches('%').parse().unwrap();
            assert!(v < 25.0, "validation error {cell} too large");
        }
    }

    #[test]
    fn sim_study_covers_strategy_rate_grid() {
        let mut scene = SimScene::new("sharegpt", 64.0, 5);
        scene.rates_rps = vec![2.0, 8.0];
        let hw = sim_default_hw(64.0);
        let mut cfg = sim::SimConfig::new(ServingStrategy::Orca);
        cfg.max_batch = 8;
        cfg.eval_blocks = 1;
        cfg.ctx_bucket = 512;
        let rows = sim_serving_study(&scene, &hw, &cfg, 3);
        assert_eq!(rows.len(), 2 * ServingStrategy::ALL.len());
        for r in &rows {
            assert_eq!(
                r.metrics.n_completed + r.metrics.n_rejected,
                r.metrics.n_arrived,
                "{:?}@{}",
                r.strategy,
                r.rate_rps
            );
        }
        let t = sim_study_table(&scene, &rows);
        assert_eq!(t.rows.len(), rows.len());
        let occ = sim_study_occupancy(&rows, ServingStrategy::ChunkedPrefill, cfg.max_batch);
        assert!(occ.contains("occupancy"));
        assert!(occ.contains("batch |"));
    }

    #[test]
    fn kv_study_covers_layout_rate_grid() {
        let mut scene = SimScene::new("sharegpt", 64.0, 6);
        // second rate floods all requests in at once: admissions overlap,
        // so the materialized prefix is referenced by later requests
        scene.rates_rps = vec![3.0, 500.0];
        let hw = sim_default_hw(64.0);
        let mut cfg = sim::SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.eval_blocks = 1;
        cfg.ctx_bucket = 512;
        // chunked admissions spread over iterations, so the prefix is
        // Ready before the later admissions (they skip it)
        cfg.chunk_tokens = 64;
        // tight DRAM so the cache layout actually binds
        cfg.kv_budget_tokens = 0;
        cfg.dram_gb = 2048.0 * ModelSpec::gpt3_7b().kv_bytes_per_token() as f64 / 1e9;
        let specs = default_kv_specs(16, 64);
        assert_eq!(specs.len(), 7);
        let rows = kv_paging_study(&scene, &hw, &cfg, &specs, 64, 3);
        assert_eq!(rows.len(), 2 * specs.len());
        for r in &rows {
            assert_eq!(
                r.metrics.n_completed + r.metrics.n_rejected,
                r.metrics.n_arrived,
                "{}@{}",
                r.kv.describe(),
                r.rate_rps
            );
        }
        // quantized layouts get more tokens from the same DRAM
        let cap_of = |name: &str| {
            rows.iter()
                .find(|r| r.kv.describe() == name)
                .map(|r| r.capacity_tokens)
                .unwrap()
        };
        assert!(cap_of("int4/bt1") >= 4 * cap_of("fp16/bt1"));
        // sharing layouts record hits on the prefixed trace
        assert!(rows
            .iter()
            .filter(|r| r.kv.prefix_tokens > 0)
            .any(|r| r.metrics.kv_shared_tokens > 0));
        let t = kv_study_table(&scene, &rows);
        assert_eq!(t.rows.len(), rows.len());
    }

    #[test]
    fn fleet_study_covers_shape_rate_grid() {
        let mut scene = FleetScene::new("sharegpt", 64.0, 2, 6);
        scene.rates_rps = vec![4.0, 16.0];
        let hw = sim_default_hw(scene.tops_per_replica());
        let mut cfg = sim::SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.eval_blocks = 1;
        cfg.ctx_bucket = 512;
        let shapes = default_fleet_shapes(scene.n_replicas, 1e-8);
        assert_eq!(shapes.len(), 3);
        let rows = fleet_study(&scene, &hw, &cfg, &shapes, 3);
        assert_eq!(rows.len(), 2 * shapes.len());
        for r in &rows {
            assert_eq!(
                r.metrics.n_completed + r.metrics.n_rejected,
                r.metrics.n_arrived,
                "{}@{}",
                r.fleet.describe(),
                r.rate_rps
            );
        }
        // the disaggregated shape reports handoff traffic
        assert!(rows
            .iter()
            .filter(|r| r.fleet.router == sim::RouterPolicy::PrefillDecode)
            .any(|r| r.metrics.kv_transfer_tokens > 0));
        let t = fleet_study_table(&scene, &rows);
        assert_eq!(t.rows.len(), rows.len());
    }

    #[test]
    fn frontend_study_covers_cell_rate_grid() {
        let mut scene = FleetScene::new("sharegpt", 64.0, 2, 8);
        scene.rates_rps = vec![4.0, 20.0];
        let hw = sim_default_hw(scene.tops_per_replica());
        let mut cfg = sim::SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.eval_blocks = 1;
        cfg.ctx_bucket = 512;
        let knobs = FrontendKnobs::default();
        let rows =
            frontend_study_with_model(&scene, &ModelSpec::gpt3_7b(), &hw, &cfg, &knobs, 3);
        assert_eq!(rows.len(), 2 * 6, "2 rates x 6 cells");
        for r in &rows {
            assert_eq!(
                r.metrics.n_completed + r.metrics.n_rejected,
                r.metrics.n_arrived,
                "{}@{}",
                r.key,
                r.rate_rps
            );
        }
        // the baseline cell never sheds or rebalances
        for r in rows.iter().filter(|r| r.key == "jsq") {
            assert_eq!(r.metrics.n_shed, 0);
            assert_eq!(r.metrics.n_rebalanced, 0);
        }
        // shed counts stay within rejections on every shedding cell
        for r in rows.iter().filter(|r| r.key.contains("shed")) {
            assert!(r.metrics.n_shed <= r.metrics.n_rejected);
        }
        let t = frontend_study_table(&scene, &rows);
        assert_eq!(t.rows.len(), rows.len());
        let headline = frontend_study_headline(&rows);
        assert!(headline.contains("slo-shed"), "{headline}");
        assert!(headline.contains("hetero-disagg"), "{headline}");
    }

    #[test]
    fn fault_study_covers_cell_rate_grid_and_conserves_requests() {
        let mut scene = FleetScene::new("sharegpt", 64.0, 2, 8);
        scene.rates_rps = vec![4.0, 20.0];
        let hw = sim_default_hw(scene.tops_per_replica());
        let mut cfg = sim::SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.eval_blocks = 1;
        cfg.ctx_bucket = 512;
        let knobs = FaultKnobs::default();
        let rows = fault_study_with_model(&scene, &ModelSpec::gpt3_7b(), &hw, &cfg, &knobs, 3);
        assert_eq!(rows.len(), 2 * 6, "2 rates x 6 cells");
        for r in &rows {
            // conservation holds even with crashes, retries and losses
            assert_eq!(
                r.metrics.n_completed + r.metrics.n_rejected,
                r.metrics.n_arrived,
                "{}@{}",
                r.key,
                r.rate_rps
            );
            assert!(!r.metrics.truncated, "{}@{}", r.key, r.rate_rps);
        }
        // the fault-free reference never loses a request
        for r in rows.iter().filter(|r| r.key == "no-fault") {
            assert_eq!(r.metrics.faults.n_lost, 0);
            assert_eq!(r.metrics.faults.n_failed, 0);
            assert_eq!(r.metrics.faults.availability.to_bits(), 1.0f64.to_bits());
        }
        // every faulted cell replays the scheduled crash count
        for r in rows.iter().filter(|r| r.key != "no-fault") {
            assert_eq!(r.metrics.faults.n_crashes, knobs.n_crashes);
            assert!(r.metrics.faults.availability < 1.0);
            assert!(r.metrics.faults.downtime_s > 0.0);
        }
        // the spare cell really adds a replica
        for r in rows.iter().filter(|r| r.key.contains("spare")) {
            assert_eq!(r.n_replicas, scene.n_replicas + 1);
        }
        // determinism: a rerun is bit-identical
        let again = fault_study_with_model(&scene, &ModelSpec::gpt3_7b(), &hw, &cfg, &knobs, 3);
        for (a, b) in rows.iter().zip(&again) {
            assert_eq!(
                a.metrics.slo_goodput_tps.to_bits(),
                b.metrics.slo_goodput_tps.to_bits(),
                "{}@{}",
                a.key,
                a.rate_rps
            );
        }
        let t = fault_study_table(&scene, &rows);
        assert_eq!(t.rows.len(), rows.len());
        let headline = fault_study_headline(&rows);
        assert!(headline.contains("failover+retry+drain"), "{headline}");
        assert!(headline.contains("spare"), "{headline}");
    }

    #[test]
    fn require_replicas_and_validate_rates_gate_cli_inputs() {
        assert_eq!(require_replicas(2, "fleet-study"), Ok(2));
        assert_eq!(require_replicas(5, "fault-study"), Ok(5));
        let err = require_replicas(1, "fleet-study").unwrap_err();
        assert!(err.contains("fleet-study"), "{err}");
        assert!(err.contains("--replicas"), "{err}");
        assert!(require_replicas(0, "frontend-study").is_err());
        assert!(validate_rates(&[]).is_ok());
        assert!(validate_rates(&[0.5, 2.0]).is_ok());
        assert!(validate_rates(&[0.0]).is_err());
        assert!(validate_rates(&[-1.0]).is_err());
        assert!(validate_rates(&[f64::NAN]).is_err());
        assert!(validate_rates(&[f64::INFINITY]).is_err());
        assert!(validate_rates(&[1.0, -2.0, 3.0]).is_err());
    }

    #[test]
    fn study_records_cover_every_cell() {
        let mut scene = SimScene::new("sharegpt", 64.0, 4);
        scene.rates_rps = vec![2.0, 8.0];
        let hw = sim_default_hw(64.0);
        let mut cfg = sim::SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.eval_blocks = 1;
        cfg.ctx_bucket = 512;
        let rows = sim_serving_study(&scene, &hw, &cfg, 3);
        let recs = sim_study_records(&rows);
        assert_eq!(recs.len(), rows.len());
        for (rec, row) in recs.iter().zip(&rows) {
            assert_eq!(rec.study, "sim-study");
            assert_eq!(rec.cell, row.strategy.name());
            assert_eq!(rec.rate_rps.to_bits(), row.rate_rps.to_bits());
            assert_eq!(rec.n_arrived, row.metrics.n_arrived);
            assert!(!rec.degraded);
            let line = rec.to_json();
            assert!(line.starts_with('{') && line.ends_with('}'), "{line}");
            assert!(line.contains("\"study\":\"sim-study\""), "{line}");
        }
    }

    #[test]
    fn sim_study_traced_cell_replays_the_reported_cell() {
        let mut scene = SimScene::new("sharegpt", 64.0, 4);
        scene.rates_rps = vec![2.0, 8.0];
        let hw = sim_default_hw(64.0);
        let mut cfg = sim::SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.eval_blocks = 1;
        cfg.ctx_bucket = 512;
        let (cell, rate, sink) = sim_study_traced_cell(&scene, &hw, &cfg, 3);
        assert_eq!(cell, ServingStrategy::ChunkedPrefill.name());
        assert_eq!(rate.to_bits(), 8.0f64.to_bits());
        let c = sink.lock().unwrap();
        assert!(c.n_finished() > 0, "traced replay finished no requests");
        assert!(!c.events().is_empty());
        // the trace must match what the study reported for that cell
        let rows = sim_serving_study(&scene, &hw, &cfg, 3);
        let row = rows
            .iter()
            .find(|r| r.strategy == ServingStrategy::ChunkedPrefill && r.rate_rps == rate)
            .unwrap();
        assert_eq!(c.n_finished(), row.metrics.n_completed);
    }

    #[test]
    fn steady_state_reference_close_to_timeline_for_pipeline() {
        let model = ModelSpec::tiny();
        let hw =
            HwConfig::homogeneous(2, 2, ChipletClass::S, Dataflow::WeightStationary, 32.0, 16.0);
        let batch = vec![crate::workload::Request::prefill(64); 8];
        let params = crate::workload::WorkloadParams {
            micro_batch_size: 2,
            tensor_parallel: 2,
            eval_blocks: 2,
        };
        let w = crate::workload::build_workload(&model, &batch, &params);
        let m =
            crate::mapping::presets::pipeline_parallel(w.num_micro_batches(), w.layers_per_mb, 4);
        let r = Evaluator::new().eval_batch(&w, &hw, &m);
        let (lref, eref) = steady_state_reference(&w, &hw, &m);
        // independent methodology, same scale: agreement within 25%
        let lerr = (r.latency_cycles - lref).abs() / lref;
        assert!(lerr < 0.25, "latency mismatch {lerr}");
        let err = (r.energy_pj - eref).abs() / eref;
        assert!(err < 0.05, "energy mismatch {err}");
    }
}

//! Inter-chiplet latency & energy simulation (paper §V-C).
//!
//! Per-layer processing time under double buffering:
//!     `T_proc = max(T_comp, T_DRAM, T_NoP)`
//! Start time: the later of (a) the completion of the previously scheduled
//! layer on the same chiplet and (b) the latest completion among direct
//! predecessors:
//!     `T_start = max(max_{pred} T_end, max_{same core} T_end)`
//! Model latency is the maximum completion time across all layers; energy
//! is the sum `E_comp + E_DRAM + E_NoP` over layers.

use crate::arch::constants::*;
use crate::arch::HwConfig;
use crate::mapping::Mapping;
use crate::workload::{Phase, Workload};

use super::access::{AccessFlags, InputSrc};
use super::dataflow::layer_cost;

/// One executed task in the spatio-temporal diagram (paper Fig. 5/8).
#[derive(Debug, Clone, Copy)]
pub struct TimelineEntry {
    pub mb: usize,
    pub layer: usize,
    pub chip: u16,
    pub start: f64,
    pub end: f64,
    pub phase: Phase,
}


/// Energy / latency breakdown by component.
#[derive(Debug, Clone, Copy, Default)]
pub struct Breakdown {
    pub comp_cycles: f64,
    pub dram_cycles: f64,
    pub nop_cycles: f64,
    pub comp_energy_pj: f64,
    pub dram_energy_pj: f64,
    pub nop_energy_pj: f64,
    pub dram_bytes: f64,
    pub nop_bytes: f64,
    pub macs: f64,
}

/// Result of simulating one batch on one (hardware, mapping) pair.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// End-to-end latency in cycles (block-extrapolated).
    pub latency_cycles: f64,
    /// Total energy in pJ (block-extrapolated).
    pub energy_pj: f64,
    pub breakdown: Breakdown,
    /// Per-phase energy (pJ), for the paper's breakdown plots.
    pub phase_energy: Vec<(Phase, f64)>,
    /// Spatio-temporal execution diagram (only when requested).
    pub timeline: Option<Vec<TimelineEntry>>,
}

/// Simulation switches.
#[derive(Debug, Clone, Copy)]
pub struct SimOptions {
    /// Record the full spatio-temporal diagram.
    pub record_timeline: bool,
    /// Serialise DRAM accesses per DRAM chip (bandwidth contention)
    /// instead of the paper's per-layer bandwidth model.
    pub dram_contention: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions {
            record_timeline: false,
            dram_contention: false,
        }
    }
}

/// Index of a chiplet's (capacity class, dataflow) pair in the
/// kernel-cost memo: `class * 2 + dataflow`, 6 kinds total.
#[inline]
pub(crate) fn chip_kind(c: crate::arch::Chiplet) -> usize {
    let cls = match c.class {
        crate::arch::ChipletClass::S => 0,
        crate::arch::ChipletClass::M => 1,
        crate::arch::ChipletClass::L => 2,
    };
    let df = match c.dataflow {
        crate::arch::Dataflow::WeightStationary => 0,
        crate::arch::Dataflow::OutputStationary => 1,
    };
    cls * 2 + df
}

#[inline]
fn chiplet_of_kind(kind: usize) -> crate::arch::Chiplet {
    use crate::arch::{Chiplet, ChipletClass, Dataflow};
    Chiplet {
        class: [ChipletClass::S, ChipletClass::M, ChipletClass::L][kind / 2],
        dataflow: [Dataflow::WeightStationary, Dataflow::OutputStationary][kind % 2],
    }
}

/// Per-(shape-class, chiplet-kind, load-flag) kernel-cost memo. Kernel
/// costs depend only on the layer shape and the executing chiplet's
/// (class, dataflow, load) — never on the mapping — so the evaluation
/// engine builds the full table once per (workload, hardware) search and
/// shares it read-only across threads (see EXPERIMENTS.md #Perf).
#[derive(Debug, Clone, Default)]
pub struct KernelMemo {
    /// `costs[class * 12 + chip_kind * 2 + load]`; entries stay `None`
    /// for chiplet kinds absent from the hardware.
    costs: Vec<Option<super::dataflow::KernelCost>>,
}

impl KernelMemo {
    pub fn build(workload: &Workload, hw: &HwConfig) -> Self {
        // cost memo: classes x (3 chiplet classes x 2 dataflows) x load flag
        let n_classes = workload
            .micro_batches
            .iter()
            .flat_map(|mb| mb.layers.iter())
            .map(|l| l.shape_class + 1)
            .max()
            .unwrap_or(1) as usize;
        let mut present = [false; 6];
        for i in 0..hw.num_chiplets() {
            present[chip_kind(hw.chiplet(i))] = true;
        }
        let mut costs = vec![None; n_classes * 12];
        let mut seen = vec![false; n_classes];
        for mb in &workload.micro_batches {
            for node in &mb.layers {
                let cls = node.shape_class as usize;
                if seen[cls] {
                    continue;
                }
                seen[cls] = true;
                for (kind, &p) in present.iter().enumerate() {
                    if !p {
                        continue;
                    }
                    let chip = chiplet_of_kind(kind);
                    for load in 0..2usize {
                        costs[cls * 12 + kind * 2 + load] =
                            Some(layer_cost(&node.kind, node.vec_ops, chip, load == 1));
                    }
                }
            }
        }
        KernelMemo { costs }
    }

    #[inline]
    fn get(&self, key: usize) -> super::dataflow::KernelCost {
        self.costs[key].expect("kernel memo built for a different workload/hardware")
    }
}

/// Reusable per-thread working state of [`simulate_into`], so the
/// timeline walk allocates nothing per individual.
#[derive(Debug, Default)]
pub struct SimScratch {
    chip_avail: Vec<f64>,
    dram_avail: Vec<f64>,
    layer_end: Vec<f64>,
}

/// Simulate one batch. `flags` must come from `access::analyze` on the
/// same (workload, mapping).
pub fn simulate(
    workload: &Workload,
    hw: &HwConfig,
    mapping: &Mapping,
    flags: &AccessFlags,
    opts: &SimOptions,
) -> SimResult {
    simulate_with_order(workload, hw, mapping, flags, opts, &mapping.schedule_order())
}

/// `simulate` with a precomputed schedule order (builds the kernel-cost
/// memo and scratch buffers fresh; searches should use [`simulate_into`]
/// through the evaluation engine instead).
pub fn simulate_with_order(
    workload: &Workload,
    hw: &HwConfig,
    mapping: &Mapping,
    flags: &AccessFlags,
    opts: &SimOptions,
    order: &[(usize, usize)],
) -> SimResult {
    let memo = KernelMemo::build(workload, hw);
    let mut scratch = SimScratch::default();
    simulate_into(workload, hw, mapping, flags, opts, order, &memo, &mut scratch)
}

/// Allocation-free timeline simulation: reuses `scratch` buffers and the
/// search-invariant kernel-cost `memo` — the evaluation engine's
/// hot-path variant (see EXPERIMENTS.md #Perf).
#[allow(clippy::too_many_arguments)]
pub fn simulate_into(
    workload: &Workload,
    hw: &HwConfig,
    mapping: &Mapping,
    flags: &AccessFlags,
    opts: &SimOptions,
    order: &[(usize, usize)],
    memo: &KernelMemo,
    scratch: &mut SimScratch,
) -> SimResult {
    let cols = mapping.cols;
    let nop_bytes_per_cycle = hw.nop_bw_gbs * 1e9 / CLOCK_HZ;
    let dram_bytes_per_cycle = hw.dram_bw_gbs * 1e9 / CLOCK_HZ;

    scratch.chip_avail.clear();
    scratch.chip_avail.resize(hw.num_chiplets(), 0.0);
    scratch.dram_avail.clear();
    scratch.dram_avail.resize(NUM_DRAM_CHIPS, 0.0);
    scratch.layer_end.clear();
    scratch.layer_end.resize(mapping.rows * cols, 0.0);
    let chip_avail = &mut scratch.chip_avail;
    let dram_avail = &mut scratch.dram_avail;
    let layer_end = &mut scratch.layer_end;
    let mut bd = Breakdown::default();
    let mut phase_energy: Vec<(Phase, f64)> = Vec::new();
    let mut timeline = if opts.record_timeline {
        Some(Vec::with_capacity(mapping.rows * cols))
    } else {
        None
    };
    let mut makespan = 0.0f64;

    for &(mb, layer) in order {
        let t = mb * cols + layer;
        let chip_id = mapping.chip(mb, layer);
        let chip = hw.chiplet(chip_id as usize);
        let node = &workload.micro_batches[mb].layers[layer];

        let load_wei = flags.is_load_wei[t]
            // resident reuse only possible when the weights fit the GLB
            || node.weight_bytes > (chip.class.glb_bytes() as f64 * 0.9) as u64;
        let write_out = flags.is_write_out[t] || node.force_out;

        let key = (node.shape_class as usize * 12) + chip_kind(chip) * 2 + load_wei as usize;
        let cost = memo.get(key);

        // --- classify activation traffic ---
        let n_preds = node.preds.len().max(1) as f64;
        let per_pred_bytes = node.in_bytes as f64 / n_preds;
        let mut dram_rd = cost.weight_dram + cost.spill_dram + node.kv_read_bytes as f64;
        let mut nop_bytes = 0.0;
        let mut nop_hop_bytes = 0.0;
        if node.preds.is_empty() {
            // model input arrives from DRAM
            dram_rd += node.in_bytes as f64;
        } else {
            for src in flags.srcs(t) {
                match *src {
                    InputSrc::Local => {}
                    InputSrc::Nop { chip: c } => {
                        let hops = hw.hops(c as usize, chip_id as usize).max(1) as f64;
                        nop_bytes += per_pred_bytes;
                        nop_hop_bytes += per_pred_bytes * hops;
                    }
                    InputSrc::Dram => dram_rd += per_pred_bytes,
                }
            }
        }
        let dram_wr =
            if write_out { node.out_bytes as f64 } else { 0.0 } + node.kv_write_bytes as f64;
        let dram_bytes = dram_rd + dram_wr;

        // --- per-layer times (double buffering: overlap, take max) ---
        let t_comp = cost.cycles;
        let t_dram = if dram_bytes > 0.0 {
            dram_bytes / dram_bytes_per_cycle + DRAM_LAT_CYCLES
        } else {
            0.0
        };
        let t_nop = if nop_bytes > 0.0 {
            nop_bytes / nop_bytes_per_cycle
                + NOP_HOP_CYCLES * (nop_hop_bytes / nop_bytes.max(1.0)).max(1.0)
        } else {
            0.0
        };
        let t_proc = t_comp.max(t_dram).max(t_nop);

        // --- start time: dependencies + core availability ---
        let mut start = chip_avail[chip_id as usize];
        for &p in &node.preds {
            start = start.max(layer_end[mb * cols + p]);
        }
        // DRAM channel contention (optional extension)
        if opts.dram_contention && dram_bytes > 0.0 {
            let d = node
                .dram_id
                .map(|d| d as usize % NUM_DRAM_CHIPS)
                .unwrap_or_else(|| hw.nearest_dram(chip_id as usize));
            start = start.max(dram_avail[d] - t_proc.min(t_dram));
            dram_avail[d] = start.max(dram_avail[d]) + t_dram;
        }
        let end = start + t_proc;
        chip_avail[chip_id as usize] = end;
        layer_end[t] = end;
        makespan = makespan.max(end);

        // --- energy ---
        let dram_hops = {
            let d = node
                .dram_id
                .map(|d| d as usize % NUM_DRAM_CHIPS)
                .unwrap_or_else(|| hw.nearest_dram(chip_id as usize));
            hw.dram_hops(chip_id as usize, d) as f64
        };
        let e_comp = cost.onchip_energy_pj();
        let e_dram = dram_bytes * E_DRAM_PJ_BYTE + dram_bytes * dram_hops * E_NOP_PJ_BYTE_HOP;
        let e_nop = nop_hop_bytes * E_NOP_PJ_BYTE_HOP;
        bd.comp_cycles += t_comp;
        bd.dram_cycles += t_dram;
        bd.nop_cycles += t_nop;
        bd.comp_energy_pj += e_comp;
        bd.dram_energy_pj += e_dram;
        bd.nop_energy_pj += e_nop;
        bd.dram_bytes += dram_bytes;
        bd.nop_bytes += nop_bytes;
        bd.macs += cost.macs;

        let e_total = e_comp + e_dram + e_nop;
        match phase_energy.iter_mut().find(|(p, _)| *p == node.phase) {
            Some((_, e)) => *e += e_total,
            None => phase_energy.push((node.phase, e_total)),
        }

        if let Some(tl) = timeline.as_mut() {
            tl.push(TimelineEntry {
                mb,
                layer,
                chip: chip_id,
                start,
                end,
                phase: node.phase,
            });
        }
    }

    let scale = workload.block_scale;
    let energy: f64 =
        (bd.comp_energy_pj + bd.dram_energy_pj + bd.nop_energy_pj) * scale;
    for (_, e) in phase_energy.iter_mut() {
        *e *= scale;
    }
    SimResult {
        latency_cycles: makespan * scale,
        energy_pj: energy,
        breakdown: bd,
        phase_energy,
        timeline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::cost::access;
    use crate::mapping::presets;
    use crate::workload::{build_workload, ModelSpec, Request, WorkloadParams};

    fn setup(
        rows: usize,
        chips: usize,
    ) -> (Workload, HwConfig) {
        let m = ModelSpec::tiny();
        let batch = vec![Request::prefill(64); rows];
        let w = build_workload(
            &m,
            &batch,
            &WorkloadParams {
                micro_batch_size: 1,
                tensor_parallel: 2,
                eval_blocks: 2,
            },
        );
        let (h, wd) = crate::arch::HwSpace::grid_dims(chips);
        let hw = HwConfig::homogeneous(h, wd, ChipletClass::S, Dataflow::WeightStationary, 32.0, 16.0);
        (w, hw)
    }

    fn run(
        w: &Workload,
        hw: &HwConfig,
        map: &Mapping,
        opts: &SimOptions,
    ) -> SimResult {
        let flags = access::analyze(w, map);
        simulate(w, hw, map, &flags, opts)
    }

    #[test]
    fn latency_and_energy_positive_and_scaled() {
        let (w, hw) = setup(2, 4);
        let map = presets::pipeline_parallel(2, w.layers_per_mb, 4);
        let r = run(&w, &hw, &map, &SimOptions::default());
        assert!(r.latency_cycles > 0.0);
        assert!(r.energy_pj > 0.0);
        // tiny model has 4 blocks, we eval 2 -> scale 2
        assert!((w.block_scale - 2.0).abs() < 1e-12);
    }

    #[test]
    fn dependencies_respected_in_timeline() {
        let (w, hw) = setup(2, 4);
        let map = presets::model_parallel(w.layers_per_mb, 4);
        let map = {
            let mut m = crate::mapping::Mapping::new(2, w.layers_per_mb);
            m.layer_to_chip = map
                .layer_to_chip
                .iter()
                .cycle()
                .take(2 * w.layers_per_mb)
                .copied()
                .collect();
            m
        };
        let r = run(
            &w,
            &hw,
            &map,
            &SimOptions {
                record_timeline: true,
                ..Default::default()
            },
        );
        let tl = r.timeline.unwrap();
        let end_of = |mb: usize, l: usize| {
            tl.iter()
                .find(|e| e.mb == mb && e.layer == l)
                .map(|e| e.end)
                .unwrap()
        };
        for e in &tl {
            for &p in &w.micro_batches[e.mb].layers[e.layer].preds {
                assert!(
                    e.start + 1e-9 >= end_of(e.mb, p),
                    "layer {} started before pred {p}",
                    e.layer
                );
            }
        }
    }

    #[test]
    fn same_chip_tasks_serialize() {
        let (w, hw) = setup(1, 1);
        let map = presets::data_parallel(1, w.layers_per_mb, 1);
        let r = run(
            &w,
            &hw,
            &map,
            &SimOptions {
                record_timeline: true,
                ..Default::default()
            },
        );
        let tl = r.timeline.unwrap();
        for pair in tl.windows(2) {
            assert!(pair[1].start + 1e-9 >= pair[0].end);
        }
    }

    #[test]
    fn more_chips_reduce_latency_for_parallel_work() {
        let m = ModelSpec::tiny();
        let batch = vec![Request::prefill(64); 8];
        let w = build_workload(
            &m,
            &batch,
            &WorkloadParams {
                micro_batch_size: 1,
                tensor_parallel: 2,
                eval_blocks: 1,
            },
        );
        let hw1 = HwConfig::homogeneous(1, 1, ChipletClass::S, Dataflow::WeightStationary, 32.0, 64.0);
        let hw4 = HwConfig::homogeneous(2, 2, ChipletClass::S, Dataflow::WeightStationary, 32.0, 64.0);
        let m1 = presets::data_parallel(8, w.layers_per_mb, 1);
        let m4 = presets::data_parallel(8, w.layers_per_mb, 4);
        let r1 = run(&w, &hw1, &m1, &SimOptions::default());
        let r4 = run(&w, &hw4, &m4, &SimOptions::default());
        assert!(
            r4.latency_cycles < r1.latency_cycles * 0.6,
            "4 chips {} vs 1 chip {}",
            r4.latency_cycles,
            r1.latency_cycles
        );
    }

    #[test]
    fn higher_dram_bw_never_hurts() {
        let (w, hw_lo) = setup(2, 4);
        let mut hw_hi = hw_lo.clone();
        hw_hi.dram_bw_gbs = 256.0;
        let map = presets::data_parallel(2, w.layers_per_mb, 4);
        let lo = run(&w, &hw_lo, &map, &SimOptions::default());
        let hi = run(&w, &hw_hi, &map, &SimOptions::default());
        assert!(hi.latency_cycles <= lo.latency_cycles + 1e-9);
        assert!((hi.energy_pj - lo.energy_pj).abs() / lo.energy_pj < 1e-9);
    }

    #[test]
    fn contention_model_is_never_faster() {
        let (w, hw) = setup(4, 4);
        let map = presets::data_parallel(4, w.layers_per_mb, 4);
        let base = run(&w, &hw, &map, &SimOptions::default());
        let cont = run(
            &w,
            &hw,
            &map,
            &SimOptions {
                dram_contention: true,
                ..Default::default()
            },
        );
        assert!(cont.latency_cycles + 1e-9 >= base.latency_cycles);
    }

    #[test]
    fn phase_energy_sums_to_total() {
        let (w, hw) = setup(2, 4);
        let map = presets::pipeline_parallel(2, w.layers_per_mb, 4);
        let r = run(&w, &hw, &map, &SimOptions::default());
        let sum: f64 = r.phase_energy.iter().map(|(_, e)| e).sum();
        assert!((sum - r.energy_pj).abs() / r.energy_pj < 1e-9);
    }
}

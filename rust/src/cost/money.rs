//! Monetary-cost model (paper §V-C, Gemini yield formula).
//!
//!   Y_c      = Y_unit ^ (A_c / A_unit)
//!   A_c      = A_MAC + A_SRAM + A_NoC + alpha * BW_NoP + A_others
//!   MC_c     = A_c / Y_c * COST_chip
//!   A_IO     = beta * BW_NoP + gamma * BW_DRAM
//!   MC_IO    = A_IO / Y_IO * COST_IO
//!   MC_pack  = (sum A_c + sum A_IO) * COST_pack
//!   MC_total = sum MC_c + sum MC_IO + MC_pack


use crate::arch::constants::*;
use crate::arch::HwConfig;

/// Monetary-cost report ($).
#[derive(Debug, Clone, Copy, Default)]
pub struct MoneyCost {
    pub chiplets: f64,
    pub io_dies: f64,
    pub package: f64,
    pub total: f64,
    /// Area of one compute chiplet (mm^2).
    pub chiplet_area_mm2: f64,
    /// Total silicon area (mm^2).
    pub silicon_area_mm2: f64,
}

/// Yield of a die of `area` mm^2 under the Gemini model.
pub fn yield_of(area: f64) -> f64 {
    Y_UNIT.powf(area / A_UNIT_MM2)
}

/// Evaluate the monetary cost of a hardware configuration.
pub fn monetary_cost(hw: &HwConfig) -> MoneyCost {
    let n = hw.num_chiplets() as f64;
    // all chiplets share the class; dataflow does not change area in the
    // template (same MACs, same GLB, different interconnect pattern)
    let a_c = hw.class.base_area_mm2() + A_NOP_MM2_PER_GBS * hw.nop_bw_gbs;
    let mc_c = a_c / yield_of(a_c) * COST_CHIP_PER_MM2;

    let n_io = NUM_DRAM_CHIPS as f64;
    let a_io = A_IO_NOP_MM2_PER_GBS * hw.nop_bw_gbs + A_IO_DRAM_MM2_PER_GBS * hw.dram_bw_gbs;
    let mc_io = a_io / Y_IO * COST_IO_PER_MM2;

    let silicon = n * a_c + n_io * a_io;
    let mc_pack = silicon * PACKAGE_AREA_FACTOR * COST_PACK_PER_MM2;

    MoneyCost {
        chiplets: n * mc_c,
        io_dies: n_io * mc_io,
        package: mc_pack,
        total: n * mc_c + n_io * mc_io + mc_pack,
        chiplet_area_mm2: a_c,
        silicon_area_mm2: silicon,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow, HwConfig};

    fn hw(class: ChipletClass, n: usize, nop: f64, dram: f64) -> HwConfig {
        let (h, w) = crate::arch::HwSpace::grid_dims(n);
        HwConfig::homogeneous(h, w, class, Dataflow::WeightStationary, nop, dram)
    }

    #[test]
    fn yield_decreases_with_area() {
        assert!(yield_of(10.0) > yield_of(100.0));
        assert!((yield_of(A_UNIT_MM2) - Y_UNIT).abs() < 1e-12);
        assert!(yield_of(1.0) < 1.0);
    }

    #[test]
    fn cost_components_positive_and_sum() {
        let mc = monetary_cost(&hw(ChipletClass::M, 8, 32.0, 16.0));
        assert!(mc.chiplets > 0.0 && mc.io_dies > 0.0 && mc.package > 0.0);
        assert!((mc.total - (mc.chiplets + mc.io_dies + mc.package)).abs() < 1e-9);
    }

    #[test]
    fn more_chiplets_cost_more() {
        let a = monetary_cost(&hw(ChipletClass::M, 8, 32.0, 16.0));
        let b = monetary_cost(&hw(ChipletClass::M, 16, 32.0, 16.0));
        assert!(b.total > a.total);
    }

    #[test]
    fn bandwidth_increases_cost() {
        let a = monetary_cost(&hw(ChipletClass::M, 8, 32.0, 16.0));
        let b = monetary_cost(&hw(ChipletClass::M, 8, 512.0, 256.0));
        assert!(b.total > a.total);
    }

    #[test]
    fn chiplet_yield_advantage_over_monolith() {
        // equal total MACs: 16 x M vs 4 x L; the big die pays a yield
        // penalty, one of the core economic motivations for chiplets
        let many_small = monetary_cost(&hw(ChipletClass::M, 16, 32.0, 16.0));
        let few_large = monetary_cost(&hw(ChipletClass::L, 4, 32.0, 16.0));
        let small_per_mm2 = many_small.chiplets / (16.0 * many_small.chiplet_area_mm2);
        let large_per_mm2 = few_large.chiplets / (4.0 * few_large.chiplet_area_mm2);
        assert!(
            large_per_mm2 > small_per_mm2,
            "large dies must cost more per mm^2 ({large_per_mm2} vs {small_per_mm2})"
        );
    }

    #[test]
    fn simba_like_config_cost_scale() {
        // Table V reference point: a Simba-like 64-TOPS configuration
        // should land in the low-thousands-of-dollars range.
        let mc = monetary_cost(&hw(ChipletClass::S, 31, 32.0, 16.0));
        assert!(
            mc.total > 1_000.0 && mc.total < 10_000.0,
            "got ${}",
            mc.total
        );
    }
}

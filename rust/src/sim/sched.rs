//! Deterministic discrete-event, iteration-level continuous-batching
//! scheduler (paper §II / Fig. 9, made dynamic).
//!
//! The simulator replays a [`RequestStream`] through one of the three
//! `ServingStrategy` policies:
//!
//! * **vLLM-style** — prefill priority: waiting prompts pause decodes
//!   and run as a standalone batch;
//! * **Orca-style** — iteration-level mixed batches: new prompts join
//!   the in-flight decode batch wholesale;
//! * **Sarathi-style chunked prefill** — each decode iteration carries
//!   at most `chunk_tokens` prompt tokens from the admission queue.
//!
//! All three share an admission queue, a KV-cache token budget derived
//! from the hardware's DRAM capacity (admission stalls when full;
//! youngest-first preemption with prefill recomputation under decode
//! pressure), and per-request lifecycle tracking (arrival → first token
//! → completion). The clock advances by each iteration's simulated
//! latency, costed through [`BatchCoster`]; when nothing is runnable it
//! jumps to the next arrival. Everything is pure `f64`/integer
//! arithmetic on a fixed event order, so a fixed stream produces
//! bit-identical metrics on every run.

use std::collections::VecDeque;

use crate::arch::constants::CLOCK_HZ;
use crate::arch::HwConfig;
use crate::workload::serving::ServingStrategy;
use crate::workload::{ModelSpec, Request};

use super::coster::BatchCoster;
use super::metrics::{finalize, IterRecord, RequestOutcome, ServingMetrics};
use super::stream::RequestStream;
use super::SimConfig;

/// Per-request lifecycle state.
#[derive(Debug, Clone, Copy)]
struct Live {
    arrival_s: f64,
    input_len: u64,
    output_len: u64,
    /// Context tokens the current admission must prefill (prompt plus
    /// any tokens generated before a preemption).
    prefill_target: u64,
    prefill_done: u64,
    generated: u64,
    /// KV-cache tokens currently held.
    kv_held: u64,
    first_token_s: Option<f64>,
    finish_s: Option<f64>,
    rejected: bool,
}

impl Live {
    /// An admitted request is decoding once its prefill is complete.
    fn decoding(&self) -> bool {
        self.finish_s.is_none() && self.prefill_done >= self.prefill_target
    }

    /// Context tokens a (re-)admission must cover.
    fn context_needed(&self) -> u64 {
        self.input_len + self.generated
    }
}

/// What a request does in one iteration batch.
#[derive(Debug, Clone, Copy)]
enum Role {
    /// Generate one token against the current context.
    Decode,
    /// Prefill `t` prompt tokens (the whole prompt for vLLM/Orca).
    Chunk(u64),
}

fn admit(r: &mut Live, idx: usize, running: &mut Vec<usize>) {
    r.prefill_target = r.context_needed();
    r.prefill_done = 0;
    running.push(idx);
}

fn preempt(r: &mut Live, kv_used: &mut u64) {
    *kv_used -= r.kv_held;
    r.kv_held = 0;
    r.prefill_done = 0;
}

/// Replay `stream` on `(model, hw)` under `cfg` and aggregate serving
/// metrics. Deterministic: identical inputs give bit-identical output.
pub fn simulate_serving(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
) -> ServingMetrics {
    let kv_budget = cfg.kv_budget(model).max(2);
    let mut coster = BatchCoster::new(model, hw, cfg.policy, cfg.eval_blocks, cfg.ctx_bucket);
    let n = stream.requests.len();
    let mut reqs: Vec<Live> = stream
        .requests
        .iter()
        .map(|r| Live {
            arrival_s: r.arrival_s,
            input_len: r.input_len.max(1),
            output_len: r.output_len.max(1),
            prefill_target: r.input_len.max(1),
            prefill_done: 0,
            generated: 0,
            kv_held: 0,
            first_token_s: None,
            finish_s: None,
            rejected: false,
        })
        .collect();

    let mut queue: VecDeque<usize> = VecDeque::new();
    let mut running: Vec<usize> = Vec::new(); // admission order: oldest first
    let mut kv_used = 0u64;
    let mut clock = 0.0f64;
    let mut next_arrival = 0usize;
    let mut iters: Vec<IterRecord> = Vec::new();
    let (mut done, mut rejected) = (0usize, 0usize);
    let mut preemptions = 0usize;
    let mut energy = 0.0f64;
    let mut ideal_cycles = 0.0f64;
    let mut gen_tokens = 0u64;
    let peak_macs_per_cycle = (hw.num_chiplets() as f64) * (hw.class.macs() as f64);

    while done + rejected < n {
        if iters.len() >= cfg.max_iterations {
            break; // safety valve; `ServingMetrics::truncated` is set
        }

        // --- arrivals up to the current clock ---
        while next_arrival < n && reqs[next_arrival].arrival_s <= clock + 1e-12 {
            let i = next_arrival;
            next_arrival += 1;
            if reqs[i].input_len + reqs[i].output_len + 1 > kv_budget {
                // can never fit, even alone: explicit rejection
                reqs[i].rejected = true;
                rejected += 1;
            } else {
                queue.push_back(i);
            }
        }

        // --- KV pressure: evict youngest (never the oldest) so the
        // in-flight decodes can write this iteration's tokens ---
        loop {
            let writes = running.iter().filter(|&&i| reqs[i].decoding()).count() as u64;
            if kv_used + writes <= kv_budget || running.len() <= 1 {
                break;
            }
            let victim = running.pop().unwrap();
            preempt(&mut reqs[victim], &mut kv_used);
            queue.push_front(victim);
            preemptions += 1;
        }

        // --- batch formation ---
        let decoding: Vec<usize> = running
            .iter()
            .copied()
            .filter(|&i| reqs[i].decoding())
            .collect();
        let mut batch: Vec<(usize, Role)> = Vec::new();
        let mut head = kv_budget - kv_used; // token headroom this iteration
        match cfg.strategy {
            ServingStrategy::Vllm => {
                while running.len() < cfg.max_batch {
                    let Some(&q) = queue.front() else { break };
                    let need = reqs[q].context_needed();
                    if need + 1 > head {
                        break;
                    }
                    queue.pop_front();
                    admit(&mut reqs[q], q, &mut running);
                    head -= need;
                    batch.push((q, Role::Chunk(need)));
                }
                if batch.is_empty() {
                    batch.extend(decoding.iter().map(|&i| (i, Role::Decode)));
                }
            }
            ServingStrategy::Orca => {
                batch.extend(decoding.iter().map(|&i| (i, Role::Decode)));
                head = head.saturating_sub(decoding.len() as u64);
                while running.len() < cfg.max_batch {
                    let Some(&q) = queue.front() else { break };
                    let need = reqs[q].context_needed();
                    if need + 1 > head {
                        break;
                    }
                    queue.pop_front();
                    admit(&mut reqs[q], q, &mut running);
                    head -= need;
                    batch.push((q, Role::Chunk(need)));
                }
            }
            ServingStrategy::ChunkedPrefill => {
                batch.extend(decoding.iter().map(|&i| (i, Role::Decode)));
                head = head.saturating_sub(decoding.len() as u64);
                let mut budget = cfg.chunk_tokens.max(1);
                // continue in-flight prefills first, admission order
                let prefilling: Vec<usize> = running
                    .iter()
                    .copied()
                    .filter(|&i| !reqs[i].decoding())
                    .collect();
                for i in prefilling {
                    if budget == 0 || head == 0 {
                        break;
                    }
                    let rem = reqs[i].prefill_target - reqs[i].prefill_done;
                    let t = rem.min(budget).min(head);
                    if t > 0 {
                        budget -= t;
                        head -= t;
                        batch.push((i, Role::Chunk(t)));
                    }
                }
                // then admit new prompts; reserve their full context so
                // later chunks are guaranteed to fit
                while budget > 0 && running.len() < cfg.max_batch {
                    let Some(&q) = queue.front() else { break };
                    let need = reqs[q].context_needed();
                    if need + 1 > head {
                        break;
                    }
                    queue.pop_front();
                    admit(&mut reqs[q], q, &mut running);
                    head -= need;
                    let t = need.min(budget);
                    budget -= t;
                    batch.push((q, Role::Chunk(t)));
                }
            }
        }

        if batch.is_empty() {
            // KV-blocked prefills with no runnable decode: free the
            // youngest and retry (the oldest always keeps its cache, so
            // the system is guaranteed to make progress)
            if running.len() > 1 {
                let victim = running.pop().unwrap();
                preempt(&mut reqs[victim], &mut kv_used);
                queue.push_front(victim);
                preemptions += 1;
                continue;
            }
            if next_arrival < n {
                // idle: jump to the next arrival
                clock = clock.max(reqs[next_arrival].arrival_s);
                continue;
            }
            break; // defensive: no work left that can run
        }

        // --- cost the composed batch ---
        let mut cost_batch: Vec<Request> = Vec::with_capacity(batch.len());
        let mut n_prefill = 0usize;
        let mut prefill_tokens = 0u64;
        for &(i, role) in &batch {
            match role {
                Role::Decode => {
                    cost_batch.push(Request::decode(reqs[i].context_needed()));
                }
                Role::Chunk(t) => {
                    n_prefill += 1;
                    prefill_tokens += t;
                    cost_batch.push(Request::Prefill {
                        len: t,
                        past: reqs[i].prefill_done,
                    });
                }
            }
        }
        let n_decode = batch.len() - n_prefill;
        let c = coster.cost(&cost_batch);
        let dt = c.latency_cycles / CLOCK_HZ;
        let end = clock + dt;
        energy += c.energy_pj;
        ideal_cycles += c.macs as f64 / peak_macs_per_cycle;

        // --- apply iteration effects at its completion time ---
        let mut freed: Vec<usize> = Vec::new();
        for &(i, role) in &batch {
            let r = &mut reqs[i];
            match role {
                Role::Decode => {
                    r.generated += 1;
                    r.kv_held += 1;
                    kv_used += 1;
                    gen_tokens += 1;
                    if r.generated >= r.output_len {
                        r.finish_s = Some(end);
                        done += 1;
                        kv_used -= r.kv_held;
                        r.kv_held = 0;
                        freed.push(i);
                    }
                }
                Role::Chunk(t) => {
                    r.prefill_done += t;
                    r.kv_held += t;
                    kv_used += t;
                    if r.prefill_done >= r.prefill_target && r.first_token_s.is_none() {
                        // prefill completion emits the first output token
                        r.first_token_s = Some(end);
                        r.generated += 1;
                        gen_tokens += 1;
                        if r.generated >= r.output_len {
                            r.finish_s = Some(end);
                            done += 1;
                            kv_used -= r.kv_held;
                            r.kv_held = 0;
                            freed.push(i);
                        }
                    }
                }
            }
        }
        if !freed.is_empty() {
            running.retain(|i| !freed.contains(i));
        }
        iters.push(IterRecord {
            start_s: clock,
            end_s: end,
            n_decode,
            n_prefill,
            prefill_tokens,
            queue_depth: queue.len(),
            kv_frac: kv_used as f64 / kv_budget as f64,
        });
        clock = end;
    }

    let outcomes: Vec<RequestOutcome> = reqs
        .iter()
        .map(|r| RequestOutcome {
            arrival_s: r.arrival_s,
            output_len: r.output_len,
            first_token_s: r.first_token_s,
            finish_s: r.finish_s,
            rejected: r.rejected,
        })
        .collect();
    finalize(
        &outcomes,
        iters,
        &cfg.slo,
        cfg.max_batch,
        clock,
        energy,
        ideal_cycles,
        gen_tokens,
        preemptions,
        coster.distinct_shapes(),
        done + rejected < n,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::sim::coster::MappingPolicy;
    use crate::sim::metrics::SloSpec;
    use crate::workload::trace::TraceSpec;

    fn tiny_spec() -> TraceSpec {
        TraceSpec {
            mean_in: 48.0,
            mean_out: 8.0,
            sigma_in: 0.4,
            sigma_out: 0.3,
            max_len: 4096,
        }
    }

    fn tiny_hw() -> HwConfig {
        HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        )
    }

    fn tiny_cfg(strategy: ServingStrategy) -> SimConfig {
        SimConfig {
            strategy,
            policy: MappingPolicy::Pipeline,
            max_batch: 8,
            chunk_tokens: 32,
            kv_budget_tokens: 4096,
            dram_gb: 1.0,
            ctx_bucket: 32,
            eval_blocks: 1,
            slo: SloSpec::new(1.0, 0.5),
            max_iterations: 200_000,
        }
    }

    fn run(strategy: ServingStrategy, rate_scale: f64, kv_tokens: u64) -> ServingMetrics {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(strategy);
        cfg.kv_budget_tokens = kv_tokens;
        let probe = crate::sim::probe(&model, &hw, &cfg, &tiny_spec());
        let stream = RequestStream::poisson(
            &tiny_spec(),
            probe.capacity_rps() * rate_scale,
            12,
            5,
        );
        simulate_serving(&stream, &model, &hw, &cfg)
    }

    #[test]
    fn all_strategies_complete_all_requests() {
        for strategy in ServingStrategy::ALL {
            let m = run(strategy, 0.8, 4096);
            assert_eq!(m.n_completed + m.n_rejected, m.n_arrived, "{strategy:?}");
            assert_eq!(m.n_rejected, 0, "{strategy:?}");
            assert!(m.throughput_tps > 0.0);
            assert!(m.ttft.n == m.n_completed);
        }
    }

    #[test]
    fn vllm_never_mixes_prefill_and_decode() {
        let m = run(ServingStrategy::Vllm, 1.2, 4096);
        for it in &m.iters {
            assert!(
                it.n_prefill == 0 || it.n_decode == 0,
                "mixed batch at t={}",
                it.start_s
            );
        }
    }

    #[test]
    fn orca_and_chunked_do_mix() {
        for strategy in [ServingStrategy::Orca, ServingStrategy::ChunkedPrefill] {
            let m = run(strategy, 1.2, 4096);
            assert!(
                m.iters.iter().any(|it| it.n_prefill > 0 && it.n_decode > 0),
                "{strategy:?} never mixed"
            );
        }
    }

    #[test]
    fn chunked_respects_chunk_budget() {
        let m = run(ServingStrategy::ChunkedPrefill, 1.0, 4096);
        for it in &m.iters {
            assert!(it.prefill_tokens <= 32, "chunk {}", it.prefill_tokens);
        }
    }

    #[test]
    fn tight_kv_budget_rejects_or_preempts_but_conserves() {
        let m = run(ServingStrategy::Orca, 1.0, 150);
        assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
        // tight budget must visibly constrain the run
        assert!(m.n_rejected > 0 || m.n_preemptions > 0 || m.max_queue_depth > 0);
        for it in &m.iters {
            assert!(it.kv_frac <= 1.0 + 1e-9, "kv over budget: {}", it.kv_frac);
        }
    }

    #[test]
    fn clock_is_monotone_and_iters_ordered() {
        let m = run(ServingStrategy::ChunkedPrefill, 1.3, 1024);
        for it in &m.iters {
            assert!(it.end_s >= it.start_s);
        }
        for w in m.iters.windows(2) {
            assert!(w[1].start_s >= w[0].start_s - 1e-12);
        }
        assert!(m.makespan_s >= m.iters.last().map_or(0.0, |i| i.end_s) - 1e-12);
    }
}

//! Bench T6: paper Table VI — the optimal hardware configurations found
//! by Compass per scenario (reduced matrix; `repro compare --scenes all`
//! for all 12). Also times a single BO round's surrogate update.
use compass::bo::{featurize, Gp, Hyper};
use compass::dse::DseConfig;
use compass::experiments as exp;
use compass::runtime::Runtime;
use compass::util::Bench;

fn main() {
    let mut cfg = DseConfig::reduced();
    cfg.bo.rounds = 12;
    cfg.bo.init = 5;
    let rt = Runtime::from_env().ok();
    let scenes = exp::Scene::reduced_matrix();
    let rows = exp::fig7_compare(&scenes[..2], &cfg, rt.as_ref(), 7);
    exp::table6(&rows).print();

    // surrogate-update microbenchmarks (fit + EI batch), both backends
    let mut rng = compass::util::Rng::seed_from_u64(3);
    let space = compass::arch::HwSpace::paper(64.0);
    let xs: Vec<_> = (0..32)
        .map(|_| featurize(&compass::bo::sa::random_config(&space, &mut rng)))
        .collect();
    let ys: Vec<f32> = (0..32).map(|i| (i as f32 * 0.37).sin()).collect();
    let mut native = compass::bo::NativeGp::new();
    Bench::new("gp_fit/native-32obs").run(|| native.fit(&xs, &ys, Hyper::default()).unwrap());
    native.fit(&xs, &ys, Hyper::default()).unwrap();
    Bench::new("gp_ei/native-32cand").run(|| native.ei(&xs, 0.0).unwrap());
    if let Some(rt) = rt.as_ref() {
        if rt.artifacts_available() {
            let mut pjrt = compass::bo::PjrtGp::new(rt);
            Bench::new("gp_fit/pjrt-32obs").run(|| pjrt.fit(&xs, &ys, Hyper::default()).unwrap());
            pjrt.fit(&xs, &ys, Hyper::default()).unwrap();
            Bench::new("gp_ei/pjrt-32cand").run(|| pjrt.ei(&xs, 0.0).unwrap());
        }
    }
}

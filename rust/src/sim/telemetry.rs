//! Deterministic telemetry for the serving stack: sim-time request
//! lifecycle events, per-iteration spans, a counter/gauge registry,
//! Chrome-trace/JSONL exporters, and (separately) wall-clock profiling
//! scopes for the simulator's own hot paths.
//!
//! Two clocks, never mixed:
//!
//! * **Sim time** — everything recorded through [`TraceSink`] carries
//!   the simulator's deterministic `f64` clock. Recording never feeds
//!   back into the simulation: every instrumentation site either holds
//!   no sink (`None` — the default, genuinely zero work) or appends to
//!   a [`SpanCollector`] after the arithmetic of the step is done, so
//!   metrics are bitwise-identical with telemetry on or off (anchored
//!   in `rust/tests/telemetry_properties.rs`). Decode fast-forward
//!   (`sched::Scheduler::try_fast_forward`) preserves this byte-for-
//!   byte: a coalesced stretch replays the exact per-iteration span
//!   and lifecycle-event sequence of the naive loop at the same sim
//!   instants, so trace files are identical with `COMPASS_COALESCE`
//!   on or off (anchored in `rust/tests/coalesce_equivalence.rs`).
//! * **Wall clock** — [`profile`] scopes measure where the *simulator
//!   process* spends real time (`std::time::Instant`), for the
//!   ROADMAP's raw-speed work. Wall-clock numbers are nondeterministic
//!   by nature and never enter any sim-time record.
//!
//! The event taxonomy covers the full request lifecycle: offer →
//! admit/reject/shed → prefill chunks → first token → decode →
//! preempt/recompute → KV migration → crash-fail/backoff/loss →
//! finish. [`SpanCollector::waterfall`] folds the raw events into
//! per-request phase spans (queue / prefill / decode / backoff /
//! migrate) that tile the request's lifetime, so the sum of a
//! request's span durations reproduces its stitched outcome latency —
//! the consistency gate `examples/telemetry.rs` asserts.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

/// A shared handle to a trace sink, cloned into every instrumented
/// layer of one run. A `Mutex` (uncontended in the common case) rather
/// than a `RefCell` so `Scheduler` stays `Send` and independent
/// replicas can step on scoped worker threads; determinism still
/// depends on a single sequential event order, which the parallel
/// stepping path re-establishes by buffering per-replica events and
/// replaying them in replica index order (see [`BufferSink`]).
pub type SharedSink = Arc<Mutex<dyn TraceSink + Send>>;

/// What happened to a request (sim time, deterministic).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum EventKind {
    /// Entered a replica's admission queue.
    Offer,
    /// Entered a decode replica's queue as migrated, prefilled context.
    MigrateIn,
    /// Rejected at arrival (can never fit the KV capacity).
    Reject,
    /// Shed by the front-end admission policy (final: no retry left).
    Shed,
    /// Admitted: KV leased (or materialized, for migrated requests).
    Admit,
    /// One prefill chunk of `tokens` scheduled this iteration.
    Chunk { tokens: u64 },
    /// Prefill crossed its target (re-admissions cross again).
    PrefillDone,
    /// First output token emitted (once per request).
    FirstToken,
    /// Preempted under KV pressure: re-queued, prefill recomputed.
    Preempt,
    /// Extracted for a KV migration (rebalance, drain, disaggregated
    /// handoff): in flight on the link until `MigrateIn`.
    MigrateOut,
    /// The attempt died (crash, no healthy replica): retry backoff
    /// starts if the budget allows.
    Fail,
    /// Permanently lost (retry budget exhausted).
    Loss,
    /// Completed. Disaggregated requests finish twice: once per stage.
    Finish,
}

impl EventKind {
    pub fn label(&self) -> &'static str {
        match self {
            EventKind::Offer => "offer",
            EventKind::MigrateIn => "migrate_in",
            EventKind::Reject => "reject",
            EventKind::Shed => "shed",
            EventKind::Admit => "admit",
            EventKind::Chunk { .. } => "chunk",
            EventKind::PrefillDone => "prefill_done",
            EventKind::FirstToken => "first_token",
            EventKind::Preempt => "preempt",
            EventKind::MigrateOut => "migrate_out",
            EventKind::Fail => "fail",
            EventKind::Loss => "loss",
            EventKind::Finish => "finish",
        }
    }
}

/// One recorded request event.
#[derive(Debug, Clone, Copy)]
pub struct Event {
    /// Global insertion order — the tiebreak that keeps replays stable
    /// when several events share one timestamp.
    pub seq: usize,
    pub replica: usize,
    pub t_s: f64,
    /// The run-wide external request id (stream id).
    pub ext_id: usize,
    pub kind: EventKind,
}

/// A replica-level moment (crash, drain, straggler window, link
/// change) — not tied to one request.
#[derive(Debug, Clone, Copy)]
pub struct InstantEvent {
    pub seq: usize,
    pub replica: usize,
    pub t_s: f64,
    pub label: &'static str,
}

/// One scheduler iteration, with the occupancy gauges sampled at its
/// close (the sink-side superset of `metrics::IterRecord`, kept
/// unbounded here — the collector exists to be exhaustive).
#[derive(Debug, Clone, Copy)]
pub struct IterSpan {
    pub replica: usize,
    pub start_s: f64,
    pub end_s: f64,
    pub n_prefill: usize,
    pub n_decode: usize,
    pub queue_depth: usize,
    pub kv_frac: f64,
    pub kv_frag: f64,
}

/// Where instrumented layers report. The simulator holds an
/// `Option<SharedSink>` that is `None` by default, so the disabled
/// path does no work at all; [`NullSink`] exists so generic callers
/// can still pass "a sink" and get the identical nothing.
pub trait TraceSink {
    /// Whether this sink records anything. `Scheduler::set_sink`
    /// drops sinks that report `false`, so a `NullSink` costs exactly
    /// as much as no sink.
    fn enabled(&self) -> bool;
    fn event(&mut self, replica: usize, t_s: f64, ext_id: usize, kind: EventKind);
    fn instant(&mut self, replica: usize, t_s: f64, label: &'static str);
    fn iter(&mut self, span: IterSpan);
    /// Overwrite a named counter (last writer wins — the right
    /// semantics for monotone totals like shared-memo stats, where the
    /// final writer has seen everything).
    fn counter_set(&mut self, name: &str, value: f64);
    fn counter_add(&mut self, name: &str, delta: f64);
}

/// The zero-overhead sink: records nothing, reports disabled.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {
    fn enabled(&self) -> bool {
        false
    }
    fn event(&mut self, _: usize, _: f64, _: usize, _: EventKind) {}
    fn instant(&mut self, _: usize, _: f64, _: &'static str) {}
    fn iter(&mut self, _: IterSpan) {}
    fn counter_set(&mut self, _: &str, _: f64) {}
    fn counter_add(&mut self, _: &str, _: f64) {}
}

/// One buffered sink operation, replayed verbatim by [`BufferSink::replay`].
#[derive(Debug, Clone)]
enum SinkOp {
    Event(usize, f64, usize, EventKind),
    Instant(usize, f64, &'static str),
    Iter(IterSpan),
    CounterSet(String, f64),
    CounterAdd(String, f64),
}

/// A per-replica staging sink for parallel stepping: while replicas
/// advance on worker threads, each records into its own `BufferSink`;
/// after the join, buffers are replayed into the real sink in replica
/// index order — exactly the order the serial loop (replica 0 fully
/// advanced, then replica 1, …) would have emitted, so sequence
/// stamping and every downstream artifact are bitwise identical.
#[derive(Debug, Default)]
pub struct BufferSink {
    ops: Vec<SinkOp>,
}

impl BufferSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drain the buffered operations into `sink`, preserving order.
    pub fn replay(&mut self, sink: &mut dyn TraceSink) {
        for op in self.ops.drain(..) {
            match op {
                SinkOp::Event(replica, t_s, ext_id, kind) => sink.event(replica, t_s, ext_id, kind),
                SinkOp::Instant(replica, t_s, label) => sink.instant(replica, t_s, label),
                SinkOp::Iter(span) => sink.iter(span),
                SinkOp::CounterSet(name, value) => sink.counter_set(&name, value),
                SinkOp::CounterAdd(name, delta) => sink.counter_add(&name, delta),
            }
        }
    }
}

impl TraceSink for BufferSink {
    fn enabled(&self) -> bool {
        true
    }
    fn event(&mut self, replica: usize, t_s: f64, ext_id: usize, kind: EventKind) {
        self.ops.push(SinkOp::Event(replica, t_s, ext_id, kind));
    }
    fn instant(&mut self, replica: usize, t_s: f64, label: &'static str) {
        self.ops.push(SinkOp::Instant(replica, t_s, label));
    }
    fn iter(&mut self, span: IterSpan) {
        self.ops.push(SinkOp::Iter(span));
    }
    fn counter_set(&mut self, name: &str, value: f64) {
        self.ops.push(SinkOp::CounterSet(name.to_string(), value));
    }
    fn counter_add(&mut self, name: &str, delta: f64) {
        self.ops.push(SinkOp::CounterAdd(name.to_string(), delta));
    }
}

/// The recording sink: raw events, instants, iteration spans and the
/// counter registry, in insertion order.
#[derive(Debug, Clone, Default)]
pub struct SpanCollector {
    events: Vec<Event>,
    instants: Vec<InstantEvent>,
    iters: Vec<IterSpan>,
    counters: BTreeMap<String, f64>,
    seq: usize,
}

impl SpanCollector {
    pub fn new() -> Self {
        Self::default()
    }

    /// Wrap a fresh collector as a [`SharedSink`] handle.
    pub fn shared() -> Arc<Mutex<SpanCollector>> {
        Arc::new(Mutex::new(SpanCollector::new()))
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    pub fn instants(&self) -> &[InstantEvent] {
        &self.instants
    }

    pub fn iters(&self) -> &[IterSpan] {
        &self.iters
    }

    pub fn counters(&self) -> &BTreeMap<String, f64> {
        &self.counters
    }

    /// Distinct requests with at least one `Finish` event. A
    /// disaggregated request finishes once per stage but still counts
    /// once here.
    pub fn n_finished(&self) -> usize {
        let mut ids: Vec<usize> = self
            .events
            .iter()
            .filter(|e| e.kind == EventKind::Finish)
            .map(|e| e.ext_id)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        ids.len()
    }

    /// Events of one request, ordered by `(t_s, seq)`.
    fn lane_events(&self) -> BTreeMap<usize, Vec<Event>> {
        let mut lanes: BTreeMap<usize, Vec<Event>> = BTreeMap::new();
        for e in &self.events {
            lanes.entry(e.ext_id).or_default().push(*e);
        }
        for evs in lanes.values_mut() {
            evs.sort_by(|a, b| a.t_s.total_cmp(&b.t_s).then(a.seq.cmp(&b.seq)));
        }
        lanes
    }

    /// Fold the raw events into per-request phase spans. See
    /// [`RequestLane`] for the tiling invariants.
    pub fn waterfall(&self) -> Vec<RequestLane> {
        self.lane_events()
            .into_iter()
            .map(|(ext_id, evs)| build_lane(ext_id, &evs))
            .collect()
    }

    /// Render the waterfall as fixed-width ASCII lanes (`.` queue,
    /// `#` prefill, `=` decode, `x` backoff, `~` migrating), at most
    /// `max_lanes` requests, `width` time columns.
    pub fn ascii_waterfall(&self, width: usize, max_lanes: usize) -> String {
        let lanes = self.waterfall();
        let width = width.max(8);
        let t_max = lanes
            .iter()
            .map(|l| l.last_close_s)
            .fold(0.0f64, f64::max)
            .max(1e-12);
        let mut out = String::new();
        out.push_str(&format!(
            "waterfall  0 .. {:.4}s   [.] queue [#] prefill [=] decode [x] backoff [~] migrate\n",
            t_max
        ));
        for lane in lanes.iter().take(max_lanes) {
            let mut row = vec![' '; width];
            for sp in &lane.spans {
                let a = ((sp.start_s / t_max) * width as f64).floor() as usize;
                let b = ((sp.end_s / t_max) * width as f64).ceil() as usize;
                let ch = match sp.kind {
                    SpanKind::Queue => '.',
                    SpanKind::Prefill => '#',
                    SpanKind::Decode => '=',
                    SpanKind::Backoff => 'x',
                    SpanKind::MigrateLink => '~',
                };
                for cell in row.iter_mut().take(b.min(width)).skip(a.min(width)) {
                    *cell = ch;
                }
            }
            let tag = if lane.rejected {
                "rej "
            } else if lane.lost {
                "lost"
            } else if lane.finished {
                "done"
            } else {
                "    "
            };
            out.push_str(&format!(
                "req {:>4} {tag} |{}|\n",
                lane.ext_id,
                row.into_iter().collect::<String>()
            ));
        }
        if lanes.len() > max_lanes {
            out.push_str(&format!("... {} more requests\n", lanes.len() - max_lanes));
        }
        out
    }

    /// Export everything as Chrome trace-event JSON (Perfetto-loadable:
    /// `ui.perfetto.dev`, or `chrome://tracing`). One `pid` per
    /// replica; `tid 0` is the replica's iteration track, request
    /// lanes use `tid = ext_id + 1`. Timestamps are sim-time
    /// microseconds formatted with fixed precision, so the same run
    /// always serializes to the identical byte string.
    pub fn chrome_trace_json(&self) -> String {
        let us = |t: f64| format!("{:.3}", t * 1e6);
        let mut ev: Vec<String> = Vec::new();
        let mut replicas: Vec<usize> = self
            .events
            .iter()
            .map(|e| e.replica)
            .chain(self.iters.iter().map(|i| i.replica))
            .chain(self.instants.iter().map(|i| i.replica))
            .collect();
        replicas.sort_unstable();
        replicas.dedup();
        for &r in &replicas {
            ev.push(format!(
                "{{\"name\":\"process_name\",\"ph\":\"M\",\"pid\":{r},\"tid\":0,\
                 \"args\":{{\"name\":\"replica {r}\"}}}}"
            ));
        }
        for lane in self.waterfall() {
            for sp in &lane.spans {
                let (name, cname) = match sp.kind {
                    SpanKind::Queue => ("queue", "grey"),
                    SpanKind::Prefill => ("prefill", "thread_state_running"),
                    SpanKind::Decode => ("decode", "good"),
                    SpanKind::Backoff => ("backoff", "terrible"),
                    SpanKind::MigrateLink => ("migrate", "yellow"),
                };
                ev.push(format!(
                    "{{\"name\":\"{name}\",\"cat\":\"request\",\"ph\":\"X\",\
                     \"pid\":{},\"tid\":{},\"ts\":{},\"dur\":{},\
                     \"cname\":\"{cname}\",\"args\":{{\"req\":{}}}}}",
                    sp.replica,
                    lane.ext_id + 1,
                    us(sp.start_s),
                    us((sp.end_s - sp.start_s).max(0.0)),
                    lane.ext_id
                ));
            }
        }
        for it in &self.iters {
            ev.push(format!(
                "{{\"name\":\"iter\",\"cat\":\"sched\",\"ph\":\"X\",\
                 \"pid\":{},\"tid\":0,\"ts\":{},\"dur\":{},\"args\":{{\
                 \"prefill\":{},\"decode\":{},\"queue\":{}}}}}",
                it.replica,
                us(it.start_s),
                us((it.end_s - it.start_s).max(0.0)),
                it.n_prefill,
                it.n_decode,
                it.queue_depth
            ));
            ev.push(format!(
                "{{\"name\":\"kv\",\"cat\":\"sched\",\"ph\":\"C\",\
                 \"pid\":{},\"tid\":0,\"ts\":{},\"args\":{{\
                 \"frac\":{:.6},\"frag\":{:.6}}}}}",
                it.replica,
                us(it.end_s),
                it.kv_frac,
                it.kv_frag
            ));
        }
        for i in &self.instants {
            ev.push(format!(
                "{{\"name\":\"{}\",\"cat\":\"fault\",\"ph\":\"i\",\
                 \"pid\":{},\"tid\":0,\"ts\":{},\"s\":\"p\"}}",
                json_escape(i.label),
                i.replica,
                us(i.t_s)
            ));
        }
        // self-contained summary so external validators (the CI JSON
        // check) need no side-channel: finished-request count plus the
        // whole counter registry
        let t_last = self
            .events
            .iter()
            .map(|e| e.t_s)
            .fold(0.0f64, f64::max);
        let counters: Vec<String> = self
            .counters
            .iter()
            .map(|(k, v)| format!("\"{}\":{:.6}", json_escape(k), v))
            .collect();
        ev.push(format!(
            "{{\"name\":\"run_summary\",\"ph\":\"i\",\"pid\":0,\"tid\":0,\
             \"ts\":{},\"s\":\"g\",\"args\":{{\"finished\":{},\"events\":{},\
             \"counters\":{{{}}}}}}}",
            us(t_last),
            self.n_finished(),
            self.events.len(),
            counters.join(",")
        ));
        format!(
            "{{\"traceEvents\":[{}],\"displayTimeUnit\":\"ms\"}}\n",
            ev.join(",\n")
        )
    }
}

impl TraceSink for SpanCollector {
    fn enabled(&self) -> bool {
        true
    }

    fn event(&mut self, replica: usize, t_s: f64, ext_id: usize, kind: EventKind) {
        let seq = self.seq;
        self.seq += 1;
        self.events.push(Event {
            seq,
            replica,
            t_s,
            ext_id,
            kind,
        });
    }

    fn instant(&mut self, replica: usize, t_s: f64, label: &'static str) {
        let seq = self.seq;
        self.seq += 1;
        self.instants.push(InstantEvent {
            seq,
            replica,
            t_s,
            label,
        });
    }

    fn iter(&mut self, span: IterSpan) {
        self.iters.push(span);
    }

    fn counter_set(&mut self, name: &str, value: f64) {
        self.counters.insert(name.to_string(), value);
    }

    fn counter_add(&mut self, name: &str, delta: f64) {
        *self.counters.entry(name.to_string()).or_insert(0.0) += delta;
    }
}

/// A request's phase while time passes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SpanKind {
    /// In an admission queue (offered, not yet admitted).
    Queue,
    /// Admitted, prefilling (chunks in flight or scheduled).
    Prefill,
    /// First token emitted, generating output.
    Decode,
    /// Between a failure and the retry re-offer.
    Backoff,
    /// KV in flight over a migration/handoff link.
    MigrateLink,
}

/// One contiguous phase span of a request.
#[derive(Debug, Clone, Copy)]
pub struct Span {
    pub ext_id: usize,
    /// Replica of the event that opened the span (the link "replica"
    /// for `MigrateLink` is the source).
    pub replica: usize,
    pub kind: SpanKind,
    pub start_s: f64,
    pub end_s: f64,
}

/// All spans of one request, tiling `[first_open_s, last_close_s]`
/// contiguously: every span starts exactly where the previous one
/// closed, so the durations sum to the lane's total latency. Crash
/// timestamps can run *behind* a replica's overshooting iteration
/// clock (iteration atomicity); the builder clamps closes to the
/// running cursor, which redistributes the overlap but never breaks
/// the tiling or produces a negative span.
#[derive(Debug, Clone)]
pub struct RequestLane {
    pub ext_id: usize,
    pub spans: Vec<Span>,
    pub finished: bool,
    pub rejected: bool,
    pub lost: bool,
    pub shed: bool,
    pub n_failures: usize,
    pub first_open_s: f64,
    pub last_close_s: f64,
}

impl RequestLane {
    /// Sum of span durations — equals `last_close_s - first_open_s` up
    /// to float association error, and (for completed requests)
    /// reproduces the stitched outcome's `finish - arrival` latency.
    pub fn total_s(&self) -> f64 {
        self.spans.iter().map(|s| s.end_s - s.start_s).sum()
    }
}

/// Cursor-contiguous span construction (see [`RequestLane`]).
fn build_lane(ext_id: usize, evs: &[Event]) -> RequestLane {
    let mut lane = RequestLane {
        ext_id,
        spans: Vec::new(),
        finished: false,
        rejected: false,
        lost: false,
        shed: false,
        n_failures: 0,
        first_open_s: evs.first().map_or(0.0, |e| e.t_s),
        last_close_s: evs.first().map_or(0.0, |e| e.t_s),
    };
    let mut cursor = lane.first_open_s;
    let mut open: Option<(SpanKind, usize, f64)> = None;
    let mut close = |open: &mut Option<(SpanKind, usize, f64)>, cursor: &mut f64, t: f64| {
        let t = t.max(*cursor);
        if let Some((kind, replica, start)) = open.take() {
            lane.spans.push(Span {
                ext_id,
                replica,
                kind,
                start_s: start,
                end_s: t,
            });
        }
        *cursor = t;
    };
    for e in evs {
        match e.kind {
            EventKind::Offer | EventKind::MigrateIn => {
                close(&mut open, &mut cursor, e.t_s);
                open = Some((SpanKind::Queue, e.replica, cursor));
            }
            EventKind::Admit => {
                close(&mut open, &mut cursor, e.t_s);
                open = Some((SpanKind::Prefill, e.replica, cursor));
            }
            EventKind::PrefillDone => {
                close(&mut open, &mut cursor, e.t_s);
                open = Some((SpanKind::Decode, e.replica, cursor));
            }
            EventKind::Preempt => {
                close(&mut open, &mut cursor, e.t_s);
                open = Some((SpanKind::Queue, e.replica, cursor));
            }
            EventKind::MigrateOut => {
                close(&mut open, &mut cursor, e.t_s);
                open = Some((SpanKind::MigrateLink, e.replica, cursor));
            }
            EventKind::Fail => {
                lane.n_failures += 1;
                close(&mut open, &mut cursor, e.t_s);
                open = Some((SpanKind::Backoff, e.replica, cursor));
            }
            EventKind::Finish => {
                lane.finished = true;
                close(&mut open, &mut cursor, e.t_s);
            }
            EventKind::Reject => {
                lane.rejected = true;
                close(&mut open, &mut cursor, e.t_s);
            }
            EventKind::Shed => {
                lane.shed = true;
                lane.rejected = true;
                close(&mut open, &mut cursor, e.t_s);
            }
            EventKind::Loss => {
                lane.lost = true;
                lane.rejected = true;
                close(&mut open, &mut cursor, e.t_s);
            }
            EventKind::Chunk { .. } | EventKind::FirstToken => {}
        }
    }
    // a truncated run can leave a span open; close it at the cursor so
    // the tiling invariant survives
    close(&mut open, &mut cursor, cursor);
    lane.last_close_s = cursor;
    lane
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// One structured run record (one study cell), exported as a JSONL
/// line under `--record`. `degraded` marks cells produced after the
/// CLI substituted a fallback for an invalid input (the old silent
/// paths now either exit non-zero or set this flag).
#[derive(Debug, Clone)]
pub struct RunRecord {
    pub study: String,
    pub cell: String,
    pub rate_rps: f64,
    pub n_arrived: usize,
    pub n_completed: usize,
    pub n_rejected: usize,
    pub slo_attainment: f64,
    pub slo_goodput_tps: f64,
    pub throughput_tps: f64,
    pub ttft_p99_s: f64,
    pub tpot_p99_s: f64,
    pub makespan_s: f64,
    pub energy_pj: f64,
    pub truncated: bool,
    pub degraded: bool,
}

impl RunRecord {
    pub fn to_json(&self) -> String {
        format!(
            "{{\"study\":\"{}\",\"cell\":\"{}\",\"rate_rps\":{:.6},\
             \"n_arrived\":{},\"n_completed\":{},\"n_rejected\":{},\
             \"slo_attainment\":{:.6},\"slo_goodput_tps\":{:.6},\
             \"throughput_tps\":{:.6},\"ttft_p99_s\":{:.6},\
             \"tpot_p99_s\":{:.6},\"makespan_s\":{:.6},\"energy_pj\":{:.6e},\
             \"truncated\":{},\"degraded\":{}}}",
            json_escape(&self.study),
            json_escape(&self.cell),
            self.rate_rps,
            self.n_arrived,
            self.n_completed,
            self.n_rejected,
            self.slo_attainment,
            self.slo_goodput_tps,
            self.throughput_tps,
            self.ttft_p99_s,
            self.tpot_p99_s,
            self.makespan_s,
            self.energy_pj,
            self.truncated,
            self.degraded
        )
    }
}

/// Write run records as one JSON object per line.
pub fn write_jsonl<P: AsRef<std::path::Path>>(
    path: P,
    records: &[RunRecord],
) -> std::io::Result<()> {
    use std::io::Write;
    let mut f = std::fs::File::create(path)?;
    for r in records {
        writeln!(f, "{}", r.to_json())?;
    }
    Ok(())
}

/// Wall-clock profiling scopes (process time, nondeterministic —
/// strictly separated from the sim-time telemetry above). Disabled by
/// default: [`scope`] returns `None` after one thread-local flag read,
/// so instrumented hot paths cost nothing in normal runs. Enabled
/// under `repro --profile`, the guards accumulate per-label call
/// counts, total and *self* time (children subtracted), and
/// [`take_report`] prints the table the ROADMAP's raw-speed item
/// starts from.
pub mod profile {
    use std::cell::{Cell, RefCell};
    use std::time::Instant;

    #[derive(Debug, Clone, Copy)]
    struct Frame {
        label: &'static str,
        start: Instant,
        child_s: f64,
    }

    #[derive(Debug, Clone, Copy, Default)]
    struct Tally {
        calls: u64,
        total_s: f64,
        self_s: f64,
    }

    thread_local! {
        static ENABLED: Cell<bool> = const { Cell::new(false) };
        static STACK: RefCell<Vec<Frame>> = const { RefCell::new(Vec::new()) };
        static TOTALS: RefCell<Vec<(&'static str, Tally)>> =
            const { RefCell::new(Vec::new()) };
    }

    /// Turn profiling on/off for this thread (the sim is per-thread).
    pub fn set_enabled(on: bool) {
        ENABLED.with(|e| e.set(on));
    }

    pub fn enabled() -> bool {
        ENABLED.with(|e| e.get())
    }

    /// Worker width for search/study outer loops: the configured thread
    /// count (`COMPASS_THREADS`-aware), forced to 1 while profiling is
    /// enabled — the profiler's accumulators are thread-local, so scopes
    /// recorded on worker threads would vanish from the report.
    pub fn outer_threads() -> usize {
        if enabled() {
            1
        } else {
            crate::cost::engine::default_threads()
        }
    }

    /// RAII timing scope; `None` (no timer started) when disabled.
    /// Usage: `let _p = profile::scope("coster.memo_miss");`
    #[must_use]
    pub fn scope(label: &'static str) -> Option<ScopeGuard> {
        if !enabled() {
            return None;
        }
        STACK.with(|s| {
            s.borrow_mut().push(Frame {
                label,
                start: Instant::now(),
                child_s: 0.0,
            })
        });
        Some(ScopeGuard { _priv: () })
    }

    pub struct ScopeGuard {
        _priv: (),
    }

    impl Drop for ScopeGuard {
        fn drop(&mut self) {
            let frame = match STACK.with(|s| s.borrow_mut().pop()) {
                Some(f) => f,
                None => return,
            };
            let elapsed = frame.start.elapsed().as_secs_f64();
            let self_s = (elapsed - frame.child_s).max(0.0);
            STACK.with(|s| {
                if let Some(parent) = s.borrow_mut().last_mut() {
                    parent.child_s += elapsed;
                }
            });
            TOTALS.with(|t| {
                let mut t = t.borrow_mut();
                if let Some((_, tally)) = t.iter_mut().find(|(l, _)| *l == frame.label) {
                    tally.calls += 1;
                    tally.total_s += elapsed;
                    tally.self_s += self_s;
                } else {
                    t.push((
                        frame.label,
                        Tally {
                            calls: 1,
                            total_s: elapsed,
                            self_s,
                        },
                    ));
                }
            });
        }
    }

    /// Drain the accumulated tallies into a self-time table (descending
    /// self time) and reset. Empty string when nothing was recorded.
    pub fn take_report() -> String {
        let mut rows = TOTALS.with(|t| std::mem::take(&mut *t.borrow_mut()));
        if rows.is_empty() {
            return String::new();
        }
        rows.sort_by(|a, b| b.1.self_s.total_cmp(&a.1.self_s));
        let mut out = String::from(
            "wall-clock profile (self time, children subtracted)\n\
             self (s)     total (s)    calls        scope\n",
        );
        for (label, t) in rows {
            out.push_str(&format!(
                "{:<12.6} {:<12.6} {:<12} {label}\n",
                t.self_s, t.total_s, t.calls
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn collect(script: &[(usize, f64, usize, EventKind)]) -> SpanCollector {
        let mut c = SpanCollector::new();
        for &(replica, t, ext, kind) in script {
            c.event(replica, t, ext, kind);
        }
        c
    }

    #[test]
    fn lane_spans_tile_the_request_lifetime() {
        let c = collect(&[
            (0, 1.0, 7, EventKind::Offer),
            (0, 1.5, 7, EventKind::Admit),
            (0, 1.6, 7, EventKind::Chunk { tokens: 32 }),
            (0, 2.0, 7, EventKind::PrefillDone),
            (0, 2.0, 7, EventKind::FirstToken),
            (0, 5.0, 7, EventKind::Finish),
        ]);
        let lanes = c.waterfall();
        assert_eq!(lanes.len(), 1);
        let lane = &lanes[0];
        assert!(lane.finished && !lane.rejected);
        assert_eq!(lane.spans.len(), 3);
        assert_eq!(lane.spans[0].kind, SpanKind::Queue);
        assert_eq!(lane.spans[1].kind, SpanKind::Prefill);
        assert_eq!(lane.spans[2].kind, SpanKind::Decode);
        // contiguous tiling: each span starts where the last closed
        for w in lane.spans.windows(2) {
            assert_eq!(w[0].end_s.to_bits(), w[1].start_s.to_bits());
        }
        assert!((lane.total_s() - 4.0).abs() < 1e-12);
        assert_eq!(c.n_finished(), 1);
    }

    #[test]
    fn preempt_retry_and_migration_reopen_spans() {
        let c = collect(&[
            (0, 0.0, 3, EventKind::Offer),
            (0, 0.5, 3, EventKind::Admit),
            (0, 1.0, 3, EventKind::PrefillDone),
            (0, 1.5, 3, EventKind::Preempt),
            (0, 2.0, 3, EventKind::Admit),
            (0, 2.5, 3, EventKind::PrefillDone),
            (0, 3.0, 3, EventKind::MigrateOut),
            (1, 3.4, 3, EventKind::MigrateIn),
            (1, 3.5, 3, EventKind::Admit),
            (1, 3.5, 3, EventKind::PrefillDone),
            (1, 6.0, 3, EventKind::Finish),
        ]);
        let lanes = c.waterfall();
        let lane = &lanes[0];
        let kinds: Vec<SpanKind> = lane.spans.iter().map(|s| s.kind).collect();
        assert_eq!(
            kinds,
            vec![
                SpanKind::Queue,
                SpanKind::Prefill,
                SpanKind::Decode,
                SpanKind::Queue,
                SpanKind::Prefill,
                SpanKind::Decode,
                SpanKind::MigrateLink,
                SpanKind::Queue,
                SpanKind::Prefill,
                SpanKind::Decode,
            ]
        );
        // zero-length prefill span for the migrated admission
        assert_eq!(lane.spans[8].end_s.to_bits(), lane.spans[8].start_s.to_bits());
        assert!((lane.total_s() - 6.0).abs() < 1e-12);
        // the migrate span belongs to the source replica
        assert_eq!(lane.spans[6].replica, 0);
        assert_eq!(lane.spans[9].replica, 1);
    }

    #[test]
    fn crash_clock_overshoot_never_goes_negative() {
        // the replica's iteration clock overshot the crash time: the
        // Fail event carries t=10.0 while Admit was stamped at 10.5
        let c = collect(&[
            (0, 9.0, 1, EventKind::Offer),
            (0, 10.5, 1, EventKind::Admit),
            (0, 10.0, 1, EventKind::Fail),
            (0, 10.3, 1, EventKind::Offer),
            (1, 10.8, 1, EventKind::Admit),
            (1, 11.0, 1, EventKind::PrefillDone),
            (1, 12.0, 1, EventKind::Finish),
        ]);
        let lane = &c.waterfall()[0];
        for sp in &lane.spans {
            assert!(
                sp.end_s >= sp.start_s,
                "negative span {:?} [{}, {}]",
                sp.kind,
                sp.start_s,
                sp.end_s
            );
        }
        assert_eq!(lane.n_failures, 1);
        // tiling still holds
        for w in lane.spans.windows(2) {
            assert_eq!(w[0].end_s.to_bits(), w[1].start_s.to_bits());
        }
        assert!((lane.total_s() - (12.0 - 9.0)).abs() < 1e-12);
    }

    #[test]
    fn chrome_trace_is_valid_shape_and_deterministic() {
        let mut c = collect(&[
            (0, 0.0, 0, EventKind::Offer),
            (0, 0.1, 0, EventKind::Admit),
            (0, 0.2, 0, EventKind::PrefillDone),
            (0, 0.4, 0, EventKind::Finish),
            (1, 0.0, 1, EventKind::Offer),
            (1, 0.3, 1, EventKind::Reject),
        ]);
        c.instant(0, 0.25, "crash");
        c.iter(IterSpan {
            replica: 0,
            start_s: 0.1,
            end_s: 0.2,
            n_prefill: 1,
            n_decode: 0,
            queue_depth: 0,
            kv_frac: 0.5,
            kv_frag: 0.0,
        });
        c.counter_set("coster.lookups", 3.0);
        let json = c.chrome_trace_json();
        assert!(json.starts_with("{\"traceEvents\":["));
        assert!(json.contains("\"ph\":\"X\""));
        assert!(json.contains("\"ph\":\"C\""));
        assert!(json.contains("\"ph\":\"i\""));
        assert!(json.contains("\"name\":\"crash\""));
        assert!(json.contains("\"finished\":1"));
        assert!(json.contains("coster.lookups"));
        // balanced braces/brackets — a cheap well-formedness check
        let depth = json.chars().fold((0i64, 0i64), |(b, k), c| match c {
            '{' => (b + 1, k),
            '}' => (b - 1, k),
            '[' => (b, k + 1),
            ']' => (b, k - 1),
            _ => (b, k),
        });
        assert_eq!(depth, (0, 0));
        assert_eq!(json, c.chrome_trace_json(), "export must be deterministic");
    }

    #[test]
    fn counters_set_and_add() {
        let mut c = SpanCollector::new();
        c.counter_add("x", 2.0);
        c.counter_add("x", 3.0);
        c.counter_set("y", 7.0);
        c.counter_set("y", 9.0);
        assert_eq!(c.counters()["x"], 5.0);
        assert_eq!(c.counters()["y"], 9.0);
    }

    #[test]
    fn null_sink_is_disabled_and_inert() {
        let mut n = NullSink;
        assert!(!n.enabled());
        n.event(0, 0.0, 0, EventKind::Offer);
        n.counter_add("x", 1.0);
        // nothing observable — the trait contract is "does nothing"
    }

    #[test]
    fn run_record_serializes_valid_json_line() {
        let r = RunRecord {
            study: "sim-study".into(),
            cell: "vllm@2rps".into(),
            rate_rps: 2.0,
            n_arrived: 10,
            n_completed: 9,
            n_rejected: 1,
            slo_attainment: 0.9,
            slo_goodput_tps: 12.0,
            throughput_tps: 15.0,
            ttft_p99_s: 0.2,
            tpot_p99_s: 0.01,
            makespan_s: 5.0,
            energy_pj: 1e9,
            truncated: false,
            degraded: true,
        };
        let j = r.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"degraded\":true"));
        assert!(!j.contains('\n'));
    }

    #[test]
    fn profile_scopes_accumulate_self_time() {
        profile::set_enabled(true);
        {
            let _outer = profile::scope("outer");
            {
                let _inner = profile::scope("inner");
                std::hint::black_box((0..1000).sum::<u64>());
            }
        }
        let report = profile::take_report();
        assert!(report.contains("outer"), "{report}");
        assert!(report.contains("inner"), "{report}");
        profile::set_enabled(false);
        assert!(profile::scope("off").is_none());
        assert_eq!(profile::take_report(), "");
    }
}

//! Timed request streams: arrival processes layered on the paper's
//! sequence-length distributions (`TraceSpec`), feeding the serving
//! simulator with (arrival time, input length, output length) triples.

use crate::util::Rng;
use crate::workload::trace::TraceSpec;

/// One request of a serving trace: a prompt of `input_len` tokens
/// arriving at `arrival_s`, expecting `output_len` generated tokens.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimedRequest {
    pub id: usize,
    pub arrival_s: f64,
    pub input_len: u64,
    pub output_len: u64,
}

/// A timed request trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct RequestStream {
    pub name: String,
    pub requests: Vec<TimedRequest>,
    /// Mean request arrival rate used to generate the stream (req/s).
    pub rate_rps: f64,
    pub seed: u64,
}

impl RequestStream {
    /// Poisson arrivals at `rate_rps` requests/s: exponential
    /// inter-arrival gaps layered on lengths sampled from `spec`.
    /// Deterministic for a fixed `seed`.
    pub fn poisson(spec: &TraceSpec, rate_rps: f64, n: usize, seed: u64) -> Self {
        Self::generate(spec, rate_rps, n, seed, true)
    }

    /// Fixed-rate arrivals: one request every `1/rate_rps` seconds.
    pub fn fixed_rate(spec: &TraceSpec, rate_rps: f64, n: usize, seed: u64) -> Self {
        Self::generate(spec, rate_rps, n, seed, false)
    }

    fn generate(spec: &TraceSpec, rate_rps: f64, n: usize, seed: u64, poisson: bool) -> Self {
        assert!(rate_rps > 0.0, "arrival rate must be positive");
        let lens = spec.sample(n, seed);
        let mut gap_rng = Rng::seed_from_u64(seed ^ 0x5157_6172_7269_7661); // "arrival"
        let mut t = 0.0f64;
        let requests = lens
            .into_iter()
            .enumerate()
            .map(|(id, (input_len, output_len))| {
                let gap = if poisson {
                    // exponential inter-arrival: -ln(1 - u) / rate
                    let u = gap_rng.gen_f64();
                    -(1.0 - u).max(f64::EPSILON).ln() / rate_rps
                } else {
                    1.0 / rate_rps
                };
                t += gap;
                TimedRequest {
                    id,
                    arrival_s: t,
                    input_len,
                    output_len,
                }
            })
            .collect();
        RequestStream {
            name: format!("{}req@{:.3}rps", n, rate_rps),
            requests,
            rate_rps,
            seed,
        }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Arrival time of the last request (the load window).
    pub fn horizon_s(&self) -> f64 {
        self.requests.last().map_or(0.0, |r| r.arrival_s)
    }

    /// Total output tokens the stream asks for.
    pub fn total_output_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.output_len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TraceSpec {
        TraceSpec::sharegpt()
    }

    #[test]
    fn arrivals_sorted_and_deterministic() {
        let a = RequestStream::poisson(&spec(), 2.0, 64, 9);
        let b = RequestStream::poisson(&spec(), 2.0, 64, 9);
        assert_eq!(a.requests, b.requests);
        for w in a.requests.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let c = RequestStream::poisson(&spec(), 2.0, 64, 10);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn poisson_mean_rate_matches() {
        let s = RequestStream::poisson(&spec(), 4.0, 2000, 3);
        let rate = s.len() as f64 / s.horizon_s();
        assert!((rate - 4.0).abs() / 4.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn fixed_rate_is_evenly_spaced() {
        let s = RequestStream::fixed_rate(&spec(), 2.0, 10, 1);
        for w in s.requests.windows(2) {
            assert!((w[1].arrival_s - w[0].arrival_s - 0.5).abs() < 1e-12);
        }
        assert_eq!(s.requests[0].id, 0);
        assert!(s.total_output_tokens() > 0);
    }
}

//! Property tests for the multi-replica fleet simulator: fleet-level
//! request conservation (completed + rejected == arrived across
//! replicas), bit-identical reruns, single-replica equivalence with
//! `simulate_serving`, and disaggregation invariants — over randomized
//! streams, router policies, fleet shapes and KV budgets.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{self, FleetConfig, FleetMetrics, MappingPolicy, RouterPolicy, SimConfig, SloSpec};
use compass::util::Rng;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

fn tiny_hw() -> HwConfig {
    HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    )
}

fn tiny_spec() -> TraceSpec {
    TraceSpec {
        mean_in: 48.0,
        mean_out: 8.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 4096,
        shared_prefix_tokens: 0,
    }
}

fn cfg_for(strategy: ServingStrategy, kv_tokens: u64) -> SimConfig {
    let mut cfg = SimConfig::new(strategy);
    cfg.policy = MappingPolicy::Pipeline;
    cfg.max_batch = 6;
    cfg.chunk_tokens = 24;
    cfg.kv_budget_tokens = kv_tokens;
    cfg.ctx_bucket = 32;
    cfg.eval_blocks = 1;
    cfg.slo = SloSpec::new(0.5, 0.1);
    cfg.max_iterations = 500_000;
    cfg
}

fn run(
    fleet: &FleetConfig,
    strategy: ServingStrategy,
    kv_tokens: u64,
    rate_scale: f64,
    n: usize,
    seed: u64,
) -> FleetMetrics {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(strategy, kv_tokens);
    let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
    let rate = rate_scale * fleet.total_replicas() as f64 * probe.capacity_rps();
    let stream = sim::RequestStream::poisson(&tiny_spec(), rate, n, seed);
    sim::simulate_fleet(&stream, &model, &hw, &cfg, fleet)
}

fn shapes() -> Vec<FleetConfig> {
    vec![
        FleetConfig::homogeneous(2, RouterPolicy::RoundRobin),
        FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue),
        FleetConfig::disaggregated(1, 2, 1e-7),
    ]
}

/// Fleet-level conservation: arrived == completed + rejected across
/// replicas, for every router policy over randomized seeds, rates,
/// strategies and KV budgets (including budgets tight enough to force
/// rejections and preemptions on individual replicas).
#[test]
fn fleet_conservation_across_randomized_runs() {
    let mut rng = Rng::seed_from_u64(1234);
    let shapes = shapes();
    for trial in 0..9 {
        let fleet = &shapes[trial % shapes.len()];
        let strategy = ServingStrategy::ALL[trial % 3];
        let kv_tokens = *rng.choose(&[4096u64, 512, 160]);
        let rate_scale = 0.3 + rng.gen_f64() * 2.0;
        let n = 8 + rng.gen_index(10);
        let seed = rng.next_u64();
        let m = run(fleet, strategy, kv_tokens, rate_scale, n, seed);
        assert_eq!(
            m.n_completed + m.n_rejected,
            m.n_arrived,
            "{} {strategy:?} kv={kv_tokens} scale={rate_scale} n={n} seed={seed}",
            fleet.describe()
        );
        assert!(
            !m.truncated,
            "iteration cap hit: {} {strategy:?} kv={kv_tokens}",
            fleet.describe()
        );
        // per-replica arrivals partition the stream (prefill stage sees
        // every request; homogeneous fleets split it)
        let replica_arrivals: usize = match fleet.router {
            RouterPolicy::PrefillDecode => m.per_replica[..fleet.n_prefill]
                .iter()
                .map(|r| r.n_arrived)
                .sum(),
            _ => m.per_replica.iter().map(|r| r.n_arrived).sum(),
        };
        assert_eq!(replica_arrivals, m.n_arrived, "{}", fleet.describe());
    }
}

/// Bit-identical fleet metrics across repeated runs with the same seed,
/// and different results for a different stream seed.
#[test]
fn fleet_metrics_bit_identical_for_same_seed() {
    for fleet in shapes() {
        let a = run(&fleet, ServingStrategy::ChunkedPrefill, 768, 1.2, 12, 21);
        let b = run(&fleet, ServingStrategy::ChunkedPrefill, 768, 1.2, 12, 21);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{}", fleet.describe());
        assert_eq!(
            a.throughput_tps.to_bits(),
            b.throughput_tps.to_bits(),
            "{}",
            fleet.describe()
        );
        assert_eq!(a.ttft.p99.to_bits(), b.ttft.p99.to_bits(), "{}", fleet.describe());
        assert_eq!(a.tpot.p99.to_bits(), b.tpot.p99.to_bits(), "{}", fleet.describe());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{}", fleet.describe());
        assert_eq!(a.kv_transfer_tokens, b.kv_transfer_tokens, "{}", fleet.describe());
        for (x, y) in a.per_replica.iter().zip(&b.per_replica) {
            assert_eq!(x.n_iterations, y.n_iterations, "{}", fleet.describe());
            assert_eq!(x.n_preemptions, y.n_preemptions, "{}", fleet.describe());
        }
        let c = run(&fleet, ServingStrategy::ChunkedPrefill, 768, 1.2, 12, 22);
        assert_ne!(
            a.makespan_s.to_bits(),
            c.makespan_s.to_bits(),
            "{} should differ across seeds",
            fleet.describe()
        );
    }
}

/// The fleet-level outcome export (new in the front-end refactor):
/// one stitched outcome per arrival, consistent with the counters,
/// and the baseline front end neither sheds nor rebalances.
#[test]
fn fleet_outcomes_cover_every_arrival() {
    for fleet in shapes() {
        let m = run(&fleet, ServingStrategy::Orca, 768, 1.5, 12, 17);
        assert_eq!(m.outcomes.len(), m.n_arrived, "{}", fleet.describe());
        let rejected = m.outcomes.iter().filter(|o| o.rejected).count();
        assert_eq!(rejected, m.n_rejected, "{}", fleet.describe());
        let completed = m
            .outcomes
            .iter()
            .filter(|o| !o.rejected && o.finish_s.is_some())
            .count();
        assert_eq!(completed, m.n_completed, "{}", fleet.describe());
        assert_eq!(m.n_shed, 0, "{}", fleet.describe());
        assert_eq!(m.shed_rate, 0.0, "{}", fleet.describe());
        assert_eq!(m.n_rebalanced, 0, "{}", fleet.describe());
    }
}

/// A one-replica fleet is the single-package simulator, bit for bit:
/// both run the same `Scheduler` under the same driver.
#[test]
fn one_replica_fleet_equals_simulate_serving() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    for strategy in ServingStrategy::ALL {
        let cfg = cfg_for(strategy, 1024);
        let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
        let stream =
            sim::RequestStream::poisson(&tiny_spec(), 1.4 * probe.capacity_rps(), 11, 9);
        let single = sim::simulate_serving(&stream, &model, &hw, &cfg);
        let fleet = sim::simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(1, RouterPolicy::RoundRobin),
        );
        let m = &fleet.per_replica[0];
        assert_eq!(m.makespan_s.to_bits(), single.makespan_s.to_bits(), "{strategy:?}");
        assert_eq!(m.energy_pj.to_bits(), single.energy_pj.to_bits(), "{strategy:?}");
        assert_eq!(m.n_iterations, single.n_iterations, "{strategy:?}");
        assert_eq!(m.n_preemptions, single.n_preemptions, "{strategy:?}");
        assert_eq!(fleet.n_completed, single.n_completed, "{strategy:?}");
        assert_eq!(fleet.ttft.p99.to_bits(), single.ttft.p99.to_bits(), "{strategy:?}");
        assert_eq!(fleet.tpot.p99.to_bits(), single.tpot.p99.to_bits(), "{strategy:?}");
    }
}

/// Disaggregation invariants: prefill replicas never decode more than
/// one token per request, decode replicas never run prefill compute,
/// and the KV handoff covers every migrated context.
#[test]
fn disaggregation_splits_phases() {
    let fleet = FleetConfig::disaggregated(1, 2, 1e-7);
    let m = run(&fleet, ServingStrategy::ChunkedPrefill, 2048, 1.2, 14, 33);
    assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
    assert!(m.kv_transfer_tokens > 0, "no KV migrated");
    let (pre, dec) = m.per_replica.split_at(fleet.n_prefill);
    // prefill pool: every request runs exactly to its first token
    for r in pre {
        for it in &r.iters {
            assert!(
                it.n_decode == 0,
                "prefill replica ran a decode iteration"
            );
        }
    }
    // decode pool: pure decode, KV arrives by transfer
    for r in dec {
        assert_eq!(r.kv_transfer_tokens > 0, r.n_arrived > 0);
        for it in &r.iters {
            assert_eq!(it.n_prefill, 0, "decode replica ran prefill compute");
            assert_eq!(it.prefill_tokens, 0);
        }
    }
    // TPOT includes the handoff: a pricier link can only raise the tail
    let slow = FleetConfig::disaggregated(1, 2, 1e-4);
    let ms = run(&slow, ServingStrategy::ChunkedPrefill, 2048, 1.2, 14, 33);
    assert!(ms.tpot.p99 >= m.tpot.p99 - 1e-12);
}

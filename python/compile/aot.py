"""AOT-lower the L2 GP graphs to HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly. See /opt/xla-example.

Artifacts (shapes from constants.py; all f32):
  gram_train.hlo.txt : composite_gram over (TRAIN_N, TRAIN_N)
  gram_cross.hlo.txt : composite_gram over (CAND_Q, TRAIN_N)
  gram_diag.hlo.txt  : K(z, z) for CAND_Q candidates
  gp_fit.hlo.txt     : masked Cholesky fit -> (alpha, L, mll)
  gp_ei.hlo.txt      : posterior mean/var/EI for CAND_Q candidates
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .constants import CAND_Q, SLOTS, SYS_D, TRAIN_N, TYPES

F32 = jnp.float32


def _s(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _gram_specs(q):
    return (
        _s(q, SYS_D),  # xsys
        _s(TRAIN_N, SYS_D),  # ysys
        _s(SYS_D),  # inv_ls
        _s(q, SLOTS, TYPES),  # a
        _s(TRAIN_N, SLOTS, TYPES),  # b
        _s(SLOTS, SLOTS),  # w
        _s(q, 2),  # sa
        _s(TRAIN_N, 2),  # sb
        _s(),  # sigma2
    )


ARTIFACTS = {
    "gram_train": (model.composite_gram, _gram_specs(TRAIN_N)),
    "gram_cross": (model.composite_gram, _gram_specs(CAND_Q)),
    "gram_diag": (
        model.gram_diag,
        (_s(CAND_Q, SLOTS, TYPES), _s(SLOTS, SLOTS), _s()),
    ),
    "gp_fit": (
        model.gp_fit,
        (_s(TRAIN_N, TRAIN_N), _s(TRAIN_N), _s(TRAIN_N), _s()),
    ),
    "gp_ei": (
        model.gp_ei,
        (
            _s(CAND_Q, TRAIN_N),  # k_cross
            _s(CAND_Q),  # k_diag
            _s(TRAIN_N, TRAIN_N),  # chol
            _s(TRAIN_N),  # alpha
            _s(TRAIN_N),  # mask
            _s(),  # f_best
        ),
    ),
    "ei_fused": (
        model.gp_ei_fused,
        (
            _s(CAND_Q, SYS_D),  # xsys_c
            _s(CAND_Q, SLOTS, TYPES),  # a_c
            _s(CAND_Q, 2),  # s_c
            _s(TRAIN_N, SYS_D),  # xsys_t
            _s(TRAIN_N, SLOTS, TYPES),  # a_t
            _s(TRAIN_N, 2),  # s_t
            _s(SYS_D),  # inv_ls
            _s(SLOTS, SLOTS),  # w
            _s(),  # sigma2
            _s(TRAIN_N, TRAIN_N),  # chol
            _s(TRAIN_N),  # alpha
            _s(TRAIN_N),  # mask
            _s(),  # f_best
        ),
    ),
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_artifact(name: str) -> str:
    fn, specs = ARTIFACTS[name]
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {
        "shapes": {
            "SLOTS": SLOTS,
            "TYPES": TYPES,
            "TRAIN_N": TRAIN_N,
            "CAND_Q": CAND_Q,
            "SYS_D": SYS_D,
        },
        "artifacts": {},
    }
    # partial rebuilds (--only) merge into the existing manifest
    if args.only and os.path.exists(manifest_path):
        try:
            old = json.load(open(manifest_path))
            if old.get("shapes") == manifest["shapes"]:
                manifest["artifacts"].update(old.get("artifacts", {}))
        except (json.JSONDecodeError, OSError):
            pass
    names = args.only or list(ARTIFACTS)
    for name in names:
        text = lower_artifact(name)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256_16": digest,
            "chars": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")
    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {manifest_path}")


if __name__ == "__main__":
    main()

//! Hardware sampling engine: GP-based Bayesian optimization over the
//! heterogeneous multi-chiplet design space (paper §V-B).
//!
//! Every sampled architecture is scored by a full mapping search (the GA
//! engine), so sample efficiency matters: a Gaussian process with the
//! hardware-aware composite kernel of Eq. 2-4 is the surrogate, Expected
//! Improvement the acquisition, and a two-tier simulated-annealing walk
//! the acquisition optimizer. The GP algebra executes on AOT-compiled
//! JAX/Pallas artifacts through PJRT (`PjrtGp`), mirroring the paper's
//! accelerator-resident BO update; `NativeGp` is the artifact-less mirror.

pub mod features;
pub mod gp;
pub mod sa;

use crate::arch::{HwConfig, HwSpace};
use crate::cost::engine::{default_threads, par_map_f64};
use crate::util::Rng;

pub use features::{featurize, HwFeatures};
#[cfg(feature = "xla")]
pub use gp::PjrtGp;
pub use gp::{Gp, Hyper, NativeGp};

/// BO budget and annealing knobs (paper: 100 BO iterations).
#[derive(Debug, Clone, Copy)]
pub struct BoConfig {
    /// Total architecture evaluations (including the initial design).
    pub rounds: usize,
    /// Random initial design size.
    pub init: usize,
    /// SA steps per acquisition maximisation.
    pub sa_steps: usize,
    /// Neighbour batch per SA step (capped at the artifact CAND_Q).
    pub sa_batch: usize,
    /// Probability of an outer-tier move (annealed toward inner moves).
    pub p_outer: f64,
    /// Re-learn GP hyperparameters every k rounds (0 = never).
    pub hyper_every: usize,
    pub seed: u64,
}

impl BoConfig {
    pub fn reduced() -> Self {
        BoConfig {
            rounds: 24,
            init: 6,
            sa_steps: 8,
            sa_batch: 32,
            p_outer: 0.5,
            hyper_every: 5,
            seed: 0xBEEF,
        }
    }

    pub fn paper() -> Self {
        BoConfig {
            rounds: 100,
            init: 12,
            sa_steps: 12,
            sa_batch: 64,
            ..Self::reduced()
        }
    }

    pub fn tiny() -> Self {
        BoConfig {
            rounds: 6,
            init: 3,
            sa_steps: 3,
            sa_batch: 8,
            ..Self::reduced()
        }
    }
}

/// One evaluated architecture.
#[derive(Debug, Clone)]
pub struct Observation {
    pub hw: HwConfig,
    /// Raw objective (lower is better; typically latency*energy*MC).
    pub objective: f64,
}

/// BO outcome.
#[derive(Debug, Clone)]
pub struct BoResult {
    pub best: Observation,
    pub observations: Vec<Observation>,
    /// Best objective after each round (for convergence plots).
    pub history: Vec<f64>,
    pub backend: &'static str,
}

/// Run Bayesian optimization. `objective` is the expensive evaluation
/// (mapping search + evaluation engine); lower is better.
///
/// BO rounds are sequential by construction (each observation feeds the
/// surrogate guiding the next), but the initial design is a fixed set of
/// independent evaluations: it is selected serially from the seeded RNG
/// and then scored across threads, preserving the seeded result exactly.
pub fn optimize<F: Fn(&HwConfig) -> f64 + Sync>(
    space: &HwSpace,
    cfg: &BoConfig,
    gp: &mut dyn Gp,
    objective: F,
) -> BoResult {
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut obs: Vec<Observation> = Vec::with_capacity(cfg.rounds);
    let mut seen: std::collections::HashSet<String> = Default::default();
    let mut history = Vec::with_capacity(cfg.rounds);
    let mut hyper = Hyper::default();

    // --- initial design: homogeneous (class x dataflow) anchors at
    // median bandwidths, topped up with random heterogeneous samples;
    // selected serially, evaluated as one parallel batch ---
    let init = cfg.init.min(cfg.rounds).max(1);
    let mut init_hws: Vec<HwConfig> = Vec::new();
    for hw in sa::homogeneous_seeds(space) {
        if init_hws.len() >= init.max(2) && init_hws.len() >= cfg.rounds {
            break;
        }
        if seen.insert(hw.describe()) {
            init_hws.push(hw);
        }
    }
    while init_hws.len() < init {
        let hw = sa::random_config(space, &mut rng);
        let key = hw.describe();
        if !seen.insert(key) && init_hws.len() + 1 < init {
            continue;
        }
        init_hws.push(hw);
    }
    // narrow outer width: each objective (a full GA mapping search) is
    // already internally parallel, so a wide outer fan-out would multiply
    // thread pools; a few outer lanes only cover the inner loops' serial
    // phases (breeding, workload build)
    let outer = (default_threads() / 4).max(1);
    let init_ys = par_map_f64(&init_hws, outer, &objective);
    for (hw, y) in init_hws.into_iter().zip(init_ys) {
        obs.push(Observation { hw, objective: y });
        history.push(best_of(&obs));
    }

    // --- BO rounds ---
    while obs.len() < cfg.rounds {
        let round = obs.len();
        // standardise log-objectives
        let ys_raw: Vec<f64> = obs.iter().map(|o| o.objective.max(1e-300).ln()).collect();
        let mean = ys_raw.iter().sum::<f64>() / ys_raw.len() as f64;
        let std = (ys_raw.iter().map(|y| (y - mean) * (y - mean)).sum::<f64>()
            / ys_raw.len() as f64)
            .sqrt()
            .max(1e-9);
        let ys: Vec<f32> = ys_raw.iter().map(|y| ((y - mean) / std) as f32).collect();
        let xs: Vec<HwFeatures> = obs.iter().map(|o| featurize(&o.hw)).collect();
        let f_best = ys.iter().cloned().fold(f32::INFINITY, f32::min);

        // hyperparameter learning by MLL grid (paper: learned during BO)
        if cfg.hyper_every > 0 && round % cfg.hyper_every == 0 {
            hyper = learn_hyper(gp, &xs, &ys, hyper);
        }
        if gp.fit(&xs, &ys, hyper).is_err() {
            // surrogate failure (degenerate gram): fall back to random
            let hw = sa::random_config(space, &mut rng);
            let y = objective(&hw);
            obs.push(Observation { hw, objective: y });
            history.push(best_of(&obs));
            continue;
        }

        // --- two-tier SA over the surrogate ---
        let incumbent = obs
            .iter()
            .min_by(|a, b| a.objective.total_cmp(&b.objective))
            .unwrap()
            .hw
            .clone();
        let mut state = incumbent;
        let mut state_ei = 0.0f32;
        let mut best_cand: Option<(HwConfig, f32)> = None;
        for step in 0..cfg.sa_steps {
            let temp = 1.0 - step as f64 / cfg.sa_steps.max(1) as f64;
            let p_outer = cfg.p_outer * temp; // anneal toward inner moves
            let cands: Vec<HwConfig> = (0..cfg.sa_batch.min(crate::runtime::shapes::CAND_Q))
                .map(|_| sa::propose(&state, space, p_outer, &mut rng))
                .collect();
            let feats: Vec<HwFeatures> = cands.iter().map(featurize).collect();
            let Ok(batch) = gp.ei(&feats, f_best) else {
                break;
            };
            // track the global best unseen candidate
            let mut order: Vec<usize> = (0..cands.len()).collect();
            order.sort_by(|&a, &b| batch.ei[b].total_cmp(&batch.ei[a]));
            for &i in &order {
                if !seen.contains(&cands[i].describe()) {
                    if best_cand.as_ref().map_or(true, |(_, e)| batch.ei[i] > *e) {
                        best_cand = Some((cands[i].clone(), batch.ei[i]));
                    }
                    break;
                }
            }
            // SA acceptance on the batch argmax
            let top = order[0];
            let d = (batch.ei[top] - state_ei) as f64;
            if d >= 0.0 || rng.gen_bool((d / (0.05 * temp.max(1e-3))).exp().min(1.0)) {
                state = cands[top].clone();
                state_ei = batch.ei[top];
            }
        }

        let next = best_cand
            .map(|(hw, _)| hw)
            .unwrap_or_else(|| sa::random_config(space, &mut rng));
        seen.insert(next.describe());
        let y = objective(&next);
        obs.push(Observation {
            hw: next,
            objective: y,
        });
        history.push(best_of(&obs));
    }

    let best = obs
        .iter()
        .min_by(|a, b| a.objective.total_cmp(&b.objective))
        .unwrap()
        .clone();
    BoResult {
        best,
        backend: gp.backend(),
        observations: obs,
        history,
    }
}

fn best_of(obs: &[Observation]) -> f64 {
    obs.iter()
        .map(|o| o.objective)
        .fold(f64::INFINITY, f64::min)
}

/// Small MLL grid search for the kernel hyperparameters.
fn learn_hyper(gp: &mut dyn Gp, xs: &[HwFeatures], ys: &[f32], current: Hyper) -> Hyper {
    let mut best = current;
    let mut best_mll = f32::NEG_INFINITY;
    for &sigma2 in &[0.02f32, 0.05, 0.15] {
        for &lambda in &[1.0f32, 2.0, 4.0] {
            for &ls in &[1.5f32, 3.0] {
                let h = Hyper {
                    sigma2,
                    lambda,
                    ls,
                    noise: current.noise,
                };
                if let Ok(mll) = gp.fit(xs, ys, h) {
                    if mll.is_finite() && mll > best_mll {
                        best_mll = mll;
                        best = h;
                    }
                }
            }
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::Dataflow;

    /// Synthetic objective with known structure: prefers M-class chiplets,
    /// NoP 64, a balanced WS/OS mix, and moderate TP.
    fn synth_objective(hw: &HwConfig) -> f64 {
        let (ws, os) = sa::dataflow_mix(hw);
        let balance = (ws as f64 - os as f64).abs() / hw.num_chiplets().max(1) as f64;
        let class_pen = match hw.class {
            crate::arch::ChipletClass::M => 0.0,
            _ => 1.0,
        };
        let bw_pen = ((hw.nop_bw_gbs as f64).log2() - 6.0).abs();
        (1.0 + balance) * (1.0 + class_pen) * (1.0 + 0.3 * bw_pen)
    }

    #[test]
    fn bo_improves_over_initial_design() {
        let space = HwSpace::paper(64.0);
        let cfg = BoConfig {
            rounds: 14,
            init: 5,
            ..BoConfig::reduced()
        };
        let mut gp = NativeGp::new();
        let r = optimize(&space, &cfg, &mut gp, synth_objective);
        assert_eq!(r.observations.len(), 14);
        let init_best = r.history[cfg.init - 1];
        let final_best = *r.history.last().unwrap();
        assert!(
            final_best <= init_best,
            "BO should not regress: {final_best} vs {init_best}"
        );
        // history is monotone non-increasing
        for w in r.history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn bo_beats_random_on_average() {
        let space = HwSpace::paper(64.0);
        let budget = 16usize;
        let mut wins = 0;
        for seed in 0..3u64 {
            let cfg = BoConfig {
                rounds: budget,
                init: 5,
                seed,
                ..BoConfig::reduced()
            };
            let mut gp = NativeGp::new();
            let bo = optimize(&space, &cfg, &mut gp, synth_objective);
            let mut rng = Rng::seed_from_u64(seed.wrapping_add(1000));
            let rand_best = (0..budget)
                .map(|_| synth_objective(&sa::random_config(&space, &mut rng)))
                .fold(f64::INFINITY, f64::min);
            if bo.best.objective <= rand_best {
                wins += 1;
            }
        }
        assert!(wins >= 2, "BO won only {wins}/3 against random");
    }

    #[test]
    fn bo_finds_heterogeneous_balance() {
        // the synthetic objective rewards a balanced WS/OS mix; BO must
        // discover heterogeneity (neither all-WS nor all-OS)
        let space = HwSpace::paper(64.0);
        let cfg = BoConfig {
            rounds: 18,
            init: 6,
            seed: 7,
            ..BoConfig::reduced()
        };
        let mut gp = NativeGp::new();
        let r = optimize(&space, &cfg, &mut gp, synth_objective);
        let (ws, os) = sa::dataflow_mix(&r.best.hw);
        assert!(ws > 0 && os > 0, "expected heterogeneous best, got WS={ws} OS={os}");
    }

    #[test]
    fn deterministic_under_seed() {
        let space = HwSpace::paper(64.0);
        let cfg = BoConfig::tiny();
        let a = {
            let mut gp = NativeGp::new();
            optimize(&space, &cfg, &mut gp, synth_objective)
        };
        let b = {
            let mut gp = NativeGp::new();
            optimize(&space, &cfg, &mut gp, synth_objective)
        };
        assert_eq!(a.best.objective, b.best.objective);
        assert_eq!(a.best.hw.describe(), b.best.hw.describe());
    }

    #[test]
    fn observations_stay_in_space() {
        let space = HwSpace::paper(512.0);
        let cfg = BoConfig::tiny();
        let mut gp = NativeGp::new();
        let r = optimize(&space, &cfg, &mut gp, synth_objective);
        for o in &r.observations {
            assert!(space.nop_bw_gbs.contains(&o.hw.nop_bw_gbs));
            assert!(o.hw.num_chiplets() <= space.max_chiplets);
            assert!(o
                .hw
                .layout
                .iter()
                .all(|d| matches!(d, Dataflow::WeightStationary | Dataflow::OutputStationary)));
        }
    }
}

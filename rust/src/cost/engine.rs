//! Batched, multi-threaded mapping-evaluation engine (EXPERIMENTS.md
//! #Perf).
//!
//! Every search loop (GA, SA, random, joint baselines, BO objectives)
//! funnels its fitness evaluations through [`BatchEvaluator`], which
//! scores a whole generation at once. [`MappingEvaluator`] is the
//! production implementation:
//!
//! * search-invariant workload state ([`PreparedWorkload`]: pred-edge
//!   offsets, successor counts, the per-(shape-class, chiplet-kind,
//!   load-flag) kernel-cost table) is computed once per search and
//!   shared read-only across threads;
//! * per-thread [`EvalScratch`] arenas make each individual's
//!   Algorithm-2 walk and timeline simulation allocation-free;
//! * a fitness memo keyed by the mapping genome means duplicate
//!   individuals (elites, crossover clones) are never re-simulated;
//! * batches are split across scoped `std::thread`s. Each mapping's
//!   score is computed independently and written back to its slot, so
//!   results are bit-identical on 1 or N threads.

use std::collections::HashMap;
use std::sync::Mutex;

use crate::arch::HwConfig;
use crate::mapping::Mapping;
use crate::workload::Workload;

use super::access::{self, AccessFlags, AccessScratch, PredEdges};
use super::timeline::{self, KernelMemo, SimOptions, SimResult, SimScratch};

/// Worker-thread count for batch evaluation: `COMPASS_THREADS` when set,
/// else the machine's available parallelism (capped to keep nested
/// search loops from oversubscribing).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("COMPASS_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            return n.clamp(1, 64);
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(16)
}

/// A batch fitness evaluator (lower is better). Implementations must
/// fill `out[i]` with the score of `batch[i]` and be deterministic: the
/// same mapping always gets the same score regardless of batch order or
/// thread count.
pub trait BatchEvaluator {
    fn eval_batch(&self, batch: &[Mapping], out: &mut Vec<f64>);

    /// Convenience for sequential searches (simulated annealing).
    fn eval_one(&self, m: &Mapping) -> f64 {
        let mut out = Vec::with_capacity(1);
        self.eval_batch(std::slice::from_ref(m), &mut out);
        out[0]
    }
}

/// Any plain `Fn(&Mapping) -> f64` is a (serial) batch evaluator; used
/// by tests and toy objectives.
impl<F> BatchEvaluator for F
where
    F: Fn(&Mapping) -> f64 + Sync,
{
    fn eval_batch(&self, batch: &[Mapping], out: &mut Vec<f64>) {
        out.clear();
        out.extend(batch.iter().map(self));
    }
}

/// Search-invariant precomputation for one (workload, hardware) pair:
/// everything `eval` needs that does not depend on the mapping.
pub struct PreparedWorkload<'a> {
    pub workload: &'a Workload,
    pub hw: &'a HwConfig,
    pred: PredEdges,
    memo: KernelMemo,
}

impl<'a> PreparedWorkload<'a> {
    pub fn new(workload: &'a Workload, hw: &'a HwConfig) -> Self {
        PreparedWorkload {
            workload,
            hw,
            pred: PredEdges::build(workload),
            memo: KernelMemo::build(workload, hw),
        }
    }

    /// Full evaluation of one mapping, allocation-free given `scratch`.
    pub fn evaluate(
        &self,
        mapping: &Mapping,
        opts: &SimOptions,
        scratch: &mut EvalScratch,
    ) -> SimResult {
        mapping.schedule_order_into(&mut scratch.order);
        access::analyze_into(
            self.workload,
            mapping,
            &scratch.order,
            &self.pred,
            &mut scratch.access,
            &mut scratch.flags,
        );
        timeline::simulate_into(
            self.workload,
            self.hw,
            mapping,
            &scratch.flags,
            opts,
            &scratch.order,
            &self.memo,
            &mut scratch.sim,
        )
    }
}

/// Per-thread scratch arena: schedule order, access flags, Algorithm-2
/// state, and timeline buffers, all reused across individuals.
#[derive(Default)]
pub struct EvalScratch {
    order: Vec<(usize, usize)>,
    flags: AccessFlags,
    access: AccessScratch,
    sim: SimScratch,
}

/// The production batch evaluator: EDP (`latency * energy`) of one
/// workload batch under fixed hardware, parallel across threads, with a
/// genome-keyed fitness memo.
pub struct MappingEvaluator<'a> {
    prep: PreparedWorkload<'a>,
    pub opts: SimOptions,
    threads: usize,
    cache: Mutex<HashMap<Mapping, f64>>,
    /// Reused by single-threaded paths (`eval_one`, 1-thread batches) so
    /// sequential searches stay allocation-free too.
    serial_scratch: Mutex<EvalScratch>,
}

impl<'a> MappingEvaluator<'a> {
    pub fn new(workload: &'a Workload, hw: &'a HwConfig) -> Self {
        MappingEvaluator {
            prep: PreparedWorkload::new(workload, hw),
            opts: SimOptions::default(),
            threads: default_threads(),
            cache: Mutex::new(HashMap::new()),
            serial_scratch: Mutex::new(EvalScratch::default()),
        }
    }

    /// Override the worker-thread count (1 = fully serial).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads.max(1);
        self
    }

    pub fn prepared(&self) -> &PreparedWorkload<'a> {
        &self.prep
    }

    /// Simulate one mapping with the prepared state (no memo).
    pub fn simulate(&self, m: &Mapping, scratch: &mut EvalScratch) -> SimResult {
        self.prep.evaluate(m, &self.opts, scratch)
    }

    fn edp(&self, m: &Mapping, scratch: &mut EvalScratch) -> f64 {
        let r = self.prep.evaluate(m, &self.opts, scratch);
        r.latency_cycles * r.energy_pj
    }

    /// Memoised single-mapping fitness.
    pub fn fitness(&self, m: &Mapping) -> f64 {
        if let Some(&f) = self.cache.lock().unwrap().get(m) {
            return f;
        }
        let f = {
            let mut scratch = self.serial_scratch.lock().unwrap();
            self.edp(m, &mut scratch)
        };
        self.cache.lock().unwrap().insert(m.clone(), f);
        f
    }

    /// Number of distinct mappings simulated so far.
    pub fn cache_len(&self) -> usize {
        self.cache.lock().unwrap().len()
    }
}

impl BatchEvaluator for MappingEvaluator<'_> {
    fn eval_batch(&self, batch: &[Mapping], out: &mut Vec<f64>) {
        out.clear();
        out.resize(batch.len(), f64::NAN);

        // memo lookup + within-batch dedup: collect distinct misses and
        // the output slots each one feeds
        let mut unique: Vec<&Mapping> = Vec::new();
        let mut slots: Vec<Vec<usize>> = Vec::new();
        {
            let cache = self.cache.lock().unwrap();
            let mut index: HashMap<&Mapping, usize> = HashMap::new();
            for (i, m) in batch.iter().enumerate() {
                if let Some(&f) = cache.get(m) {
                    out[i] = f;
                    continue;
                }
                match index.get(m) {
                    Some(&u) => slots[u].push(i),
                    None => {
                        index.insert(m, unique.len());
                        unique.push(m);
                        slots.push(vec![i]);
                    }
                }
            }
        }
        if unique.is_empty() {
            return;
        }

        // evaluate distinct misses, each into its own slot (deterministic
        // regardless of chunking), with one scratch arena per thread
        let mut fits = vec![0f64; unique.len()];
        let threads = self.threads.min(unique.len()).max(1);
        if threads == 1 {
            let mut scratch = self.serial_scratch.lock().unwrap();
            for (f, m) in fits.iter_mut().zip(&unique) {
                *f = self.edp(m, &mut scratch);
            }
        } else {
            let chunk = unique.len().div_ceil(threads);
            std::thread::scope(|s| {
                for (ms, fs) in unique.chunks(chunk).zip(fits.chunks_mut(chunk)) {
                    s.spawn(move || {
                        let mut scratch = EvalScratch::default();
                        for (f, m) in fs.iter_mut().zip(ms) {
                            *f = self.edp(m, &mut scratch);
                        }
                    });
                }
            });
        }

        let mut cache = self.cache.lock().unwrap();
        for (u, &f) in fits.iter().enumerate() {
            for &i in &slots[u] {
                out[i] = f;
            }
            cache.insert(unique[u].clone(), f);
        }
    }

    /// Sequential-search path (simulated annealing): reads the memo but
    /// does not populate it — an SA chain almost never revisits a genome,
    /// so inserting every candidate would only grow memory — and reuses
    /// the evaluator's serial scratch arena instead of allocating.
    fn eval_one(&self, m: &Mapping) -> f64 {
        if let Some(&f) = self.cache.lock().unwrap().get(m) {
            return f;
        }
        let mut scratch = self.serial_scratch.lock().unwrap();
        self.edp(m, &mut scratch)
    }
}

/// Deterministic parallel map for search loops whose individuals are not
/// plain mappings (the joint hardware+mapping baseline, BO initial
/// designs): `out[i] = f(&items[i])`, split across scoped threads.
pub fn par_map_f64<T, F>(items: &[T], threads: usize, f: &F) -> Vec<f64>
where
    T: Sync,
    F: Fn(&T) -> f64 + Sync,
{
    let mut out = vec![0f64; items.len()];
    if items.is_empty() {
        return out;
    }
    let threads = threads.min(items.len()).max(1);
    if threads == 1 {
        for (o, t) in out.iter_mut().zip(items) {
            *o = f(t);
        }
    } else {
        let chunk = items.len().div_ceil(threads);
        std::thread::scope(|s| {
            for (ts, os) in items.chunks(chunk).zip(out.chunks_mut(chunk)) {
                s.spawn(move || {
                    for (o, t) in os.iter_mut().zip(ts) {
                        *o = f(t);
                    }
                });
            }
        });
    }
    out
}

/// Deterministic parallel map with arbitrary result types, for search
/// outer loops (study cells, DSE candidates): `out[i] = f(i, &items[i])`
/// with results assembled in index order, so downstream argmin scans and
/// row tables are bit-identical to a serial run.
///
/// Unlike [`par_map_f64`]'s contiguous chunking, work is handed out one
/// item at a time from a shared atomic counter: search cells are highly
/// heterogeneous (a cell near saturation simulates far longer than an
/// idle one), and chunking would serialize the slow cells onto one
/// thread. `threads == 1` runs inline with no thread or lock overhead —
/// callers pass 1 to force the serial path (e.g. while the thread-local
/// profiler is enabled).
pub fn par_map<T, R, F>(items: &[T], threads: usize, f: &F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    if items.is_empty() {
        return Vec::new();
    }
    let threads = threads.min(items.len()).max(1);
    if threads == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let slots: Vec<std::sync::Mutex<Option<R>>> =
        items.iter().map(|_| std::sync::Mutex::new(None)).collect();
    let next = std::sync::atomic::AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|s| s.into_inner().unwrap().expect("every slot filled"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::cost::Evaluator;
    use crate::ga::ops;
    use crate::util::Rng;
    use crate::workload::{build_workload, ModelSpec, Request, WorkloadParams};

    fn setup() -> (Workload, HwConfig) {
        let model = ModelSpec::tiny();
        let batch = vec![Request::prefill(48); 4];
        let w = build_workload(
            &model,
            &batch,
            &WorkloadParams {
                micro_batch_size: 2,
                tensor_parallel: 2,
                eval_blocks: 2,
            },
        );
        let hw = HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        (w, hw)
    }

    #[test]
    fn batch_matches_reference_evaluator_bitwise() {
        let (w, hw) = setup();
        let mev = MappingEvaluator::new(&w, &hw).with_threads(3);
        let ev = Evaluator::new();
        let mut rng = Rng::seed_from_u64(7);
        let maps: Vec<_> = (0..9)
            .map(|_| ops::random_mapping(w.num_micro_batches(), w.layers_per_mb, 4, &mut rng))
            .collect();
        let mut fits = Vec::new();
        mev.eval_batch(&maps, &mut fits);
        assert_eq!(fits.len(), maps.len());
        for (m, f) in maps.iter().zip(&fits) {
            let r = ev.eval_batch(&w, &hw, m);
            let reference = r.latency_cycles * r.energy_pj;
            assert_eq!(f.to_bits(), reference.to_bits());
        }
    }

    #[test]
    fn one_and_many_threads_agree_bitwise() {
        let (w, hw) = setup();
        let mut rng = Rng::seed_from_u64(11);
        let maps: Vec<_> = (0..16)
            .map(|_| ops::random_mapping(w.num_micro_batches(), w.layers_per_mb, 4, &mut rng))
            .collect();
        let m1 = MappingEvaluator::new(&w, &hw).with_threads(1);
        let m4 = MappingEvaluator::new(&w, &hw).with_threads(4);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        m1.eval_batch(&maps, &mut a);
        m4.eval_batch(&maps, &mut b);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn duplicates_hit_the_memo() {
        let (w, hw) = setup();
        let mev = MappingEvaluator::new(&w, &hw).with_threads(2);
        let mut rng = Rng::seed_from_u64(3);
        let a = ops::random_mapping(w.num_micro_batches(), w.layers_per_mb, 4, &mut rng);
        let b = ops::random_mapping(w.num_micro_batches(), w.layers_per_mb, 4, &mut rng);
        let batch = vec![a.clone(), b.clone(), a.clone(), a.clone(), b.clone()];
        let mut fits = Vec::new();
        mev.eval_batch(&batch, &mut fits);
        // only two distinct genomes were ever simulated
        assert_eq!(mev.cache_len(), 2);
        assert_eq!(fits[0].to_bits(), fits[2].to_bits());
        assert_eq!(fits[0].to_bits(), fits[3].to_bits());
        assert_eq!(fits[1].to_bits(), fits[4].to_bits());
        // a second batch is served from the memo and stays identical
        let mut again = Vec::new();
        mev.eval_batch(&batch, &mut again);
        assert_eq!(mev.cache_len(), 2);
        for (x, y) in fits.iter().zip(&again) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn closure_blanket_impl_is_serial_identity() {
        let maps: Vec<_> = (0..5)
            .map(|i| {
                let mut m = Mapping::new(2, 3);
                m.set_chip(0, 0, i as u16);
                m
            })
            .collect();
        let f = |m: &Mapping| m.chip(0, 0) as f64;
        let mut out = Vec::new();
        BatchEvaluator::eval_batch(&f, &maps, &mut out);
        assert_eq!(out, vec![0.0, 1.0, 2.0, 3.0, 4.0]);
        assert_eq!(f.eval_one(&maps[3]), 3.0);
    }

    #[test]
    fn par_map_preserves_order() {
        let items: Vec<u64> = (0..100).collect();
        let f = |x: &u64| (*x * 3) as f64;
        let serial = par_map_f64(&items, 1, &f);
        let parallel = par_map_f64(&items, 7, &f);
        assert_eq!(serial, parallel);
        assert_eq!(serial[41], 123.0);
    }

    #[test]
    fn generic_par_map_is_index_ordered_and_thread_invariant() {
        let items: Vec<u64> = (0..97).collect();
        let f = |i: usize, x: &u64| (i as u64, *x * 7, format!("cell-{x}"));
        let serial = par_map(&items, 1, &f);
        for threads in [2, 5, 16] {
            let parallel = par_map(&items, threads, &f);
            assert_eq!(serial, parallel, "threads={threads}");
        }
        assert_eq!(serial[13], (13, 91, "cell-13".to_string()));
        let empty: Vec<u64> = Vec::new();
        assert!(par_map(&empty, 4, &f).is_empty());
    }
}

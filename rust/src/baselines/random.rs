//! Random-search ablations (paper §VI-G, Fig. 11): replace the GA with
//! random mapping sampling and/or the BO with random hardware sampling at
//! identical evaluation budgets.

use crate::arch::{HwConfig, HwSpace};
use crate::bo::sa::random_config;
use crate::bo::BoConfig;
use crate::cost::engine::{BatchEvaluator, MappingEvaluator};
use crate::cost::{group_params, Evaluator};
use crate::dse::MappingSearch;
use crate::ga::{ops, GaConfig};
use crate::mapping::Mapping;
use crate::util::Rng;
use crate::workload::serving::Scenario;
use crate::workload::{build_workload, ModelSpec};

/// Random mapping search with the GA's evaluation budget. Samples are
/// drawn serially from the seeded RNG, then scored as one parallel batch
/// through the evaluation engine (ties keep the first-drawn sample, so
/// the result matches the serial loop exactly).
pub fn random_mappings(
    scenario: &Scenario,
    model: &ModelSpec,
    hw: &HwConfig,
    ga: &GaConfig,
    eval_blocks: usize,
) -> MappingSearch {
    let ev = Evaluator::new();
    let budget = ga.population * (ga.generations + 1);
    let chips = hw.num_chiplets();
    let mut mappings = Vec::new();
    for (gi, group) in scenario.groups.iter().enumerate() {
        let params = group_params(hw, group.has_prefill, eval_blocks);
        let w = build_workload(model, &group.batch, &params);
        let mut rng = Rng::seed_from_u64(ga.seed.wrapping_add(777 + gi as u64));
        let mut samples: Vec<Mapping> = Vec::with_capacity(budget);
        for _ in 0..budget {
            samples.push(ops::random_mapping(
                w.num_micro_batches(),
                w.layers_per_mb,
                chips,
                &mut rng,
            ));
        }
        let mev = MappingEvaluator::new(&w, hw);
        let mut fits = Vec::with_capacity(budget);
        mev.eval_batch(&samples, &mut fits);
        let mut best_i = 0usize;
        for i in 1..fits.len() {
            if fits[i] < fits[best_i] {
                best_i = i;
            }
        }
        mappings.push(samples.swap_remove(best_i));
    }
    let eval = ev.eval_scenario(scenario, model, hw, &mappings, eval_blocks);
    MappingSearch { mappings, eval }
}

/// Random hardware search with the BO's round budget (mapping search
/// still by `mapping_search`, so only the sampler is ablated).
pub fn random_hardware<F: FnMut(&HwConfig) -> f64>(
    space: &HwSpace,
    bo: &BoConfig,
    mut objective: F,
) -> (HwConfig, f64) {
    let mut rng = Rng::seed_from_u64(bo.seed ^ 0x52414e44);
    let mut best: Option<(HwConfig, f64)> = None;
    for _ in 0..bo.rounds {
        let hw = random_config(space, &mut rng);
        let y = objective(&hw);
        if best.as_ref().map_or(true, |(_, b)| y < *b) {
            best = Some((hw, y));
        }
    }
    best.unwrap()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{Trace, TraceSpec};

    #[test]
    fn random_mapping_search_returns_valid_best() {
        let trace = Trace::new(&TraceSpec::sharegpt(), 32, 5);
        let scen = Scenario::prefill(&trace, 2, 1);
        let model = ModelSpec::tiny();
        let hw = HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let cfg = GaConfig {
            population: 5,
            generations: 3,
            ..GaConfig::tiny()
        };
        let ms = random_mappings(&scen, &model, &hw, &cfg, 1);
        assert!(ms.mappings[0].is_valid(4));
        assert!(ms.eval.total_cost() > 0.0);
    }

    #[test]
    fn random_hardware_returns_space_member() {
        let space = HwSpace::paper(64.0);
        let bo = BoConfig::tiny();
        let (hw, y) = random_hardware(&space, &bo, |hw| hw.nop_bw_gbs + hw.dram_bw_gbs);
        assert!(space.nop_bw_gbs.contains(&hw.nop_bw_gbs));
        assert!(y >= 32.0 + 16.0);
        // picks the minimum over its samples
        assert!(y <= 512.0 + 256.0);
    }
}

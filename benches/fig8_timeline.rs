//! Bench F8: paper Fig. 8 — execution latency timeline of the mapping
//! found under ShareGPT-64TOPS (prefill and decode), plus timeline-
//! recording overhead measurement.
use compass::cost::{Evaluator, SimOptions};
use compass::dse::DseConfig;
use compass::experiments as exp;
use compass::mapping::presets;
use compass::runtime::Runtime;
use compass::util::Bench;
use compass::workload::{build_workload, ModelSpec, Request, WorkloadParams};

fn main() {
    let mut cfg = DseConfig::reduced();
    cfg.bo.rounds = 8;
    cfg.bo.init = 4;
    let rt = Runtime::from_env().ok();
    println!("{}", exp::fig8_timeline(&exp::Scene::new("sharegpt", true, 64.0), &cfg, rt.as_ref(), 7));
    println!("{}", exp::fig8_timeline(&exp::Scene::new("sharegpt", false, 64.0), &cfg, rt.as_ref(), 7));

    let w = build_workload(
        &ModelSpec::gpt3_7b(),
        &vec![Request::prefill(128); 4],
        &WorkloadParams { micro_batch_size: 2, tensor_parallel: 8, eval_blocks: 1 },
    );
    let hw = compass::arch::HwConfig::homogeneous(
        2, 4, compass::arch::ChipletClass::M, compass::arch::Dataflow::WeightStationary, 32.0, 16.0,
    );
    let m = presets::pipeline_parallel(w.num_micro_batches(), w.layers_per_mb, 8);
    let plain = Evaluator::new();
    let recording = Evaluator { opts: SimOptions { record_timeline: true, ..Default::default() } };
    Bench::new("timeline/eval-no-recording").run(|| plain.eval_batch(&w, &hw, &m));
    Bench::new("timeline/eval-with-recording").run(|| recording.eval_batch(&w, &hw, &m));
}

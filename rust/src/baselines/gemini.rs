//! Gemini-style baseline (paper §VI-A): single-model DSE with
//! simulated-annealing mapping search and grid-searched homogeneous
//! hardware, operating on a fixed (average) sequence length.

use crate::arch::{Dataflow, HwConfig, HwSpace};
use crate::cost::engine::{BatchEvaluator, MappingEvaluator};
use crate::cost::{group_params, EvalResult, Evaluator};
use crate::dse::MappingSearch;
use crate::ga::ops;
use crate::mapping::{presets, Mapping};
use crate::util::Rng;
use crate::workload::serving::Scenario;
use crate::workload::{build_workload, ModelSpec};

/// SA mapping-search budget (matched to the GA's evaluation count).
#[derive(Debug, Clone, Copy)]
pub struct SaConfig {
    pub iterations: usize,
    pub t0: f64,
    pub seed: u64,
}

impl SaConfig {
    pub fn matched_to(ga: &crate::ga::GaConfig) -> Self {
        SaConfig {
            iterations: ga.population * (ga.generations + 1),
            t0: 1.0,
            seed: ga.seed,
        }
    }
}

/// Simulated-annealing search over the mapping encoding (Gemini's
/// mapping method, ported onto the Compass representation).
///
/// SA is an inherently sequential chain, so it scores one candidate at a
/// time; passing a [`MappingEvaluator`] still pays off through the
/// prepared workload state and the fitness memo.
pub fn sa_mapping_search<E: BatchEvaluator + ?Sized>(
    rows: usize,
    cols: usize,
    chips: usize,
    cfg: &SaConfig,
    evaluator: &E,
) -> (Mapping, f64) {
    let mut rng = Rng::seed_from_u64(cfg.seed);
    let mut curr = presets::pipeline_parallel(rows, cols, chips);
    let mut curr_f = evaluator.eval_one(&curr);
    let mut best = curr.clone();
    let mut best_f = curr_f;
    for i in 0..cfg.iterations.saturating_sub(1) {
        let temp = cfg.t0 * (1.0 - i as f64 / cfg.iterations.max(1) as f64);
        let mut cand = curr.clone();
        let op = ops::pick_operator(1.0 - temp, &mut rng);
        ops::apply_operator(&mut cand, chips, op, &mut rng);
        if rng.gen_bool(0.3) {
            ops::mutate_segmentation(&mut cand, &mut rng);
        }
        let f = evaluator.eval_one(&cand);
        let accept = f < curr_f || {
            let d = (curr_f - f) / curr_f.abs().max(1e-300);
            rng.gen_bool((d / temp.max(1e-6)).exp().min(1.0))
        };
        if accept {
            curr = cand;
            curr_f = f;
            if f < best_f {
                best = curr.clone();
                best_f = f;
            }
        }
    }
    (best, best_f)
}

/// Run the SA mapping search for every scenario group on fixed hardware.
pub fn gemini_mappings(
    scenario: &Scenario,
    model: &ModelSpec,
    hw: &HwConfig,
    sa: &SaConfig,
    eval_blocks: usize,
) -> MappingSearch {
    let ev = Evaluator::new();
    let mut mappings = Vec::new();
    for (gi, group) in scenario.groups.iter().enumerate() {
        let params = group_params(hw, group.has_prefill, eval_blocks);
        let w = build_workload(model, &group.batch, &params);
        let mut cfg = *sa;
        cfg.seed = sa.seed.wrapping_add(gi as u64);
        let (m, _) = sa_mapping_search(
            w.num_micro_batches(),
            w.layers_per_mb,
            hw.num_chiplets(),
            &cfg,
            &MappingEvaluator::new(&w, hw),
        );
        mappings.push(m);
    }
    let eval = ev.eval_scenario(scenario, model, hw, &mappings, eval_blocks);
    MappingSearch { mappings, eval }
}

/// Gemini-style full DSE: grid search over *homogeneous* hardware
/// (uniform dataflow), SA mapping search per point, fixed-length
/// workload view during search. Returns the best (hw, mappings) and the
/// evaluation of that design.
///
/// `grid_stride` subsamples the bandwidth grids to keep the budget
/// comparable to the BO round count.
pub fn gemini_dse(
    search_scenario: &Scenario,
    model: &ModelSpec,
    space: &HwSpace,
    sa: &SaConfig,
    eval_blocks: usize,
    grid_stride: usize,
) -> (HwConfig, MappingSearch) {
    let stride = grid_stride.max(1);
    let mut best: Option<(f64, HwConfig, MappingSearch)> = None;
    for class in space.feasible_classes() {
        let n = class.chiplets_for(space.target_tops).min(space.max_chiplets);
        let (h, w) = HwSpace::grid_dims(n);
        for &df in &[Dataflow::WeightStationary, Dataflow::OutputStationary] {
            for nop in space.nop_bw_gbs.iter().step_by(stride) {
                for dram in space.dram_bw_gbs.iter().step_by(stride) {
                    let mut hw = HwConfig::homogeneous(h, w, class, df, *nop, *dram);
                    // Gemini searches micro-batch/TP coarsely: median values
                    hw.micro_batch_prefill =
                        space.micro_batch_prefill[space.micro_batch_prefill.len() / 2];
                    hw.micro_batch_decode =
                        space.micro_batch_decode[space.micro_batch_decode.len() / 2];
                    hw.tensor_parallel = space.tensor_parallel[space.tensor_parallel.len() / 2]
                        .min(hw.num_chiplets());
                    let ms = gemini_mappings(search_scenario, model, &hw, sa, eval_blocks);
                    let cost = ms.eval.total_cost();
                    if best.as_ref().map_or(true, |(c, _, _)| cost < *c) {
                        best = Some((cost, hw, ms));
                    }
                }
            }
        }
    }
    let (_, hw, ms) = best.expect("non-empty grid");
    (hw, ms)
}

/// Re-evaluate found mappings on the *real* (variable-length) scenario
/// (search may have used the fixed-length view; rows must match, so the
/// mapping shapes transfer directly).
pub fn reevaluate(
    scenario: &Scenario,
    model: &ModelSpec,
    hw: &HwConfig,
    mappings: &[Mapping],
    eval_blocks: usize,
) -> EvalResult {
    Evaluator::new().eval_scenario(scenario, model, hw, mappings, eval_blocks)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::{Trace, TraceSpec};

    #[test]
    fn sa_search_improves_over_start() {
        let trace = Trace::new(&TraceSpec::sharegpt(), 32, 1);
        let scen = Scenario::prefill(&trace, 2, 1);
        let model = ModelSpec::tiny();
        let hw = HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let ev = Evaluator::new();
        let params = group_params(&hw, true, 1);
        let w = build_workload(&model, &scen.groups[0].batch, &params);
        let start = presets::pipeline_parallel(w.num_micro_batches(), w.layers_per_mb, 4);
        let start_f = {
            let r = ev.eval_batch(&w, &hw, &start);
            r.latency_cycles * r.energy_pj
        };
        let sa = SaConfig {
            iterations: 120,
            t0: 1.0,
            seed: 5,
        };
        let (best, best_f) = sa_mapping_search(
            w.num_micro_batches(),
            w.layers_per_mb,
            4,
            &sa,
            &|m: &Mapping| {
                let r = ev.eval_batch(&w, &hw, m);
                r.latency_cycles * r.energy_pj
            },
        );
        assert!(best.is_valid(4));
        assert!(best_f <= start_f, "SA must not regress: {best_f} vs {start_f}");
    }

    #[test]
    fn gemini_dse_returns_homogeneous_hw() {
        let trace = Trace::new(&TraceSpec::sharegpt(), 32, 2);
        let scen = Scenario::prefill(&trace, 2, 1);
        let fixed = crate::baselines::fixed_length_scenario(&scen, &trace);
        let model = ModelSpec::tiny();
        let mut space = HwSpace::paper(64.0);
        space.nop_bw_gbs = vec![32.0];
        space.dram_bw_gbs = vec![16.0];
        let sa = SaConfig {
            iterations: 20,
            t0: 1.0,
            seed: 1,
        };
        let (hw, ms) = gemini_dse(&fixed, &model, &space, &sa, 1, 1);
        // homogeneous: exactly one dataflow present
        let (ws, os) = crate::bo::sa::dataflow_mix(&hw);
        assert!(ws == 0 || os == 0, "gemini hardware must be homogeneous");
        assert!(ms.eval.total_cost() > 0.0);
        // transfer to the real scenario works
        let real = reevaluate(&scen, &model, &hw, &ms.mappings, 1);
        assert!(real.latency_cycles > 0.0);
    }
}

//! Serving quality metrics: TTFT/TPOT distributions, SLO attainment,
//! goodput, utilization and EDP-under-load, plus the per-iteration
//! occupancy trace behind the report's ASCII occupancy plot.

use crate::arch::constants::CLOCK_HZ;

/// Service-level objectives on per-request latency.
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    /// Time-to-first-token target (s): arrival -> first output token.
    pub ttft_s: f64,
    /// Time-per-output-token target (s): mean decode-token gap.
    pub tpot_s: f64,
}

impl SloSpec {
    pub fn new(ttft_s: f64, tpot_s: f64) -> Self {
        SloSpec { ttft_s, tpot_s }
    }
}

/// Mean / median / tail summary of a latency sample.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyStats {
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub n: usize,
}

impl LatencyStats {
    /// Summarise a sample (empty samples yield zeros).
    pub fn from(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return LatencyStats::default();
        }
        let _p = super::telemetry::profile::scope("metrics.latency_sort");
        let mut sorted = xs.to_vec();
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        LatencyStats {
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50: percentile(&sorted, 0.50),
            p99: percentile(&sorted, 0.99),
            n: sorted.len(),
        }
    }
}

/// True nearest-rank percentile of an ascending-sorted sample: the
/// element at 1-based rank `ceil(q * n)` (so p50 of 1..=100 is 50, not
/// the interpolation-index 51 a rounded `q * (n-1)` would give).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = (q.clamp(0.0, 1.0) * n as f64).ceil() as usize;
    sorted[rank.saturating_sub(1).min(n - 1)]
}

/// One scheduler iteration in the occupancy trace.
#[derive(Debug, Clone, Copy)]
pub struct IterRecord {
    pub start_s: f64,
    pub end_s: f64,
    /// Decode requests co-batched this iteration.
    pub n_decode: usize,
    /// Prefill requests (or chunks) co-batched this iteration.
    pub n_prefill: usize,
    /// Prefill tokens scheduled this iteration.
    pub prefill_tokens: u64,
    /// Admission-queue depth after batch formation.
    pub queue_depth: usize,
    /// KV-cache occupancy after this iteration's writes (0..=1).
    pub kv_frac: f64,
    /// KV-block internal fragmentation after this iteration's writes
    /// (0..=1; always 0 for token-granular caches).
    pub kv_frag: f64,
    /// Co-resident admitted requests during this iteration (the
    /// effective concurrency the KV capacity sustains).
    pub n_running: usize,
}

/// Bounded occupancy trace: keeps exact running aggregates (iteration
/// count, queue-depth and batch-slot sums, busy time) for the metrics,
/// while the stored [`IterRecord`]s are capped at `2 * cap` entries by
/// deterministic pairwise merging (duration-weighted), so a 1M-iteration
/// run keeps a plottable trace in O(cap) memory instead of ~72 MB.
///
/// Decode fast-forward feeds this buffer one [`IterRecord`] per
/// *replayed* iteration — identical fields in identical order to the
/// naive loop — so the running sums, the downsampling cadence, and the
/// stored records are all bitwise-independent of `COMPASS_COALESCE`.
#[derive(Debug, Clone)]
pub struct TraceBuffer {
    /// Target record count; 0 = unbounded (keep every iteration).
    cap: usize,
    records: Vec<IterRecord>,
    n_iters: usize,
    sum_queue_depth: f64,
    max_queue_depth: usize,
    sum_slots: f64,
    busy_s: f64,
    /// Duration-weighted fragmentation integral (frag x dt), exact
    /// across downsampling.
    sum_frag_dt: f64,
    /// Duration-weighted co-resident-request integral (n_running x dt).
    sum_running_dt: f64,
}

impl TraceBuffer {
    pub fn new(cap: usize) -> Self {
        TraceBuffer {
            cap,
            records: Vec::new(),
            n_iters: 0,
            sum_queue_depth: 0.0,
            max_queue_depth: 0,
            sum_slots: 0.0,
            busy_s: 0.0,
            sum_frag_dt: 0.0,
            sum_running_dt: 0.0,
        }
    }

    /// Exact number of iterations pushed (not the stored record count).
    pub fn n_iters(&self) -> usize {
        self.n_iters
    }

    /// Time spent inside iterations (s), exact across downsampling.
    pub fn busy_s(&self) -> f64 {
        self.busy_s
    }

    pub fn records(&self) -> &[IterRecord] {
        &self.records
    }

    /// Duration-weighted mean KV fragmentation over the run's busy time.
    pub fn kv_fragmentation(&self) -> f64 {
        if self.busy_s > 1e-12 {
            self.sum_frag_dt / self.busy_s
        } else {
            0.0
        }
    }

    /// Duration-weighted mean co-resident requests over busy time.
    pub fn effective_concurrency(&self) -> f64 {
        if self.busy_s > 1e-12 {
            self.sum_running_dt / self.busy_s
        } else {
            0.0
        }
    }

    pub fn push(&mut self, rec: IterRecord) {
        self.n_iters += 1;
        self.sum_queue_depth += rec.queue_depth as f64;
        self.max_queue_depth = self.max_queue_depth.max(rec.queue_depth);
        self.sum_slots += (rec.n_decode + rec.n_prefill) as f64;
        let dt = (rec.end_s - rec.start_s).max(0.0);
        self.busy_s += dt;
        self.sum_frag_dt += rec.kv_frag * dt;
        self.sum_running_dt += rec.n_running as f64 * dt;
        self.records.push(rec);
        if self.cap > 0 && self.records.len() >= 2 * self.cap {
            self.compact();
        }
    }

    /// Merge adjacent record pairs (duration-weighted averages for the
    /// occupancy fields, summed prefill tokens), halving the trace while
    /// keeping `ascii_occupancy`'s time-bucketed rendering faithful.
    fn compact(&mut self) {
        let mut out = Vec::with_capacity(self.records.len() / 2 + 1);
        let mut it = self.records.chunks_exact(2);
        for pair in &mut it {
            let (a, b) = (pair[0], pair[1]);
            let (wa, wb) = ((a.end_s - a.start_s).max(0.0), (b.end_s - b.start_s).max(0.0));
            let w = wa + wb;
            let mix = |x: f64, y: f64| {
                if w > 0.0 {
                    (x * wa + y * wb) / w
                } else {
                    0.5 * (x + y)
                }
            };
            out.push(IterRecord {
                start_s: a.start_s,
                end_s: b.end_s,
                n_decode: mix(a.n_decode as f64, b.n_decode as f64).round() as usize,
                n_prefill: mix(a.n_prefill as f64, b.n_prefill as f64).round() as usize,
                prefill_tokens: a.prefill_tokens + b.prefill_tokens,
                queue_depth: mix(a.queue_depth as f64, b.queue_depth as f64).round() as usize,
                kv_frac: mix(a.kv_frac, b.kv_frac),
                kv_frag: mix(a.kv_frag, b.kv_frag),
                n_running: mix(a.n_running as f64, b.n_running as f64).round() as usize,
            });
        }
        out.extend(it.remainder().iter().copied());
        self.records = out;
    }
}

/// End-to-end serving quality of one simulated run.
#[derive(Debug, Clone)]
pub struct ServingMetrics {
    pub n_arrived: usize,
    pub n_completed: usize,
    /// Requests rejected at arrival (can never fit the KV budget).
    pub n_rejected: usize,
    /// Requests still in flight when the run stopped (nonzero only for
    /// truncated runs): admitted or queued, neither completed nor
    /// rejected. Their TTFT samples (when the first token was emitted)
    /// are included in `ttft` so capped runs keep their tail signal.
    pub n_in_flight: usize,
    /// KV-pressure preemptions (request re-queued, prefill recomputed).
    pub n_preemptions: usize,
    pub n_iterations: usize,
    /// True when the run stopped at the iteration safety valve with
    /// requests still in flight: the other metrics then cover only the
    /// surviving subset and must not be compared against full runs.
    pub truncated: bool,
    /// Distinct batch shapes actually simulated (memo size).
    pub distinct_shapes: usize,
    /// Wall-clock span of the simulated run (s).
    pub makespan_s: f64,
    /// Time spent inside scheduler iterations (s); `makespan_s` minus
    /// idle gaps. The fleet layer's load-imbalance signal.
    pub busy_s: f64,
    /// Generated output tokens over the run.
    pub gen_tokens: u64,
    /// Generated output tokens per second over the makespan.
    pub throughput_tps: f64,
    /// SLO-satisfying completed requests per second.
    pub goodput_rps: f64,
    /// Output tokens of SLO-satisfying requests per second — the
    /// SLO-constrained goodput objective of the sim-backed DSE.
    pub slo_goodput_tps: f64,
    pub ttft: LatencyStats,
    pub tpot: LatencyStats,
    /// Fraction of completed requests meeting both TTFT and TPOT SLOs.
    pub slo_attainment: f64,
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    /// Mean batch slots filled per iteration / `max_batch`.
    pub mean_batch_occupancy: f64,
    /// Compute utilization: ideal MAC cycles / elapsed cycles.
    pub utilization: f64,
    pub energy_pj: f64,
    /// EDP under load: total energy (J) x makespan (s).
    pub edp_under_load: f64,
    /// KV tokens materialized from a fleet handoff (disaggregated
    /// prefill/decode migration traffic landing on this replica;
    /// block-granular for paged caches).
    pub kv_transfer_tokens: u64,
    /// KV-cache token capacity (whole blocks) this run was given.
    pub kv_capacity_tokens: u64,
    /// Duration-weighted mean internal fragmentation of allocated KV
    /// blocks (0 for token-granular caches).
    pub kv_fragmentation: f64,
    /// Prefill tokens served from the shared system-prompt prefix
    /// instead of recomputed.
    pub kv_shared_tokens: u64,
    /// Context tokens requested across prefill admissions (the
    /// sharing-hit-rate denominator).
    pub kv_demand_tokens: u64,
    /// `kv_shared_tokens / kv_demand_tokens` (0 when sharing is off).
    pub kv_sharing_hit_rate: f64,
    /// Times the shared prefix was (re-)materialized into cache blocks.
    pub kv_prefix_materializations: usize,
    /// Duration-weighted mean co-resident admitted requests — the
    /// effective concurrency the KV capacity sustained.
    pub effective_concurrency: f64,
    /// Per-iteration occupancy trace (for the ASCII plot); downsampled
    /// to the configured cap on long runs — use `n_iterations` for the
    /// exact count, never `iters.len()`.
    pub iters: Vec<IterRecord>,
}

/// Raw per-request outcomes collected by the scheduler.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub arrival_s: f64,
    pub input_len: u64,
    pub output_len: u64,
    pub first_token_s: Option<f64>,
    pub finish_s: Option<f64>,
    pub rejected: bool,
}

/// Per-request latency/SLO tallies shared by the single-replica
/// `finalize` and the fleet-level aggregation.
#[derive(Debug, Clone, Default)]
pub(crate) struct OutcomeStats {
    pub ttfts: Vec<f64>,
    pub tpots: Vec<f64>,
    pub n_completed: usize,
    pub n_rejected: usize,
    pub n_in_flight: usize,
    pub slo_ok: usize,
    pub slo_ok_tokens: u64,
}

pub(crate) fn outcome_stats(outcomes: &[RequestOutcome], slo: &SloSpec) -> OutcomeStats {
    let mut s = OutcomeStats::default();
    for o in outcomes {
        if o.rejected {
            s.n_rejected += 1;
            continue;
        }
        let ttft = o.first_token_s.map(|f| f - o.arrival_s);
        let Some(finish) = o.finish_s else {
            // still in flight (iteration-capped run): keep the TTFT
            // sample when the first token was emitted — capped runs
            // under-report tail TTFT exactly when it matters otherwise
            s.n_in_flight += 1;
            if let Some(t) = ttft {
                s.ttfts.push(t);
            }
            continue;
        };
        let first = o.first_token_s.unwrap_or(finish);
        let ttft = ttft.unwrap_or(finish - o.arrival_s);
        s.n_completed += 1;
        s.ttfts.push(ttft);
        let tpot = if o.output_len > 1 {
            (finish - first) / (o.output_len - 1) as f64
        } else {
            0.0
        };
        s.tpots.push(tpot);
        if ttft <= slo.ttft_s && tpot <= slo.tpot_s {
            s.slo_ok += 1;
            s.slo_ok_tokens += o.output_len;
        }
    }
    s
}

/// Scalar run totals carried from the scheduler into [`finalize`].
#[derive(Debug, Clone, Copy)]
pub struct RunTotals {
    pub slo: SloSpec,
    pub max_batch: usize,
    pub makespan_s: f64,
    pub energy_pj: f64,
    pub ideal_cycles: f64,
    pub gen_tokens: u64,
    pub n_preemptions: usize,
    pub distinct_shapes: usize,
    pub kv_transfer_tokens: u64,
    pub kv_capacity_tokens: u64,
    pub kv_shared_tokens: u64,
    pub kv_demand_tokens: u64,
    pub kv_prefix_materializations: usize,
    pub truncated: bool,
}

/// Aggregate raw scheduler state into `ServingMetrics`.
pub fn finalize(outcomes: &[RequestOutcome], trace: TraceBuffer, t: &RunTotals) -> ServingMetrics {
    let _p = super::telemetry::profile::scope("metrics.finalize");
    let s = outcome_stats(outcomes, &t.slo);
    let span = t.makespan_s.max(1e-12);
    let n_iter = trace.n_iters();
    let mean_queue_depth = if n_iter > 0 {
        trace.sum_queue_depth / n_iter as f64
    } else {
        0.0
    };
    let mean_batch_occupancy = if n_iter > 0 {
        trace.sum_slots / (n_iter * t.max_batch.max(1)) as f64
    } else {
        0.0
    };
    ServingMetrics {
        n_arrived: outcomes.len(),
        n_completed: s.n_completed,
        n_rejected: s.n_rejected,
        n_in_flight: s.n_in_flight,
        n_preemptions: t.n_preemptions,
        n_iterations: n_iter,
        truncated: t.truncated,
        distinct_shapes: t.distinct_shapes,
        makespan_s: t.makespan_s,
        busy_s: trace.busy_s(),
        gen_tokens: t.gen_tokens,
        throughput_tps: t.gen_tokens as f64 / span,
        goodput_rps: s.slo_ok as f64 / span,
        slo_goodput_tps: s.slo_ok_tokens as f64 / span,
        ttft: LatencyStats::from(&s.ttfts),
        tpot: LatencyStats::from(&s.tpots),
        slo_attainment: if s.n_completed > 0 {
            s.slo_ok as f64 / s.n_completed as f64
        } else {
            0.0
        },
        mean_queue_depth,
        max_queue_depth: trace.max_queue_depth,
        mean_batch_occupancy,
        utilization: t.ideal_cycles / (span * CLOCK_HZ),
        energy_pj: t.energy_pj,
        edp_under_load: (t.energy_pj * 1e-12) * t.makespan_s,
        kv_transfer_tokens: t.kv_transfer_tokens,
        kv_capacity_tokens: t.kv_capacity_tokens,
        kv_fragmentation: trace.kv_fragmentation(),
        kv_shared_tokens: t.kv_shared_tokens,
        kv_demand_tokens: t.kv_demand_tokens,
        kv_sharing_hit_rate: if t.kv_demand_tokens > 0 {
            t.kv_shared_tokens as f64 / t.kv_demand_tokens as f64
        } else {
            0.0
        },
        kv_prefix_materializations: t.kv_prefix_materializations,
        effective_concurrency: trace.effective_concurrency(),
        iters: trace.records,
    }
}

impl ServingMetrics {
    /// Scalar objective for the DSE (lower is better): negated
    /// SLO-constrained goodput with a small throughput tiebreak so the
    /// surrogate keeps gradient signal when attainment saturates at 0/1.
    /// Truncated runs score 0 (worse than any run with progress) so the
    /// search never prefers a configuration it could not fully simulate.
    pub fn objective(&self) -> f64 {
        if self.truncated {
            return 0.0;
        }
        -(self.slo_goodput_tps + 1e-3 * self.throughput_tps)
    }

    /// One-line summary for logs and examples.
    pub fn summary(&self) -> String {
        format!(
            "done {}/{} (rej {}, preempt {}) | {:.1} tok/s | ttft p99 {:.3}s | \
             tpot p99 {:.4}s | SLO {:.0}% | util {:.0}% | queue mean {:.1}",
            self.n_completed,
            self.n_arrived,
            self.n_rejected,
            self.n_preemptions,
            self.throughput_tps,
            self.ttft.p99,
            self.tpot.p99,
            100.0 * self.slo_attainment,
            100.0 * self.utilization,
            self.mean_queue_depth,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.5), 50.0); // ceil(0.5 * 100) = rank 50
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        // odd-length sample: p50 of {1,2,3} is the true median
        assert_eq!(percentile(&[1.0, 2.0, 3.0], 0.5), 2.0);
        assert_eq!(percentile(&[7.0], 0.5), 7.0);
    }

    #[test]
    fn latency_stats_of_constant_sample() {
        let s = LatencyStats::from(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.p99, 2.0);
        assert_eq!(s.n, 10);
    }

    fn totals(slo: SloSpec, makespan_s: f64) -> RunTotals {
        RunTotals {
            slo,
            max_batch: 8,
            makespan_s,
            energy_pj: 1e12,
            ideal_cycles: 0.0,
            gen_tokens: 21,
            n_preemptions: 0,
            distinct_shapes: 3,
            kv_transfer_tokens: 0,
            kv_capacity_tokens: 1024,
            kv_shared_tokens: 0,
            kv_demand_tokens: 0,
            kv_prefix_materializations: 0,
            truncated: false,
        }
    }

    #[test]
    fn finalize_counts_slo_and_rejections() {
        let slo = SloSpec::new(1.0, 0.1);
        let outcomes = vec![
            // meets both SLOs
            RequestOutcome {
                arrival_s: 0.0,
                input_len: 16,
                output_len: 11,
                first_token_s: Some(0.5),
                finish_s: Some(1.4), // tpot 0.09
                rejected: false,
            },
            // misses TPOT
            RequestOutcome {
                arrival_s: 0.0,
                input_len: 16,
                output_len: 11,
                first_token_s: Some(0.5),
                finish_s: Some(3.0), // tpot 0.25
                rejected: false,
            },
            RequestOutcome {
                arrival_s: 0.0,
                input_len: 16,
                output_len: 5,
                first_token_s: None,
                finish_s: None,
                rejected: true,
            },
        ];
        let m = finalize(&outcomes, TraceBuffer::new(0), &totals(slo, 10.0));
        assert!(!m.truncated);
        assert_eq!(m.n_arrived, 3);
        assert_eq!(m.n_completed, 2);
        assert_eq!(m.n_rejected, 1);
        assert_eq!(m.n_in_flight, 0);
        assert!((m.slo_attainment - 0.5).abs() < 1e-12);
        assert!((m.goodput_rps - 0.1).abs() < 1e-12);
        assert!((m.slo_goodput_tps - 1.1).abs() < 1e-12);
        assert!((m.throughput_tps - 2.1).abs() < 1e-12);
        assert!((m.edp_under_load - 10.0).abs() < 1e-9); // 1 J x 10 s
        assert!(m.objective() < 0.0);
        assert!(!m.summary().is_empty());
    }

    #[test]
    fn finalize_keeps_in_flight_ttft_samples() {
        let slo = SloSpec::new(1.0, 0.1);
        let outcomes = vec![
            // completed, fast first token
            RequestOutcome {
                arrival_s: 0.0,
                input_len: 16,
                output_len: 4,
                first_token_s: Some(0.2),
                finish_s: Some(0.5),
                rejected: false,
            },
            // truncated mid-decode: first token seen at 5.0s — the tail
            // sample a capped run must not lose
            RequestOutcome {
                arrival_s: 0.0,
                input_len: 16,
                output_len: 64,
                first_token_s: Some(5.0),
                finish_s: None,
                rejected: false,
            },
            // truncated while still queued: in flight, no TTFT sample
            RequestOutcome {
                arrival_s: 1.0,
                input_len: 16,
                output_len: 8,
                first_token_s: None,
                finish_s: None,
                rejected: false,
            },
        ];
        let mut t = totals(slo, 10.0);
        t.truncated = true;
        let m = finalize(&outcomes, TraceBuffer::new(0), &t);
        assert_eq!(m.n_completed, 1);
        assert_eq!(m.n_in_flight, 2);
        assert_eq!(m.ttft.n, 2, "in-flight TTFT sample must be included");
        assert_eq!(m.ttft.p99, 5.0, "tail TTFT comes from the in-flight request");
        assert_eq!(m.tpot.n, 1, "TPOT needs a completion");
        assert_eq!(m.objective(), 0.0, "truncated runs score 0");
    }

    fn rec(start_s: f64, end_s: f64, queue_depth: usize, kv_frac: f64) -> IterRecord {
        IterRecord {
            start_s,
            end_s,
            n_decode: 2,
            n_prefill: 1,
            prefill_tokens: 8,
            queue_depth,
            kv_frac,
            kv_frag: 0.25,
            n_running: 3,
        }
    }

    #[test]
    fn trace_buffer_caps_records_but_keeps_exact_aggregates() {
        let mut t = TraceBuffer::new(8);
        for i in 0..1000 {
            t.push(rec(i as f64, i as f64 + 1.0, i % 5, 0.5));
        }
        assert_eq!(t.n_iters(), 1000);
        assert!(t.records().len() < 16, "trace grew to {}", t.records().len());
        assert!((t.busy_s() - 1000.0).abs() < 1e-6);
        assert_eq!(t.max_queue_depth, 4);
        // duration-weighted means stay exact across downsampling
        assert!((t.kv_fragmentation() - 0.25).abs() < 1e-9);
        assert!((t.effective_concurrency() - 3.0).abs() < 1e-9);
        // records stay time-ordered with monotone spans
        for w in t.records().windows(2) {
            assert!(w[1].start_s >= w[0].start_s);
            assert!(w[0].end_s >= w[0].start_s);
        }
        // prefill tokens are conserved by pairwise merging
        let toks: u64 = t.records().iter().map(|r| r.prefill_tokens).sum();
        assert_eq!(toks, 8 * 1000);
        // kv_frac is a weighted average, so it stays in [0, 1]
        for r in t.records() {
            assert!(r.kv_frac >= 0.0 && r.kv_frac <= 1.0 + 1e-9);
        }
    }

    #[test]
    fn trace_buffer_unbounded_when_cap_zero() {
        let mut t = TraceBuffer::new(0);
        for i in 0..100 {
            t.push(rec(i as f64, i as f64 + 0.5, 0, 0.1));
        }
        assert_eq!(t.records().len(), 100);
        assert_eq!(t.n_iters(), 100);
    }
}

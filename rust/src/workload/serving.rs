//! System-level inference-serving orchestration strategies (paper §II,
//! §VI-F, Fig. 9): how prefill and decode requests are arranged into the
//! batches the accelerator sees.


use super::trace::Trace;
use super::Request;

/// SOTA serving strategies compared in paper Fig. 9 / Fig. 10.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServingStrategy {
    /// vLLM-style: a prefill request pauses decodes and runs as a
    /// standalone batch (type-separated workloads).
    Vllm,
    /// Orca-style iteration-level batching: the prefill request is
    /// co-executed with in-flight decode requests in one batch.
    Orca,
    /// Sarathi-style chunked prefill: the prefill is split into fixed-size
    /// chunks, each interleaved with a decode batch.
    ChunkedPrefill,
}

impl ServingStrategy {
    pub const ALL: [ServingStrategy; 3] = [
        ServingStrategy::Vllm,
        ServingStrategy::Orca,
        ServingStrategy::ChunkedPrefill,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            ServingStrategy::Vllm => "vLLM",
            ServingStrategy::Orca => "Orca",
            ServingStrategy::ChunkedPrefill => "ChunkedPrefill",
        }
    }
}

/// One batch group of a serving scenario: a batch composition plus how
/// many times it repeats during the modeled window (paper §VI-F defines
/// the GovReport-512TOPS workload as 1 prefill group + 5 decode groups).
#[derive(Debug, Clone)]
pub struct BatchGroup {
    pub label: String,
    pub batch: Vec<Request>,
    /// Repetition weight in the scenario objective.
    pub weight: f64,
    /// True when this group contains prefill work (selects the prefill
    /// micro-batch-size knob).
    pub has_prefill: bool,
}

/// A serving scenario: the batch groups jointly optimized by the DSE.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub groups: Vec<BatchGroup>,
}

impl Scenario {
    /// Pure prefill scenario (paper §VI-C comparisons: batch size 4).
    pub fn prefill(trace: &Trace, batch_size: usize, n_batches: usize) -> Self {
        let groups = trace
            .batches(true, batch_size, n_batches)
            .into_iter()
            .enumerate()
            .map(|(i, batch)| BatchGroup {
                label: format!("prefill[{i}]"),
                batch,
                weight: 1.0,
                has_prefill: true,
            })
            .collect();
        Scenario {
            name: "prefill".into(),
            groups,
        }
    }

    /// Pure decode scenario (paper §VI-C: batch size 128).
    pub fn decode(trace: &Trace, batch_size: usize, n_batches: usize) -> Self {
        let groups = trace
            .batches(false, batch_size, n_batches)
            .into_iter()
            .enumerate()
            .map(|(i, batch)| BatchGroup {
                label: format!("decode[{i}]"),
                batch,
                weight: 1.0,
                has_prefill: false,
            })
            .collect();
        Scenario {
            name: "decode".into(),
            groups,
        }
    }

    /// Mixed serving scenario of paper §VI-F: one prefill request of
    /// `prefill_len` arriving amid `decode_groups` batches of
    /// `decode_batch` in-flight decodes, orchestrated per `strategy`.
    pub fn serving(
        strategy: ServingStrategy,
        trace: &Trace,
        prefill_len: u64,
        decode_batch: usize,
        decode_groups: usize,
        chunk_size: u64,
    ) -> Self {
        let decodes: Vec<Vec<Request>> = (0..decode_groups)
            .map(|i| trace.decode_batch(decode_batch, i * decode_batch))
            .collect();
        let mut groups = Vec::new();
        match strategy {
            ServingStrategy::Vllm => {
                // separated: prefill alone, decodes untouched
                groups.push(BatchGroup {
                    label: "prefill-solo".into(),
                    batch: vec![Request::prefill(prefill_len)],
                    weight: 1.0,
                    has_prefill: true,
                });
                for (i, d) in decodes.into_iter().enumerate() {
                    groups.push(BatchGroup {
                        label: format!("decode[{i}]"),
                        batch: d,
                        weight: 1.0,
                        has_prefill: false,
                    });
                }
            }
            ServingStrategy::Orca => {
                // mixed: the whole prefill joins the first decode batch
                // (a prefill-only group when there are no decodes)
                let mut first = vec![Request::prefill(prefill_len)];
                let mut rest = decodes.into_iter();
                if let Some(d0) = rest.next() {
                    first.extend(d0);
                }
                groups.push(BatchGroup {
                    label: "mixed[0]".into(),
                    batch: first,
                    weight: 1.0,
                    has_prefill: true,
                });
                for (i, d) in rest.enumerate() {
                    groups.push(BatchGroup {
                        label: format!("decode[{}]", i + 1),
                        batch: d,
                        weight: 1.0,
                        has_prefill: false,
                    });
                }
            }
            ServingStrategy::ChunkedPrefill => {
                // the prefill is chunked across the decode batches; when
                // there are more chunks than decode batches the tail runs
                // as trailing chunk-only groups so the whole prompt is
                // always covered
                let n_chunks = prefill_len.div_ceil(chunk_size).max(1) as usize;
                let n_groups = decodes.len().max(n_chunks);
                let mut past = 0u64;
                for (i, d) in decodes
                    .into_iter()
                    .map(Some)
                    .chain(std::iter::repeat_with(|| None))
                    .take(n_groups)
                    .enumerate()
                {
                    let mut batch = Vec::new();
                    if i < n_chunks {
                        let len = chunk_size.min(prefill_len - past);
                        batch.push(Request::Prefill { len, past });
                        past += len;
                    }
                    if let Some(d) = d {
                        batch.extend(d);
                    }
                    groups.push(BatchGroup {
                        label: format!("chunk+decode[{i}]"),
                        batch,
                        weight: 1.0,
                        has_prefill: i < n_chunks,
                    });
                }
            }
        }
        Scenario {
            name: strategy.name().into(),
            groups,
        }
    }

    pub fn total_weight(&self) -> f64 {
        self.groups.iter().map(|g| g.weight).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TraceSpec;

    fn trace() -> Trace {
        Trace::new(&TraceSpec::govreport(), 256, 9)
    }

    #[test]
    fn vllm_separates_prefill() {
        let s = Scenario::serving(ServingStrategy::Vllm, &trace(), 9652, 128, 5, 512);
        assert_eq!(s.groups.len(), 6);
        assert_eq!(s.groups[0].batch.len(), 1);
        assert!(s.groups[0].has_prefill);
        assert!(s.groups[1..].iter().all(|g| !g.has_prefill));
    }

    #[test]
    fn orca_mixes_prefill_with_decodes() {
        let s = Scenario::serving(ServingStrategy::Orca, &trace(), 9652, 128, 5, 512);
        assert_eq!(s.groups.len(), 5);
        assert_eq!(s.groups[0].batch.len(), 129); // prefill + 128 decodes
        assert!(s.groups[0].batch[0].is_prefill());
        assert!(s.groups[0].batch[1..].iter().all(|r| !r.is_prefill()));
    }

    #[test]
    fn chunked_prefill_covers_whole_prompt() {
        let len = 9652u64;
        let chunk = 2048u64;
        let s = Scenario::serving(
            ServingStrategy::ChunkedPrefill,
            &trace(),
            len,
            128,
            5,
            chunk,
        );
        assert_eq!(s.groups.len(), 5);
        let covered: u64 = s
            .groups
            .iter()
            .flat_map(|g| g.batch.iter())
            .filter_map(|r| match r {
                Request::Prefill { len, .. } => Some(*len),
                _ => None,
            })
            .sum();
        assert_eq!(covered, len);
        // continuation chunks carry their past context
        match s.groups[1].batch[0] {
            Request::Prefill { past, .. } => assert_eq!(past, chunk),
            _ => panic!("second group must start with a chunk"),
        }
    }

    #[test]
    fn chunked_prefill_balances_batches() {
        let s = Scenario::serving(
            ServingStrategy::ChunkedPrefill,
            &trace(),
            9652,
            128,
            5,
            2048,
        );
        // every group has the decode payload; chunked groups have one more
        for g in &s.groups {
            assert!(g.batch.len() == 128 || g.batch.len() == 129);
        }
    }

    #[test]
    fn orca_zero_decode_groups_degrades_to_prefill_only() {
        // regression: decode_groups == 0 used to index decodes[0]
        let s = Scenario::serving(ServingStrategy::Orca, &trace(), 1024, 128, 0, 512);
        assert_eq!(s.groups.len(), 1);
        assert_eq!(s.groups[0].batch.len(), 1);
        assert!(s.groups[0].has_prefill);
        assert!(s.groups[0].batch[0].is_prefill());
        // the other strategies also tolerate an empty decode side
        let v = Scenario::serving(ServingStrategy::Vllm, &trace(), 1024, 128, 0, 512);
        assert_eq!(v.groups.len(), 1);
        let c = Scenario::serving(ServingStrategy::ChunkedPrefill, &trace(), 1024, 128, 0, 512);
        assert_eq!(c.groups.len(), 2); // 1024 / 512 = 2 chunk-only groups
        assert!(c.groups.iter().all(|g| g.has_prefill && g.batch.len() == 1));
    }

    #[test]
    fn chunked_prefill_keeps_trailing_chunks_when_groups_scarce() {
        // regression: chunks beyond the decode groups were silently
        // dropped, truncating the prompt
        let len = 9652u64;
        let chunk = 2048u64;
        let decode_groups = 2; // n_chunks = 5 > 2
        let s = Scenario::serving(
            ServingStrategy::ChunkedPrefill,
            &trace(),
            len,
            128,
            decode_groups,
            chunk,
        );
        assert_eq!(s.groups.len(), 5);
        let covered: u64 = s
            .groups
            .iter()
            .flat_map(|g| g.batch.iter())
            .filter_map(|r| match r {
                Request::Prefill { len, .. } => Some(*len),
                _ => None,
            })
            .sum();
        assert_eq!(covered, len, "whole prompt must be prefilled");
        // first two groups mix chunk + decodes; the tail is chunk-only
        for (i, g) in s.groups.iter().enumerate() {
            assert!(g.has_prefill);
            if i < decode_groups {
                assert_eq!(g.batch.len(), 129);
            } else {
                assert_eq!(g.batch.len(), 1);
            }
        }
        // past context still accumulates across the chunk-only tail
        match s.groups[4].batch[0] {
            Request::Prefill { past, .. } => assert_eq!(past, 4 * chunk),
            _ => panic!("tail group must be a chunk"),
        }
    }

    #[test]
    fn prefill_and_decode_scenarios() {
        let t = Trace::new(&TraceSpec::sharegpt(), 512, 1);
        let p = Scenario::prefill(&t, 4, 2);
        assert_eq!(p.groups.len(), 2);
        assert!(p.groups.iter().all(|g| g.batch.len() == 4));
        let d = Scenario::decode(&t, 128, 2);
        assert!(d.groups.iter().all(|g| g.batch.len() == 128));
        assert!((d.total_weight() - 2.0).abs() < 1e-12);
    }
}

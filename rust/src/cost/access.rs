//! Data-access flag determination — Algorithm 2 of the paper (§V-C).
//!
//! Walking the computation execution graph in scheduling order with a
//! per-chiplet status table determines, for every (micro-batch, layer):
//!
//! * `is_load_wei` — false when the previous layer executed on the same
//!   chiplet was the *same layer index of a different micro-batch*
//!   (weights stay resident, no reload);
//! * `is_write_out` — false when every successor of the evicted layer has
//!   already been scheduled while it was resident (its output never needs
//!   to reach off-chip memory);
//! * `input_srcs` — for every predecessor, whether its activation is read
//!   back from DRAM (the producer was evicted before this consumer ran)
//!   or fetched from another chiplet over the NoP / reused locally.

use crate::mapping::Mapping;
use crate::workload::Workload;

/// Where a consumer finds one predecessor's activation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InputSrc {
    /// Same chiplet, still resident: free.
    Local,
    /// Resident on another chiplet: NoP transfer from `chip`.
    Nop { chip: u16 },
    /// Evicted: read back from DRAM.
    Dram,
}

/// Per-task data-access flags, indexed `[mb * M + layer]`.
///
/// Input sources are stored flat (one entry per predecessor edge, in
/// schedule-independent `[task][pred]` order) to keep the hot path
/// allocation-free; access them via [`AccessFlags::srcs`].
#[derive(Debug, Clone, Default)]
pub struct AccessFlags {
    pub is_load_wei: Vec<bool>,
    pub is_write_out: Vec<bool>,
    srcs_flat: Vec<InputSrc>,
    srcs_off: Vec<u32>, // len n+1
    cols: usize,
}

impl AccessFlags {
    #[inline]
    pub fn idx(&self, mb: usize, layer: usize) -> usize {
        mb * self.cols + layer
    }

    /// Input sources of task `t`, parallel to that layer's `preds`.
    #[inline]
    pub fn srcs(&self, t: usize) -> &[InputSrc] {
        &self.srcs_flat[self.srcs_off[t] as usize..self.srcs_off[t + 1] as usize]
    }

    /// Reset to the all-default state for `pred`'s workload shape,
    /// reusing the existing buffers.
    fn prepare(&mut self, pred: &PredEdges) {
        let n = pred.rows * pred.cols;
        self.cols = pred.cols;
        self.is_load_wei.clear();
        self.is_load_wei.resize(n, true);
        self.is_write_out.clear();
        self.is_write_out.resize(n, true);
        self.srcs_off.clone_from(&pred.srcs_off);
        self.srcs_flat.clear();
        self.srcs_flat.resize(pred.srcs_off[n] as usize, InputSrc::Dram);
    }
}

/// Schedule-independent predecessor-edge structure of a workload: flat
/// pred-edge offsets and initial outstanding-successor counts. Depends
/// only on the workload graph, never on the mapping — the evaluation
/// engine computes it once per search and shares it read-only across
/// every fitness evaluation (see EXPERIMENTS.md #Perf).
#[derive(Debug, Clone, Default)]
pub struct PredEdges {
    /// Prefix offsets into the flat pred-edge array, len `n + 1`.
    pub srcs_off: Vec<u32>,
    /// layersNext seed: successor counts per task.
    pub succ_init: Vec<u32>,
    pub rows: usize,
    pub cols: usize,
}

impl PredEdges {
    pub fn build(workload: &Workload) -> Self {
        let rows = workload.num_micro_batches();
        let cols = workload.layers_per_mb;
        let n = rows * cols;
        let mut srcs_off = vec![0u32; n + 1];
        let mut succ_init = vec![0u32; n];
        for mb in 0..rows {
            for (l, layer) in workload.micro_batches[mb].layers.iter().enumerate() {
                srcs_off[mb * cols + l + 1] = layer.preds.len() as u32;
                for &p in &layer.preds {
                    succ_init[mb * cols + p] += 1;
                }
            }
        }
        for i in 0..n {
            srcs_off[i + 1] += srcs_off[i];
        }
        PredEdges {
            srcs_off,
            succ_init,
            rows,
            cols,
        }
    }
}

#[derive(Clone, Copy)]
struct ChipState {
    mb: usize,
    layer: usize,
    valid: bool,
}

/// Reusable working state of [`analyze_into`] — one per evaluation
/// thread, so the Algorithm-2 walk allocates nothing per individual.
#[derive(Default)]
pub struct AccessScratch {
    succ_left: Vec<u32>,
    resident_on: Vec<Option<u16>>,
    scheduled: Vec<bool>,
    chip_state: Vec<ChipState>,
}

/// Run Algorithm 2 over `workload` scheduled by `mapping`.
///
/// `force_writeout` on a layer (KV-cache management) keeps its
/// `is_write_out` pinned true.
pub fn analyze(workload: &Workload, mapping: &Mapping) -> AccessFlags {
    analyze_with_order(workload, mapping, &mapping.schedule_order())
}

/// `analyze` with a precomputed schedule order (the evaluator computes
/// the order once and shares it with the timeline simulation).
pub fn analyze_with_order(
    workload: &Workload,
    mapping: &Mapping,
    order: &[(usize, usize)],
) -> AccessFlags {
    let pred = PredEdges::build(workload);
    let mut scratch = AccessScratch::default();
    let mut flags = AccessFlags::default();
    analyze_into(workload, mapping, order, &pred, &mut scratch, &mut flags);
    flags
}

/// Allocation-free Algorithm 2: writes the flags into `flags`, reusing
/// `scratch` buffers and the search-invariant `pred` structure. This is
/// the evaluation engine's hot path (see EXPERIMENTS.md #Perf).
pub fn analyze_into(
    workload: &Workload,
    mapping: &Mapping,
    order: &[(usize, usize)],
    pred: &PredEdges,
    scratch: &mut AccessScratch,
    flags: &mut AccessFlags,
) {
    let cols = mapping.cols;
    debug_assert_eq!((pred.rows, pred.cols), (mapping.rows, mapping.cols));
    let n = pred.rows * pred.cols;
    flags.prepare(pred);

    // layersNext: outstanding successor counts per (mb, layer);
    // layersPrev-style residency: which chip (if any) holds each layer's
    // output right now. Algorithm 2's chipState generalised to also track
    // eviction so input sources can be classified.
    scratch.succ_left.clone_from(&pred.succ_init);
    scratch.resident_on.clear();
    scratch.resident_on.resize(n, None);
    scratch.scheduled.clear();
    scratch.scheduled.resize(n, false);
    let chips = mapping
        .layer_to_chip
        .iter()
        .map(|&c| c as usize)
        .max()
        .unwrap_or(0)
        + 1;
    scratch.chip_state.clear();
    scratch.chip_state.resize(
        chips,
        ChipState {
            mb: 0,
            layer: 0,
            valid: false,
        },
    );

    for &(mb, layer) in order {
        let t = mb * cols + layer;
        let curr_chip = mapping.chip(mb, layer);
        let node = &workload.micro_batches[mb].layers[layer];

        // weight-residency check (Alg. 2 line 10-11): previous occupant of
        // this chiplet ran the same layer index for a different micro-batch
        let st = scratch.chip_state[curr_chip as usize];
        if st.valid && st.layer == layer && st.mb != mb {
            flags.is_load_wei[t] = false;
        }

        // classify each predecessor's activation source
        let base = flags.srcs_off[t] as usize;
        for (i, &p) in node.preds.iter().enumerate() {
            let pt = mb * cols + p;
            flags.srcs_flat[base + i] = match scratch.resident_on[pt] {
                Some(c) if c == curr_chip => InputSrc::Local,
                Some(c) => InputSrc::Nop { chip: c },
                None => InputSrc::Dram,
            };
        }

        // consume predecessor outputs (layersNext erase, Alg. 2 line 13)
        for &p in &node.preds {
            let pt = mb * cols + p;
            scratch.succ_left[pt] = scratch.succ_left[pt].saturating_sub(1);
        }

        // evict the chiplet's previous occupant (Alg. 2 lines 12-16):
        // if all of its successors have now been scheduled, its output
        // never needs the DRAM round-trip.
        if st.valid {
            let prev_t = st.mb * cols + st.layer;
            if prev_t != t {
                if scratch.succ_left[prev_t] == 0
                    && scratch.scheduled[prev_t]
                    && !is_last_layer(st.layer, cols)
                    && !workload.micro_batches[st.mb].layers[st.layer].force_writeout()
                {
                    flags.is_write_out[prev_t] = false;
                }
                scratch.resident_on[prev_t] = None;
            }
        }

        scratch.chip_state[curr_chip as usize] = ChipState {
            mb,
            layer,
            valid: true,
        };
        scratch.resident_on[t] = Some(curr_chip);
        scratch.scheduled[t] = true;
    }
}

#[inline]
fn is_last_layer(layer: usize, cols: usize) -> bool {
    layer + 1 == cols
}

impl crate::workload::LayerNode {
    /// Paper: "Compass supports setting mandatory result write-out flags
    /// on a per-layer basis" (KV-cache management). KV-cache bytes are
    /// charged separately (`kv_write_bytes`); `force_out` additionally
    /// pins the layer's *activation* write-back when set.
    pub fn force_writeout(&self) -> bool {
        self.force_out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::presets;
    use crate::workload::{build_workload, ModelSpec, Request, WorkloadParams};

    fn workload(rows: usize) -> Workload {
        let m = ModelSpec::tiny();
        let batch = vec![Request::prefill(32); rows];
        build_workload(
            &m,
            &batch,
            &WorkloadParams {
                micro_batch_size: 1,
                tensor_parallel: 2,
                eval_blocks: 1,
            },
        )
    }

    #[test]
    fn pipeline_reuses_weights_across_micro_batches() {
        let w = workload(4);
        let cols = w.layers_per_mb;
        // pipeline: layer j pinned to chip j%C; segmentation cuts -> the
        // same chip re-runs the same layer for consecutive micro-batches
        let map = presets::pipeline_parallel(4, cols, cols.min(8));
        let flags = analyze(&w, &map);
        // first micro-batch loads weights
        assert!(flags.is_load_wei[flags.idx(0, 0)]);
        // later micro-batches of the same layer reuse them
        for mb in 1..4 {
            assert!(
                !flags.is_load_wei[flags.idx(mb, 0)],
                "mb {mb} should reuse resident weights"
            );
        }
    }

    #[test]
    fn data_parallel_reloads_weights_every_layer() {
        let w = workload(4);
        let cols = w.layers_per_mb;
        let map = presets::data_parallel(4, cols, 4);
        let flags = analyze(&w, &map);
        // each chip runs a full column of *different* layers: no reuse
        assert!(flags.is_load_wei.iter().all(|&x| x));
    }

    #[test]
    fn chain_on_one_chip_skips_writeout_and_reads_locally() {
        let w = workload(1);
        let cols = w.layers_per_mb;
        let map = presets::data_parallel(1, cols, 1); // everything on chip 0
        let flags = analyze(&w, &map);
        // single-successor chain: producer evicted only when its consumer
        // replaces it, and the consumer has consumed it -> no write-out
        let qkv = flags.idx(0, 0);
        assert!(!flags.is_write_out[qkv], "qkv output consumed on-chip");
        // consumers read locally
        assert!(flags.srcs(flags.idx(0, 1))
            .iter()
            .all(|s| *s == InputSrc::Local));
        // final layer always writes out
        assert!(flags.is_write_out[flags.idx(0, cols - 1)]);
    }

    #[test]
    fn model_parallel_moves_activations_over_nop() {
        let w = workload(1);
        let cols = w.layers_per_mb;
        let map = presets::model_parallel(cols, 4);
        let flags = analyze(&w, &map);
        // layer 1 (mha) runs on chip 1, its predecessor qkv on chip 0,
        // still resident -> NoP source
        let srcs = flags.srcs(flags.idx(0, 1));
        assert_eq!(srcs.len(), 1);
        assert_eq!(srcs[0], InputSrc::Nop { chip: 0 });
    }

    #[test]
    fn evicted_producer_forces_dram_readback() {
        // two micro-batches, layer-first schedule, single chip: by the
        // time mb1's consumer runs, mb0 finished; within mb0, producer
        // evicted by the next layer on the same chip before a *later*
        // multi-hop consumer reads it -> that consumer reads from DRAM.
        let m = ModelSpec::tiny();
        let batch = vec![Request::prefill(16); 2];
        let w = build_workload(
            &m,
            &batch,
            &WorkloadParams {
                micro_batch_size: 1,
                tensor_parallel: 4,
                eval_blocks: 2,
            },
        );
        let cols = w.layers_per_mb;
        let map = presets::data_parallel(2, cols, 1);
        let flags = analyze(&w, &map);
        // proj (idx 2) feeds all 4 ffn1 slices; on a single chip proj is
        // evicted by ffn1.0 before ffn1.1..3 run -> they read from DRAM
        let srcs = flags.srcs(flags.idx(0, 4)); // ffn1.1
        assert_eq!(srcs[0], InputSrc::Dram);
        // and proj must therefore keep its write-out
        assert!(flags.is_write_out[flags.idx(0, 2)]);
    }

    #[test]
    fn flags_cover_every_task() {
        let w = workload(2);
        let map = presets::pipeline_parallel(2, w.layers_per_mb, 4);
        let flags = analyze(&w, &map);
        assert_eq!(flags.is_load_wei.len(), 2 * w.layers_per_mb);
        
        for mb in 0..2 {
            for l in 0..w.layers_per_mb {
                let t = flags.idx(mb, l);
                assert_eq!(
                    flags.srcs(t).len(),
                    w.micro_batches[mb].layers[l].preds.len()
                );
            }
        }
    }
}

//! Tiny leveled stderr logger for the CLI.
//!
//! Study tables and CSV stay on stdout (machine-parseable); all
//! `[compass]` progress chatter goes through here to stderr, gated by
//! a process-wide level: `--quiet` silences it, `-v` adds debug lines.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Quiet = 0,
    Info = 1,
    Debug = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Quiet,
        1 => Level::Info,
        _ => Level::Debug,
    }
}

/// Progress chatter — on by default, silenced by `--quiet`.
pub fn info(msg: &str) {
    if level() >= Level::Info {
        eprintln!("[compass] {msg}");
    }
}

/// Extra detail — only under `-v`.
pub fn debug(msg: &str) {
    if level() >= Level::Debug {
        eprintln!("[compass] {msg}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let prev = level();
        set_level(Level::Quiet);
        assert_eq!(level(), Level::Quiet);
        assert!(Level::Debug > Level::Info && Level::Info > Level::Quiet);
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(prev);
    }
}

//! Quickstart: the whole Compass stack in ~40 lines.
//!
//! Builds an LLM serving workload from a synthetic ShareGPT-like trace,
//! co-explores hardware (BO over the heterogeneous chiplet space; GP on
//! PJRT artifacts when `make artifacts` has run) and mapping (GA over the
//! computation-execution-graph encoding), then prints the winning design.
//!
//! Run: `cargo run --release --example quickstart`

use compass::arch::HwSpace;
use compass::dse::{compass_dse, DseConfig};
use compass::experiments::{make_gp, model_for_tops};
use compass::runtime::Runtime;
use compass::workload::serving::Scenario;
use compass::workload::trace::{Trace, TraceSpec};

fn main() {
    // 1. workload: a prefill scenario sampled from a dialogue-like trace
    let trace = Trace::new(&TraceSpec::sharegpt(), 256, 7);
    let scenario = Scenario::prefill(&trace, 4, 2);
    let model = model_for_tops(64.0);
    println!(
        "workload: {} | trace means in/out = {:.0}/{:.0} tokens",
        model.name,
        trace.mean_in(),
        trace.mean_out()
    );

    // 2. hardware space: the paper's Table-IV candidates at 64 TOPS
    let space = HwSpace::paper(64.0);

    // 3. co-explore (reduced single-core budget; DseConfig::paper() for
    //    the full GA 120x100 / BO 100-round search)
    let rt = Runtime::from_env().ok();
    let mut gp = make_gp(rt.as_ref());
    let out = compass_dse(&scenario, &model, &space, &DseConfig::reduced(), gp.as_mut());

    // 4. results
    println!("surrogate backend : {}", out.backend);
    println!("best hardware     : {}", out.hw.describe());
    println!(
        "latency {:.3e} cycles | energy {:.3e} pJ | MC ${:.0} | L*E*MC {:.3e}",
        out.eval.latency_cycles,
        out.eval.energy_pj,
        out.eval.mc_usd,
        out.eval.total_cost()
    );
    println!(
        "mapping[0]: {} micro-batches x {} layers on {} chiplets ({} segments)",
        out.mappings[0].rows,
        out.mappings[0].cols,
        out.hw.num_chiplets(),
        out.mappings[0].segments().len()
    );
    let first = out.bo_history.first().copied().unwrap_or(f64::NAN);
    let last = out.bo_history.last().copied().unwrap_or(f64::NAN);
    println!(
        "BO convergence    : {:.3e} -> {:.3e} ({:.1}% better than the initial design)",
        first,
        last,
        100.0 * (first - last) / first
    );
}

//! Timed request streams: arrival processes layered on the paper's
//! sequence-length distributions (`TraceSpec`), feeding the serving
//! simulator with (arrival time, input length, output length) triples.

use crate::util::Rng;
use crate::workload::trace::TraceSpec;

/// One request of a serving trace: a prompt of `input_len` tokens
/// arriving at `arrival_s`, expecting `output_len` generated tokens.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TimedRequest {
    pub id: usize,
    pub arrival_s: f64,
    pub input_len: u64,
    pub output_len: u64,
}

/// A timed request trace, sorted by arrival time.
#[derive(Debug, Clone)]
pub struct RequestStream {
    pub name: String,
    pub requests: Vec<TimedRequest>,
    /// Mean request arrival rate used to generate the stream (req/s).
    pub rate_rps: f64,
    pub seed: u64,
}

impl RequestStream {
    /// Poisson arrivals at `rate_rps` requests/s: exponential
    /// inter-arrival gaps layered on lengths sampled from `spec`.
    /// Deterministic for a fixed `seed`.
    pub fn poisson(spec: &TraceSpec, rate_rps: f64, n: usize, seed: u64) -> Self {
        Self::generate(spec, rate_rps, n, seed, true)
    }

    /// Fixed-rate arrivals: one request every `1/rate_rps` seconds.
    pub fn fixed_rate(spec: &TraceSpec, rate_rps: f64, n: usize, seed: u64) -> Self {
        Self::generate(spec, rate_rps, n, seed, false)
    }

    /// Parse a timestamped production arrival trace in the
    /// Azure-LLM-inference CSV style: one `arrival_s,prompt_len,gen_len`
    /// triple per line (extra trailing fields are ignored). Lines that
    /// are empty or start with `#` are skipped anywhere; a non-numeric
    /// first field is tolerated only *before* the first data row (a
    /// header) — after that it is a parse error, so a corrupted line
    /// mid-file can never silently drop a request. Requests are sorted
    /// by arrival time
    /// (stable, so ties keep file order) and re-numbered `0..n` in that
    /// order; `rate_rps` is derived from the arrival span (degenerate
    /// traces — one row, or all rows at one timestamp — report
    /// `n / max(span, 1 s)` rather than a silent `1.0`). Parsing is
    /// pure: the same text always yields the same stream, so replays
    /// are bit-reproducible like the synthetic generators.
    pub fn from_trace(name: &str, csv: &str) -> Result<Self, String> {
        let mut rows: Vec<(f64, u64, u64)> = Vec::new();
        for (ln, raw) in csv.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut fields = line.split(',').map(str::trim);
            let (Some(a), Some(b), Some(c)) = (fields.next(), fields.next(), fields.next())
            else {
                return Err(format!(
                    "{name}: line {}: expected `arrival_s,prompt_len,gen_len`, got {line:?}",
                    ln + 1
                ));
            };
            let Ok(arrival_s) = a.parse::<f64>() else {
                if rows.is_empty() {
                    continue; // header row (e.g. "arrival_s,prompt_len,gen_len")
                }
                return Err(format!("{name}: line {}: bad arrival time {a:?}", ln + 1));
            };
            if !arrival_s.is_finite() || arrival_s < 0.0 {
                return Err(format!("{name}: line {}: bad arrival time {a:?}", ln + 1));
            }
            let input_len: u64 = b
                .parse()
                .map_err(|_| format!("{name}: line {}: bad prompt length {b:?}", ln + 1))?;
            let output_len: u64 = c
                .parse()
                .map_err(|_| format!("{name}: line {}: bad gen length {c:?}", ln + 1))?;
            rows.push((arrival_s, input_len, output_len));
        }
        if rows.is_empty() {
            return Err(format!("{name}: trace contains no requests"));
        }
        rows.sort_by(|x, y| x.0.total_cmp(&y.0));
        let span = rows.last().unwrap().0 - rows[0].0;
        let rate_rps = if span > 1e-9 {
            (rows.len() - 1) as f64 / span
        } else {
            // degenerate traces (a single row, or identical timestamps)
            // have no measurable span: report `n / max(span, 1 s)` — n
            // requests over a nominal 1-second window — instead of a
            // silent 1.0 that hid the trace size
            rows.len() as f64 / span.max(1.0)
        };
        let requests = rows
            .into_iter()
            .enumerate()
            .map(|(id, (arrival_s, input_len, output_len))| TimedRequest {
                id,
                arrival_s,
                input_len: input_len.max(1),
                output_len: output_len.max(1),
            })
            .collect();
        Ok(RequestStream {
            name: name.to_string(),
            requests,
            rate_rps,
            seed: 0,
        })
    }

    /// [`RequestStream::from_trace`] loaded from a CSV file.
    pub fn from_trace_file<P: AsRef<std::path::Path>>(path: P) -> Result<Self, String> {
        let p = path.as_ref();
        let csv =
            std::fs::read_to_string(p).map_err(|e| format!("{}: {e}", p.display()))?;
        let name = p
            .file_stem()
            .and_then(|s| s.to_str())
            .unwrap_or("trace")
            .to_string();
        Self::from_trace(&name, &csv)
    }

    fn generate(spec: &TraceSpec, rate_rps: f64, n: usize, seed: u64, poisson: bool) -> Self {
        assert!(rate_rps > 0.0, "arrival rate must be positive");
        let lens = spec.sample(n, seed);
        let mut gap_rng = Rng::seed_from_u64(seed ^ 0x5157_6172_7269_7661); // "arrival"
        let mut t = 0.0f64;
        let requests = lens
            .into_iter()
            .enumerate()
            .map(|(id, (input_len, output_len))| {
                let gap = if poisson {
                    // exponential inter-arrival: -ln(1 - u) / rate
                    let u = gap_rng.gen_f64();
                    -(1.0 - u).max(f64::EPSILON).ln() / rate_rps
                } else {
                    1.0 / rate_rps
                };
                t += gap;
                TimedRequest {
                    id,
                    arrival_s: t,
                    input_len,
                    output_len,
                }
            })
            .collect();
        RequestStream {
            name: format!("{}req@{:.3}rps", n, rate_rps),
            requests,
            rate_rps,
            seed,
        }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Arrival time of the last request (the load window).
    pub fn horizon_s(&self) -> f64 {
        self.requests.last().map_or(0.0, |r| r.arrival_s)
    }

    /// Total output tokens the stream asks for.
    pub fn total_output_tokens(&self) -> u64 {
        self.requests.iter().map(|r| r.output_len).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> TraceSpec {
        TraceSpec::sharegpt()
    }

    #[test]
    fn arrivals_sorted_and_deterministic() {
        let a = RequestStream::poisson(&spec(), 2.0, 64, 9);
        let b = RequestStream::poisson(&spec(), 2.0, 64, 9);
        assert_eq!(a.requests, b.requests);
        for w in a.requests.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        let c = RequestStream::poisson(&spec(), 2.0, 64, 10);
        assert_ne!(a.requests, c.requests);
    }

    #[test]
    fn poisson_mean_rate_matches() {
        let s = RequestStream::poisson(&spec(), 4.0, 2000, 3);
        let rate = s.len() as f64 / s.horizon_s();
        assert!((rate - 4.0).abs() / 4.0 < 0.1, "rate {rate}");
    }

    #[test]
    fn trace_loader_parses_sorts_and_is_deterministic() {
        let csv = "\
# comment line
arrival_s,prompt_len,gen_len
0.50,128,12
0.10,64,8,extra-field-ignored

0.10,32,4
0.90,0,0
";
        let a = RequestStream::from_trace("t", csv).unwrap();
        let b = RequestStream::from_trace("t", csv).unwrap();
        assert_eq!(a.requests, b.requests, "parsing must be deterministic");
        assert_eq!(a.len(), 4);
        // sorted by arrival; the 0.10 tie keeps file order (64 first)
        assert_eq!(a.requests[0].arrival_s, 0.10);
        assert_eq!(a.requests[0].input_len, 64);
        assert_eq!(a.requests[1].input_len, 32);
        assert_eq!(a.requests[3].arrival_s, 0.90);
        // ids are re-numbered in arrival order
        assert_eq!(
            a.requests.iter().map(|r| r.id).collect::<Vec<_>>(),
            vec![0, 1, 2, 3]
        );
        // zero lengths are clamped to 1 like the synthetic generators
        assert_eq!(a.requests[3].input_len, 1);
        assert_eq!(a.requests[3].output_len, 1);
        // rate over the span: 3 gaps / 0.8 s
        assert!((a.rate_rps - 3.0 / 0.8).abs() < 1e-9, "rate {}", a.rate_rps);
    }

    /// Degenerate traces report a documented `n / max(span, 1 s)` rate
    /// rather than the old silent `rate_rps = 1.0` fallback.
    #[test]
    fn trace_loader_degenerate_rates_are_documented_not_silent() {
        // a single row has no span: 1 request / 1 s nominal window
        let one = RequestStream::from_trace("t", "2.5,64,8\n").unwrap();
        assert_eq!(one.len(), 1);
        assert!((one.rate_rps - 1.0).abs() < 1e-12, "rate {}", one.rate_rps);
        // identical timestamps: 3 requests / 1 s nominal window — the
        // trace size is no longer hidden behind a constant
        let same = RequestStream::from_trace("t", "0.1,8,4\n0.1,16,4\n0.1,32,4\n").unwrap();
        assert_eq!(same.len(), 3);
        assert!((same.rate_rps - 3.0).abs() < 1e-12, "rate {}", same.rate_rps);
        // a sub-nanosecond span still counts as degenerate
        let tiny = RequestStream::from_trace("t", "0.1,8,4\n0.1000000001,8,4\n").unwrap();
        assert!((tiny.rate_rps - 2.0).abs() < 1e-9, "rate {}", tiny.rate_rps);
    }

    #[test]
    fn trace_loader_rejects_garbage() {
        assert!(RequestStream::from_trace("t", "").is_err());
        assert!(RequestStream::from_trace("t", "# only comments\n").is_err());
        assert!(RequestStream::from_trace("t", "0.1,not-a-number,4\n").is_err());
        assert!(RequestStream::from_trace("t", "0.1,8\n").is_err());
        assert!(RequestStream::from_trace("t", "-1.0,8,4\n").is_err());
        assert!(RequestStream::from_trace("t", "nan,8,4\n").is_err());
        // a corrupted line after real data must error, not vanish
        assert!(RequestStream::from_trace("t", "0.1,8,4\n0,4x,300,64\n").is_err());
        assert!(RequestStream::from_trace("t", "0.1,8,4\ntruncated-line,3,3\n").is_err());
    }

    #[test]
    fn bundled_azure_fixture_loads() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/data/traces/azure_tiny.csv");
        let s = RequestStream::from_trace_file(path).expect("bundled fixture parses");
        assert_eq!(s.name, "azure_tiny");
        assert_eq!(s.len(), 10);
        for w in s.requests.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        assert!(s.rate_rps > 0.0);
        assert!(s.total_output_tokens() > 0);
        // deterministic reload
        let t = RequestStream::from_trace_file(path).unwrap();
        assert_eq!(s.requests, t.requests);
    }

    #[test]
    fn fixed_rate_is_evenly_spaced() {
        let s = RequestStream::fixed_rate(&spec(), 2.0, 10, 1);
        for w in s.requests.windows(2) {
            assert!((w[1].arrival_s - w[0].arrival_s - 0.5).abs() < 1e-12);
        }
        assert_eq!(s.requests[0].id, 0);
        assert!(s.total_output_tokens() > 0);
    }
}

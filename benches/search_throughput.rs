//! Search-layer throughput bench: study-cells/s and DSE-candidates/s at
//! `COMPASS_THREADS=1` vs N, plus the shared [`CostCache`] hit rate on a
//! warm re-run (EXPERIMENTS.md "Search-layer parallelism & cost cache").
//!
//! Results are bit-identical at any thread count — this bench measures
//! wall clock only. The budget recorded in `BENCH_engine_micro.json`
//! (`search_throughput`) tracks the threads=1 -> N cell-throughput
//! speedup and the warm-cache speedup.
//!
//! [`CostCache`]: compass::sim::CostCache

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::dse::{self, ResilienceSpace};
use compass::experiments as exp;
use compass::sim::{self, CostCache, Frontend, SimConfig};
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

fn set_threads(n: usize) {
    std::env::set_var("COMPASS_THREADS", n.to_string());
}

/// One full `sim-study` grid (rate x strategy) on fixed hardware;
/// returns (cells, wall seconds).
fn run_study(scene: &exp::SimScene, hw: &HwConfig, cfg: &SimConfig) -> (usize, f64) {
    let t0 = std::time::Instant::now();
    let rows = exp::sim_serving_study(scene, hw, cfg, 7);
    (rows.len(), t0.elapsed().as_secs_f64())
}

/// One `search_resilience` sweep (redundancy x retry x drain); returns
/// (candidates, wall seconds).
fn run_dse(
    stream: &sim::RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    space: &ResilienceSpace,
    schedule: &sim::FaultSchedule,
) -> (usize, f64) {
    let t0 = std::time::Instant::now();
    let (_, rows) = dse::search_resilience(
        stream,
        model,
        hw,
        cfg,
        &Frontend::baseline(),
        space,
        schedule,
    );
    (rows.len(), t0.elapsed().as_secs_f64())
}

fn main() {
    // capture the parallel width before pinning COMPASS_THREADS
    let n_threads = compass::cost::engine::default_threads().max(2);
    let cache = CostCache::global();

    // --- study cells: rate x strategy grid, gpt3-7b on a fixed package
    let mut scene = exp::SimScene::new("sharegpt", 64.0, 12);
    scene.rates_rps = vec![0.5, 1.0, 2.0, 4.0];
    let hw = exp::sim_default_hw(scene.tops);
    let cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
    println!(
        "search_throughput: sim-study grid [{}], {} requests x {} rates, threads 1 vs {}",
        scene.label(),
        scene.n_requests,
        scene.rates_rps.len(),
        n_threads
    );

    set_threads(1);
    cache.clear();
    let (cells, serial_s) = run_study(&scene, &hw, &cfg);
    let serial_rate = cells as f64 / serial_s.max(1e-12);
    println!(
        "    threads=1  cold: {cells} cells in {serial_s:.2}s -> {serial_rate:.2} cells/s"
    );

    set_threads(n_threads);
    cache.clear();
    let (_, par_s) = run_study(&scene, &hw, &cfg);
    let par_rate = cells as f64 / par_s.max(1e-12);
    println!(
        "    threads={n_threads}  cold: {cells} cells in {par_s:.2}s -> {par_rate:.2} cells/s \
         | speedup {:.2}x",
        serial_s / par_s.max(1e-12)
    );

    // warm re-run: every shape is already in the shared cache
    let s0 = cache.stats();
    let (_, warm_s) = run_study(&scene, &hw, &cfg);
    let s1 = cache.stats();
    let probes = (s1.hits - s0.hits) + (s1.misses - s0.misses);
    let hit_rate = (s1.hits - s0.hits) as f64 / probes.max(1) as f64;
    println!(
        "    threads={n_threads}  warm: {cells} cells in {warm_s:.2}s -> {:.2} cells/s \
         | shared-cache hit rate {:.1}% ({} entries) | warm speedup {:.2}x",
        cells as f64 / warm_s.max(1e-12),
        100.0 * hit_rate,
        s1.entries,
        par_s / warm_s.max(1e-12)
    );

    // --- DSE candidates: resilience grid on a tiny model so the bench
    // measures the search loop, not the cost model
    let model = ModelSpec::tiny();
    let thw = HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    );
    let spec = TraceSpec {
        mean_in: 128.0,
        mean_out: 32.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 8192,
        shared_prefix_tokens: 0,
    };
    let mut dcfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
    dcfg.max_batch = 8;
    dcfg.eval_blocks = 1;
    dcfg.ctx_bucket = 64;
    let probe = sim::probe(&model, &thw, &dcfg, &spec);
    dcfg.slo = probe.slo(3.0, 4.0);
    let stream =
        sim::RequestStream::poisson(&spec, 2.0 * 0.9 * probe.capacity_rps(), 48, 7);
    let space = ResilienceSpace::new(2);
    let schedule = sim::FaultSchedule::seeded(2, stream.horizon_s(), 1, 1, 17);

    set_threads(1);
    cache.clear();
    let (cands, dse_serial_s) = run_dse(&stream, &model, &thw, &dcfg, &space, &schedule);
    println!(
        "    dse threads=1:  {cands} candidates in {dse_serial_s:.2}s -> {:.2} candidates/s",
        cands as f64 / dse_serial_s.max(1e-12)
    );
    set_threads(n_threads);
    cache.clear();
    let (_, dse_par_s) = run_dse(&stream, &model, &thw, &dcfg, &space, &schedule);
    println!(
        "    dse threads={n_threads}: {cands} candidates in {dse_par_s:.2}s -> \
         {:.2} candidates/s | speedup {:.2}x",
        cands as f64 / dse_par_s.max(1e-12),
        dse_serial_s / dse_par_s.max(1e-12)
    );
    println!(
        "budget (BENCH_engine_micro.json/search_throughput): cold speedup >= 2x and \
         warm-cache speedup >= 1.5x at 8 threads on an 8-core host"
    );
}

//! Bitwise-equivalence properties for decode fast-forward (PR 10).
//!
//! Coalesced stepping (`Scheduler::try_fast_forward`) costs a quiescent
//! decode stretch once and replays the per-iteration scalar updates in
//! the exact floating-point operation order of the naive loop, so it
//! must not move a single bit anywhere: every metric, per-replica
//! breakdown, per-request timing, fault counter, and trace byte is
//! compared between
//!
//! * coalesce-on (`COMPASS_COALESCE=1`, the default) and coalesce-off
//!   (`COMPASS_COALESCE=0`, the naive per-iteration loop) runs,
//! * at one worker thread and eight (coalescing happens inside
//!   `Scheduler::advance_to`, under the parallel replica stepping),
//! * across all three `ServingStrategy` policies, token-granular /
//!   paged / prefix-sharing KV layouts, homogeneous and disaggregated
//!   fleets, shed / rebalance front ends, and seeded fault storms.
//!
//! The `COMPASS_COALESCE` and `COMPASS_THREADS` variables are
//! process-global, so every mutation here is serialized behind one
//! static mutex and restored afterwards (the same discipline as
//! `hotpath_equivalence.rs`).

use std::sync::Mutex;

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{
    self, DrainSpec, FaultSchedule, FleetConfig, Frontend, KvSpec, MappingPolicy, RebalanceSpec,
    ResilienceSpec, RetryPolicy, RouterPolicy, Scheduler, SimConfig, SloSpec, SpanCollector,
};
use compass::util::Rng;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

/// Serializes `COMPASS_COALESCE`/`COMPASS_THREADS` mutation across the
/// whole test binary.
static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the decode fast-forward switch and the pool thread
/// count pinned, restoring the previous environment afterwards (a
/// poisoned guard is fine: the next caller re-acquires and re-sets).
fn with_coalesce<T>(on: bool, threads: usize, f: impl FnOnce() -> T) -> T {
    let _g = ENV_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let old_c = std::env::var("COMPASS_COALESCE").ok();
    let old_t = std::env::var("COMPASS_THREADS").ok();
    std::env::set_var("COMPASS_COALESCE", if on { "1" } else { "0" });
    std::env::set_var("COMPASS_THREADS", threads.to_string());
    let out = f();
    match old_c {
        Some(v) => std::env::set_var("COMPASS_COALESCE", v),
        None => std::env::remove_var("COMPASS_COALESCE"),
    }
    match old_t {
        Some(v) => std::env::set_var("COMPASS_THREADS", v),
        None => std::env::remove_var("COMPASS_THREADS"),
    }
    out
}

fn tiny_hw() -> HwConfig {
    HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    )
}

/// Decode-heavy trace spec (long outputs make real quiescent stretches)
/// with an optional shared system prompt.
fn decode_spec(prefix: u64) -> TraceSpec {
    TraceSpec {
        mean_in: 48.0,
        mean_out: 40.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 4096,
        shared_prefix_tokens: prefix,
    }
}

fn cfg_for(strategy: ServingStrategy, kv_tokens: u64) -> SimConfig {
    let mut cfg = SimConfig::new(strategy);
    cfg.policy = MappingPolicy::Pipeline;
    cfg.max_batch = 6;
    cfg.chunk_tokens = 24;
    cfg.kv_budget_tokens = kv_tokens;
    cfg.ctx_bucket = 32;
    cfg.eval_blocks = 1;
    cfg.slo = SloSpec::new(0.5, 0.1);
    cfg.max_iterations = 500_000;
    cfg
}

fn stream_for(spec: &TraceSpec, rate_scale: f64, n: usize, seed: u64, cfg: &SimConfig) -> sim::RequestStream {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let probe = sim::probe(&model, &hw, cfg, spec);
    sim::RequestStream::poisson(spec, rate_scale * probe.capacity_rps(), n, seed)
}

fn assert_serving_bitwise(a: &sim::ServingMetrics, b: &sim::ServingMetrics, ctx: &str) {
    assert_eq!(a.n_arrived, b.n_arrived, "{ctx}: arrived");
    assert_eq!(a.n_completed, b.n_completed, "{ctx}: completed");
    assert_eq!(a.n_rejected, b.n_rejected, "{ctx}: rejected");
    assert_eq!(a.n_preemptions, b.n_preemptions, "{ctx}: preemptions");
    assert_eq!(a.n_iterations, b.n_iterations, "{ctx}: iterations");
    assert_eq!(a.gen_tokens, b.gen_tokens, "{ctx}: gen tokens");
    assert_eq!(a.distinct_shapes, b.distinct_shapes, "{ctx}: shapes");
    assert_eq!(a.max_queue_depth, b.max_queue_depth, "{ctx}: max queue");
    for (name, x, y) in [
        ("makespan", a.makespan_s, b.makespan_s),
        ("busy", a.busy_s, b.busy_s),
        ("energy", a.energy_pj, b.energy_pj),
        ("ttft p99", a.ttft.p99, b.ttft.p99),
        ("tpot p99", a.tpot.p99, b.tpot.p99),
        ("ttft mean", a.ttft.mean, b.ttft.mean),
        ("tpot mean", a.tpot.mean, b.tpot.mean),
        ("slo goodput", a.slo_goodput_tps, b.slo_goodput_tps),
        ("occupancy", a.mean_batch_occupancy, b.mean_batch_occupancy),
        ("mean queue", a.mean_queue_depth, b.mean_queue_depth),
        ("utilization", a.utilization, b.utilization),
        ("kv frag", a.kv_fragmentation, b.kv_fragmentation),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}");
    }
}

/// Per-replica metrics, fault counters and per-request timings, all via
/// `to_bits`.
fn assert_fleet_bitwise(a: &sim::FleetMetrics, b: &sim::FleetMetrics, ctx: &str) {
    assert_eq!(a.per_replica.len(), b.per_replica.len(), "{ctx}: replicas");
    for (i, (x, y)) in a.per_replica.iter().zip(&b.per_replica).enumerate() {
        assert_serving_bitwise(x, y, &format!("{ctx}: replica {i}"));
    }
    assert_eq!(a.n_arrived, b.n_arrived, "{ctx}: arrived");
    assert_eq!(a.n_completed, b.n_completed, "{ctx}: completed");
    assert_eq!(a.n_rejected, b.n_rejected, "{ctx}: rejected");
    assert_eq!(a.n_shed, b.n_shed, "{ctx}: shed");
    assert_eq!(a.n_rebalanced, b.n_rebalanced, "{ctx}: rebalanced");
    assert_eq!(a.faults.n_failed, b.faults.n_failed, "{ctx}: failed");
    assert_eq!(a.faults.n_retried, b.faults.n_retried, "{ctx}: retried");
    assert_eq!(a.faults.n_lost, b.faults.n_lost, "{ctx}: lost");
    assert_eq!(a.faults.n_drained, b.faults.n_drained, "{ctx}: drained");
    for (name, x, y) in [
        ("makespan", a.makespan_s, b.makespan_s),
        ("energy", a.energy_pj, b.energy_pj),
        ("ttft p99", a.ttft.p99, b.ttft.p99),
        ("tpot p99", a.tpot.p99, b.tpot.p99),
        ("slo goodput", a.slo_goodput_tps, b.slo_goodput_tps),
        ("imbalance", a.load_imbalance, b.load_imbalance),
    ] {
        assert_eq!(x.to_bits(), y.to_bits(), "{ctx}: {name}");
    }
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{ctx}: outcomes");
    for (i, (x, y)) in a.outcomes.iter().zip(&b.outcomes).enumerate() {
        assert_eq!(
            x.arrival_s.to_bits(),
            y.arrival_s.to_bits(),
            "{ctx}: outcome {i} arrival"
        );
        assert_eq!(
            x.first_token_s.map(f64::to_bits),
            y.first_token_s.map(f64::to_bits),
            "{ctx}: outcome {i} first token"
        );
        assert_eq!(
            x.finish_s.map(f64::to_bits),
            y.finish_s.map(f64::to_bits),
            "{ctx}: outcome {i} finish"
        );
        assert_eq!(x.rejected, y.rejected, "{ctx}: outcome {i} rejected");
    }
}

/// Single replica, coalesce on vs off, across all three strategies and
/// token-granular / tight / paged / prefix-sharing KV layouts on
/// randomized decode-heavy streams. The tight budget exercises the
/// KV-pressure stretch break (evictions end a stretch); the paged
/// layouts exercise per-iteration block growth from the phase residues;
/// the prefix layout checks that shared blocks never perturb a stretch.
#[test]
fn serving_coalesced_matches_naive_across_strategies_and_kv_layouts() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut rng = Rng::seed_from_u64(0x0C0A);
    let layouts: [(&str, KvSpec, u64, u64); 4] = [
        ("token-ample", KvSpec::token_granular(), 4096, 0),
        ("token-tight", KvSpec::token_granular(), 448, 0),
        ("paged-16", KvSpec::paged(16), 4096, 0),
        ("paged-prefix", KvSpec::paged(8).with_prefix(64), 2048, 64),
    ];
    for strategy in [
        ServingStrategy::Vllm,
        ServingStrategy::Orca,
        ServingStrategy::ChunkedPrefill,
    ] {
        for (name, kv, budget, prefix) in &layouts {
            let mut cfg = cfg_for(strategy, *budget);
            cfg.kv = *kv;
            let spec = decode_spec(*prefix);
            let n = 10 + rng.gen_index(8);
            let seed = rng.next_u64();
            let scale = 1.0 + rng.gen_f64();
            let stream = stream_for(&spec, scale, n, seed, &cfg);
            let naive =
                with_coalesce(false, 1, || sim::simulate_serving(&stream, &model, &hw, &cfg));
            let fast =
                with_coalesce(true, 1, || sim::simulate_serving(&stream, &model, &hw, &cfg));
            assert_serving_bitwise(&fast, &naive, &format!("{strategy:?} {name}"));
            assert_eq!(
                naive.n_completed + naive.n_rejected + naive.n_in_flight,
                naive.n_arrived,
                "{strategy:?} {name}: conservation"
            );
        }
    }
}

/// Sink-on single-replica runs: the fast-forward replays per-iteration
/// occupancy spans and lifecycle events exactly, so the Chrome-trace
/// JSON must be byte-identical between coalesce on and off (and the
/// metrics bitwise-equal to the untraced run).
#[test]
fn traced_serving_replays_identical_bytes() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut cfg = cfg_for(ServingStrategy::ChunkedPrefill, 2048);
    cfg.kv = KvSpec::paged(16);
    let spec = decode_spec(0);
    let stream = stream_for(&spec, 1.4, 14, 99, &cfg);
    let untraced = with_coalesce(true, 1, || sim::simulate_serving(&stream, &model, &hw, &cfg));
    let run_traced = |on: bool| {
        with_coalesce(on, 1, || {
            let c = SpanCollector::shared();
            let sink: sim::SharedSink = c.clone();
            let m = sim::simulate_serving_traced(&stream, &model, &hw, &cfg, &sink);
            let json = c.lock().unwrap().chrome_trace_json();
            (m, json)
        })
    };
    let (m_on, j_on) = run_traced(true);
    let (m_off, j_off) = run_traced(false);
    assert_serving_bitwise(&m_on, &m_off, "traced serving");
    assert_serving_bitwise(&m_on, &untraced, "traced vs untraced");
    assert_eq!(j_on, j_off, "trace JSON differs between coalesce on/off");
    assert!(!j_on.is_empty() && j_on.starts_with("{\"traceEvents\":["));
}

/// Fleets: coalesce on/off × 1/8 worker threads, bitwise, across
/// homogeneous (JSQ with SLO shedding, round-robin baseline,
/// JSQ rebalancing) and disaggregated prefill/decode shapes.
#[test]
fn fleet_coalesced_matches_naive_at_one_and_eight_threads() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 1024);
    let spec = decode_spec(0);
    let probe = sim::probe(&model, &hw, &cfg, &spec);
    let combos: [(FleetConfig, Frontend); 4] = [
        (
            FleetConfig::homogeneous(4, RouterPolicy::JoinShortestQueue),
            Frontend::with_shedding(probe, 3.0),
        ),
        (
            FleetConfig::homogeneous(3, RouterPolicy::RoundRobin),
            Frontend::baseline(),
        ),
        (
            FleetConfig::homogeneous(4, RouterPolicy::JoinShortestQueue),
            Frontend::baseline().with_rebalance(RebalanceSpec::new(0.3, 1e-7)),
        ),
        (FleetConfig::disaggregated(1, 3, 1e-7), Frontend::baseline()),
    ];
    let mut rng = Rng::seed_from_u64(0xC0A1E5CE);
    for (ci, (fleet, fe)) in combos.iter().enumerate() {
        let n = 12 + rng.gen_index(8);
        let seed = rng.next_u64();
        let stream = stream_for(&spec, 1.6 + rng.gen_f64(), n, seed, &cfg);
        let hws = vec![hw.clone(); fleet.total_replicas()];
        let run = |on: bool, threads: usize| {
            with_coalesce(on, threads, || {
                sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, fleet, fe)
            })
        };
        let anchor = run(false, 1);
        for (on, threads) in [(true, 1), (true, 8), (false, 8)] {
            let m = run(on, threads);
            assert_fleet_bitwise(
                &anchor,
                &m,
                &format!(
                    "combo {ci} ({}) coalesce={on} threads={threads}",
                    fleet.describe()
                ),
            );
        }
    }
}

/// Seeded fault storms (crashes + stragglers with failover, capped
/// retries and proactive drains): fault instants arrive as `advance_to`
/// horizons, so a stretch must end exactly at them. Coalesce on/off at
/// 1 and 8 threads, untraced bitwise plus one traced byte-compare.
#[test]
fn faulted_fleet_coalesced_matches_naive() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 1024);
    let spec = decode_spec(0);
    let mut rng = Rng::seed_from_u64(0xFA_C0A1);
    for case in 0..2 {
        let n = 14 + rng.gen_index(8);
        let seed = rng.next_u64();
        let stream = stream_for(&spec, 2.0, n, seed, &cfg);
        let fleet = FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue);
        let hws = vec![hw.clone(); 3];
        let fe = Frontend::baseline().with_rebalance(RebalanceSpec::new(0.4, 1e-7));
        let horizon = stream.horizon_s();
        let schedule = FaultSchedule::seeded(3, horizon, 2, 1, 17 + case as u64);
        let res = ResilienceSpec::none()
            .with_schedule(schedule)
            .with_retry(RetryPolicy::capped(2, 0.05 * horizon, 0.2 * horizon))
            .with_drain(DrainSpec::new(0.05 * horizon, 1e-7, 4))
            .with_failover(case == 0);
        let run = |on: bool, threads: usize| {
            with_coalesce(on, threads, || {
                sim::simulate_fleet_faults(&stream, &model, &hws, &cfg, &fleet, &fe, &res)
            })
        };
        let anchor = run(false, 1);
        for (on, threads) in [(true, 1), (true, 8)] {
            let m = run(on, threads);
            assert_fleet_bitwise(
                &anchor,
                &m,
                &format!("faults case {case} coalesce={on} threads={threads}"),
            );
        }
        if case == 0 {
            let run_traced = |on: bool| {
                with_coalesce(on, 1, || {
                    let c = SpanCollector::shared();
                    let sink: sim::SharedSink = c.clone();
                    let m = sim::simulate_fleet_faults_traced(
                        &stream, &model, &hws, &cfg, &fleet, &fe, &res, &sink,
                    );
                    let json = c.lock().unwrap().chrome_trace_json();
                    (m, json)
                })
            };
            let (m_on, j_on) = run_traced(true);
            let (m_off, j_off) = run_traced(false);
            assert_fleet_bitwise(&m_on, &m_off, "faults traced on/off");
            assert_fleet_bitwise(&anchor, &m_off, "faults traced vs untraced");
            assert_eq!(j_on, j_off, "fault-run trace JSON differs on/off");
        }
    }
}

/// The `max_iterations` satellite regression: a cap boundary landing
/// deep inside a coalesced stretch must count every replayed iteration
/// toward the cap and set `truncated` exactly where the naive loop
/// would — same iteration count, same clock bits, same metrics.
#[test]
fn iteration_cap_inside_a_stretch_truncates_identically() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let run = |cap: usize, coalesce: bool| {
        let mut cfg = cfg_for(ServingStrategy::ChunkedPrefill, 8192);
        // one huge bucket: after the single prefill iteration the whole
        // decode run is one quiescent stretch, so the cap lands mid-way
        cfg.ctx_bucket = 1024;
        cfg.max_iterations = cap;
        let mut s = Scheduler::new(&model, &hw, &cfg);
        s.set_coalescing(coalesce);
        s.inject(0, 0.0, 8, 400);
        s.run_to_end();
        let truncated = s.truncated();
        let clock = s.clock();
        (truncated, clock, s.finish().metrics)
    };
    // cap 64: the prefill iteration plus 63 of the ~400 decode
    // iterations — far inside the stretch
    let (tc, clock_c, mc) = run(64, true);
    let (tn, clock_n, mn) = run(64, false);
    assert!(tc && tn, "the cap must truncate both runs mid-stretch");
    assert_eq!(mc.n_iterations, 64, "coalesced run overran the cap");
    assert_eq!(clock_c.to_bits(), clock_n.to_bits(), "cap: clock");
    assert_serving_bitwise(&mc, &mn, "cap boundary inside a stretch");
    // ample cap: the same scenario runs to completion, still bitwise
    let (tc, clock_c, mc) = run(100_000, true);
    let (tn, clock_n, mn) = run(100_000, false);
    assert!(!tc && !tn, "ample cap must not truncate");
    assert_eq!(mc.n_completed, 1);
    assert_eq!(clock_c.to_bits(), clock_n.to_bits(), "completion: clock");
    assert_serving_bitwise(&mc, &mn, "completion after long stretches");
}

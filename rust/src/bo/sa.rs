//! Two-tier simulated-annealing acquisition optimizer (paper §V-B-2).
//!
//! The design space is fully discrete, so EI cannot be maximised by
//! gradients. The outer tier perturbs `z_shape` (chiplet class, hence
//! grid dimension) and `z_sys` (bandwidths, micro-batch sizes, tensor
//! parallelism); the inner tier refines `z_layout` with single-slot
//! replacements or dual-slot swaps. A shape change triggers a layout
//! reallocation onto the new grid.

use crate::arch::{Dataflow, HwConfig, HwSpace};
use crate::util::Rng;

/// Draw a uniformly random configuration from the space.
pub fn random_config(space: &HwSpace, rng: &mut Rng) -> HwConfig {
    let classes = space.feasible_classes();
    let class = *rng.choose(&classes);
    let n = class.chiplets_for(space.target_tops).min(space.max_chiplets);
    let (h, w) = HwSpace::grid_dims(n);
    let mut hw = HwConfig {
        grid_h: h,
        grid_w: w,
        class,
        layout: (0..n).map(|_| *rng.choose(&space.dataflows)).collect(),
        nop_bw_gbs: *rng.choose(&space.nop_bw_gbs),
        dram_bw_gbs: *rng.choose(&space.dram_bw_gbs),
        micro_batch_prefill: *rng.choose(&space.micro_batch_prefill),
        micro_batch_decode: *rng.choose(&space.micro_batch_decode),
        tensor_parallel: *rng.choose(&space.tensor_parallel),
    };
    // keep TP within the chiplet budget (a slice per chiplet at most)
    hw.tensor_parallel = hw.tensor_parallel.min(n.max(1));
    hw
}

/// Homogeneous seed designs: every feasible (class, dataflow) corner at
/// median bandwidths. Seeding the BO initial design with these gives the
/// surrogate the same well-understood anchor points a grid search starts
/// from; the two-tier SA then explores heterogeneous refinements.
pub fn homogeneous_seeds(space: &HwSpace) -> Vec<HwConfig> {
    let mut out = Vec::new();
    for class in space.feasible_classes() {
        let n = class.chiplets_for(space.target_tops).min(space.max_chiplets);
        let (h, w) = HwSpace::grid_dims(n);
        for &df in &space.dataflows {
            let mut hw = HwConfig::homogeneous(
                h,
                w,
                class,
                df,
                space.nop_bw_gbs[space.nop_bw_gbs.len() / 2],
                space.dram_bw_gbs[space.dram_bw_gbs.len() / 2],
            );
            hw.micro_batch_prefill = *space.micro_batch_prefill.last().unwrap_or(&1);
            hw.micro_batch_decode = space.micro_batch_decode[space.micro_batch_decode.len() / 2];
            hw.tensor_parallel =
                space.tensor_parallel[space.tensor_parallel.len() / 2].min(n.max(1));
            out.push(hw);
        }
    }
    out
}

/// Outer-tier move: perturb one dimension of `z_shape` or `z_sys`.
/// A class change reallocates the layout onto the new grid (paper: "if
/// the array dimension changes, it triggers a reallocation mapping").
pub fn outer_move(hw: &HwConfig, space: &HwSpace, rng: &mut Rng) -> HwConfig {
    let mut next = hw.clone();
    match rng.gen_index(6) {
        0 => {
            let classes = space.feasible_classes();
            let class = *rng.choose(&classes);
            if class != next.class {
                let n = class.chiplets_for(space.target_tops).min(space.max_chiplets);
                let (h, w) = HwSpace::grid_dims(n);
                let old = next.layout.clone();
                next.class = class;
                next.grid_h = h;
                next.grid_w = w;
                // reallocation mapping: tile the old layout pattern over
                // the new grid (preserves the WS/OS mix)
                next.layout = (0..n).map(|i| old[i % old.len()]).collect();
                next.tensor_parallel = next.tensor_parallel.min(n.max(1));
            }
        }
        1 => next.nop_bw_gbs = *rng.choose(&space.nop_bw_gbs),
        2 => next.dram_bw_gbs = *rng.choose(&space.dram_bw_gbs),
        3 => next.micro_batch_prefill = *rng.choose(&space.micro_batch_prefill),
        4 => next.micro_batch_decode = *rng.choose(&space.micro_batch_decode),
        _ => {
            next.tensor_parallel =
                (*rng.choose(&space.tensor_parallel)).min(next.num_chiplets().max(1))
        }
    }
    next
}

/// Inner-tier move: single-slot random replacement or dual-slot swap.
pub fn inner_move(hw: &HwConfig, space: &HwSpace, rng: &mut Rng) -> HwConfig {
    let mut next = hw.clone();
    let n = next.layout.len();
    if n == 0 {
        return next;
    }
    if rng.gen_bool(0.5) {
        let i = rng.gen_index(n);
        next.layout[i] = *rng.choose(&space.dataflows);
    } else if n >= 2 {
        let i = rng.gen_index(n);
        let mut j = rng.gen_index(n);
        if i == j {
            j = (j + 1) % n;
        }
        next.layout.swap(i, j);
    }
    next
}

/// One annealing proposal: outer with probability `p_outer`, else inner.
pub fn propose(hw: &HwConfig, space: &HwSpace, p_outer: f64, rng: &mut Rng) -> HwConfig {
    if rng.gen_bool(p_outer) {
        outer_move(hw, space, rng)
    } else {
        inner_move(hw, space, rng)
    }
}

/// Count the WS/OS mix (report helper).
pub fn dataflow_mix(hw: &HwConfig) -> (usize, usize) {
    (
        hw.count_dataflow(Dataflow::WeightStationary),
        hw.count_dataflow(Dataflow::OutputStationary),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn space() -> HwSpace {
        HwSpace::paper(64.0)
    }

    #[test]
    fn random_configs_respect_space() {
        let sp = space();
        let mut rng = Rng::seed_from_u64(0);
        for _ in 0..100 {
            let hw = random_config(&sp, &mut rng);
            assert!(sp.nop_bw_gbs.contains(&hw.nop_bw_gbs));
            assert!(sp.dram_bw_gbs.contains(&hw.dram_bw_gbs));
            assert_eq!(hw.layout.len(), hw.num_chiplets());
            assert!(hw.num_chiplets() <= sp.max_chiplets);
            // total compute must be close to the target
            let tops = hw.total_tops();
            assert!(
                (tops - 64.0).abs() / 64.0 < 0.5,
                "tops {tops} too far from target"
            );
            assert!(hw.tensor_parallel <= hw.num_chiplets().max(1));
        }
    }

    #[test]
    fn outer_move_changes_one_dimension() {
        let sp = space();
        let mut rng = Rng::seed_from_u64(1);
        let base = random_config(&sp, &mut rng);
        for _ in 0..200 {
            let next = outer_move(&base, &sp, &mut rng);
            // layout length always consistent with grid
            assert_eq!(next.layout.len(), next.num_chiplets());
            // a class change must rebuild the grid to the compute target
            if next.class != base.class {
                let n = next.class.chiplets_for(sp.target_tops);
                assert_eq!(next.num_chiplets(), n.min(sp.max_chiplets));
            }
        }
    }

    #[test]
    fn inner_move_preserves_shape_and_class() {
        let sp = space();
        let mut rng = Rng::seed_from_u64(2);
        let base = random_config(&sp, &mut rng);
        for _ in 0..100 {
            let next = inner_move(&base, &sp, &mut rng);
            assert_eq!(next.class, base.class);
            assert_eq!((next.grid_h, next.grid_w), (base.grid_h, base.grid_w));
            // at most two slots differ
            let diff = next
                .layout
                .iter()
                .zip(&base.layout)
                .filter(|(a, b)| a != b)
                .count();
            assert!(diff <= 2, "inner move changed {diff} slots");
        }
    }

    #[test]
    fn swap_preserves_dataflow_mix() {
        let sp = space();
        let mut rng = Rng::seed_from_u64(3);
        let mut hw = random_config(&sp, &mut rng);
        // force a known mix
        for (i, d) in hw.layout.iter_mut().enumerate() {
            *d = if i % 3 == 0 {
                Dataflow::OutputStationary
            } else {
                Dataflow::WeightStationary
            };
        }
        let mix = dataflow_mix(&hw);
        // swaps (second branch) keep the multiset; replacements may not --
        // verify over many proposals that mixes stay in plausible range
        let mut seen_same_mix = false;
        for _ in 0..50 {
            let next = inner_move(&hw, &sp, &mut rng);
            if dataflow_mix(&next) == mix {
                seen_same_mix = true;
            }
        }
        assert!(seen_same_mix);
    }
}

//! Sequence-length trace generation (paper §VI-A scenario setup).
//!
//! The paper samples ShareGPT (dialogue: short-in/long-out, means 78/483)
//! and GovReport (summarisation: long-in/short-out, means 9652/602) into a
//! *fitting set* that guides DSE and a *test set* that validates it. We
//! synthesise traces from lognormal fits calibrated to those published
//! means with heavy tails spanning the 1..161,281 range the paper cites
//! (see DESIGN.md "Substitutions").

use crate::util::Rng;

use super::Request;

/// Maximum sequence length observed in ShareGPT per the paper.
pub const MAX_SEQ_LEN: u64 = 161_281;

/// A (input_len, output_len) request-length pair.
pub type LenPair = (u64, u64);

/// Lognormal sequence-length distribution of one serving scenario.
#[derive(Debug, Clone, Copy)]
pub struct TraceSpec {
    pub mean_in: f64,
    pub mean_out: f64,
    /// Lognormal shape parameters (sigma of ln X).
    pub sigma_in: f64,
    pub sigma_out: f64,
    pub max_len: u64,
    /// Shared system-prompt prefix prepended to every sampled prompt
    /// (0 = none). The serving simulator's KV cache can deduplicate it
    /// across requests (`sim::KvSpec::prefix_tokens`).
    pub shared_prefix_tokens: u64,
}

impl TraceSpec {
    /// ShareGPT-like dialogue scenario: short input, long output.
    pub fn sharegpt() -> Self {
        TraceSpec {
            mean_in: 78.0,
            mean_out: 483.0,
            sigma_in: 1.2,
            sigma_out: 0.9,
            max_len: MAX_SEQ_LEN,
            shared_prefix_tokens: 0,
        }
    }

    /// GovReport-like summarisation scenario: long input, short output.
    pub fn govreport() -> Self {
        TraceSpec {
            mean_in: 9652.0,
            mean_out: 602.0,
            sigma_in: 0.6,
            sigma_out: 0.5,
            max_len: MAX_SEQ_LEN,
            shared_prefix_tokens: 0,
        }
    }

    /// Prepend a shared system-prompt prefix to every sampled prompt:
    /// each request's input becomes `prefix + user content`, so every
    /// prompt is strictly longer than the prefix and eligible for
    /// KV-cache prefix sharing.
    pub fn with_prefix(mut self, shared_prefix_tokens: u64) -> Self {
        self.shared_prefix_tokens = shared_prefix_tokens;
        self
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "sharegpt" => Some(Self::sharegpt()),
            "govreport" => Some(Self::govreport()),
            _ => None,
        }
    }

    fn mu(mean: f64, sigma: f64) -> f64 {
        // E[lognormal(mu, sigma)] = exp(mu + sigma^2/2)
        mean.ln() - 0.5 * sigma * sigma
    }

    fn sample_len(&self, rng: &mut Rng, mean: f64, sigma: f64) -> u64 {
        let mu = Self::mu(mean, sigma);
        let z = rng.gen_normal();
        let x = (mu + sigma * z).exp();
        (x.round() as u64).clamp(1, self.max_len)
    }

    /// Sample `n` request-length pairs. A nonzero shared prefix is
    /// prepended to every input length (clamped to `max_len`); with
    /// `shared_prefix_tokens == 0` sampling is bit-identical to the
    /// prefix-free path.
    pub fn sample(&self, n: usize, seed: u64) -> Vec<LenPair> {
        let mut rng = Rng::seed_from_u64(seed);
        (0..n)
            .map(|_| {
                let raw_in = self.sample_len(&mut rng, self.mean_in, self.sigma_in);
                (
                    (raw_in + self.shared_prefix_tokens).min(self.max_len),
                    self.sample_len(&mut rng, self.mean_out, self.sigma_out),
                )
            })
            .collect()
    }

    /// Disjoint fitting/test splits (paper: the fitting set guides DSE,
    /// the test set validates the found designs on unseen lengths).
    pub fn fit_test_split(&self, n_fit: usize, n_test: usize, seed: u64) -> (Vec<LenPair>, Vec<LenPair>) {
        (self.sample(n_fit, seed), self.sample(n_test, seed.wrapping_add(0x9e37_79b9)))
    }
}

/// A sampled trace with batch-builder helpers (paper: Compass "generates
/// multiple batches from the input traces to capture average performance
/// across the sequence-length distribution").
#[derive(Debug, Clone)]
pub struct Trace {
    pub pairs: Vec<LenPair>,
    pub seed: u64,
}

impl Trace {
    pub fn new(spec: &TraceSpec, n: usize, seed: u64) -> Self {
        Trace {
            pairs: spec.sample(n, seed),
            seed,
        }
    }

    pub fn mean_in(&self) -> f64 {
        self.pairs.iter().map(|p| p.0 as f64).sum::<f64>() / self.pairs.len().max(1) as f64
    }

    pub fn mean_out(&self) -> f64 {
        self.pairs.iter().map(|p| p.1 as f64).sum::<f64>() / self.pairs.len().max(1) as f64
    }

    /// A prefill batch of `b` requests drawn round-robin from the trace.
    pub fn prefill_batch(&self, b: usize, offset: usize) -> Vec<Request> {
        (0..b)
            .map(|i| Request::prefill(self.pairs[(offset + i) % self.pairs.len()].0))
            .collect()
    }

    /// A decode batch: each request decodes against a context of its input
    /// length plus a uniformly-progressed slice of its output.
    pub fn decode_batch(&self, b: usize, offset: usize) -> Vec<Request> {
        let mut rng = Rng::seed_from_u64(self.seed ^ offset as u64);
        (0..b)
            .map(|i| {
                let (inp, out) = self.pairs[(offset + i) % self.pairs.len()];
                let progressed = rng.gen_range(0, out + 1);
                Request::decode(inp + progressed)
            })
            .collect()
    }

    /// Multiple batches for distribution-aware DSE.
    pub fn batches(
        &self,
        prefill: bool,
        batch_size: usize,
        n_batches: usize,
    ) -> Vec<Vec<Request>> {
        (0..n_batches)
            .map(|i| {
                if prefill {
                    self.prefill_batch(batch_size, i * batch_size)
                } else {
                    self.decode_batch(batch_size, i * batch_size)
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lognormal_means_match_paper() {
        let s = TraceSpec::sharegpt();
        let t = Trace::new(&s, 4000, 7);
        assert!(
            (t.mean_in() - 78.0).abs() / 78.0 < 0.15,
            "sharegpt mean_in {}",
            t.mean_in()
        );
        assert!(
            (t.mean_out() - 483.0).abs() / 483.0 < 0.15,
            "sharegpt mean_out {}",
            t.mean_out()
        );
        let g = TraceSpec::govreport();
        let t = Trace::new(&g, 2000, 11);
        assert!(
            (t.mean_in() - 9652.0).abs() / 9652.0 < 0.15,
            "govreport mean_in {}",
            t.mean_in()
        );
    }

    #[test]
    fn lengths_span_orders_of_magnitude() {
        let t = Trace::new(&TraceSpec::sharegpt(), 8000, 3);
        let min = t.pairs.iter().map(|p| p.0).min().unwrap();
        let max = t.pairs.iter().map(|p| p.0).max().unwrap();
        assert!(min <= 16, "min {min}");
        assert!(max >= 1000, "max {max}");
        assert!(t.pairs.iter().all(|p| p.0 >= 1 && p.0 <= MAX_SEQ_LEN));
    }

    #[test]
    fn deterministic_with_seed() {
        let spec = TraceSpec::govreport();
        assert_eq!(spec.sample(100, 42), spec.sample(100, 42));
        assert_ne!(spec.sample(100, 42), spec.sample(100, 43));
    }

    #[test]
    fn fit_test_sets_disjoint_sampling() {
        let spec = TraceSpec::sharegpt();
        let (fit, test) = spec.fit_test_split(50, 50, 1);
        assert_eq!(fit.len(), 50);
        assert_eq!(test.len(), 50);
        assert_ne!(fit, test);
    }

    #[test]
    fn shared_prefix_inflates_every_prompt() {
        let spec = TraceSpec::sharegpt();
        let with = spec.with_prefix(256).sample(200, 9);
        let without = spec.sample(200, 9);
        for ((wi, wo), (pi, po)) in without.iter().zip(&with) {
            assert_eq!(*pi, (*wi + 256).min(MAX_SEQ_LEN));
            assert!(*pi > 256, "prompt not longer than the prefix");
            assert_eq!(wo, po, "outputs must be unaffected");
        }
        // prefix 0 is bit-identical to the prefix-free path
        assert_eq!(spec.with_prefix(0).sample(50, 3), spec.sample(50, 3));
    }

    #[test]
    fn decode_batch_contexts_progress() {
        let t = Trace::new(&TraceSpec::sharegpt(), 256, 5);
        let batch = t.decode_batch(128, 0);
        assert_eq!(batch.len(), 128);
        assert!(batch.iter().all(|r| matches!(r, Request::Decode { .. })));
        // contexts must vary (variable sequence lengths within a batch)
        let ctxs: Vec<u64> = batch
            .iter()
            .map(|r| match r {
                Request::Decode { ctx } => *ctx,
                _ => 0,
            })
            .collect();
        let uniq: std::collections::HashSet<_> = ctxs.iter().collect();
        assert!(uniq.len() > 16);
    }
}

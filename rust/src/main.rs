//! `repro` — the Compass reproduction launcher.
//!
//! One subcommand per paper artifact (see DESIGN.md experiment index):
//!
//! ```text
//! repro table1    [--dram-bw N]                 # Table I  EDP ratios
//! repro validate                                # Table V  engine validation
//! repro compare   [--scenes all|reduced] ...    # Fig 7 + Table VI
//! repro dse       --trace T --phase P --tops N  # single-scene DSE
//! repro timeline                                # Fig 8    execution timeline
//! repro serving-study [--decode-groups N]       # Fig 10 + Table VII
//! repro sim-study [--rates A,B,C] [--requests N]# serving simulator sweep
//! repro fleet-study [--replicas N] ...          # multi-replica fleet sweep
//! repro kv-study  [--block-tokens N] [--prefix N] # KV paging/quantization
//! repro frontend-study [--shed-margin M] ...    # front-end control plane
//! repro fault-study [--crashes N] ...           # fault injection & resilience
//! repro ablation                                # Fig 11   ablations
//! repro all                                     # everything above
//! ```
//!
//! Common flags: `--full` (paper-scale budgets), `--seed S`,
//! `--out-dir D` (CSV output), `--native` (skip PJRT artifacts).

use compass::dse::DseConfig;
use compass::experiments as exp;
use compass::report::Table;
use compass::runtime::Runtime;

const HELP: &str = "repro <command> [flags]

commands:
  table1          Table I   EDP ratio (OS/WS) per phase x seq length
  validate        Table V   evaluation-engine validation
  compare         Fig 7     Gemini vs MOHaM vs Compass (+ Table VI)
  dse             single-scene co-exploration (--trace/--phase/--tops)
  timeline        Fig 8     execution timeline of the found mapping
  serving-study   Fig 10    vLLM / Orca / ChunkedPrefill (+ Table VII)
  sim-study       serving simulator: arrival rate x strategy sweep
  fleet-study     fleet serving: rate x router policy x fleet shape
  kv-study        KV cache: paged-vs-token x dtype x sharing sweep
  frontend-study  front end: SLO shedding x rebalancing x hetero sizing
  fault-study     fault injection: crashes x failover x retry x drain
  ablation        Fig 11    GA->random, BO->random, SCAR mapping
  all             everything above

flags:
  --full              paper-scale search budgets (GA 120x100, BO 100)
  --native            force the native GP (skip PJRT artifacts)
  --seed S            RNG seed (default 7)
  --out-dir D         also write CSVs under D
  --scenes all|reduced   scenario matrix for compare/all (default reduced)
  --trace sharegpt|govreport   (default sharegpt)
  --phase prefill|decode       (default prefill)
  --tops N            compute target (default 64)
  --dram-bw N         Table-I probe DRAM bandwidth (default 64)
  --decode-groups N   serving-study decode batches (default 3)
  --rates A,B,C       sim-study arrival rates in req/s (default: auto
                      {0.4,0.8,1.3} x estimated capacity)
  --requests N        sim-study requests per stream (default 24)
  --threads N         worker threads for parallel search/study loops and
                      replica stepping (overrides COMPASS_THREADS;
                      default: auto). Results are bit-identical at any
                      thread count
  --tiny              shrink any study to a CI-smoke grid: 6 requests,
                      fixed rates {1.0, 2.5} req/s unless --rates is
                      given
  --replicas N        fleet-study replicas; --tops is the fleet's *total*
                      budget, split evenly (default 4)
  --handoff S         fleet-study KV handoff cost, s per migrated token
                      (default 1e-8)
  --block-tokens N    kv-study paged block size in tokens (default 16)
  --prefix N          kv-study shared system-prompt prefix length
                      (default 64; 0 disables the sharing layouts)
  --kv-gb G           kv-study DRAM reserved for KV; default auto-sizes
                      so the fp16 baseline holds ~8x the mean request
                      footprint (KV-bound on purpose)
  --shed-margin M     frontend-study SLO-shed margin in TTFT multiples
                      (default 1.0)
  --rebalance-threshold T   frontend-study busy-time imbalance trigger
                      (default 0.5)
  --prefill-share F   frontend-study hetero fleet: prefill pool's share
                      of the total TOPS budget (default 0.15)
  --trace-file P      frontend-study: replay a timestamped CSV trace
                      (arrival_s,prompt_len,gen_len per line) at its
                      native rate instead of the synthetic rate sweep
  --crashes N         fault-study crashes per schedule (default 1)
  --stragglers N      fault-study straggler windows per schedule
                      (default 1)
  --fault-seed S      fault-study schedule seed, separate from --seed so
                      the same faults strike every cell (default 17)
  --retry-attempts N  fault-study total offers per request in the retry
                      cells (default 3)
  --trace-out P       sim/fleet/frontend/fault-study: re-run the
                      representative cell (highest rate) with telemetry
                      attached and write Chrome trace-event JSON to P
                      (open in ui.perfetto.dev or chrome://tracing)
  --record P          append one JSON line per study cell to P (JSONL
                      run records; file truncated at startup)
  --profile           time simulator hot paths (wall clock); self-time
                      table printed to stderr at exit
  --quiet             silence [compass] stderr chatter
  -v                  verbose [compass] stderr chatter
";

struct Args {
    cmd: String,
    full: bool,
    native: bool,
    seed: u64,
    out_dir: Option<String>,
    scenes: String,
    trace: String,
    prefill: bool,
    tops: f64,
    dram_bw: f64,
    decode_groups: usize,
    rates: Vec<f64>,
    requests: usize,
    threads: usize,
    tiny: bool,
    replicas: usize,
    handoff: f64,
    block_tokens: u64,
    prefix: u64,
    kv_gb: f64,
    shed_margin: f64,
    rebalance_threshold: f64,
    prefill_share: f64,
    trace_file: Option<String>,
    crashes: usize,
    stragglers: usize,
    fault_seed: u64,
    retry_attempts: usize,
    trace_out: Option<String>,
    record: Option<String>,
    profile: bool,
    quiet: bool,
    verbose: bool,
}

fn parse_args() -> Args {
    let mut args = Args {
        cmd: String::new(),
        full: false,
        native: false,
        seed: 7,
        out_dir: None,
        scenes: "reduced".into(),
        trace: "sharegpt".into(),
        prefill: true,
        tops: 64.0,
        dram_bw: 64.0,
        decode_groups: 3,
        rates: Vec::new(),
        requests: 24,
        threads: 0,
        tiny: false,
        replicas: 4,
        handoff: 1e-8,
        block_tokens: 16,
        prefix: 64,
        kv_gb: 0.0,
        shed_margin: 1.0,
        rebalance_threshold: 0.5,
        prefill_share: 0.15,
        trace_file: None,
        crashes: 1,
        stragglers: 1,
        fault_seed: 17,
        retry_attempts: 3,
        trace_out: None,
        record: None,
        profile: false,
        quiet: false,
        verbose: false,
    };
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let mut it = argv.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--full" => args.full = true,
            "--native" => args.native = true,
            "--seed" => args.seed = next_val(&mut it, a),
            "--out-dir" => args.out_dir = Some(next_str(&mut it, a)),
            "--scenes" => args.scenes = next_str(&mut it, a),
            "--trace" => args.trace = next_str(&mut it, a),
            "--phase" => args.prefill = next_str(&mut it, a) != "decode",
            "--tops" => args.tops = next_val(&mut it, a),
            "--dram-bw" => args.dram_bw = next_val(&mut it, a),
            "--decode-groups" => args.decode_groups = next_val(&mut it, a),
            "--rates" => {
                args.rates = next_str(&mut it, a)
                    .split(',')
                    .map(|s| {
                        s.trim().parse().unwrap_or_else(|_| {
                            eprintln!("--rates: invalid value {s}");
                            std::process::exit(2);
                        })
                    })
                    .collect();
            }
            "--requests" => args.requests = next_val(&mut it, a),
            "--threads" => args.threads = next_val(&mut it, a),
            "--tiny" => args.tiny = true,
            "--replicas" => args.replicas = next_val(&mut it, a),
            "--handoff" => args.handoff = next_val(&mut it, a),
            "--block-tokens" => args.block_tokens = next_val(&mut it, a),
            "--prefix" => args.prefix = next_val(&mut it, a),
            "--kv-gb" => args.kv_gb = next_val(&mut it, a),
            "--shed-margin" => args.shed_margin = next_val(&mut it, a),
            "--rebalance-threshold" => args.rebalance_threshold = next_val(&mut it, a),
            "--prefill-share" => args.prefill_share = next_val(&mut it, a),
            "--trace-file" => args.trace_file = Some(next_str(&mut it, a)),
            "--crashes" => args.crashes = next_val(&mut it, a),
            "--stragglers" => args.stragglers = next_val(&mut it, a),
            "--fault-seed" => args.fault_seed = next_val(&mut it, a),
            "--retry-attempts" => args.retry_attempts = next_val(&mut it, a),
            "--trace-out" => args.trace_out = Some(next_str(&mut it, a)),
            "--record" => args.record = Some(next_str(&mut it, a)),
            "--profile" => args.profile = true,
            "--quiet" => args.quiet = true,
            "-v" | "--verbose" => args.verbose = true,
            "-h" | "--help" => {
                print!("{HELP}");
                std::process::exit(0);
            }
            s if !s.starts_with('-') && args.cmd.is_empty() => args.cmd = s.to_string(),
            other => {
                eprintln!("unknown argument: {other}\n{HELP}");
                std::process::exit(2);
            }
        }
    }
    if args.cmd.is_empty() {
        print!("{HELP}");
        std::process::exit(2);
    }
    if args.tiny {
        // CI-smoke preset: small fixed grid, explicit rates so no cell
        // depends on probe-calibrated auto sweeps drifting with --tops
        args.requests = 6;
        if args.rates.is_empty() {
            args.rates = vec![1.0, 2.5];
        }
    }
    if let Err(e) = exp::validate_rates(&args.rates) {
        eprintln!("{e}");
        std::process::exit(2);
    }
    args
}

/// Exit with a usage error when a fleet-shaped study gets fewer than
/// two replicas (silent clamping hid sizing mistakes).
fn replicas_or_exit(n: usize, study: &str) -> usize {
    exp::require_replicas(n, study).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(2);
    })
}

fn write_trace(path: &str, cell: &str, rate: f64, json: &str) {
    compass::log::info(&format!("traced representative cell {cell} @ {rate:.3} req/s"));
    if let Err(e) = std::fs::write(path, json) {
        eprintln!("[compass] trace write failed: {e}");
        std::process::exit(1);
    }
    compass::log::info(&format!("wrote {path}"));
}

fn append_records(out: &Option<String>, recs: &[compass::sim::RunRecord]) {
    use std::io::Write;
    let Some(path) = out else { return };
    let mut f = match std::fs::OpenOptions::new().create(true).append(true).open(path) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("[compass] record open failed: {e}");
            std::process::exit(1);
        }
    };
    for r in recs {
        if let Err(e) = writeln!(f, "{}", r.to_json()) {
            eprintln!("[compass] record write failed: {e}");
            std::process::exit(1);
        }
    }
    compass::log::info(&format!("appended {} run records to {path}", recs.len()));
}

fn next_str(it: &mut std::iter::Peekable<std::slice::Iter<String>>, flag: &str) -> String {
    it.next()
        .unwrap_or_else(|| {
            eprintln!("{flag} needs a value");
            std::process::exit(2);
        })
        .clone()
}

fn next_val<T: std::str::FromStr>(
    it: &mut std::iter::Peekable<std::slice::Iter<String>>,
    flag: &str,
) -> T {
    next_str(it, flag).parse().unwrap_or_else(|_| {
        eprintln!("{flag}: invalid value");
        std::process::exit(2);
    })
}

fn save(t: &Table, out_dir: &Option<String>, name: &str) {
    t.print();
    if let Some(dir) = out_dir {
        let path = format!("{dir}/{name}.csv");
        if let Err(e) = t.write_csv(&path) {
            eprintln!("[compass] csv write failed: {e}");
        } else {
            compass::log::info(&format!("wrote {path}"));
        }
    }
}

fn run_sim_study(args: &Args) {
    let mut scene = exp::SimScene::new(&args.trace, args.tops, args.requests);
    scene.rates_rps = args.rates.clone();
    let hw = exp::sim_default_hw(args.tops);
    let cfg = compass::sim::SimConfig::new(
        compass::workload::serving::ServingStrategy::ChunkedPrefill,
    );
    println!(
        "sim-study [{}] on fixed hw: {}",
        scene.label(),
        hw.describe()
    );
    let rows = exp::sim_serving_study(&scene, &hw, &cfg, args.seed);
    save(
        &exp::sim_study_table(&scene, &rows),
        &args.out_dir,
        "sim_study",
    );
    append_records(&args.record, &exp::sim_study_records(&rows));
    if let Some(path) = &args.trace_out {
        let (cell, rate, sink) = exp::sim_study_traced_cell(&scene, &hw, &cfg, args.seed);
        write_trace(path, &cell, rate, &sink.lock().unwrap().chrome_trace_json());
    }
    println!(
        "\n{}",
        exp::sim_study_occupancy(
            &rows,
            compass::workload::serving::ServingStrategy::ChunkedPrefill,
            cfg.max_batch,
        )
    );
}

fn run_fleet_study(args: &Args) {
    let replicas = replicas_or_exit(args.replicas, "fleet-study");
    let mut scene = exp::FleetScene::new(&args.trace, args.tops, replicas, args.requests);
    scene.rates_rps = args.rates.clone();
    let hw = exp::sim_default_hw(scene.tops_per_replica());
    let cfg = compass::sim::SimConfig::new(
        compass::workload::serving::ServingStrategy::ChunkedPrefill,
    );
    println!(
        "fleet-study [{}]: {} replicas, per-replica hw: {}",
        scene.label(),
        scene.n_replicas,
        hw.describe()
    );
    let shapes = exp::default_fleet_shapes(scene.n_replicas, args.handoff);
    let rows = exp::fleet_study(&scene, &hw, &cfg, &shapes, args.seed);
    save(
        &exp::fleet_study_table(&scene, &rows),
        &args.out_dir,
        "fleet_study",
    );
    append_records(&args.record, &exp::fleet_study_records(&rows));
    if let Some(path) = &args.trace_out {
        let (cell, rate, sink) =
            exp::fleet_study_traced_cell(&scene, &hw, &cfg, &shapes, args.seed);
        write_trace(path, &cell, rate, &sink.lock().unwrap().chrome_trace_json());
    }
}

fn run_frontend_study(args: &Args) {
    let replicas = replicas_or_exit(args.replicas, "frontend-study");
    let mut scene = exp::FleetScene::new(&args.trace, args.tops, replicas, args.requests);
    scene.rates_rps = args.rates.clone();
    let hw = exp::sim_default_hw(scene.tops_per_replica());
    let cfg = compass::sim::SimConfig::new(
        compass::workload::serving::ServingStrategy::ChunkedPrefill,
    );
    let knobs = exp::FrontendKnobs {
        shed_margin: args.shed_margin,
        rebalance_threshold: args.rebalance_threshold,
        handoff_s_per_token: args.handoff,
        prefill_share: args.prefill_share,
    };
    println!(
        "frontend-study [{}]: {} replicas, per-replica hw: {} | shed x{} | rebal>{} | \
         prefill share {:.0}%",
        scene.label(),
        scene.n_replicas,
        hw.describe(),
        knobs.shed_margin,
        knobs.rebalance_threshold,
        100.0 * knobs.prefill_share,
    );
    let rows = if let Some(path) = &args.trace_file {
        // timestamped trace replay at its native rate: SLOs are still
        // calibrated from the unloaded probe on the trace's own means
        let stream = match compass::sim::RequestStream::from_trace_file(path) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[compass] trace load failed: {e}");
                std::process::exit(2);
            }
        };
        let model = scene.model();
        let probe = compass::sim::probe_stream(&model, &hw, &cfg, &stream);
        let mut c = cfg;
        c.slo = probe.slo(3.0, 4.0);
        println!(
            "replaying {} ({} requests @ {:.3} req/s native rate)",
            stream.name,
            stream.len(),
            stream.rate_rps
        );
        exp::frontend_study_stream(&scene, &model, &hw, &c, &knobs, &probe, &stream)
    } else {
        exp::frontend_study(&scene, &cfg, &knobs, args.seed)
    };
    save(
        &exp::frontend_study_table(&scene, &rows),
        &args.out_dir,
        "frontend_study",
    );
    append_records(&args.record, &exp::frontend_study_records(&rows));
    if let Some(path) = &args.trace_out {
        if args.trace_file.is_some() {
            eprintln!("--trace-out replays the synthetic sweep's representative cell and cannot be combined with --trace-file");
            std::process::exit(2);
        }
        let (cell, rate, sink) = exp::frontend_study_traced_cell(
            &scene,
            &scene.model(),
            &hw,
            &cfg,
            &knobs,
            args.seed,
        );
        write_trace(path, &cell, rate, &sink.lock().unwrap().chrome_trace_json());
    }
    println!("\n{}", exp::frontend_study_headline(&rows));
}

fn run_fault_study(args: &Args) {
    let replicas = replicas_or_exit(args.replicas, "fault-study");
    let mut scene = exp::FleetScene::new(&args.trace, args.tops, replicas, args.requests);
    scene.rates_rps = args.rates.clone();
    let hw = exp::sim_default_hw(scene.tops_per_replica());
    let cfg = compass::sim::SimConfig::new(
        compass::workload::serving::ServingStrategy::ChunkedPrefill,
    );
    let knobs = exp::FaultKnobs {
        n_crashes: args.crashes,
        n_stragglers: args.stragglers,
        fault_seed: args.fault_seed,
        retry_attempts: args.retry_attempts,
        handoff_s_per_token: args.handoff,
        ..exp::FaultKnobs::default()
    };
    println!(
        "fault-study [{}]: {} replicas, per-replica hw: {} | {} crash + {} straggler \
         (fault seed {}) | retry x{}",
        scene.label(),
        scene.n_replicas,
        hw.describe(),
        knobs.n_crashes,
        knobs.n_stragglers,
        knobs.fault_seed,
        knobs.retry_attempts.saturating_sub(1),
    );
    let rows = exp::fault_study(&scene, &cfg, &knobs, args.seed);
    save(
        &exp::fault_study_table(&scene, &rows),
        &args.out_dir,
        "fault_study",
    );
    append_records(&args.record, &exp::fault_study_records(&rows));
    if let Some(path) = &args.trace_out {
        let (cell, rate, sink) = exp::fault_study_traced_cell(
            &scene,
            &scene.model(),
            &hw,
            &cfg,
            &knobs,
            args.seed,
        );
        write_trace(path, &cell, rate, &sink.lock().unwrap().chrome_trace_json());
    }
    println!("\n{}", exp::fault_study_headline(&rows));
}

fn run_kv_study(args: &Args) {
    let mut scene = exp::SimScene::new(&args.trace, args.tops, args.requests);
    scene.rates_rps = args.rates.clone();
    let hw = exp::sim_default_hw(args.tops);
    let model = scene.model();
    let spec = scene.spec();
    let mut cfg = compass::sim::SimConfig::new(
        compass::workload::serving::ServingStrategy::ChunkedPrefill,
    );
    // KV-bound on purpose: size the DRAM so the fp16 token-granular
    // baseline holds ~8x the mean request footprint — then dtype, block
    // size and sharing decide the effective concurrency
    cfg.kv_budget_tokens = 0;
    let mean_footprint = spec.mean_in + spec.mean_out + args.prefix as f64;
    cfg.dram_gb = if args.kv_gb > 0.0 {
        args.kv_gb
    } else {
        8.0 * mean_footprint * model.kv_bytes_per_token() as f64 / 1e9
    };
    println!(
        "kv-study [{}] on fixed hw: {} | kv dram {:.4} GB | prefix {} tok | block {} tok",
        scene.label(),
        hw.describe(),
        cfg.dram_gb,
        args.prefix,
        args.block_tokens,
    );
    let specs = exp::default_kv_specs(args.block_tokens, args.prefix);
    let rows = exp::kv_paging_study(&scene, &hw, &cfg, &specs, args.prefix, args.seed);
    save(&exp::kv_study_table(&scene, &rows), &args.out_dir, "kv_study");
    append_records(&args.record, &exp::kv_study_records(&rows));
    // headline: best non-baseline layout vs the fp16 token-granular
    // baseline at the overload (highest) rate
    let hi = rows
        .iter()
        .map(|r| r.rate_rps)
        .fold(f64::NEG_INFINITY, f64::max);
    let at_hi: Vec<_> = rows.iter().filter(|r| r.rate_rps == hi).collect();
    let base = at_hi
        .iter()
        .find(|r| r.kv == compass::sim::KvSpec::token_granular())
        .expect("baseline layout present");
    if let Some(best) = at_hi
        .iter()
        .filter(|r| r.kv != base.kv)
        .max_by(|a, b| a.metrics.slo_goodput_tps.total_cmp(&b.metrics.slo_goodput_tps))
    {
        println!(
            "\nkv-study @ {:.3} req/s (overload): best layout {} goodput {:.1} tok/s \
             vs fp16 token-granular {:.1} tok/s ({:+.1}%)",
            hi,
            best.kv.describe(),
            best.metrics.slo_goodput_tps,
            base.metrics.slo_goodput_tps,
            100.0 * (best.metrics.slo_goodput_tps - base.metrics.slo_goodput_tps)
                / base.metrics.slo_goodput_tps.max(1e-9),
        );
    }
}

fn main() {
    let args = parse_args();
    if args.threads > 0 {
        // before any work: default_threads() reads the env per call, so
        // every downstream pool and search loop sees the override
        std::env::set_var("COMPASS_THREADS", args.threads.to_string());
    }
    compass::log::set_level(if args.quiet {
        compass::log::Level::Quiet
    } else if args.verbose {
        compass::log::Level::Debug
    } else {
        compass::log::Level::Info
    });
    if let Some(path) = &args.trace_out {
        const TRACEABLE: [&str; 4] =
            ["sim-study", "fleet-study", "frontend-study", "fault-study"];
        if !TRACEABLE.contains(&args.cmd.as_str()) {
            eprintln!(
                "--trace-out ({path}) is supported by {} only",
                TRACEABLE.join("/")
            );
            std::process::exit(2);
        }
    }
    if let Some(path) = &args.record {
        const RECORDABLE: [&str; 6] = [
            "sim-study",
            "fleet-study",
            "kv-study",
            "frontend-study",
            "fault-study",
            "all",
        ];
        if !RECORDABLE.contains(&args.cmd.as_str()) {
            eprintln!(
                "--record ({path}) is supported by {} only",
                RECORDABLE.join("/")
            );
            std::process::exit(2);
        }
        // truncate once so a run's records never mix with a prior run's
        if let Err(e) = std::fs::write(path, "") {
            eprintln!("[compass] record open failed: {e}");
            std::process::exit(1);
        }
    }
    compass::sim::profile::set_enabled(args.profile);
    let cfg = if args.full {
        DseConfig::paper()
    } else {
        DseConfig::reduced()
    };
    let rt = if args.native {
        None
    } else {
        match Runtime::from_env() {
            Ok(rt) => Some(rt),
            Err(e) => {
                compass::log::info(&format!("PJRT unavailable ({e}); using native GP"));
                None
            }
        }
    };
    let rt_ref = rt.as_ref();
    let t0 = std::time::Instant::now();

    match args.cmd.as_str() {
        "table1" => {
            save(&exp::table1(args.dram_bw), &args.out_dir, "table1");
        }
        "validate" => {
            save(&exp::table5(2), &args.out_dir, "table5");
        }
        "compare" => {
            let scenes = if args.scenes == "all" {
                exp::Scene::paper_matrix()
            } else {
                exp::Scene::reduced_matrix()
            };
            let rows = exp::fig7_compare(&scenes, &cfg, rt_ref, args.seed);
            save(&exp::fig7_table(&rows), &args.out_dir, "fig7_normalized");
            save(&exp::fig7_savings(&rows), &args.out_dir, "fig7_savings");
            save(&exp::table6(&rows), &args.out_dir, "table6");
        }
        "dse" => {
            let scene = exp::Scene::new(&args.trace, args.prefill, args.tops);
            let rows = exp::fig7_compare(std::slice::from_ref(&scene), &cfg, rt_ref, args.seed);
            save(&exp::fig7_table(&rows), &args.out_dir, "dse_compare");
            save(&exp::table6(&rows), &args.out_dir, "dse_hw");
        }
        "timeline" => {
            let scene = exp::Scene::new(&args.trace, true, args.tops);
            println!("{}", exp::fig8_timeline(&scene, &cfg, rt_ref, args.seed));
            let scene_d = exp::Scene::new(&args.trace, false, args.tops);
            println!("{}", exp::fig8_timeline(&scene_d, &cfg, rt_ref, args.seed));
        }
        "serving-study" => {
            let results = exp::fig10_serving(&cfg, rt_ref, args.seed, args.decode_groups);
            save(&exp::fig10a_table(&results), &args.out_dir, "fig10a");
            save(&exp::table7(&results), &args.out_dir, "table7");
            let cp = results
                .iter()
                .find(|r| r.strategy == compass::workload::serving::ServingStrategy::ChunkedPrefill)
                .expect("chunked prefill result");
            save(
                &exp::fig10b_homo_hetero(&cfg, &cp.hw, args.seed, args.decode_groups),
                &args.out_dir,
                "fig10b",
            );
        }
        "sim-study" => {
            run_sim_study(&args);
        }
        "fleet-study" => {
            run_fleet_study(&args);
        }
        "kv-study" => {
            run_kv_study(&args);
        }
        "frontend-study" => {
            run_frontend_study(&args);
        }
        "fault-study" => {
            run_fault_study(&args);
        }
        "ablation" => {
            save(&exp::fig11_ablation(&cfg, rt_ref, args.seed), &args.out_dir, "fig11");
        }
        "all" => {
            save(&exp::table1(args.dram_bw), &args.out_dir, "table1");
            save(&exp::table5(2), &args.out_dir, "table5");
            let scenes = if args.scenes == "all" {
                exp::Scene::paper_matrix()
            } else {
                exp::Scene::reduced_matrix()
            };
            let rows = exp::fig7_compare(&scenes, &cfg, rt_ref, args.seed);
            save(&exp::fig7_table(&rows), &args.out_dir, "fig7_normalized");
            save(&exp::fig7_savings(&rows), &args.out_dir, "fig7_savings");
            save(&exp::table6(&rows), &args.out_dir, "table6");
            let scene = exp::Scene::new("sharegpt", true, 64.0);
            println!("{}", exp::fig8_timeline(&scene, &cfg, rt_ref, args.seed));
            let results = exp::fig10_serving(&cfg, rt_ref, args.seed, args.decode_groups);
            save(&exp::fig10a_table(&results), &args.out_dir, "fig10a");
            save(&exp::table7(&results), &args.out_dir, "table7");
            if let Some(cp) = results
                .iter()
                .find(|r| r.strategy == compass::workload::serving::ServingStrategy::ChunkedPrefill)
            {
                save(
                    &exp::fig10b_homo_hetero(&cfg, &cp.hw, args.seed, args.decode_groups),
                    &args.out_dir,
                    "fig10b",
                );
            }
            run_sim_study(&args);
            run_fleet_study(&args);
            run_kv_study(&args);
            run_frontend_study(&args);
            run_fault_study(&args);
            save(&exp::fig11_ablation(&cfg, rt_ref, args.seed), &args.out_dir, "fig11");
        }
        other => {
            eprintln!("unknown command: {other}\n{HELP}");
            std::process::exit(2);
        }
    }
    if args.profile {
        let report = compass::sim::profile::take_report();
        if report.is_empty() {
            eprintln!("[compass] profile: no scopes recorded");
        } else {
            eprint!("{report}");
        }
        let s = compass::sim::CostCache::global().stats();
        eprintln!(
            "shared cost cache: {} hits, {} misses, {} GA searches run, \
             {} GA searches avoided, {} configs, {} entries",
            s.hits, s.misses, s.ga_searches, s.ga_avoided, s.configs, s.entries
        );
    }
    compass::log::info(&format!("done in {:.1}s", t0.elapsed().as_secs_f64()));
}

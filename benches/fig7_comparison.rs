//! Bench F7: regenerate paper Fig. 7 (Gemini vs MOHaM vs Compass) on the
//! reduced scenario matrix with CI-sized search budgets, printing the
//! normalized table, the average-savings summary, and Table VI.
//! `repro compare --scenes all [--full]` runs the full 12-scene matrix.
use compass::dse::DseConfig;
use compass::experiments as exp;
use compass::runtime::Runtime;
use compass::util::Bench;

fn main() {
    let mut cfg = DseConfig::reduced();
    cfg.ga.population = 12;
    cfg.ga.generations = 8;
    cfg.bo.rounds = 10;
    cfg.bo.init = 4;
    let rt = Runtime::from_env().ok();
    let scenes = exp::Scene::reduced_matrix();
    let rows = exp::fig7_compare(&scenes, &cfg, rt.as_ref(), 7);
    exp::fig7_table(&rows).print();
    exp::fig7_savings(&rows).print();
    exp::table6(&rows).print();
    let one = [exp::Scene::new("sharegpt", false, 64.0)];
    Bench::new("fig7/one-scene-three-methods").budget_ms(1).run(|| {
        exp::fig7_compare(&one, &cfg, rt.as_ref(), 7)
    });
}

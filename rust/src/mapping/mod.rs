//! Computation-execution-graph-based mapping encoding (paper §IV).
//!
//! A workload with `R = N / micro_batch_size` micro-batches and `M` layers
//! is encoded by three components:
//!   * `micro_batch_size` — division along the micro-batch dimension
//!     (searched by the hardware sampling engine, paper §V-A);
//!   * `segmentation`    — binary vector of length `M-1` segmenting the
//!     layer dimension;
//!   * `layer_to_chip`   — an `R x M` matrix assigning every
//!     (micro-batch, layer) cell to a chiplet.
//!
//! Scheduling order (paper Fig. 4 / Algorithm 2 loop order): segments in
//! layer order; within a segment, micro-batches in order; within a
//! micro-batch, layers in order. All-zero segmentation gives layer-first
//! (row-wise) scheduling, all-ones gives micro-batch-first (column-wise).

pub mod presets;


/// The mapping genome explored by the GA (paper §IV).
///
/// `Hash`/`Eq` make the genome usable as a fitness-memo key, so duplicate
/// individuals (elites, crossover clones) are never re-simulated (see
/// EXPERIMENTS.md #Perf).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Mapping {
    /// `R x M` row-major chiplet assignment.
    pub layer_to_chip: Vec<u16>,
    /// Segment boundary after layer `i` when `segmentation[i]` is true
    /// (length `M - 1`).
    pub segmentation: Vec<bool>,
    /// Rows (`R` = number of micro-batches).
    pub rows: usize,
    /// Columns (`M` = layers per micro-batch).
    pub cols: usize,
}

impl Mapping {
    pub fn new(rows: usize, cols: usize) -> Self {
        Mapping {
            layer_to_chip: vec![0; rows * cols],
            segmentation: vec![false; cols.saturating_sub(1)],
            rows,
            cols,
        }
    }

    #[inline]
    pub fn chip(&self, mb: usize, layer: usize) -> u16 {
        self.layer_to_chip[mb * self.cols + layer]
    }

    #[inline]
    pub fn set_chip(&mut self, mb: usize, layer: usize, chip: u16) {
        self.layer_to_chip[mb * self.cols + layer] = chip;
    }

    /// Validity against a chiplet count.
    pub fn is_valid(&self, num_chips: usize) -> bool {
        self.layer_to_chip.len() == self.rows * self.cols
            && self.segmentation.len() == self.cols.saturating_sub(1)
            && self.layer_to_chip.iter().all(|&c| (c as usize) < num_chips)
    }

    /// Segment boundaries as `[start, end)` layer ranges.
    pub fn segments(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut start = 0usize;
        for (i, &cut) in self.segmentation.iter().enumerate() {
            if cut {
                out.push((start, i + 1));
                start = i + 1;
            }
        }
        if start < self.cols {
            out.push((start, self.cols));
        }
        out
    }

    /// The scheduling order of paper Fig. 4: for each segment, for each
    /// micro-batch, for each layer in the segment, yield `(mb, layer)`.
    pub fn schedule_order(&self) -> Vec<(usize, usize)> {
        let mut order = Vec::with_capacity(self.rows * self.cols);
        self.schedule_order_into(&mut order);
        order
    }

    /// [`Mapping::schedule_order`] into a reused buffer — the evaluation
    /// engine's allocation-free hot path (see EXPERIMENTS.md #Perf).
    pub fn schedule_order_into(&self, out: &mut Vec<(usize, usize)>) {
        out.clear();
        out.reserve(self.rows * self.cols);
        let mut push_segment = |s: usize, e: usize| {
            for mb in 0..self.rows {
                for layer in s..e {
                    out.push((mb, layer));
                }
            }
        };
        let mut start = 0usize;
        for (i, &cut) in self.segmentation.iter().enumerate() {
            if cut {
                push_segment(start, i + 1);
                start = i + 1;
            }
        }
        if start < self.cols {
            push_segment(start, self.cols);
        }
    }

    /// Distinct chiplets actually used.
    pub fn chips_used(&self) -> usize {
        let mut seen = std::collections::HashSet::new();
        for &c in &self.layer_to_chip {
            seen.insert(c);
        }
        seen.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_cover_all_layers() {
        let mut m = Mapping::new(2, 6);
        m.segmentation = vec![false, true, false, false, true];
        let segs = m.segments();
        assert_eq!(segs, vec![(0, 2), (2, 5), (5, 6)]);
        let total: usize = segs.iter().map(|(s, e)| e - s).sum();
        assert_eq!(total, 6);
    }

    #[test]
    fn all_zero_segmentation_is_layer_first() {
        let m = Mapping::new(2, 3);
        // one segment: mb0 runs all layers, then mb1 (row-wise)
        assert_eq!(
            m.schedule_order(),
            vec![(0, 0), (0, 1), (0, 2), (1, 0), (1, 1), (1, 2)]
        );
    }

    #[test]
    fn all_one_segmentation_is_micro_batch_first() {
        let mut m = Mapping::new(2, 3);
        m.segmentation = vec![true, true];
        // per-layer segments: layer 0 across mbs, then layer 1 (column-wise)
        assert_eq!(
            m.schedule_order(),
            vec![(0, 0), (1, 0), (0, 1), (1, 1), (0, 2), (1, 2)]
        );
    }

    #[test]
    fn schedule_is_a_permutation() {
        let mut m = Mapping::new(3, 5);
        m.segmentation = vec![false, true, true, false];
        let order = m.schedule_order();
        assert_eq!(order.len(), 15);
        let set: std::collections::HashSet<_> = order.iter().collect();
        assert_eq!(set.len(), 15);
    }

    #[test]
    fn validity_checks_chip_range() {
        let mut m = Mapping::new(2, 2);
        assert!(m.is_valid(1));
        m.set_chip(1, 1, 7);
        assert!(!m.is_valid(4));
        assert!(m.is_valid(8));
    }
}

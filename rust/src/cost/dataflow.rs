//! Intra-chiplet analytical cost model (ZigZag-style loop-nest analysis,
//! paper §V-C "Intra-Chiplet Evaluation").
//!
//! The two library dataflows differ in which operand is *stationary*:
//!
//! * **WS** — weights parked in the PE array; partial sums are reduced
//!   in-array and held in an accumulator-backed GLB tile `m x Tn`. The
//!   GLB n-tile `Tn` shrinks as `m` grows (`Tn ∝ S / m`), so inputs are
//!   re-fetched `ceil(n / Tn)` times: WS degrades *quadratically* with
//!   the sequence length `m`.
//! * **OS** — outputs parked in PE registers; weights and inputs stream.
//!   Weights are cached in a GLB input-tile loop (`Tm ∝ S / k`), so the
//!   weight re-fetch grows *linearly* with `m`; additionally a short
//!   stationary operand (`m` below a few array heights) under-utilises
//!   the weight stream (`SHORT_M` penalty).
//!
//! Together these reproduce the preference crossovers of paper Table I:
//! WS superior for short sequences / decode, OS superior for long-context
//! prefill, with the QK^T flip arriving earlier (no resident weight,
//! `n = s_kv` grows with context).

use crate::arch::constants::*;
use crate::arch::{Chiplet, Dataflow};
use crate::workload::LayerKind;

/// GLB fraction backing the WS accumulator tile (calibrated, Table I).
const C_PS: f64 = 0.8;
/// GLB fraction backing the OS weight-reuse tile (calibrated, Table I).
const C_OS: f64 = 0.35;
/// OS short-stationary-operand penalty horizon (in array heights).
const SHORT_M: u64 = 4;

/// Cost of one layer's computation on one chiplet, before inter-chiplet
/// flags (weight-skip / write-out) are applied.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KernelCost {
    /// Compute cycles (MAC array + vector unit, overlap-free sum).
    pub cycles: f64,
    /// DRAM bytes for resident weights (dropped when `isLoadWei` = false).
    pub weight_dram: f64,
    /// DRAM bytes for activation refetch beyond the first read
    /// (tiling spills; charged regardless of the input's source).
    pub spill_dram: f64,
    /// GLB bytes moved (array streaming traffic).
    pub glb_bytes: f64,
    /// Accumulator / register-file bytes moved.
    pub reg_bytes: f64,
    /// MAC operations.
    pub macs: f64,
    /// Vector-unit scalar operations.
    pub vec_ops: f64,
}

impl KernelCost {
    fn add(&mut self, o: &KernelCost) {
        self.cycles += o.cycles;
        self.weight_dram += o.weight_dram;
        self.spill_dram += o.spill_dram;
        self.glb_bytes += o.glb_bytes;
        self.reg_bytes += o.reg_bytes;
        self.macs += o.macs;
        self.vec_ops += o.vec_ops;
    }

    /// Compute + on-chip energy (pJ); DRAM/NoP energy is added by the
    /// timeline once data sources are known.
    pub fn onchip_energy_pj(&self) -> f64 {
        self.macs * E_MAC_PJ
            + self.vec_ops * E_VEC_PJ_OP
            + self.glb_bytes * E_GLB_PJ_BYTE
            + self.reg_bytes * E_REG_PJ_BYTE
    }
}

#[inline]
fn div_ceil_f(a: u64, b: u64) -> f64 {
    a.div_ceil(b.max(1)) as f64
}

/// GEMM `[m x k] @ [k x n]` (weight resident iff `has_weight`).
pub fn gemm_cost(m: u64, k: u64, n: u64, chip: Chiplet, has_weight: bool) -> KernelCost {
    let a = chip.class.array_side();
    let s = chip.class.glb_bytes() as f64;
    let b = BYTES_PER_ELEM as f64;
    let (m, k, n) = (m.max(1), k.max(1), n.max(1));
    let macs = (m * k * n) as f64;
    let w_bytes = (k * n) as f64 * b;
    let in_bytes = (m * k) as f64 * b;
    let out_bytes = (m * n) as f64 * b;

    match chip.dataflow {
        Dataflow::WeightStationary => {
            // array: k -> rows, n -> cols; stream m; stall on weight
            // reloads when the streamed dimension is shorter than the
            // array fill time.
            let folds = div_ceil_f(k, a) * div_ceil_f(n, a);
            let cycles = folds * (m.max(a)) as f64;
            // accumulator-backed psum tile m x Tn in GLB
            let tn = ((C_PS * s / (BYTES_PER_PSUM as f64 * m as f64)) as u64).clamp(a.min(n), n);
            let in_refetch = div_ceil_f(n, tn);
            KernelCost {
                cycles,
                weight_dram: if has_weight { w_bytes } else { 0.0 },
                spill_dram: in_bytes * (in_refetch - 1.0),
                glb_bytes: w_bytes + in_bytes * div_ceil_f(n, a) + out_bytes,
                reg_bytes: 2.0 * (m * n) as f64 * BYTES_PER_PSUM as f64 * div_ceil_f(k, a),
                macs,
                vec_ops: 0.0,
            }
        }
        Dataflow::OutputStationary => {
            // array: m -> rows, n -> cols; stream k.
            let folds = div_ceil_f(m, a) * div_ceil_f(n, a);
            let cycles = folds * (k.max(a)) as f64;
            // weights cached across a GLB input tile of Tm rows; the
            // double-buffered weight stream bounds the k-extent of a
            // tile at 64 array-heights, so huge-k GEMMs (FFN2 down
            // projections) keep a usable Tm instead of degenerating
            let k_eff = k.min(64 * a);
            let tm = ((C_OS * s / (k_eff as f64 * b)) as u64).clamp(a, m.max(a));
            let mut w_refetch = div_ceil_f(m, tm);
            // short stationary operand: the weight stream cannot be
            // amortised over enough output rows
            let short = (SHORT_M * a).div_ceil(m).clamp(1, 4) as f64;
            w_refetch = w_refetch.max(short);
            let w_spill = if has_weight {
                w_bytes * (w_refetch - 1.0)
            } else {
                // activation-operand "weights" (attention) spill equally
                w_bytes * (w_refetch - 1.0)
            };
            KernelCost {
                cycles,
                weight_dram: if has_weight { w_bytes } else { 0.0 },
                spill_dram: w_spill,
                glb_bytes: w_bytes * div_ceil_f(m, a) + in_bytes * div_ceil_f(n, a) + out_bytes,
                reg_bytes: 2.0 * out_bytes * div_ceil_f(k, a),
                macs,
                vec_ops: 0.0,
            }
        }
    }
}

/// Per-request multi-head attention: `heads x (QK^T + AV)` GEMMs per
/// `(s_q, s_kv)` pair. Neither operand is a resident weight (K/V arrive
/// from the KV cache or the upstream QKV layer).
pub fn attention_cost(heads: u64, head_dim: u64, reqs: &[(u64, u64)], chip: Chiplet) -> KernelCost {
    let mut total = KernelCost::default();
    for &(sq, skv) in reqs {
        // QK^T: [s_q x d_h] @ [d_h x s_kv]
        let mut qkt = gemm_cost(sq, head_dim, skv, chip, false);
        qkt.scale(heads as f64);
        total.add(&qkt);
        // AV: [s_q x s_kv] @ [s_kv x d_h]
        let mut av = gemm_cost(sq, skv, head_dim, chip, false);
        av.scale(heads as f64);
        total.add(&av);
    }
    total
}

impl KernelCost {
    fn scale(&mut self, f: f64) {
        self.cycles *= f;
        self.weight_dram *= f;
        self.spill_dram *= f;
        self.glb_bytes *= f;
        self.reg_bytes *= f;
        self.macs *= f;
        self.vec_ops *= f;
    }
}

/// Dispatch on the layer kind; folds the layer's post-processing scalar
/// ops onto the vector unit (`vec_ops / lanes` cycles, serialised after
/// the GEMM per the paper's post-processing-unit model).
pub fn layer_cost(kind: &LayerKind, vec_ops: u64, chip: Chiplet, has_weight: bool) -> KernelCost {
    let mut c = match kind {
        LayerKind::Gemm { m, k, n } => gemm_cost(*m, *k, *n, chip, has_weight),
        LayerKind::Attention {
            heads,
            head_dim,
            reqs,
        } => attention_cost(*heads, *head_dim, reqs, chip),
    };
    let lanes = (chip.class.macs() as f64 * VEC_LANES_PER_MAC).max(1.0);
    c.cycles += vec_ops as f64 / lanes;
    c.vec_ops += vec_ops as f64;
    c
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::ChipletClass;

    fn chip(df: Dataflow) -> Chiplet {
        Chiplet {
            class: ChipletClass::M,
            dataflow: df,
        }
    }

    #[test]
    fn macs_identical_across_dataflows() {
        let ws = gemm_cost(128, 4096, 12288, chip(Dataflow::WeightStationary), true);
        let os = gemm_cost(128, 4096, 12288, chip(Dataflow::OutputStationary), true);
        assert_eq!(ws.macs, os.macs);
        assert_eq!(ws.macs, 128.0 * 4096.0 * 12288.0);
    }

    #[test]
    fn full_utilization_latency_floor() {
        // all dims >> array: cycles ~= macs / (A*A)
        let c = gemm_cost(4096, 4096, 4096, chip(Dataflow::WeightStationary), true);
        let ideal = 4096.0f64.powi(3) / (64.0 * 64.0);
        assert!((c.cycles - ideal).abs() / ideal < 0.05, "{} vs {ideal}", c.cycles);
        let o = gemm_cost(4096, 4096, 4096, chip(Dataflow::OutputStationary), true);
        assert!((o.cycles - ideal).abs() / ideal < 0.05);
    }

    #[test]
    fn ws_wins_short_sequences_os_wins_long() {
        // DRAM-traffic comparison behind paper Table I: QKV GEMM of
        // GPT3-7B at m = 128 (short prefill) and m = 10240 (long).
        let dram = |m: u64, df: Dataflow| {
            let c = gemm_cost(m, 4096, 12288, chip(df), true);
            c.weight_dram + c.spill_dram
        };
        let short_ws = dram(128, Dataflow::WeightStationary);
        let short_os = dram(128, Dataflow::OutputStationary);
        assert!(
            short_os > 1.3 * short_ws,
            "short: OS {short_os} must exceed WS {short_ws}"
        );
        let long_ws = dram(10240, Dataflow::WeightStationary);
        let long_os = dram(10240, Dataflow::OutputStationary);
        assert!(
            long_ws > 1.3 * long_os,
            "long: WS {long_ws} must exceed OS {long_os}"
        );
    }

    #[test]
    fn ws_input_refetch_grows_quadratically() {
        let spill = |m: u64| {
            gemm_cost(m, 4096, 12288, chip(Dataflow::WeightStationary), true).spill_dram
        };
        let s1 = spill(2560).max(1.0);
        let s2 = spill(10240);
        // 4x m -> ~16x spill (quadratic regime)
        assert!(s2 / s1 > 6.0, "ratio {}", s2 / s1);
    }

    #[test]
    fn decode_gemv_prefers_ws_latency() {
        // merged decode QKV: m = micro-batch (small); OS leaves the
        // m-rows of the array idle.
        let ws = gemm_cost(8, 4096, 12288, chip(Dataflow::WeightStationary), true);
        let os = gemm_cost(8, 4096, 12288, chip(Dataflow::OutputStationary), true);
        assert!(ws.cycles <= os.cycles * 1.01);
        // and OS pays the short-operand weight spill
        assert!(os.spill_dram > 0.0);
        assert_eq!(ws.spill_dram, 0.0);
    }

    #[test]
    fn attention_has_no_resident_weight() {
        let c = attention_cost(32, 128, &[(128, 128), (1, 501)], chip(Dataflow::WeightStationary));
        assert_eq!(c.weight_dram, 0.0);
        let expect = 32.0 * (2.0 * 128.0 * 128.0 * 128.0 + 2.0 * 501.0 * 128.0);
        assert_eq!(c.macs, expect);
    }

    #[test]
    fn vec_ops_add_latency_and_energy() {
        let kind = LayerKind::Gemm { m: 64, k: 64, n: 64 };
        let plain = layer_cost(&kind, 0, chip(Dataflow::WeightStationary), true);
        let with_vec = layer_cost(&kind, 1_000_000, chip(Dataflow::WeightStationary), true);
        assert!(with_vec.cycles > plain.cycles);
        assert!(with_vec.onchip_energy_pj() > plain.onchip_energy_pj());
    }

    #[test]
    fn onchip_energy_is_positive_and_mac_dominated_when_large() {
        let c = gemm_cost(2048, 4096, 4096, chip(Dataflow::OutputStationary), true);
        let e = c.onchip_energy_pj();
        assert!(e > 0.0);
        assert!(c.macs * E_MAC_PJ / e > 0.3, "MACs should be a major term");
    }
}

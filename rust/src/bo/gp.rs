//! The GP surrogate of the hardware sampling engine (paper §V-B-2).
//!
//! Two interchangeable backends compute identical math:
//!
//! * [`PjrtGp`] — the shipped path: composite-kernel Gram, masked
//!   Cholesky fit, and batched Expected Improvement are executed as the
//!   AOT-lowered JAX/Pallas artifacts through the PJRT runtime (the
//!   paper updates its BO model on an accelerator; see DESIGN.md).
//! * [`NativeGp`] — a pure-Rust mirror used for cross-validation tests
//!   and as a fallback when `artifacts/` has not been built.

use crate::runtime::shapes::{SLOTS, SYS_D, TYPES};
#[cfg(feature = "xla")]
use crate::runtime::{
    shapes::{CAND_Q, TRAIN_N},
    Runtime,
};
use crate::util::{Error, Result};

use super::features::{inv_lengthscales, manhattan_weights, HwFeatures};

/// GP kernel hyperparameters (learned by MLL grid search during BO).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Hyper {
    /// Layout-kernel variance sigma^2 (Eq. 3).
    pub sigma2: f32,
    /// Layout length scale lambda (Eq. 4).
    pub lambda: f32,
    /// Sys-RBF lengthscale.
    pub ls: f32,
    /// Observation noise variance.
    pub noise: f32,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            sigma2: 0.05,
            lambda: 2.0,
            ls: 2.0,
            noise: 1e-3,
        }
    }
}

/// Posterior + acquisition for one candidate batch.
#[derive(Debug, Clone)]
pub struct EiBatch {
    pub mean: Vec<f32>,
    pub var: Vec<f32>,
    pub ei: Vec<f32>,
}

/// A fitted surrogate able to score candidate batches.
pub trait Gp {
    /// Fit on `n` observations (features + standardised objectives).
    /// Returns the log marginal likelihood.
    fn fit(&mut self, xs: &[HwFeatures], ys: &[f32], hyper: Hyper) -> Result<f32>;

    /// Expected improvement of up to `CAND_Q` candidates against the
    /// standardised incumbent `f_best` (minimisation).
    fn ei(&self, cands: &[HwFeatures], f_best: f32) -> Result<EiBatch>;

    fn backend(&self) -> &'static str;
}

// ---------------------------------------------------------------------
// shared feature packing (PJRT artifact layout)
// ---------------------------------------------------------------------

#[cfg(feature = "xla")]
struct Packed {
    sys: Vec<f32>,    // (rows, SYS_D)
    layout: Vec<f32>, // (rows, SLOTS, TYPES)
    shape: Vec<f32>,  // (rows, 2)
    rows: usize,
}

#[cfg(feature = "xla")]
fn pack(xs: &[HwFeatures], rows: usize) -> Packed {
    assert!(xs.len() <= rows, "{} > {rows}", xs.len());
    let mut sys = vec![0f32; rows * SYS_D];
    let mut layout = vec![0f32; rows * SLOTS * TYPES];
    let mut shape = vec![0f32; rows * 2];
    for (i, x) in xs.iter().enumerate() {
        sys[i * SYS_D..(i + 1) * SYS_D].copy_from_slice(&x.sys);
        layout[i * SLOTS * TYPES..(i + 1) * SLOTS * TYPES].copy_from_slice(&x.layout);
        shape[i * 2] = x.shape[0];
        shape[i * 2 + 1] = x.shape[1];
        // padding rows keep shape (0,0): they never match a real shape
    }
    Packed {
        sys,
        layout,
        shape,
        rows,
    }
}

// ---------------------------------------------------------------------
// PJRT backend
// ---------------------------------------------------------------------

/// GP executed on the AOT artifacts through PJRT.
#[cfg(feature = "xla")]
pub struct PjrtGp<'rt> {
    rt: &'rt Runtime,
    hyper: Hyper,
    train: Option<Packed>,
    n_act: usize,
    alpha: Vec<f32>,
    chol: Vec<f32>,
    mask: Vec<f32>,
    w: Vec<f32>,
}

#[cfg(feature = "xla")]
impl<'rt> PjrtGp<'rt> {
    pub fn new(rt: &'rt Runtime) -> Self {
        PjrtGp {
            rt,
            hyper: Hyper::default(),
            train: None,
            n_act: 0,
            alpha: Vec::new(),
            chol: Vec::new(),
            mask: Vec::new(),
            w: Vec::new(),
        }
    }
}

#[cfg(feature = "xla")]
const N_I: i64 = TRAIN_N as i64;
#[cfg(feature = "xla")]
const Q_I: i64 = CAND_Q as i64;
#[cfg(feature = "xla")]
const S_I: i64 = SLOTS as i64;
#[cfg(feature = "xla")]
const T_I: i64 = TYPES as i64;
#[cfg(feature = "xla")]
const D_I: i64 = SYS_D as i64;

#[cfg(feature = "xla")]
impl Gp for PjrtGp<'_> {
    fn fit(&mut self, xs: &[HwFeatures], ys: &[f32], hyper: Hyper) -> Result<f32> {
        assert_eq!(xs.len(), ys.len());
        self.hyper = hyper;
        self.n_act = xs.len().min(TRAIN_N);
        let p = pack(&xs[..self.n_act], TRAIN_N);
        self.w = manhattan_weights(hyper.lambda);
        let ils = inv_lengthscales(hyper.ls);
        let sigma2 = [hyper.sigma2];
        let gram = self.rt.run_f32(
            "gram_train",
            &[
                (&p.sys, &[N_I, D_I]),
                (&p.sys, &[N_I, D_I]),
                (&ils, &[D_I]),
                (&p.layout, &[N_I, S_I, T_I]),
                (&p.layout, &[N_I, S_I, T_I]),
                (&self.w, &[S_I, S_I]),
                (&p.shape, &[N_I, 2]),
                (&p.shape, &[N_I, 2]),
                (&sigma2, &[]),
            ],
        )?;
        let k = &gram[0];
        let mut y = vec![0f32; TRAIN_N];
        y[..self.n_act].copy_from_slice(&ys[..self.n_act]);
        let mut mask = vec![0f32; TRAIN_N];
        for m in mask.iter_mut().take(self.n_act) {
            *m = 1.0;
        }
        let noise = [hyper.noise];
        let fit = self.rt.run_f32(
            "gp_fit",
            &[
                (k, &[N_I, N_I]),
                (&y, &[N_I]),
                (&mask, &[N_I]),
                (&noise, &[]),
            ],
        )?;
        self.alpha = fit[0].clone();
        self.chol = fit[1].clone();
        let mll = fit[2][0];
        self.mask = mask;
        self.train = Some(p);
        Ok(mll)
    }

    fn ei(&self, cands: &[HwFeatures], f_best: f32) -> Result<EiBatch> {
        let train = self
            .train
            .as_ref()
            .expect("fit must be called before ei");
        let q_act = cands.len().min(CAND_Q);
        let c = pack(&cands[..q_act], CAND_Q);
        let ils = inv_lengthscales(self.hyper.ls);
        let sigma2 = [self.hyper.sigma2];
        let fb = [f_best];
        // fused acquisition: one dispatch per SA step (gram + diag + EI);
        // the 3-call path remains as a fallback for pre-fusion artifacts
        if self.rt.artifacts_dir().join("ei_fused.hlo.txt").exists() {
            let out = self.rt.run_f32(
                "ei_fused",
                &[
                    (&c.sys, &[Q_I, D_I]),
                    (&c.layout, &[Q_I, S_I, T_I]),
                    (&c.shape, &[Q_I, 2]),
                    (&train.sys, &[N_I, D_I]),
                    (&train.layout, &[N_I, S_I, T_I]),
                    (&train.shape, &[N_I, 2]),
                    (&ils, &[D_I]),
                    (&self.w, &[S_I, S_I]),
                    (&sigma2, &[]),
                    (&self.chol, &[N_I, N_I]),
                    (&self.alpha, &[N_I]),
                    (&self.mask, &[N_I]),
                    (&fb, &[]),
                ],
            )?;
            return Ok(EiBatch {
                mean: out[0][..q_act].to_vec(),
                var: out[1][..q_act].to_vec(),
                ei: out[2][..q_act].to_vec(),
            });
        }
        let cross = self.rt.run_f32(
            "gram_cross",
            &[
                (&c.sys, &[Q_I, D_I]),
                (&train.sys, &[N_I, D_I]),
                (&ils, &[D_I]),
                (&c.layout, &[Q_I, S_I, T_I]),
                (&train.layout, &[N_I, S_I, T_I]),
                (&self.w, &[S_I, S_I]),
                (&c.shape, &[Q_I, 2]),
                (&train.shape, &[N_I, 2]),
                (&sigma2, &[]),
            ],
        )?;
        let diag = self.rt.run_f32(
            "gram_diag",
            &[
                (&c.layout, &[Q_I, S_I, T_I]),
                (&self.w, &[S_I, S_I]),
                (&sigma2, &[]),
            ],
        )?;
        let out = self.rt.run_f32(
            "gp_ei",
            &[
                (&cross[0], &[Q_I, N_I]),
                (&diag[0], &[Q_I]),
                (&self.chol, &[N_I, N_I]),
                (&self.alpha, &[N_I]),
                (&self.mask, &[N_I]),
                (&fb, &[]),
            ],
        )?;
        Ok(EiBatch {
            mean: out[0][..q_act].to_vec(),
            var: out[1][..q_act].to_vec(),
            ei: out[2][..q_act].to_vec(),
        })
    }

    fn backend(&self) -> &'static str {
        "pjrt"
    }
}

// ---------------------------------------------------------------------
// native backend (f64 mirror)
// ---------------------------------------------------------------------

/// Pure-Rust GP identical in math to the artifacts (used for tests and
/// as an artifact-less fallback).
#[derive(Default)]
pub struct NativeGp {
    hyper: Hyper,
    xs: Vec<HwFeatures>,
    w: Vec<f32>,
    alpha: Vec<f64>,
    chol: Vec<f64>, // n x n lower
    n: usize,
}

impl NativeGp {
    pub fn new() -> Self {
        NativeGp {
            hyper: Hyper::default(),
            ..Default::default()
        }
    }

    /// Composite kernel of Eq. 2 between two feature sets.
    fn kernel(&self, a: &HwFeatures, b: &HwFeatures) -> f64 {
        let ils = inv_lengthscales(self.hyper.ls);
        // K_sys: ARD RBF
        let mut d2 = 0f64;
        for d in 0..SYS_D {
            let x = ((a.sys[d] - b.sys[d]) * ils[d]) as f64;
            d2 += x * x;
        }
        let k_sys = (-0.5 * d2).exp();
        // indicator
        let ind = if a.shape == b.shape { 2.0 } else { 1.0 };
        // layout kernel
        let mut k_lay = 0f64;
        for u in 0..SLOTS {
            for t in 0..TYPES {
                let au = a.layout[u * TYPES + t];
                if au == 0.0 {
                    continue;
                }
                for v in 0..SLOTS {
                    let bv = b.layout[v * TYPES + t];
                    if bv != 0.0 {
                        k_lay += (au * bv * self.w[u * SLOTS + v]) as f64;
                    }
                }
            }
        }
        k_sys * ind * (self.hyper.sigma2 as f64) * k_lay
    }
}

/// Dense lower-Cholesky of a positive-definite matrix (row-major n x n).
pub fn cholesky(a: &[f64], n: usize) -> Option<Vec<f64>> {
    let mut l = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[i * n + j];
            for k in 0..j {
                s -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if s <= 0.0 {
                    return None;
                }
                l[i * n + i] = s.sqrt();
            } else {
                l[i * n + j] = s / l[j * n + j];
            }
        }
    }
    Some(l)
}

/// Solve `L x = b` (lower triangular).
pub fn solve_lower(l: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut x = vec![0f64; n];
    for i in 0..n {
        let mut s = b[i];
        for k in 0..i {
            s -= l[i * n + k] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

/// Solve `L^T x = b`.
pub fn solve_upper_t(l: &[f64], b: &[f64], n: usize) -> Vec<f64> {
    let mut x = vec![0f64; n];
    for i in (0..n).rev() {
        let mut s = b[i];
        for k in i + 1..n {
            s -= l[k * n + i] * x[k];
        }
        x[i] = s / l[i * n + i];
    }
    x
}

fn norm_cdf(z: f64) -> f64 {
    0.5 * (1.0 + erf(z / std::f64::consts::SQRT_2))
}

fn norm_pdf(z: f64) -> f64 {
    (-0.5 * z * z).exp() / (2.0 * std::f64::consts::PI).sqrt()
}

/// Abramowitz-Stegun erf approximation (|err| < 1.5e-7).
pub fn erf(x: f64) -> f64 {
    let sign = if x < 0.0 { -1.0 } else { 1.0 };
    let x = x.abs();
    let t = 1.0 / (1.0 + 0.3275911 * x);
    let y = 1.0
        - (((((1.061405429 * t - 1.453152027) * t) + 1.421413741) * t - 0.284496736) * t
            + 0.254829592)
            * t
            * (-x * x).exp();
    sign * y
}

impl Gp for NativeGp {
    fn fit(&mut self, xs: &[HwFeatures], ys: &[f32], hyper: Hyper) -> Result<f32> {
        self.hyper = hyper;
        self.w = manhattan_weights(hyper.lambda);
        self.xs = xs.to_vec();
        self.n = xs.len();
        let n = self.n;
        let mut k = vec![0f64; n * n];
        for i in 0..n {
            for j in 0..=i {
                let v = self.kernel(&xs[i], &xs[j]);
                k[i * n + j] = v;
                k[j * n + i] = v;
            }
            k[i * n + i] += (hyper.noise + 1e-6) as f64;
        }
        let l = cholesky(&k, n)
            .ok_or_else(|| Error::msg("kernel matrix not positive definite"))?;
        let y64: Vec<f64> = ys.iter().map(|&v| v as f64).collect();
        let z = solve_lower(&l, &y64, n);
        self.alpha = solve_upper_t(&l, &z, n);
        let logdet: f64 = (0..n).map(|i| l[i * n + i].ln()).sum::<f64>() * 2.0;
        let fit: f64 = y64.iter().zip(&self.alpha).map(|(y, a)| y * a).sum();
        let mll = -0.5 * fit - 0.5 * logdet - 0.5 * n as f64 * (2.0 * std::f64::consts::PI).ln();
        self.chol = l;
        Ok(mll as f32)
    }

    fn ei(&self, cands: &[HwFeatures], f_best: f32) -> Result<EiBatch> {
        let n = self.n;
        let mut mean = Vec::with_capacity(cands.len());
        let mut var = Vec::with_capacity(cands.len());
        let mut ei = Vec::with_capacity(cands.len());
        for c in cands {
            let kc: Vec<f64> = self.xs.iter().map(|x| self.kernel(c, x)).collect();
            let m: f64 = kc.iter().zip(&self.alpha).map(|(k, a)| k * a).sum();
            let v = solve_lower(&self.chol, &kc, n);
            let prior = self.kernel(c, c);
            let s2 = (prior - v.iter().map(|x| x * x).sum::<f64>()).max(1e-10);
            let sd = s2.sqrt();
            let z = (f_best as f64 - m) / sd;
            let e = (sd * (z * norm_cdf(z) + norm_pdf(z))).max(0.0);
            mean.push(m as f32);
            var.push(s2 as f32);
            ei.push(e as f32);
        }
        Ok(EiBatch { mean, var, ei })
    }

    fn backend(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow, HwConfig};
    use crate::bo::features::featurize;
    use crate::util::Rng;

    fn random_hw(rng: &mut Rng) -> HwConfig {
        let mut hw = HwConfig::homogeneous(
            2,
            4,
            *rng.choose(&ChipletClass::ALL),
            Dataflow::WeightStationary,
            *rng.choose(&[32.0, 64.0, 128.0]),
            *rng.choose(&[16.0, 32.0, 64.0]),
        );
        for d in hw.layout.iter_mut() {
            *d = *rng.choose(&Dataflow::ALL);
        }
        hw.tensor_parallel = *rng.choose(&[4usize, 8, 16]);
        hw
    }

    fn toy_data(n: usize, seed: u64) -> (Vec<HwFeatures>, Vec<f32>) {
        let mut rng = Rng::seed_from_u64(seed);
        let xs: Vec<HwFeatures> = (0..n).map(|_| featurize(&random_hw(&mut rng))).collect();
        // smooth objective of the features: correlated with sys dims + WS count
        let ys: Vec<f32> = xs
            .iter()
            .map(|f| {
                let ws: f32 = (0..SLOTS).map(|u| f.layout[u * TYPES]).sum();
                (f.sys[0] - f.sys[1]) * 0.3 + ws * 0.1
            })
            .collect();
        (xs, ys)
    }

    #[test]
    fn cholesky_solves_linear_system() {
        // A = M M^T positive definite
        let m = [2.0, 0.0, 0.0, 1.0, 3.0, 0.0, 0.5, -1.0, 1.5];
        let n = 3;
        let mut a = vec![0f64; 9];
        for i in 0..n {
            for j in 0..n {
                for k in 0..n {
                    a[i * n + j] += m[i * n + k] * m[j * n + k];
                }
            }
        }
        let l = cholesky(&a, n).unwrap();
        let b = [1.0, -2.0, 0.5];
        let z = solve_lower(&l, &b, n);
        let x = solve_upper_t(&l, &z, n);
        // check A x = b
        for i in 0..n {
            let got: f64 = (0..n).map(|j| a[i * n + j] * x[j]).sum();
            assert!((got - b[i]).abs() < 1e-9);
        }
    }

    #[test]
    fn erf_accuracy() {
        let cases = [
            (0.0, 0.0),
            (1.0, 0.8427007929),
            (-1.0, -0.8427007929),
            (2.0, 0.9953222650),
        ];
        for (x, want) in cases {
            assert!((erf(x) - want).abs() < 1e-6, "erf({x})");
        }
    }

    #[test]
    fn native_gp_interpolates_and_ranks() {
        let (xs, ys) = toy_data(16, 3);
        let mut gp = NativeGp::new();
        let mll = gp
            .fit(&xs, &ys, Hyper { noise: 1e-4, ..Default::default() })
            .unwrap();
        assert!(mll.is_finite());
        let batch = gp.ei(&xs, *ys.iter().min_by(|a, b| a.total_cmp(b)).unwrap()).unwrap();
        // posterior mean at training points tracks targets
        for (m, y) in batch.mean.iter().zip(&ys) {
            assert!((m - y).abs() < 0.25, "mean {m} vs y {y}");
        }
        // variance at training points is small
        assert!(batch.var.iter().all(|&v| v < 0.05));
        assert!(batch.ei.iter().all(|&e| e >= 0.0));
    }

    #[test]
    fn ei_rewards_unseen_regions() {
        let (xs, ys) = toy_data(10, 5);
        let mut gp = NativeGp::new();
        gp.fit(&xs, &ys, Hyper::default()).unwrap();
        let f_best = ys.iter().cloned().fold(f32::INFINITY, f32::min);
        let train_ei = gp.ei(&xs[..4], f_best).unwrap();
        // a far-away candidate (different shape, different sys) has more EI
        let mut rng = Rng::seed_from_u64(99);
        let mut far = random_hw(&mut rng);
        far.grid_h = 4;
        far.grid_w = 4;
        far.layout = vec![Dataflow::OutputStationary; 16];
        far.nop_bw_gbs = 512.0;
        let far_f = featurize(&far);
        let far_ei = gp.ei(std::slice::from_ref(&far_f), f_best).unwrap();
        let max_train = train_ei.ei.iter().cloned().fold(0.0f32, f32::max);
        assert!(
            far_ei.ei[0] >= max_train * 0.5,
            "unseen candidate EI {} should rival training EI {max_train}",
            far_ei.ei[0]
        );
        assert!(far_ei.var[0] > train_ei.var.iter().cloned().fold(0.0f32, f32::max));
    }

    #[test]
    fn identical_layouts_more_similar_than_different() {
        let mut rng = Rng::seed_from_u64(1);
        let hw = random_hw(&mut rng);
        let fa = featurize(&hw);
        let mut hw2 = hw.clone();
        for d in hw2.layout.iter_mut() {
            *d = Dataflow::OutputStationary;
        }
        let fb = featurize(&hw2);
        let gp = {
            let mut g = NativeGp::new();
            let (xs, ys) = toy_data(4, 2);
            g.fit(&xs, &ys, Hyper::default()).unwrap();
            g
        };
        let kaa = gp.kernel(&fa, &fa);
        let kab = gp.kernel(&fa, &fb);
        assert!(kaa > kab, "self-similarity {kaa} must exceed cross {kab}");
    }
}

//! Front-end control-plane sweep: SLO-aware load shedding x
//! decode-pool rebalancing x even/heterogeneous fleet sizing on one
//! request stream (the control-plane counterpart of `fleet_sim`).
//!
//! The default configuration replays GovReport-style traffic across a
//! 4-replica fleet carved from a 512-TOPS budget and compares the
//! PR 3 baseline (JSQ + arrival-time rejection) against SLO-aware
//! shedding, busy-time rebalancing, and a heterogeneous
//! prefill/decode split, at near- and over-capacity rates. It then
//! checks:
//!
//! * the refactor anchor: the legacy `simulate_fleet` entry point and
//!   the trait-based front end with `Frontend::baseline()` are
//!   bit-identical;
//! * every cell conserves requests (completed + rejected == arrived)
//!   and sheds only within its rejections;
//! * at overload, SLO-aware shedding achieves at least the
//!   arrival-time-rejection baseline's SLO goodput (full run only —
//!   the tiny CI smoke just proves the subsystem end-to-end);
//! * the bundled Azure-style trace fixture replays deterministically
//!   through the same cells.
//!
//! Run:   cargo run --release --example frontend_control
//! CI:    cargo run --example frontend_control -- --tiny
//!
//! Output is deterministic for the fixed seed baked in below.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::experiments as exp;
use compass::sim::{self, Frontend, RouterPolicy, SimConfig};
use compass::workload::serving::ServingStrategy;
use compass::workload::ModelSpec;

const SEED: u64 = 23;

struct Setup {
    label: &'static str,
    scene: exp::FleetScene,
    model: ModelSpec,
    hw: HwConfig,
    cfg: SimConfig,
}

fn setup(tiny: bool) -> Setup {
    if tiny {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.chunk_tokens = 32;
        cfg.kv_budget_tokens = 2048;
        cfg.ctx_bucket = 64;
        cfg.eval_blocks = 1;
        let mut scene = exp::FleetScene::new("sharegpt", 64.0, 2, 12);
        scene.rates_rps = Vec::new(); // auto {0.8, 1.3} x capacity
        Setup {
            label: "tiny-frontend",
            scene,
            model: ModelSpec::tiny(),
            hw: HwConfig::homogeneous(
                2,
                2,
                ChipletClass::S,
                Dataflow::WeightStationary,
                32.0,
                16.0,
            ),
            cfg,
        }
    } else {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.ctx_bucket = 1024; // GovReport contexts are ~10k tokens
        let scene = exp::FleetScene::new("govreport", 512.0, 4, 36);
        Setup {
            label: "govreport-512T-frontend4",
            model: scene.model(),
            hw: exp::sim_default_hw(scene.tops_per_replica()),
            scene,
            cfg,
        }
    }
}

fn main() {
    let tiny = std::env::args().skip(1).any(|a| a == "--tiny");
    let s = setup(tiny);
    let t0 = std::time::Instant::now();
    let knobs = exp::FrontendKnobs::default();

    println!(
        "frontend_control [{}] model={} | {} replicas of: {}",
        s.label,
        s.model.name,
        s.scene.n_replicas,
        s.hw.describe()
    );

    // --- refactor anchor: legacy entry point == baseline front end ---
    {
        let spec = s.scene.spec();
        let probe = sim::probe(&s.model, &s.hw, &s.cfg, &spec);
        let stream = sim::RequestStream::poisson(
            &spec,
            1.2 * s.scene.n_replicas as f64 * probe.capacity_rps(),
            s.scene.n_requests,
            SEED,
        );
        let mut cfg = s.cfg;
        cfg.slo = probe.slo(3.0, 4.0);
        let fleet =
            sim::FleetConfig::homogeneous(s.scene.n_replicas, RouterPolicy::JoinShortestQueue);
        let legacy = sim::simulate_fleet(&stream, &s.model, &s.hw, &cfg, &fleet);
        let hws = vec![s.hw.clone(); fleet.total_replicas()];
        let traity = sim::simulate_fleet_frontend(
            &stream,
            &s.model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
        );
        assert_eq!(
            legacy.makespan_s.to_bits(),
            traity.makespan_s.to_bits(),
            "trait front end drifted from the legacy router"
        );
        assert_eq!(legacy.energy_pj.to_bits(), traity.energy_pj.to_bits());
        assert_eq!(legacy.ttft.p99.to_bits(), traity.ttft.p99.to_bits());
        println!("refactor anchor: baseline front end is bit-identical to legacy: PASS");
    }

    // --- the control-plane sweep ---
    let rows = exp::frontend_study_with_model(&s.scene, &s.model, &s.hw, &s.cfg, &knobs, SEED);
    exp::frontend_study_table(&s.scene, &rows).print();
    for r in &rows {
        let m = &r.metrics;
        assert_eq!(
            m.n_completed + m.n_rejected,
            m.n_arrived,
            "{} @ {} does not conserve requests",
            r.key,
            r.rate_rps
        );
        assert!(m.n_shed <= m.n_rejected, "{}: shed beyond rejections", r.key);
    }
    println!("\nconservation: every cell completes or rejects every arrival: PASS");

    // --- determinism: rerun of one shedding cell is bit-identical ---
    {
        let a = exp::frontend_study_with_model(&s.scene, &s.model, &s.hw, &s.cfg, &knobs, SEED);
        let pick = |rows: &[exp::FrontendStudyRow]| {
            rows.iter()
                .map(|r| (r.metrics.makespan_s.to_bits(), r.metrics.n_shed))
                .collect::<Vec<_>>()
        };
        assert_eq!(pick(&rows), pick(&a), "front-end study rerun differs");
        println!("determinism: full study rerun is bit-identical: PASS");
    }

    // --- headline orderings at overload ---
    print!("\n{}", exp::frontend_study_headline(&rows));
    let hi = rows
        .iter()
        .map(|r| r.rate_rps)
        .fold(f64::NEG_INFINITY, f64::max);
    let at = |key: &str| {
        rows.iter()
            .find(|r| r.rate_rps == hi && r.key == key)
            .map(|r| &r.metrics)
            .expect("cell present")
    };
    let (base, shed) = (at("jsq"), at("jsq+shed"));
    let shed_ok = shed.slo_goodput_tps >= base.slo_goodput_tps;
    println!(
        "slo-shed >= arrival-reject on SLO goodput at overload: {}",
        if shed_ok { "PASS" } else { "FAIL" }
    );
    let (even, het) = (at("even-disagg"), at("hetero-disagg"));
    println!(
        "hetero-disagg vs even-disagg SLO goodput at overload: {:.1} vs {:.1} tok/s",
        het.slo_goodput_tps, even.slo_goodput_tps
    );

    // --- bundled Azure-style trace fixture replays through the cells ---
    {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/data/traces/azure_tiny.csv");
        let stream = sim::RequestStream::from_trace_file(path).expect("bundled fixture");
        let probe = sim::probe_stream(&s.model, &s.hw, &s.cfg, &stream);
        let mut cfg = s.cfg;
        cfg.slo = probe.slo(3.0, 4.0);
        let trace_rows = exp::frontend_study_stream(
            &s.scene, &s.model, &s.hw, &cfg, &knobs, &probe, &stream,
        );
        for r in &trace_rows {
            assert_eq!(r.metrics.n_completed + r.metrics.n_rejected, r.metrics.n_arrived);
            assert_eq!(r.metrics.n_arrived, stream.len());
        }
        let rerun = exp::frontend_study_stream(
            &s.scene, &s.model, &s.hw, &cfg, &knobs, &probe, &stream,
        );
        assert_eq!(
            trace_rows[0].metrics.makespan_s.to_bits(),
            rerun[0].metrics.makespan_s.to_bits(),
            "trace replay not bit-identical"
        );
        println!(
            "trace replay: {} ({} requests) through all {} cells, deterministic: PASS",
            stream.name,
            stream.len(),
            trace_rows.len()
        );
    }

    // the full GovReport run is the acceptance gate for the shedding
    // ordering; the tiny smoke only proves the subsystem end-to-end
    // (toy scale need not sit in the regime where admission dominates)
    if !tiny && !shed_ok {
        eprintln!("[frontend_control] FAIL: SLO shedding below arrival-reject goodput at overload");
        std::process::exit(1);
    }
    eprintln!("[frontend_control] done in {:.1}s", t0.elapsed().as_secs_f64());
}

//! Multi-replica fleet serving: shapes ([`FleetConfig`]) and aggregate
//! quality ([`FleetMetrics`]) for a front end replaying one
//! [`RequestStream`] across N per-replica continuous-batching
//! schedulers — the first layer where the framework answers "how many
//! packages, and split how?" rather than "which mapping?".
//!
//! Three legacy router policies:
//!
//! * **round-robin** — requests cycle replica 0, 1, ..., N-1 regardless
//!   of load;
//! * **join-shortest-queue** — each request goes to the replica with the
//!   fewest outstanding tokens (`Scheduler::backlog_tokens`; ties to
//!   the lowest index);
//! * **disaggregated prefill/decode** — P prefill replicas run prompts
//!   to the first token, then the request's KV cache migrates to one of
//!   D decode replicas (JSQ within each pool) over a handoff link costed
//!   per migrated token. Decode-side preemptions re-materialize the KV
//!   (counted again as transfer traffic) instead of recomputing.
//!
//! The decision-making itself lives in [`super::frontend`]: the legacy
//! enum variants are [`super::frontend::Router`] trait impls, and
//! [`simulate_fleet`] here is a thin wrapper over
//! [`super::frontend::simulate_fleet_frontend`] with the baseline front
//! end (legacy admission, no rebalancing) and identical hardware on
//! every replica — bitwise-equal to the pre-refactor inline router.
//!
//! Replicas advance their clocks independently; the front end
//! interleaves them at arrival (and migration) events in global time
//! order, so a fixed stream gives bit-identical fleet metrics on every
//! run — and a one-replica fleet is bitwise-equal to `simulate_serving`.

use crate::arch::HwConfig;
use crate::workload::ModelSpec;

use super::faults::FaultStats;
use super::frontend::{simulate_fleet_frontend, simulate_fleet_frontend_traced, Frontend};
use super::metrics::{outcome_stats, LatencyStats, RequestOutcome, ServingMetrics};
use super::stream::RequestStream;
use super::telemetry::SharedSink;
use super::SimConfig;

/// Front-end routing policy of the fleet.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    RoundRobin,
    JoinShortestQueue,
    /// JSQ restricted to replicas with KV headroom for the request's
    /// full footprint (falls back to plain JSQ when none has room) —
    /// the first policy added through the `Router` trait rather than
    /// the fleet loop ([`super::frontend::KvAwareRouter`]).
    KvAware,
    /// Disaggregated prefill/decode pools with KV handoff.
    PrefillDecode,
}

impl RouterPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::JoinShortestQueue => "jsq",
            RouterPolicy::KvAware => "kv-aware",
            RouterPolicy::PrefillDecode => "prefill/decode",
        }
    }
}

/// Fleet shape: N identical replicas, or a disaggregated P+D split.
#[derive(Debug, Clone)]
pub struct FleetConfig {
    pub router: RouterPolicy,
    /// Replica count for the homogeneous routers (round-robin / JSQ).
    pub n_replicas: usize,
    /// Prefill-pool size (PrefillDecode router).
    pub n_prefill: usize,
    /// Decode-pool size (PrefillDecode router).
    pub n_decode: usize,
    /// KV handoff cost per migrated token (s/token): the per-request
    /// migration delay is `context * handoff_s_per_token`.
    pub handoff_s_per_token: f64,
    /// Share of the fleet's *total* TOPS budget given to the prefill
    /// pool for heterogeneous sizing (0 = pool-proportional even
    /// split). Only meaningful for `PrefillDecode` shapes; the DSE's
    /// `FleetSpace` sizes per-replica hardware from it.
    pub prefill_tops_share: f64,
}

impl FleetConfig {
    /// N identical replicas under a per-request router.
    ///
    /// Panics (release builds too) on `RouterPolicy::PrefillDecode`:
    /// the disaggregated router is a two-pool structure, not a
    /// homogeneous per-request pick — use [`FleetConfig::disaggregated`].
    /// (This was a `debug_assert` before, so release builds silently
    /// accepted a nonsensical config with `n_prefill == n_decode == 0`.)
    pub fn homogeneous(n_replicas: usize, router: RouterPolicy) -> Self {
        assert!(
            router != RouterPolicy::PrefillDecode,
            "FleetConfig::homogeneous cannot use the PrefillDecode router; \
             use FleetConfig::disaggregated(n_prefill, n_decode, handoff)"
        );
        FleetConfig {
            router,
            n_replicas: n_replicas.max(1),
            n_prefill: 0,
            n_decode: 0,
            handoff_s_per_token: 0.0,
            prefill_tops_share: 0.0,
        }
    }

    pub fn disaggregated(n_prefill: usize, n_decode: usize, handoff_s_per_token: f64) -> Self {
        FleetConfig {
            router: RouterPolicy::PrefillDecode,
            n_replicas: 0,
            n_prefill: n_prefill.max(1),
            n_decode: n_decode.max(1),
            handoff_s_per_token,
            prefill_tops_share: 0.0,
        }
    }

    /// A disaggregated split with heterogeneous pool sizing: the
    /// prefill pool gets `prefill_tops_share` of the fleet's total
    /// compute budget (clamped to (0, 1)), the decode pool the rest —
    /// instead of the even per-replica split.
    pub fn disaggregated_hetero(
        n_prefill: usize,
        n_decode: usize,
        handoff_s_per_token: f64,
        prefill_tops_share: f64,
    ) -> Self {
        let mut cfg = Self::disaggregated(n_prefill, n_decode, handoff_s_per_token);
        cfg.prefill_tops_share = prefill_tops_share.clamp(1e-3, 1.0 - 1e-3);
        cfg
    }

    /// Total packages in the fleet (the TOPS-budget denominator).
    pub fn total_replicas(&self) -> usize {
        match self.router {
            RouterPolicy::PrefillDecode => self.n_prefill.max(1) + self.n_decode.max(1),
            _ => self.n_replicas.max(1),
        }
    }

    pub fn describe(&self) -> String {
        match self.router {
            RouterPolicy::PrefillDecode => {
                let mut s = format!(
                    "{}P+{}D disagg ({:.1e} s/tok handoff)",
                    self.n_prefill.max(1),
                    self.n_decode.max(1),
                    self.handoff_s_per_token
                );
                if self.prefill_tops_share > 0.0 {
                    s.push_str(&format!(
                        " pre={:.0}%tops",
                        100.0 * self.prefill_tops_share
                    ));
                }
                s
            }
            r => format!("{}x {}", self.n_replicas.max(1), r.name()),
        }
    }
}

/// Fleet-wide serving quality: per-replica metrics plus request-level
/// TTFT/TPOT tails stitched across replica boundaries (for the
/// disaggregated router a request's first token and completion land on
/// different replicas, so per-replica tails alone would be wrong).
#[derive(Debug, Clone)]
pub struct FleetMetrics {
    pub per_replica: Vec<ServingMetrics>,
    pub n_arrived: usize,
    pub n_completed: usize,
    pub n_rejected: usize,
    pub n_in_flight: usize,
    /// End-to-end TTFT over stitched outcomes (arrival -> first token).
    pub ttft: LatencyStats,
    /// End-to-end TPOT; for disaggregated fleets this includes the KV
    /// handoff delay between the prefill and decode stages.
    pub tpot: LatencyStats,
    pub slo_attainment: f64,
    pub goodput_rps: f64,
    /// SLO-constrained goodput (tok/s) over the fleet makespan — the
    /// fleet DSE objective.
    pub slo_goodput_tps: f64,
    pub throughput_tps: f64,
    /// Latest replica clock (the fleet drains when its last replica does).
    pub makespan_s: f64,
    pub energy_pj: f64,
    pub edp_under_load: f64,
    /// KV tokens migrated prefill -> decode (0 for homogeneous routers;
    /// block-granular for paged caches — whole blocks move).
    pub kv_transfer_tokens: u64,
    /// Busy-time-weighted mean KV-block internal fragmentation across
    /// replicas (0 for token-granular caches).
    pub kv_fragmentation: f64,
    /// Fleet-wide prefill tokens served from shared prefixes.
    pub kv_shared_tokens: u64,
    /// Fleet-wide sharing hit rate: shared tokens / prefill demand.
    pub kv_sharing_hit_rate: f64,
    /// Busy-time imbalance across replicas: `(max - min) / mean` of
    /// per-replica busy seconds (0 = perfectly balanced).
    pub load_imbalance: f64,
    /// Requests shed by SLO-aware front-end admission (a subset of
    /// `n_rejected`; 0 under the arrival-time-rejection baseline).
    pub n_shed: usize,
    /// `n_shed / n_arrived` — the shed-rate headline vs the
    /// arrival-time-rejection baseline.
    pub shed_rate: f64,
    /// Mid-decode migrations performed by the front-end rebalancer
    /// (0 with rebalancing off).
    pub n_rebalanced: usize,
    /// Fault-injection truth (availability, failed/retried/lost counts,
    /// recovery times). The all-default value — availability 1, zero
    /// counts — outside `simulate_fleet_faults`.
    pub faults: FaultStats,
    pub truncated: bool,
    /// Stitched per-request outcomes at fleet level (arrival / first
    /// token / finish across replica boundaries) — the router-trait
    /// equivalence anchors compare these bitwise.
    pub outcomes: Vec<RequestOutcome>,
}

impl FleetMetrics {
    /// Scalar objective for the fleet DSE (lower is better), mirroring
    /// [`ServingMetrics::objective`].
    pub fn objective(&self) -> f64 {
        if self.truncated {
            return 0.0;
        }
        -(self.slo_goodput_tps + 1e-3 * self.throughput_tps)
    }

    pub fn summary(&self) -> String {
        format!(
            "done {}/{} (rej {}, shed {}) | {:.1} tok/s | goodput {:.1} tok/s | \
             ttft p99 {:.3}s | tpot p99 {:.4}s | SLO {:.0}% | imbalance {:.2} | \
             kv-handoff {} tok | rebal {}",
            self.n_completed,
            self.n_arrived,
            self.n_rejected,
            self.n_shed,
            self.throughput_tps,
            self.slo_goodput_tps,
            self.ttft.p99,
            self.tpot.p99,
            100.0 * self.slo_attainment,
            self.load_imbalance,
            self.kv_transfer_tokens,
            self.n_rebalanced,
        )
    }
}

/// Replay `stream` across a fleet of identical replicas under the
/// baseline front end (legacy admission, no rebalancing, no shedding).
/// Deterministic: identical inputs give bit-identical output. This is
/// the pre-refactor entry point, now a thin wrapper over
/// [`simulate_fleet_frontend`] — the equivalence is property-tested in
/// `rust/tests/frontend_properties.rs` against a verbatim
/// reimplementation of the old inline routers.
pub fn simulate_fleet(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    fleet: &FleetConfig,
) -> FleetMetrics {
    let hws = vec![hw.clone(); fleet.total_replicas()];
    simulate_fleet_frontend(stream, model, &hws, cfg, fleet, &Frontend::baseline())
}

/// [`simulate_fleet`] with a telemetry sink attached to every replica.
/// Emission happens after each step's arithmetic, so the metrics are
/// bitwise-identical to the untraced run.
pub fn simulate_fleet_traced(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    fleet: &FleetConfig,
    sink: &SharedSink,
) -> FleetMetrics {
    let hws = vec![hw.clone(); fleet.total_replicas()];
    simulate_fleet_frontend_traced(stream, model, &hws, cfg, fleet, &Frontend::baseline(), sink)
}

/// Collapse per-replica metrics plus stitched per-request outcomes into
/// [`FleetMetrics`] (shared by every front-end path).
pub(crate) fn aggregate(
    per_replica: Vec<ServingMetrics>,
    outcomes: Vec<RequestOutcome>,
    cfg: &SimConfig,
    n_shed: usize,
    n_rebalanced: usize,
    faults: FaultStats,
) -> FleetMetrics {
    let s = outcome_stats(&outcomes, &cfg.slo);
    let makespan_s = per_replica.iter().map(|m| m.makespan_s).fold(0.0, f64::max);
    let span = makespan_s.max(1e-12);
    let gen_tokens: u64 = per_replica.iter().map(|m| m.gen_tokens).sum();
    let energy_pj: f64 = per_replica.iter().map(|m| m.energy_pj).sum();
    let kv_transfer_tokens: u64 = per_replica.iter().map(|m| m.kv_transfer_tokens).sum();
    let kv_shared_tokens: u64 = per_replica.iter().map(|m| m.kv_shared_tokens).sum();
    let kv_demand_tokens: u64 = per_replica.iter().map(|m| m.kv_demand_tokens).sum();
    let truncated = per_replica.iter().any(|m| m.truncated);
    let busy: Vec<f64> = per_replica.iter().map(|m| m.busy_s).collect();
    let busy_sum: f64 = busy.iter().sum();
    // per-replica fragmentation is already busy-weighted, so the fleet
    // mean re-weights by each replica's busy time
    let kv_fragmentation = if busy_sum > 1e-12 {
        per_replica
            .iter()
            .map(|m| m.kv_fragmentation * m.busy_s)
            .sum::<f64>()
            / busy_sum
    } else {
        0.0
    };
    let mean_busy = busy_sum / busy.len().max(1) as f64;
    let load_imbalance = if mean_busy > 1e-12 {
        let max = busy.iter().cloned().fold(f64::MIN, f64::max);
        let min = busy.iter().cloned().fold(f64::MAX, f64::min);
        (max - min) / mean_busy
    } else {
        0.0
    };
    FleetMetrics {
        n_arrived: outcomes.len(),
        n_completed: s.n_completed,
        n_rejected: s.n_rejected,
        n_in_flight: s.n_in_flight,
        ttft: LatencyStats::from(&s.ttfts),
        tpot: LatencyStats::from(&s.tpots),
        slo_attainment: if s.n_completed > 0 {
            s.slo_ok as f64 / s.n_completed as f64
        } else {
            0.0
        },
        goodput_rps: s.slo_ok as f64 / span,
        slo_goodput_tps: s.slo_ok_tokens as f64 / span,
        throughput_tps: gen_tokens as f64 / span,
        makespan_s,
        energy_pj,
        edp_under_load: (energy_pj * 1e-12) * makespan_s,
        kv_transfer_tokens,
        kv_fragmentation,
        kv_shared_tokens,
        kv_sharing_hit_rate: if kv_demand_tokens > 0 {
            kv_shared_tokens as f64 / kv_demand_tokens as f64
        } else {
            0.0
        },
        load_imbalance,
        n_shed,
        shed_rate: if outcomes.is_empty() {
            0.0
        } else {
            n_shed as f64 / outcomes.len() as f64
        },
        n_rebalanced,
        faults,
        truncated,
        per_replica,
        outcomes,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::sim::coster::MappingPolicy;
    use crate::sim::metrics::SloSpec;
    use crate::sim::simulate_serving;
    use crate::workload::serving::ServingStrategy;
    use crate::workload::trace::TraceSpec;

    fn tiny_hw() -> HwConfig {
        HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        )
    }

    fn tiny_spec() -> TraceSpec {
        TraceSpec {
            mean_in: 48.0,
            mean_out: 8.0,
            sigma_in: 0.5,
            sigma_out: 0.4,
            max_len: 4096,
            shared_prefix_tokens: 0,
        }
    }

    fn tiny_cfg() -> SimConfig {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.policy = MappingPolicy::Pipeline;
        cfg.max_batch = 6;
        cfg.chunk_tokens = 24;
        cfg.kv_budget_tokens = 1024;
        cfg.ctx_bucket = 32;
        cfg.eval_blocks = 1;
        cfg.slo = SloSpec::new(0.5, 0.1);
        cfg
    }

    fn tiny_stream(rate_scale: f64, n: usize, seed: u64) -> RequestStream {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let probe = crate::sim::probe(&model, &hw, &cfg, &tiny_spec());
        RequestStream::poisson(&tiny_spec(), rate_scale * probe.capacity_rps(), n, seed)
    }

    #[test]
    fn one_replica_fleet_matches_single_package() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let stream = tiny_stream(1.1, 10, 7);
        let single = simulate_serving(&stream, &model, &hw, &cfg);
        for router in [RouterPolicy::RoundRobin, RouterPolicy::JoinShortestQueue] {
            let fleet = FleetConfig::homogeneous(1, router);
            let f = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(f.per_replica.len(), 1);
            let m = &f.per_replica[0];
            assert_eq!(m.makespan_s.to_bits(), single.makespan_s.to_bits());
            assert_eq!(m.energy_pj.to_bits(), single.energy_pj.to_bits());
            assert_eq!(m.n_iterations, single.n_iterations);
            assert_eq!(f.n_completed, single.n_completed);
            assert_eq!(f.ttft.p99.to_bits(), single.ttft.p99.to_bits());
        }
    }

    #[test]
    fn fleet_conserves_and_is_deterministic_per_policy() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let stream = tiny_stream(2.5, 14, 3);
        for fleet in [
            FleetConfig::homogeneous(3, RouterPolicy::RoundRobin),
            FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue),
            FleetConfig::disaggregated(1, 2, 1e-7),
        ] {
            let a = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(
                a.n_completed + a.n_rejected,
                a.n_arrived,
                "{}",
                fleet.describe()
            );
            assert_eq!(a.per_replica.len(), fleet.total_replicas());
            assert!(a.n_completed > 0, "{}", fleet.describe());
            let b = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.ttft.p99.to_bits(), b.ttft.p99.to_bits());
            assert_eq!(a.kv_transfer_tokens, b.kv_transfer_tokens);
        }
    }

    #[test]
    fn disaggregation_migrates_kv_and_pays_handoff() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let stream = tiny_stream(1.5, 12, 9);
        let cheap = FleetConfig::disaggregated(1, 1, 0.0);
        let a = simulate_fleet(&stream, &model, &hw, &cfg, &cheap);
        assert!(
            a.kv_transfer_tokens > 0,
            "disaggregation must report KV handoff traffic"
        );
        // every multi-token request migrates at least its prompt + 1
        let multi: u64 = stream
            .requests
            .iter()
            .filter(|r| r.output_len > 1)
            .map(|r| r.input_len + 1)
            .sum();
        assert!(a.kv_transfer_tokens >= multi);
        // a costly handoff link can only stretch completion times
        let slow = FleetConfig::disaggregated(1, 1, 1e-3);
        let b = simulate_fleet(&stream, &model, &hw, &cfg, &slow);
        assert_eq!(a.n_completed, b.n_completed);
        assert!(
            b.makespan_s >= a.makespan_s - 1e-9,
            "handoff cost shortened the run: {} < {}",
            b.makespan_s,
            a.makespan_s
        );
        assert!(b.tpot.p99 >= a.tpot.p99 - 1e-12, "handoff must tax TPOT");
    }

    #[test]
    fn jsq_balances_no_worse_than_round_robin() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        // overload: imbalance shows up when replicas saturate
        let stream = tiny_stream(3.9, 24, 5);
        let rr = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(3, RouterPolicy::RoundRobin),
        );
        let jsq = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(3, RouterPolicy::JoinShortestQueue),
        );
        // backlog-aware routing must beat blind rotation on at least one
        // of: work balance, or drain time (both, typically)
        assert!(
            jsq.load_imbalance <= rr.load_imbalance + 1e-9
                || jsq.makespan_s <= rr.makespan_s + 1e-9,
            "jsq (imbalance {}, makespan {}) worse than rr ({}, {})",
            jsq.load_imbalance,
            jsq.makespan_s,
            rr.load_imbalance,
            rr.makespan_s
        );
    }

    /// Paged + prefix-sharing caches across a fleet: runs conserve,
    /// handoff traffic is block-rounded, and the aggregated sharing /
    /// fragmentation stats are populated.
    #[test]
    fn paged_shared_fleet_conserves_and_rounds_handoff() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg();
        cfg.kv_budget_tokens = 1024;
        cfg.kv = crate::sim::KvSpec::paged(16).with_prefix(32);
        let spec = tiny_spec().with_prefix(32);
        let probe = crate::sim::probe(&model, &hw, &cfg, &spec);
        // heavy overload: admissions overlap, so the materialized prefix
        // is referenced by co-resident requests (sharing hits)
        let stream = RequestStream::poisson(&spec, 2.5 * probe.capacity_rps(), 12, 9);
        for fleet in [
            FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue),
            FleetConfig::disaggregated(1, 1, 1e-7),
        ] {
            let m = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(
                m.n_completed + m.n_rejected,
                m.n_arrived,
                "{}",
                fleet.describe()
            );
            assert!(m.kv_shared_tokens > 0, "{}: no sharing hits", fleet.describe());
            assert!(m.kv_sharing_hit_rate > 0.0);
            assert!(m.kv_fragmentation >= 0.0 && m.kv_fragmentation <= 1.0);
            if fleet.router == RouterPolicy::PrefillDecode {
                // whole 16-token blocks migrate
                assert!(m.kv_transfer_tokens > 0);
                assert_eq!(m.kv_transfer_tokens % 16, 0, "handoff not block-granular");
            }
            // deterministic
            let b = simulate_fleet(&stream, &model, &hw, &cfg, &fleet);
            assert_eq!(m.makespan_s.to_bits(), b.makespan_s.to_bits());
        }
    }

    /// Regression: `homogeneous` used to `debug_assert` only, so
    /// release builds silently accepted a PrefillDecode "homogeneous"
    /// fleet with empty pools. It must now panic unconditionally.
    #[test]
    #[should_panic(expected = "PrefillDecode")]
    fn homogeneous_rejects_prefill_decode_router() {
        let _ = FleetConfig::homogeneous(2, RouterPolicy::PrefillDecode);
    }

    #[test]
    fn hetero_split_clamps_share_and_describes_it() {
        let f = FleetConfig::disaggregated_hetero(1, 3, 1e-8, 0.25);
        assert_eq!(f.router, RouterPolicy::PrefillDecode);
        assert!((f.prefill_tops_share - 0.25).abs() < 1e-12);
        assert!(f.describe().contains("pre=25%tops"), "{}", f.describe());
        // shares are clamped into (0, 1) so sizing never divides by zero
        assert!(FleetConfig::disaggregated_hetero(1, 1, 0.0, 0.0).prefill_tops_share > 0.0);
        assert!(FleetConfig::disaggregated_hetero(1, 1, 0.0, 7.0).prefill_tops_share < 1.0);
        // the even constructor keeps the share at zero (even split)
        assert_eq!(FleetConfig::disaggregated(1, 1, 0.0).prefill_tops_share, 0.0);
    }

    #[test]
    fn empty_stream_yields_zeroed_fleet() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg();
        let stream = RequestStream {
            name: "empty".into(),
            requests: Vec::new(),
            rate_rps: 1.0,
            seed: 0,
        };
        let f = simulate_fleet(
            &stream,
            &model,
            &hw,
            &cfg,
            &FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue),
        );
        assert_eq!(f.n_arrived, 0);
        assert_eq!(f.n_completed, 0);
        assert!(!f.truncated);
        assert_eq!(f.makespan_s, 0.0);
    }
}

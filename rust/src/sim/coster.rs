//! Iteration costing: every scheduler iteration's batch composition is
//! costed through the existing `PreparedWorkload`/`MappingEvaluator`
//! path, behind a composition-keyed memo so repeated batch shapes are
//! never re-simulated.
//!
//! Compositions are quantized before costing (context lengths rounded up
//! to `ctx_bucket`), which bounds the number of distinct shapes a long
//! simulation can produce: steady-state serving then pays one hash
//! lookup per iteration instead of one timeline simulation.
//!
//! On top of the per-coster memo sits a process-wide [`CostCache`]:
//! study cells, DSE candidates, and whole runs that cost the same batch
//! shape under the same (model, hw, policy, kv-dtype) configuration
//! share one entry instead of each re-simulating (or re-running the
//! `Searched` GA). Sharing is bitwise-sound because `cost` is a pure
//! function of exactly the fingerprinted inputs plus the quantized key.

use std::collections::HashMap;
use std::hash::BuildHasherDefault;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

use crate::arch::HwConfig;
use crate::cost::{group_params, EvalScratch, Evaluator, MappingEvaluator};
use crate::ga::{self, GaConfig};
use crate::mapping::presets;
use crate::workload::{build_workload, ModelSpec, Request};

/// How the simulator maps each iteration's workload onto the chiplets.
#[derive(Debug, Clone, Copy)]
pub enum MappingPolicy {
    /// Layer-pipeline preset (Algorithm 1), instantiated per batch shape.
    Pipeline,
    /// Data-parallel preset: each micro-batch on one chiplet.
    DataParallel,
    /// GA mapping search per distinct batch shape (the sim-backed
    /// objective of `dse::compass_dse_serving`); results are memoized so
    /// each shape is searched exactly once.
    Searched(GaConfig),
}

impl MappingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            MappingPolicy::Pipeline => "pipeline",
            MappingPolicy::DataParallel => "data-parallel",
            MappingPolicy::Searched(_) => "searched",
        }
    }
}

/// Cost of one scheduler iteration (one full forward pass of the batch).
#[derive(Debug, Clone, Copy)]
pub struct IterCost {
    pub latency_cycles: f64,
    pub energy_pj: f64,
    /// Total MACs of the (quantized) batch, for utilization accounting.
    pub macs: u64,
}

/// Canonical (sorted, quantized) batch composition: `(tag, len, past)`
/// triples with tag 0 = prefill, 1 = decode.
type CompKey = Vec<(u8, u64, u64)>;

/// Snapshot of the shared-cache counters (the `--profile` cache-stats
/// table). Unlike the per-coster counters these are *not* deterministic
/// under parallel search — which coster reaches a shape first depends on
/// scheduling — so they are reported for observability only and never
/// enter metrics, records, or trace bytes.
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    /// Lookups served by the shared cache (the local memo missed but
    /// another coster had already simulated the shape).
    pub hits: usize,
    /// Lookups that fell through to a fresh simulation.
    pub misses: usize,
    /// Misses that ran a `MappingPolicy::Searched` GA search.
    pub ga_searches: usize,
    /// Shared hits that would have run a GA search without the cache.
    pub ga_avoided: usize,
    /// Distinct (model, hw, policy, kv-dtype) fingerprints seen.
    pub configs: usize,
    /// Total cost entries across all fingerprints.
    pub entries: usize,
}

/// One fingerprint's slice of the shared cache: a mutex-guarded map from
/// quantized composition key to cost. Costers resolve their shard once
/// at construction, so the hot path never touches the shard directory.
#[derive(Default)]
struct Shard {
    map: Mutex<HashMap<CompKey, IterCost, BuildHasherDefault<FxHasher>>>,
}

/// Thread-safe cost cache shared across [`BatchCoster`] instances — and
/// therefore across study cells, DSE candidates, and whole runs in one
/// process.
///
/// Entries are keyed by an exact configuration fingerprint (the `Debug`
/// rendering of model, hardware, and policy, plus `eval_blocks` and the
/// KV bit width) and, within that fingerprint's shard, by the quantized
/// composition key. Sharing is bitwise-sound because `cost` is a pure
/// function of exactly those inputs: the quantized key *is* the costed
/// batch (so `ctx_bucket` is deliberately *not* fingerprinted — two
/// costers with different buckets that land on the same quantized key
/// cost the identical workload), and `Searched` GA seeds derive from
/// the key alone via `key_hash`, never from lookup order or thread
/// identity. Racing threads compute bit-identical values for the same
/// key, so which insert wins is unobservable.
pub struct CostCache {
    shards: Mutex<HashMap<String, Arc<Shard>>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
    ga_searches: AtomicUsize,
    ga_avoided: AtomicUsize,
}

impl CostCache {
    pub fn new() -> Self {
        CostCache {
            shards: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            ga_searches: AtomicUsize::new(0),
            ga_avoided: AtomicUsize::new(0),
        }
    }

    /// The process-global cache attached by [`BatchCoster::new`]
    /// (unless `COMPASS_SHARED_CACHE=0`).
    pub fn global() -> Arc<CostCache> {
        static GLOBAL: OnceLock<Arc<CostCache>> = OnceLock::new();
        GLOBAL.get_or_init(|| Arc::new(CostCache::new())).clone()
    }

    fn shard(&self, fingerprint: String) -> Arc<Shard> {
        let mut shards = self.shards.lock().unwrap();
        shards.entry(fingerprint).or_default().clone()
    }

    /// Counter + size snapshot (taken non-atomically across shards;
    /// exact when the cache is quiescent, e.g. at end of run).
    pub fn stats(&self) -> CacheStats {
        let (configs, entries) = {
            let shards = self.shards.lock().unwrap();
            let entries = shards.values().map(|s| s.map.lock().unwrap().len()).sum();
            (shards.len(), entries)
        };
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            ga_searches: self.ga_searches.load(Ordering::Relaxed),
            ga_avoided: self.ga_avoided.load(Ordering::Relaxed),
            configs,
            entries,
        }
    }

    /// Drop every entry and zero the counters. Costers constructed
    /// before the clear keep their (now detached) shards; benches call
    /// this between phases for cold-vs-warm comparisons.
    pub fn clear(&self) {
        self.shards.lock().unwrap().clear();
        self.hits.store(0, Ordering::Relaxed);
        self.misses.store(0, Ordering::Relaxed);
        self.ga_searches.store(0, Ordering::Relaxed);
        self.ga_avoided.store(0, Ordering::Relaxed);
    }
}

impl Default for CostCache {
    fn default() -> Self {
        CostCache::new()
    }
}

/// Cross-coster sharing is on by default; `COMPASS_SHARED_CACHE=0`
/// turns it off (every coster then sees only its local memo).
fn sharing_enabled() -> bool {
    std::env::var("COMPASS_SHARED_CACHE").map_or(true, |v| v != "0")
}

/// Exact configuration fingerprint for shard selection. The `Debug`
/// renderings are structural over every field that enters `cost`, so
/// distinct configurations can never collide into one shard; the string
/// is built once per coster, never on the hot path.
fn fingerprint(
    model: &ModelSpec,
    hw: &HwConfig,
    policy: &MappingPolicy,
    eval_blocks: usize,
    kv_bits: u64,
) -> String {
    format!("{model:?}|{hw:?}|{policy:?}|blocks={eval_blocks}|kv={kv_bits}")
}

/// Composition-memoized batch coster.
pub struct BatchCoster<'a> {
    model: &'a ModelSpec,
    hw: &'a HwConfig,
    policy: MappingPolicy,
    eval_blocks: usize,
    ctx_bucket: u64,
    /// KV-cache element width (bits): quantized caches (fp8/int4) move
    /// proportionally fewer KV bytes per iteration, so decode-phase
    /// attention gets cheaper along with the capacity gain.
    kv_bits: u64,
    memo: HashMap<CompKey, IterCost, BuildHasherDefault<FxHasher>>,
    /// Reusable composition-key scratch: `fill_key` rebuilds it in place
    /// so steady-state memo hits allocate nothing.
    key_buf: CompKey,
    /// Shared cache handle plus this configuration's pre-resolved shard
    /// (`None` = local memo only).
    shared: Option<(Arc<CostCache>, Arc<Shard>)>,
    lookups: usize,
    /// Explicit counters — one per lookup outcome, so accounting stays
    /// exact however lookups are served (local memo, shared cache, or a
    /// fresh simulation). Invariant: lookups == hits + shared_hits +
    /// computed.
    hits: usize,
    shared_hits: usize,
    computed: usize,
}

impl<'a> BatchCoster<'a> {
    pub fn new(
        model: &'a ModelSpec,
        hw: &'a HwConfig,
        policy: MappingPolicy,
        eval_blocks: usize,
        ctx_bucket: u64,
        kv_dtype: super::kv::KvDtype,
    ) -> Self {
        let cache = sharing_enabled().then(CostCache::global);
        Self::with_cache(model, hw, policy, eval_blocks, ctx_bucket, kv_dtype, cache)
    }

    /// Like [`BatchCoster::new`] but with an explicit shared cache
    /// (`None` disables cross-coster sharing). `new` attaches the
    /// process-global [`CostCache::global`] unless the
    /// `COMPASS_SHARED_CACHE=0` kill switch is set.
    pub fn with_cache(
        model: &'a ModelSpec,
        hw: &'a HwConfig,
        policy: MappingPolicy,
        eval_blocks: usize,
        ctx_bucket: u64,
        kv_dtype: super::kv::KvDtype,
        cache: Option<Arc<CostCache>>,
    ) -> Self {
        let kv_bits = kv_dtype.bits();
        let shared = cache.map(|c| {
            let shard = c.shard(fingerprint(model, hw, &policy, eval_blocks, kv_bits));
            (c, shard)
        });
        BatchCoster {
            model,
            hw,
            policy,
            eval_blocks,
            ctx_bucket,
            kv_bits,
            memo: HashMap::default(),
            key_buf: CompKey::new(),
            shared,
            lookups: 0,
            hits: 0,
            shared_hits: 0,
            computed: 0,
        }
    }

    #[inline]
    fn quantize(&self, x: u64) -> u64 {
        let b = self.ctx_bucket.max(1);
        x.div_ceil(b) * b
    }

    /// Rebuild the canonical quantized composition key of a batch into
    /// the reusable `key_buf` (no allocation once the buffer has grown
    /// to the steady-state batch size).
    fn fill_key(&mut self, batch: &[Request]) {
        let b = self.ctx_bucket.max(1);
        let q = |x: u64| x.div_ceil(b) * b;
        self.key_buf.clear();
        self.key_buf.extend(batch.iter().map(|r| match *r {
            Request::Prefill { len, past } => (0u8, q(len.max(1)), q(past)),
            Request::Decode { ctx } => (1u8, q(ctx.max(1)), 0),
        }));
        self.key_buf.sort_unstable();
    }

    /// Distinct batch shapes simulated so far.
    pub fn distinct_shapes(&self) -> usize {
        self.memo.len()
    }

    /// Total `cost` calls (memo hits + misses).
    pub fn lookups(&self) -> usize {
        self.lookups
    }

    /// Local memo hits: lookups this coster served from its own memo.
    /// (Counted explicitly — the old derived form `lookups - memo.len()`
    /// could not distinguish a shared-cache hit from a local repeat.)
    /// Deterministic under any thread count, so it is safe in traces.
    pub fn hits(&self) -> usize {
        self.hits
    }

    /// Lookups served by the shared [`CostCache`]: the local memo missed
    /// but another coster had already simulated the shape. *Not*
    /// deterministic under parallel search (it depends on which coster
    /// got there first), so it feeds only observability surfaces.
    pub fn shared_hits(&self) -> usize {
        self.shared_hits
    }

    /// Lookups that actually simulated (both the local memo and the
    /// shared cache missed). `shared_hits + computed == distinct_shapes`.
    pub fn computed(&self) -> usize {
        self.computed
    }

    /// Account for `n` cost lookups the scheduler's decode fast-forward
    /// replayed without calling [`BatchCoster::cost`]: a coalesced
    /// stretch costs its (constant) composition once and reuses the
    /// `IterCost` for the remaining iterations, each of which the naive
    /// loop would have served as a guaranteed *local* memo hit (the
    /// first lookup of the stretch leaves the key in the local memo on
    /// every path). Booking them keeps the deterministic counters —
    /// which feed traced-run counter records — bitwise identical to
    /// naive stepping, and the invariant
    /// `lookups == hits + shared_hits + computed` intact. The shared
    /// [`CostCache`] counters are deliberately untouched: local repeats
    /// never reach the shared cache.
    pub fn note_replayed_hits(&mut self, n: usize) {
        self.lookups += n;
        self.hits += n;
    }

    /// Cost one iteration batch; memo hits never re-simulate.
    ///
    /// The steady-state hit path is allocation-free: the composition key
    /// is rebuilt into a reusable buffer and looked up as a borrowed
    /// slice (`Vec<K>: Borrow<[K]>`); only a miss clones the key into
    /// the memo.
    pub fn cost(&mut self, batch: &[Request]) -> IterCost {
        debug_assert!(!batch.is_empty(), "cannot cost an empty batch");
        self.lookups += 1;
        self.fill_key(batch);
        if let Some(c) = self.memo.get(self.key_buf.as_slice()) {
            self.hits += 1;
            let _p = super::telemetry::profile::scope("coster.memo_hit");
            return *c;
        }
        let searched = matches!(self.policy, MappingPolicy::Searched(_));
        if let Some((cache, shard)) = &self.shared {
            let found = shard.map.lock().unwrap().get(self.key_buf.as_slice()).copied();
            if let Some(c) = found {
                let _p = super::telemetry::profile::scope("coster.shared_hit");
                self.shared_hits += 1;
                cache.hits.fetch_add(1, Ordering::Relaxed);
                if searched {
                    cache.ga_avoided.fetch_add(1, Ordering::Relaxed);
                }
                // Mirror into the local memo: steady-state repeats stay
                // lock-free, and the deterministic local counters keep
                // the same values a cache-off run would report.
                self.memo.insert(self.key_buf.clone(), c);
                return c;
            }
        }
        let _p = super::telemetry::profile::scope("coster.memo_miss");
        // the quantized key *is* the costed batch: decode it back
        let qbatch: Vec<Request> = self
            .key_buf
            .iter()
            .map(|&(tag, len, past)| {
                if tag == 0 {
                    Request::Prefill { len, past }
                } else {
                    Request::Decode { ctx: len }
                }
            })
            .collect();
        let has_prefill = qbatch.iter().any(|r| r.is_prefill());
        let params = group_params(self.hw, has_prefill, self.eval_blocks);
        let mut w = build_workload(self.model, &qbatch, &params);
        if self.kv_bits != 16 {
            // scale the fp16-sized KV traffic to the cache dtype; the
            // uniform factor keeps shape-class cost memoization sound
            for mb in w.micro_batches.iter_mut() {
                for l in mb.layers.iter_mut() {
                    l.kv_read_bytes = l.kv_read_bytes * self.kv_bits / 16;
                    l.kv_write_bytes = l.kv_write_bytes * self.kv_bits / 16;
                }
            }
        }
        let (rows, cols) = (w.num_micro_batches(), w.layers_per_mb);
        let chips = self.hw.num_chiplets();
        let (latency_cycles, energy_pj) = match self.policy {
            MappingPolicy::Pipeline => {
                let m = presets::pipeline_parallel(rows, cols, chips);
                let r = Evaluator::new().eval_batch(&w, self.hw, &m);
                (r.latency_cycles, r.energy_pj)
            }
            MappingPolicy::DataParallel => {
                let m = presets::data_parallel(rows, cols, chips);
                let r = Evaluator::new().eval_batch(&w, self.hw, &m);
                (r.latency_cycles, r.energy_pj)
            }
            MappingPolicy::Searched(ga_cfg) => {
                // per-shape seed: order-independent, deterministic
                let mut cfg = ga_cfg;
                cfg.seed = ga_cfg.seed ^ key_hash(&self.key_buf);
                let mev = MappingEvaluator::new(&w, self.hw);
                let res = ga::search(rows, cols, chips, &cfg, &mev);
                let mut scratch = EvalScratch::default();
                let r = mev.simulate(&res.best, &mut scratch);
                (r.latency_cycles, r.energy_pj)
            }
        };
        let c = IterCost {
            latency_cycles,
            energy_pj,
            macs: w.total_macs(),
        };
        self.computed += 1;
        let key = self.key_buf.clone();
        if let Some((cache, shard)) = &self.shared {
            cache.misses.fetch_add(1, Ordering::Relaxed);
            if searched {
                cache.ga_searches.fetch_add(1, Ordering::Relaxed);
            }
            // First writer wins; any racer computed the same bits, so
            // keeping the existing entry is value-identical.
            shard.map.lock().unwrap().entry(key.clone()).or_insert(c);
        }
        self.memo.insert(key, c);
        c
    }
}

/// Deterministic 64-bit hash of a composition key.
///
/// Stays on `DefaultHasher` (keyed with fixed constants, stable across
/// runs) because it seeds `MappingPolicy::Searched` GA runs: switching
/// it would silently change every searched-policy result bitwise. The
/// memo's table hasher ([`FxHasher`]) is a separate, cheaper function —
/// map iteration order is never observed, so it is free to change.
fn key_hash(key: &CompKey) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    h.finish()
}

/// Cheap deterministic hasher for the composition memo (FxHash-style
/// rotate–xor–multiply, fixed seed). Unkeyed by design: the memo is an
/// internal cache whose iteration order is never observed, and the hot
/// path hashes a handful of machine words per lookup, where SipHash's
/// setup cost dominates.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const FX_SEED: u64 = 0x517c_c1b7_2722_0a95;

impl FxHasher {
    #[inline]
    fn add(&mut self, w: u64) {
        self.hash = (self.hash.rotate_left(5) ^ w).wrapping_mul(FX_SEED);
    }
}

impl std::hash::Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.add(b as u64);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::sim::kv::KvDtype;

    fn setup() -> (ModelSpec, HwConfig) {
        let model = ModelSpec::tiny();
        let hw = HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        (model, hw)
    }

    #[test]
    fn memo_hits_on_quantized_repeats() {
        let (model, hw) = setup();
        let mut c = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 64, KvDtype::Fp16);
        let a = c.cost(&[Request::decode(100), Request::decode(120)]);
        // same bucket (128) for both contexts -> same shape, no re-sim
        let b = c.cost(&[Request::decode(97), Request::decode(128)]);
        assert_eq!(c.distinct_shapes(), 1);
        assert_eq!(c.lookups(), 2);
        assert_eq!(c.hits(), 1);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        // crossing a bucket boundary is a new shape
        c.cost(&[Request::decode(200), Request::decode(128)]);
        assert_eq!(c.distinct_shapes(), 2);
        assert_eq!(c.hits(), 1);
    }

    #[test]
    fn replayed_hits_match_repeated_lookups() {
        let (model, hw) = setup();
        let batch = [Request::decode(100), Request::decode(120)];
        // naive: one real lookup + k-1 identical repeats
        let mut naive = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            64,
            KvDtype::Fp16,
            None,
        );
        let k = 5;
        for _ in 0..k {
            naive.cost(&batch);
        }
        // coalesced: one real lookup, then book the replays
        let mut ff = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            64,
            KvDtype::Fp16,
            None,
        );
        ff.cost(&batch);
        ff.note_replayed_hits(k - 1);
        assert_eq!(ff.lookups(), naive.lookups());
        assert_eq!(ff.hits(), naive.hits());
        assert_eq!(ff.shared_hits(), naive.shared_hits());
        assert_eq!(ff.computed(), naive.computed());
        assert_eq!(ff.distinct_shapes(), naive.distinct_shapes());
        assert_eq!(
            ff.lookups(),
            ff.hits() + ff.shared_hits() + ff.computed(),
            "accounting invariant"
        );
    }

    #[test]
    fn key_is_order_invariant() {
        let (model, hw) = setup();
        let mut c = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Fp16);
        let x = c.cost(&[Request::prefill(60), Request::decode(40)]);
        let y = c.cost(&[Request::decode(40), Request::prefill(60)]);
        assert_eq!(c.distinct_shapes(), 1);
        assert_eq!(x.latency_cycles.to_bits(), y.latency_cycles.to_bits());
    }

    #[test]
    fn quantized_kv_never_costs_more_than_fp16() {
        let (model, hw) = setup();
        // long-context decode batch: KV traffic dominates the iteration
        let batch = vec![Request::decode(2048); 8];
        let mut fp16 = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Fp16);
        let mut int4 = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Int4);
        let a = fp16.cost(&batch);
        let b = int4.cost(&batch);
        assert!(
            b.latency_cycles <= a.latency_cycles,
            "int4 KV slower than fp16: {} > {}",
            b.latency_cycles,
            a.latency_cycles
        );
        assert!(b.energy_pj <= a.energy_pj);
        assert_eq!(a.macs, b.macs, "quantization must not change the math");
    }

    #[test]
    fn memo_counters_stay_consistent_under_reused_key_buffer() {
        let (model, hw) = setup();
        let mut c = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 64, KvDtype::Fp16);
        // Vary batch size up and down so the reusable key buffer must
        // both grow and shrink; the accounting invariant
        // lookups == hits + distinct_shapes must hold after every call.
        let batches: Vec<Vec<Request>> = vec![
            vec![Request::decode(100); 8],
            vec![Request::decode(100); 2],
            vec![Request::decode(100); 8],
            vec![Request::prefill(60), Request::decode(40)],
            vec![Request::decode(100); 2],
            vec![Request::decode(40), Request::prefill(60)],
        ];
        for (i, b) in batches.iter().enumerate() {
            c.cost(b);
            assert_eq!(
                c.lookups(),
                c.hits() + c.distinct_shapes(),
                "accounting broke after call {i}"
            );
            assert_eq!(c.lookups(), i + 1);
        }
        // 8-wide decode, 2-wide decode, mixed: three distinct shapes,
        // each repeated once.
        assert_eq!(c.distinct_shapes(), 3);
        assert_eq!(c.hits(), 3);
    }

    #[test]
    fn stale_key_buffer_tail_never_leaks_into_smaller_batches() {
        let (model, hw) = setup();
        let mut big = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Fp16);
        let mut fresh = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Fp16);
        // Prime `big`'s key buffer with a wide batch, then cost a narrow
        // one: the result must be bitwise what a fresh coster computes.
        big.cost(&vec![Request::decode(500); 16]);
        let small = [Request::prefill(20), Request::decode(70)];
        let a = big.cost(&small);
        let b = fresh.cost(&small);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.macs, b.macs);
        assert_eq!(big.distinct_shapes(), 2);
    }

    #[test]
    fn quantized_key_costs_identically_to_decoded_batch() {
        let (model, hw) = setup();
        let bucket = 64;
        let mut raw = BatchCoster::new(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            bucket,
            KvDtype::Fp16,
        );
        let mut dec = BatchCoster::new(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            bucket,
            KvDtype::Fp16,
        );
        // Cost an unaligned batch, then hand a second coster the
        // pre-quantized (bucket-aligned) equivalent: the memo key is the
        // costed batch, so both must produce bitwise-identical costs and
        // the aligned batch must also land on the same key.
        let q = |x: u64| x.div_ceil(bucket) * bucket;
        let batch = [
            Request::Prefill { len: 90, past: 10 },
            Request::decode(130),
        ];
        let aligned = [
            Request::Prefill {
                len: q(90),
                past: q(10),
            },
            Request::decode(q(130)),
        ];
        let a = raw.cost(&batch);
        let b = dec.cost(&aligned);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.macs, b.macs);
        // and the aligned batch is a memo hit on the raw coster
        raw.cost(&aligned);
        assert_eq!(raw.distinct_shapes(), 1);
        assert_eq!(raw.hits(), 1);
    }

    #[test]
    fn fx_hasher_is_deterministic_and_spreads() {
        use std::hash::{Hash, Hasher};
        let key: CompKey = vec![(0, 64, 0), (1, 128, 0)];
        let h = |k: &CompKey| {
            let mut h = FxHasher::default();
            k.hash(&mut h);
            h.finish()
        };
        assert_eq!(h(&key), h(&key.clone()));
        let other: CompKey = vec![(0, 64, 0), (1, 192, 0)];
        assert_ne!(h(&key), h(&other));
        // slice and owned-vec hashing agree (the borrowed-slice memo
        // lookup depends on this)
        let mut hs = FxHasher::default();
        key.as_slice().hash(&mut hs);
        let mut hv = FxHasher::default();
        key.hash(&mut hv);
        assert_eq!(hs.finish(), hv.finish());
    }

    #[test]
    fn hit_accounting_is_explicit_under_shared_cache() {
        let (model, hw) = setup();
        let cache = Arc::new(CostCache::new());
        let mk = |c: Option<Arc<CostCache>>| {
            BatchCoster::with_cache(&model, &hw, MappingPolicy::Pipeline, 1, 64, KvDtype::Fp16, c)
        };
        let batch = [Request::decode(100), Request::decode(120)];
        let mut c1 = mk(Some(cache.clone()));
        let a = c1.cost(&batch);
        assert_eq!((c1.hits(), c1.shared_hits(), c1.computed()), (0, 0, 1));
        // Second coster: the shared cache serves its first lookup. The
        // old derived accounting (`lookups - memo.len()`) could not
        // represent this outcome; the explicit counters must.
        let mut c2 = mk(Some(cache.clone()));
        let b = c2.cost(&batch);
        assert_eq!((c2.hits(), c2.shared_hits(), c2.computed()), (0, 1, 0));
        assert_eq!(c2.distinct_shapes(), 1, "shared hit mirrors locally");
        // A repeat is now a plain local hit, not a second shared hit.
        c2.cost(&batch);
        assert_eq!((c2.hits(), c2.shared_hits(), c2.computed()), (1, 1, 0));
        assert_eq!(c2.lookups(), c2.hits() + c2.shared_hits() + c2.computed());
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.macs, b.macs);
        let s = cache.stats();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!((s.configs, s.entries), (1, 1));
    }

    #[test]
    fn cache_off_matches_cache_on_bitwise() {
        let (model, hw) = setup();
        let cache = Arc::new(CostCache::new());
        let batches: Vec<Vec<Request>> = vec![
            vec![Request::decode(100); 4],
            vec![Request::prefill(60), Request::decode(40)],
            vec![Request::decode(100); 4],
        ];
        let mut on1 = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            32,
            KvDtype::Fp16,
            Some(cache.clone()),
        );
        let mut on2 = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            32,
            KvDtype::Fp16,
            Some(cache.clone()),
        );
        let mut off = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            32,
            KvDtype::Fp16,
            None,
        );
        for b in &batches {
            let x = on1.cost(b);
            let y = on2.cost(b); // always shared- or memo-served
            let z = off.cost(b);
            assert_eq!(x.latency_cycles.to_bits(), z.latency_cycles.to_bits());
            assert_eq!(y.latency_cycles.to_bits(), z.latency_cycles.to_bits());
            assert_eq!(x.energy_pj.to_bits(), z.energy_pj.to_bits());
            assert_eq!(y.energy_pj.to_bits(), z.energy_pj.to_bits());
        }
        assert_eq!(on2.computed(), 0, "on2 never had to simulate");
        // Deterministic local accounting matches the cache-off coster.
        assert_eq!(on1.hits(), off.hits());
        assert_eq!(on1.distinct_shapes(), off.distinct_shapes());
    }

    #[test]
    fn shared_cache_avoids_ga_searches_bitwise() {
        let (model, hw) = setup();
        let cfg = crate::ga::GaConfig::tiny();
        let cache = Arc::new(CostCache::new());
        let batch = vec![Request::decode(50); 4];
        let mut c1 = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Searched(cfg),
            1,
            32,
            KvDtype::Fp16,
            Some(cache.clone()),
        );
        let mut c2 = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Searched(cfg),
            1,
            32,
            KvDtype::Fp16,
            Some(cache.clone()),
        );
        let mut solo = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Searched(cfg),
            1,
            32,
            KvDtype::Fp16,
            None,
        );
        let a = c1.cost(&batch);
        let b = c2.cost(&batch);
        let c = solo.cost(&batch);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.latency_cycles.to_bits(), c.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.energy_pj.to_bits(), c.energy_pj.to_bits());
        let s = cache.stats();
        assert_eq!(s.ga_searches, 1, "one real GA run");
        assert_eq!(s.ga_avoided, 1, "one GA run served from the cache");
    }

    #[test]
    fn distinct_configs_never_share_a_shard() {
        let (model, hw) = setup();
        let cache = Arc::new(CostCache::new());
        let batch = vec![Request::decode(2048); 8];
        let mut fp16 = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            32,
            KvDtype::Fp16,
            Some(cache.clone()),
        );
        let mut int4 = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            32,
            KvDtype::Int4,
            Some(cache.clone()),
        );
        fp16.cost(&batch);
        int4.cost(&batch);
        let s = cache.stats();
        assert_eq!(s.misses, 2, "different kv dtypes must not share");
        assert_eq!(s.hits, 0);
        assert_eq!(s.configs, 2);
    }

    #[test]
    fn cross_ctx_bucket_sharing_costs_the_quantized_key() {
        let (model, hw) = setup();
        let cache = Arc::new(CostCache::new());
        // bucket 64 quantizes decode(100) to decode(128) before costing;
        // a bucket-1 coster handed decode(128) lands on the same key, so
        // excluding ctx_bucket from the fingerprint is sound.
        let mut wide = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            64,
            KvDtype::Fp16,
            Some(cache.clone()),
        );
        let mut exact = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            1,
            KvDtype::Fp16,
            Some(cache.clone()),
        );
        let mut fresh = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            1,
            KvDtype::Fp16,
            None,
        );
        let a = wide.cost(&[Request::decode(100), Request::decode(120)]);
        let b = exact.cost(&[Request::decode(128), Request::decode(128)]);
        let c = fresh.cost(&[Request::decode(128), Request::decode(128)]);
        assert_eq!(exact.shared_hits(), 1, "cross-bucket shared hit");
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(b.latency_cycles.to_bits(), c.latency_cycles.to_bits());
        assert_eq!(b.energy_pj.to_bits(), c.energy_pj.to_bits());
        assert_eq!(cache.stats().configs, 1, "ctx_bucket not fingerprinted");
    }

    #[test]
    fn clear_resets_entries_and_counters() {
        let (model, hw) = setup();
        let cache = Arc::new(CostCache::new());
        let mut c = BatchCoster::with_cache(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            32,
            KvDtype::Fp16,
            Some(cache.clone()),
        );
        c.cost(&[Request::decode(64)]);
        assert_eq!(cache.stats().entries, 1);
        cache.clear();
        let s = cache.stats();
        assert_eq!((s.hits, s.misses, s.entries, s.configs), (0, 0, 0, 0));
    }

    #[test]
    fn searched_policy_is_deterministic() {
        let (model, hw) = setup();
        let cfg = crate::ga::GaConfig::tiny();
        let batch = vec![Request::decode(50); 4];
        let mut c1 = BatchCoster::new(&model, &hw, MappingPolicy::Searched(cfg), 1, 32, KvDtype::Fp16);
        let mut c2 = BatchCoster::new(&model, &hw, MappingPolicy::Searched(cfg), 1, 32, KvDtype::Fp16);
        let a = c1.cost(&batch);
        let b = c2.cost(&batch);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert!(a.macs > 0);
    }
}

//! Paper §VI-F case study: the interplay between system-level serving
//! strategies (vLLM / Orca / Chunked Prefill, Fig. 9) and multi-chiplet
//! hardware design, on the GovReport-512TOPS scenario; finishes with the
//! homogeneous-vs-heterogeneous comparison of Fig. 10(b).
//!
//! Run: `cargo run --release --example serving_strategies`

use compass::dse::DseConfig;
use compass::experiments as exp;
use compass::runtime::Runtime;
use compass::workload::serving::ServingStrategy;

fn main() {
    let cfg = DseConfig::reduced();
    let rt = Runtime::from_env().ok();
    let decode_groups = 3;

    println!("GovReport-512TOPS: 1 long prefill amid {decode_groups} decode batches of 128\n");
    let results = exp::fig10_serving(&cfg, rt.as_ref(), 11, decode_groups);
    exp::fig10a_table(&results).print();
    exp::table7(&results).print();

    // chunked prefill should even out per-batch cost: report the
    // first-batch share of total latency per strategy
    println!();
    for r in &results {
        let share = r.first_other[0] / r.latency.max(1e-300);
        println!(
            "{:<14} first-batch latency share: {:5.1}%",
            r.strategy.name(),
            100.0 * share
        );
    }

    let cp = results
        .iter()
        .find(|r| r.strategy == ServingStrategy::ChunkedPrefill)
        .expect("chunked prefill present");
    exp::fig10b_homo_hetero(&cfg, &cp.hw, 11, decode_groups).print();
}

//! Deterministic telemetry end-to-end: request lifecycle spans,
//! per-iteration occupancy, the counter registry and the Chrome-trace
//! exporter, driven through every traced entry point and checked for
//! the two invariants the subsystem promises:
//!
//! * **Free when attached**: metrics with a recording `SpanCollector`
//!   (and with the explicit `NullSink`) are bit-identical to the
//!   untraced run — emission happens after each step's arithmetic, so
//!   observation never perturbs the simulation;
//! * **Spans conserve to outcomes**: each request's phase spans
//!   (queue / prefill / decode / backoff / migrate) tile its lifetime
//!   contiguously, the per-lane durations sum to the lane window, the
//!   lane windows reproduce the stitched outcome latencies, and lane
//!   counts reproduce the run totals (arrived / completed / rejected)
//!   — including under a seeded crash + straggler storm with retries,
//!   where crash-clock overshoot makes lane windows an upper bound on
//!   outcome latency rather than an exact match.
//!
//! Also renders the per-request ASCII waterfall, proves the trace
//! JSON is byte-identical across reruns, and smoke-tests the
//! wall-clock profiler. With `--trace-out PATH` the Chrome trace of
//! the fault scenario is written to PATH (Perfetto-loadable; this is
//! what the CI smoke validates).
//!
//! Run:   cargo run --release --example telemetry
//! CI:    cargo run --example telemetry -- --tiny --trace-out /tmp/trace.json
//!
//! Output is deterministic for the fixed seeds baked in below.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::experiments as exp;
use compass::sim::{
    self, Frontend, ResilienceSpec, RouterPolicy, SimConfig, SpanCollector,
};
use compass::workload::serving::ServingStrategy;
use compass::workload::ModelSpec;

const SEED: u64 = 31;

/// Relative tolerance for float-association error in span sums. The
/// span endpoints are the simulator's own f64 timestamps, so the only
/// slack needed is summation order — never modelling error.
const REL_TOL: f64 = 1e-6;

struct Setup {
    label: &'static str,
    scene: exp::FleetScene,
    model: ModelSpec,
    hw: HwConfig,
    cfg: SimConfig,
}

fn setup(tiny: bool) -> Setup {
    if tiny {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.chunk_tokens = 32;
        cfg.kv_budget_tokens = 2048;
        cfg.ctx_bucket = 64;
        cfg.eval_blocks = 1;
        let mut scene = exp::FleetScene::new("sharegpt", 64.0, 2, 12);
        scene.rates_rps = Vec::new();
        Setup {
            label: "tiny-telemetry",
            scene,
            model: ModelSpec::tiny(),
            hw: HwConfig::homogeneous(
                2,
                2,
                ChipletClass::S,
                Dataflow::WeightStationary,
                32.0,
                16.0,
            ),
            cfg,
        }
    } else {
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.ctx_bucket = 1024;
        let scene = exp::FleetScene::new("govreport", 512.0, 4, 36);
        Setup {
            label: "govreport-512T-telemetry4",
            model: scene.model(),
            hw: exp::sim_default_hw(scene.tops_per_replica()),
            scene,
            cfg,
        }
    }
}

fn rel_close(a: f64, b: f64) -> bool {
    (a - b).abs() <= REL_TOL * a.abs().max(b.abs()).max(1e-9)
}

/// The lane-level conservation gate shared by every scenario below:
/// each lane tiles its `[first_open, last_close]` window, and the lane
/// population reproduces the run totals.
fn assert_lane_conservation(
    c: &SpanCollector,
    n_arrived: usize,
    n_completed: usize,
    n_rejected: usize,
    what: &str,
) {
    let lanes = c.waterfall();
    for lane in &lanes {
        let window = lane.last_close_s - lane.first_open_s;
        assert!(
            rel_close(lane.total_s(), window),
            "{what}: req {} spans sum to {:.9}s but the lane window is {:.9}s",
            lane.ext_id,
            lane.total_s(),
            window
        );
        for sp in &lane.spans {
            assert!(
                sp.end_s >= sp.start_s,
                "{what}: req {} has a negative span",
                lane.ext_id
            );
        }
    }
    assert_eq!(
        lanes.len(),
        n_arrived,
        "{what}: every arrival must leave a lane"
    );
    assert_eq!(
        lanes.iter().filter(|l| l.finished).count(),
        n_completed,
        "{what}: finished lanes != n_completed"
    );
    assert_eq!(
        lanes.iter().filter(|l| l.rejected).count(),
        n_rejected,
        "{what}: rejected lanes != n_rejected"
    );
    assert_eq!(c.n_finished(), n_completed, "{what}: n_finished drifted");
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let tiny = args.iter().any(|a| a == "--tiny");
    let trace_out = args
        .iter()
        .position(|a| a == "--trace-out")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let s = setup(tiny);
    let t0 = std::time::Instant::now();

    println!(
        "telemetry [{}] model={} | {} replicas of: {}",
        s.label,
        s.model.name,
        s.scene.n_replicas,
        s.hw.describe()
    );

    let spec = s.scene.spec();
    let probe = sim::probe(&s.model, &s.hw, &s.cfg, &spec);
    let mut cfg = s.cfg;
    cfg.slo = probe.slo(3.0, 4.0);

    // --- 1. single replica: plain == NullSink == SpanCollector, bitwise ---
    {
        let stream = sim::RequestStream::poisson(
            &spec,
            1.2 * probe.capacity_rps(),
            s.scene.n_requests,
            SEED,
        );
        let plain = sim::simulate_serving(&stream, &s.model, &s.hw, &cfg);
        let null: sim::SharedSink =
            std::sync::Arc::new(std::sync::Mutex::new(sim::NullSink));
        let nulled = sim::simulate_serving_traced(&stream, &s.model, &s.hw, &cfg, &null);
        let c = SpanCollector::shared();
        let sink: sim::SharedSink = c.clone();
        let traced = sim::simulate_serving_traced(&stream, &s.model, &s.hw, &cfg, &sink);
        for (a, b) in [(&plain, &nulled), (&plain, &traced)] {
            assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
            assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
            assert_eq!(a.ttft.p99.to_bits(), b.ttft.p99.to_bits());
            assert_eq!(a.tpot.p99.to_bits(), b.tpot.p99.to_bits());
            assert_eq!(a.slo_goodput_tps.to_bits(), b.slo_goodput_tps.to_bits());
            assert_eq!(a.n_completed, b.n_completed);
            assert_eq!(a.n_preemptions, b.n_preemptions);
        }
        let c = c.lock().unwrap();
        assert_lane_conservation(
            &c,
            traced.n_arrived,
            traced.n_completed,
            traced.n_rejected,
            "serving",
        );
        assert!(!c.events().is_empty(), "recording sink saw no events");
        assert!(
            c.counters().contains_key("coster.lookups")
                && c.counters().contains_key("r0.n_arrived"),
            "counter registry incomplete: {:?}",
            c.counters().keys().collect::<Vec<_>>()
        );
        println!("serving: traced run is bit-identical, lanes conserve: PASS");
    }

    // --- 2. fleet front end, no faults: spans reproduce stitched latencies ---
    {
        let stream = sim::RequestStream::poisson(
            &spec,
            1.1 * s.scene.n_replicas as f64 * probe.capacity_rps(),
            s.scene.n_requests,
            SEED,
        );
        let fleet =
            sim::FleetConfig::homogeneous(s.scene.n_replicas, RouterPolicy::JoinShortestQueue);
        let hws = vec![s.hw.clone(); fleet.total_replicas()];
        let plain = sim::simulate_fleet_frontend(
            &stream,
            &s.model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
        );
        let c = SpanCollector::shared();
        let sink: sim::SharedSink = c.clone();
        let traced = sim::simulate_fleet_frontend_traced(
            &stream,
            &s.model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
            &sink,
        );
        assert_eq!(plain.makespan_s.to_bits(), traced.makespan_s.to_bits());
        assert_eq!(plain.energy_pj.to_bits(), traced.energy_pj.to_bits());
        assert_eq!(plain.ttft.p99.to_bits(), traced.ttft.p99.to_bits());
        assert_eq!(plain.n_completed, traced.n_completed);
        let c = c.lock().unwrap();
        assert_lane_conservation(
            &c,
            traced.n_arrived,
            traced.n_completed,
            traced.n_rejected,
            "frontend",
        );
        // without faults every lane window equals its stitched outcome
        // latency exactly (same clock, no crash overshoot); match the
        // two as sorted multisets since outcomes carry no request id
        let mut lane_lat: Vec<f64> = c
            .waterfall()
            .iter()
            .filter(|l| l.finished)
            .map(|l| l.last_close_s - l.first_open_s)
            .collect();
        let mut out_lat: Vec<f64> = traced
            .outcomes
            .iter()
            .filter_map(|o| o.finish_s.map(|f| f - o.arrival_s))
            .collect();
        lane_lat.sort_by(f64::total_cmp);
        out_lat.sort_by(f64::total_cmp);
        assert_eq!(lane_lat.len(), out_lat.len());
        for (l, o) in lane_lat.iter().zip(&out_lat) {
            assert!(
                rel_close(*l, *o),
                "lane latency {l:.9}s != outcome latency {o:.9}s"
            );
        }
        println!("frontend: span windows reproduce stitched outcome latencies: PASS");
        print!("\n{}", c.ascii_waterfall(72, 16));
    }

    // --- 3. fault storm: conservation holds through crash/retry/backoff ---
    let fault_trace = {
        let knobs = exp::FaultKnobs::default();
        let stream = sim::RequestStream::poisson(
            &spec,
            1.2 * s.scene.n_replicas as f64 * probe.capacity_rps(),
            s.scene.n_requests,
            SEED,
        );
        let backoff = knobs.retry_base_prefills * probe.t_prefill_s;
        let res = ResilienceSpec::none()
            .with_schedule(sim::FaultSchedule::seeded(
                s.scene.n_replicas,
                stream.horizon_s(),
                knobs.n_crashes,
                knobs.n_stragglers,
                knobs.fault_seed,
            ))
            .with_retry(sim::RetryPolicy::capped(
                knobs.retry_attempts,
                backoff,
                10.0 * backoff,
            ))
            .with_failover(true);
        let fleet =
            sim::FleetConfig::homogeneous(s.scene.n_replicas, RouterPolicy::JoinShortestQueue);
        let hws = vec![s.hw.clone(); fleet.total_replicas()];
        let plain = sim::simulate_fleet_faults(
            &stream,
            &s.model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
            &res,
        );
        let run_traced = || {
            let c = SpanCollector::shared();
            let sink: sim::SharedSink = c.clone();
            let m = sim::simulate_fleet_faults_traced(
                &stream,
                &s.model,
                &hws,
                &cfg,
                &fleet,
                &Frontend::baseline(),
                &res,
                &sink,
            );
            (c, m)
        };
        let (c, traced) = run_traced();
        assert_eq!(plain.makespan_s.to_bits(), traced.makespan_s.to_bits());
        assert_eq!(plain.energy_pj.to_bits(), traced.energy_pj.to_bits());
        assert_eq!(plain.faults.n_failed, traced.faults.n_failed);
        assert_eq!(plain.faults.n_lost, traced.faults.n_lost);
        let cb = c.lock().unwrap();
        assert_lane_conservation(
            &cb,
            traced.n_arrived,
            traced.n_completed,
            traced.n_rejected,
            "faults",
        );
        // crash timestamps can trail a replica's overshooting iteration
        // clock, so a failed lane's window bounds its outcome latency
        // from above; k-th order statistics inherit the pointwise bound
        let mut lane_lat: Vec<f64> = cb
            .waterfall()
            .iter()
            .filter(|l| l.finished)
            .map(|l| l.last_close_s - l.first_open_s)
            .collect();
        let mut out_lat: Vec<f64> = traced
            .outcomes
            .iter()
            .filter_map(|o| o.finish_s.map(|f| f - o.arrival_s))
            .collect();
        lane_lat.sort_by(f64::total_cmp);
        out_lat.sort_by(f64::total_cmp);
        assert_eq!(lane_lat.len(), out_lat.len());
        for (l, o) in lane_lat.iter().zip(&out_lat) {
            assert!(
                *l + REL_TOL * o.max(1.0) >= *o,
                "fault lane window {l:.9}s below outcome latency {o:.9}s"
            );
        }
        if traced.faults.n_failed > 0 {
            assert!(
                cb.waterfall().iter().any(|l| l.n_failures > 0),
                "failures reported but no lane recorded one"
            );
        }
        println!(
            "faults: conservation holds through {} failures / {} lost: PASS",
            traced.faults.n_failed, traced.faults.n_lost
        );

        // --- 4. trace export is byte-identical across reruns ---
        let j1 = cb.chrome_trace_json();
        drop(cb);
        let (c2, _) = run_traced();
        let j2 = c2.lock().unwrap().chrome_trace_json();
        assert_eq!(j1, j2, "chrome trace JSON differs between identical reruns");
        assert!(j1.starts_with("{\"traceEvents\":["));
        assert!(j1.contains("\"run_summary\""));
        println!("export: chrome trace JSON is byte-identical across reruns: PASS");
        j1
    };

    if let Some(path) = &trace_out {
        if let Err(e) = std::fs::write(path, &fault_trace) {
            eprintln!("[telemetry] cannot write {path}: {e}");
            std::process::exit(1);
        }
        println!("wrote {path} ({} bytes)", fault_trace.len());
    }

    // --- 5. wall-clock profiler smoke (separate clock, nondeterministic) ---
    {
        sim::profile::set_enabled(true);
        let stream =
            sim::RequestStream::poisson(&spec, probe.capacity_rps(), s.scene.n_requests, SEED);
        let _ = sim::simulate_serving(&stream, &s.model, &s.hw, &cfg);
        let report = sim::profile::take_report();
        sim::profile::set_enabled(false);
        assert!(
            report.contains("sched.run_batch"),
            "profiler recorded no scheduler scopes:\n{report}"
        );
        println!("profile: wall-clock scopes recorded under the flag: PASS");
    }

    eprintln!("[telemetry] done in {:.1}s", t0.elapsed().as_secs_f64());
}

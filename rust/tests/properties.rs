//! Property-based tests over the coordinator's core invariants (routing,
//! scheduling, access analysis, cost monotonicity). No proptest crate is
//! vendored, so properties run over seeded random instance sweeps —
//! every case prints its seed on failure for reproduction.

use compass::arch::{ChipletClass, Dataflow, HwConfig, HwSpace};
use compass::cost::access::{self, InputSrc};
use compass::cost::{Evaluator, SimOptions};
use compass::ga::ops;
use compass::mapping::Mapping;
use compass::util::Rng;
use compass::workload::{build_workload, ModelSpec, Request, Workload, WorkloadParams};

fn random_workload(rng: &mut Rng) -> (Workload, WorkloadParams) {
    let model = ModelSpec::tiny();
    let n = 1 + rng.gen_index(8);
    let batch: Vec<Request> = (0..n)
        .map(|_| {
            if rng.gen_bool(0.5) {
                Request::prefill(1 + rng.gen_range(1, 256))
            } else {
                Request::decode(rng.gen_range(1, 2048))
            }
        })
        .collect();
    let params = WorkloadParams {
        micro_batch_size: 1 + rng.gen_index(n),
        tensor_parallel: 1 + rng.gen_index(4),
        eval_blocks: 1 + rng.gen_index(2),
    };
    (build_workload(&model, &batch, &params), params)
}

fn random_hw(rng: &mut Rng) -> HwConfig {
    let n = [1usize, 2, 4, 6, 8, 9, 12, 16][rng.gen_index(8)];
    let (h, w) = HwSpace::grid_dims(n);
    let mut hw = HwConfig::homogeneous(
        h,
        w,
        *rng.choose(&ChipletClass::ALL),
        Dataflow::WeightStationary,
        *rng.choose(&[32.0, 64.0, 128.0]),
        *rng.choose(&[16.0, 32.0, 64.0]),
    );
    for d in hw.layout.iter_mut() {
        *d = *rng.choose(&Dataflow::ALL);
    }
    hw
}

fn random_mapping(w: &Workload, chips: usize, rng: &mut Rng) -> Mapping {
    ops::random_mapping(w.num_micro_batches(), w.layers_per_mb, chips, rng)
}

const CASES: u64 = 60;

/// Schedule order is always a permutation of all (mb, layer) cells, and
/// within one micro-batch layers appear in increasing order.
#[test]
fn prop_schedule_order_is_valid_permutation() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(seed);
        let (w, _) = random_workload(&mut rng);
        let m = random_mapping(&w, 4, &mut rng);
        let order = m.schedule_order();
        assert_eq!(order.len(), m.rows * m.cols, "seed {seed}");
        let uniq: std::collections::HashSet<_> = order.iter().collect();
        assert_eq!(uniq.len(), order.len(), "seed {seed}");
        let mut last = vec![-1i64; m.rows];
        for &(mb, l) in &order {
            assert!(last[mb] < l as i64, "seed {seed}: layers out of order");
            last[mb] = l as i64;
        }
    }
}

/// Algorithm 2: a weight reload can only be skipped when the previous
/// occupant of the chip was the same layer of another micro-batch, and
/// every NoP source actually differs from the consuming chip.
#[test]
fn prop_access_flags_are_consistent() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(1000 + seed);
        let (w, _) = random_workload(&mut rng);
        let hw = random_hw(&mut rng);
        let m = random_mapping(&w, hw.num_chiplets(), &mut rng);
        let flags = access::analyze(&w, &m);
        // reconstruct chip history to verify the weight-skip invariant
        let mut prev_on_chip: Vec<Option<(usize, usize)>> = vec![None; hw.num_chiplets()];
        for (mb, l) in m.schedule_order() {
            let t = mb * m.cols + l;
            let chip = m.chip(mb, l) as usize;
            if !flags.is_load_wei[t] {
                let (pmb, pl) = prev_on_chip[chip].expect("skip without predecessor");
                assert_eq!(pl, l, "seed {seed}: skipped weights of another layer");
                assert_ne!(pmb, mb, "seed {seed}: same micro-batch reuse");
            }
            for src in flags.srcs(t) {
                if let InputSrc::Nop { chip: c } = src {
                    assert_ne!(*c as usize, chip, "seed {seed}: NoP to itself");
                }
            }
            prev_on_chip[chip] = Some((mb, l));
        }
    }
}

/// The last layer always writes out.
#[test]
fn prop_last_layer_always_writes_out() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(2000 + seed);
        let (w, _) = random_workload(&mut rng);
        let m = random_mapping(&w, 6, &mut rng);
        let flags = access::analyze(&w, &m);
        for mb in 0..m.rows {
            assert!(
                flags.is_write_out[mb * m.cols + (m.cols - 1)],
                "seed {seed}: final layer must write out"
            );
        }
    }
}

/// Timeline invariants: dependencies respected, same-chip serialization,
/// latency covers every task, energy strictly positive.
#[test]
fn prop_timeline_respects_dependencies_and_serialization() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(3000 + seed);
        let (w, _) = random_workload(&mut rng);
        let hw = random_hw(&mut rng);
        let m = random_mapping(&w, hw.num_chiplets(), &mut rng);
        let ev = Evaluator {
            opts: SimOptions {
                record_timeline: true,
                ..Default::default()
            },
        };
        let r = ev.eval_batch(&w, &hw, &m);
        let tl = r.timeline.as_ref().unwrap();
        let mut end_of = std::collections::HashMap::new();
        for e in tl.iter() {
            end_of.insert((e.mb, e.layer), e.end);
        }
        let mut chip_tasks: std::collections::HashMap<u16, Vec<(f64, f64)>> = Default::default();
        for e in tl.iter() {
            for &p in &w.micro_batches[e.mb].layers[e.layer].preds {
                assert!(
                    e.start + 1e-6 >= end_of[&(e.mb, p)],
                    "seed {seed}: dependency violated"
                );
            }
            chip_tasks.entry(e.chip).or_default().push((e.start, e.end));
            assert!(
                e.end <= r.latency_cycles / w.block_scale + 1e-6,
                "seed {seed}"
            );
        }
        for (_, mut spans) in chip_tasks {
            spans.sort_by(|a, b| a.0.total_cmp(&b.0));
            for pair in spans.windows(2) {
                assert!(
                    pair[1].0 + 1e-6 >= pair[0].1,
                    "seed {seed}: same-chip overlap"
                );
            }
        }
        assert!(r.energy_pj > 0.0);
    }
}

/// Cost monotonicity: raising DRAM and NoP bandwidth never increases
/// latency.
#[test]
fn prop_bandwidth_monotonicity() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(4000 + seed);
        let (w, _) = random_workload(&mut rng);
        let hw = random_hw(&mut rng);
        let m = random_mapping(&w, hw.num_chiplets(), &mut rng);
        let ev = Evaluator::new();
        let base = ev.eval_batch(&w, &hw, &m);
        let mut fast = hw.clone();
        fast.dram_bw_gbs *= 4.0;
        fast.nop_bw_gbs *= 4.0;
        let faster = ev.eval_batch(&w, &fast, &m);
        assert!(
            faster.latency_cycles <= base.latency_cycles + 1e-6,
            "seed {seed}: more bandwidth slowed things down"
        );
    }
}

/// GA operator closure: any sequence of Table-III operators and
/// segmentation mutations keeps the mapping valid.
#[test]
fn prop_ga_operators_closed_over_validity() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(5000 + seed);
        let rows = 1 + rng.gen_index(6);
        let cols = 2 + rng.gen_index(30);
        let chips = 1 + rng.gen_index(16);
        let mut m = ops::random_mapping(rows, cols, chips, &mut rng);
        for step in 0..100 {
            let op = 1 + (rng.gen_index(7) as u8);
            ops::apply_operator(&mut m, chips, op, &mut rng);
            ops::mutate_segmentation(&mut m, &mut rng);
            assert!(m.is_valid(chips), "seed {seed} step {step} op {op}");
        }
    }
}

/// Crossover closure: children only contain parent genes and stay valid.
#[test]
fn prop_crossover_closed_over_validity() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(6000 + seed);
        let rows = 1 + rng.gen_index(4);
        let cols = 2 + rng.gen_index(20);
        let chips = 2 + rng.gen_index(8);
        let a = ops::random_mapping(rows, cols, chips, &mut rng);
        let b = ops::random_mapping(rows, cols, chips, &mut rng);
        let c = ops::crossover(&a, &b, &mut rng);
        assert!(c.is_valid(chips), "seed {seed}");
        for mb in 0..rows {
            for l in 0..cols {
                let v = c.chip(mb, l);
                assert!(
                    v == a.chip(mb, l) || v == b.chip(mb, l),
                    "seed {seed}: foreign gene"
                );
            }
        }
    }
}

/// Workload invariant: merged GEMM rows equal the sum of per-request
/// query tokens for every micro-batch, under any batch composition.
#[test]
fn prop_merged_gemm_rows_match_requests() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(7000 + seed);
        let (w, _) = random_workload(&mut rng);
        for mb in &w.micro_batches {
            let sum_s: u64 = mb.requests.iter().map(|r| r.q_tokens()).sum();
            match &mb.layers[0].kind {
                compass::workload::LayerKind::Gemm { m, .. } => {
                    assert_eq!(*m, sum_s, "seed {seed}")
                }
                _ => panic!("first layer must be the merged QKV GEMM"),
            }
            match &mb.layers[1].kind {
                compass::workload::LayerKind::Attention { reqs, .. } => {
                    assert_eq!(reqs.len(), mb.requests.len(), "seed {seed}")
                }
                _ => panic!("second layer must be split MHA"),
            }
        }
    }
}

/// Monetary cost is invariant to the dataflow layout (same silicon).
#[test]
fn prop_money_layout_invariant() {
    for seed in 0..CASES {
        let mut rng = Rng::seed_from_u64(8000 + seed);
        let hw = random_hw(&mut rng);
        let mc = compass::cost::money::monetary_cost(&hw).total;
        let mut flipped = hw.clone();
        for d in flipped.layout.iter_mut() {
            *d = Dataflow::OutputStationary;
        }
        let mc2 = compass::cost::money::monetary_cost(&flipped).total;
        assert!((mc - mc2).abs() < 1e-9, "seed {seed}: layout changed MC");
    }
}

//! Bench F10: paper Fig. 10 + Table VII — DSE under the vLLM / Orca /
//! Chunked-Prefill serving strategies (GovReport-512TOPS) and the
//! homogeneous-vs-heterogeneous EDP comparison.
use compass::dse::DseConfig;
use compass::experiments as exp;
use compass::runtime::Runtime;
use compass::util::Bench;
use compass::workload::serving::{Scenario, ServingStrategy};
use compass::workload::trace::{Trace, TraceSpec};

fn main() {
    let mut cfg = DseConfig::reduced();
    cfg.ga.population = 12;
    cfg.ga.generations = 8;
    cfg.bo.rounds = 8;
    cfg.bo.init = 4;
    let rt = Runtime::from_env().ok();
    let results = exp::fig10_serving(&cfg, rt.as_ref(), 11, 2);
    exp::fig10a_table(&results).print();
    exp::table7(&results).print();
    let cp = results.iter().find(|r| r.strategy == ServingStrategy::ChunkedPrefill).unwrap();
    exp::fig10b_homo_hetero(&cfg, &cp.hw, 11, 2).print();

    // microbench: scenario construction per strategy
    let trace = Trace::new(&TraceSpec::govreport(), 512, 11);
    for s in ServingStrategy::ALL {
        Bench::new(&format!("scenario_build/{}", s.name())).run(|| {
            Scenario::serving(s, &trace, 9652, 128, 5, 2048)
        });
    }
}

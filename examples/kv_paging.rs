//! KV paging & quantization sweep: cache layout (paged-vs-token x
//! dtype x prefix sharing x eviction policy) x arrival rate on fixed
//! hardware, with a deliberately KV-bound DRAM budget.
//!
//! The study answers the capacity question behind the paper's serving
//! results: how many concurrent requests fit in chiplet DRAM? The fp16
//! token-granular baseline reproduces the pre-paging simulator
//! semantics; quantized caches (fp8/int4) multiply the token capacity,
//! paged blocks trade internal fragmentation for allocator realism, and
//! prefix sharing deduplicates the shared system prompt every request
//! carries. At the overload rate the capacity-raising layouts should
//! lift SLO goodput over the baseline — the full run enforces that
//! ordering, the `--tiny` smoke only proves the subsystem end-to-end.
//!
//! Run:   cargo run --release --example kv_paging
//! CI:    cargo run --example kv_paging -- --tiny
//!
//! Output is deterministic for the fixed seed baked in below.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::experiments as exp;
use compass::sim::{self, KvSpec, SimConfig};
use compass::workload::serving::ServingStrategy;
use compass::workload::ModelSpec;

const SEED: u64 = 17;

struct Setup {
    label: &'static str,
    scene: exp::SimScene,
    hw: HwConfig,
    cfg: SimConfig,
    block_tokens: u64,
    prefix_tokens: u64,
}

fn setup(tiny: bool) -> Setup {
    if tiny {
        let mut scene = exp::SimScene::new("sharegpt", 64.0, 8);
        // flood rate second: co-resident admissions exercise sharing
        scene.rates_rps = vec![2.0, 200.0];
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.chunk_tokens = 32;
        cfg.ctx_bucket = 64;
        cfg.eval_blocks = 1;
        Setup {
            label: "tiny-kv",
            scene,
            hw: HwConfig::homogeneous(
                2,
                2,
                ChipletClass::S,
                Dataflow::WeightStationary,
                32.0,
                16.0,
            ),
            cfg,
            block_tokens: 8,
            prefix_tokens: 32,
        }
    } else {
        let scene = exp::SimScene::new("sharegpt", 64.0, 16);
        let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
        cfg.ctx_bucket = 256;
        Setup {
            label: "sharegpt-64T-kv",
            scene,
            hw: exp::sim_default_hw(64.0),
            cfg,
            block_tokens: 16,
            prefix_tokens: 64,
        }
    }
}

fn main() {
    let tiny = std::env::args().skip(1).any(|a| a == "--tiny");
    let s = setup(tiny);
    let t0 = std::time::Instant::now();

    // the scene's TOPS-matched model (GPT3-7B at 64T) is too heavy for
    // a CI smoke, so the tiny path substitutes the test model into the
    // shared study protocol
    let model = if tiny {
        ModelSpec::tiny()
    } else {
        s.scene.model()
    };

    // KV-bound DRAM: the fp16 token-granular baseline holds ~8x the
    // mean request footprint, so cache layout decides concurrency
    let spec = s.scene.spec();
    let mean_footprint = spec.mean_in + spec.mean_out + s.prefix_tokens as f64;
    let mut cfg = s.cfg;
    cfg.kv_budget_tokens = 0;
    cfg.dram_gb = 8.0 * mean_footprint * model.kv_bytes_per_token() as f64 / 1e9;

    println!(
        "kv_paging [{}] model={} hw={} | kv dram {:.5} GB | prefix {} | block {}",
        s.label,
        model.name,
        s.hw.describe(),
        cfg.dram_gb,
        s.prefix_tokens,
        s.block_tokens,
    );

    let specs = exp::default_kv_specs(s.block_tokens, s.prefix_tokens);
    // one shared protocol for smoke and acceptance runs; only the model
    // differs (full mode passes the scene's own TOPS-matched model)
    let rows = exp::kv_paging_study_with_model(
        &s.scene,
        &model,
        &s.hw,
        &cfg,
        &specs,
        s.prefix_tokens,
        SEED,
    );
    exp::kv_study_table(&s.scene, &rows).print();

    // --- invariants on every cell ---
    for r in &rows {
        assert_eq!(
            r.metrics.n_completed + r.metrics.n_rejected,
            r.metrics.n_arrived,
            "conservation violated for {}",
            r.kv.describe()
        );
    }
    // quantization multiplies the token capacity from the same DRAM
    let cap = |name: &str| {
        rows.iter()
            .find(|r| r.kv.describe() == name)
            .map(|r| r.capacity_tokens)
            .expect("layout present")
    };
    assert!(cap("int4/bt1") >= 4 * cap("fp16/bt1"));
    assert!(cap("fp8/bt1") >= 2 * cap("fp16/bt1"));
    // paged layouts report fragmentation; token-granular never does
    assert!(rows
        .iter()
        .filter(|r| r.kv.block_tokens == 1)
        .all(|r| r.metrics.kv_fragmentation == 0.0));

    // --- determinism: replaying one cell is bit-identical ---
    let hi_rate = rows
        .iter()
        .map(|r| r.rate_rps)
        .fold(f64::NEG_INFINITY, f64::max);
    {
        let probe_cfg = cfg.with_kv(KvSpec::token_granular());
        let stream = sim::RequestStream::poisson(
            &spec.with_prefix(s.prefix_tokens),
            hi_rate,
            s.scene.n_requests,
            SEED,
        );
        let a = sim::simulate_serving(&stream, &model, &s.hw, &probe_cfg);
        let b = sim::simulate_serving(&stream, &model, &s.hw, &probe_cfg);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
    }

    // --- headline: capacity-raising layouts vs the fp16 baseline at
    // the overload rate ---
    let at_hi: Vec<_> = rows.iter().filter(|r| r.rate_rps == hi_rate).collect();
    let base = at_hi
        .iter()
        .find(|r| r.kv == KvSpec::token_granular())
        .expect("baseline present");
    let best = at_hi
        .iter()
        .filter(|r| r.kv != base.kv)
        .max_by(|a, b| {
            a.metrics
                .slo_goodput_tps
                .total_cmp(&b.metrics.slo_goodput_tps)
        })
        .expect("variant present");
    let shared_hits: u64 = rows.iter().map(|r| r.metrics.kv_shared_tokens).sum();
    println!(
        "\n@ {:.3} req/s (overload): best layout {} goodput {:.1} tok/s vs \
         fp16/bt1 {:.1} tok/s | sharing hits {} tok across the sweep",
        hi_rate,
        best.kv.describe(),
        best.metrics.slo_goodput_tps,
        base.metrics.slo_goodput_tps,
        shared_hits,
    );
    let ok = best.metrics.slo_goodput_tps >= base.metrics.slo_goodput_tps;
    println!(
        "  quantization/paging+sharing lifts SLO goodput at overload: {}",
        if ok { "PASS" } else { "FAIL" }
    );
    // the full run is the acceptance gate; the tiny smoke only proves
    // the subsystem runs end-to-end (toy scale noise is allowed)
    if !tiny {
        if !ok {
            eprintln!("[kv_paging] FAIL: no KV layout beat the fp16 token-granular baseline");
            std::process::exit(1);
        }
        assert!(
            shared_hits > 0,
            "prefix sharing never hit on the prefixed trace"
        );
    }
    eprintln!("[kv_paging] done in {:.1}s", t0.elapsed().as_secs_f64());
}

//! PJRT runtime: loads the AOT-compiled HLO artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python runs only at build time (`make artifacts`); after that the
//! coordinator is self-contained: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`.
//!
//! HLO *text* is the interchange format (not serialized protos): jax ≥0.5
//! emits 64-bit instruction ids that xla_extension 0.5.1 rejects; the
//! text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The whole runtime is gated behind the non-default `xla` cargo feature
//! (it needs the vendored `xla` crate); without it a stub [`Runtime`]
//! reports artifacts as unavailable so every caller falls back to the
//! native GP, and the default build stays dependency-free.

#[cfg(not(feature = "xla"))]
use crate::util::{Error, Result};

/// Fixed artifact shapes — must match `python/compile/constants.py`
/// (checked against `artifacts/manifest.json` at load time).
pub mod shapes {
    /// Max chiplet slots in a padded layout grid.
    pub const SLOTS: usize = 256;
    /// Dataflow-type vocabulary size.
    pub const TYPES: usize = 4;
    /// Max BO observations.
    pub const TRAIN_N: usize = 128;
    /// EI candidate batch.
    pub const CAND_Q: usize = 64;
    /// Padded system-parameter feature dimension.
    pub const SYS_D: usize = 8;
}

/// Stub runtime for builds without the `xla` feature: construction
/// fails, artifacts never exist, so callers take the native-GP path.
#[cfg(not(feature = "xla"))]
#[derive(Debug, Default)]
pub struct Runtime;

#[cfg(not(feature = "xla"))]
impl Runtime {
    pub fn new<P: AsRef<std::path::Path>>(_artifacts_dir: P) -> Result<Self> {
        Err(Error::msg("compass was built without the `xla` feature"))
    }

    /// Always errors: no PJRT backend is compiled in.
    pub fn from_env() -> Result<Self> {
        Err(Error::msg("compass was built without the `xla` feature"))
    }

    pub fn artifacts_dir(&self) -> &std::path::Path {
        std::path::Path::new("artifacts")
    }

    pub fn artifacts_available(&self) -> bool {
        false
    }

    pub fn check_manifest(&self) -> Result<()> {
        Err(Error::msg("compass was built without the `xla` feature"))
    }
}

#[cfg(feature = "xla")]
pub use pjrt::Runtime;

#[cfg(feature = "xla")]
mod pjrt {
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};
    use std::sync::Mutex;

    use super::shapes;
    use crate::util::{Error, Result};

    /// A loaded, compiled artifact cache keyed by artifact name.
    pub struct Runtime {
        client: xla::PjRtClient,
        dir: PathBuf,
        execs: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
    }

    impl Runtime {
        /// Create a CPU PJRT client rooted at an artifacts directory.
        pub fn new<P: AsRef<Path>>(artifacts_dir: P) -> Result<Self> {
            let dir = artifacts_dir.as_ref().to_path_buf();
            let client = xla::PjRtClient::cpu()
                .map_err(|e| Error::msg(format!("PJRT cpu client: {e:?}")))?;
            Ok(Runtime {
                client,
                dir,
                execs: Mutex::new(HashMap::new()),
            })
        }

        /// Default artifacts location (`$COMPASS_ARTIFACTS` or `./artifacts`).
        pub fn from_env() -> Result<Self> {
            let dir = std::env::var("COMPASS_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
            Self::new(dir)
        }

        pub fn artifacts_dir(&self) -> &Path {
            &self.dir
        }

        /// True when every artifact named in the manifest is present.
        pub fn artifacts_available(&self) -> bool {
            self.dir.join("manifest.json").exists()
                && ["gram_train", "gram_cross", "gram_diag", "gp_fit", "gp_ei"]
                    .iter()
                    .all(|n| self.dir.join(format!("{n}.hlo.txt")).exists())
        }

        /// Load + compile an artifact (cached).
        pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
            if let Some(e) = self.execs.lock().unwrap().get(name) {
                return Ok(e.clone());
            }
            let path = self.dir.join(format!("{name}.hlo.txt"));
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| Error::msg(format!("parse {}: {e:?}", path.display())))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| Error::msg(format!("compile {name}: {e:?}")))?;
            let exe = std::sync::Arc::new(exe);
            self.execs
                .lock()
                .unwrap()
                .insert(name.to_string(), exe.clone());
            Ok(exe)
        }

        /// Execute an artifact on f32 tensors; returns the flat f32 outputs.
        ///
        /// Inputs are `(data, dims)` pairs; the jax side lowers with
        /// `return_tuple=True`, so the single result literal is a tuple with
        /// one entry per graph output.
        pub fn run_f32(&self, name: &str, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            let exe = self.executable(name)?;
            let lits: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let l = xla::Literal::vec1(data);
                    if dims.len() == 1 && dims[0] as usize == data.len() {
                        Ok(l)
                    } else {
                        l.reshape(dims)
                            .map_err(|e| Error::msg(format!("reshape {dims:?}: {e:?}")))
                    }
                })
                .collect::<Result<_>>()?;
            let result = exe
                .execute::<xla::Literal>(&lits)
                .map_err(|e| Error::msg(format!("execute {name}: {e:?}")))?[0][0]
                .to_literal_sync()
                .map_err(|e| Error::msg(format!("sync {name}: {e:?}")))?;
            let parts = result
                .to_tuple()
                .map_err(|e| Error::msg(format!("tuple {name}: {e:?}")))?;
            parts
                .into_iter()
                .map(|p| {
                    p.to_vec::<f32>()
                        .map_err(|e| Error::msg(format!("to_vec: {e:?}")))
                })
                .collect()
        }

        /// Sanity-check the manifest shape constants against `shapes`.
        pub fn check_manifest(&self) -> Result<()> {
            let path = self.dir.join("manifest.json");
            let text = std::fs::read_to_string(&path)
                .map_err(|e| Error::msg(format!("read {}: {e}", path.display())))?;
            for (key, want) in [
                ("\"SLOTS\"", shapes::SLOTS),
                ("\"TYPES\"", shapes::TYPES),
                ("\"TRAIN_N\"", shapes::TRAIN_N),
                ("\"CAND_Q\"", shapes::CAND_Q),
                ("\"SYS_D\"", shapes::SYS_D),
            ] {
                let found = text
                    .split(key)
                    .nth(1)
                    .and_then(|s| s.split(':').nth(1))
                    .and_then(|s| {
                        let digits: String = s
                            .chars()
                            .skip_while(|c| c.is_whitespace())
                            .take_while(|c| c.is_ascii_digit())
                            .collect();
                        digits.parse::<usize>().ok()
                    })
                    .ok_or_else(|| Error::msg(format!("manifest missing {key}")))?;
                if found != want {
                    return Err(Error::msg(format!(
                        "artifact shape mismatch for {key}: manifest {found} != runtime {want}; \
                         re-run `make artifacts`"
                    )));
                }
            }
            Ok(())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // PJRT-backed integration tests live in rust/tests/pjrt_gp.rs (they
    // need `make artifacts` first); here we cover the artifact-less paths.

    #[cfg(feature = "xla")]
    #[test]
    fn missing_artifacts_detected() {
        let rt = Runtime::new("/nonexistent-dir");
        // client creation may fail in odd environments; if it succeeds the
        // artifact probe must report absence
        if let Ok(rt) = rt {
            assert!(!rt.artifacts_available());
            assert!(rt.executable("gram_train").is_err());
            assert!(rt.check_manifest().is_err());
        }
    }

    #[cfg(not(feature = "xla"))]
    #[test]
    fn stub_runtime_reports_unavailable() {
        assert!(Runtime::from_env().is_err());
        assert!(Runtime::new("artifacts").is_err());
        let rt = Runtime;
        assert!(!rt.artifacts_available());
        assert!(rt.check_manifest().is_err());
        assert_eq!(rt.artifacts_dir(), std::path::Path::new("artifacts"));
    }

    #[test]
    fn shape_constants_match_python() {
        // mirrors python/compile/constants.py
        assert_eq!(shapes::SLOTS, 256);
        assert_eq!(shapes::TYPES, 4);
        assert_eq!(shapes::TRAIN_N, 128);
        assert_eq!(shapes::CAND_Q, 64);
        assert_eq!(shapes::SYS_D, 8);
    }
}

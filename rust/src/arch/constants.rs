//! Technology constants (TSMC-12nm-era, Simba/Gemini-calibrated).
//!
//! Only *relative* latency/energy/cost across candidate designs drives the
//! paper's conclusions; the absolute values below are public-literature
//! figures for a 12 nm process with GRS-based NoP and organic-substrate
//! packaging (see DESIGN.md "Substitutions").

/// Clock frequency of every chiplet (paper: 1 GHz).
pub const CLOCK_HZ: f64 = 1.0e9;

/// Bytes per model element (fp16 weights/activations).
pub const BYTES_PER_ELEM: u64 = 2;
/// Bytes per partial sum (fp32 accumulation).
pub const BYTES_PER_PSUM: u64 = 4;

// ---- energy (picojoules) ----------------------------------------------
/// Energy per MAC operation (fp16 multiply-accumulate, 12 nm).
pub const E_MAC_PJ: f64 = 0.6;
/// Energy per byte read/written at the global buffer (large SRAM).
pub const E_GLB_PJ_BYTE: f64 = 1.4;
/// Energy per byte in the local accumulator / register-file level.
pub const E_REG_PJ_BYTE: f64 = 0.12;
/// Energy per byte of off-package DRAM access.
pub const E_DRAM_PJ_BYTE: f64 = 62.0;
/// Energy per byte per NoP hop (GRS signalling + router).
pub const E_NOP_PJ_BYTE_HOP: f64 = 2.6;
/// Energy per scalar op in the post-processing (vector) unit.
pub const E_VEC_PJ_OP: f64 = 0.9;

// ---- latency ----------------------------------------------------------
/// Router pipeline latency per NoP hop (cycles).
pub const NOP_HOP_CYCLES: f64 = 4.0;
/// Fixed DRAM access latency (cycles) added to bandwidth time.
pub const DRAM_LAT_CYCLES: f64 = 120.0;

// ---- area (mm^2) ------------------------------------------------------
/// Area per MAC unit (fp16 datapath, 12 nm).
pub const A_MAC_MM2: f64 = 0.0011 / 1.024; // ~1.07 mm^2 per 1K MACs
/// Area per MiB of global-buffer SRAM.
pub const A_SRAM_MM2_PER_MIB: f64 = 0.85;
/// Fixed NoC / control / post-processing overhead per chiplet.
pub const A_OTHERS_MM2: f64 = 1.9;
/// alpha: chiplet area per GB/s of NoP bandwidth (PHY + router).
pub const A_NOP_MM2_PER_GBS: f64 = 0.004;
/// beta: IO-die area per GB/s of NoP bandwidth.
pub const A_IO_NOP_MM2_PER_GBS: f64 = 0.006;
/// gamma: IO-die area per GB/s of DRAM bandwidth (PHY).
pub const A_IO_DRAM_MM2_PER_GBS: f64 = 0.035;

// ---- monetary cost (Gemini yield model) --------------------------------
/// Reference yield at the reference area.
pub const Y_UNIT: f64 = 0.95;
/// Reference area (mm^2) for `Y_UNIT`.
pub const A_UNIT_MM2: f64 = 10.0;
/// Yield of an IO die (mature process, fixed).
pub const Y_IO: f64 = 0.98;
/// Manufacturing cost per mm^2 of compute-chiplet silicon (normalised
/// cost units, calibrated so a Simba-like 64-TOPS package lands at the
/// Table-V reference scale of ~$2.4K).
pub const COST_CHIP_PER_MM2: f64 = 11.0;
/// Manufacturing cost per mm^2 of IO-die silicon (mature node).
pub const COST_IO_PER_MM2: f64 = 5.0;
/// Packaging cost per mm^2 of substrate area (organic substrate).
pub const COST_PACK_PER_MM2: f64 = 0.8;
/// Package substrate area per mm^2 of total silicon (fan-out factor).
pub const PACKAGE_AREA_FACTOR: f64 = 3.2;

/// Number of DRAM chips on the package (paper: 4, split left/right).
pub const NUM_DRAM_CHIPS: usize = 4;

/// Vector lanes in the post-processing unit, as a fraction of MACs.
pub const VEC_LANES_PER_MAC: f64 = 1.0 / 16.0;

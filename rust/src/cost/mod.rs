//! The Compass evaluation engine (paper §V-C): latency, energy, and
//! monetary cost for a (workload, hardware, mapping) triplet, combining
//! the intra-chiplet dataflow model, Algorithm-2 data-access analysis,
//! the inter-chiplet timeline, and the Gemini-style monetary model.

pub mod access;
pub mod dataflow;
pub mod engine;
pub mod money;
pub mod timeline;

pub use engine::{BatchEvaluator, EvalScratch, MappingEvaluator, PreparedWorkload};

use crate::arch::constants::CLOCK_HZ;
use crate::arch::{Chiplet, HwConfig};
use crate::mapping::Mapping;
use crate::workload::serving::Scenario;
use crate::workload::{build_workload, Phase, Workload, WorkloadParams};

pub use money::MoneyCost;
pub use timeline::{Breakdown, SimOptions, SimResult, TimelineEntry};

/// Aggregate evaluation of a scenario on one hardware + mapping set.
#[derive(Debug, Clone)]
pub struct EvalResult {
    /// Weighted total latency (cycles).
    pub latency_cycles: f64,
    /// Weighted total energy (pJ).
    pub energy_pj: f64,
    /// Hardware monetary cost ($).
    pub mc_usd: f64,
    /// Per-group (latency, energy) pairs in scenario order.
    pub per_group: Vec<(f64, f64)>,
    /// Per-phase energy across groups (pJ).
    pub phase_energy: Vec<(Phase, f64)>,
}

impl EvalResult {
    /// Design objective: the product of latency, energy and monetary
    /// cost (paper §VI-A), in SI-ish units (s * J * $) for scale sanity.
    pub fn total_cost(&self) -> f64 {
        (self.latency_cycles / CLOCK_HZ) * (self.energy_pj * 1e-12) * self.mc_usd
    }

    /// Energy-delay product (s * J), used by the homo/hetero study.
    pub fn edp(&self) -> f64 {
        (self.latency_cycles / CLOCK_HZ) * (self.energy_pj * 1e-12)
    }
}

/// The evaluation engine. Holds simulation options; construction is cheap.
#[derive(Debug, Clone, Default)]
pub struct Evaluator {
    pub opts: SimOptions,
}

impl Evaluator {
    pub fn new() -> Self {
        Self::default()
    }

    /// Evaluate one batch (one workload) under one mapping.
    ///
    /// One-shot path: builds the search-invariant state and scratch
    /// buffers fresh. Search loops evaluating many mappings against one
    /// (workload, hardware) pair should use [`MappingEvaluator`], which
    /// hoists that work out of the per-individual hot path.
    pub fn eval_batch(
        &self,
        workload: &Workload,
        hw: &HwConfig,
        mapping: &Mapping,
    ) -> SimResult {
        // compute the schedule order once; analysis and simulation share it
        let order = mapping.schedule_order();
        let flags = access::analyze_with_order(workload, mapping, &order);
        timeline::simulate_with_order(workload, hw, mapping, &flags, &self.opts, &order)
    }

    /// Evaluate a full scenario: each batch group is instantiated with
    /// the hardware's workload knobs (micro-batch size per request type,
    /// tensor parallelism) and simulated under its own mapping.
    ///
    /// `mappings` must be parallel to `scenario.groups`.
    pub fn eval_scenario(
        &self,
        scenario: &Scenario,
        model: &crate::workload::ModelSpec,
        hw: &HwConfig,
        mappings: &[Mapping],
        eval_blocks: usize,
    ) -> EvalResult {
        assert_eq!(mappings.len(), scenario.groups.len());
        let mut latency = 0.0;
        let mut energy = 0.0;
        let mut per_group = Vec::with_capacity(scenario.groups.len());
        let mut phase_energy: Vec<(Phase, f64)> = Vec::new();
        for (group, mapping) in scenario.groups.iter().zip(mappings) {
            let w = build_workload(model, &group.batch, &group_params(hw, group.has_prefill, eval_blocks));
            let r = self.eval_batch(&w, hw, mapping);
            latency += r.latency_cycles * group.weight;
            energy += r.energy_pj * group.weight;
            per_group.push((r.latency_cycles, r.energy_pj));
            for (p, e) in r.phase_energy {
                match phase_energy.iter_mut().find(|(pp, _)| *pp == p) {
                    Some((_, acc)) => *acc += e * group.weight,
                    None => phase_energy.push((p, e * group.weight)),
                }
            }
        }
        EvalResult {
            latency_cycles: latency,
            energy_pj: energy,
            mc_usd: money::monetary_cost(hw).total,
            per_group,
            phase_energy,
        }
    }
}

/// Workload knobs a hardware configuration implies for a batch group.
pub fn group_params(hw: &HwConfig, has_prefill: bool, eval_blocks: usize) -> WorkloadParams {
    WorkloadParams {
        micro_batch_size: if has_prefill {
            hw.micro_batch_prefill
        } else {
            hw.micro_batch_decode
        },
        tensor_parallel: hw.tensor_parallel,
        eval_blocks,
    }
}

/// Single-GEMM EDP probe used by paper Table I: one phase of a GPT3-class
/// block at sequence length `seq`, on a single chiplet with `dram_bw`
/// GB/s. Returns (latency_cycles, energy_pj).
pub fn edp_probe(
    phase: Phase,
    seq: u64,
    hidden: u64,
    ffn: u64,
    head_dim: u64,
    chip: Chiplet,
    dram_bw_gbs: f64,
) -> (f64, f64) {
    use crate::arch::constants::*;
    let (cost, w_bytes, io_bytes) = match phase {
        Phase::QkvGen => {
            let c = dataflow::gemm_cost(seq, hidden, 3 * hidden, chip, true);
            (c, (hidden * 3 * hidden * BYTES_PER_ELEM) as f64, (seq * 4 * hidden * BYTES_PER_ELEM) as f64)
        }
        Phase::QkT => {
            // one head; both operands are activations
            let c = dataflow::gemm_cost(seq, head_dim, seq, chip, false);
            (c, (head_dim * seq * BYTES_PER_ELEM) as f64, (seq * head_dim * BYTES_PER_ELEM) as f64)
        }
        Phase::Av => {
            let c = dataflow::gemm_cost(seq, seq, head_dim, chip, false);
            (c, (seq * head_dim * BYTES_PER_ELEM) as f64, (seq * seq * BYTES_PER_ELEM) as f64)
        }
        Phase::Ffn1 => {
            let c = dataflow::gemm_cost(seq, hidden, ffn, chip, true);
            (c, (hidden * ffn * BYTES_PER_ELEM) as f64, (seq * (hidden + ffn) * BYTES_PER_ELEM) as f64)
        }
        Phase::Ffn2 => {
            let c = dataflow::gemm_cost(seq, ffn, hidden, chip, true);
            (c, (ffn * hidden * BYTES_PER_ELEM) as f64, (seq * (hidden + ffn) * BYTES_PER_ELEM) as f64)
        }
        _ => panic!("probe supports GEMM phases only"),
    };
    let dram_bytes = cost.weight_dram.max(if w_bytes > 0.0 { w_bytes } else { 0.0 })
        + cost.spill_dram
        + io_bytes;
    let bytes_per_cycle = dram_bw_gbs * 1e9 / CLOCK_HZ;
    let t_dram = dram_bytes / bytes_per_cycle + DRAM_LAT_CYCLES;
    let latency = cost.cycles.max(t_dram);
    let energy = cost.onchip_energy_pj() + dram_bytes * E_DRAM_PJ_BYTE;
    (latency, energy)
}

/// EDP of a probe.
pub fn edp_of(probe: (f64, f64)) -> f64 {
    (probe.0 / CLOCK_HZ) * (probe.1 * 1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::mapping::presets;
    use crate::workload::trace::{Trace, TraceSpec};
    use crate::workload::{ModelSpec, Request};

    fn chip(df: Dataflow) -> Chiplet {
        Chiplet {
            class: ChipletClass::M,
            dataflow: df,
        }
    }

    /// The headline inspiration of the paper (Table I): dataflow
    /// preference flips with sequence length.
    #[test]
    fn table1_preference_crossover() {
        let h = 4096;
        let ffn = 16384;
        let ratio = |phase: Phase, seq: u64| {
            let os = edp_of(edp_probe(phase, seq, h, ffn, 128, chip(Dataflow::OutputStationary), 64.0));
            let ws = edp_of(edp_probe(phase, seq, h, ffn, 128, chip(Dataflow::WeightStationary), 64.0));
            os / ws
        };
        // short sequences: WS superior (ratio > 1)
        assert!(ratio(Phase::QkvGen, 128) > 1.2, "qkv@128 {}", ratio(Phase::QkvGen, 128));
        assert!(ratio(Phase::Ffn2, 128) > 1.2, "ffn2@128 {}", ratio(Phase::Ffn2, 128));
        // long sequences: OS superior (ratio < 1)
        assert!(ratio(Phase::QkvGen, 10240) < 1.0, "qkv@10240 {}", ratio(Phase::QkvGen, 10240));
        assert!(ratio(Phase::Ffn1, 10240) < 1.0, "ffn1@10240 {}", ratio(Phase::Ffn1, 10240));
        // QK^T flips earlier than the weight GEMMs (paper: 0.88 @ 1024)
        assert!(ratio(Phase::QkT, 1024) < ratio(Phase::QkvGen, 1024));
        assert!(ratio(Phase::QkT, 5120) < 1.0);
    }

    #[test]
    fn scenario_eval_weights_groups() {
        let model = ModelSpec::tiny();
        let trace = Trace::new(&TraceSpec::sharegpt(), 64, 3);
        let scen = Scenario::prefill(&trace, 2, 2);
        let hw = HwConfig::homogeneous(2, 2, ChipletClass::S, Dataflow::WeightStationary, 32.0, 16.0);
        let ev = Evaluator::new();
        let cols = {
            let w = build_workload(&model, &scen.groups[0].batch, &group_params(&hw, true, 1));
            w.layers_per_mb
        };
        let rows = scen.groups[0].batch.len() / hw.micro_batch_prefill.min(scen.groups[0].batch.len());
        let maps: Vec<Mapping> = scen
            .groups
            .iter()
            .map(|_| presets::data_parallel(rows.max(1), cols, 4))
            .collect();
        let r = ev.eval_scenario(&scen, &model, &hw, &maps, 1);
        assert!(r.latency_cycles > 0.0 && r.energy_pj > 0.0 && r.mc_usd > 0.0);
        assert_eq!(r.per_group.len(), 2);
        let sum_l: f64 = r.per_group.iter().map(|g| g.0).sum();
        assert!((sum_l - r.latency_cycles).abs() / r.latency_cycles < 1e-9);
        assert!(r.total_cost() > 0.0);
    }

    #[test]
    fn better_mapping_beats_worse_mapping() {
        // pipeline mapping with weight reuse must beat an adversarial
        // mapping that round-robins layers across chips at random
        let model = ModelSpec::tiny();
        let batch = vec![Request::decode(300); 8];
        let params = WorkloadParams {
            micro_batch_size: 2,
            tensor_parallel: 2,
            eval_blocks: 2,
        };
        let w = build_workload(&model, &batch, &params);
        let hw = HwConfig::homogeneous(2, 2, ChipletClass::S, Dataflow::WeightStationary, 32.0, 16.0);
        let ev = Evaluator::new();
        let good = presets::pipeline_parallel(4, w.layers_per_mb, 4);
        let mut bad = Mapping::new(4, w.layers_per_mb);
        for (i, g) in bad.layer_to_chip.iter_mut().enumerate() {
            *g = ((i * 7 + 3) % 4) as u16;
        }
        let rg = ev.eval_batch(&w, &hw, &good);
        let rb = ev.eval_batch(&w, &hw, &bad);
        let eg = rg.latency_cycles * rg.energy_pj;
        let eb = rb.latency_cycles * rb.energy_pj;
        assert!(eg < eb, "pipeline EDP {eg} should beat random {eb}");
    }
}

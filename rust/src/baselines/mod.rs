//! Baseline DSE methods (paper §VI-A baseline setup + §VI-G ablations),
//! re-implemented on the Compass evaluation engine exactly as the paper
//! adapted them ("both methods are adapted to convert into the mapping
//! method of Compass"):
//!
//! * [`gemini`] — single-model DSE: simulated-annealing mapping search,
//!   grid-searched *homogeneous* hardware, and a fixed (average) sequence
//!   length with padding;
//! * [`moham`]  — multi-model DSE: joint GA over hardware + mapping, each
//!   micro-batch treated as an independent model (no merged batching);
//! * [`scar`]   — SCAR-style heuristic mapping (load-balanced segment
//!   placement) for the Fig. 11 ablation;
//! * [`random`] — random mapping / random hardware search at matched
//!   budgets for the Fig. 11 ablations.

pub mod gemini;
pub mod moham;
pub mod random;
pub mod scar;

use crate::workload::serving::Scenario;
use crate::workload::trace::Trace;
use crate::workload::Request;

/// Gemini's fixed-sequence-length view of a scenario: every request is
/// padded/truncated to the trace average (paper: "we perform DSE with the
/// average sequence length of the scenario").
pub fn fixed_length_scenario(scenario: &Scenario, trace: &Trace) -> Scenario {
    let mean_in = trace.mean_in().round().max(1.0) as u64;
    let mean_ctx = (trace.mean_in() + 0.5 * trace.mean_out()).round().max(1.0) as u64;
    let mut out = scenario.clone();
    for g in out.groups.iter_mut() {
        for r in g.batch.iter_mut() {
            *r = match *r {
                Request::Prefill { .. } => Request::prefill(mean_in),
                Request::Decode { .. } => Request::decode(mean_ctx),
            };
        }
    }
    out.name = format!("{}-fixedlen", scenario.name);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::trace::TraceSpec;

    #[test]
    fn fixed_length_pads_every_request() {
        let trace = Trace::new(&TraceSpec::sharegpt(), 128, 1);
        let scen = Scenario::decode(&trace, 16, 2);
        let fixed = fixed_length_scenario(&scen, &trace);
        let mut ctxs: Vec<u64> = fixed
            .groups
            .iter()
            .flat_map(|g| g.batch.iter())
            .map(|r| match r {
                Request::Decode { ctx } => *ctx,
                Request::Prefill { len, .. } => *len,
            })
            .collect();
        ctxs.dedup();
        assert_eq!(ctxs.len(), 1, "all requests must share one length");
        // and the real scenario had variety
        let mut real: Vec<u64> = scen
            .groups
            .iter()
            .flat_map(|g| g.batch.iter())
            .map(|r| r.kv_tokens())
            .collect();
        real.sort();
        real.dedup();
        assert!(real.len() > 4);
    }
}

//! Report writers: markdown/CSV tables mirroring the paper's tables and
//! figures (no external serialisation crates are vendored, so the
//! writers are self-contained).

use std::io::Write as _;
use std::path::Path;

/// A printable table (markdown to stdout, CSV to disk).
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        debug_assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells);
        self
    }

    /// Render as a markdown table string.
    pub fn to_markdown(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let fmt_row = |cells: &[String]| {
            let inner: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            format!("| {} |", inner.join(" | "))
        };
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        let mut out = format!("\n## {}\n\n", self.title);
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&format!("|-{}-|\n", sep.join("-|-")));
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        print!("{}", self.to_markdown());
    }

    /// Write as CSV (quotes cells containing separators).
    pub fn write_csv<P: AsRef<Path>>(&self, path: P) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::fs::File::create(path)?;
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        writeln!(
            f,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        )?;
        for row in &self.rows {
            writeln!(
                f,
                "{}",
                row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(",")
            )?;
        }
        Ok(())
    }
}

/// Normalise a metric series so the maximum is 1.0 (paper Fig. 7 style).
pub fn normalize_max(xs: &[f64]) -> Vec<f64> {
    let max = xs.iter().cloned().fold(0.0f64, f64::max).max(1e-300);
    xs.iter().map(|x| x / max).collect()
}

/// ASCII horizontal bar (for terminal "figures").
pub fn bar(frac: f64, width: usize) -> String {
    let n = ((frac.clamp(0.0, 1.0)) * width as f64).round() as usize;
    format!("{}{}", "#".repeat(n), ".".repeat(width.saturating_sub(n)))
}

/// Render a spatio-temporal execution timeline (paper Fig. 5/8) as ASCII:
/// one row per chiplet, time bucketed into `width` columns, cells showing
/// the phase initial of the task occupying the bucket.
pub fn ascii_timeline(
    entries: &[crate::cost::TimelineEntry],
    num_chips: usize,
    width: usize,
) -> String {
    let t_end = entries.iter().map(|e| e.end).fold(0.0, f64::max).max(1e-9);
    let mut grid = vec![vec![' '; width]; num_chips];
    for e in entries {
        let c = e.chip as usize;
        if c >= num_chips {
            continue;
        }
        let s = ((e.start / t_end) * width as f64) as usize;
        let en = (((e.end / t_end) * width as f64).ceil() as usize).min(width);
        let ch = match e.phase {
            crate::workload::Phase::QkvGen => 'Q',
            crate::workload::Phase::QkT | crate::workload::Phase::Av => 'A',
            crate::workload::Phase::Proj => 'P',
            crate::workload::Phase::Ffn1 => 'F',
            crate::workload::Phase::Ffn2 => 'f',
            crate::workload::Phase::Vector => 'v',
        };
        for cell in grid[c].iter_mut().take(en).skip(s) {
            *cell = ch;
        }
    }
    let mut out = String::new();
    for (c, row) in grid.iter().enumerate() {
        out.push_str(&format!("chip{c:>3} |{}|\n", row.iter().collect::<String>()));
    }
    out.push_str(&format!(
        "legend: Q=QKV A=MHA P=Proj F=FFN1 f=FFN2  (span = {:.3e} cycles)\n",
        t_end
    ));
    out
}

/// Render a serving-simulator run as an ASCII occupancy plot: four
/// sparkline rows (batch-slot occupancy, admission-queue depth,
/// KV-cache fill, KV-block internal fragmentation) over wall-clock
/// time, each bucketed into `width` columns with time-weighted
/// averaging. Idle gaps count as zero; the fragmentation row is blank
/// for token-granular caches.
pub fn ascii_occupancy(
    iters: &[crate::sim::IterRecord],
    max_batch: usize,
    width: usize,
) -> String {
    const RAMP: [char; 10] = [' ', '.', ':', '-', '=', '+', '*', '#', '%', '@'];
    let width = width.max(1);
    let t_end = iters.iter().map(|i| i.end_s).fold(0.0, f64::max).max(1e-12);
    let max_queue = iters.iter().map(|i| i.queue_depth).max().unwrap_or(0).max(1) as f64;
    let col_w = t_end / width as f64;
    let mut rows = [
        vec![0.0f64; width],
        vec![0.0f64; width],
        vec![0.0f64; width],
        vec![0.0f64; width],
    ];
    for it in iters {
        let occ = (it.n_decode + it.n_prefill) as f64 / max_batch.max(1) as f64;
        let vals = [occ, it.queue_depth as f64 / max_queue, it.kv_frac, it.kv_frag];
        let c0 = ((it.start_s / col_w) as usize).min(width - 1);
        let c1 = ((it.end_s / col_w) as usize).min(width - 1);
        for c in c0..=c1 {
            let lo = (c as f64 * col_w).max(it.start_s);
            let hi = ((c + 1) as f64 * col_w).min(it.end_s);
            let w = (hi - lo).max(0.0) / col_w;
            for (row, v) in rows.iter_mut().zip(vals) {
                row[c] += v * w;
            }
        }
    }
    let mut out = String::new();
    for (name, row) in ["batch", "queue", "kv   ", "frag "].iter().zip(&rows) {
        out.push_str(&format!("{name} |"));
        for &v in row {
            let idx = (v.clamp(0.0, 1.0) * (RAMP.len() - 1) as f64).round() as usize;
            out.push(RAMP[idx.min(RAMP.len() - 1)]);
        }
        out.push_str("|\n");
    }
    out.push_str(&format!(
        "span {:.3}s | batch /{} | queue /{} | kv = cache fill | frag = block waste\n",
        t_end,
        max_batch,
        max_queue as usize
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn markdown_table_well_formed() {
        let mut t = Table::new("Demo", &["a", "bb"]);
        t.row(vec!["1".into(), "2".into()]);
        t.row(vec!["333".into(), "4".into()]);
        let md = t.to_markdown();
        assert!(md.contains("## Demo"));
        // leading blank + title + blank + header + separator + 2 rows
        assert_eq!(md.matches('\n').count(), 7);
        assert!(md.lines().skip(2).all(|l| l.is_empty() || l.starts_with('|')));
    }

    #[test]
    fn csv_roundtrip_escaping() {
        let dir = std::env::temp_dir().join("compass_test_csv");
        let path = dir.join("t.csv");
        let mut t = Table::new("x", &["h1", "h2"]);
        t.row(vec!["plain".into(), "with,comma".into()]);
        t.write_csv(&path).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("\"with,comma\""));
        assert!(body.starts_with("h1,h2"));
    }

    #[test]
    fn normalize_max_puts_max_at_one() {
        let n = normalize_max(&[2.0, 4.0, 1.0]);
        assert_eq!(n, vec![0.5, 1.0, 0.25]);
    }

    #[test]
    fn bar_width_clamped() {
        assert_eq!(bar(0.5, 10), "#####.....");
        assert_eq!(bar(2.0, 4), "####");
        assert_eq!(bar(-1.0, 4), "....");
    }

    #[test]
    fn occupancy_plot_shape_and_saturation() {
        let iters = vec![
            crate::sim::IterRecord {
                start_s: 0.0,
                end_s: 1.0,
                n_decode: 8,
                n_prefill: 0,
                prefill_tokens: 0,
                queue_depth: 4,
                kv_frac: 1.0,
                kv_frag: 1.0,
                n_running: 8,
            },
            crate::sim::IterRecord {
                start_s: 1.0,
                end_s: 2.0,
                n_decode: 0,
                n_prefill: 1,
                prefill_tokens: 64,
                queue_depth: 0,
                kv_frac: 0.0,
                kv_frag: 0.0,
                n_running: 1,
            },
        ];
        let s = ascii_occupancy(&iters, 8, 20);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        assert!(lines[0].starts_with("batch |"));
        assert!(lines[3].starts_with("frag "));
        // first half of the batch row is saturated ('@'), kv + frag too
        assert!(lines[0].contains('@'));
        assert!(lines[2].contains('@'));
        assert!(lines[3].contains('@'));
        assert!(lines[4].contains("span"));
        // every sparkline row has exactly `width` cells between pipes
        for line in &lines[..4] {
            let inner = line.split('|').nth(1).unwrap();
            assert_eq!(inner.chars().count(), 20);
        }
    }
}

//! Bench T5: regenerate paper Table V (evaluation-engine validation vs a
//! steady-state reference on Simba-like hardware) and time both engines.
use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::cost::Evaluator;
use compass::experiments::steady_state_reference;
use compass::mapping::presets;
use compass::util::Bench;
use compass::workload::{build_workload, ModelSpec, Request, WorkloadParams};

fn main() {
    compass::experiments::table5(2).print();
    let model = ModelSpec::gpt3_7b();
    let hw = HwConfig::homogeneous(6, 6, ChipletClass::S, Dataflow::WeightStationary, 32.0, 16.0);
    let w = build_workload(
        &model,
        &vec![Request::decode(512); 128],
        &WorkloadParams { micro_batch_size: 32, tensor_parallel: 8, eval_blocks: 2 },
    );
    let m = presets::pipeline_parallel(w.num_micro_batches(), w.layers_per_mb, 36);
    let ev = Evaluator::new();
    Bench::new("eval_engine/decode-batch128").run(|| ev.eval_batch(&w, &hw, &m));
    Bench::new("steady_state_reference/decode-batch128").run(|| steady_state_reference(&w, &hw, &m));
}

//! Deterministic discrete-event, iteration-level continuous-batching
//! scheduler (paper §II / Fig. 9, made dynamic).
//!
//! The simulator replays a [`RequestStream`] through one of the three
//! `ServingStrategy` policies:
//!
//! * **vLLM-style** — prefill priority: waiting prompts pause decodes
//!   and run as a standalone batch;
//! * **Orca-style** — iteration-level mixed batches: new prompts join
//!   the in-flight decode batch wholesale;
//! * **Sarathi-style chunked prefill** — each decode iteration carries
//!   at most `chunk_tokens` prompt tokens from the admission queue.
//!
//! All three share an admission queue, a KV-cache token budget derived
//! from the hardware's DRAM capacity (admission stalls when full;
//! youngest-first preemption with prefill recomputation under decode
//! pressure), and per-request lifecycle tracking (arrival → first token
//! → completion). Admission reserves a request's full context
//! (`kv_reserved`) until its prefill has written every token, so later
//! admissions can never steal the headroom an in-flight chunked prefill
//! still needs. The clock advances by each iteration's simulated
//! latency, costed through [`BatchCoster`]; when nothing is runnable it
//! jumps to the next arrival. Everything is pure `f64`/integer
//! arithmetic on a fixed event order, so a fixed stream produces
//! bit-identical metrics on every run.
//!
//! The scheduler is a resumable state machine ([`Scheduler`]): the
//! single-package entry point [`simulate_serving`] drives one instance
//! over a whole stream, while the fleet layer (`sim::fleet`) interleaves
//! many instances under a front-end router, injecting requests (or KV
//! migrations, for disaggregated prefill/decode pools) between steps.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use crate::arch::constants::CLOCK_HZ;
use crate::arch::HwConfig;
use crate::workload::serving::ServingStrategy;
use crate::workload::{ModelSpec, Request};

use super::coster::BatchCoster;
use super::metrics::{finalize, IterRecord, RequestOutcome, RunTotals, ServingMetrics, TraceBuffer};
use super::stream::RequestStream;
use super::SimConfig;

/// Per-request lifecycle state.
#[derive(Debug, Clone, Copy)]
struct Live {
    arrival_s: f64,
    input_len: u64,
    output_len: u64,
    /// Context tokens the current admission must prefill (prompt plus
    /// any tokens generated before a preemption).
    prefill_target: u64,
    prefill_done: u64,
    generated: u64,
    /// KV-cache tokens currently held.
    kv_held: u64,
    first_token_s: Option<f64>,
    finish_s: Option<f64>,
    rejected: bool,
    /// Fleet KV migration: the context materializes on admission via
    /// the handoff transfer instead of prefill compute.
    prefilled: bool,
}

impl Live {
    /// An admitted request is decoding once its prefill is complete.
    fn decoding(&self) -> bool {
        self.finish_s.is_none() && self.prefill_done >= self.prefill_target
    }

    /// Context tokens a (re-)admission must cover.
    fn context_needed(&self) -> u64 {
        self.input_len + self.generated
    }
}

/// What a request does in one iteration batch.
#[derive(Debug, Clone, Copy)]
enum Role {
    /// Generate one token against the current context.
    Decode,
    /// Prefill `t` prompt tokens (the whole prompt for vLLM/Orca).
    Chunk(u64),
}

/// A finished replica: aggregate metrics plus per-request outcomes
/// keyed by the caller's external request ids (for fleet stitching).
#[derive(Debug, Clone)]
pub struct ReplicaResult {
    pub metrics: ServingMetrics,
    pub outcomes: Vec<(usize, RequestOutcome)>,
}

/// Resumable continuous-batching scheduler for one package.
///
/// Drive it with [`Scheduler::inject`] / [`Scheduler::advance_to`] /
/// [`Scheduler::step`]; arrivals are the caller's responsibility (a
/// request must be injected once the clock has reached its arrival
/// time), which is what lets a fleet router interleave replicas
/// deterministically.
pub struct Scheduler<'a> {
    cfg: SimConfig,
    kv_budget: u64,
    /// Composition-keyed cost memo; shareable across the replicas of a
    /// fleet (costs are order-independent, so sharing is bit-exact).
    coster: Rc<RefCell<BatchCoster<'a>>>,
    peak_macs_per_cycle: f64,
    reqs: Vec<Live>,
    ext_ids: Vec<usize>,
    queue: VecDeque<usize>,
    running: Vec<usize>, // admission order: oldest first
    kv_used: u64,
    /// Reserved-but-unwritten KV of in-flight prefills: admission books
    /// the full context here and chunk writes move tokens from reserved
    /// to used, so the guarantee survives across iterations.
    kv_reserved: u64,
    clock: f64,
    trace: TraceBuffer,
    n_arrived: usize,
    done: usize,
    rejected: usize,
    preemptions: usize,
    energy: f64,
    ideal_cycles: f64,
    gen_tokens: u64,
    kv_transfer_tokens: u64,
    truncated: bool,
}

impl<'a> Scheduler<'a> {
    pub fn new(model: &'a ModelSpec, hw: &'a HwConfig, cfg: &SimConfig) -> Self {
        let coster = Rc::new(RefCell::new(BatchCoster::new(
            model,
            hw,
            cfg.policy,
            cfg.eval_blocks,
            cfg.ctx_bucket,
        )));
        Self::with_coster(model, hw, cfg, coster)
    }

    /// Build a scheduler on a shared cost memo: identical fleet replicas
    /// pass clones of one `Rc` so a batch shape simulated (or
    /// GA-searched, under `MappingPolicy::Searched`) on any replica is
    /// never re-costed on another. `distinct_shapes` then reports the
    /// shared memo's size.
    pub fn with_coster(
        model: &'a ModelSpec,
        hw: &'a HwConfig,
        cfg: &SimConfig,
        coster: Rc<RefCell<BatchCoster<'a>>>,
    ) -> Self {
        Scheduler {
            cfg: *cfg,
            kv_budget: cfg.kv_budget(model).max(2),
            coster,
            peak_macs_per_cycle: (hw.num_chiplets() as f64) * (hw.class.macs() as f64),
            reqs: Vec::new(),
            ext_ids: Vec::new(),
            queue: VecDeque::new(),
            running: Vec::new(),
            kv_used: 0,
            kv_reserved: 0,
            clock: 0.0,
            trace: TraceBuffer::new(cfg.trace_cap),
            n_arrived: 0,
            done: 0,
            rejected: 0,
            preemptions: 0,
            energy: 0.0,
            ideal_cycles: 0.0,
            gen_tokens: 0,
            kv_transfer_tokens: 0,
            truncated: false,
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn truncated(&self) -> bool {
        self.truncated
    }

    /// Queued or admitted requests that still have work.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || !self.running.is_empty()
    }

    /// Outstanding token work (queued context+output plus in-flight
    /// remainders): the join-shortest-queue routing signal.
    pub fn backlog_tokens(&self) -> u64 {
        let queued: u64 = self
            .queue
            .iter()
            .map(|&i| self.reqs[i].input_len + self.reqs[i].output_len)
            .sum();
        let inflight: u64 = self
            .running
            .iter()
            .map(|&i| {
                let r = &self.reqs[i];
                (r.prefill_target - r.prefill_done) + r.output_len.saturating_sub(r.generated)
            })
            .sum();
        queued + inflight
    }

    /// Offer a request at `arrival_s` (must be called in nondecreasing
    /// arrival order once the clock has caught up; see `advance_to`).
    /// Requests that can never fit the KV budget are rejected here.
    pub fn inject(&mut self, ext_id: usize, arrival_s: f64, input_len: u64, output_len: u64) {
        self.push_request(ext_id, arrival_s, input_len, output_len, false);
    }

    /// Offer a KV-migrated request (disaggregated decode pool): its
    /// `context_len` tokens of KV arrive over the fleet handoff link and
    /// materialize on admission without prefill compute; `output_len`
    /// counts only the tokens still to decode here (the first token was
    /// emitted by the prefill replica).
    pub fn inject_migrated(
        &mut self,
        ext_id: usize,
        arrival_s: f64,
        context_len: u64,
        output_len: u64,
    ) {
        self.push_request(ext_id, arrival_s, context_len, output_len, true);
    }

    fn push_request(
        &mut self,
        ext_id: usize,
        arrival_s: f64,
        input_len: u64,
        output_len: u64,
        prefilled: bool,
    ) {
        let (input_len, output_len) = (input_len.max(1), output_len.max(1));
        self.n_arrived += 1;
        let idx = self.reqs.len();
        let mut live = Live {
            arrival_s,
            input_len,
            output_len,
            prefill_target: input_len,
            prefill_done: 0,
            generated: 0,
            kv_held: 0,
            first_token_s: None,
            finish_s: None,
            rejected: false,
            prefilled,
        };
        if input_len + output_len + 1 > self.kv_budget {
            // can never fit, even alone: explicit rejection
            live.rejected = true;
            self.rejected += 1;
            self.reqs.push(live);
            self.ext_ids.push(ext_id);
            return;
        }
        if !self.has_work() {
            // idle replica: the clock jumps to the arrival
            self.clock = self.clock.max(arrival_s);
        }
        self.reqs.push(live);
        self.ext_ids.push(ext_id);
        self.queue.push_back(idx);
    }

    /// Run iterations until the clock reaches `t` (or nothing is
    /// runnable / the iteration cap hits). Call before injecting a
    /// request arriving at `t` so admission happens at the first
    /// iteration boundary past the arrival, exactly as in the
    /// single-package driver.
    pub fn advance_to(&mut self, t: f64) {
        while !self.truncated && self.clock < t - 1e-12 && self.has_work() {
            if !self.step() {
                break;
            }
        }
    }

    /// Drain all remaining work.
    pub fn run_to_end(&mut self) {
        while !self.truncated && self.step() {}
    }

    fn evict_youngest(&mut self) {
        let victim = self.running.pop().expect("eviction needs a running request");
        let r = &mut self.reqs[victim];
        self.kv_used -= r.kv_held;
        self.kv_reserved -= r.prefill_target - r.prefill_done;
        r.kv_held = 0;
        r.prefill_done = 0;
        self.queue.push_front(victim);
        self.preemptions += 1;
    }

    fn admit(&mut self, idx: usize) {
        let r = &mut self.reqs[idx];
        r.prefill_target = r.context_needed();
        r.prefill_done = 0;
        if r.prefilled {
            // KV materializes via the handoff transfer: no compute, the
            // context is resident. Re-admission after a preemption
            // re-fetches instantaneously — a documented modeling
            // simplification (EXPERIMENTS.md "Fleet serving"): the
            // traffic is counted again in `kv_transfer_tokens`, but no
            // extra link latency is charged.
            r.prefill_done = r.prefill_target;
            r.kv_held = r.prefill_target;
            self.kv_used += r.prefill_target;
            self.kv_transfer_tokens += r.prefill_target;
            // the request's real first token was emitted on the prefill
            // replica; stamping admission time makes this replica's TTFT
            // the decode-pool queueing delay (arrival -> admission)
            if r.first_token_s.is_none() {
                r.first_token_s = Some(self.clock);
            }
        } else {
            self.kv_reserved += r.prefill_target;
        }
        self.running.push(idx);
    }

    /// Run one scheduler iteration. Returns `false` when nothing is
    /// runnable (idle — inject more work or stop) or the iteration cap
    /// was hit (`truncated`).
    pub fn step(&mut self) -> bool {
        if self.truncated || !self.has_work() {
            return false;
        }
        if self.trace.n_iters() >= self.cfg.max_iterations {
            self.truncated = true; // safety valve
            return false;
        }
        loop {
            // --- KV pressure: evict youngest (never the oldest) so the
            // in-flight decodes can write this iteration's tokens
            // without consuming reserved prefill headroom ---
            loop {
                let writes = self
                    .running
                    .iter()
                    .filter(|&&i| self.reqs[i].decoding())
                    .count() as u64;
                if self.kv_used + self.kv_reserved + writes <= self.kv_budget
                    || self.running.len() <= 1
                {
                    break;
                }
                self.evict_youngest();
            }

            let batch = self.form_batch();
            if batch.is_empty() {
                // KV-blocked prefills with no runnable decode: free the
                // youngest and retry (the oldest always keeps its cache,
                // so the system is guaranteed to make progress)
                if self.running.len() > 1 {
                    self.evict_youngest();
                    continue;
                }
                return false; // idle: the driver injects or stops
            }
            self.run_batch(&batch);
            return true;
        }
    }

    /// Compose this iteration's batch per the serving strategy.
    /// Headroom excludes both written (`kv_used`) and reserved
    /// (`kv_reserved`) tokens, so admission can never invade the
    /// reservation of an in-flight chunked prefill.
    fn form_batch(&mut self) -> Vec<(usize, Role)> {
        let mut batch: Vec<(usize, Role)> = Vec::new();
        let mut head = self.kv_budget.saturating_sub(self.kv_used + self.kv_reserved);

        // migrated requests (disaggregated decode pool) join the decode
        // set directly: admit before the strategy composes its batch.
        // Unlike prompt admission, the context is written immediately
        // *and* the admittee decodes this iteration, so the headroom
        // check must also cover every co-scheduled decode write.
        let mut writes = self
            .running
            .iter()
            .filter(|&&i| self.reqs[i].decoding())
            .count() as u64;
        while self.running.len() < self.cfg.max_batch {
            let Some(&q) = self.queue.front() else { break };
            if !self.reqs[q].prefilled {
                break;
            }
            let need = self.reqs[q].context_needed();
            if need + 1 + writes > head {
                break;
            }
            self.queue.pop_front();
            self.admit(q);
            head -= need;
            writes += 1;
        }

        let decoding: Vec<usize> = self
            .running
            .iter()
            .copied()
            .filter(|&i| self.reqs[i].decoding())
            .collect();
        match self.cfg.strategy {
            ServingStrategy::Vllm => {
                while self.running.len() < self.cfg.max_batch {
                    let Some(&q) = self.queue.front() else { break };
                    if self.reqs[q].prefilled {
                        break; // migrated: next iteration's pre-pass
                    }
                    let need = self.reqs[q].context_needed();
                    if need + 1 > head {
                        break;
                    }
                    self.queue.pop_front();
                    self.admit(q);
                    head -= need;
                    batch.push((q, Role::Chunk(need)));
                }
                if batch.is_empty() {
                    batch.extend(decoding.iter().map(|&i| (i, Role::Decode)));
                }
            }
            ServingStrategy::Orca => {
                batch.extend(decoding.iter().map(|&i| (i, Role::Decode)));
                head = head.saturating_sub(decoding.len() as u64);
                while self.running.len() < self.cfg.max_batch {
                    let Some(&q) = self.queue.front() else { break };
                    if self.reqs[q].prefilled {
                        break; // migrated: next iteration's pre-pass
                    }
                    let need = self.reqs[q].context_needed();
                    if need + 1 > head {
                        break;
                    }
                    self.queue.pop_front();
                    self.admit(q);
                    head -= need;
                    batch.push((q, Role::Chunk(need)));
                }
            }
            ServingStrategy::ChunkedPrefill => {
                batch.extend(decoding.iter().map(|&i| (i, Role::Decode)));
                head = head.saturating_sub(decoding.len() as u64);
                let mut budget = self.cfg.chunk_tokens.max(1);
                // continue in-flight prefills first, admission order;
                // their tokens draw on the reservation booked at
                // admission, so headroom is guaranteed
                let prefilling: Vec<usize> = self
                    .running
                    .iter()
                    .copied()
                    .filter(|&i| !self.reqs[i].decoding())
                    .collect();
                for i in prefilling {
                    if budget == 0 {
                        break;
                    }
                    let rem = self.reqs[i].prefill_target - self.reqs[i].prefill_done;
                    let t = rem.min(budget);
                    if t > 0 {
                        budget -= t;
                        batch.push((i, Role::Chunk(t)));
                    }
                }
                // then admit new prompts; the admission books their full
                // context into `kv_reserved`, so later chunks are
                // guaranteed to fit even across iterations
                while budget > 0 && self.running.len() < self.cfg.max_batch {
                    let Some(&q) = self.queue.front() else { break };
                    if self.reqs[q].prefilled {
                        break; // migrated: next iteration's pre-pass
                    }
                    let need = self.reqs[q].context_needed();
                    if need + 1 > head {
                        break;
                    }
                    self.queue.pop_front();
                    self.admit(q);
                    head -= need;
                    let t = need.min(budget);
                    budget -= t;
                    batch.push((q, Role::Chunk(t)));
                }
            }
        }
        batch
    }

    /// Cost the composed batch and apply its effects at completion time.
    fn run_batch(&mut self, batch: &[(usize, Role)]) {
        let mut cost_batch: Vec<Request> = Vec::with_capacity(batch.len());
        let mut n_prefill = 0usize;
        let mut prefill_tokens = 0u64;
        for &(i, role) in batch {
            match role {
                Role::Decode => {
                    cost_batch.push(Request::decode(self.reqs[i].context_needed()));
                }
                Role::Chunk(t) => {
                    n_prefill += 1;
                    prefill_tokens += t;
                    cost_batch.push(Request::Prefill {
                        len: t,
                        past: self.reqs[i].prefill_done,
                    });
                }
            }
        }
        let n_decode = batch.len() - n_prefill;
        let c = self.coster.borrow_mut().cost(&cost_batch);
        let dt = c.latency_cycles / CLOCK_HZ;
        let end = self.clock + dt;
        self.energy += c.energy_pj;
        self.ideal_cycles += c.macs as f64 / self.peak_macs_per_cycle;

        let mut freed: Vec<usize> = Vec::new();
        for &(i, role) in batch {
            let r = &mut self.reqs[i];
            match role {
                Role::Decode => {
                    r.generated += 1;
                    r.kv_held += 1;
                    self.kv_used += 1;
                    self.gen_tokens += 1;
                    if r.generated >= r.output_len {
                        r.finish_s = Some(end);
                        self.done += 1;
                        self.kv_used -= r.kv_held;
                        r.kv_held = 0;
                        freed.push(i);
                    }
                }
                Role::Chunk(t) => {
                    r.prefill_done += t;
                    r.kv_held += t;
                    self.kv_used += t;
                    self.kv_reserved -= t; // written: reservation realized
                    if r.prefill_done >= r.prefill_target && r.first_token_s.is_none() {
                        // prefill completion emits the first output token
                        r.first_token_s = Some(end);
                        r.generated += 1;
                        self.gen_tokens += 1;
                        if r.generated >= r.output_len {
                            r.finish_s = Some(end);
                            self.done += 1;
                            self.kv_used -= r.kv_held;
                            r.kv_held = 0;
                            freed.push(i);
                        }
                    }
                }
            }
        }
        if !freed.is_empty() {
            self.running.retain(|i| !freed.contains(i));
        }
        self.trace.push(IterRecord {
            start_s: self.clock,
            end_s: end,
            n_decode,
            n_prefill,
            prefill_tokens,
            queue_depth: self.queue.len(),
            kv_frac: self.kv_used as f64 / self.kv_budget as f64,
        });
        self.clock = end;
    }

    /// Close the run and aggregate metrics + per-request outcomes.
    pub fn finish(self) -> ReplicaResult {
        let outcomes: Vec<(usize, RequestOutcome)> = self
            .ext_ids
            .iter()
            .zip(&self.reqs)
            .map(|(&ext, r)| {
                (
                    ext,
                    RequestOutcome {
                        arrival_s: r.arrival_s,
                        input_len: r.input_len,
                        output_len: r.output_len,
                        first_token_s: r.first_token_s,
                        finish_s: r.finish_s,
                        rejected: r.rejected,
                    },
                )
            })
            .collect();
        let raw: Vec<RequestOutcome> = outcomes.iter().map(|&(_, o)| o).collect();
        let metrics = finalize(
            &raw,
            self.trace,
            &RunTotals {
                slo: self.cfg.slo,
                max_batch: self.cfg.max_batch,
                makespan_s: self.clock,
                energy_pj: self.energy,
                ideal_cycles: self.ideal_cycles,
                gen_tokens: self.gen_tokens,
                n_preemptions: self.preemptions,
                distinct_shapes: self.coster.borrow().distinct_shapes(),
                kv_transfer_tokens: self.kv_transfer_tokens,
                truncated: self.truncated || self.done + self.rejected < self.n_arrived,
            },
        );
        ReplicaResult { metrics, outcomes }
    }
}

/// Replay `stream` on `(model, hw)` under `cfg` and aggregate serving
/// metrics. Deterministic: identical inputs give bit-identical output.
/// (A single-replica fleet runs this exact driver, so `simulate_fleet`
/// with one replica is bitwise-equal to `simulate_serving`.)
pub fn simulate_serving(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
) -> ServingMetrics {
    let mut s = Scheduler::new(model, hw, cfg);
    for r in &stream.requests {
        s.advance_to(r.arrival_s);
        s.inject(r.id, r.arrival_s, r.input_len, r.output_len);
    }
    s.run_to_end();
    s.finish().metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::sim::coster::MappingPolicy;
    use crate::sim::metrics::SloSpec;
    use crate::sim::stream::TimedRequest;
    use crate::workload::trace::TraceSpec;

    fn tiny_spec() -> TraceSpec {
        TraceSpec {
            mean_in: 48.0,
            mean_out: 8.0,
            sigma_in: 0.4,
            sigma_out: 0.3,
            max_len: 4096,
        }
    }

    fn tiny_hw() -> HwConfig {
        HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        )
    }

    fn tiny_cfg(strategy: ServingStrategy) -> SimConfig {
        SimConfig {
            strategy,
            policy: MappingPolicy::Pipeline,
            max_batch: 8,
            chunk_tokens: 32,
            kv_budget_tokens: 4096,
            dram_gb: 1.0,
            ctx_bucket: 32,
            eval_blocks: 1,
            slo: SloSpec::new(1.0, 0.5),
            max_iterations: 200_000,
            trace_cap: 0,
        }
    }

    fn run(strategy: ServingStrategy, rate_scale: f64, kv_tokens: u64) -> ServingMetrics {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(strategy);
        cfg.kv_budget_tokens = kv_tokens;
        let probe = crate::sim::probe(&model, &hw, &cfg, &tiny_spec());
        let stream = RequestStream::poisson(
            &tiny_spec(),
            probe.capacity_rps() * rate_scale,
            12,
            5,
        );
        simulate_serving(&stream, &model, &hw, &cfg)
    }

    /// A hand-built stream (already sorted by arrival time).
    fn fixed_stream(reqs: &[(f64, u64, u64)]) -> RequestStream {
        RequestStream {
            name: "fixed".into(),
            requests: reqs
                .iter()
                .enumerate()
                .map(|(id, &(arrival_s, input_len, output_len))| TimedRequest {
                    id,
                    arrival_s,
                    input_len,
                    output_len,
                })
                .collect(),
            rate_rps: 1.0,
            seed: 0,
        }
    }

    #[test]
    fn all_strategies_complete_all_requests() {
        for strategy in ServingStrategy::ALL {
            let m = run(strategy, 0.8, 4096);
            assert_eq!(m.n_completed + m.n_rejected, m.n_arrived, "{strategy:?}");
            assert_eq!(m.n_rejected, 0, "{strategy:?}");
            assert_eq!(m.n_in_flight, 0, "{strategy:?}");
            assert!(m.throughput_tps > 0.0);
            assert!(m.ttft.n == m.n_completed);
        }
    }

    #[test]
    fn vllm_never_mixes_prefill_and_decode() {
        let m = run(ServingStrategy::Vllm, 1.2, 4096);
        for it in &m.iters {
            assert!(
                it.n_prefill == 0 || it.n_decode == 0,
                "mixed batch at t={}",
                it.start_s
            );
        }
    }

    #[test]
    fn orca_and_chunked_do_mix() {
        for strategy in [ServingStrategy::Orca, ServingStrategy::ChunkedPrefill] {
            let m = run(strategy, 1.2, 4096);
            assert!(
                m.iters.iter().any(|it| it.n_prefill > 0 && it.n_decode > 0),
                "{strategy:?} never mixed"
            );
        }
    }

    #[test]
    fn chunked_respects_chunk_budget() {
        let m = run(ServingStrategy::ChunkedPrefill, 1.0, 4096);
        for it in &m.iters {
            assert!(it.prefill_tokens <= 32, "chunk {}", it.prefill_tokens);
        }
    }

    #[test]
    fn tight_kv_budget_rejects_or_preempts_but_conserves() {
        let m = run(ServingStrategy::Orca, 1.0, 150);
        assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
        // tight budget must visibly constrain the run
        assert!(m.n_rejected > 0 || m.n_preemptions > 0 || m.max_queue_depth > 0);
        for it in &m.iters {
            assert!(it.kv_frac <= 1.0 + 1e-9, "kv over budget: {}", it.kv_frac);
        }
    }

    #[test]
    fn clock_is_monotone_and_iters_ordered() {
        let m = run(ServingStrategy::ChunkedPrefill, 1.3, 1024);
        for it in &m.iters {
            assert!(it.end_s >= it.start_s);
        }
        for w in m.iters.windows(2) {
            assert!(w[1].start_s >= w[0].start_s - 1e-12);
        }
        assert!(m.makespan_s >= m.iters.last().map_or(0.0, |i| i.end_s) - 1e-12);
    }

    /// Regression (PR 3): under ChunkedPrefill, the admission of request
    /// B must not steal the KV headroom reserved for request A's
    /// later chunks. Pre-fix, `head` was recomputed each iteration from
    /// `kv_used` (written tokens only), so the reservation evaporated
    /// after the admitting iteration: with a 100-token budget, A
    /// (60-token prompt) was admitted, then B (60-token prompt) was
    /// admitted one chunk later into headroom A still needed — forcing
    /// spurious preemption/recompute cycles. Post-fix, `kv_reserved`
    /// holds A's full context until written, B waits, and the run
    /// completes with zero preemptions.
    #[test]
    fn chunked_reservation_survives_across_iterations() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::ChunkedPrefill);
        cfg.kv_budget_tokens = 100;
        cfg.chunk_tokens = 16; // A's 60-token prefill takes 4 iterations
        let stream = fixed_stream(&[(0.0, 60, 4), (1e-6, 60, 4)]);
        let m = simulate_serving(&stream, &model, &hw, &cfg);
        assert_eq!(m.n_completed, 2);
        assert_eq!(m.n_rejected, 0);
        assert_eq!(
            m.n_preemptions, 0,
            "admission stole reserved chunked-prefill headroom"
        );
        for it in &m.iters {
            assert!(it.kv_frac <= 1.0 + 1e-9);
        }
    }

    /// Mixed queues (normal + migrated requests on one scheduler) keep
    /// KV accounting sane: the strategy admission loops defer migrated
    /// requests to the dedicated pre-pass instead of treating them as
    /// prompts (which would double-count their context and underflow
    /// `kv_reserved`).
    #[test]
    fn mixed_normal_and_migrated_queue_conserves() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        for strategy in ServingStrategy::ALL {
            let mut cfg = tiny_cfg(strategy);
            cfg.kv_budget_tokens = 256;
            let mut s = Scheduler::new(&model, &hw, &cfg);
            s.inject(0, 0.0, 60, 4);
            s.inject_migrated(1, 0.0, 60, 4);
            s.inject(2, 0.0, 40, 3);
            s.inject_migrated(3, 0.0, 40, 3);
            s.run_to_end();
            let r = s.finish();
            assert_eq!(r.metrics.n_completed, 4, "{strategy:?}");
            assert!(!r.metrics.truncated, "{strategy:?}");
            for it in &r.metrics.iters {
                assert!(it.kv_frac <= 1.0 + 1e-9, "{strategy:?} kv {}", it.kv_frac);
            }
        }
    }

    /// The occupancy trace stays bounded on long runs while the exact
    /// iteration count keeps counting, and the plot still renders.
    #[test]
    fn long_run_trace_stays_bounded() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::Orca);
        cfg.trace_cap = 32;
        let probe = crate::sim::probe(&model, &hw, &cfg, &tiny_spec());
        let stream =
            RequestStream::poisson(&tiny_spec(), probe.capacity_rps() * 0.8, 48, 11);
        let m = simulate_serving(&stream, &model, &hw, &cfg);
        assert!(
            m.n_iterations > 64,
            "run too short to exercise the cap ({} iters)",
            m.n_iterations
        );
        assert!(
            m.iters.len() < 64,
            "trace not downsampled: {} records",
            m.iters.len()
        );
        let plot = crate::report::ascii_occupancy(&m.iters, cfg.max_batch, 48);
        assert!(plot.contains("batch |"));
        // uncapped run over the same stream agrees on the exact metrics
        cfg.trace_cap = 0;
        let full = simulate_serving(&stream, &model, &hw, &cfg);
        assert_eq!(full.n_iterations, m.n_iterations);
        assert_eq!(full.makespan_s.to_bits(), m.makespan_s.to_bits());
        assert_eq!(full.mean_queue_depth.to_bits(), m.mean_queue_depth.to_bits());
        assert_eq!(full.busy_s.to_bits(), m.busy_s.to_bits());
    }
}

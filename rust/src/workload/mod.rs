//! LLM inference-service workloads (paper §III-A, Fig. 2).
//!
//! A batch mixes requests of different *types* (prefill / decode) and
//! *sequence lengths*. During execution the batch is **merged** into one
//! tall GEMM for QKV generation, **split** per request for multi-head
//! attention, and **re-merged** for the projection and FFN layers — the
//! merge–split–merge pattern that distinguishes LLM serving workloads
//! from traditional DNNs.

pub mod models;
pub mod serving;
pub mod trace;


pub use models::ModelSpec;

/// A single request inside a batch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Request {
    /// Prefill over `len` new tokens with `past` tokens of existing
    /// context (``past > 0`` for chunked prefill continuation chunks).
    Prefill { len: u64, past: u64 },
    /// Decode of one token against a `ctx`-token KV cache.
    Decode { ctx: u64 },
}

impl Request {
    pub fn prefill(len: u64) -> Self {
        Request::Prefill { len, past: 0 }
    }

    pub fn decode(ctx: u64) -> Self {
        Request::Decode { ctx }
    }

    /// Query-side tokens this request contributes to merged GEMMs.
    pub fn q_tokens(&self) -> u64 {
        match *self {
            Request::Prefill { len, .. } => len,
            Request::Decode { .. } => 1,
        }
    }

    /// KV-side context length attended over.
    pub fn kv_tokens(&self) -> u64 {
        match *self {
            Request::Prefill { len, past } => len + past,
            Request::Decode { ctx } => ctx + 1,
        }
    }

    pub fn is_prefill(&self) -> bool {
        matches!(self, Request::Prefill { .. })
    }
}

/// Computation phase of a layer (paper Table I breakdown axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    QkvGen,
    QkT,
    Av,
    Proj,
    Ffn1,
    Ffn2,
    Vector,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::QkvGen => "QKV Gen",
            Phase::QkT => "QK^T",
            Phase::Av => "AV",
            Phase::Proj => "Proj",
            Phase::Ffn1 => "FFN1",
            Phase::Ffn2 => "FFN2",
            Phase::Vector => "Vector",
        }
    }
}

/// Computational shape of one schedulable layer.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub enum LayerKind {
    /// Dense GEMM `[m x k] @ [k x n]` with a resident `k x n` weight.
    Gemm { m: u64, k: u64, n: u64 },
    /// Per-request multi-head attention: for every `(s_q, s_kv)` request,
    /// `heads` x (QK^T: [s_q x d_h][d_h x s_kv]; AV: [s_q x s_kv][s_kv x d_h]).
    /// Both operands are activations (no resident weight).
    Attention {
        heads: u64,
        head_dim: u64,
        reqs: Vec<(u64, u64)>,
    },
}

impl LayerKind {
    /// Total multiply-accumulate operations.
    pub fn macs(&self) -> u64 {
        match self {
            LayerKind::Gemm { m, k, n } => m * k * n,
            LayerKind::Attention {
                heads,
                head_dim,
                reqs,
            } => reqs
                .iter()
                .map(|&(sq, skv)| 2 * heads * head_dim * sq * skv)
                .sum(),
        }
    }
}

/// One schedulable node of the computation execution graph.
#[derive(Debug, Clone)]
pub struct LayerNode {
    pub name: String,
    pub phase: Phase,
    pub kind: LayerKind,
    /// Resident weight bytes (0 for attention).
    pub weight_bytes: u64,
    /// Activation bytes consumed from predecessor layers.
    pub in_bytes: u64,
    /// Activation bytes produced.
    pub out_bytes: u64,
    /// Bytes always read from DRAM regardless of mapping (KV-cache reads).
    pub kv_read_bytes: u64,
    /// Bytes always written to DRAM (KV-cache writes; paper: per-layer
    /// mandatory write-out flags for KV management).
    pub kv_write_bytes: u64,
    /// Predecessor layer indices within the same micro-batch column.
    pub preds: Vec<usize>,
    /// Folded post-processing scalar ops (LayerNorm/softmax/activation/
    /// residual/partial-sum reduction), costed on the vector unit.
    pub vec_ops: u64,
    /// Pinned DRAM chip for this layer's off-chip traffic (paper: per-layer
    /// DRAM ID); `None` = nearest to the executing chiplet.
    pub dram_id: Option<u8>,
    /// Mandatory result write-out (paper: per-layer flags supporting
    /// KV-cache management); the Algorithm-2 optimisation may not clear
    /// this layer's write-back.
    pub force_out: bool,
    /// Shape-equivalence class id (layers with identical `kind`+`vec_ops`
    /// share one id): the evaluation engine memoises per-class kernel
    /// costs, the dominant win on batched workloads where micro-batches
    /// and transformer blocks repeat the same GEMM shapes.
    pub shape_class: u32,
}

/// The work of one micro-batch: requests fused per §III-A plus the layer
/// column they expand into (identical *structure* across micro-batches;
/// shapes differ with the fused sequence lengths).
#[derive(Debug, Clone)]
pub struct MicroBatch {
    pub requests: Vec<Request>,
    pub layers: Vec<LayerNode>,
}

/// A fully instantiated workload: the 2-D computation execution graph
/// (micro-batch x layer) of paper §IV.
#[derive(Debug, Clone)]
pub struct Workload {
    pub micro_batches: Vec<MicroBatch>,
    /// Layers per micro-batch column (`M`).
    pub layers_per_mb: usize,
    /// Cost multiplier extrapolating the evaluated transformer blocks to
    /// the full model depth (identical blocks -> steady state).
    pub block_scale: f64,
    pub model: String,
}

impl Workload {
    pub fn num_micro_batches(&self) -> usize {
        self.micro_batches.len()
    }

    pub fn total_macs(&self) -> u64 {
        let per: u64 = self
            .micro_batches
            .iter()
            .flat_map(|mb| mb.layers.iter())
            .map(|l| l.kind.macs())
            .sum();
        (per as f64 * self.block_scale) as u64
    }
}

/// Workload-construction knobs that the DSE searches or the scenario fixes.
#[derive(Debug, Clone, Copy)]
pub struct WorkloadParams {
    /// Requests fused per micro-batch (must divide the batch size).
    pub micro_batch_size: usize,
    /// FFN partition count (tensor parallelism).
    pub tensor_parallel: usize,
    /// Transformer blocks instantiated explicitly; the rest are
    /// extrapolated by `block_scale` (0 = all blocks).
    pub eval_blocks: usize,
}

impl Default for WorkloadParams {
    fn default() -> Self {
        WorkloadParams {
            micro_batch_size: 1,
            tensor_parallel: 8,
            eval_blocks: 2,
        }
    }
}

/// Build the computation execution graph for `batch` on `model`.
///
/// Layer column per transformer block (paper Fig. 2):
///   QKV(merged) -> MHA(split per request) -> Proj(merged)
///   -> FFN1_0..FFN1_{tp-1} -> FFN2_0..FFN2_{tp-1}
/// Norm/softmax/activation/residual/reduction costs are folded into the
/// adjacent GEMM's `vec_ops` (post-processing unit, paper §V-C).
pub fn build_workload(
    model: &ModelSpec,
    batch: &[Request],
    params: &WorkloadParams,
) -> Workload {
    let mbs = params.micro_batch_size.clamp(1, batch.len().max(1));
    let tp = params.tensor_parallel.max(1);
    let eval_blocks = if params.eval_blocks == 0 {
        model.n_blocks as usize
    } else {
        params.eval_blocks.min(model.n_blocks as usize)
    };
    let block_scale = model.n_blocks as f64 / eval_blocks as f64;

    let mut micro_batches = Vec::new();
    for chunk in batch.chunks(mbs) {
        micro_batches.push(MicroBatch {
            requests: chunk.to_vec(),
            layers: build_mb_layers(model, chunk, tp, eval_blocks),
        });
    }
    let layers_per_mb = micro_batches.first().map_or(0, |m| m.layers.len());
    debug_assert!(micro_batches.iter().all(|m| m.layers.len() == layers_per_mb));
    assign_shape_classes(&mut micro_batches);
    Workload {
        micro_batches,
        layers_per_mb,
        block_scale,
        model: model.name.clone(),
    }
}

/// Assign shape-equivalence class ids (see `LayerNode::shape_class`).
/// Keys are 64-bit hashes of (kind, vec_ops) to avoid cloning attention
/// request lists; a collision would only merge two cost-memo entries.
fn assign_shape_classes(micro_batches: &mut [MicroBatch]) {
    use std::hash::{Hash, Hasher};
    let mut table: std::collections::HashMap<u64, u32> = std::collections::HashMap::new();
    for mb in micro_batches.iter_mut() {
        for layer in mb.layers.iter_mut() {
            let mut h = std::collections::hash_map::DefaultHasher::new();
            layer.kind.hash(&mut h);
            layer.vec_ops.hash(&mut h);
            let key = h.finish();
            let next = table.len() as u32;
            layer.shape_class = *table.entry(key).or_insert(next);
        }
    }
}

fn build_mb_layers(
    model: &ModelSpec,
    reqs: &[Request],
    tp: usize,
    eval_blocks: usize,
) -> Vec<LayerNode> {
    let b = crate::arch::constants::BYTES_PER_ELEM;
    let h = model.hidden;
    let dh = model.head_dim;
    let kv_dim = model.n_kv_heads * dh;
    let qkv_n = h + 2 * kv_dim; // fused Q + K + V projection (GQA-aware)
    let ffn = model.ffn_hidden;
    let sum_s: u64 = reqs.iter().map(|r| r.q_tokens()).sum();
    let act = |tokens: u64, width: u64| tokens * width * b;

    let mut layers = Vec::with_capacity(eval_blocks * (3 + 2 * tp));
    let mut prev_block_outs: Vec<usize> = Vec::new();

    for blk in 0..eval_blocks {
        let base = layers.len();
        // --- QKV generation (merged across all requests) ---
        // vec_ops: pre-LayerNorm + residual add + (if a previous block
        // exists) the tp-way partial-sum reduction of its FFN2 outputs.
        let mut qkv_vec = sum_s * h * 7 + sum_s * h;
        if blk > 0 {
            qkv_vec += sum_s * h * (tp as u64 - 1);
        }
        layers.push(LayerNode {
            name: format!("b{blk}.qkv"),
            phase: Phase::QkvGen,
            kind: LayerKind::Gemm {
                m: sum_s,
                k: h,
                n: qkv_n,
            },
            weight_bytes: h * qkv_n * b,
            in_bytes: act(sum_s, h),
            out_bytes: act(sum_s, qkv_n),
            kv_read_bytes: 0,
            kv_write_bytes: 0,
            preds: prev_block_outs.clone(),
            vec_ops: qkv_vec,
            dram_id: None,
            force_out: false,
            shape_class: 0,
        });
        // --- MHA (split per request; KV cache traffic) ---
        let att_reqs: Vec<(u64, u64)> = reqs.iter().map(|r| (r.q_tokens(), r.kv_tokens())).collect();
        let kv_read: u64 = reqs
            .iter()
            .map(|r| match *r {
                // past context K+V must come from the KV cache in DRAM
                Request::Prefill { past, .. } => 2 * past * kv_dim * b,
                Request::Decode { ctx } => 2 * ctx * kv_dim * b,
            })
            .sum();
        // newly produced K+V of this step is appended to the cache
        let kv_write: u64 = reqs.iter().map(|r| 2 * r.q_tokens() * kv_dim * b).sum();
        let softmax_ops: u64 = att_reqs
            .iter()
            .map(|&(sq, skv)| model.n_heads * sq * skv * 5)
            .sum();
        layers.push(LayerNode {
            name: format!("b{blk}.mha"),
            phase: Phase::QkT, // split into QkT/Av inside the cost model
            kind: LayerKind::Attention {
                heads: model.n_heads,
                head_dim: dh,
                reqs: att_reqs,
            },
            weight_bytes: 0,
            in_bytes: act(sum_s, qkv_n),
            out_bytes: act(sum_s, h),
            kv_read_bytes: kv_read,
            kv_write_bytes: kv_write,
            preds: vec![base],
            vec_ops: softmax_ops,
            dram_id: None,
            force_out: false,
            shape_class: 0,
        });
        // --- output projection (re-merged) ---
        layers.push(LayerNode {
            name: format!("b{blk}.proj"),
            phase: Phase::Proj,
            kind: LayerKind::Gemm { m: sum_s, k: h, n: h },
            weight_bytes: h * h * b,
            in_bytes: act(sum_s, h),
            out_bytes: act(sum_s, h),
            kv_read_bytes: 0,
            kv_write_bytes: 0,
            preds: vec![base + 1],
            vec_ops: sum_s * h * 8, // residual + post-attn LayerNorm
            dram_id: None,
            force_out: false,
            shape_class: 0,
        });
        let proj_idx = base + 2;
        // --- FFN, tensor-parallel into `tp` column/row slices ---
        // SwiGLU models fuse gate+up: widen FFN1 by the gate factor.
        let ffn1_n_total = ffn * model.ffn1_mult();
        let ffn1_slice = ffn1_n_total.div_ceil(tp as u64);
        let ffn2_k_slice = ffn.div_ceil(tp as u64);
        let mut ffn2_idxs = Vec::with_capacity(tp);
        for j in 0..tp {
            layers.push(LayerNode {
                name: format!("b{blk}.ffn1.{j}"),
                phase: Phase::Ffn1,
                kind: LayerKind::Gemm {
                    m: sum_s,
                    k: h,
                    n: ffn1_slice,
                },
                weight_bytes: h * ffn1_slice * b,
                in_bytes: act(sum_s, h),
                out_bytes: act(sum_s, ffn.div_ceil(tp as u64)),
                kv_read_bytes: 0,
                kv_write_bytes: 0,
                preds: vec![proj_idx],
                vec_ops: sum_s * ffn1_slice * 2, // activation (+ gating mul)
                dram_id: None,
                force_out: false,
                shape_class: 0,
            });
        }
        for j in 0..tp {
            let idx = layers.len();
            layers.push(LayerNode {
                name: format!("b{blk}.ffn2.{j}"),
                phase: Phase::Ffn2,
                kind: LayerKind::Gemm {
                    m: sum_s,
                    k: ffn2_k_slice,
                    n: h,
                },
                weight_bytes: ffn2_k_slice * h * b,
                in_bytes: act(sum_s, ffn2_k_slice),
                out_bytes: act(sum_s, h),
                kv_read_bytes: 0,
                kv_write_bytes: 0,
                preds: vec![proj_idx + 1 + j],
                vec_ops: 0, // reduction charged on the consumer (next QKV)
                dram_id: None,
                force_out: false,
                shape_class: 0,
            });
            ffn2_idxs.push(idx);
        }
        prev_block_outs = ffn2_idxs;
    }
    layers
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gpt7b() -> ModelSpec {
        ModelSpec::gpt3_7b()
    }

    #[test]
    fn request_token_accounting() {
        assert_eq!(Request::prefill(128).q_tokens(), 128);
        assert_eq!(Request::prefill(128).kv_tokens(), 128);
        assert_eq!(Request::Prefill { len: 64, past: 192 }.kv_tokens(), 256);
        assert_eq!(Request::decode(500).q_tokens(), 1);
        assert_eq!(Request::decode(500).kv_tokens(), 501);
    }

    #[test]
    fn layer_column_structure() {
        let m = gpt7b();
        let batch = vec![Request::prefill(128); 4];
        let params = WorkloadParams {
            micro_batch_size: 2,
            tensor_parallel: 4,
            eval_blocks: 2,
        };
        let w = build_workload(&m, &batch, &params);
        assert_eq!(w.num_micro_batches(), 2);
        // per block: qkv + mha + proj + 4xffn1 + 4xffn2 = 11; x2 blocks
        assert_eq!(w.layers_per_mb, 22);
        assert!((w.block_scale - 16.0).abs() < 1e-9); // 32 blocks / 2
    }

    #[test]
    fn merged_gemm_uses_sum_of_seq_lens() {
        let m = gpt7b();
        let batch = vec![Request::prefill(100), Request::prefill(28)];
        let params = WorkloadParams {
            micro_batch_size: 2,
            tensor_parallel: 1,
            eval_blocks: 1,
        };
        let w = build_workload(&m, &batch, &params);
        match &w.micro_batches[0].layers[0].kind {
            LayerKind::Gemm { m: mm, k, n } => {
                assert_eq!(*mm, 128); // merged 100 + 28
                assert_eq!(*k, m.hidden);
                assert_eq!(*n, m.hidden + 2 * m.n_kv_heads * m.head_dim);
            }
            _ => panic!("expected gemm"),
        }
    }

    #[test]
    fn mha_splits_per_request() {
        let m = gpt7b();
        let batch = vec![Request::prefill(100), Request::decode(400)];
        let params = WorkloadParams {
            micro_batch_size: 2,
            tensor_parallel: 1,
            eval_blocks: 1,
        };
        let w = build_workload(&m, &batch, &params);
        match &w.micro_batches[0].layers[1].kind {
            LayerKind::Attention { reqs, .. } => {
                assert_eq!(reqs.len(), 2);
                assert_eq!(reqs[0], (100, 100));
                assert_eq!(reqs[1], (1, 401));
            }
            _ => panic!("expected attention"),
        }
    }

    #[test]
    fn decode_reads_kv_cache_prefill_writes_it() {
        let m = gpt7b();
        let params = WorkloadParams {
            micro_batch_size: 1,
            tensor_parallel: 1,
            eval_blocks: 1,
        };
        let wd = build_workload(&m, &[Request::decode(1000)], &params);
        let mha = &wd.micro_batches[0].layers[1];
        assert!(mha.kv_read_bytes > 0);
        let wp = build_workload(&m, &[Request::prefill(512)], &params);
        let mha_p = &wp.micro_batches[0].layers[1];
        assert_eq!(mha_p.kv_read_bytes, 0); // first chunk: no past context
        assert!(mha_p.kv_write_bytes > 0);
    }

    #[test]
    fn chunked_prefill_reads_past_context() {
        let m = gpt7b();
        let params = WorkloadParams {
            micro_batch_size: 1,
            tensor_parallel: 1,
            eval_blocks: 1,
        };
        let w = build_workload(&m, &[Request::Prefill { len: 512, past: 1024 }], &params);
        let mha = &w.micro_batches[0].layers[1];
        assert!(mha.kv_read_bytes > 0);
        match &mha.kind {
            LayerKind::Attention { reqs, .. } => assert_eq!(reqs[0], (512, 1536)),
            _ => panic!(),
        }
    }

    #[test]
    fn gqa_shrinks_kv_projection() {
        let llama = ModelSpec::llama3_70b();
        let gpt = ModelSpec::gpt3_7b();
        assert!(llama.n_kv_heads < llama.n_heads);
        assert_eq!(gpt.n_kv_heads, gpt.n_heads);
        let params = WorkloadParams::default();
        let w = build_workload(&llama, &[Request::prefill(64)], &params);
        match &w.micro_batches[0].layers[0].kind {
            LayerKind::Gemm { n, .. } => {
                assert_eq!(*n, llama.hidden + 2 * llama.n_kv_heads * llama.head_dim);
                assert!(*n < 3 * llama.hidden);
            }
            _ => panic!(),
        }
    }

    #[test]
    fn macs_scale_with_depth_extrapolation() {
        let m = gpt7b();
        let batch = vec![Request::prefill(64)];
        let p1 = WorkloadParams {
            eval_blocks: 1,
            ..Default::default()
        };
        let p2 = WorkloadParams {
            eval_blocks: 2,
            ..Default::default()
        };
        let w1 = build_workload(&m, &batch, &p1);
        let w2 = build_workload(&m, &batch, &p2);
        // different eval depth, same extrapolated total (+-rounding)
        let rel = (w1.total_macs() as f64 - w2.total_macs() as f64).abs()
            / w2.total_macs() as f64;
        assert!(rel < 1e-6, "rel={rel}");
    }

    #[test]
    fn ffn_slices_cover_full_width() {
        let m = gpt7b();
        for tp in [1usize, 3, 8] {
            let params = WorkloadParams {
                micro_batch_size: 1,
                tensor_parallel: tp,
                eval_blocks: 1,
            };
            let w = build_workload(&m, &[Request::prefill(32)], &params);
            let total_n: u64 = w.micro_batches[0]
                .layers
                .iter()
                .filter(|l| l.phase == Phase::Ffn1)
                .map(|l| match l.kind {
                    LayerKind::Gemm { n, .. } => n,
                    _ => 0,
                })
                .sum();
            assert!(total_n >= m.ffn_hidden * m.ffn1_mult());
        }
    }
}

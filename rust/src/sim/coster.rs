//! Iteration costing: every scheduler iteration's batch composition is
//! costed through the existing `PreparedWorkload`/`MappingEvaluator`
//! path, behind a composition-keyed memo so repeated batch shapes are
//! never re-simulated.
//!
//! Compositions are quantized before costing (context lengths rounded up
//! to `ctx_bucket`), which bounds the number of distinct shapes a long
//! simulation can produce: steady-state serving then pays one hash
//! lookup per iteration instead of one timeline simulation.

use std::collections::HashMap;
use std::hash::BuildHasherDefault;

use crate::arch::HwConfig;
use crate::cost::{group_params, EvalScratch, Evaluator, MappingEvaluator};
use crate::ga::{self, GaConfig};
use crate::mapping::presets;
use crate::workload::{build_workload, ModelSpec, Request};

/// How the simulator maps each iteration's workload onto the chiplets.
#[derive(Debug, Clone, Copy)]
pub enum MappingPolicy {
    /// Layer-pipeline preset (Algorithm 1), instantiated per batch shape.
    Pipeline,
    /// Data-parallel preset: each micro-batch on one chiplet.
    DataParallel,
    /// GA mapping search per distinct batch shape (the sim-backed
    /// objective of `dse::compass_dse_serving`); results are memoized so
    /// each shape is searched exactly once.
    Searched(GaConfig),
}

impl MappingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            MappingPolicy::Pipeline => "pipeline",
            MappingPolicy::DataParallel => "data-parallel",
            MappingPolicy::Searched(_) => "searched",
        }
    }
}

/// Cost of one scheduler iteration (one full forward pass of the batch).
#[derive(Debug, Clone, Copy)]
pub struct IterCost {
    pub latency_cycles: f64,
    pub energy_pj: f64,
    /// Total MACs of the (quantized) batch, for utilization accounting.
    pub macs: u64,
}

/// Canonical (sorted, quantized) batch composition: `(tag, len, past)`
/// triples with tag 0 = prefill, 1 = decode.
type CompKey = Vec<(u8, u64, u64)>;

/// Composition-memoized batch coster.
pub struct BatchCoster<'a> {
    model: &'a ModelSpec,
    hw: &'a HwConfig,
    policy: MappingPolicy,
    eval_blocks: usize,
    ctx_bucket: u64,
    /// KV-cache element width (bits): quantized caches (fp8/int4) move
    /// proportionally fewer KV bytes per iteration, so decode-phase
    /// attention gets cheaper along with the capacity gain.
    kv_bits: u64,
    memo: HashMap<CompKey, IterCost, BuildHasherDefault<FxHasher>>,
    /// Reusable composition-key scratch: `fill_key` rebuilds it in place
    /// so steady-state memo hits allocate nothing.
    key_buf: CompKey,
    lookups: usize,
}

impl<'a> BatchCoster<'a> {
    pub fn new(
        model: &'a ModelSpec,
        hw: &'a HwConfig,
        policy: MappingPolicy,
        eval_blocks: usize,
        ctx_bucket: u64,
        kv_dtype: super::kv::KvDtype,
    ) -> Self {
        BatchCoster {
            model,
            hw,
            policy,
            eval_blocks,
            ctx_bucket,
            kv_bits: kv_dtype.bits(),
            memo: HashMap::default(),
            key_buf: CompKey::new(),
            lookups: 0,
        }
    }

    #[inline]
    fn quantize(&self, x: u64) -> u64 {
        let b = self.ctx_bucket.max(1);
        x.div_ceil(b) * b
    }

    /// Rebuild the canonical quantized composition key of a batch into
    /// the reusable `key_buf` (no allocation once the buffer has grown
    /// to the steady-state batch size).
    fn fill_key(&mut self, batch: &[Request]) {
        let b = self.ctx_bucket.max(1);
        let q = |x: u64| x.div_ceil(b) * b;
        self.key_buf.clear();
        self.key_buf.extend(batch.iter().map(|r| match *r {
            Request::Prefill { len, past } => (0u8, q(len.max(1)), q(past)),
            Request::Decode { ctx } => (1u8, q(ctx.max(1)), 0),
        }));
        self.key_buf.sort_unstable();
    }

    /// Distinct batch shapes simulated so far.
    pub fn distinct_shapes(&self) -> usize {
        self.memo.len()
    }

    /// Total `cost` calls (memo hits + misses).
    pub fn lookups(&self) -> usize {
        self.lookups
    }

    /// Memo hits so far: every lookup that did not simulate a new
    /// distinct shape.
    pub fn hits(&self) -> usize {
        self.lookups - self.memo.len()
    }

    /// Cost one iteration batch; memo hits never re-simulate.
    ///
    /// The steady-state hit path is allocation-free: the composition key
    /// is rebuilt into a reusable buffer and looked up as a borrowed
    /// slice (`Vec<K>: Borrow<[K]>`); only a miss clones the key into
    /// the memo.
    pub fn cost(&mut self, batch: &[Request]) -> IterCost {
        debug_assert!(!batch.is_empty(), "cannot cost an empty batch");
        self.lookups += 1;
        self.fill_key(batch);
        if let Some(c) = self.memo.get(self.key_buf.as_slice()) {
            let _p = super::telemetry::profile::scope("coster.memo_hit");
            return *c;
        }
        let _p = super::telemetry::profile::scope("coster.memo_miss");
        // the quantized key *is* the costed batch: decode it back
        let qbatch: Vec<Request> = self
            .key_buf
            .iter()
            .map(|&(tag, len, past)| {
                if tag == 0 {
                    Request::Prefill { len, past }
                } else {
                    Request::Decode { ctx: len }
                }
            })
            .collect();
        let has_prefill = qbatch.iter().any(|r| r.is_prefill());
        let params = group_params(self.hw, has_prefill, self.eval_blocks);
        let mut w = build_workload(self.model, &qbatch, &params);
        if self.kv_bits != 16 {
            // scale the fp16-sized KV traffic to the cache dtype; the
            // uniform factor keeps shape-class cost memoization sound
            for mb in w.micro_batches.iter_mut() {
                for l in mb.layers.iter_mut() {
                    l.kv_read_bytes = l.kv_read_bytes * self.kv_bits / 16;
                    l.kv_write_bytes = l.kv_write_bytes * self.kv_bits / 16;
                }
            }
        }
        let (rows, cols) = (w.num_micro_batches(), w.layers_per_mb);
        let chips = self.hw.num_chiplets();
        let (latency_cycles, energy_pj) = match self.policy {
            MappingPolicy::Pipeline => {
                let m = presets::pipeline_parallel(rows, cols, chips);
                let r = Evaluator::new().eval_batch(&w, self.hw, &m);
                (r.latency_cycles, r.energy_pj)
            }
            MappingPolicy::DataParallel => {
                let m = presets::data_parallel(rows, cols, chips);
                let r = Evaluator::new().eval_batch(&w, self.hw, &m);
                (r.latency_cycles, r.energy_pj)
            }
            MappingPolicy::Searched(ga_cfg) => {
                // per-shape seed: order-independent, deterministic
                let mut cfg = ga_cfg;
                cfg.seed = ga_cfg.seed ^ key_hash(&self.key_buf);
                let mev = MappingEvaluator::new(&w, self.hw);
                let res = ga::search(rows, cols, chips, &cfg, &mev);
                let mut scratch = EvalScratch::default();
                let r = mev.simulate(&res.best, &mut scratch);
                (r.latency_cycles, r.energy_pj)
            }
        };
        let c = IterCost {
            latency_cycles,
            energy_pj,
            macs: w.total_macs(),
        };
        let key = self.key_buf.clone();
        self.memo.insert(key, c);
        c
    }
}

/// Deterministic 64-bit hash of a composition key.
///
/// Stays on `DefaultHasher` (keyed with fixed constants, stable across
/// runs) because it seeds `MappingPolicy::Searched` GA runs: switching
/// it would silently change every searched-policy result bitwise. The
/// memo's table hasher ([`FxHasher`]) is a separate, cheaper function —
/// map iteration order is never observed, so it is free to change.
fn key_hash(key: &CompKey) -> u64 {
    use std::hash::{Hash, Hasher};
    let mut h = std::collections::hash_map::DefaultHasher::new();
    key.hash(&mut h);
    h.finish()
}

/// Cheap deterministic hasher for the composition memo (FxHash-style
/// rotate–xor–multiply, fixed seed). Unkeyed by design: the memo is an
/// internal cache whose iteration order is never observed, and the hot
/// path hashes a handful of machine words per lookup, where SipHash's
/// setup cost dominates.
#[derive(Default)]
pub struct FxHasher {
    hash: u64,
}

const FX_SEED: u64 = 0x517c_c1b7_2722_0a95;

impl FxHasher {
    #[inline]
    fn add(&mut self, w: u64) {
        self.hash = (self.hash.rotate_left(5) ^ w).wrapping_mul(FX_SEED);
    }
}

impl std::hash::Hasher for FxHasher {
    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }

    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.add(b as u64);
        }
    }

    #[inline]
    fn write_u8(&mut self, n: u8) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u16(&mut self, n: u16) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u32(&mut self, n: u32) {
        self.add(n as u64);
    }

    #[inline]
    fn write_u64(&mut self, n: u64) {
        self.add(n);
    }

    #[inline]
    fn write_usize(&mut self, n: usize) {
        self.add(n as u64);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::sim::kv::KvDtype;

    fn setup() -> (ModelSpec, HwConfig) {
        let model = ModelSpec::tiny();
        let hw = HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        (model, hw)
    }

    #[test]
    fn memo_hits_on_quantized_repeats() {
        let (model, hw) = setup();
        let mut c = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 64, KvDtype::Fp16);
        let a = c.cost(&[Request::decode(100), Request::decode(120)]);
        // same bucket (128) for both contexts -> same shape, no re-sim
        let b = c.cost(&[Request::decode(97), Request::decode(128)]);
        assert_eq!(c.distinct_shapes(), 1);
        assert_eq!(c.lookups(), 2);
        assert_eq!(c.hits(), 1);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        // crossing a bucket boundary is a new shape
        c.cost(&[Request::decode(200), Request::decode(128)]);
        assert_eq!(c.distinct_shapes(), 2);
        assert_eq!(c.hits(), 1);
    }

    #[test]
    fn key_is_order_invariant() {
        let (model, hw) = setup();
        let mut c = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Fp16);
        let x = c.cost(&[Request::prefill(60), Request::decode(40)]);
        let y = c.cost(&[Request::decode(40), Request::prefill(60)]);
        assert_eq!(c.distinct_shapes(), 1);
        assert_eq!(x.latency_cycles.to_bits(), y.latency_cycles.to_bits());
    }

    #[test]
    fn quantized_kv_never_costs_more_than_fp16() {
        let (model, hw) = setup();
        // long-context decode batch: KV traffic dominates the iteration
        let batch = vec![Request::decode(2048); 8];
        let mut fp16 = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Fp16);
        let mut int4 = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Int4);
        let a = fp16.cost(&batch);
        let b = int4.cost(&batch);
        assert!(
            b.latency_cycles <= a.latency_cycles,
            "int4 KV slower than fp16: {} > {}",
            b.latency_cycles,
            a.latency_cycles
        );
        assert!(b.energy_pj <= a.energy_pj);
        assert_eq!(a.macs, b.macs, "quantization must not change the math");
    }

    #[test]
    fn memo_counters_stay_consistent_under_reused_key_buffer() {
        let (model, hw) = setup();
        let mut c = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 64, KvDtype::Fp16);
        // Vary batch size up and down so the reusable key buffer must
        // both grow and shrink; the accounting invariant
        // lookups == hits + distinct_shapes must hold after every call.
        let batches: Vec<Vec<Request>> = vec![
            vec![Request::decode(100); 8],
            vec![Request::decode(100); 2],
            vec![Request::decode(100); 8],
            vec![Request::prefill(60), Request::decode(40)],
            vec![Request::decode(100); 2],
            vec![Request::decode(40), Request::prefill(60)],
        ];
        for (i, b) in batches.iter().enumerate() {
            c.cost(b);
            assert_eq!(
                c.lookups(),
                c.hits() + c.distinct_shapes(),
                "accounting broke after call {i}"
            );
            assert_eq!(c.lookups(), i + 1);
        }
        // 8-wide decode, 2-wide decode, mixed: three distinct shapes,
        // each repeated once.
        assert_eq!(c.distinct_shapes(), 3);
        assert_eq!(c.hits(), 3);
    }

    #[test]
    fn stale_key_buffer_tail_never_leaks_into_smaller_batches() {
        let (model, hw) = setup();
        let mut big = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Fp16);
        let mut fresh = BatchCoster::new(&model, &hw, MappingPolicy::Pipeline, 1, 32, KvDtype::Fp16);
        // Prime `big`'s key buffer with a wide batch, then cost a narrow
        // one: the result must be bitwise what a fresh coster computes.
        big.cost(&vec![Request::decode(500); 16]);
        let small = [Request::prefill(20), Request::decode(70)];
        let a = big.cost(&small);
        let b = fresh.cost(&small);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.macs, b.macs);
        assert_eq!(big.distinct_shapes(), 2);
    }

    #[test]
    fn quantized_key_costs_identically_to_decoded_batch() {
        let (model, hw) = setup();
        let bucket = 64;
        let mut raw = BatchCoster::new(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            bucket,
            KvDtype::Fp16,
        );
        let mut dec = BatchCoster::new(
            &model,
            &hw,
            MappingPolicy::Pipeline,
            1,
            bucket,
            KvDtype::Fp16,
        );
        // Cost an unaligned batch, then hand a second coster the
        // pre-quantized (bucket-aligned) equivalent: the memo key is the
        // costed batch, so both must produce bitwise-identical costs and
        // the aligned batch must also land on the same key.
        let q = |x: u64| x.div_ceil(bucket) * bucket;
        let batch = [
            Request::Prefill { len: 90, past: 10 },
            Request::decode(130),
        ];
        let aligned = [
            Request::Prefill {
                len: q(90),
                past: q(10),
            },
            Request::decode(q(130)),
        ];
        let a = raw.cost(&batch);
        let b = dec.cost(&aligned);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.macs, b.macs);
        // and the aligned batch is a memo hit on the raw coster
        raw.cost(&aligned);
        assert_eq!(raw.distinct_shapes(), 1);
        assert_eq!(raw.hits(), 1);
    }

    #[test]
    fn fx_hasher_is_deterministic_and_spreads() {
        use std::hash::{Hash, Hasher};
        let key: CompKey = vec![(0, 64, 0), (1, 128, 0)];
        let h = |k: &CompKey| {
            let mut h = FxHasher::default();
            k.hash(&mut h);
            h.finish()
        };
        assert_eq!(h(&key), h(&key.clone()));
        let other: CompKey = vec![(0, 64, 0), (1, 192, 0)];
        assert_ne!(h(&key), h(&other));
        // slice and owned-vec hashing agree (the borrowed-slice memo
        // lookup depends on this)
        let mut hs = FxHasher::default();
        key.as_slice().hash(&mut hs);
        let mut hv = FxHasher::default();
        key.hash(&mut hv);
        assert_eq!(hs.finish(), hv.finish());
    }

    #[test]
    fn searched_policy_is_deterministic() {
        let (model, hw) = setup();
        let cfg = crate::ga::GaConfig::tiny();
        let batch = vec![Request::decode(50); 4];
        let mut c1 = BatchCoster::new(&model, &hw, MappingPolicy::Searched(cfg), 1, 32, KvDtype::Fp16);
        let mut c2 = BatchCoster::new(&model, &hw, MappingPolicy::Searched(cfg), 1, 32, KvDtype::Fp16);
        let a = c1.cost(&batch);
        let b = c2.cost(&batch);
        assert_eq!(a.latency_cycles.to_bits(), b.latency_cycles.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert!(a.macs > 0);
    }
}

"""L1 ARD-RBF Pallas kernel vs pure-jnp oracle (K_sys of Eq. 2)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import rbf_gram
from compile.kernels.ref import rbf_gram_ref


@settings(max_examples=25, deadline=None)
@given(
    q=st.sampled_from([1, 2, 4, 8, 16]),
    n=st.sampled_from([1, 2, 4, 8, 16]),
    d=st.sampled_from([1, 2, 4, 8]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref_hypothesis(q, n, d, seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(q, d)).astype(np.float32)
    y = rng.normal(size=(n, d)).astype(np.float32)
    ils = rng.uniform(0.1, 2.0, size=d).astype(np.float32)
    got = rbf_gram(jnp.asarray(x), jnp.asarray(y), jnp.asarray(ils))
    want = rbf_gram_ref(jnp.asarray(x), jnp.asarray(y), jnp.asarray(ils))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_self_similarity_is_one():
    rng = np.random.default_rng(1)
    x = rng.normal(size=(8, 4)).astype(np.float32)
    ils = np.full(4, 0.7, np.float32)
    k = np.asarray(rbf_gram(jnp.asarray(x), jnp.asarray(x), jnp.asarray(ils)))
    np.testing.assert_allclose(np.diag(k), 1.0, rtol=1e-5)


def test_bounds_and_symmetry():
    rng = np.random.default_rng(2)
    x = rng.normal(size=(16, 8)).astype(np.float32)
    ils = rng.uniform(0.2, 1.0, size=8).astype(np.float32)
    k = np.asarray(rbf_gram(jnp.asarray(x), jnp.asarray(x), jnp.asarray(ils)))
    assert (k > 0).all() and (k <= 1.0 + 1e-6).all()
    np.testing.assert_allclose(k, k.T, rtol=1e-5, atol=1e-6)


def test_zero_inverse_lengthscale_disables_dim():
    """Padded feature dims (inv_ls = 0) must not affect similarity."""
    rng = np.random.default_rng(3)
    x = rng.normal(size=(4, 4)).astype(np.float32)
    y = rng.normal(size=(4, 4)).astype(np.float32)
    ils = np.array([1.0, 1.0, 0.0, 0.0], np.float32)
    x2 = np.array(x)
    x2[:, 2:] = 999.0  # junk in disabled dims
    k1 = rbf_gram(jnp.asarray(x), jnp.asarray(y), jnp.asarray(ils))
    k2 = rbf_gram(jnp.asarray(x2), jnp.asarray(y), jnp.asarray(ils))
    np.testing.assert_allclose(k1, k2, rtol=1e-5)


def test_similarity_decays_with_distance():
    x = np.zeros((1, 2), np.float32)
    ys = np.array([[0.5, 0.0], [1.0, 0.0], [2.0, 0.0]], np.float32)
    ils = np.ones(2, np.float32)
    k = np.asarray(rbf_gram(jnp.asarray(x), jnp.asarray(ys), jnp.asarray(ils)))[0]
    assert k[0] > k[1] > k[2]


@pytest.mark.parametrize("bq,bn", [(2, 4), (4, 2), (8, 8)])
def test_blocking_invariance(bq, bn):
    rng = np.random.default_rng(4)
    x = rng.normal(size=(8, 4)).astype(np.float32)
    y = rng.normal(size=(8, 4)).astype(np.float32)
    ils = np.ones(4, np.float32)
    full = rbf_gram(jnp.asarray(x), jnp.asarray(y), jnp.asarray(ils))
    tiled = rbf_gram(
        jnp.asarray(x), jnp.asarray(y), jnp.asarray(ils), block_q=bq, block_n=bn
    )
    np.testing.assert_allclose(full, tiled, rtol=1e-6)

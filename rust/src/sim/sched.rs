//! Deterministic discrete-event, iteration-level continuous-batching
//! scheduler (paper §II / Fig. 9, made dynamic).
//!
//! The simulator replays a [`RequestStream`] through one of the three
//! `ServingStrategy` policies:
//!
//! * **vLLM-style** — prefill priority: waiting prompts pause decodes
//!   and run as a standalone batch;
//! * **Orca-style** — iteration-level mixed batches: new prompts join
//!   the in-flight decode batch wholesale;
//! * **Sarathi-style chunked prefill** — each decode iteration carries
//!   at most `chunk_tokens` prompt tokens from the admission queue.
//!
//! All three share an admission queue and a paged KV cache
//! ([`super::kv::KvCache`]) sized from the hardware's DRAM capacity at
//! the configured cache dtype. The scheduler speaks only the `KvCache`
//! API: admission headroom (`can_admit`), chunked-prefill reservation
//! leases (`lease`/`write_chunk`), decode growth (`write_decode`), and
//! policy-driven preemption with prefill recomputation ([`super::kv::
//! EvictionPolicy`]). Prefix-sharing admissions skip the shared
//! system-prompt tokens: their chunks carry `past >= skip` so the
//! attention cost still covers the full context while the prefill
//! compute shrinks. The clock advances by each iteration's simulated
//! latency, costed through [`BatchCoster`]; when nothing is runnable it
//! jumps to the next arrival. Everything is pure `f64`/integer
//! arithmetic on a fixed event order, so a fixed stream produces
//! bit-identical metrics on every run — and under the default
//! token-granular fp16 spec the paged accounting is bitwise-equal to
//! the pre-paging scalar counters (see `rust/tests/kv_properties.rs`).
//!
//! The scheduler is a resumable state machine ([`Scheduler`]): the
//! single-package entry point [`simulate_serving`] drives one instance
//! over a whole stream, while the fleet layer (`sim::fleet`) interleaves
//! many instances under a front-end router, injecting requests (or KV
//! migrations, for disaggregated prefill/decode pools) between steps.
//!
//! Quiescent decode stretches are *fast-forwarded*: when no admission
//! is possible, no chunked prefill is in flight, and no eviction can
//! trigger, the batch composition is provably constant until the next
//! finish or `ctx_bucket` crossing, so `advance_to` costs it once and
//! replays the per-iteration scalar updates in the exact floating-point
//! operation order of the naive loop — bitwise-identical results at a
//! fraction of the per-iteration work (see
//! [`Scheduler::try_fast_forward`]; `COMPASS_COALESCE=0` forces the
//! naive loop, anchored in `rust/tests/coalesce_equivalence.rs`).

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::arch::constants::CLOCK_HZ;
use crate::arch::HwConfig;
use crate::workload::serving::ServingStrategy;
use crate::workload::{ModelSpec, Request};

use super::coster::BatchCoster;
use super::kv::{EvictionPolicy, KvCache};
use super::metrics::{finalize, IterRecord, RequestOutcome, RunTotals, ServingMetrics, TraceBuffer};
use super::stream::RequestStream;
use super::telemetry::{profile, EventKind, IterSpan, SharedSink};
use super::SimConfig;

/// Per-request lifecycle state.
#[derive(Debug, Clone, Copy)]
struct Live {
    arrival_s: f64,
    input_len: u64,
    output_len: u64,
    /// Context tokens the current admission must prefill (prompt plus
    /// any tokens generated before a preemption, minus any shared-prefix
    /// skip granted at admission).
    prefill_target: u64,
    prefill_done: u64,
    /// Context tokens already resident before this admission's first
    /// chunk (the shared-prefix skip): chunk costs carry
    /// `past = past_base + prefill_done`.
    past_base: u64,
    generated: u64,
    first_token_s: Option<f64>,
    finish_s: Option<f64>,
    rejected: bool,
    /// Fleet KV migration: the context materializes on admission via
    /// the handoff transfer instead of prefill compute.
    prefilled: bool,
    /// Extracted by the front end mid-decode (rebalancing): the request
    /// finishes on another replica, so this replica's outcomes skip it.
    migrated_out: bool,
    /// Killed by a replica crash ([`Scheduler::crash`]): the front end
    /// owns the final outcome (retry elsewhere or permanent loss), so
    /// this replica's outcomes skip it — exactly like `migrated_out`.
    failed: bool,
}

impl Live {
    /// An admitted request is decoding once its prefill is complete.
    fn decoding(&self) -> bool {
        self.finish_s.is_none() && self.prefill_done >= self.prefill_target
    }

    /// Context tokens a (re-)admission must cover.
    fn context_needed(&self) -> u64 {
        self.input_len + self.generated
    }
}

/// What a request does in one iteration batch.
#[derive(Debug, Clone, Copy)]
enum Role {
    /// Generate one token against the current context.
    Decode,
    /// Prefill `t` prompt tokens (the whole prompt for vLLM/Orca).
    Chunk(u64),
}

/// A finished replica: aggregate metrics plus per-request outcomes
/// keyed by the caller's external request ids (for fleet stitching).
#[derive(Debug, Clone)]
pub struct ReplicaResult {
    pub metrics: ServingMetrics,
    pub outcomes: Vec<(usize, RequestOutcome)>,
}

/// Front-end observation counters (see
/// [`Scheduler::frontend_counters`]). Maintained incrementally at every
/// queue/running transition, so the per-arrival × per-replica routing
/// observation is O(1) instead of a full queue + running rescan.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FrontendCounters {
    pub backlog_tokens: u64,
    pub pending_prefill_tokens: u64,
    pub n_prefilling: usize,
    pub n_decoding: usize,
}

/// A request killed by a replica crash ([`Scheduler::crash`]): the
/// fleet layer decides its fate (retry on a healthy replica under the
/// retry policy, or permanent loss). The lengths are this replica's
/// view — a migrated-in request reports its context, not the original
/// prompt; the front end keeps the true origin record.
#[derive(Debug, Clone, Copy)]
pub struct FailedRequest {
    pub ext_id: usize,
    pub input_len: u64,
    pub output_len: u64,
}

/// A mid-decode request removed from a replica by the front-end
/// rebalancer ([`Scheduler::extract_youngest_decoding`]): the caller
/// owns re-injection (via [`Scheduler::inject_migrated`] on another
/// replica) and outcome stitching.
#[derive(Debug, Clone, Copy)]
pub struct ExtractedRequest {
    pub ext_id: usize,
    /// Arrival time at *this* replica (the fleet keeps the true origin
    /// for requests that migrate more than once).
    pub arrival_s: f64,
    pub input_len: u64,
    pub output_len: u64,
    /// When this replica emitted (or inherited) the first token.
    pub first_token_s: f64,
    /// Context tokens to re-materialize at the destination.
    pub context_len: u64,
    /// Output tokens still to decode.
    pub rest: u64,
}

/// Sentinel for "no request" in the intrusive running-list links.
const NONE: usize = usize::MAX;

/// Decode fast-forward is on by default; `COMPASS_COALESCE=0` turns it
/// off, forcing every iteration through the naive [`Scheduler::step`]
/// loop (mirroring the `COMPASS_SHARED_CACHE` kill switch). Read once
/// at scheduler construction; [`Scheduler::set_coalescing`] overrides
/// per instance.
fn coalescing_enabled() -> bool {
    std::env::var("COMPASS_COALESCE").map_or(true, |v| v != "0")
}

/// Resumable continuous-batching scheduler for one package.
///
/// Drive it with [`Scheduler::inject`] / [`Scheduler::advance_to`] /
/// [`Scheduler::step`]; arrivals are the caller's responsibility (a
/// request must be injected once the clock has reached its arrival
/// time), which is what lets a fleet router interleave replicas
/// deterministically.
///
/// Hot-path layout: `reqs` is an append-only arena (slots are never
/// reused — request indices double as KV-cache ids), the running set is
/// an index-based intrusive doubly-linked list threaded through
/// `run_next`/`run_prev` (O(1) unlink replaces the old
/// `Vec::remove`/`retain` shifts; link order *is* admission order, the
/// explicit ordinal every batch-composition and eviction scan relies
/// on), and per-step batch/cost/event buffers are reused across
/// iterations so the steady state allocates nothing.
pub struct Scheduler<'a> {
    cfg: SimConfig,
    /// All KV accounting lives here: block allocator, reservation
    /// leases, prefix sharing, fragmentation/sharing stats.
    kv: KvCache,
    /// Composition-keyed cost memo; shareable across the replicas of a
    /// fleet (costs are order-independent, so sharing is bit-exact —
    /// also across the parallel-stepping worker threads, hence the
    /// `Mutex`: a lookup holds the lock for the whole cost call, so a
    /// shape is never computed twice and every replica observes the
    /// identical memoized value).
    coster: Arc<Mutex<BatchCoster<'a>>>,
    peak_macs_per_cycle: f64,
    reqs: Vec<Live>,
    ext_ids: Vec<usize>,
    queue: VecDeque<usize>,
    /// Intrusive running list (admission order: oldest first). Links are
    /// request-arena indices; `NONE` terminates.
    run_next: Vec<usize>,
    run_prev: Vec<usize>,
    run_head: usize,
    run_tail: usize,
    n_running: usize,
    /// Incrementally maintained front-end counters; `frontend_counters`
    /// cross-checks them against a full scan under `debug_assertions`.
    fc: FrontendCounters,
    /// Reusable per-step scratch (taken/restored around each use so the
    /// steady state never allocates).
    scratch_batch: Vec<(usize, Role)>,
    scratch_cost: Vec<Request>,
    scratch_ev: Vec<(usize, EventKind)>,
    /// Decode fast-forward scratch ([`Scheduler::try_fast_forward`]):
    /// the stretch's run-list-order request ids and their KV tail-block
    /// phase residues.
    stretch_ids: Vec<usize>,
    stretch_resid: Vec<u64>,
    /// Decode fast-forward switch: `COMPASS_COALESCE=0` (or
    /// [`Scheduler::set_coalescing`]`(false)`) forces the naive
    /// per-iteration loop, which is bitwise-identical by construction.
    coalesce: bool,
    clock: f64,
    trace: TraceBuffer,
    n_arrived: usize,
    done: usize,
    rejected: usize,
    preemptions: usize,
    energy: f64,
    ideal_cycles: f64,
    gen_tokens: u64,
    kv_transfer_tokens: u64,
    /// Requests extracted by the front-end rebalancer: they arrived
    /// here but finish elsewhere, so they count as resolved in the
    /// truncation accounting and are skipped by `finish`.
    migrated_out: usize,
    /// Requests killed by a crash ([`Scheduler::crash`]): resolved by
    /// the front end (retry or loss), so they too count as resolved
    /// here and are skipped by `finish`.
    failed: usize,
    /// Straggler window ([`Scheduler::set_slowdown`]): iterations
    /// starting before `slow_until_s` have their latency multiplied by
    /// `slow_mult`. The defaults (0.0, 1.0) never fire, and the
    /// multiplication is skipped entirely outside the window, so the
    /// no-fault arithmetic is bitwise-untouched.
    slow_until_s: f64,
    slow_mult: f64,
    truncated: bool,
    /// Telemetry sink ([`super::telemetry`]): `None` by default, so the
    /// untraced path does no recording work at all. Emissions happen
    /// strictly *after* each step's arithmetic, so attaching a sink
    /// never perturbs the simulation (bitwise anchor in
    /// `rust/tests/telemetry_properties.rs`).
    sink: Option<SharedSink>,
    /// This scheduler's replica index in the recorded trace.
    replica: usize,
}

impl<'a> Scheduler<'a> {
    pub fn new(model: &'a ModelSpec, hw: &'a HwConfig, cfg: &SimConfig) -> Self {
        let coster = Arc::new(Mutex::new(BatchCoster::new(
            model,
            hw,
            cfg.policy,
            cfg.eval_blocks,
            cfg.ctx_bucket,
            cfg.kv.dtype,
        )));
        Self::with_coster(model, hw, cfg, coster)
    }

    /// Build a scheduler on a shared cost memo: identical fleet replicas
    /// pass clones of one `Arc` so a batch shape simulated (or
    /// GA-searched, under `MappingPolicy::Searched`) on any replica is
    /// never re-costed on another — including replicas stepping
    /// concurrently on worker threads. `distinct_shapes` then reports
    /// the shared memo's size.
    pub fn with_coster(
        model: &'a ModelSpec,
        hw: &'a HwConfig,
        cfg: &SimConfig,
        coster: Arc<Mutex<BatchCoster<'a>>>,
    ) -> Self {
        Scheduler {
            cfg: *cfg,
            kv: KvCache::new(cfg.kv, cfg.kv_budget(model).max(2)),
            coster,
            peak_macs_per_cycle: (hw.num_chiplets() as f64) * (hw.class.macs() as f64),
            reqs: Vec::new(),
            ext_ids: Vec::new(),
            queue: VecDeque::new(),
            run_next: Vec::new(),
            run_prev: Vec::new(),
            run_head: NONE,
            run_tail: NONE,
            n_running: 0,
            fc: FrontendCounters::default(),
            scratch_batch: Vec::new(),
            scratch_cost: Vec::new(),
            scratch_ev: Vec::new(),
            stretch_ids: Vec::new(),
            stretch_resid: Vec::new(),
            coalesce: coalescing_enabled(),
            clock: 0.0,
            trace: TraceBuffer::new(cfg.trace_cap),
            n_arrived: 0,
            done: 0,
            rejected: 0,
            preemptions: 0,
            energy: 0.0,
            ideal_cycles: 0.0,
            gen_tokens: 0,
            kv_transfer_tokens: 0,
            migrated_out: 0,
            failed: 0,
            slow_until_s: 0.0,
            slow_mult: 1.0,
            truncated: false,
            sink: None,
            replica: 0,
        }
    }

    /// Attach a telemetry sink, reporting as replica `replica` in the
    /// recorded trace. Disabled sinks ([`super::telemetry::NullSink`])
    /// are dropped on the spot, so they cost exactly as much as never
    /// calling this.
    pub fn set_sink(&mut self, sink: SharedSink, replica: usize) {
        self.replica = replica;
        self.sink = if sink.lock().unwrap().enabled() {
            Some(sink)
        } else {
            None
        };
    }

    /// Swap the attached sink handle, returning the previous one. The
    /// parallel-stepping path uses this to stage each replica's
    /// emissions into a per-replica [`super::telemetry::BufferSink`]
    /// while worker threads run, then restore the real sink and replay
    /// the buffers in replica index order.
    pub(crate) fn swap_sink(&mut self, sink: Option<SharedSink>) -> Option<SharedSink> {
        std::mem::replace(&mut self.sink, sink)
    }

    fn emit(&self, t_s: f64, ext_id: usize, kind: EventKind) {
        if let Some(sink) = &self.sink {
            sink.lock().unwrap().event(self.replica, t_s, ext_id, kind);
        }
    }

    pub fn clock(&self) -> f64 {
        self.clock
    }

    pub fn truncated(&self) -> bool {
        self.truncated
    }

    /// Queued or admitted requests that still have work.
    pub fn has_work(&self) -> bool {
        !self.queue.is_empty() || self.n_running > 0
    }

    /// Whether [`Scheduler::advance_to`]`(t)` would run at least one
    /// iteration — the exact loop condition it tests. The fleet's
    /// parallel stepping uses this to count lagging replicas before
    /// deciding whether spawning worker threads is worth it.
    pub fn needs_advance(&self, t: f64) -> bool {
        !self.truncated && self.clock < t - 1e-12 && self.has_work()
    }

    /// Outstanding token work (queued context+output plus in-flight
    /// remainders): the join-shortest-queue routing signal. One of the
    /// [`Scheduler::frontend_counters`] counters — that single-pass
    /// snapshot is the one source of truth for all of them.
    pub fn backlog_tokens(&self) -> u64 {
        self.frontend_counters().backlog_tokens
    }

    /// Admission-queue depth (offered requests not yet admitted).
    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    /// Co-resident admitted requests.
    pub fn n_running(&self) -> usize {
        self.n_running
    }

    /// Admitted requests currently in their decode phase
    /// (see [`Scheduler::frontend_counters`]).
    pub fn n_decoding(&self) -> usize {
        self.frontend_counters().n_decoding
    }

    /// Admitted requests still prefilling
    /// (see [`Scheduler::frontend_counters`]).
    pub fn n_prefilling(&self) -> usize {
        self.frontend_counters().n_prefilling
    }

    /// Prompt tokens that must still be prefilled before every
    /// currently known request has emitted its first token: queued
    /// prompts plus in-flight prefill remainders. The front-end TTFT
    /// estimator's backlog signal (migrated requests materialize by
    /// transfer, so they contribute no prefill work). See
    /// [`Scheduler::frontend_counters`].
    pub fn pending_prefill_tokens(&self) -> u64 {
        self.frontend_counters().pending_prefill_tokens
    }

    /// Unallocated KV capacity in tokens (whole free blocks; the
    /// cache's own block size — it clamps oversized configs).
    pub fn kv_free_tokens(&self) -> u64 {
        self.kv.free_blocks() * self.kv.spec().block_tokens.max(1)
    }

    /// Time this replica has spent inside iterations so far (s) — the
    /// front-end rebalancer's load signal.
    pub fn busy_s(&self) -> f64 {
        self.trace.busy_s()
    }

    /// O(1) snapshot of the queue/running observation counters for
    /// front-end routing (the per-arrival × per-replica hot path).
    /// Maintained incrementally at every queue/running transition;
    /// under `debug_assertions` each call cross-checks the increments
    /// against the full traversal they replaced.
    pub fn frontend_counters(&self) -> FrontendCounters {
        debug_assert_eq!(
            self.fc,
            self.scan_counters(),
            "incremental front-end counters drifted from the full scan"
        );
        self.fc
    }

    /// The full queue + running traversal the incremental counters
    /// replaced; kept as the `debug_assertions` cross-check oracle.
    fn scan_counters(&self) -> FrontendCounters {
        let mut c = FrontendCounters::default();
        for &i in &self.queue {
            let r = &self.reqs[i];
            c.backlog_tokens += r.input_len + r.output_len;
            if !r.prefilled {
                c.pending_prefill_tokens += r.input_len;
            }
        }
        let mut i = self.run_head;
        while i != NONE {
            let r = &self.reqs[i];
            c.backlog_tokens +=
                (r.prefill_target - r.prefill_done) + r.output_len.saturating_sub(r.generated);
            c.pending_prefill_tokens += r.prefill_target.saturating_sub(r.prefill_done);
            if r.decoding() {
                c.n_decoding += 1;
            } else {
                c.n_prefilling += 1;
            }
            i = self.run_next[i];
        }
        c
    }

    /// What request `idx` contributes to the counters while queued
    /// (contributions depend only on immutable fields, so add/remove
    /// are exactly symmetric across a queue stay).
    fn fc_queue_add(&mut self, idx: usize) {
        let r = &self.reqs[idx];
        self.fc.backlog_tokens += r.input_len + r.output_len;
        if !r.prefilled {
            self.fc.pending_prefill_tokens += r.input_len;
        }
    }

    fn fc_queue_remove(&mut self, idx: usize) {
        let r = &self.reqs[idx];
        self.fc.backlog_tokens -= r.input_len + r.output_len;
        if !r.prefilled {
            self.fc.pending_prefill_tokens -= r.input_len;
        }
    }

    /// What request `idx` contributes to the counters while running;
    /// must be called with the fields it reads in their in-list state
    /// (i.e. before an eviction resets `prefill_done`).
    fn fc_run_add(&mut self, idx: usize) {
        let r = &self.reqs[idx];
        self.fc.backlog_tokens +=
            (r.prefill_target - r.prefill_done) + r.output_len.saturating_sub(r.generated);
        self.fc.pending_prefill_tokens += r.prefill_target.saturating_sub(r.prefill_done);
        if r.decoding() {
            self.fc.n_decoding += 1;
        } else {
            self.fc.n_prefilling += 1;
        }
    }

    fn fc_run_remove(&mut self, idx: usize) {
        let r = &self.reqs[idx];
        self.fc.backlog_tokens -=
            (r.prefill_target - r.prefill_done) + r.output_len.saturating_sub(r.generated);
        self.fc.pending_prefill_tokens -= r.prefill_target.saturating_sub(r.prefill_done);
        if r.decoding() {
            self.fc.n_decoding -= 1;
        } else {
            self.fc.n_prefilling -= 1;
        }
    }

    /// Append `idx` to the intrusive running list (admission order).
    fn run_push_back(&mut self, idx: usize) {
        self.run_next[idx] = NONE;
        self.run_prev[idx] = self.run_tail;
        if self.run_tail != NONE {
            self.run_next[self.run_tail] = idx;
        } else {
            self.run_head = idx;
        }
        self.run_tail = idx;
        self.n_running += 1;
    }

    /// Unlink `idx` from the running list in O(1). `idx` must be in the
    /// list; relative order of the remaining requests is untouched
    /// (exactly `Vec::remove`/`retain` semantics, without the shifts).
    fn run_unlink(&mut self, idx: usize) {
        let (p, n) = (self.run_prev[idx], self.run_next[idx]);
        if p != NONE {
            self.run_next[p] = n;
        } else {
            self.run_head = n;
        }
        if n != NONE {
            self.run_prev[n] = p;
        } else {
            self.run_tail = p;
        }
        self.run_prev[idx] = NONE;
        self.run_next[idx] = NONE;
        self.n_running -= 1;
    }

    /// Whether a migrated request with `context_len` resident tokens
    /// and `rest` outputs to decode could ever fit this replica's KV
    /// capacity — the same test `inject_migrated` applies. The
    /// rebalancer checks it on the destination *before* extracting,
    /// so a migration never converts into a rejection on a smaller
    /// heterogeneous replica.
    pub fn kv_can_ever_fit(&self, context_len: u64, rest: u64) -> bool {
        self.kv.can_ever_fit(context_len.max(1), rest.max(1))
    }

    /// The `(context_len, rest)` footprint that
    /// [`Scheduler::extract_youngest_decoding`] would migrate next,
    /// without extracting it.
    pub fn peek_youngest_decoding(&self) -> Option<(u64, u64)> {
        let idx = self.find_youngest_decoding()?;
        let r = &self.reqs[idx];
        Some((r.input_len + r.generated, r.output_len - r.generated))
    }

    /// Youngest-first (tail-to-head) scan for a mid-decode request.
    fn find_youngest_decoding(&self) -> Option<usize> {
        let mut i = self.run_tail;
        while i != NONE {
            let r = &self.reqs[i];
            if r.decoding() && r.generated >= 1 && r.generated < r.output_len {
                return Some(i);
            }
            i = self.run_prev[i];
        }
        None
    }

    /// Remove the youngest mid-decode request (first token emitted,
    /// output remaining) from the running set, releasing its KV blocks.
    /// The request vanishes from this replica's outcomes (`finish`
    /// skips it); the caller owns re-injection — typically
    /// [`Scheduler::inject_migrated`] on another replica, paying the
    /// block-granular KV handoff — and fleet-level outcome stitching.
    pub fn extract_youngest_decoding(&mut self) -> Option<ExtractedRequest> {
        let idx = self.find_youngest_decoding()?;
        self.run_unlink(idx);
        self.fc_run_remove(idx);
        self.kv.release(idx);
        let first_token_s = self.reqs[idx].first_token_s.unwrap_or(self.clock);
        let r = &mut self.reqs[idx];
        r.migrated_out = true;
        self.migrated_out += 1;
        self.emit(self.clock, self.ext_ids[idx], EventKind::MigrateOut);
        let r = &self.reqs[idx];
        Some(ExtractedRequest {
            ext_id: self.ext_ids[idx],
            arrival_s: r.arrival_s,
            input_len: r.input_len,
            output_len: r.output_len,
            first_token_s,
            context_len: r.input_len + r.generated,
            rest: r.output_len - r.generated,
        })
    }

    /// Crash this replica at time `t`: every queued or running request
    /// fails (returned for the front end to retry or count lost) and
    /// the KV cache is wiped wholesale — written blocks, reservation
    /// leases and the materialized shared prefix all vanish, so a
    /// recovered replica rejoins cold and its first admissions pay the
    /// prefix re-materialization again (the warm-up cost). Requests
    /// already resolved (completed, rejected, migrated out) are
    /// untouched. The clock only moves forward: an iteration that had
    /// already run past `t` stands — the crash takes effect at the
    /// next event boundary, keeping iteration atomicity.
    pub fn crash(&mut self, t: f64) -> Vec<FailedRequest> {
        self.clock = self.clock.max(t);
        let queued: Vec<usize> = self.queue.drain(..).collect();
        let mut running = Vec::with_capacity(self.n_running);
        let mut i = self.run_head;
        while i != NONE {
            running.push(i);
            i = self.run_next[i];
        }
        for &idx in &running {
            self.run_prev[idx] = NONE;
            self.run_next[idx] = NONE;
        }
        self.run_head = NONE;
        self.run_tail = NONE;
        self.n_running = 0;
        self.fc = FrontendCounters::default();
        let mut failed = Vec::with_capacity(queued.len() + running.len());
        for idx in queued.into_iter().chain(running) {
            let r = &mut self.reqs[idx];
            r.failed = true;
            self.failed += 1;
            failed.push(FailedRequest {
                ext_id: self.ext_ids[idx],
                input_len: r.input_len,
                output_len: r.output_len,
            });
        }
        // rebuild rather than release request-by-request: a crash also
        // loses the shared prefix blocks, which per-request release
        // would keep resident
        self.kv = KvCache::new(self.cfg.kv, self.kv.capacity_tokens().max(2));
        failed
    }

    /// Override the decode fast-forward switch (the default comes from
    /// the `COMPASS_COALESCE` environment variable at construction).
    /// `false` reproduces the naive per-iteration loop exactly; `true`
    /// coalesces quiescent decode stretches with bitwise-identical
    /// results (`rust/tests/coalesce_equivalence.rs`).
    pub fn set_coalescing(&mut self, on: bool) {
        self.coalesce = on;
    }

    /// Apply a straggler window: iterations *starting* before
    /// `until_s` have their costed latency multiplied by `factor`
    /// (clamped >= 1). Later calls override earlier ones; the default
    /// `(0.0, 1.0)` never fires.
    pub fn set_slowdown(&mut self, until_s: f64, factor: f64) {
        self.slow_until_s = until_s;
        self.slow_mult = factor.max(1.0);
    }

    /// Offer a request at `arrival_s` (must be called in nondecreasing
    /// arrival order once the clock has caught up; see `advance_to`).
    /// Requests that can never fit the KV capacity are rejected here.
    pub fn inject(&mut self, ext_id: usize, arrival_s: f64, input_len: u64, output_len: u64) {
        self.push_request(ext_id, arrival_s, input_len, output_len, false);
    }

    /// Offer a KV-migrated request (disaggregated decode pool): its
    /// `context_len` tokens of KV arrive over the fleet handoff link and
    /// materialize on admission without prefill compute; `output_len`
    /// counts only the tokens still to decode here (the first token was
    /// emitted by the prefill replica).
    pub fn inject_migrated(
        &mut self,
        ext_id: usize,
        arrival_s: f64,
        context_len: u64,
        output_len: u64,
    ) {
        self.push_request(ext_id, arrival_s, context_len, output_len, true);
    }

    fn push_request(
        &mut self,
        ext_id: usize,
        arrival_s: f64,
        input_len: u64,
        output_len: u64,
        prefilled: bool,
    ) {
        let (input_len, output_len) = (input_len.max(1), output_len.max(1));
        self.n_arrived += 1;
        let idx = self.reqs.len();
        let mut live = Live {
            arrival_s,
            input_len,
            output_len,
            prefill_target: input_len,
            prefill_done: 0,
            past_base: 0,
            generated: 0,
            first_token_s: None,
            finish_s: None,
            rejected: false,
            prefilled,
            migrated_out: false,
            failed: false,
        };
        if !self.kv.can_ever_fit(input_len, output_len) {
            // can never fit, even alone: explicit rejection
            live.rejected = true;
            self.rejected += 1;
            self.reqs.push(live);
            self.ext_ids.push(ext_id);
            self.run_next.push(NONE);
            self.run_prev.push(NONE);
            self.emit(arrival_s, ext_id, EventKind::Reject);
            return;
        }
        if !self.has_work() {
            // idle replica: the clock jumps to the arrival
            self.clock = self.clock.max(arrival_s);
        }
        self.reqs.push(live);
        self.ext_ids.push(ext_id);
        self.run_next.push(NONE);
        self.run_prev.push(NONE);
        self.queue.push_back(idx);
        self.fc_queue_add(idx);
        self.emit(
            arrival_s,
            ext_id,
            if prefilled {
                EventKind::MigrateIn
            } else {
                EventKind::Offer
            },
        );
    }

    /// Run iterations until the clock reaches `t` (or nothing is
    /// runnable / the iteration cap hits). Call before injecting a
    /// request arriving at `t` so admission happens at the first
    /// iteration boundary past the arrival, exactly as in the
    /// single-package driver.
    pub fn advance_to(&mut self, t: f64) {
        while !self.truncated && self.clock < t - 1e-12 && self.has_work() {
            // fast-forward a quiescent decode stretch when possible; an
            // inapplicable state (or any composition change) falls back
            // to one naive step and re-tests on the next pass
            if self.coalesce && self.try_fast_forward(t) {
                continue;
            }
            if !self.step() {
                break;
            }
        }
    }

    /// Drain all remaining work. Routed through [`Scheduler::advance_to`]
    /// with an unbounded horizon so the final drain fast-forwards decode
    /// stretches too; `step`'s own idle/cap exits terminate the loop
    /// exactly as the old direct `while step()` form did.
    pub fn run_to_end(&mut self) {
        self.advance_to(f64::INFINITY);
    }

    /// Mirror of the admission gates [`Scheduler::form_batch`] applies
    /// at the top of an iteration over a pure-decode running set:
    /// `true` means neither the migrated pre-pass nor the strategy arm
    /// can admit the queue head this iteration. Because every admission
    /// loop stops at its first inadmissible head, and free headroom net
    /// of decode growth only shrinks while a pure-decode stretch writes
    /// (iteration `j+1`'s free blocks are iteration `j`'s minus the
    /// growth it checked), a blocked head stays blocked until the next
    /// composition change — a finish or an eviction, both of which end
    /// the stretch. The prefix-sharing plan is untouched by decode
    /// writes, so `can_admit`'s lease planning is stable across the
    /// stretch too.
    fn admission_blocked(&self, growth: u64) -> bool {
        if self.n_running >= self.cfg.max_batch {
            return true;
        }
        let Some(&q) = self.queue.front() else {
            return true; // empty queue: nothing to admit
        };
        let r = &self.reqs[q];
        let need = r.context_needed();
        if r.prefilled {
            // the migrated-admission pre-pass gate (the strategy arms
            // all skip a migrated head)
            return !self.kv.can_admit_written(need, growth);
        }
        match self.cfg.strategy {
            // vLLM admits prompts without co-scheduled decode growth
            ServingStrategy::Vllm => !self.kv.can_admit(need, r.input_len, 0),
            ServingStrategy::Orca | ServingStrategy::ChunkedPrefill => {
                !self.kv.can_admit(need, r.input_len, growth)
            }
        }
    }

    /// Attempt one coalesced quiescent-decode stretch under horizon `t`.
    ///
    /// Returns `true` when at least one iteration executed (the
    /// `advance_to` loop then re-tests); `false` defers to the naive
    /// [`Scheduler::step`] without touching any state.
    ///
    /// Preconditions — each mirroring what `step` would establish this
    /// iteration: every running request is decoding (no chunked prefill
    /// in flight), this iteration's decode writes fit without eviction,
    /// and no admission is possible ([`Scheduler::admission_blocked`]).
    /// Under those, the batch composition — and with it the coster's
    /// quantized key and memoized [`super::coster::IterCost`] — is
    /// constant until the nearest finish or the first decode context to
    /// cross a `ctx_bucket` boundary, whichever comes first; that bound
    /// is the stretch length `k`. The composition is costed once and
    /// each of the (up to) `k` iterations replays the naive
    /// [`Scheduler::run_batch`] scalar tail operation for operation on
    /// the same f64 inputs (dt/slowdown branch, `end = clock + dt`,
    /// energy and ideal-cycle accumulation, KV gauges, trace and sink
    /// emissions), so coalesced results — metrics, per-request timings,
    /// counters, and trace bytes — are bitwise identical to naive
    /// stepping. The horizon, the `max_iterations` cap, and
    /// per-iteration KV pressure are re-checked before every replayed
    /// iteration exactly where the naive loop checks them, so the
    /// stretch never overshoots an external event and the cap truncates
    /// mid-stretch precisely where naive stepping would.
    fn try_fast_forward(&mut self, t: f64) -> bool {
        // pure-decode running set with at least one decoder
        if self.fc.n_prefilling != 0 || self.fc.n_decoding == 0 {
            return false;
        }
        // the naive step would truncate before running anything
        if self.trace.n_iters() >= self.cfg.max_iterations {
            return false;
        }
        // this iteration's decode writes must fit without eviction
        let growth = self.decode_growth();
        if !self.kv.fits_growth(growth) {
            return false;
        }
        if !self.admission_blocked(growth) {
            return false;
        }
        let _p = profile::scope("sched.fast_forward");

        // ---- stretch bounds: nearest finish, nearest bucket crossing --
        let bucket = self.cfg.ctx_bucket.max(1);
        let bt = self.kv.spec().block_tokens.max(1);
        let mut ids = std::mem::take(&mut self.stretch_ids);
        let mut resid = std::mem::take(&mut self.stretch_resid);
        let mut cost_batch = std::mem::take(&mut self.scratch_cost);
        ids.clear();
        resid.clear();
        cost_batch.clear();
        let mut k_finish = u64::MAX;
        let mut k_bucket = u64::MAX;
        let mut i = self.run_head;
        while i != NONE {
            let r = &self.reqs[i];
            debug_assert!(r.decoding(), "non-decoder in a pure-decode stretch");
            let ctx = r.context_needed();
            k_finish = k_finish.min(r.output_len - r.generated);
            // iterations until q(ctx) changes: reach the next multiple
            // of the bucket, plus one to step past it
            k_bucket = k_bucket.min(ctx.div_ceil(bucket) * bucket - ctx + 1);
            ids.push(i);
            resid.push(self.kv.decode_phase(i));
            cost_batch.push(Request::decode(ctx));
            i = self.run_next[i];
        }
        let k = k_finish.min(k_bucket);
        let n = ids.len();
        debug_assert_eq!(n, self.n_running, "stretch must cover the running set");

        // ---- cost the constant composition once; iterations 2..k are
        // the guaranteed local-memo hits the naive loop would have
        // issued, booked after the loop via note_replayed_hits ----
        let c = self.coster.lock().unwrap().cost(&cost_batch);
        self.scratch_cost = cost_batch;
        let dt_base = c.latency_cycles / CLOCK_HZ;
        let ideal_inc = c.macs as f64 / self.peak_macs_per_cycle;
        let n_running = self.n_running;
        let queue_depth = self.queue.len();
        let tracing = self.sink.is_some();

        let mut executed = 0u64;
        let mut synced = false;
        for j in 0..k {
            // this iteration's block growth from the tail-block phases:
            // sequence r allocates at j iff (resid_r + j) % bt == 0
            let phase = (bt - (j % bt)) % bt;
            let delta = resid.iter().filter(|&&p| p == phase).count() as u64;
            if j == 0 {
                debug_assert_eq!(delta, growth, "phase residues drifted from the rescan");
            } else {
                // the naive gate sequence between iterations, verbatim:
                // advance_to's horizon test, step's cap test, then the
                // KV-pressure test (an eviction would change the
                // composition, so the stretch ends there)
                if !(self.clock < t - 1e-12) {
                    break;
                }
                if self.trace.n_iters() >= self.cfg.max_iterations {
                    self.truncated = true;
                    break;
                }
                if !self.kv.fits_growth(delta) {
                    break;
                }
            }

            // --- run_batch's scalar tail, replayed operation for
            // operation on the same f64 inputs ---
            let mut dt = dt_base;
            if self.clock < self.slow_until_s {
                dt *= self.slow_mult;
            }
            let end = self.clock + dt;
            self.energy += c.energy_pj;
            self.ideal_cycles += ideal_inc;
            self.kv.bulk_decode_iter(delta, n as u64);
            self.gen_tokens += n as u64;
            self.fc.backlog_tokens -= n as u64;
            executed += 1;

            if j + 1 == k_finish {
                // the finishing iteration: sync per-sequence KV state
                // first (release reads it), then process finishers in
                // batch (run-list) order exactly like run_batch
                self.kv.finish_decode_stretch(&ids, executed);
                let mut ev = std::mem::take(&mut self.scratch_ev);
                ev.clear();
                for &idx in &ids {
                    let r = &mut self.reqs[idx];
                    r.generated += executed;
                    if r.generated >= r.output_len {
                        r.finish_s = Some(end);
                        self.done += 1;
                        self.kv.release(idx);
                        self.run_unlink(idx);
                        self.fc.n_decoding -= 1;
                        if tracing {
                            ev.push((self.ext_ids[idx], EventKind::Finish));
                        }
                    }
                }
                synced = true;
                self.trace.push(IterRecord {
                    start_s: self.clock,
                    end_s: end,
                    n_decode: n,
                    n_prefill: 0,
                    prefill_tokens: 0,
                    queue_depth,
                    kv_frac: self.kv.frac(),
                    kv_frag: self.kv.fragmentation(),
                    n_running,
                });
                if let Some(sink) = &self.sink {
                    let mut s = sink.lock().unwrap();
                    for &(ext, kind) in &ev {
                        s.event(self.replica, end, ext, kind);
                    }
                    s.iter(IterSpan {
                        replica: self.replica,
                        start_s: self.clock,
                        end_s: end,
                        n_prefill: 0,
                        n_decode: n,
                        queue_depth,
                        kv_frac: self.kv.frac(),
                        kv_frag: self.kv.fragmentation(),
                    });
                }
                self.scratch_ev = ev;
                self.clock = end;
                break; // the composition changes here: stretch over
            }

            // non-finishing iteration: no lifecycle events to emit
            self.trace.push(IterRecord {
                start_s: self.clock,
                end_s: end,
                n_decode: n,
                n_prefill: 0,
                prefill_tokens: 0,
                queue_depth,
                kv_frac: self.kv.frac(),
                kv_frag: self.kv.fragmentation(),
                n_running,
            });
            if let Some(sink) = &self.sink {
                let mut s = sink.lock().unwrap();
                s.iter(IterSpan {
                    replica: self.replica,
                    start_s: self.clock,
                    end_s: end,
                    n_prefill: 0,
                    n_decode: n,
                    queue_depth,
                    kv_frac: self.kv.frac(),
                    kv_frag: self.kv.fragmentation(),
                });
            }
            self.clock = end;
        }

        if !synced {
            // ended early (horizon / cap / KV pressure) or at a bucket
            // boundary: no finishes happened — just sync the deferred
            // per-sequence state
            self.kv.finish_decode_stretch(&ids, executed);
            for &idx in &ids {
                self.reqs[idx].generated += executed;
            }
        }
        // the naive loop would have issued one (local-hit) cost lookup
        // per replayed iteration
        if executed > 1 {
            self.coster
                .lock()
                .unwrap()
                .note_replayed_hits((executed - 1) as usize);
        }
        self.stretch_ids = ids;
        self.stretch_resid = resid;
        debug_assert!(executed >= 1, "a committed stretch always runs j = 0");
        true
    }

    /// KV blocks this iteration's decode writes would newly allocate.
    fn decode_growth(&self) -> u64 {
        let mut sum = 0;
        let mut i = self.run_head;
        while i != NONE {
            if self.reqs[i].decoding() {
                sum += self.kv.decode_growth_one(i);
            }
            i = self.run_next[i];
        }
        sum
    }

    /// Pick the preemption victim (never the list head: the oldest
    /// request keeps its cache so the system always progresses).
    fn pick_victim(&self) -> usize {
        match self.cfg.kv.eviction {
            EvictionPolicy::YoungestFirst => self.run_tail,
            EvictionPolicy::CostBased => {
                // lowest recompute loss: the non-oldest request whose
                // eviction discards the least already-invested work —
                // prefill tokens written this admission plus generated
                // tokens whose KV must be re-prefilled. (Not the full
                // re-admission context: a barely-started large prefill
                // owes its remaining tokens either way, so only the
                // written part counts. Ties go to the youngest,
                // matching the default policy: the tail-to-head walk
                // with a strict `<` visits youngest first, exactly the
                // old positional `(1..len).rev()` loop.)
                let mut best = self.run_tail;
                let mut best_loss = u64::MAX;
                let mut i = self.run_tail;
                while i != NONE && i != self.run_head {
                    let r = &self.reqs[i];
                    // migrated requests re-fetch over the handoff link
                    // instead of recomputing: zero compute loss
                    let loss = if r.prefilled {
                        0
                    } else {
                        r.prefill_done + r.generated
                    };
                    if loss < best_loss {
                        best_loss = loss;
                        best = i;
                    }
                    i = self.run_prev[i];
                }
                best
            }
        }
    }

    /// Evict one victim, returning the decode-write growth it was
    /// contributing (so the caller's KV-pressure loop can subtract it
    /// instead of rescanning: growth is per-request state, so releasing
    /// one request never changes another's contribution).
    fn evict_victim(&mut self) -> u64 {
        debug_assert!(self.n_running > 0, "eviction needs a running request");
        let victim = self.pick_victim();
        // measured before `release` — growth_one needs the live lease
        let growth = if self.reqs[victim].decoding() {
            self.kv.decode_growth_one(victim)
        } else {
            0
        };
        self.run_unlink(victim);
        self.fc_run_remove(victim);
        self.kv.release(victim);
        let r = &mut self.reqs[victim];
        r.prefill_done = 0;
        r.past_base = 0;
        self.queue.push_front(victim);
        self.fc_queue_add(victim);
        self.preemptions += 1;
        self.emit(self.clock, self.ext_ids[victim], EventKind::Preempt);
        growth
    }

    fn admit(&mut self, idx: usize) {
        // `idx` was just popped from the queue front: retire its queued
        // counter contribution before the admission mutates its fields
        self.fc_queue_remove(idx);
        let ctx = self.reqs[idx].context_needed();
        let migrated = self.reqs[idx].prefilled;
        if migrated {
            // KV materializes via the handoff transfer: no compute, the
            // context is resident. Whole blocks migrate, so the traffic
            // is block-rounded. Re-admission after a preemption
            // re-fetches instantaneously — a documented modeling
            // simplification (EXPERIMENTS.md "Fleet serving"): the
            // traffic is counted again in `kv_transfer_tokens`, but no
            // extra link latency is charged.
            let transferred = self.kv.admit_written(idx, ctx);
            self.kv_transfer_tokens += transferred;
            let r = &mut self.reqs[idx];
            r.prefill_target = ctx;
            r.prefill_done = ctx;
            r.past_base = 0;
            // the request's real first token was emitted on the prefill
            // replica; stamping admission time makes this replica's TTFT
            // the decode-pool queueing delay (arrival -> admission)
            if r.first_token_s.is_none() {
                r.first_token_s = Some(self.clock);
            }
        } else {
            let grant = self.kv.lease(idx, ctx, self.reqs[idx].input_len);
            let r = &mut self.reqs[idx];
            r.past_base = grant.skip;
            r.prefill_target = ctx - grant.skip;
            r.prefill_done = 0;
        }
        self.run_push_back(idx);
        self.fc_run_add(idx);
        self.emit(self.clock, self.ext_ids[idx], EventKind::Admit);
        if migrated {
            // the context materialized by transfer: a zero-length
            // prefill span, straight into decode
            self.emit(self.clock, self.ext_ids[idx], EventKind::PrefillDone);
        }
    }

    /// Run one scheduler iteration. Returns `false` when nothing is
    /// runnable (idle — inject more work or stop) or the iteration cap
    /// was hit (`truncated`).
    pub fn step(&mut self) -> bool {
        if self.truncated || !self.has_work() {
            return false;
        }
        if self.trace.n_iters() >= self.cfg.max_iterations {
            self.truncated = true; // safety valve
            return false;
        }
        let mut batch = std::mem::take(&mut self.scratch_batch);
        loop {
            // --- KV pressure: preempt per policy (never the oldest) so
            // the in-flight decodes can write this iteration's tokens
            // without consuming reserved prefill headroom. One scan,
            // then each eviction subtracts its victim's contribution ---
            let mut growth = self.decode_growth();
            while !self.kv.fits_growth(growth) && self.n_running > 1 {
                growth -= self.evict_victim();
            }
            debug_assert_eq!(
                growth,
                self.decode_growth(),
                "incremental eviction-loop growth drifted from the rescan"
            );

            batch.clear();
            self.form_batch(&mut batch, growth);
            if batch.is_empty() {
                // KV-blocked prefills with no runnable decode: free a
                // victim and retry (the oldest always keeps its cache,
                // so the system is guaranteed to make progress)
                if self.n_running > 1 {
                    self.evict_victim();
                    continue;
                }
                self.scratch_batch = batch;
                return false; // idle: the driver injects or stops
            }
            self.run_batch(&batch);
            self.scratch_batch = batch;
            return true;
        }
    }

    /// Compose this iteration's batch per the serving strategy into the
    /// caller's (reused) buffer. Admission headroom is the cache's free
    /// blocks: written and reserved (leased) blocks are both excluded,
    /// so admission can never invade the reservation of an in-flight
    /// chunked prefill.
    ///
    /// `growth` is the decode-write growth of the current running set —
    /// exactly what `decode_growth()` would rescan — carried over from
    /// the caller's KV-pressure loop and kept incremental through the
    /// migrated-admission pre-pass (an admitted migrated request is
    /// decoding, so its contribution joins the sum the strategy arms
    /// previously recomputed over the decoding set).
    fn form_batch(&mut self, batch: &mut Vec<(usize, Role)>, mut growth: u64) {
        // migrated requests (disaggregated decode pool) join the decode
        // set directly: admit before the strategy composes its batch.
        // Unlike prompt admission, the context is written immediately
        // *and* the admittee decodes this iteration, so the headroom
        // check must also cover every co-scheduled decode write.
        while self.n_running < self.cfg.max_batch {
            let Some(&q) = self.queue.front() else { break };
            if !self.reqs[q].prefilled {
                break;
            }
            let need = self.reqs[q].context_needed();
            if !self.kv.can_admit_written(need, growth) {
                break;
            }
            self.queue.pop_front();
            self.admit(q);
            // the admittee decodes this very iteration: its write joins
            // the co-scheduled growth (the pre-paging `writes += 1`)
            growth += self.kv.decode_growth_one(q);
        }
        debug_assert_eq!(
            growth,
            self.decode_growth(),
            "carried decode growth drifted from the rescan"
        );

        match self.cfg.strategy {
            ServingStrategy::Vllm => {
                while self.n_running < self.cfg.max_batch {
                    let Some(&q) = self.queue.front() else { break };
                    if self.reqs[q].prefilled {
                        break; // migrated: next iteration's pre-pass
                    }
                    let need = self.reqs[q].context_needed();
                    if !self.kv.can_admit(need, self.reqs[q].input_len, 0) {
                        break;
                    }
                    self.queue.pop_front();
                    self.admit(q);
                    batch.push((q, Role::Chunk(self.reqs[q].prefill_target)));
                }
                if batch.is_empty() {
                    // no admission happened, so the running set (and its
                    // decoding subset) is exactly the pre-arm state
                    let mut i = self.run_head;
                    while i != NONE {
                        if self.reqs[i].decoding() {
                            batch.push((i, Role::Decode));
                        }
                        i = self.run_next[i];
                    }
                }
            }
            ServingStrategy::Orca => {
                let mut i = self.run_head;
                while i != NONE {
                    if self.reqs[i].decoding() {
                        batch.push((i, Role::Decode));
                    }
                    i = self.run_next[i];
                }
                // this iteration's decode writes shrink the admission
                // headroom (the pre-paging `head -= |decoding|`); that
                // sum is `growth`, already in hand
                while self.n_running < self.cfg.max_batch {
                    let Some(&q) = self.queue.front() else { break };
                    if self.reqs[q].prefilled {
                        break; // migrated: next iteration's pre-pass
                    }
                    let need = self.reqs[q].context_needed();
                    if !self.kv.can_admit(need, self.reqs[q].input_len, growth) {
                        break;
                    }
                    self.queue.pop_front();
                    self.admit(q);
                    batch.push((q, Role::Chunk(self.reqs[q].prefill_target)));
                }
            }
            ServingStrategy::ChunkedPrefill => {
                let mut i = self.run_head;
                while i != NONE {
                    if self.reqs[i].decoding() {
                        batch.push((i, Role::Decode));
                    }
                    i = self.run_next[i];
                }
                let mut budget = self.cfg.chunk_tokens.max(1);
                // continue in-flight prefills first, admission order;
                // their tokens draw on the reservation leased at
                // admission, so headroom is guaranteed
                let mut i = self.run_head;
                while i != NONE {
                    if budget == 0 {
                        break;
                    }
                    if !self.reqs[i].decoding() {
                        let rem = self.reqs[i].prefill_target - self.reqs[i].prefill_done;
                        let t = rem.min(budget);
                        if t > 0 {
                            budget -= t;
                            batch.push((i, Role::Chunk(t)));
                        }
                    }
                    i = self.run_next[i];
                }
                // then admit new prompts; the admission leases their
                // full remaining context, so later chunks are
                // guaranteed to fit even across iterations
                while budget > 0 && self.n_running < self.cfg.max_batch {
                    let Some(&q) = self.queue.front() else { break };
                    if self.reqs[q].prefilled {
                        break; // migrated: next iteration's pre-pass
                    }
                    let need = self.reqs[q].context_needed();
                    if !self.kv.can_admit(need, self.reqs[q].input_len, growth) {
                        break;
                    }
                    self.queue.pop_front();
                    self.admit(q);
                    let t = self.reqs[q].prefill_target.min(budget);
                    budget -= t;
                    batch.push((q, Role::Chunk(t)));
                }
            }
        }
    }

    /// Cost the composed batch and apply its effects at completion time.
    fn run_batch(&mut self, batch: &[(usize, Role)]) {
        let _p = profile::scope("sched.run_batch");
        let n_running = self.n_running;
        let mut cost_batch = std::mem::take(&mut self.scratch_cost);
        cost_batch.clear();
        let mut n_prefill = 0usize;
        let mut prefill_tokens = 0u64;
        for &(i, role) in batch {
            match role {
                Role::Decode => {
                    cost_batch.push(Request::decode(self.reqs[i].context_needed()));
                }
                Role::Chunk(t) => {
                    n_prefill += 1;
                    prefill_tokens += t;
                    cost_batch.push(Request::Prefill {
                        len: t,
                        // shared-prefix skip plus already-written chunks:
                        // attention still spans the full context
                        past: self.reqs[i].past_base + self.reqs[i].prefill_done,
                    });
                }
            }
        }
        let n_decode = batch.len() - n_prefill;
        let c = self.coster.lock().unwrap().cost(&cost_batch);
        self.scratch_cost = cost_batch;
        let mut dt = c.latency_cycles / CLOCK_HZ;
        // straggler fault: stretch the iteration latency (energy is
        // unchanged — a throttled clock does the same work, slower).
        // Applied here, after costing, so the shared BatchCoster memo
        // never sees one replica's slowdown. Outside a window the
        // branch never fires, keeping the arithmetic bitwise-intact.
        if self.clock < self.slow_until_s {
            dt *= self.slow_mult;
        }
        let end = self.clock + dt;
        self.energy += c.energy_pj;
        self.ideal_cycles += c.macs as f64 / self.peak_macs_per_cycle;

        let tracing = self.sink.is_some();
        let mut ev = std::mem::take(&mut self.scratch_ev);
        ev.clear();
        for &(i, role) in batch {
            match role {
                Role::Decode => {
                    self.kv.write_decode(i);
                    let r = &mut self.reqs[i];
                    r.generated += 1;
                    let finished = r.generated >= r.output_len;
                    if finished {
                        r.finish_s = Some(end);
                    }
                    self.gen_tokens += 1;
                    // a running decode always has generated < output_len
                    // before the write (it would have finished already
                    // otherwise), so the remainder shrinks by exactly 1
                    self.fc.backlog_tokens -= 1;
                    if finished {
                        self.done += 1;
                        self.kv.release(i);
                        self.run_unlink(i);
                        self.fc.n_decoding -= 1;
                        if tracing {
                            ev.push((self.ext_ids[i], EventKind::Finish));
                        }
                    }
                }
                Role::Chunk(t) => {
                    self.kv.write_chunk(i, t);
                    let r = &mut self.reqs[i];
                    let crossed = r.prefill_done < r.prefill_target;
                    r.prefill_done += t;
                    let crossed = crossed && r.prefill_done >= r.prefill_target;
                    // chunk sizes never overshoot the target, so both
                    // prefill remainders shrink by exactly t
                    self.fc.backlog_tokens -= t;
                    self.fc.pending_prefill_tokens -= t;
                    if crossed {
                        self.fc.n_prefilling -= 1;
                        self.fc.n_decoding += 1;
                    }
                    if tracing {
                        ev.push((self.ext_ids[i], EventKind::Chunk { tokens: t }));
                        // re-admitted (preempted) requests re-cross the
                        // target without re-emitting a first token, but
                        // the span still flips back to decode
                        if crossed {
                            ev.push((self.ext_ids[i], EventKind::PrefillDone));
                        }
                    }
                    let r = &mut self.reqs[i];
                    if r.prefill_done >= r.prefill_target && r.first_token_s.is_none() {
                        // prefill completion emits the first output token
                        r.first_token_s = Some(end);
                        r.generated += 1;
                        let finished = r.generated >= r.output_len;
                        if finished {
                            r.finish_s = Some(end);
                        }
                        self.gen_tokens += 1;
                        self.fc.backlog_tokens -= 1;
                        if tracing {
                            ev.push((self.ext_ids[i], EventKind::FirstToken));
                        }
                        if finished {
                            self.done += 1;
                            self.kv.release(i);
                            self.run_unlink(i);
                            self.fc.n_decoding -= 1;
                            if tracing {
                                ev.push((self.ext_ids[i], EventKind::Finish));
                            }
                        }
                    }
                }
            }
        }
        self.trace.push(IterRecord {
            start_s: self.clock,
            end_s: end,
            n_decode,
            n_prefill,
            prefill_tokens,
            queue_depth: self.queue.len(),
            kv_frac: self.kv.frac(),
            kv_frag: self.kv.fragmentation(),
            n_running,
        });
        if let Some(sink) = &self.sink {
            let mut s = sink.lock().unwrap();
            for &(ext, kind) in &ev {
                s.event(self.replica, end, ext, kind);
            }
            s.iter(IterSpan {
                replica: self.replica,
                start_s: self.clock,
                end_s: end,
                n_prefill,
                n_decode,
                queue_depth: self.queue.len(),
                kv_frac: self.kv.frac(),
                kv_frag: self.kv.fragmentation(),
            });
        }
        self.scratch_ev = ev;
        self.clock = end;
    }

    /// Close the run and aggregate metrics + per-request outcomes.
    /// Requests extracted by the front-end rebalancer finish on another
    /// replica, so they are skipped here (the fleet stitches their
    /// timings from the extraction record plus the final holder);
    /// crash-failed requests are skipped the same way (the fleet's
    /// retry path owns their final outcome).
    pub fn finish(self) -> ReplicaResult {
        let _p = profile::scope("sched.finish");
        if let Some(sink) = &self.sink {
            let mut s = sink.lock().unwrap();
            let r = self.replica;
            s.counter_set(&format!("r{r}.n_arrived"), self.n_arrived as f64);
            s.counter_set(&format!("r{r}.completed"), self.done as f64);
            s.counter_set(&format!("r{r}.rejected"), self.rejected as f64);
            s.counter_set(&format!("r{r}.preemptions"), self.preemptions as f64);
            s.counter_set(&format!("r{r}.gen_tokens"), self.gen_tokens as f64);
            s.counter_set(
                &format!("r{r}.kv_transfer_tokens"),
                self.kv_transfer_tokens as f64,
            );
            s.counter_set(&format!("r{r}.kv_frac"), self.kv.frac());
            // the memo may be shared fleet-wide; each replica overwrites
            // with the totals it sees, so the last finisher reports the
            // run-wide numbers (counter_set, not counter_add)
            let c = self.coster.lock().unwrap();
            s.counter_set("coster.lookups", c.lookups() as f64);
            s.counter_set("coster.distinct_shapes", c.distinct_shapes() as f64);
            s.counter_set("coster.memo_hits", c.hits() as f64);
        }
        let outcomes: Vec<(usize, RequestOutcome)> = self
            .ext_ids
            .iter()
            .zip(&self.reqs)
            .filter(|(_, r)| !r.migrated_out && !r.failed)
            .map(|(&ext, r)| {
                (
                    ext,
                    RequestOutcome {
                        arrival_s: r.arrival_s,
                        input_len: r.input_len,
                        output_len: r.output_len,
                        first_token_s: r.first_token_s,
                        finish_s: r.finish_s,
                        rejected: r.rejected,
                    },
                )
            })
            .collect();
        let raw: Vec<RequestOutcome> = outcomes.iter().map(|&(_, o)| o).collect();
        let metrics = finalize(
            &raw,
            self.trace,
            &RunTotals {
                slo: self.cfg.slo,
                max_batch: self.cfg.max_batch,
                makespan_s: self.clock,
                energy_pj: self.energy,
                ideal_cycles: self.ideal_cycles,
                gen_tokens: self.gen_tokens,
                n_preemptions: self.preemptions,
                distinct_shapes: self.coster.lock().unwrap().distinct_shapes(),
                kv_transfer_tokens: self.kv_transfer_tokens,
                kv_capacity_tokens: self.kv.capacity_tokens(),
                kv_shared_tokens: self.kv.shared_tokens(),
                kv_demand_tokens: self.kv.demand_tokens(),
                kv_prefix_materializations: self.kv.prefix_materializations(),
                truncated: self.truncated
                    || self.done + self.rejected + self.migrated_out + self.failed
                        < self.n_arrived,
            },
        );
        ReplicaResult { metrics, outcomes }
    }
}

/// Replay `stream` on `(model, hw)` under `cfg` and aggregate serving
/// metrics. Deterministic: identical inputs give bit-identical output.
/// (A single-replica fleet runs this exact driver, so `simulate_fleet`
/// with one replica is bitwise-equal to `simulate_serving`.)
pub fn simulate_serving(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
) -> ServingMetrics {
    let mut s = Scheduler::new(model, hw, cfg);
    for r in &stream.requests {
        s.advance_to(r.arrival_s);
        s.inject(r.id, r.arrival_s, r.input_len, r.output_len);
    }
    s.run_to_end();
    s.finish().metrics
}

/// [`simulate_serving`] with a telemetry sink attached (replica 0).
/// Metrics are bitwise-identical to the untraced run — recording
/// happens after each step's arithmetic and never feeds back.
pub fn simulate_serving_traced(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    cfg: &SimConfig,
    sink: &SharedSink,
) -> ServingMetrics {
    let mut s = Scheduler::new(model, hw, cfg);
    s.set_sink(sink.clone(), 0);
    for r in &stream.requests {
        s.advance_to(r.arrival_s);
        s.inject(r.id, r.arrival_s, r.input_len, r.output_len);
    }
    s.run_to_end();
    s.finish().metrics
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::{ChipletClass, Dataflow};
    use crate::sim::coster::MappingPolicy;
    use crate::sim::kv::{EvictionPolicy, KvDtype, KvSpec};
    use crate::sim::metrics::SloSpec;
    use crate::sim::stream::TimedRequest;
    use crate::workload::trace::TraceSpec;

    fn tiny_spec() -> TraceSpec {
        TraceSpec {
            mean_in: 48.0,
            mean_out: 8.0,
            sigma_in: 0.4,
            sigma_out: 0.3,
            max_len: 4096,
            shared_prefix_tokens: 0,
        }
    }

    fn tiny_hw() -> HwConfig {
        HwConfig::homogeneous(
            2,
            2,
            ChipletClass::S,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        )
    }

    fn tiny_cfg(strategy: ServingStrategy) -> SimConfig {
        SimConfig {
            strategy,
            policy: MappingPolicy::Pipeline,
            max_batch: 8,
            chunk_tokens: 32,
            kv_budget_tokens: 4096,
            dram_gb: 1.0,
            ctx_bucket: 32,
            eval_blocks: 1,
            slo: SloSpec::new(1.0, 0.5),
            max_iterations: 200_000,
            trace_cap: 0,
            kv: KvSpec::token_granular(),
        }
    }

    fn run(strategy: ServingStrategy, rate_scale: f64, kv_tokens: u64) -> ServingMetrics {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(strategy);
        cfg.kv_budget_tokens = kv_tokens;
        let probe = crate::sim::probe(&model, &hw, &cfg, &tiny_spec());
        let stream = RequestStream::poisson(
            &tiny_spec(),
            probe.capacity_rps() * rate_scale,
            12,
            5,
        );
        simulate_serving(&stream, &model, &hw, &cfg)
    }

    /// A hand-built stream (already sorted by arrival time).
    fn fixed_stream(reqs: &[(f64, u64, u64)]) -> RequestStream {
        RequestStream {
            name: "fixed".into(),
            requests: reqs
                .iter()
                .enumerate()
                .map(|(id, &(arrival_s, input_len, output_len))| TimedRequest {
                    id,
                    arrival_s,
                    input_len,
                    output_len,
                })
                .collect(),
            rate_rps: 1.0,
            seed: 0,
        }
    }

    #[test]
    fn fast_forward_engages_and_matches_naive_bitwise() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::ChunkedPrefill);
        cfg.ctx_bucket = 64;
        let stream = fixed_stream(&[(0.0, 8, 100)]);
        let mut naive = Scheduler::new(&model, &hw, &cfg);
        naive.set_coalescing(false);
        let mut fast = Scheduler::new(&model, &hw, &cfg);
        fast.set_coalescing(true);
        for s in [&mut naive, &mut fast] {
            for r in &stream.requests {
                s.advance_to(r.arrival_s);
                s.inject(r.id, r.arrival_s, r.input_len, r.output_len);
            }
        }
        // one chunked-prefill iteration completes the prompt and emits
        // the first token; the remaining decode stretch is quiescent
        assert!(naive.step());
        assert!(fast.step());
        let before = fast.trace.n_iters();
        assert!(
            fast.try_fast_forward(f64::INFINITY),
            "quiescent decode stretch must engage the fast-forward"
        );
        let coalesced = fast.trace.n_iters() - before;
        // ctx = 9 after the first token, bucket 64: the stretch runs to
        // the bucket crossing (64 - 9 + 1 iterations) in one call
        assert!(coalesced > 1, "only {coalesced} iterations coalesced");
        naive.run_to_end();
        fast.run_to_end();
        assert_eq!(naive.clock().to_bits(), fast.clock().to_bits());
        assert_eq!(naive.trace.n_iters(), fast.trace.n_iters());
        // replayed-hit booking keeps the coster counters identical
        {
            let (nc, fc) = (naive.coster.lock().unwrap(), fast.coster.lock().unwrap());
            assert_eq!(nc.lookups(), fc.lookups());
            assert_eq!(nc.hits(), fc.hits());
            assert_eq!(nc.distinct_shapes(), fc.distinct_shapes());
        }
        let (a, b) = (naive.finish().metrics, fast.finish().metrics);
        assert_eq!(a.n_iterations, b.n_iterations);
        assert_eq!(a.gen_tokens, b.gen_tokens);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits());
    }

    #[test]
    fn all_strategies_complete_all_requests() {
        for strategy in ServingStrategy::ALL {
            let m = run(strategy, 0.8, 4096);
            assert_eq!(m.n_completed + m.n_rejected, m.n_arrived, "{strategy:?}");
            assert_eq!(m.n_rejected, 0, "{strategy:?}");
            assert_eq!(m.n_in_flight, 0, "{strategy:?}");
            assert!(m.throughput_tps > 0.0);
            assert!(m.ttft.n == m.n_completed);
            // token-granular cache: no block waste, no sharing
            assert_eq!(m.kv_fragmentation, 0.0, "{strategy:?}");
            assert_eq!(m.kv_shared_tokens, 0, "{strategy:?}");
            assert!(m.effective_concurrency > 0.0, "{strategy:?}");
        }
    }

    #[test]
    fn vllm_never_mixes_prefill_and_decode() {
        let m = run(ServingStrategy::Vllm, 1.2, 4096);
        for it in &m.iters {
            assert!(
                it.n_prefill == 0 || it.n_decode == 0,
                "mixed batch at t={}",
                it.start_s
            );
        }
    }

    #[test]
    fn orca_and_chunked_do_mix() {
        for strategy in [ServingStrategy::Orca, ServingStrategy::ChunkedPrefill] {
            let m = run(strategy, 1.2, 4096);
            assert!(
                m.iters.iter().any(|it| it.n_prefill > 0 && it.n_decode > 0),
                "{strategy:?} never mixed"
            );
        }
    }

    #[test]
    fn chunked_respects_chunk_budget() {
        let m = run(ServingStrategy::ChunkedPrefill, 1.0, 4096);
        for it in &m.iters {
            assert!(it.prefill_tokens <= 32, "chunk {}", it.prefill_tokens);
        }
    }

    #[test]
    fn tight_kv_budget_rejects_or_preempts_but_conserves() {
        let m = run(ServingStrategy::Orca, 1.0, 150);
        assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
        // tight budget must visibly constrain the run
        assert!(m.n_rejected > 0 || m.n_preemptions > 0 || m.max_queue_depth > 0);
        for it in &m.iters {
            assert!(it.kv_frac <= 1.0 + 1e-9, "kv over budget: {}", it.kv_frac);
        }
    }

    #[test]
    fn clock_is_monotone_and_iters_ordered() {
        let m = run(ServingStrategy::ChunkedPrefill, 1.3, 1024);
        for it in &m.iters {
            assert!(it.end_s >= it.start_s);
        }
        for w in m.iters.windows(2) {
            assert!(w[1].start_s >= w[0].start_s - 1e-12);
        }
        assert!(m.makespan_s >= m.iters.last().map_or(0.0, |i| i.end_s) - 1e-12);
    }

    /// Regression (PR 3): under ChunkedPrefill, the admission of request
    /// B must not steal the KV headroom reserved for request A's
    /// later chunks. Pre-fix, `head` was recomputed each iteration from
    /// `kv_used` (written tokens only), so the reservation evaporated
    /// after the admitting iteration: with a 100-token budget, A
    /// (60-token prompt) was admitted, then B (60-token prompt) was
    /// admitted one chunk later into headroom A still needed — forcing
    /// spurious preemption/recompute cycles. Post-fix (now via the
    /// cache's reservation leases), B waits and the run completes with
    /// zero preemptions.
    #[test]
    fn chunked_reservation_survives_across_iterations() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::ChunkedPrefill);
        cfg.kv_budget_tokens = 100;
        cfg.chunk_tokens = 16; // A's 60-token prefill takes 4 iterations
        let stream = fixed_stream(&[(0.0, 60, 4), (1e-6, 60, 4)]);
        let m = simulate_serving(&stream, &model, &hw, &cfg);
        assert_eq!(m.n_completed, 2);
        assert_eq!(m.n_rejected, 0);
        assert_eq!(
            m.n_preemptions, 0,
            "admission stole reserved chunked-prefill headroom"
        );
        for it in &m.iters {
            assert!(it.kv_frac <= 1.0 + 1e-9);
        }
    }

    /// Regression (this PR): an eviction landing mid-chunked-prefill
    /// releases both the written blocks and the outstanding lease. The
    /// pre-refactor scalar path computed that release with raw `-=` on
    /// `u64` (`kv_used -= kv_held; kv_reserved -= target - done`), which
    /// wraps silently in release builds if the two counters ever drift;
    /// the KvCache does it with checked ops, so this sequence either
    /// conserves exactly or panics loudly.
    #[test]
    fn eviction_during_chunked_prefill_keeps_checked_accounting() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::ChunkedPrefill);
        // A (40-token prompt) prefills, then B (75 tokens) is admitted
        // into the remaining headroom; A's decode writes force KV
        // pressure while B's chunked prefill is still in flight, so the
        // eviction releases a partially-realized lease
        cfg.kv_budget_tokens = 120;
        cfg.chunk_tokens = 8; // long in-flight prefills
        cfg.max_batch = 4;
        let stream = fixed_stream(&[(0.0, 40, 30), (1e-6, 75, 20), (2e-6, 40, 30)]);
        let m = simulate_serving(&stream, &model, &hw, &cfg);
        assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
        assert!(!m.truncated);
        assert!(
            m.n_preemptions > 0,
            "sequence must exercise eviction during chunked prefill"
        );
        for it in &m.iters {
            assert!(it.kv_frac <= 1.0 + 1e-9);
        }
    }

    /// Mixed queues (normal + migrated requests on one scheduler) keep
    /// KV accounting sane: the strategy admission loops defer migrated
    /// requests to the dedicated pre-pass instead of treating them as
    /// prompts (which would double-count their context and underflow
    /// the reservation accounting).
    #[test]
    fn mixed_normal_and_migrated_queue_conserves() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        for strategy in ServingStrategy::ALL {
            let mut cfg = tiny_cfg(strategy);
            cfg.kv_budget_tokens = 256;
            let mut s = Scheduler::new(&model, &hw, &cfg);
            s.inject(0, 0.0, 60, 4);
            s.inject_migrated(1, 0.0, 60, 4);
            s.inject(2, 0.0, 40, 3);
            s.inject_migrated(3, 0.0, 40, 3);
            s.run_to_end();
            let r = s.finish();
            assert_eq!(r.metrics.n_completed, 4, "{strategy:?}");
            assert!(!r.metrics.truncated, "{strategy:?}");
            for it in &r.metrics.iters {
                assert!(it.kv_frac <= 1.0 + 1e-9, "{strategy:?} kv {}", it.kv_frac);
            }
        }
    }

    /// Paged blocks conserve and report fragmentation; every strategy
    /// completes under a coarse block size.
    #[test]
    fn paged_blocks_conserve_across_strategies() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        for strategy in ServingStrategy::ALL {
            let mut cfg = tiny_cfg(strategy);
            cfg.kv_budget_tokens = 1024;
            cfg.kv = KvSpec::paged(16);
            let stream = fixed_stream(&[(0.0, 50, 6), (1e-6, 33, 9), (2e-6, 70, 4)]);
            let m = simulate_serving(&stream, &model, &hw, &cfg);
            assert_eq!(m.n_completed, 3, "{strategy:?}");
            assert!(!m.truncated, "{strategy:?}");
            assert!(
                m.kv_fragmentation > 0.0,
                "{strategy:?}: 16-token blocks on odd lengths must waste slots"
            );
            for it in &m.iters {
                assert!(it.kv_frac <= 1.0 + 1e-9, "{strategy:?}");
                assert!(it.kv_frag >= 0.0 && it.kv_frag <= 1.0, "{strategy:?}");
            }
        }
    }

    /// Prefix sharing: with a shared system prompt in the trace, later
    /// admissions skip the prefix (sharing hits), total prefill compute
    /// drops, and the run still conserves.
    #[test]
    fn prefix_sharing_skips_prefill_and_conserves() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::ChunkedPrefill);
        cfg.kv_budget_tokens = 2048;
        let stream = fixed_stream(&[(0.0, 80, 4), (1e-6, 90, 4), (2e-6, 85, 4)]);

        cfg.kv = KvSpec::paged(8).with_prefix(64);
        let shared = simulate_serving(&stream, &model, &hw, &cfg);
        assert_eq!(shared.n_completed, 3);
        assert!(!shared.truncated);
        // first request materializes (no skip), the other two hit
        assert_eq!(shared.kv_prefix_materializations, 1);
        assert_eq!(shared.kv_shared_tokens, 2 * 64);
        assert!(shared.kv_sharing_hit_rate > 0.0);

        // sharing off on the same stream: same completions, zero hits,
        // and at least as many prefill tokens scheduled
        cfg.kv = KvSpec::paged(8);
        let private = simulate_serving(&stream, &model, &hw, &cfg);
        assert_eq!(private.n_completed, 3);
        assert_eq!(private.kv_shared_tokens, 0);
        let toks = |m: &ServingMetrics| m.iters.iter().map(|i| i.prefill_tokens).sum::<u64>();
        assert!(
            toks(&shared) + 2 * 64 <= toks(&private),
            "sharing must cut prefill work by the skipped prefix tokens"
        );
    }

    /// `prefix_tokens = 0` must run the exact sharing-off code path.
    #[test]
    fn zero_prefix_is_identical_to_sharing_off() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::Orca);
        cfg.kv = KvSpec::paged(4);
        let stream = fixed_stream(&[(0.0, 50, 6), (1e-6, 33, 9)]);
        let a = simulate_serving(&stream, &model, &hw, &cfg);
        cfg.kv = KvSpec::paged(4).with_prefix(0);
        let b = simulate_serving(&stream, &model, &hw, &cfg);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits());
        assert_eq!(a.n_iterations, b.n_iterations);
    }

    /// Cost-based eviction preempts the cheapest-to-recompute victim:
    /// the run completes, conserves, and (on a stream engineered with
    /// one short and one long co-resident request) recomputes no more
    /// prefill tokens than youngest-first.
    #[test]
    fn cost_based_eviction_conserves_and_recomputes_less() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mk = |eviction: EvictionPolicy| {
            let mut cfg = tiny_cfg(ServingStrategy::Orca);
            cfg.kv_budget_tokens = 200;
            cfg.kv = KvSpec::token_granular().with_eviction(eviction);
            // A (90) + B (30) + C (60) co-resident; decode growth forces
            // exactly one preemption: youngest-first evicts C (67-token
            // recompute), cost-based evicts B (37 tokens)
            let stream = fixed_stream(&[(0.0, 90, 12), (1e-6, 30, 12), (2e-6, 60, 12)]);
            simulate_serving(&stream, &model, &hw, &cfg)
        };
        let yf = mk(EvictionPolicy::YoungestFirst);
        let cb = mk(EvictionPolicy::CostBased);
        for m in [&yf, &cb] {
            assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
            assert!(!m.truncated);
        }
        let prefill_toks =
            |m: &ServingMetrics| m.iters.iter().map(|i| i.prefill_tokens).sum::<u64>();
        assert!(
            prefill_toks(&cb) <= prefill_toks(&yf),
            "cost-based eviction recomputed more prefill than youngest-first ({} > {})",
            prefill_toks(&cb),
            prefill_toks(&yf)
        );
    }

    /// Quantized cache dtypes raise the DRAM-derived token capacity, so
    /// an int4 cache sustains a tight workload with fewer preemptions
    /// and rejections than fp16 on the same DRAM.
    #[test]
    fn quantized_dtype_raises_capacity_under_fixed_dram() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::Orca);
        cfg.kv_budget_tokens = 0; // derive from DRAM bytes
        cfg.dram_gb = 160.0 * model.kv_bytes_per_token() as f64 / 1e9; // ~160 fp16 tokens
        let stream = fixed_stream(&[(0.0, 60, 20), (1e-6, 60, 20), (2e-6, 60, 20)]);
        let fp16 = simulate_serving(&stream, &model, &hw, &cfg);
        cfg.kv = KvSpec::token_granular().with_dtype(KvDtype::Int4);
        let int4 = simulate_serving(&stream, &model, &hw, &cfg);
        // floor(bytes/per_tok) at 4x-smaller per_tok is >= 4x the tokens
        assert!(int4.kv_capacity_tokens >= 4 * fp16.kv_capacity_tokens);
        assert!(fp16.kv_capacity_tokens >= 150, "budget sizing drifted");
        assert_eq!(int4.n_completed + int4.n_rejected, int4.n_arrived);
        assert!(
            int4.n_rejected + int4.n_preemptions <= fp16.n_rejected + fp16.n_preemptions,
            "4x capacity must not increase KV pressure"
        );
    }

    /// The occupancy trace stays bounded on long runs while the exact
    /// iteration count keeps counting, and the plot still renders.
    #[test]
    fn long_run_trace_stays_bounded() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::Orca);
        cfg.trace_cap = 32;
        let probe = crate::sim::probe(&model, &hw, &cfg, &tiny_spec());
        let stream =
            RequestStream::poisson(&tiny_spec(), probe.capacity_rps() * 0.8, 48, 11);
        let m = simulate_serving(&stream, &model, &hw, &cfg);
        assert!(
            m.n_iterations > 64,
            "run too short to exercise the cap ({} iters)",
            m.n_iterations
        );
        assert!(
            m.iters.len() < 64,
            "trace not downsampled: {} records",
            m.iters.len()
        );
        let plot = crate::report::ascii_occupancy(&m.iters, cfg.max_batch, 48);
        assert!(plot.contains("batch |"));
        // uncapped run over the same stream agrees on the exact metrics
        cfg.trace_cap = 0;
        let full = simulate_serving(&stream, &model, &hw, &cfg);
        assert_eq!(full.n_iterations, m.n_iterations);
        assert_eq!(full.makespan_s.to_bits(), m.makespan_s.to_bits());
        assert_eq!(full.mean_queue_depth.to_bits(), m.mean_queue_depth.to_bits());
        assert_eq!(full.busy_s.to_bits(), m.busy_s.to_bits());
    }

    /// Crashing a replica fails its queued + running requests, wipes
    /// the cache (shared prefix included), and keeps the truncation
    /// accounting consistent: failed requests count as resolved and
    /// vanish from the outcomes, and the replica serves fresh work
    /// afterwards from a cold cache.
    #[test]
    fn crash_fails_inflight_wipes_kv_and_serves_again() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let mut cfg = tiny_cfg(ServingStrategy::ChunkedPrefill);
        cfg.kv = KvSpec::paged(8).with_prefix(32);
        cfg.kv_budget_tokens = 1024;
        let mut s = Scheduler::new(&model, &hw, &cfg);
        s.inject(0, 0.0, 60, 8);
        s.inject(1, 1e-6, 50, 8);
        s.inject(2, 2e-6, 40, 8);
        // run partway: some prefill/decode work happens, prefix resident
        for _ in 0..4 {
            s.step();
        }
        let t = s.clock();
        let failed = s.crash(t);
        assert!(!failed.is_empty(), "in-flight work must fail at the crash");
        assert!(!s.has_work(), "crash must empty queue and running set");
        assert_eq!(
            s.kv_free_tokens(),
            1024,
            "crash must wipe the whole cache, prefix blocks included"
        );
        // cold rejoin: new work admits, re-materializes the prefix, runs
        s.inject(3, t + 1.0, 60, 4);
        s.run_to_end();
        let r = s.finish();
        assert!(!r.metrics.truncated, "failed requests must count as resolved");
        assert_eq!(r.outcomes.len(), 1, "failed requests vanish from outcomes");
        assert_eq!(r.outcomes[0].0, 3);
        assert!(r.outcomes[0].1.finish_s.is_some());
        // the rebuilt cache counts from zero, so a count of 1 proves the
        // prefix was re-materialized from scratch after the crash
        assert_eq!(
            r.metrics.kv_prefix_materializations, 1,
            "recovered replica must re-materialize the shared prefix"
        );
    }

    /// A straggler window stretches exactly the iterations that start
    /// inside it, and a `(0, 1)` (default) window is bitwise-free.
    #[test]
    fn slowdown_window_stretches_latency_not_energy() {
        let model = ModelSpec::tiny();
        let hw = tiny_hw();
        let cfg = tiny_cfg(ServingStrategy::Orca);
        // both arrivals at t = 0 so the batch compositions are identical
        // regardless of how far the slowdown stretches each iteration
        let stream = fixed_stream(&[(0.0, 60, 12), (0.0, 50, 12)]);
        let run = |slow: Option<(f64, f64)>| {
            let mut s = Scheduler::new(&model, &hw, &cfg);
            if let Some((until, mult)) = slow {
                s.set_slowdown(until, mult);
            }
            for r in &stream.requests {
                s.advance_to(r.arrival_s);
                s.inject(r.id, r.arrival_s, r.input_len, r.output_len);
            }
            s.run_to_end();
            s.finish().metrics
        };
        let base = run(None);
        let noop = run(Some((0.0, 1.0)));
        assert_eq!(base.makespan_s.to_bits(), noop.makespan_s.to_bits());
        assert_eq!(base.energy_pj.to_bits(), noop.energy_pj.to_bits());
        let slowed = run(Some((f64::INFINITY, 3.0)));
        assert!(
            slowed.makespan_s > 2.5 * base.makespan_s,
            "3x window over the whole run must stretch the makespan ~3x \
             ({} vs {})",
            slowed.makespan_s,
            base.makespan_s
        );
        assert_eq!(
            slowed.energy_pj.to_bits(),
            base.energy_pj.to_bits(),
            "throttling stretches time, not work"
        );
        assert_eq!(slowed.n_completed, base.n_completed);
    }
}

"""L2 GP fit / EI graphs vs a plain-numpy reference implementation."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels.ref import rbf_gram_ref


def numpy_gp(k, y, noise):
    """Dense-numpy reference GP fit (no masking)."""
    n = k.shape[0]
    km = k + np.eye(n) * (noise + 1e-6)
    chol = np.linalg.cholesky(km)
    alpha = np.linalg.solve(km, y)
    logdet = 2.0 * np.log(np.diag(chol)).sum()
    mll = -0.5 * y @ alpha - 0.5 * logdet - 0.5 * n * np.log(2 * np.pi)
    return alpha, chol, mll


def make_problem(n_act, n_pad, d=3, seed=0):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=(n_act, d)).astype(np.float32)
    y = np.sin(x).sum(1).astype(np.float32)
    ils = np.full(d, 0.8, np.float32)
    k_act = np.asarray(rbf_gram_ref(jnp.asarray(x), jnp.asarray(x), jnp.asarray(ils)))
    n = n_act + n_pad
    k = rng.normal(size=(n, n)).astype(np.float32)  # junk outside active block
    k = k @ k.T  # keep symmetric junk
    k[:n_act, :n_act] = k_act
    yy = rng.normal(size=n).astype(np.float32)
    yy[:n_act] = y
    mask = np.zeros(n, np.float32)
    mask[:n_act] = 1.0
    return x, k, yy, mask, k_act, y


@settings(max_examples=10, deadline=None)
@given(n_act=st.integers(2, 12), n_pad=st.integers(0, 8), seed=st.integers(0, 999))
def test_masked_fit_matches_numpy_on_active_block(n_act, n_pad, seed):
    _, k, y, mask, k_act, y_act = make_problem(n_act, n_pad, seed=seed)
    noise = 0.01
    alpha, chol, mll = model.gp_fit(
        jnp.asarray(k), jnp.asarray(y), jnp.asarray(mask), jnp.float32(noise)
    )
    ref_alpha, _, ref_mll = numpy_gp(k_act.astype(np.float64), y_act, noise)
    np.testing.assert_allclose(np.asarray(alpha)[:n_act], ref_alpha, rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(alpha)[n_act:], 0.0, atol=1e-6)
    assert float(mll) == pytest.approx(ref_mll, rel=2e-3, abs=2e-2)


def test_padding_is_inert():
    """Adding masked rows must not change alpha/mll of the active block."""
    _, k0, y0, m0, _, _ = make_problem(8, 0, seed=3)
    _, k1, y1, m1, _, _ = make_problem(8, 6, seed=3)
    a0, _, mll0 = model.gp_fit(jnp.asarray(k0), jnp.asarray(y0), jnp.asarray(m0), jnp.float32(0.05))
    a1, _, mll1 = model.gp_fit(jnp.asarray(k1), jnp.asarray(y1), jnp.asarray(m1), jnp.float32(0.05))
    np.testing.assert_allclose(np.asarray(a0)[:8], np.asarray(a1)[:8], rtol=1e-4)
    assert float(mll0) == pytest.approx(float(mll1), rel=1e-4)


def test_posterior_interpolates_training_points():
    """With tiny noise, posterior mean at train inputs ~= train targets."""
    rng = np.random.default_rng(5)
    d, n = 2, 10
    x = rng.normal(size=(n, d)).astype(np.float32)
    y = (x**2).sum(1).astype(np.float32)
    ils = np.full(d, 1.0, np.float32)
    k = np.asarray(rbf_gram_ref(jnp.asarray(x), jnp.asarray(x), jnp.asarray(ils)))
    mask = np.ones(n, np.float32)
    alpha, chol, _ = model.gp_fit(
        jnp.asarray(k), jnp.asarray(y), jnp.asarray(mask), jnp.float32(1e-5)
    )
    mean, var, ei = model.gp_ei(
        jnp.asarray(k),  # k_cross = train-vs-train
        jnp.asarray(np.diag(k)),
        chol,
        alpha,
        jnp.asarray(mask),
        jnp.float32(float(y.min())),
    )
    np.testing.assert_allclose(np.asarray(mean), y, rtol=5e-2, atol=5e-2)
    assert (np.asarray(var) < 1e-2).all()
    # EI at noiseless training points is ~0 (no expected improvement)
    assert (np.asarray(ei) < 1e-2).all()


def test_ei_properties():
    """EI >= 0; further-from-incumbent means with equal var -> lower EI."""
    n, q = 6, 4
    rng = np.random.default_rng(7)
    x = rng.normal(size=(n, 2)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    ils = np.ones(2, np.float32)
    k = np.asarray(rbf_gram_ref(jnp.asarray(x), jnp.asarray(x), jnp.asarray(ils)))
    mask = np.ones(n, np.float32)
    alpha, chol, _ = model.gp_fit(
        jnp.asarray(k), jnp.asarray(y), jnp.asarray(mask), jnp.float32(0.01)
    )
    xq = rng.normal(size=(q, 2)).astype(np.float32)
    kc = np.asarray(rbf_gram_ref(jnp.asarray(xq), jnp.asarray(x), jnp.asarray(ils)))
    mean, var, ei = model.gp_ei(
        jnp.asarray(kc),
        jnp.ones(q, jnp.float32),
        chol,
        alpha,
        jnp.asarray(mask),
        jnp.float32(float(y.min())),
    )
    assert (np.asarray(ei) >= 0).all()
    assert (np.asarray(var) > 0).all()


def test_ei_monotone_in_incumbent():
    """A worse incumbent (higher f_best for minimisation) raises EI."""
    n = 5
    rng = np.random.default_rng(9)
    x = rng.normal(size=(n, 2)).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    ils = np.ones(2, np.float32)
    k = np.asarray(rbf_gram_ref(jnp.asarray(x), jnp.asarray(x), jnp.asarray(ils)))
    mask = np.ones(n, np.float32)
    alpha, chol, _ = model.gp_fit(
        jnp.asarray(k), jnp.asarray(y), jnp.asarray(mask), jnp.float32(0.05)
    )
    xq = rng.normal(size=(3, 2)).astype(np.float32)
    kc = jnp.asarray(np.asarray(rbf_gram_ref(jnp.asarray(xq), jnp.asarray(x), jnp.asarray(ils))))
    kd = jnp.ones(3, jnp.float32)
    _, _, ei_lo = model.gp_ei(kc, kd, chol, alpha, jnp.asarray(mask), jnp.float32(-1.0))
    _, _, ei_hi = model.gp_ei(kc, kd, chol, alpha, jnp.asarray(mask), jnp.float32(1.0))
    assert (np.asarray(ei_hi) >= np.asarray(ei_lo) - 1e-7).all()


def test_composite_gram_combines_terms():
    """Eq. 2: composite = rbf * (1 + shape indicator) * sigma2 * layout."""
    from compile.kernels.ref import (
        composite_gram_ref,
        manhattan_weights_ref,
    )

    rng = np.random.default_rng(11)
    q, n, d, s, t = 4, 4, 3, 9, 2
    xs = rng.normal(size=(q, d)).astype(np.float32)
    ys = rng.normal(size=(n, d)).astype(np.float32)
    ils = np.full(d, 0.5, np.float32)
    a = np.zeros((q, s, t), np.float32)
    b = np.zeros((n, s, t), np.float32)
    for i in range(q):
        a[i, np.arange(s), rng.integers(0, t, s)] = 1.0
    for i in range(n):
        b[i, np.arange(s), rng.integers(0, t, s)] = 1.0
    coords = np.array([(x_, y_) for y_ in range(3) for x_ in range(3)], np.float32)
    w = np.asarray(manhattan_weights_ref(jnp.asarray(coords), 2.0))
    sa = np.tile(np.array([[3.0, 3.0]], np.float32), (q, 1))
    sb = np.tile(np.array([[3.0, 3.0]], np.float32), (n, 1))
    sb[2] = [1.0, 9.0]  # different array dims -> indicator 1 not 2
    got = model.composite_gram(
        *map(jnp.asarray, (xs, ys, ils, a, b, w, sa, sb)), jnp.float32(1.7)
    )[0]
    want = composite_gram_ref(
        *map(jnp.asarray, (xs, ys, ils, a, b, w, sa, sb)), 1.7
    )
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)

//! Multi-chiplet accelerator hardware template (paper §III-B, Fig. 3).
//!
//! A package integrates an `H x W` grid of compute chiplets (possibly
//! heterogeneous in dataflow), interconnected by a mesh NoP with XY
//! routing; edge chiplets reach IO dies that bridge to off-package DRAM
//! chips placed on the left/right package edges (paper: 4 DRAM chips).

pub mod constants;


use constants::*;

/// Dataflow microarchitecture of a compute chiplet (paper Table IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dataflow {
    /// Weight-stationary: weights resident in the PE array, inputs stream,
    /// partial sums reduced in-array + accumulator buffer.
    WeightStationary,
    /// Output-stationary: partial sums resident in PE registers, weights
    /// and inputs both stream through the array.
    OutputStationary,
}

impl Dataflow {
    pub const ALL: [Dataflow; 2] = [Dataflow::WeightStationary, Dataflow::OutputStationary];

    pub fn short(&self) -> &'static str {
        match self {
            Dataflow::WeightStationary => "WS",
            Dataflow::OutputStationary => "OS",
        }
    }
}

/// Compute-capacity point from the pre-built chiplet library (Table IV).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChipletClass {
    /// 1K MACs, 2 MiB GLB (32x32 array)
    S,
    /// 4K MACs, 8 MiB GLB (64x64 array)
    M,
    /// 16K MACs, 32 MiB GLB (128x128 array)
    L,
}

impl ChipletClass {
    pub const ALL: [ChipletClass; 3] = [ChipletClass::S, ChipletClass::M, ChipletClass::L];

    pub fn short(&self) -> &'static str {
        match self {
            ChipletClass::S => "S",
            ChipletClass::M => "M",
            ChipletClass::L => "L",
        }
    }

    /// MAC units per chiplet (also MACs per cycle at full utilization).
    pub fn macs(&self) -> u64 {
        match self {
            ChipletClass::S => 1 << 10,
            ChipletClass::M => 1 << 12,
            ChipletClass::L => 1 << 14,
        }
    }

    /// Square PE-array side (`macs = side * side`).
    pub fn array_side(&self) -> u64 {
        match self {
            ChipletClass::S => 32,
            ChipletClass::M => 64,
            ChipletClass::L => 128,
        }
    }

    /// Global-buffer capacity in bytes.
    pub fn glb_bytes(&self) -> u64 {
        match self {
            ChipletClass::S => 2 << 20,
            ChipletClass::M => 8 << 20,
            ChipletClass::L => 32 << 20,
        }
    }

    /// Peak TOPS at `CLOCK_HZ` (2 ops per MAC).
    pub fn tops(&self) -> f64 {
        2.0 * self.macs() as f64 * CLOCK_HZ / 1e12
    }

    /// Chiplets needed to reach `target_tops` total compute.
    pub fn chiplets_for(&self, target_tops: f64) -> usize {
        (target_tops / self.tops()).round().max(1.0) as usize
    }

    /// Silicon area of one chiplet with this class' MACs + GLB,
    /// excluding the NoP-bandwidth-dependent term.
    pub fn base_area_mm2(&self) -> f64 {
        self.macs() as f64 * A_MAC_MM2
            + (self.glb_bytes() as f64 / (1 << 20) as f64) * A_SRAM_MM2_PER_MIB
            + A_OTHERS_MM2
    }
}

/// One compute chiplet instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Chiplet {
    pub class: ChipletClass,
    pub dataflow: Dataflow,
}

/// Full hardware configuration: the joint tensor `Z = [z_sys, z_shape,
/// z_layout]` of the hardware sampling engine (paper §V-B), plus the
/// searched system parameters of Table IV.
#[derive(Debug, Clone, PartialEq)]
pub struct HwConfig {
    /// Grid height (z_shape.H).
    pub grid_h: usize,
    /// Grid width (z_shape.W).
    pub grid_w: usize,
    /// Uniform compute-capacity class of every chiplet (z_shape).
    pub class: ChipletClass,
    /// Per-slot dataflow assignment, row-major (z_layout).
    pub layout: Vec<Dataflow>,
    /// NoP link bandwidth, GB/s (z_sys).
    pub nop_bw_gbs: f64,
    /// Bandwidth per DRAM chip, GB/s (z_sys).
    pub dram_bw_gbs: f64,
    /// Micro-batch size used when instantiating prefill workloads (z_sys).
    pub micro_batch_prefill: usize,
    /// Micro-batch size for decode workloads (z_sys).
    pub micro_batch_decode: usize,
    /// Number of partitions for FFN layers (tensor parallelism, z_sys).
    pub tensor_parallel: usize,
}

impl HwConfig {
    /// Homogeneous configuration helper.
    pub fn homogeneous(
        grid_h: usize,
        grid_w: usize,
        class: ChipletClass,
        dataflow: Dataflow,
        nop_bw_gbs: f64,
        dram_bw_gbs: f64,
    ) -> Self {
        HwConfig {
            grid_h,
            grid_w,
            class,
            layout: vec![dataflow; grid_h * grid_w],
            nop_bw_gbs,
            dram_bw_gbs,
            micro_batch_prefill: 4,
            micro_batch_decode: 64,
            tensor_parallel: 8,
        }
    }

    pub fn num_chiplets(&self) -> usize {
        self.grid_h * self.grid_w
    }

    pub fn chiplet(&self, idx: usize) -> Chiplet {
        Chiplet {
            class: self.class,
            dataflow: self.layout[idx],
        }
    }

    /// (x, y) grid coordinate of chiplet `idx` (row-major).
    pub fn coord(&self, idx: usize) -> (usize, usize) {
        (idx % self.grid_w, idx / self.grid_w)
    }

    /// Manhattan hop count between two chiplets under XY mesh routing.
    pub fn hops(&self, from: usize, to: usize) -> u64 {
        let (x0, y0) = self.coord(from);
        let (x1, y1) = self.coord(to);
        (x0.abs_diff(x1) + y0.abs_diff(y1)) as u64
    }

    /// DRAM chips sit on the left/right package edges, split evenly
    /// top/bottom (paper: 4 chips). Returns hop count from a chiplet to
    /// the package-edge port of DRAM chip `dram_id`.
    pub fn dram_hops(&self, chip: usize, dram_id: usize) -> u64 {
        let (x, y) = self.coord(chip);
        let half = (NUM_DRAM_CHIPS / 2).max(1);
        let slot = dram_id % NUM_DRAM_CHIPS;
        let left = slot < half;
        // port row: distribute DRAM chips across the grid height
        let band = self.grid_h.max(1).div_ceil(half);
        let port_y = ((slot % half) * band + band / 2).min(self.grid_h.saturating_sub(1));
        let x_hops = if left { x + 1 } else { self.grid_w - x };
        (x_hops + y.abs_diff(port_y)) as u64
    }

    /// Nearest DRAM chip for a chiplet (used when the mapping does not
    /// pin a layer to a specific DRAM id).
    pub fn nearest_dram(&self, chip: usize) -> usize {
        (0..NUM_DRAM_CHIPS)
            .min_by_key(|&d| self.dram_hops(chip, d))
            .unwrap_or(0)
    }

    pub fn total_tops(&self) -> f64 {
        self.class.tops() * self.num_chiplets() as f64
    }

    pub fn count_dataflow(&self, df: Dataflow) -> usize {
        self.layout.iter().filter(|&&d| d == df).count()
    }

    /// One-line description for reports.
    pub fn describe(&self) -> String {
        format!(
            "{}x{} {} | WS={} OS={} | NoP={}GB/s DRAM={}GB/s | mbp={} mbd={} tp={}",
            self.grid_h,
            self.grid_w,
            self.class.short(),
            self.count_dataflow(Dataflow::WeightStationary),
            self.count_dataflow(Dataflow::OutputStationary),
            self.nop_bw_gbs,
            self.dram_bw_gbs,
            self.micro_batch_prefill,
            self.micro_batch_decode,
            self.tensor_parallel,
        )
    }
}

/// Candidate values for the searched hardware parameters (paper Table IV).
#[derive(Debug, Clone)]
pub struct HwSpace {
    pub classes: Vec<ChipletClass>,
    pub dataflows: Vec<Dataflow>,
    pub nop_bw_gbs: Vec<f64>,
    pub dram_bw_gbs: Vec<f64>,
    pub micro_batch_prefill: Vec<usize>,
    pub micro_batch_decode: Vec<usize>,
    pub tensor_parallel: Vec<usize>,
    /// Total compute target (TOPS); fixes chiplet count per class.
    pub target_tops: f64,
    /// Upper bound on chiplets (rules out impractical S-chip seas).
    pub max_chiplets: usize,
}

impl HwSpace {
    /// The paper's Table-IV space at a given compute target.
    pub fn paper(target_tops: f64) -> Self {
        HwSpace {
            classes: ChipletClass::ALL.to_vec(),
            dataflows: Dataflow::ALL.to_vec(),
            nop_bw_gbs: vec![32.0, 64.0, 128.0, 256.0, 512.0],
            dram_bw_gbs: vec![16.0, 32.0, 64.0, 128.0, 256.0],
            micro_batch_prefill: vec![1, 2, 4],
            micro_batch_decode: vec![1, 2, 4, 8, 16, 32, 64, 128],
            tensor_parallel: vec![4, 8, 16, 32, 64],
            target_tops,
            max_chiplets: 256,
        }
    }

    /// Grid dimensions (H, W) for `n` chiplets: the most-square
    /// factorization, favouring wider-than-tall (DRAM on left/right).
    pub fn grid_dims(n: usize) -> (usize, usize) {
        let mut best = (1, n);
        let mut best_gap = usize::MAX;
        for h in 1..=n {
            if n % h != 0 {
                continue;
            }
            let w = n / h;
            let gap = h.abs_diff(w);
            if h <= w && gap < best_gap {
                best_gap = gap;
                best = (h, w);
            }
        }
        best
    }

    /// Classes that satisfy `target_tops` within `max_chiplets`.
    pub fn feasible_classes(&self) -> Vec<ChipletClass> {
        self.classes
            .iter()
            .copied()
            .filter(|c| c.chiplets_for(self.target_tops) <= self.max_chiplets)
            .collect()
    }

    /// A representative fixed configuration for a compute target: the
    /// largest feasible chiplet class (fewest chiplets), a near-square
    /// grid, median Table-IV bandwidths. Used when a study (or the
    /// fleet DSE's non-searched pool) needs *a* sensible package at a
    /// TOPS share rather than a searched one.
    pub fn representative(target_tops: f64) -> HwConfig {
        let space = HwSpace::paper(target_tops);
        let class = space
            .feasible_classes()
            .last()
            .copied()
            .unwrap_or(ChipletClass::L);
        let n = class.chiplets_for(target_tops);
        let (h, w) = HwSpace::grid_dims(n);
        HwConfig::homogeneous(h, w, class, Dataflow::WeightStationary, 128.0, 64.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_parameters_match_table_iv() {
        assert_eq!(ChipletClass::S.macs(), 1024);
        assert_eq!(ChipletClass::M.macs(), 4096);
        assert_eq!(ChipletClass::L.macs(), 16384);
        assert_eq!(ChipletClass::S.glb_bytes(), 2 * 1024 * 1024);
        assert_eq!(ChipletClass::M.glb_bytes(), 8 * 1024 * 1024);
        assert_eq!(ChipletClass::L.glb_bytes(), 32 * 1024 * 1024);
    }

    #[test]
    fn tops_and_chiplet_counts() {
        // L chiplet: 16K MACs * 2 ops * 1 GHz = 32.768 TOPS
        assert!((ChipletClass::L.tops() - 32.768).abs() < 1e-9);
        // 2048 TOPS needs 62.5 -> 63-ish L chiplets; rounds to 63
        assert_eq!(ChipletClass::L.chiplets_for(2048.0), 63);
        assert_eq!(ChipletClass::M.chiplets_for(64.0), 8);
        assert_eq!(ChipletClass::S.chiplets_for(64.0), 31);
    }

    #[test]
    fn grid_dims_near_square() {
        assert_eq!(HwSpace::grid_dims(8), (2, 4));
        assert_eq!(HwSpace::grid_dims(16), (4, 4));
        assert_eq!(HwSpace::grid_dims(63), (7, 9));
        assert_eq!(HwSpace::grid_dims(1), (1, 1));
    }

    #[test]
    fn xy_hops_are_manhattan() {
        let hw = HwConfig::homogeneous(
            4,
            4,
            ChipletClass::M,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        assert_eq!(hw.hops(0, 0), 0);
        assert_eq!(hw.hops(0, 3), 3); // same row
        assert_eq!(hw.hops(0, 15), 6); // corner to corner
        assert_eq!(hw.hops(5, 10), 2);
    }

    #[test]
    fn dram_ports_on_edges() {
        let hw = HwConfig::homogeneous(
            4,
            4,
            ChipletClass::M,
            Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        // chip 0 is top-left: DRAM 0 (left, upper band) must be closest
        assert_eq!(hw.nearest_dram(0), 0);
        // chip 15 bottom-right: a right-side DRAM must be nearest
        assert!(hw.nearest_dram(15) >= 2);
        // all hops positive (off-package access always crosses an edge)
        for c in 0..16 {
            for d in 0..4 {
                assert!(hw.dram_hops(c, d) >= 1);
            }
        }
    }

    #[test]
    fn feasible_classes_respect_cap() {
        let mut space = HwSpace::paper(2048.0);
        space.max_chiplets = 256;
        let feas = space.feasible_classes();
        // S would need 1000 chiplets at 2048 TOPS -> excluded
        assert!(!feas.contains(&ChipletClass::S));
        assert!(feas.contains(&ChipletClass::M));
        assert!(feas.contains(&ChipletClass::L));
    }

    #[test]
    fn describe_mentions_counts() {
        let hw = HwConfig::homogeneous(
            2,
            4,
            ChipletClass::L,
            Dataflow::OutputStationary,
            64.0,
            32.0,
        );
        let d = hw.describe();
        assert!(d.contains("OS=8") && d.contains("WS=0"));
    }
}

//! Top-level co-exploration driver (paper Fig. 6): ties the workload
//! instantiation, the GA mapping generation engine, the BO hardware
//! sampling engine, and the evaluation engine into the loop
//!
//!   hardware sample -> mapping search -> (L, E, MC) -> surrogate update
//!
//! `compass_dse` is the framework entrypoint; `search_mappings` is the
//! inner mapping search reused by the baselines and benches.

use crate::arch::{HwConfig, HwSpace};
use crate::bo::{self, BoConfig, Gp};
use crate::cost::{group_params, EvalResult, Evaluator, MappingEvaluator};
use crate::ga::{self, GaConfig};
use crate::mapping::Mapping;
use crate::sim::{
    self, FleetConfig, FleetMetrics, KvSpec, MappingPolicy, RequestStream, RouterPolicy,
    ServingMetrics, SimConfig,
};
use crate::workload::serving::Scenario;
use crate::workload::{build_workload, ModelSpec};

/// Full co-exploration budget.
#[derive(Debug, Clone, Copy)]
pub struct DseConfig {
    pub ga: GaConfig,
    pub bo: BoConfig,
    /// Transformer blocks instantiated explicitly (0 = full depth).
    pub eval_blocks: usize,
}

impl DseConfig {
    pub fn reduced() -> Self {
        DseConfig {
            ga: GaConfig::reduced(),
            bo: BoConfig::reduced(),
            eval_blocks: 2,
        }
    }

    pub fn paper() -> Self {
        DseConfig {
            ga: GaConfig::paper(),
            bo: BoConfig::paper(),
            eval_blocks: 4,
        }
    }

    pub fn tiny() -> Self {
        DseConfig {
            ga: GaConfig::tiny(),
            bo: BoConfig::tiny(),
            eval_blocks: 1,
        }
    }
}

/// Outcome of a co-exploration run.
#[derive(Debug, Clone)]
pub struct DseOutcome {
    pub hw: HwConfig,
    pub mappings: Vec<Mapping>,
    pub eval: EvalResult,
    /// Best-objective trajectory over BO rounds.
    pub bo_history: Vec<f64>,
    pub backend: &'static str,
}

/// Mapping-search result for a fixed hardware configuration.
#[derive(Debug, Clone)]
pub struct MappingSearch {
    pub mappings: Vec<Mapping>,
    pub eval: EvalResult,
}

/// Run the GA mapping search for every batch group of `scenario` on
/// hardware `hw`, then evaluate the scenario end-to-end.
///
/// Each group's search runs through a [`MappingEvaluator`]: the
/// search-invariant workload state is prepared once, generations are
/// scored batch-parallel across threads, and duplicate individuals hit
/// the fitness memo (EXPERIMENTS.md #Perf). Results are bit-identical to
/// the serial closure path for a given seed.
pub fn search_mappings(
    scenario: &Scenario,
    model: &ModelSpec,
    hw: &HwConfig,
    ga_cfg: &GaConfig,
    eval_blocks: usize,
) -> MappingSearch {
    let ev = Evaluator::new();
    let chips = hw.num_chiplets();
    let mut mappings = Vec::with_capacity(scenario.groups.len());
    for (gi, group) in scenario.groups.iter().enumerate() {
        let params = group_params(hw, group.has_prefill, eval_blocks);
        let w = build_workload(model, &group.batch, &params);
        let rows = w.num_micro_batches();
        let cols = w.layers_per_mb;
        let mut cfg = *ga_cfg;
        cfg.seed = ga_cfg.seed.wrapping_add(gi as u64);
        let res = ga::search(rows, cols, chips, &cfg, &MappingEvaluator::new(&w, hw));
        mappings.push(res.best);
    }
    let eval = ev.eval_scenario(scenario, model, hw, &mappings, eval_blocks);
    MappingSearch { mappings, eval }
}

/// The Compass framework: BO over hardware, GA over mappings, the
/// evaluation engine inside. `gp` selects the surrogate backend
/// (PJRT artifacts or the native mirror).
pub fn compass_dse(
    scenario: &Scenario,
    model: &ModelSpec,
    space: &HwSpace,
    cfg: &DseConfig,
    gp: &mut dyn Gp,
) -> DseOutcome {
    let result = bo::optimize(space, &cfg.bo, gp, |hw| {
        search_mappings(scenario, model, hw, &cfg.ga, cfg.eval_blocks)
            .eval
            .total_cost()
    });
    // re-derive the winning mappings for reporting
    let best = search_mappings(scenario, model, &result.best.hw, &cfg.ga, cfg.eval_blocks);
    DseOutcome {
        hw: result.best.hw.clone(),
        mappings: best.mappings,
        eval: best.eval,
        bo_history: result.history,
        backend: result.backend,
    }
}

/// Outcome of a serving-simulator-backed co-exploration run.
#[derive(Debug, Clone)]
pub struct ServingDseOutcome {
    pub hw: HwConfig,
    pub metrics: ServingMetrics,
    /// Best-objective trajectory over BO rounds (negated SLO-constrained
    /// goodput; lower is better).
    pub bo_history: Vec<f64>,
    pub backend: &'static str,
}

/// Sim-backed mapping search for a fixed hardware configuration: replay
/// `stream` through the continuous-batching scheduler with a GA mapping
/// search per distinct batch shape (`MappingPolicy::Searched`, memoized
/// so each shape is searched exactly once), and return the resulting
/// serving metrics. The dynamic counterpart of [`search_mappings`].
pub fn search_serving(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    ga_cfg: &GaConfig,
    sim_cfg: &SimConfig,
) -> ServingMetrics {
    let cfg = sim_cfg.with_policy(MappingPolicy::Searched(*ga_cfg));
    sim::simulate_serving(stream, model, hw, &cfg)
}

/// Compass with the time-domain objective (paper north star: serving
/// quality, not static-group latency): BO over hardware, GA over
/// per-shape mappings, the serving simulator inside. Maximizes
/// SLO-constrained goodput via [`ServingMetrics::objective`].
pub fn compass_dse_serving(
    stream: &RequestStream,
    model: &ModelSpec,
    space: &HwSpace,
    cfg: &DseConfig,
    sim_cfg: &SimConfig,
    gp: &mut dyn Gp,
) -> ServingDseOutcome {
    let result = bo::optimize(space, &cfg.bo, gp, |hw| {
        search_serving(stream, model, hw, &cfg.ga, sim_cfg).objective()
    });
    let metrics = search_serving(stream, model, &result.best.hw, &cfg.ga, sim_cfg);
    ServingDseOutcome {
        hw: result.best.hw.clone(),
        metrics,
        bo_history: result.history,
        backend: result.backend,
    }
}

/// Sweep KV-cache layouts (block size x dtype x sharing x eviction) on
/// fixed hardware, scoring each by the serving objective, and return
/// the winner plus every candidate's metrics. The KV analogue of the
/// shape loop in [`compass_dse_fleet`]: capacity-side design choices
/// change which configurations win before any hardware is re-searched.
pub fn search_kv(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    sim_cfg: &SimConfig,
    specs: &[KvSpec],
) -> (KvSpec, Vec<(KvSpec, ServingMetrics)>) {
    let mut rows: Vec<(KvSpec, ServingMetrics)> = Vec::with_capacity(specs.len());
    for &spec in specs {
        let cfg = sim_cfg.with_kv(spec);
        rows.push((spec, sim::simulate_serving(stream, model, hw, &cfg)));
    }
    let best = rows
        .iter()
        .min_by(|a, b| a.1.objective().total_cmp(&b.1.objective()))
        .map(|(s, _)| *s)
        .unwrap_or(sim_cfg.kv);
    (best, rows)
}

// ---------------------------------------------------------------------
// Fleet co-exploration (multi-replica / disaggregated serving)
// ---------------------------------------------------------------------

/// Fleet design space under a total compute budget: candidate replica
/// counts (served by the JSQ router) and disaggregated prefill/decode
/// splits, each replica sized to `total_tops / total_replicas` so every
/// shape spends the same silicon.
#[derive(Debug, Clone)]
pub struct FleetSpace {
    /// Total compute budget across the fleet (TOPS).
    pub total_tops: f64,
    /// Homogeneous fleet sizes to consider (JSQ-routed).
    pub replica_counts: Vec<usize>,
    /// Disaggregated (prefill, decode) splits to consider.
    pub splits: Vec<(usize, usize)>,
    /// KV handoff cost per migrated token for the splits (s/token).
    pub handoff_s_per_token: f64,
}

impl FleetSpace {
    pub fn new(total_tops: f64) -> Self {
        FleetSpace {
            total_tops,
            replica_counts: vec![1, 2, 4],
            splits: vec![(1, 1), (1, 3)],
            handoff_s_per_token: 1e-8,
        }
    }

    /// All fleet shapes the search scores.
    pub fn shapes(&self) -> Vec<FleetConfig> {
        let mut out: Vec<FleetConfig> = self
            .replica_counts
            .iter()
            .map(|&n| FleetConfig::homogeneous(n, RouterPolicy::JoinShortestQueue))
            .collect();
        out.extend(
            self.splits
                .iter()
                .map(|&(p, d)| FleetConfig::disaggregated(p, d, self.handoff_s_per_token)),
        );
        out
    }

    /// Per-replica hardware space for one fleet shape: the paper's
    /// Table-IV space at the budget's per-replica share.
    pub fn space_for(&self, fleet: &FleetConfig) -> HwSpace {
        HwSpace::paper((self.total_tops / fleet.total_replicas() as f64).max(1.0))
    }
}

/// Outcome of a fleet co-exploration run.
#[derive(Debug, Clone)]
pub struct FleetDseOutcome {
    /// Winning fleet shape.
    pub fleet: FleetConfig,
    /// Winning per-replica hardware configuration.
    pub hw: HwConfig,
    pub metrics: FleetMetrics,
    /// Best-objective trajectory of the winning shape's BO run.
    pub bo_history: Vec<f64>,
    /// Best objective reached per candidate fleet shape.
    pub per_shape: Vec<(FleetConfig, f64)>,
    pub backend: &'static str,
}

/// Sim-backed fleet evaluation for a fixed per-replica hardware
/// configuration: replay `stream` across the fleet with a GA mapping
/// search per distinct batch shape on every replica (memoized per
/// replica, exactly like [`search_serving`]).
pub fn search_fleet(
    stream: &RequestStream,
    model: &ModelSpec,
    hw: &HwConfig,
    ga_cfg: &GaConfig,
    sim_cfg: &SimConfig,
    fleet: &FleetConfig,
) -> FleetMetrics {
    let cfg = sim_cfg.with_policy(MappingPolicy::Searched(*ga_cfg));
    sim::simulate_fleet(stream, model, hw, &cfg, fleet)
}

/// Compass scaled out: BO over per-replica hardware *per fleet shape*
/// (replica count or prefill/decode split under the shared total-TOPS
/// budget), the fleet simulator inside, maximizing fleet SLO-constrained
/// goodput via [`FleetMetrics::objective`]. The same `gp` is reused
/// across shapes (each `fit` retrains from scratch on its own
/// observations).
pub fn compass_dse_fleet(
    stream: &RequestStream,
    model: &ModelSpec,
    fspace: &FleetSpace,
    cfg: &DseConfig,
    sim_cfg: &SimConfig,
    gp: &mut dyn Gp,
) -> FleetDseOutcome {
    let mut per_shape: Vec<(FleetConfig, f64)> = Vec::new();
    let mut best: Option<(FleetConfig, bo::BoResult)> = None;
    for fleet in fspace.shapes() {
        let space = fspace.space_for(&fleet);
        let result = bo::optimize(&space, &cfg.bo, gp, |hw| {
            search_fleet(stream, model, hw, &cfg.ga, sim_cfg, &fleet).objective()
        });
        per_shape.push((fleet.clone(), result.best.objective));
        if best
            .as_ref()
            .map_or(true, |(_, b)| result.best.objective < b.best.objective)
        {
            best = Some((fleet, result));
        }
    }
    let (fleet, result) = best.expect("fleet space yields at least one shape");
    let metrics = search_fleet(stream, model, &result.best.hw, &cfg.ga, sim_cfg, &fleet);
    FleetDseOutcome {
        fleet,
        hw: result.best.hw.clone(),
        metrics,
        bo_history: result.history,
        per_shape,
        backend: result.backend,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bo::NativeGp;
    use crate::workload::trace::{Trace, TraceSpec};

    fn tiny_scenario() -> (Scenario, ModelSpec) {
        let trace = Trace::new(&TraceSpec::sharegpt(), 64, 3);
        (Scenario::prefill(&trace, 2, 1), ModelSpec::tiny())
    }

    #[test]
    fn mapping_search_improves_over_first_generation() {
        let (scen, model) = tiny_scenario();
        let hw = crate::arch::HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let r = search_mappings(&scen, &model, &hw, &GaConfig::tiny(), 1);
        assert_eq!(r.mappings.len(), 1);
        assert!(r.mappings[0].is_valid(4));
        assert!(r.eval.latency_cycles > 0.0);
    }

    #[test]
    fn full_dse_runs_end_to_end_and_hits_target_tops() {
        let (scen, model) = tiny_scenario();
        let space = HwSpace::paper(64.0);
        let cfg = DseConfig::tiny();
        let mut gp = NativeGp::new();
        let out = compass_dse(&scen, &model, &space, &cfg, &mut gp);
        assert_eq!(out.backend, "native");
        let tops = out.hw.total_tops();
        assert!((tops - 64.0).abs() / 64.0 < 0.5, "tops {tops}");
        assert_eq!(out.mappings.len(), scen.groups.len());
        assert!(out.eval.total_cost() > 0.0);
        // history covers every BO round and never regresses
        assert_eq!(out.bo_history.len(), cfg.bo.rounds);
        for w in out.bo_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    fn tiny_sim_setup() -> (RequestStream, ModelSpec, SimConfig) {
        let spec = TraceSpec {
            mean_in: 48.0,
            mean_out: 6.0,
            sigma_in: 0.4,
            sigma_out: 0.3,
            max_len: 2048,
            shared_prefix_tokens: 0,
        };
        let mut cfg = SimConfig::new(crate::workload::serving::ServingStrategy::ChunkedPrefill);
        cfg.max_batch = 8;
        cfg.chunk_tokens = 32;
        cfg.kv_budget_tokens = 2048;
        cfg.ctx_bucket = 64;
        cfg.eval_blocks = 1;
        cfg.slo = crate::sim::SloSpec::new(1.0, 0.5);
        (
            RequestStream::poisson(&spec, 50.0, 6, 13),
            ModelSpec::tiny(),
            cfg,
        )
    }

    #[test]
    fn search_serving_is_deterministic_and_conserves() {
        let (stream, model, cfg) = tiny_sim_setup();
        let hw = crate::arch::HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let a = search_serving(&stream, &model, &hw, &GaConfig::tiny(), &cfg);
        let b = search_serving(&stream, &model, &hw, &GaConfig::tiny(), &cfg);
        assert_eq!(a.n_completed + a.n_rejected, a.n_arrived);
        assert!(a.n_completed > 0);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.throughput_tps.to_bits(), b.throughput_tps.to_bits());
        assert_eq!(a.ttft.p99.to_bits(), b.ttft.p99.to_bits());
        assert!(a.distinct_shapes > 0);
    }

    #[test]
    fn search_fleet_is_deterministic_and_conserves() {
        let (stream, model, cfg) = tiny_sim_setup();
        let hw = crate::arch::HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let fleet = FleetConfig::homogeneous(2, RouterPolicy::JoinShortestQueue);
        let a = search_fleet(&stream, &model, &hw, &GaConfig::tiny(), &cfg, &fleet);
        let b = search_fleet(&stream, &model, &hw, &GaConfig::tiny(), &cfg, &fleet);
        assert_eq!(a.n_completed + a.n_rejected, a.n_arrived);
        assert_eq!(a.per_replica.len(), 2);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        assert_eq!(a.slo_goodput_tps.to_bits(), b.slo_goodput_tps.to_bits());
    }

    #[test]
    fn fleet_dse_runs_end_to_end_over_shapes() {
        let (stream, model, cfg) = tiny_sim_setup();
        let mut fspace = FleetSpace::new(64.0);
        fspace.replica_counts = vec![1, 2];
        fspace.splits = vec![(1, 1)];
        let dse_cfg = DseConfig::tiny();
        let mut gp = NativeGp::new();
        let out = compass_dse_fleet(&stream, &model, &fspace, &dse_cfg, &cfg, &mut gp);
        assert_eq!(out.backend, "native");
        assert_eq!(out.per_shape.len(), 3);
        assert_eq!(out.bo_history.len(), dse_cfg.bo.rounds);
        assert_eq!(
            out.metrics.n_completed + out.metrics.n_rejected,
            out.metrics.n_arrived
        );
        // the winner's objective is the minimum over shapes
        let min = out
            .per_shape
            .iter()
            .map(|(_, o)| *o)
            .fold(f64::INFINITY, f64::min);
        assert_eq!(
            out.per_shape
                .iter()
                .find(|(f, _)| f.describe() == out.fleet.describe())
                .map(|(_, o)| *o),
            Some(min)
        );
        for w in out.bo_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }

    #[test]
    fn kv_search_scores_every_spec_and_picks_the_best() {
        let (stream, model, mut cfg) = tiny_sim_setup();
        cfg.policy = MappingPolicy::Pipeline;
        let hw = crate::arch::HwConfig::homogeneous(
            2,
            2,
            crate::arch::ChipletClass::S,
            crate::arch::Dataflow::WeightStationary,
            32.0,
            16.0,
        );
        let specs = [
            KvSpec::token_granular(),
            KvSpec::paged(16),
            KvSpec::token_granular().with_dtype(crate::sim::KvDtype::Int4),
        ];
        let (best, rows) = search_kv(&stream, &model, &hw, &cfg, &specs);
        assert_eq!(rows.len(), specs.len());
        let best_obj = rows
            .iter()
            .map(|(_, m)| m.objective())
            .fold(f64::INFINITY, f64::min);
        let found = rows
            .iter()
            .find(|(s, _)| s.describe() == best.describe())
            .expect("winner is one of the candidates");
        assert_eq!(found.1.objective().to_bits(), best_obj.to_bits());
        for (_, m) in &rows {
            assert_eq!(m.n_completed + m.n_rejected, m.n_arrived);
        }
    }

    #[test]
    fn serving_dse_runs_end_to_end() {
        let (stream, model, cfg) = tiny_sim_setup();
        let space = HwSpace::paper(64.0);
        let dse_cfg = DseConfig::tiny();
        let mut gp = NativeGp::new();
        let out = compass_dse_serving(&stream, &model, &space, &dse_cfg, &cfg, &mut gp);
        assert_eq!(out.backend, "native");
        assert_eq!(out.bo_history.len(), dse_cfg.bo.rounds);
        assert_eq!(
            out.metrics.n_completed + out.metrics.n_rejected,
            out.metrics.n_arrived
        );
        for w in out.bo_history.windows(2) {
            assert!(w[1] <= w[0] + 1e-12);
        }
    }
}

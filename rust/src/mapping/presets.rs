//! Algorithm 1 of the paper: the mapping-encoding representations of the
//! three common parallelism paradigms, demonstrating the encoding's
//! flexibility (data / model / pipeline parallelism are all special cases).

use super::Mapping;

/// Data parallelism: `micro_batch_size = 1`; each micro-batch (row `i`)
/// independently executes all layers on chiplet `i mod C` — no
/// inter-chiplet communication, inter-layer activations stay on-chiplet.
///
/// `rows` = batch size (B), `cols` = layers (L), `chips` = C.
pub fn data_parallel(rows: usize, cols: usize, chips: usize) -> Mapping {
    let mut m = Mapping::new(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            m.set_chip(i, j, (i % chips) as u16);
        }
    }
    m
}

/// Model parallelism: `micro_batch_size = B` (one fused micro-batch);
/// layer `i` runs on chiplet `i mod C`; inter-layer activations travel
/// over the NoP instead of DRAM.
pub fn model_parallel(cols: usize, chips: usize) -> Mapping {
    let mut m = Mapping::new(1, cols);
    for j in 0..cols {
        m.set_chip(0, j, (j % chips) as u16);
    }
    m
}

/// Pipeline parallelism: `micro_batch_size = k` (B/k rows); segmentation
/// cuts after every C-th layer (Algorithm 1 lines 21-25); layer `j` is
/// pinned to chiplet `j mod C` so batches stream through layer-stages
/// like a pipeline.
pub fn pipeline_parallel(rows: usize, cols: usize, chips: usize) -> Mapping {
    let mut m = Mapping::new(rows, cols);
    for i in 0..cols.saturating_sub(1) {
        if (i + 1) % chips == 0 {
            m.segmentation[i] = true;
        }
    }
    for j in 0..cols {
        for i in 0..rows {
            m.set_chip(i, j, (j % chips) as u16);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn data_parallel_keeps_rows_on_one_chip() {
        let m = data_parallel(8, 4, 4);
        for i in 0..8 {
            let c0 = m.chip(i, 0);
            assert!((0..4).all(|j| m.chip(i, j) == c0));
            assert_eq!(c0, (i % 4) as u16);
        }
        assert!(m.segmentation.iter().all(|&s| !s));
    }

    #[test]
    fn model_parallel_spreads_layers() {
        let m = model_parallel(6, 4);
        assert_eq!(m.rows, 1);
        let chips: Vec<u16> = (0..6).map(|j| m.chip(0, j)).collect();
        assert_eq!(chips, vec![0, 1, 2, 3, 0, 1]);
    }

    #[test]
    fn pipeline_parallel_segments_every_c_layers() {
        let m = pipeline_parallel(4, 8, 4);
        // cuts after layers 3 (i=3 -> (3+1)%4==0) and 7 is last (no cut slot)
        let cuts: Vec<usize> = m
            .segmentation
            .iter()
            .enumerate()
            .filter(|(_, &s)| s)
            .map(|(i, _)| i)
            .collect();
        assert_eq!(cuts, vec![3]);
        // layer j pinned to chip j % C for every micro-batch
        for i in 0..4 {
            for j in 0..8 {
                assert_eq!(m.chip(i, j), (j % 4) as u16);
            }
        }
        // schedule interleaves micro-batches within each segment
        let order = m.schedule_order();
        assert_eq!(order[0], (0, 0));
        assert_eq!(order[4], (1, 0)); // second micro-batch enters stage set
    }

    #[test]
    fn presets_are_valid() {
        assert!(data_parallel(8, 4, 4).is_valid(4));
        assert!(model_parallel(12, 8).is_valid(8));
        assert!(pipeline_parallel(4, 12, 6).is_valid(6));
    }
}

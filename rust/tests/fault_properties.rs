//! Fault-injection properties: the zero-fault bitwise anchor,
//! request conservation under crashes/stragglers/retries, replay
//! determinism, and the recovery semantics of the retry path.
//!
//! The anchor is the contract that makes the fault layer safe to keep
//! in the serving stack: with an empty [`FaultSchedule`] and retries
//! disabled, `simulate_fleet_faults` must be bitwise-identical —
//! per-replica metrics *and* per-request timings — to
//! `simulate_fleet_frontend` under every front end (baseline, SLO
//! shedding, rebalancing). On top of that, seeded fault storms must
//! never lose track of a request: every arrival ends as exactly one of
//! completed / rejected (with sheds and permanent losses inside the
//! rejections), reruns are bit-identical, and enabling retries can
//! only reduce permanent losses.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{
    self, AdmissionPolicy, FaultSchedule, FleetConfig, Frontend, MappingPolicy, RebalanceSpec,
    RequestStream, ResilienceSpec, RetryPolicy, RouterPolicy, SimConfig, SloSpec,
};
use compass::util::Rng;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

fn tiny_hw() -> HwConfig {
    HwConfig::homogeneous(
        2,
        2,
        ChipletClass::S,
        Dataflow::WeightStationary,
        32.0,
        16.0,
    )
}

fn tiny_spec() -> TraceSpec {
    TraceSpec {
        mean_in: 48.0,
        mean_out: 8.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 4096,
        shared_prefix_tokens: 0,
    }
}

fn cfg_for(strategy: ServingStrategy, kv_tokens: u64) -> SimConfig {
    let mut cfg = SimConfig::new(strategy);
    cfg.policy = MappingPolicy::Pipeline;
    cfg.max_batch = 6;
    cfg.chunk_tokens = 24;
    cfg.kv_budget_tokens = kv_tokens;
    cfg.ctx_bucket = 32;
    cfg.eval_blocks = 1;
    cfg.slo = SloSpec::new(0.5, 0.1);
    cfg.max_iterations = 500_000;
    cfg
}

/// Full bitwise comparison of two fleet results: per-replica metrics
/// and per-request outcome timings.
fn assert_fleet_bitwise(a: &sim::FleetMetrics, b: &sim::FleetMetrics, ctx: &str) {
    assert_eq!(a.per_replica.len(), b.per_replica.len(), "{ctx}: replica count");
    for (i, (x, y)) in a.per_replica.iter().zip(&b.per_replica).enumerate() {
        assert_eq!(
            x.makespan_s.to_bits(),
            y.makespan_s.to_bits(),
            "{ctx}: replica {i} makespan"
        );
        assert_eq!(
            x.energy_pj.to_bits(),
            y.energy_pj.to_bits(),
            "{ctx}: replica {i} energy"
        );
        assert_eq!(x.busy_s.to_bits(), y.busy_s.to_bits(), "{ctx}: replica {i} busy");
        assert_eq!(x.n_iterations, y.n_iterations, "{ctx}: replica {i} iterations");
        assert_eq!(x.n_arrived, y.n_arrived, "{ctx}: replica {i} arrivals");
    }
    assert_eq!(a.outcomes.len(), b.outcomes.len(), "{ctx}: outcome count");
    for (i, (x, y)) in a.outcomes.iter().zip(&b.outcomes).enumerate() {
        assert_eq!(
            x.arrival_s.to_bits(),
            y.arrival_s.to_bits(),
            "{ctx}: outcome {i} arrival"
        );
        assert_eq!(x.input_len, y.input_len, "{ctx}: outcome {i} input");
        assert_eq!(x.output_len, y.output_len, "{ctx}: outcome {i} output");
        assert_eq!(
            x.first_token_s.map(f64::to_bits),
            y.first_token_s.map(f64::to_bits),
            "{ctx}: outcome {i} first token"
        );
        assert_eq!(
            x.finish_s.map(f64::to_bits),
            y.finish_s.map(f64::to_bits),
            "{ctx}: outcome {i} finish"
        );
        assert_eq!(x.rejected, y.rejected, "{ctx}: outcome {i} rejected");
    }
    assert_eq!(a.n_shed, b.n_shed, "{ctx}: shed count");
    assert_eq!(a.n_rebalanced, b.n_rebalanced, "{ctx}: rebalance count");
    assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits(), "{ctx}: makespan");
    assert_eq!(a.energy_pj.to_bits(), b.energy_pj.to_bits(), "{ctx}: energy");
}

/// With no faults scheduled and retries disabled, the fault layer is
/// bitwise-free under every front end — baseline admission, SLO
/// shedding, and busy-time rebalancing — over randomized homogeneous
/// fleets. The anchor for keeping the layer permanently in the stack.
#[test]
fn zero_fault_layer_is_bitwise_frontend_under_all_frontends() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut rng = Rng::seed_from_u64(0xFA17);
    for trial in 0..6 {
        let strategy = ServingStrategy::ALL[trial % 3];
        let kv_tokens = *rng.choose(&[4096u64, 768]);
        let cfg = cfg_for(strategy, kv_tokens);
        let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
        let n_rep = 2 + trial % 2;
        let router = if trial % 2 == 0 {
            RouterPolicy::JoinShortestQueue
        } else {
            RouterPolicy::RoundRobin
        };
        let fleet = FleetConfig::homogeneous(n_rep, router);
        let rate = (0.6 + rng.gen_f64() * 1.5) * n_rep as f64 * probe.capacity_rps();
        let stream =
            RequestStream::poisson(&tiny_spec(), rate, 10 + rng.gen_index(6), rng.next_u64());
        let hws = vec![hw.clone(); n_rep];
        let frontends = [
            ("baseline", Frontend::baseline()),
            ("shed", Frontend::with_shedding(probe, 1.0)),
            (
                "rebalance",
                Frontend {
                    admission: AdmissionPolicy::ArrivalReject,
                    rebalance: Some(RebalanceSpec::new(0.2, 1e-7)),
                },
            ),
        ];
        for (name, fe) in &frontends {
            let ctx = format!("trial {trial} {strategy:?} {name} kv={kv_tokens}");
            let plain = sim::simulate_fleet_frontend(&stream, &model, &hws, &cfg, &fleet, fe);
            let faultless = sim::simulate_fleet_faults(
                &stream,
                &model,
                &hws,
                &cfg,
                &fleet,
                fe,
                &ResilienceSpec::none(),
            );
            assert_fleet_bitwise(&plain, &faultless, &ctx);
            assert_eq!(faultless.faults.n_failed, 0, "{ctx}");
            assert_eq!(faultless.faults.n_lost, 0, "{ctx}");
            assert_eq!(
                faultless.faults.availability.to_bits(),
                1.0f64.to_bits(),
                "{ctx}"
            );
        }
    }
}

/// Seeded fault storms with retries never lose track of a request:
/// every arrival is exactly one of completed / rejected, the outcome
/// list has exactly one entry per request (retried attempts collapse
/// into one stitched outcome), and sheds + permanent losses stay
/// inside the rejections.
#[test]
fn faulted_fleets_conserve_requests_over_randomized_storms() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let mut rng = Rng::seed_from_u64(0xC0A5);
    for trial in 0..8 {
        let strategy = ServingStrategy::ALL[trial % 3];
        let cfg = cfg_for(strategy, *rng.choose(&[4096u64, 768]));
        let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
        let n_rep = 2 + trial % 2;
        let fleet = FleetConfig::homogeneous(n_rep, RouterPolicy::JoinShortestQueue);
        let rate = (0.6 + rng.gen_f64() * 1.8) * n_rep as f64 * probe.capacity_rps();
        let stream =
            RequestStream::poisson(&tiny_spec(), rate, 10 + rng.gen_index(8), rng.next_u64());
        let schedule = FaultSchedule::seeded(
            n_rep,
            stream.horizon_s(),
            1 + trial % 2,
            trial % 3,
            rng.next_u64(),
        );
        let retry = if trial % 2 == 0 {
            RetryPolicy::capped(3, 0.2 * probe.t_prefill_s, 2.0)
        } else {
            RetryPolicy::disabled()
        };
        let res = ResilienceSpec::none()
            .with_schedule(schedule.clone())
            .with_retry(retry)
            .with_failover(trial % 3 != 2);
        let hws = vec![hw.clone(); n_rep];
        let m = sim::simulate_fleet_faults(
            &stream,
            &model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
            &res,
        );
        let ctx = format!(
            "trial {trial} {strategy:?} {} under {}",
            res.describe(),
            schedule.describe()
        );
        assert_eq!(m.n_completed + m.n_rejected, m.n_arrived, "{ctx}");
        assert_eq!(m.n_arrived, stream.len(), "{ctx}: arrivals != stream");
        assert_eq!(m.outcomes.len(), stream.len(), "{ctx}: double-counted outcome");
        assert!(!m.truncated, "{ctx}");
        assert!(m.n_shed + m.faults.n_lost <= m.n_rejected, "{ctx}");
        assert!(m.faults.n_lost <= m.faults.n_failed, "{ctx}");
        assert!(m.faults.availability <= 1.0 && m.faults.availability >= 0.0, "{ctx}");
        // one stitched story per request: the outcome arrivals are the
        // stream arrivals, bit for bit — retried attempts keep the
        // original arrival and never spawn a second outcome
        let mut got: Vec<u64> = m.outcomes.iter().map(|o| o.arrival_s.to_bits()).collect();
        let mut want: Vec<u64> = stream.requests.iter().map(|r| r.arrival_s.to_bits()).collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want, "{ctx}: outcome arrivals drifted from the stream");
    }
}

/// The same seeds replay bit-identically: fault injection keeps the
/// simulator's determinism contract.
#[test]
fn faulted_runs_are_bit_identical_across_reruns() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 2048);
    let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
    let n_rep = 2;
    let fleet = FleetConfig::homogeneous(n_rep, RouterPolicy::JoinShortestQueue);
    let rate = 1.4 * n_rep as f64 * probe.capacity_rps();
    let stream = RequestStream::poisson(&tiny_spec(), rate, 14, 41);
    let schedule = FaultSchedule::seeded(n_rep, stream.horizon_s(), 1, 1, 99);
    let res = ResilienceSpec::none()
        .with_schedule(schedule)
        .with_retry(RetryPolicy::capped(3, 0.2 * probe.t_prefill_s, 2.0));
    let hws = vec![hw.clone(); n_rep];
    let run = || {
        sim::simulate_fleet_faults(
            &stream,
            &model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
            &res,
        )
    };
    let a = run();
    let b = run();
    assert_fleet_bitwise(&a, &b, "fault replay");
    assert_eq!(a.faults, b.faults, "fault stats drifted between reruns");
}

/// A mid-run crash fails in-flight requests; retries win them back.
/// With retries disabled every failure is a permanent loss; with a
/// capped backoff the lost count can only shrink, and the crash's
/// downtime is visible in availability.
#[test]
fn crash_failures_are_lost_without_retry_and_recovered_with_it() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 2048);
    let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
    let n_rep = 2;
    let fleet = FleetConfig::homogeneous(n_rep, RouterPolicy::JoinShortestQueue);
    // overload so both replicas hold work when the crash lands mid-run
    let rate = 2.0 * n_rep as f64 * probe.capacity_rps();
    let stream = RequestStream::poisson(&tiny_spec(), rate, 16, 7);
    let h = stream.horizon_s();
    let schedule = FaultSchedule::none().crash(0, 0.5 * h, 0.3 * h);
    let hws = vec![hw.clone(); n_rep];
    let run = |retry: RetryPolicy| {
        let res = ResilienceSpec::none()
            .with_schedule(schedule.clone())
            .with_retry(retry);
        sim::simulate_fleet_faults(
            &stream,
            &model,
            &hws,
            &cfg,
            &fleet,
            &Frontend::baseline(),
            &res,
        )
    };
    let off = run(RetryPolicy::disabled());
    assert!(off.faults.n_failed > 0, "crash at 50% of an overloaded run must fail work");
    assert_eq!(
        off.faults.n_lost, off.faults.n_failed,
        "without retry every failure is permanent"
    );
    assert_eq!(off.faults.n_retried, 0);
    assert!(off.faults.downtime_s > 0.0);
    assert!(off.faults.availability < 1.0);

    let on = run(RetryPolicy::capped(4, 0.2 * probe.t_prefill_s, 2.0));
    assert!(on.faults.n_retried > 0, "retries must fire for the same crash");
    assert!(
        on.faults.n_lost <= off.faults.n_lost,
        "retries must not increase permanent losses ({} > {})",
        on.faults.n_lost,
        off.faults.n_lost
    );
    assert!(
        on.n_completed >= off.n_completed,
        "retries must not reduce completions"
    );
    // both runs still conserve
    for (m, tag) in [(&off, "off"), (&on, "on")] {
        assert_eq!(m.n_completed + m.n_rejected, m.n_arrived, "retry-{tag}");
        assert_eq!(m.outcomes.len(), stream.len(), "retry-{tag}");
    }
}

/// A straggler window only throttles the clock: the run finishes no
/// earlier than the fault-free one, completes the same requests, and
/// spends the same energy shape (slow clock, same work).
#[test]
fn straggler_window_never_speeds_up_the_fleet() {
    let model = ModelSpec::tiny();
    let hw = tiny_hw();
    let cfg = cfg_for(ServingStrategy::ChunkedPrefill, 4096);
    let probe = sim::probe(&model, &hw, &cfg, &tiny_spec());
    let n_rep = 2;
    let fleet = FleetConfig::homogeneous(n_rep, RouterPolicy::JoinShortestQueue);
    let rate = 0.9 * n_rep as f64 * probe.capacity_rps();
    let stream = RequestStream::poisson(&tiny_spec(), rate, 12, 11);
    let hws = vec![hw.clone(); n_rep];
    let base = sim::simulate_fleet_faults(
        &stream,
        &model,
        &hws,
        &cfg,
        &fleet,
        &Frontend::baseline(),
        &ResilienceSpec::none(),
    );
    let slowed = sim::simulate_fleet_faults(
        &stream,
        &model,
        &hws,
        &cfg,
        &fleet,
        &Frontend::baseline(),
        &ResilienceSpec::none().with_schedule(FaultSchedule::none().straggler(
            0,
            0.0,
            f64::INFINITY,
            3.0,
        )),
    );
    assert!(
        slowed.makespan_s >= base.makespan_s - 1e-9,
        "straggler sped the fleet up: {} < {}",
        slowed.makespan_s,
        base.makespan_s
    );
    assert_eq!(slowed.n_completed, base.n_completed, "straggler dropped completions");
    assert_eq!(
        slowed.n_completed + slowed.n_rejected,
        slowed.n_arrived,
        "straggler broke conservation"
    );
    assert_eq!(slowed.faults.n_failed, 0, "a straggler is not a crash");
}

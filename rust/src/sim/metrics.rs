//! Serving quality metrics: TTFT/TPOT distributions, SLO attainment,
//! goodput, utilization and EDP-under-load, plus the per-iteration
//! occupancy trace behind the report's ASCII occupancy plot.

use crate::arch::constants::CLOCK_HZ;

/// Service-level objectives on per-request latency.
#[derive(Debug, Clone, Copy)]
pub struct SloSpec {
    /// Time-to-first-token target (s): arrival -> first output token.
    pub ttft_s: f64,
    /// Time-per-output-token target (s): mean decode-token gap.
    pub tpot_s: f64,
}

impl SloSpec {
    pub fn new(ttft_s: f64, tpot_s: f64) -> Self {
        SloSpec { ttft_s, tpot_s }
    }
}

/// Mean / median / tail summary of a latency sample.
#[derive(Debug, Clone, Copy, Default)]
pub struct LatencyStats {
    pub mean: f64,
    pub p50: f64,
    pub p99: f64,
    pub n: usize,
}

impl LatencyStats {
    /// Summarise a sample (empty samples yield zeros).
    pub fn from(xs: &[f64]) -> Self {
        if xs.is_empty() {
            return LatencyStats::default();
        }
        let mut sorted = xs.to_vec();
        sorted.sort_unstable_by(|a, b| a.total_cmp(b));
        LatencyStats {
            mean: sorted.iter().sum::<f64>() / sorted.len() as f64,
            p50: percentile(&sorted, 0.50),
            p99: percentile(&sorted, 0.99),
            n: sorted.len(),
        }
    }
}

/// Nearest-rank percentile of an ascending-sorted sample.
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One scheduler iteration in the occupancy trace.
#[derive(Debug, Clone, Copy)]
pub struct IterRecord {
    pub start_s: f64,
    pub end_s: f64,
    /// Decode requests co-batched this iteration.
    pub n_decode: usize,
    /// Prefill requests (or chunks) co-batched this iteration.
    pub n_prefill: usize,
    /// Prefill tokens scheduled this iteration.
    pub prefill_tokens: u64,
    /// Admission-queue depth after batch formation.
    pub queue_depth: usize,
    /// KV-cache occupancy after this iteration's writes (0..=1).
    pub kv_frac: f64,
}

/// End-to-end serving quality of one simulated run.
#[derive(Debug, Clone)]
pub struct ServingMetrics {
    pub n_arrived: usize,
    pub n_completed: usize,
    /// Requests rejected at arrival (can never fit the KV budget).
    pub n_rejected: usize,
    /// KV-pressure preemptions (request re-queued, prefill recomputed).
    pub n_preemptions: usize,
    pub n_iterations: usize,
    /// True when the run stopped at the iteration safety valve with
    /// requests still in flight: the other metrics then cover only the
    /// surviving subset and must not be compared against full runs.
    pub truncated: bool,
    /// Distinct batch shapes actually simulated (memo size).
    pub distinct_shapes: usize,
    /// Wall-clock span of the simulated run (s).
    pub makespan_s: f64,
    /// Generated output tokens per second over the makespan.
    pub throughput_tps: f64,
    /// SLO-satisfying completed requests per second.
    pub goodput_rps: f64,
    /// Output tokens of SLO-satisfying requests per second — the
    /// SLO-constrained goodput objective of the sim-backed DSE.
    pub slo_goodput_tps: f64,
    pub ttft: LatencyStats,
    pub tpot: LatencyStats,
    /// Fraction of completed requests meeting both TTFT and TPOT SLOs.
    pub slo_attainment: f64,
    pub mean_queue_depth: f64,
    pub max_queue_depth: usize,
    /// Mean batch slots filled per iteration / `max_batch`.
    pub mean_batch_occupancy: f64,
    /// Compute utilization: ideal MAC cycles / elapsed cycles.
    pub utilization: f64,
    pub energy_pj: f64,
    /// EDP under load: total energy (J) x makespan (s).
    pub edp_under_load: f64,
    /// Per-iteration occupancy trace (for the ASCII plot).
    pub iters: Vec<IterRecord>,
}

/// Raw per-request outcomes collected by the scheduler.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub arrival_s: f64,
    pub output_len: u64,
    pub first_token_s: Option<f64>,
    pub finish_s: Option<f64>,
    pub rejected: bool,
}

/// Aggregate raw scheduler state into `ServingMetrics`.
#[allow(clippy::too_many_arguments)]
pub fn finalize(
    outcomes: &[RequestOutcome],
    iters: Vec<IterRecord>,
    slo: &SloSpec,
    max_batch: usize,
    makespan_s: f64,
    energy_pj: f64,
    ideal_cycles: f64,
    gen_tokens: u64,
    n_preemptions: usize,
    distinct_shapes: usize,
    truncated: bool,
) -> ServingMetrics {
    let mut ttfts = Vec::new();
    let mut tpots = Vec::new();
    let mut n_completed = 0usize;
    let mut n_rejected = 0usize;
    let mut slo_ok = 0usize;
    let mut slo_ok_tokens = 0u64;
    for o in outcomes {
        if o.rejected {
            n_rejected += 1;
            continue;
        }
        let (Some(first), Some(finish)) = (o.first_token_s, o.finish_s) else {
            continue; // truncated run (iteration cap): not completed
        };
        n_completed += 1;
        let ttft = first - o.arrival_s;
        ttfts.push(ttft);
        let tpot = if o.output_len > 1 {
            (finish - first) / (o.output_len - 1) as f64
        } else {
            0.0
        };
        tpots.push(tpot);
        if ttft <= slo.ttft_s && tpot <= slo.tpot_s {
            slo_ok += 1;
            slo_ok_tokens += o.output_len;
        }
    }
    let span = makespan_s.max(1e-12);
    let n_iter = iters.len();
    let mean_queue_depth = if n_iter > 0 {
        iters.iter().map(|i| i.queue_depth as f64).sum::<f64>() / n_iter as f64
    } else {
        0.0
    };
    let max_queue_depth = iters.iter().map(|i| i.queue_depth).max().unwrap_or(0);
    let mean_batch_occupancy = if n_iter > 0 {
        iters
            .iter()
            .map(|i| (i.n_decode + i.n_prefill) as f64 / max_batch.max(1) as f64)
            .sum::<f64>()
            / n_iter as f64
    } else {
        0.0
    };
    ServingMetrics {
        n_arrived: outcomes.len(),
        n_completed,
        n_rejected,
        n_preemptions,
        n_iterations: n_iter,
        truncated,
        distinct_shapes,
        makespan_s,
        throughput_tps: gen_tokens as f64 / span,
        goodput_rps: slo_ok as f64 / span,
        slo_goodput_tps: slo_ok_tokens as f64 / span,
        ttft: LatencyStats::from(&ttfts),
        tpot: LatencyStats::from(&tpots),
        slo_attainment: if n_completed > 0 {
            slo_ok as f64 / n_completed as f64
        } else {
            0.0
        },
        mean_queue_depth,
        max_queue_depth,
        mean_batch_occupancy,
        utilization: ideal_cycles / (span * CLOCK_HZ),
        energy_pj,
        edp_under_load: (energy_pj * 1e-12) * makespan_s,
        iters,
    }
}

impl ServingMetrics {
    /// Scalar objective for the DSE (lower is better): negated
    /// SLO-constrained goodput with a small throughput tiebreak so the
    /// surrogate keeps gradient signal when attainment saturates at 0/1.
    /// Truncated runs score 0 (worse than any run with progress) so the
    /// search never prefers a configuration it could not fully simulate.
    pub fn objective(&self) -> f64 {
        if self.truncated {
            return 0.0;
        }
        -(self.slo_goodput_tps + 1e-3 * self.throughput_tps)
    }

    /// One-line summary for logs and examples.
    pub fn summary(&self) -> String {
        format!(
            "done {}/{} (rej {}, preempt {}) | {:.1} tok/s | ttft p99 {:.3}s | \
             tpot p99 {:.4}s | SLO {:.0}% | util {:.0}% | queue mean {:.1}",
            self.n_completed,
            self.n_arrived,
            self.n_rejected,
            self.n_preemptions,
            self.throughput_tps,
            self.ttft.p99,
            self.tpot.p99,
            100.0 * self.slo_attainment,
            100.0 * self.utilization,
            self.mean_queue_depth,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 1.0), 100.0);
        assert_eq!(percentile(&xs, 0.5), 51.0); // round(0.5 * 99) = 50
        assert_eq!(percentile(&xs, 0.99), 99.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
    }

    #[test]
    fn latency_stats_of_constant_sample() {
        let s = LatencyStats::from(&[2.0; 10]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.p99, 2.0);
        assert_eq!(s.n, 10);
    }

    #[test]
    fn finalize_counts_slo_and_rejections() {
        let slo = SloSpec::new(1.0, 0.1);
        let outcomes = vec![
            // meets both SLOs
            RequestOutcome {
                arrival_s: 0.0,
                output_len: 11,
                first_token_s: Some(0.5),
                finish_s: Some(1.4), // tpot 0.09
                rejected: false,
            },
            // misses TPOT
            RequestOutcome {
                arrival_s: 0.0,
                output_len: 11,
                first_token_s: Some(0.5),
                finish_s: Some(3.0), // tpot 0.25
                rejected: false,
            },
            RequestOutcome {
                arrival_s: 0.0,
                output_len: 5,
                first_token_s: None,
                finish_s: None,
                rejected: true,
            },
        ];
        let m = finalize(&outcomes, Vec::new(), &slo, 8, 10.0, 1e12, 0.0, 21, 0, 3, false);
        assert!(!m.truncated);
        assert_eq!(m.n_arrived, 3);
        assert_eq!(m.n_completed, 2);
        assert_eq!(m.n_rejected, 1);
        assert!((m.slo_attainment - 0.5).abs() < 1e-12);
        assert!((m.goodput_rps - 0.1).abs() < 1e-12);
        assert!((m.slo_goodput_tps - 1.1).abs() < 1e-12);
        assert!((m.throughput_tps - 2.1).abs() < 1e-12);
        assert!((m.edp_under_load - 10.0).abs() < 1e-9); // 1 J x 10 s
        assert!(m.objective() < 0.0);
        assert!(!m.summary().is_empty());
    }
}

//! End-to-end driver (DESIGN.md "End-to-end validation"): run the full
//! Compass pipeline on a real small workload — a ShareGPT-like
//! sequence-length trace at the paper's 64-TOPS edge design point —
//! and report the paper's headline metric: latency / energy / monetary
//! cost of the Compass design vs the Gemini- and MOHaM-style baselines,
//! validated on a *held-out* test trace.
//!
//! Run: `cargo run --release --example sharegpt_dse [-- --full]`
//! The results of this run are recorded in EXPERIMENTS.md.

use compass::dse::DseConfig;
use compass::experiments as exp;
use compass::runtime::Runtime;

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    let cfg = if full {
        DseConfig::paper()
    } else {
        DseConfig::reduced()
    };
    let rt = Runtime::from_env().ok();
    let t0 = std::time::Instant::now();

    // both phases of the paper's ShareGPT-64TOPS column
    let scenes = vec![
        exp::Scene::new("sharegpt", true, 64.0),
        exp::Scene::new("sharegpt", false, 64.0),
    ];
    let rows = exp::fig7_compare(&scenes, &cfg, rt.as_ref(), 7);

    exp::fig7_table(&rows).print();
    exp::fig7_savings(&rows).print();
    exp::table6(&rows).print();

    // headline check: total cost of the Compass design vs the baselines
    for r in &rows {
        let c = r.compass[3];
        println!(
            "[{}] total cost: compass {:.3e} vs gemini {:.3e} ({:+.1}%) vs moham {:.3e} ({:+.1}%)",
            r.scene.label(),
            c,
            r.gemini[3],
            100.0 * (c - r.gemini[3]) / r.gemini[3],
            r.moham[3],
            100.0 * (c - r.moham[3]) / r.moham[3],
        );
    }
    println!("elapsed: {:.1}s", t0.elapsed().as_secs_f64());
}

//! Serving-simulator throughput bench: how many simulated seconds of
//! continuous-batching traffic one wall-clock second buys, per serving
//! strategy, plus the composition-memo hit behaviour that makes the
//! steady state cheap (EXPERIMENTS.md "Serving simulator").

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::sim::{self, SimConfig};
use compass::util::Bench;
use compass::workload::serving::ServingStrategy;
use compass::workload::trace::TraceSpec;
use compass::workload::ModelSpec;

fn main() {
    let model = ModelSpec::gpt3_7b();
    let hw = HwConfig::homogeneous(
        2,
        4,
        ChipletClass::M,
        Dataflow::WeightStationary,
        64.0,
        32.0,
    );
    let spec = TraceSpec {
        mean_in: 256.0,
        mean_out: 64.0,
        sigma_in: 0.5,
        sigma_out: 0.4,
        max_len: 16_384,
        shared_prefix_tokens: 0,
    };
    let mut cfg = SimConfig::new(ServingStrategy::ChunkedPrefill);
    cfg.max_batch = 16;
    cfg.eval_blocks = 1;
    cfg.ctx_bucket = 256;
    let probe = sim::probe(&model, &hw, &cfg, &spec);
    cfg.slo = probe.slo(3.0, 4.0);
    let rate = 0.9 * probe.capacity_rps();
    let stream = sim::RequestStream::poisson(&spec, rate, 64, 7);

    println!(
        "sim_steady_state: 64 requests @ {:.3} req/s (0.9x capacity), \
         model {}, hw {}",
        rate,
        model.name,
        hw.describe()
    );
    for strategy in ServingStrategy::ALL {
        let c = cfg.with_strategy(strategy);
        // one cold run for the shape/iteration counts
        let cold = sim::simulate_serving(&stream, &model, &hw, &c);
        let wall = Bench::new(&format!("sim_steady_state/{}", strategy.name()))
            .budget_ms(1500)
            .run(|| sim::simulate_serving(&stream, &model, &hw, &c));
        println!(
            "    {:<14} sim {:>9.3}s / wall -> {:>10.1} sim-s per wall-s | \
             {} iterations, {} distinct shapes",
            strategy.name(),
            cold.makespan_s,
            cold.makespan_s / wall.max(1e-12),
            cold.n_iterations,
            cold.distinct_shapes,
        );
    }
}

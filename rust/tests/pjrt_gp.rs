//! Integration: the PJRT-executed GP artifacts must agree with the
//! native Rust mirror (same composite kernel, fit, and EI math).
//!
//! Requires `make artifacts`; tests skip (pass trivially with a notice)
//! when the artifacts directory is absent so `cargo test` stays green on
//! a fresh checkout.

use compass::arch::{ChipletClass, Dataflow, HwConfig};
use compass::bo::{featurize, Gp, Hyper, NativeGp, PjrtGp};
use compass::util::Rng;

fn runtime() -> Option<compass::runtime::Runtime> {
    let rt = compass::runtime::Runtime::from_env().ok()?;
    if !rt.artifacts_available() {
        eprintln!("[skip] artifacts not built; run `make artifacts`");
        return None;
    }
    Some(rt)
}

fn random_hw(rng: &mut Rng) -> HwConfig {
    let class = *rng.choose(&ChipletClass::ALL);
    let n = class.chiplets_for(64.0).min(64);
    let (h, w) = compass::arch::HwSpace::grid_dims(n);
    let mut hw = HwConfig::homogeneous(
        h,
        w,
        class,
        Dataflow::WeightStationary,
        *rng.choose(&[32.0, 64.0, 128.0]),
        *rng.choose(&[16.0, 32.0, 64.0]),
    );
    for d in hw.layout.iter_mut() {
        *d = *rng.choose(&Dataflow::ALL);
    }
    hw.tensor_parallel = *rng.choose(&[4usize, 8, 16]);
    hw
}

fn toy_set(n: usize, seed: u64) -> (Vec<compass::bo::HwFeatures>, Vec<f32>) {
    let mut rng = Rng::seed_from_u64(seed);
    let hws: Vec<HwConfig> = (0..n).map(|_| random_hw(&mut rng)).collect();
    let xs: Vec<_> = hws.iter().map(featurize).collect();
    let ys: Vec<f32> = hws
        .iter()
        .map(|h| ((h.nop_bw_gbs / h.dram_bw_gbs).ln() as f32) * 0.4)
        .collect();
    (xs, ys)
}

#[test]
fn pjrt_fit_matches_native_mll_and_posterior() {
    let Some(rt) = runtime() else { return };
    let (xs, ys) = toy_set(12, 1);
    let hyper = Hyper::default();

    let mut pjrt = PjrtGp::new(&rt);
    let mll_p = pjrt.fit(&xs, &ys, hyper).expect("pjrt fit");
    let mut native = NativeGp::new();
    let mll_n = native.fit(&xs, &ys, hyper).expect("native fit");
    assert!(
        (mll_p - mll_n).abs() / mll_n.abs().max(1.0) < 0.05,
        "MLL mismatch: pjrt {mll_p} native {mll_n}"
    );

    let (cands, _) = toy_set(6, 99);
    let f_best = ys.iter().cloned().fold(f32::INFINITY, f32::min);
    let bp = pjrt.ei(&cands, f_best).expect("pjrt ei");
    let bn = native.ei(&cands, f_best).expect("native ei");
    for i in 0..cands.len() {
        assert!(
            (bp.mean[i] - bn.mean[i]).abs() < 0.05,
            "mean[{i}]: pjrt {} native {}",
            bp.mean[i],
            bn.mean[i]
        );
        assert!(
            (bp.var[i] - bn.var[i]).abs() < 0.05,
            "var[{i}]: pjrt {} native {}",
            bp.var[i],
            bn.var[i]
        );
        assert!(
            (bp.ei[i] - bn.ei[i]).abs() < 0.05,
            "ei[{i}]: pjrt {} native {}",
            bp.ei[i],
            bn.ei[i]
        );
    }
}

#[test]
fn pjrt_ei_ranks_candidates_like_native() {
    let Some(rt) = runtime() else { return };
    let (xs, ys) = toy_set(10, 3);
    let mut pjrt = PjrtGp::new(&rt);
    let mut native = NativeGp::new();
    pjrt.fit(&xs, &ys, Hyper::default()).unwrap();
    native.fit(&xs, &ys, Hyper::default()).unwrap();
    let (cands, _) = toy_set(8, 77);
    let f_best = ys.iter().cloned().fold(f32::INFINITY, f32::min);
    let bp = pjrt.ei(&cands, f_best).unwrap();
    let bn = native.ei(&cands, f_best).unwrap();
    let argmax = |v: &[f32]| {
        v.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap()
    };
    // the top-EI candidate must agree (or have near-identical EI)
    let (ip, iq) = (argmax(&bp.ei), argmax(&bn.ei));
    assert!(
        ip == iq || (bp.ei[ip] - bp.ei[iq]).abs() < 0.02,
        "pjrt argmax {ip} vs native {iq} (pjrt eis {:?})",
        bp.ei
    );
}

#[test]
fn pjrt_backed_bo_loop_runs() {
    let Some(rt) = runtime() else { return };
    let space = compass::arch::HwSpace::paper(64.0);
    let cfg = compass::bo::BoConfig::tiny();
    let mut gp = PjrtGp::new(&rt);
    let r = compass::bo::optimize(&space, &cfg, &mut gp, |hw| {
        // cheap synthetic objective
        (hw.nop_bw_gbs - 64.0).abs() + (hw.dram_bw_gbs - 32.0).abs()
    });
    assert_eq!(r.backend, "pjrt");
    assert_eq!(r.observations.len(), cfg.rounds);
    assert!(r.best.objective.is_finite());
}

#[test]
fn manifest_matches_runtime_constants() {
    let Some(rt) = runtime() else { return };
    rt.check_manifest().expect("manifest consistent");
}

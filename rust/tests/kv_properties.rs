//! Property tests for the paged KV-cache subsystem (`sim::kv`).
//!
//! The correctness anchor: under `block_tokens = 1`, fp16, sharing off,
//! the paged `KvCache` must be *bitwise-equal* to the pre-refactor
//! scalar token counters (`kv_used`/`kv_reserved` with raw `u64`
//! arithmetic). `ScalarKv` below reimplements those counters exactly as
//! `sched.rs` used them before the refactor; randomized
//! scheduler-shaped op sequences (admit / chunk / decode / evict /
//! finish, with the same admission and pressure checks the scheduler
//! issues) must produce identical decisions and identical counter
//! values at every step — so a full simulation, which only touches KV
//! state through this API, is bitwise-equal too (the behavioral
//! regression tests in `sched.rs` pin the end-to-end metrics).
//!
//! Plus the allocator laws: used + reserved + free == capacity after
//! every operation, no block is double-freed, and prefix-shared blocks
//! are freed only at refcount zero.

use compass::sim::kv::{KvCache, KvSpec};
use compass::sim::{EvictionPolicy, KvDtype};
use compass::util::Rng;

/// The pre-refactor scalar accounting, verbatim semantics: raw token
/// counters, headroom = budget - used - reserved, `need + 1` admission
/// slack, reservations realized token-by-token.
struct ScalarKv {
    budget: u64,
    used: u64,
    reserved: u64,
}

impl ScalarKv {
    fn new(budget: u64) -> Self {
        ScalarKv {
            budget,
            used: 0,
            reserved: 0,
        }
    }

    fn can_ever_fit(&self, input: u64, output: u64) -> bool {
        input + output + 1 <= self.budget
    }

    fn can_admit(&self, need: u64, extra_writes: u64) -> bool {
        let head = self.budget.saturating_sub(self.used + self.reserved);
        need + 1 + extra_writes <= head
    }

    fn lease(&mut self, need: u64) {
        self.reserved += need;
    }

    fn write_chunk(&mut self, t: u64) {
        self.used += t;
        self.reserved -= t;
    }

    fn write_decode(&mut self) {
        self.used += 1;
    }

    fn release(&mut self, held: u64, unwritten: u64) {
        self.used -= held;
        self.reserved -= unwritten;
    }

    fn fits_growth(&self, writes: u64) -> bool {
        self.used + self.reserved + writes <= self.budget
    }

    fn frac(&self) -> f64 {
        self.used as f64 / self.budget as f64
    }
}

/// Shadow state of one in-flight request on the scalar side.
#[derive(Clone, Copy)]
struct ShadowReq {
    written: u64,
    lease_left: u64,
    decoding: bool,
}

/// Drive `KvCache` (token-granular, fp16, sharing off) and `ScalarKv`
/// through the same randomized scheduler-shaped op sequence; every
/// decision and every counter must match bitwise at every step.
#[test]
fn token_granular_cache_is_bitwise_equal_to_scalar_counters() {
    let mut rng = Rng::seed_from_u64(0x6b76); // "kv"
    for trial in 0..20u64 {
        let budget = 64 + 16 * (trial % 7);
        let mut cache = KvCache::new(KvSpec::token_granular(), budget);
        let mut scalar = ScalarKv::new(budget);
        let mut live: Vec<Option<ShadowReq>> = Vec::new();
        let mut next_idx = 0usize;

        for _step in 0..400 {
            // the invariant web: every counter matches bitwise
            assert_eq!(cache.capacity_blocks(), scalar.budget);
            assert_eq!(cache.used_blocks(), scalar.used);
            assert_eq!(cache.reserved_blocks(), scalar.reserved);
            assert_eq!(
                cache.free_blocks(),
                scalar.budget - scalar.used - scalar.reserved
            );
            assert_eq!(cache.frac().to_bits(), scalar.frac().to_bits());

            let active: Vec<usize> = live
                .iter()
                .enumerate()
                .filter_map(|(i, r)| r.map(|_| i))
                .collect();
            match rng.gen_index(5) {
                // --- admission attempt ---
                0 => {
                    let input = 1 + rng.gen_index(24) as u64;
                    let output = 1 + rng.gen_index(12) as u64;
                    assert_eq!(
                        cache.can_ever_fit(input, output),
                        scalar.can_ever_fit(input, output)
                    );
                    let extra = rng.gen_index(3) as u64; // co-scheduled decodes
                    let verdict = cache.can_admit(input, input, extra);
                    assert_eq!(verdict, scalar.can_admit(input, extra), "admission verdict");
                    if verdict {
                        let grant = cache.lease(next_idx, input, input);
                        assert_eq!(grant.skip, 0, "sharing off grants no skip");
                        scalar.lease(input);
                        if next_idx >= live.len() {
                            live.resize(next_idx + 1, None);
                        }
                        live[next_idx] = Some(ShadowReq {
                            written: 0,
                            lease_left: input,
                            decoding: false,
                        });
                        next_idx += 1;
                    }
                }
                // --- chunk write on a prefilling request ---
                1 => {
                    if let Some(&i) = active
                        .iter()
                        .find(|&&i| live[i].is_some_and(|r| r.lease_left > 0))
                    {
                        let mut r = live[i].unwrap();
                        let t = 1 + rng.gen_index(r.lease_left as usize) as u64;
                        cache.write_chunk(i, t);
                        scalar.write_chunk(t);
                        r.written += t;
                        r.lease_left -= t;
                        r.decoding = r.lease_left == 0;
                        live[i] = Some(r);
                    }
                }
                // --- decode write (the scheduler's pressure loop runs
                // first: only write when growth fits) ---
                2 => {
                    if let Some(&i) = active
                        .iter()
                        .find(|&&i| live[i].is_some_and(|r| r.decoding))
                    {
                        let growth = cache.decode_growth_one(i);
                        assert_eq!(growth, 1, "token-granular decode always grows by 1");
                        assert_eq!(cache.fits_growth(growth), scalar.fits_growth(1));
                        if cache.fits_growth(growth) {
                            cache.write_decode(i);
                            scalar.write_decode();
                            let mut r = live[i].unwrap();
                            r.written += 1;
                            live[i] = Some(r);
                        }
                    }
                }
                // --- eviction (release with an unrealized lease) or
                // completion (release fully written) ---
                _ => {
                    if !active.is_empty() {
                        let i = active[rng.gen_index(active.len())];
                        let r = live[i].take().unwrap();
                        cache.release(i);
                        scalar.release(r.written, r.lease_left);
                    }
                }
            }
        }
    }
}

/// Allocator conservation under randomized paged operation: used +
/// reserved + free always equals capacity, fragmentation stays in
/// [0, 1], and every release returns exactly what was allocated.
#[test]
fn paged_allocator_conserves_capacity() {
    let mut rng = Rng::seed_from_u64(99);
    for &bt in &[1u64, 4, 16, 64] {
        let spec = KvSpec::paged(bt);
        let mut kv = KvCache::new(spec, 4096);
        let cap = kv.capacity_blocks();
        let mut live: Vec<(usize, u64)> = Vec::new(); // (idx, lease_left)
        let mut next = 0usize;
        for _ in 0..600 {
            assert_eq!(
                kv.used_blocks() + kv.reserved_blocks() + kv.free_blocks(),
                cap,
                "bt={bt}: used + reserved + free != capacity"
            );
            let frag = kv.fragmentation();
            assert!((0.0..=1.0).contains(&frag), "bt={bt}: frag {frag}");
            match rng.gen_index(4) {
                0 => {
                    let ctx = 1 + rng.gen_index(200) as u64;
                    if kv.can_admit(ctx, ctx, 0) {
                        kv.lease(next, ctx, ctx);
                        live.push((next, ctx));
                        next += 1;
                    }
                }
                1 => {
                    if let Some(e) = live.iter_mut().find(|e| e.1 > 0) {
                        let t = 1 + rng.gen_index(e.1 as usize) as u64;
                        kv.write_chunk(e.0, t);
                        e.1 -= t;
                    }
                }
                2 => {
                    if let Some(e) = live.iter().find(|e| e.1 == 0) {
                        if kv.fits_growth(kv.decode_growth_one(e.0)) {
                            kv.write_decode(e.0);
                        }
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let k = rng.gen_index(live.len());
                        let (idx, _) = live.swap_remove(k);
                        kv.release(idx);
                        assert!(!kv.is_active(idx), "released sequence still active");
                    }
                }
            }
        }
        // draining everything returns the cache to pristine
        for (idx, _) in live.drain(..) {
            kv.release(idx);
        }
        assert_eq!(kv.used_blocks(), 0, "bt={bt}");
        assert_eq!(kv.reserved_blocks(), 0, "bt={bt}");
        assert_eq!(kv.free_blocks(), cap, "bt={bt}");
    }
}

/// Prefix lifecycle under randomized churn: shared blocks exist iff
/// some active sequence references them, and they are freed exactly
/// when the refcount reaches zero (observable as used_blocks returning
/// to the sum of private allocations).
#[test]
fn prefix_blocks_freed_only_at_refcount_zero() {
    let spec = KvSpec::paged(8).with_prefix(32);
    let mut kv = KvCache::new(spec, 2048);
    let prefix_blocks = 4u64; // 32 tokens / 8 per block

    // materialize via request 0 (input > prefix)
    kv.lease(0, 40, 40);
    kv.write_chunk(0, 40);
    let used_with_prefix = kv.used_blocks();
    assert_eq!(used_with_prefix, prefix_blocks + 1); // 8 private tokens

    // three sharers take references
    for i in 1..=3usize {
        let g = kv.lease(i, 40, 40);
        assert_eq!(g.skip, 32, "ready prefix must be skipped");
        kv.write_chunk(i, 8);
    }
    assert_eq!(kv.shared_tokens(), 3 * 32);
    assert_eq!(kv.used_blocks(), prefix_blocks + 4);

    // releasing any strict subset keeps the shared blocks alive
    kv.release(0);
    kv.release(2);
    assert_eq!(kv.used_blocks(), prefix_blocks + 2);
    kv.release(1);
    assert_eq!(kv.used_blocks(), prefix_blocks + 1);
    // the last reference frees the prefix in the same release
    kv.release(3);
    assert_eq!(kv.used_blocks(), 0);
    assert_eq!(kv.free_blocks(), kv.capacity_blocks());
    assert_eq!(kv.prefix_materializations(), 1);
}

/// Capacity scaling across dtypes is exact block math: the same DRAM
/// budget yields >= 2x / >= 4x tokens at fp8 / int4, and the paged
/// capacity never exceeds the token budget.
#[test]
fn dtype_and_block_capacity_math() {
    for &budget in &[100u64, 1000, 4097] {
        for &bt in &[1u64, 3, 16] {
            let kv = KvCache::new(KvSpec::paged(bt), budget);
            assert!(kv.capacity_tokens() <= budget);
            assert!(kv.capacity_tokens() + bt > budget, "more than one block wasted");
        }
    }
    // a block size exceeding the whole budget clamps down to it: the
    // cache never promises more tokens than the DRAM holds
    let tiny = KvCache::new(KvSpec::paged(16), 8);
    assert_eq!(tiny.capacity_tokens(), 8);
    assert!(!tiny.can_ever_fit(10, 4), "15-token footprint on 8-token DRAM");
    // dtype plumbing end to end: spec names and bit widths
    assert_eq!(KvDtype::Fp16.bits(), 16);
    assert_eq!(KvDtype::Fp8.bits(), 8);
    assert_eq!(KvDtype::Int4.bits(), 4);
    let s = KvSpec::paged(16)
        .with_dtype(KvDtype::Int4)
        .with_prefix(64)
        .with_eviction(EvictionPolicy::CostBased);
    assert_eq!(s.describe(), "int4/bt16/pfx64/cb");
    assert_eq!(s.block_round(1), 16);
    assert_eq!(s.block_round(16), 16);
    assert_eq!(s.block_round(17), 32);
}

//! LLM architecture descriptions (paper §VI-A workload setup).


/// Transformer architecture parameters sufficient to instantiate the
/// per-layer GEMM shapes of the computation execution graph.
#[derive(Debug, Clone, PartialEq)]
pub struct ModelSpec {
    pub name: String,
    pub hidden: u64,
    /// FFN inner width (for SwiGLU models this is the *per-branch* width).
    pub ffn_hidden: u64,
    pub n_heads: u64,
    /// KV heads (< n_heads under GQA).
    pub n_kv_heads: u64,
    pub head_dim: u64,
    pub n_blocks: u64,
    /// SwiGLU FFNs compute gate and up projections (2 branches).
    pub swiglu: bool,
}

impl ModelSpec {
    /// Width multiplier of the first FFN GEMM (gate+up fused for SwiGLU).
    pub fn ffn1_mult(&self) -> u64 {
        if self.swiglu {
            2
        } else {
            1
        }
    }

    /// KV-cache bytes appended per generated/prefilled token across the
    /// whole model (K + V, GQA-aware) at the fp16 baseline: used by the
    /// serving simulator's KV budget accounting.
    pub fn kv_bytes_per_token(&self) -> u64 {
        self.kv_bytes_per_token_bits(8 * crate::arch::constants::BYTES_PER_ELEM)
    }

    /// KV-cache bytes per token at an arbitrary element width (cache
    /// quantization: fp16 = 16, fp8 = 8, int4 = 4 bits; see
    /// `sim::KvDtype`). The per-token element count (K + V across all
    /// heads and blocks) is even, so the int4 division is exact.
    pub fn kv_bytes_per_token_bits(&self, bits: u64) -> u64 {
        2 * self.n_kv_heads * self.head_dim * self.n_blocks * bits / 8
    }

    /// Approximate parameter count (embeddings excluded).
    pub fn params(&self) -> u64 {
        let h = self.hidden;
        let qkv = h * (h + 2 * self.n_kv_heads * self.head_dim);
        let proj = h * h;
        let ffn = h * self.ffn_hidden * self.ffn1_mult() + self.ffn_hidden * h;
        self.n_blocks * (qkv + proj + ffn)
    }

    /// GPT3-7B-class model (traditional transformer, paper 64-TOPS target).
    pub fn gpt3_7b() -> Self {
        ModelSpec {
            name: "GPT3-7B".into(),
            hidden: 4096,
            ffn_hidden: 16384,
            n_heads: 32,
            n_kv_heads: 32,
            head_dim: 128,
            n_blocks: 32,
            swiglu: false,
        }
    }

    /// GPT3-13B-class model (paper 512-TOPS target).
    pub fn gpt3_13b() -> Self {
        ModelSpec {
            name: "GPT3-13B".into(),
            hidden: 5120,
            ffn_hidden: 20480,
            n_heads: 40,
            n_kv_heads: 40,
            head_dim: 128,
            n_blocks: 40,
            swiglu: false,
        }
    }

    /// LLaMA3-70B with GQA + SwiGLU (paper 2048-TOPS target).
    pub fn llama3_70b() -> Self {
        ModelSpec {
            name: "LLaMA3-70B".into(),
            hidden: 8192,
            ffn_hidden: 28672,
            n_heads: 64,
            n_kv_heads: 8,
            head_dim: 128,
            n_blocks: 80,
            swiglu: true,
        }
    }

    /// Tiny model for fast unit/property tests.
    pub fn tiny() -> Self {
        ModelSpec {
            name: "tiny".into(),
            hidden: 64,
            ffn_hidden: 256,
            n_heads: 4,
            n_kv_heads: 4,
            head_dim: 16,
            n_blocks: 4,
            swiglu: false,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "gpt3-7b" | "gpt3_7b" | "7b" => Some(Self::gpt3_7b()),
            "gpt3-13b" | "gpt3_13b" | "13b" => Some(Self::gpt3_13b()),
            "llama3-70b" | "llama3_70b" | "70b" => Some(Self::llama3_70b()),
            "tiny" => Some(Self::tiny()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parameter_counts_are_in_class() {
        let p7 = ModelSpec::gpt3_7b().params() as f64 / 1e9;
        assert!((5.0..9.0).contains(&p7), "7B-class got {p7}B");
        let p13 = ModelSpec::gpt3_13b().params() as f64 / 1e9;
        assert!((10.0..16.0).contains(&p13), "13B-class got {p13}B");
        let p70 = ModelSpec::llama3_70b().params() as f64 / 1e9;
        assert!((55.0..80.0).contains(&p70), "70B-class got {p70}B");
    }

    #[test]
    fn head_geometry_consistent() {
        for m in [
            ModelSpec::gpt3_7b(),
            ModelSpec::gpt3_13b(),
            ModelSpec::llama3_70b(),
        ] {
            assert_eq!(m.n_heads * m.head_dim, m.hidden, "{}", m.name);
            assert!(m.n_kv_heads <= m.n_heads);
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(ModelSpec::by_name("GPT3-7B").is_some());
        assert!(ModelSpec::by_name("llama3-70b").unwrap().swiglu);
        assert!(ModelSpec::by_name("nope").is_none());
    }
}

//! Paged, quantized, prefix-sharing KV-cache subsystem.
//!
//! The serving simulator's KV budget used to be a pair of raw token
//! counters (`kv_used`/`kv_reserved`) hand-threaded through the
//! scheduler. This module extracts all KV accounting into one object:
//!
//! * **Paged allocation** — capacity is divided into fixed-size blocks
//!   of [`KvSpec::block_tokens`] tokens (vLLM-style paging). Requests
//!   allocate whole blocks; the trailing partially-filled block is
//!   internal fragmentation, reported per iteration. `block_tokens = 1`
//!   degenerates to exact token-granular accounting — the bitwise
//!   equivalence anchor against the pre-refactor scalar counters.
//! * **Reservation leases** — admission books the full prefill context
//!   as reserved blocks; chunk writes realize the lease block by block.
//!   All arithmetic is checked ([`take`]): an accounting bug panics
//!   loudly instead of wrapping a `u64` silently in release builds.
//! * **Quantized dtypes** — [`KvDtype`] (fp16/fp8/int4) parameterizes
//!   both the bytes-per-token capacity derivation and the per-iteration
//!   KV DRAM traffic seen by the batch coster.
//! * **Copy-on-write prefix sharing** — a system-prompt prefix of
//!   [`KvSpec::prefix_tokens`] tokens (from `TraceSpec`) is materialized
//!   once into shared blocks and referenced by every later request;
//!   their prefills skip the prefix (chunks carry `past >= prefix`).
//!   Generated tokens always land in private blocks, so the shared
//!   blocks are never written after they fill (the "write" side of COW
//!   never copies in an append-only cache); shared blocks are freed only
//!   when the reference count drops to zero.
//! * **Pluggable eviction** — [`EvictionPolicy`]: the scheduler keeps
//!   its youngest-first default, or picks the victim with the lowest
//!   recompute loss (cost-based).
//!
//! Global invariant, `debug_assert`ed after every mutation:
//! `used_blocks + reserved_blocks + free_blocks == capacity_blocks`,
//! with the per-sequence states summing exactly to the global counters.

use crate::workload::ModelSpec;

/// KV-cache element type (paper's fp16 baseline plus the two
/// quantized variants the capacity study sweeps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvDtype {
    Fp16,
    Fp8,
    Int4,
}

impl KvDtype {
    pub const ALL: [KvDtype; 3] = [KvDtype::Fp16, KvDtype::Fp8, KvDtype::Int4];

    /// Bits per stored KV element.
    pub fn bits(self) -> u64 {
        match self {
            KvDtype::Fp16 => 16,
            KvDtype::Fp8 => 8,
            KvDtype::Int4 => 4,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KvDtype::Fp16 => "fp16",
            KvDtype::Fp8 => "fp8",
            KvDtype::Int4 => "int4",
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name.to_ascii_lowercase().as_str() {
            "fp16" => Some(KvDtype::Fp16),
            "fp8" => Some(KvDtype::Fp8),
            "int4" => Some(KvDtype::Int4),
            _ => None,
        }
    }

    /// KV-cache bytes appended per token across the whole model at this
    /// dtype (the fp16 value is exactly `ModelSpec::kv_bytes_per_token`).
    pub fn bytes_per_token(self, model: &ModelSpec) -> u64 {
        model.kv_bytes_per_token_bits(self.bits())
    }
}

/// Which running request the scheduler preempts under KV pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Preempt the most recently admitted request (the pre-refactor
    /// behavior, and the equivalence-anchor default).
    YoungestFirst,
    /// Preempt the non-oldest request whose re-admission costs the
    /// least prefill recompute (smallest context).
    CostBased,
}

impl EvictionPolicy {
    pub fn name(self) -> &'static str {
        match self {
            EvictionPolicy::YoungestFirst => "youngest",
            EvictionPolicy::CostBased => "cost-based",
        }
    }
}

/// KV-cache configuration carried by `SimConfig`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvSpec {
    /// Tokens per allocation block (1 = exact token-granular).
    pub block_tokens: u64,
    pub dtype: KvDtype,
    /// Shared system-prompt prefix length (0 = sharing off). Requests
    /// whose prompt is longer than the prefix share its KV blocks.
    pub prefix_tokens: u64,
    pub eviction: EvictionPolicy,
}

impl KvSpec {
    /// The pre-refactor semantics: token-granular fp16, no sharing,
    /// youngest-first eviction. Paged simulation under this spec is
    /// bitwise-equal to the old scalar-counter path.
    pub fn token_granular() -> Self {
        KvSpec {
            block_tokens: 1,
            dtype: KvDtype::Fp16,
            prefix_tokens: 0,
            eviction: EvictionPolicy::YoungestFirst,
        }
    }

    pub fn paged(block_tokens: u64) -> Self {
        KvSpec {
            block_tokens: block_tokens.max(1),
            ..Self::token_granular()
        }
    }

    pub fn with_dtype(mut self, dtype: KvDtype) -> Self {
        self.dtype = dtype;
        self
    }

    pub fn with_prefix(mut self, prefix_tokens: u64) -> Self {
        self.prefix_tokens = prefix_tokens;
        self
    }

    pub fn with_eviction(mut self, eviction: EvictionPolicy) -> Self {
        self.eviction = eviction;
        self
    }

    /// Round a token count up to whole blocks (the granularity at which
    /// KV migrates over a fleet handoff link).
    pub fn block_round(&self, tokens: u64) -> u64 {
        let bt = self.block_tokens.max(1);
        tokens.div_ceil(bt) * bt
    }

    pub fn describe(&self) -> String {
        let mut s = format!("{}/bt{}", self.dtype.name(), self.block_tokens.max(1));
        if self.prefix_tokens > 0 {
            s.push_str(&format!("/pfx{}", self.prefix_tokens));
        }
        if self.eviction == EvictionPolicy::CostBased {
            s.push_str("/cb");
        }
        s
    }
}

impl Default for KvSpec {
    fn default() -> Self {
        Self::token_granular()
    }
}

/// Checked decrement: a KV accounting bug fails loudly (in release
/// builds too) instead of wrapping around and silently inflating the
/// budget — the latent hazard of the pre-refactor `-=` sites.
#[track_caller]
fn take(slot: &mut u64, amount: u64, what: &str) {
    *slot = slot
        .checked_sub(amount)
        .unwrap_or_else(|| panic!("KV accounting underflow: {what}: {} - {}", *slot, amount));
}

/// Lifecycle of the shared system-prompt prefix.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum PrefixState {
    Absent,
    /// Being prefilled into shared blocks by the sequence that first
    /// needed it (the materializer); not yet referenceable.
    Filling,
    Ready,
}

/// Per-request cache state, indexed by the scheduler's request index.
#[derive(Debug, Clone, Copy, Default)]
struct SeqState {
    active: bool,
    /// Tokens written into this sequence's private blocks.
    priv_tokens: u64,
    priv_blocks: u64,
    /// Blocks still set aside for this sequence's prefill lease.
    reserved_blocks: u64,
    /// Prefill tokens still to write under the lease.
    reserved_tokens: u64,
    /// Prefix tokens this sequence writes into the shared blocks
    /// (nonzero only for the materializer).
    shared_goal: u64,
    shared_written: u64,
    /// Holds one reference on the shared prefix blocks.
    holds_ref: bool,
}

/// Outcome of a prefill admission.
#[derive(Debug, Clone, Copy)]
pub struct AdmitGrant {
    /// Context tokens served by the shared prefix: the request's prefill
    /// shrinks by this many tokens and its chunks carry `past >= skip`.
    pub skip: u64,
}

/// Admission sizing shared by `can_admit` and `lease`.
#[derive(Debug, Clone, Copy)]
struct Plan {
    skip: u64,
    shared_goal: u64,
    priv_total: u64,
    lease_blocks: u64,
}

/// Point-in-time KV pressure gauges (see [`KvCache::gauges`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KvGauges {
    pub frac: f64,
    pub fragmentation: f64,
    pub used_blocks: u64,
    pub reserved_blocks: u64,
    pub free_blocks: u64,
    pub shared_tokens: u64,
}

/// The paged KV cache of one scheduler (one package).
#[derive(Debug, Clone)]
pub struct KvCache {
    spec: KvSpec,
    capacity_blocks: u64,
    used_blocks: u64,
    reserved_blocks: u64,
    /// Tokens resident across all blocks (private + shared), for the
    /// internal-fragmentation stat.
    written_tokens: u64,
    seqs: Vec<SeqState>,
    prefix: PrefixState,
    prefix_filled: u64,
    prefix_refs: usize,
    // --- stats ---
    shared_tokens: u64,
    demand_tokens: u64,
    prefix_materializations: usize,
}

impl KvCache {
    /// `budget_tokens` is the raw token budget (DRAM bytes / dtype
    /// bytes-per-token); capacity is floored to whole blocks. A block
    /// size larger than the whole budget is clamped down to it, so
    /// `capacity_tokens() <= budget_tokens` always holds — the cache
    /// never promises more memory than the DRAM it models.
    pub fn new(spec: KvSpec, budget_tokens: u64) -> Self {
        let budget = budget_tokens.max(1);
        let bt = spec.block_tokens.max(1).min(budget);
        KvCache {
            spec: KvSpec {
                block_tokens: bt,
                ..spec
            },
            capacity_blocks: budget / bt,
            used_blocks: 0,
            reserved_blocks: 0,
            written_tokens: 0,
            seqs: Vec::new(),
            prefix: PrefixState::Absent,
            prefix_filled: 0,
            prefix_refs: 0,
            shared_tokens: 0,
            demand_tokens: 0,
            prefix_materializations: 0,
        }
    }

    #[inline]
    fn blocks_for(&self, tokens: u64) -> u64 {
        tokens.div_ceil(self.spec.block_tokens)
    }

    pub fn spec(&self) -> &KvSpec {
        &self.spec
    }

    pub fn capacity_blocks(&self) -> u64 {
        self.capacity_blocks
    }

    /// Token capacity actually addressable (whole blocks).
    pub fn capacity_tokens(&self) -> u64 {
        self.capacity_blocks * self.spec.block_tokens
    }

    pub fn used_blocks(&self) -> u64 {
        self.used_blocks
    }

    pub fn reserved_blocks(&self) -> u64 {
        self.reserved_blocks
    }

    pub fn free_blocks(&self) -> u64 {
        self.capacity_blocks - self.used_blocks - self.reserved_blocks
    }

    /// Cache fill fraction (written blocks / capacity): the occupancy
    /// trace's `kv_frac`.
    pub fn frac(&self) -> f64 {
        self.used_blocks as f64 / self.capacity_blocks as f64
    }

    /// Internal fragmentation right now: the fraction of allocated-block
    /// capacity holding no token (0 when `block_tokens = 1`).
    pub fn fragmentation(&self) -> f64 {
        if self.used_blocks == 0 {
            return 0.0;
        }
        let cap = (self.used_blocks * self.spec.block_tokens) as f64;
        1.0 - self.written_tokens as f64 / cap
    }

    /// Prefill tokens served from the shared prefix instead of computed.
    pub fn shared_tokens(&self) -> u64 {
        self.shared_tokens
    }

    /// Context tokens requested across all prefill admissions (the
    /// sharing-hit-rate denominator).
    pub fn demand_tokens(&self) -> u64 {
        self.demand_tokens
    }

    /// Times the shared prefix was (re-)materialized into blocks.
    pub fn prefix_materializations(&self) -> usize {
        self.prefix_materializations
    }

    /// One-shot snapshot of the cache's pressure gauges — the telemetry
    /// layer's per-replica KV signal (`sim::telemetry`), equivalent to
    /// calling the individual accessors at one instant.
    pub fn gauges(&self) -> KvGauges {
        KvGauges {
            frac: self.frac(),
            fragmentation: self.fragmentation(),
            used_blocks: self.used_blocks(),
            reserved_blocks: self.reserved_blocks(),
            free_blocks: self.free_blocks(),
            shared_tokens: self.shared_tokens(),
        }
    }

    /// Whether a request of this shape could ever be served, even alone
    /// (the arrival-time rejection test).
    pub fn can_ever_fit(&self, input_len: u64, output_len: u64) -> bool {
        let p = self.spec.prefix_tokens;
        let sharing = p > 0 && input_len > p;
        let skip = if sharing { p } else { 0 };
        let need = input_len + output_len + 1 - skip;
        let blocks = self.blocks_for(need) + if sharing { self.blocks_for(p) } else { 0 };
        blocks <= self.capacity_blocks
    }

    fn plan(&self, context: u64, input_len: u64) -> Plan {
        let p = self.spec.prefix_tokens;
        let sharing = p > 0 && input_len > p;
        let (skip, shared_goal) = if !sharing {
            (0, 0)
        } else {
            match self.prefix {
                PrefixState::Ready => (p, 0),
                PrefixState::Absent => (0, p),
                // someone else is still filling it: go fully private
                PrefixState::Filling => (0, 0),
            }
        };
        let priv_total = context - skip - shared_goal;
        let lease_blocks = self.blocks_for(priv_total)
            + if shared_goal > 0 {
                self.blocks_for(shared_goal)
            } else {
                0
            };
        Plan {
            skip,
            shared_goal,
            priv_total,
            lease_blocks,
        }
    }

    /// Can a prompt with `context` total tokens be admitted now?
    /// `extra_growth_blocks` covers co-scheduled decode writes; the `+1`
    /// block headroom for the first generated token mirrors the
    /// pre-refactor `need + 1` check.
    pub fn can_admit(&self, context: u64, input_len: u64, extra_growth_blocks: u64) -> bool {
        let pl = self.plan(context, input_len);
        let plus1 = self.blocks_for(pl.priv_total + 1) - self.blocks_for(pl.priv_total);
        pl.lease_blocks + plus1 + extra_growth_blocks <= self.free_blocks()
    }

    /// Can a KV-migrated request (context materializes without prefill,
    /// fully private) be admitted now?
    pub fn can_admit_written(&self, context: u64, extra_growth_blocks: u64) -> bool {
        let blocks = self.blocks_for(context);
        let plus1 = self.blocks_for(context + 1) - blocks;
        blocks + plus1 + extra_growth_blocks <= self.free_blocks()
    }

    fn seq_slot(&mut self, idx: usize) -> &mut SeqState {
        if idx >= self.seqs.len() {
            self.seqs.resize_with(idx + 1, SeqState::default);
        }
        &mut self.seqs[idx]
    }

    /// Admit a prompt: book the full prefill context as a reservation
    /// lease (the caller must have checked [`Self::can_admit`]). Returns
    /// the shared-prefix skip; the request's prefill target is
    /// `context - skip`.
    pub fn lease(&mut self, idx: usize, context: u64, input_len: u64) -> AdmitGrant {
        let pl = self.plan(context, input_len);
        self.demand_tokens += context;
        if pl.skip > 0 {
            self.prefix_refs += 1;
            self.shared_tokens += pl.skip;
        }
        if pl.shared_goal > 0 {
            debug_assert_eq!(self.prefix_filled, 0, "materializing a non-empty prefix");
            self.prefix = PrefixState::Filling;
            self.prefix_refs += 1;
            self.prefix_materializations += 1;
        }
        self.reserved_blocks += pl.lease_blocks;
        let s = self.seq_slot(idx);
        assert!(!s.active, "KV lease for an already-admitted sequence {idx}");
        *s = SeqState {
            active: true,
            priv_tokens: 0,
            priv_blocks: 0,
            reserved_blocks: pl.lease_blocks,
            reserved_tokens: pl.priv_total + pl.shared_goal,
            shared_goal: pl.shared_goal,
            shared_written: 0,
            holds_ref: pl.skip > 0 || pl.shared_goal > 0,
        };
        self.assert_conserved();
        AdmitGrant { skip: pl.skip }
    }

    /// Admit a KV-migrated request: its context materializes immediately
    /// into private blocks (no prefill compute, no sharing — the KV
    /// arrives over the handoff link). Returns the tokens actually
    /// transferred, rounded up to whole blocks (block-granular handoff).
    pub fn admit_written(&mut self, idx: usize, context: u64) -> u64 {
        let blocks = self.blocks_for(context);
        self.used_blocks += blocks;
        self.written_tokens += context;
        let bt = self.spec.block_tokens;
        let s = self.seq_slot(idx);
        assert!(!s.active, "KV admit for an already-admitted sequence {idx}");
        *s = SeqState {
            active: true,
            priv_tokens: context,
            priv_blocks: blocks,
            ..SeqState::default()
        };
        debug_assert!(
            self.used_blocks + self.reserved_blocks <= self.capacity_blocks,
            "migrated admission over capacity"
        );
        self.assert_conserved();
        blocks * bt
    }

    /// Write `t` prefill tokens for `idx`, drawing on its lease. The
    /// materializer's leading tokens fill the shared prefix blocks;
    /// everything else is private.
    pub fn write_chunk(&mut self, idx: usize, t: u64) {
        let mut s = self.seqs[idx];
        assert!(s.active, "KV chunk write for an inactive sequence {idx}");
        take(&mut s.reserved_tokens, t, "lease tokens");
        let to_shared = t.min(s.shared_goal - s.shared_written);
        if to_shared > 0 {
            let old = self.blocks_for(self.prefix_filled);
            self.prefix_filled += to_shared;
            s.shared_written += to_shared;
            let delta = self.blocks_for(self.prefix_filled) - old;
            self.used_blocks += delta;
            take(&mut self.reserved_blocks, delta, "reserved blocks (shared)");
            take(&mut s.reserved_blocks, delta, "seq reserved blocks (shared)");
            if s.shared_written == s.shared_goal {
                self.prefix = PrefixState::Ready;
            }
        }
        let to_priv = t - to_shared;
        if to_priv > 0 {
            let old = s.priv_blocks;
            s.priv_tokens += to_priv;
            s.priv_blocks = self.blocks_for(s.priv_tokens);
            let delta = s.priv_blocks - old;
            self.used_blocks += delta;
            take(&mut self.reserved_blocks, delta, "reserved blocks");
            take(&mut s.reserved_blocks, delta, "seq reserved blocks");
        }
        self.written_tokens += t;
        if s.reserved_tokens == 0 {
            debug_assert_eq!(s.reserved_blocks, 0, "lease fully written but blocks remain");
        }
        self.seqs[idx] = s;
        self.assert_conserved();
    }

    /// Append one generated token (always private, even for
    /// prefix-sharing sequences: that is the copy-on-write rule).
    pub fn write_decode(&mut self, idx: usize) {
        let mut s = self.seqs[idx];
        assert!(s.active, "KV decode write for an inactive sequence {idx}");
        debug_assert_eq!(s.reserved_tokens, 0, "decode write during prefill");
        let old = s.priv_blocks;
        s.priv_tokens += 1;
        s.priv_blocks = self.blocks_for(s.priv_tokens);
        self.used_blocks += s.priv_blocks - old;
        self.written_tokens += 1;
        debug_assert!(
            self.used_blocks + self.reserved_blocks <= self.capacity_blocks,
            "decode write over capacity"
        );
        self.seqs[idx] = s;
        self.assert_conserved();
    }

    /// Blocks a decode write for `idx` would newly allocate (0 when its
    /// tail block has room; always 1 at `block_tokens = 1`).
    pub fn decode_growth_one(&self, idx: usize) -> u64 {
        let s = &self.seqs[idx];
        debug_assert!(s.active);
        self.blocks_for(s.priv_tokens + 1) - s.priv_blocks
    }

    /// Would `growth` more blocks of decode writes fit without eviction?
    pub fn fits_growth(&self, growth: u64) -> bool {
        self.used_blocks + self.reserved_blocks + growth <= self.capacity_blocks
    }

    /// Phase of `idx`'s private tail block: tokens already written into
    /// it (`priv_tokens % block_tokens`). A decode write allocates a new
    /// block exactly when the phase is 0, so the scheduler's decode
    /// fast-forward derives every future iteration's block growth from
    /// these residues instead of rescanning [`Self::decode_growth_one`]:
    /// at stretch iteration `j`, sequence `idx` allocates iff
    /// `(decode_phase(idx) + j) % block_tokens == 0`.
    pub fn decode_phase(&self, idx: usize) -> u64 {
        let s = &self.seqs[idx];
        debug_assert!(s.active, "KV decode phase of an inactive sequence {idx}");
        debug_assert_eq!(s.reserved_tokens, 0, "decode phase during prefill");
        s.priv_tokens % self.spec.block_tokens
    }

    /// Apply one coalesced decode iteration's *global* accounting:
    /// `delta_blocks` blocks newly allocated by this iteration's writes
    /// (derived from the [`Self::decode_phase`] residues) and `n_tokens`
    /// appended tokens (one per decoding sequence). Per-sequence state is
    /// deferred to [`Self::finish_decode_stretch`], so `frac` /
    /// `fragmentation` / `free_blocks` stay exact after every iteration
    /// of the stretch while the per-sequence fields are intentionally
    /// stale in between; conservation is re-established (and
    /// `debug_assert`ed) by the sync.
    pub fn bulk_decode_iter(&mut self, delta_blocks: u64, n_tokens: u64) {
        self.used_blocks += delta_blocks;
        self.written_tokens += n_tokens;
        debug_assert!(
            self.used_blocks + self.reserved_blocks <= self.capacity_blocks,
            "coalesced decode write over capacity"
        );
    }

    /// Sync per-sequence state after a coalesced decode stretch: each
    /// sequence in `ids` appended exactly `iters` tokens whose global
    /// accounting already went through [`Self::bulk_decode_iter`]. Must
    /// run before any of the sequences is released — [`Self::release`]
    /// reads `priv_blocks`/`priv_tokens`. Equivalent to `iters` calls to
    /// [`Self::write_decode`] per sequence (anchored by a unit test
    /// below and bitwise end-to-end in
    /// `rust/tests/coalesce_equivalence.rs`).
    pub fn finish_decode_stretch(&mut self, ids: &[usize], iters: u64) {
        for &idx in ids {
            let s = &mut self.seqs[idx];
            assert!(s.active, "KV stretch sync for an inactive sequence {idx}");
            debug_assert_eq!(s.reserved_tokens, 0, "decode stretch during prefill");
            s.priv_tokens += iters;
            s.priv_blocks = s.priv_tokens.div_ceil(self.spec.block_tokens);
        }
        self.assert_conserved();
    }

    /// Free everything `idx` holds (completion or preemption): private
    /// blocks, outstanding lease, and its shared-prefix reference.
    /// Shared blocks are freed only when the last reference drops.
    pub fn release(&mut self, idx: usize) {
        let s = self.seqs[idx];
        assert!(s.active, "KV double free of sequence {idx}");
        take(&mut self.used_blocks, s.priv_blocks, "used blocks");
        take(&mut self.reserved_blocks, s.reserved_blocks, "reserved blocks");
        take(&mut self.written_tokens, s.priv_tokens, "written tokens");
        if s.holds_ref {
            assert!(self.prefix_refs > 0, "prefix refcount underflow");
            self.prefix_refs -= 1;
            if self.prefix_refs == 0 {
                let pb = self.blocks_for(self.prefix_filled);
                take(&mut self.used_blocks, pb, "shared prefix blocks");
                take(&mut self.written_tokens, self.prefix_filled, "shared prefix tokens");
                self.prefix_filled = 0;
                self.prefix = PrefixState::Absent;
            }
        }
        self.seqs[idx] = SeqState::default();
        self.assert_conserved();
    }

    /// Whether `idx` currently holds or reserves any cache space.
    pub fn is_active(&self, idx: usize) -> bool {
        self.seqs.get(idx).is_some_and(|s| s.active)
    }

    #[cfg(debug_assertions)]
    fn assert_conserved(&self) {
        let mut used = 0u64;
        let mut resv = 0u64;
        let mut toks = 0u64;
        for s in &self.seqs {
            if s.active {
                used += s.priv_blocks;
                resv += s.reserved_blocks;
                toks += s.priv_tokens;
            }
        }
        if self.prefix_refs > 0 {
            used += self.blocks_for(self.prefix_filled);
            toks += self.prefix_filled;
        }
        debug_assert_eq!(used, self.used_blocks, "used-block conservation");
        debug_assert_eq!(resv, self.reserved_blocks, "reserved-block conservation");
        debug_assert_eq!(toks, self.written_tokens, "written-token conservation");
        debug_assert!(
            self.used_blocks + self.reserved_blocks <= self.capacity_blocks,
            "cache over capacity: used {} + reserved {} > {}",
            self.used_blocks,
            self.reserved_blocks,
            self.capacity_blocks
        );
    }

    #[cfg(not(debug_assertions))]
    fn assert_conserved(&self) {}
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_bytes_scale_with_bits() {
        let m = ModelSpec::gpt3_13b();
        let fp16 = KvDtype::Fp16.bytes_per_token(&m);
        assert_eq!(fp16, m.kv_bytes_per_token());
        assert_eq!(KvDtype::Fp8.bytes_per_token(&m), fp16 / 2);
        assert_eq!(KvDtype::Int4.bytes_per_token(&m), fp16 / 4);
        assert_eq!(KvDtype::by_name("INT4"), Some(KvDtype::Int4));
        assert_eq!(KvDtype::by_name("bf16"), None);
    }

    #[test]
    fn token_granular_mirrors_scalar_counters() {
        let mut kv = KvCache::new(KvSpec::token_granular(), 100);
        assert_eq!(kv.capacity_blocks(), 100);
        assert!(kv.can_ever_fit(60, 39)); // 60 + 39 + 1 == 100
        assert!(!kv.can_ever_fit(60, 40));
        assert!(kv.can_admit(60, 60, 0)); // 60 + 1 <= 100
        let g = kv.lease(0, 60, 60);
        assert_eq!(g.skip, 0);
        assert_eq!(kv.reserved_blocks(), 60);
        assert_eq!(kv.free_blocks(), 40);
        // the old `need + 1 > head` check: 39 + 1 <= 40 admits, 40+1 not
        assert!(kv.can_admit(39, 39, 0));
        assert!(!kv.can_admit(40, 40, 0));
        kv.write_chunk(0, 16);
        assert_eq!(kv.used_blocks(), 16);
        assert_eq!(kv.reserved_blocks(), 44);
        kv.write_chunk(0, 44);
        assert_eq!(kv.reserved_blocks(), 0);
        kv.write_decode(0);
        assert_eq!(kv.used_blocks(), 61);
        assert_eq!(kv.fragmentation(), 0.0);
        kv.release(0);
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.free_blocks(), 100);
    }

    #[test]
    fn paged_blocks_round_up_and_report_fragmentation() {
        let mut kv = KvCache::new(KvSpec::paged(16), 160);
        assert_eq!(kv.capacity_blocks(), 10);
        kv.lease(0, 20, 20); // 2 blocks leased
        assert_eq!(kv.reserved_blocks(), 2);
        kv.write_chunk(0, 20);
        assert_eq!(kv.used_blocks(), 2);
        // 20 tokens in 32 token-slots: 37.5% internal fragmentation
        assert!((kv.fragmentation() - 0.375).abs() < 1e-12);
        // 12 decode writes fill the tail block without allocating
        for _ in 0..12 {
            assert_eq!(kv.decode_growth_one(0), 0);
            kv.write_decode(0);
        }
        assert_eq!(kv.used_blocks(), 2);
        assert_eq!(kv.decode_growth_one(0), 1);
        kv.write_decode(0);
        assert_eq!(kv.used_blocks(), 3);
        kv.release(0);
        assert_eq!(kv.free_blocks(), 10);
    }

    #[test]
    fn prefix_shared_blocks_freed_only_at_refcount_zero() {
        let spec = KvSpec::paged(8).with_prefix(16);
        let mut kv = KvCache::new(spec, 160);
        // materializer: no skip, prefix lands in shared blocks
        let g = kv.lease(0, 24, 24);
        assert_eq!(g.skip, 0);
        assert_eq!(kv.prefix_materializations(), 1);
        kv.write_chunk(0, 24); // 16 shared + 8 private
        assert_eq!(kv.used_blocks(), 3);
        // second request skips the ready prefix
        let g1 = kv.lease(1, 20, 20);
        assert_eq!(g1.skip, 16);
        assert_eq!(kv.shared_tokens(), 16);
        kv.write_chunk(1, 4);
        assert_eq!(kv.used_blocks(), 4); // shared 2 + priv 1 + priv 1
        // releasing the materializer keeps the shared blocks alive
        kv.release(0);
        assert_eq!(kv.used_blocks(), 3);
        assert!(kv.fragmentation() > 0.0);
        // last reference drops: shared blocks freed
        kv.release(1);
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.free_blocks(), kv.capacity_blocks());
        // next admission re-materializes
        let g2 = kv.lease(2, 24, 24);
        assert_eq!(g2.skip, 0);
        assert_eq!(kv.prefix_materializations(), 2);
    }

    #[test]
    fn evicted_materializer_tears_down_partial_prefix() {
        let spec = KvSpec::paged(4).with_prefix(8);
        let mut kv = KvCache::new(spec, 64);
        kv.lease(0, 12, 12);
        kv.write_chunk(0, 6); // prefix only partially filled
        kv.release(0); // preempted: sole ref, partial prefix torn down
        assert_eq!(kv.used_blocks(), 0);
        assert_eq!(kv.reserved_blocks(), 0);
        // a fresh admission starts a new materialization from zero
        let g = kv.lease(1, 12, 12);
        assert_eq!(g.skip, 0);
        assert_eq!(kv.prefix_materializations(), 2);
    }

    #[test]
    fn migrated_admission_transfers_whole_blocks() {
        let mut kv = KvCache::new(KvSpec::paged(16), 320);
        let transferred = kv.admit_written(0, 50);
        assert_eq!(transferred, 64); // 4 blocks of 16
        assert_eq!(kv.used_blocks(), 4);
        // token-granular transfer is exact
        let mut kv1 = KvCache::new(KvSpec::token_granular(), 320);
        assert_eq!(kv1.admit_written(0, 50), 50);
    }

    #[test]
    fn gauges_snapshot_matches_accessors() {
        let mut kv = KvCache::new(KvSpec::paged(16), 320);
        kv.lease(0, 40, 40);
        kv.write_chunk(0, 40);
        kv.admit_written(1, 30);
        let g = kv.gauges();
        assert_eq!(g.frac.to_bits(), kv.frac().to_bits());
        assert_eq!(g.fragmentation.to_bits(), kv.fragmentation().to_bits());
        assert_eq!(g.used_blocks, kv.used_blocks());
        assert_eq!(g.reserved_blocks, kv.reserved_blocks());
        assert_eq!(g.free_blocks, kv.free_blocks());
        assert_eq!(g.shared_tokens, kv.shared_tokens());
        assert_eq!(
            g.used_blocks + g.reserved_blocks + g.free_blocks,
            kv.capacity_blocks()
        );
    }

    #[test]
    fn bulk_decode_stretch_matches_serial_writes() {
        // Mixed tail phases across paged and token-granular specs: the
        // coalesced path (per-iteration bulk_decode_iter from the phase
        // residues + one finish_decode_stretch) must land on exactly the
        // state that per-token write_decode calls produce, after *every*
        // iteration for the global gauges and at the end for everything.
        for spec in [KvSpec::token_granular(), KvSpec::paged(4), KvSpec::paged(16)] {
            let bt = spec.block_tokens;
            let mut serial = KvCache::new(spec, 640);
            let mut bulk = KvCache::new(spec, 640);
            // three sequences with distinct tail phases
            for (idx, ctx) in [(0u64, 5u64), (1, 16), (2, 23)] {
                serial.admit_written(idx as usize, ctx);
                bulk.admit_written(idx as usize, ctx);
            }
            let ids = [0usize, 1, 2];
            let resid: Vec<u64> = ids.iter().map(|&i| bulk.decode_phase(i)).collect();
            let iters = 10u64;
            for j in 0..iters {
                for &i in &ids {
                    serial.write_decode(i);
                }
                let phase = (bt - (j % bt)) % bt;
                let delta = resid.iter().filter(|&&p| p == phase).count() as u64;
                bulk.bulk_decode_iter(delta, ids.len() as u64);
                assert_eq!(bulk.used_blocks(), serial.used_blocks(), "iter {j}");
                assert_eq!(bulk.free_blocks(), serial.free_blocks(), "iter {j}");
                assert_eq!(bulk.frac().to_bits(), serial.frac().to_bits());
                assert_eq!(
                    bulk.fragmentation().to_bits(),
                    serial.fragmentation().to_bits()
                );
            }
            bulk.finish_decode_stretch(&ids, iters);
            for &i in &ids {
                assert_eq!(bulk.decode_phase(i), serial.decode_phase(i));
                assert_eq!(bulk.decode_growth_one(i), serial.decode_growth_one(i));
            }
            // release order must observe identical per-seq state
            for &i in &ids {
                serial.release(i);
                bulk.release(i);
                assert_eq!(bulk.used_blocks(), serial.used_blocks());
            }
            assert_eq!(bulk.free_blocks(), bulk.capacity_blocks());
        }
    }

    #[test]
    #[should_panic(expected = "double free")]
    fn double_free_panics() {
        let mut kv = KvCache::new(KvSpec::token_granular(), 64);
        kv.lease(0, 8, 8);
        kv.release(0);
        kv.release(0);
    }

    #[test]
    #[should_panic(expected = "underflow")]
    fn overdrawn_lease_panics_not_wraps() {
        let mut kv = KvCache::new(KvSpec::token_granular(), 64);
        kv.lease(0, 8, 8);
        kv.write_chunk(0, 9); // one more token than the lease booked
    }
}

//! Small self-contained utilities: a seeded RNG (no external crates are
//! vendored for randomness), a string error type keeping the default
//! build dependency-free, and a micro-benchmark harness used by the
//! `cargo bench` binaries.

/// Minimal string error for the crate's fallible APIs (GP fit, PJRT
/// runtime). The default build vendors no error-handling crates, so this
/// stands in for `anyhow`: message-only, `Display`/`Error`-compatible.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    pub fn msg<S: Into<String>>(s: S) -> Self {
        Error(s.into())
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Crate-wide result alias over [`Error`].
pub type Result<T> = std::result::Result<T, Error>;

/// Deterministic 64-bit RNG: splitmix64 state update with an xorshift
/// output mix. Statistical quality is ample for search heuristics and
/// synthetic trace generation; determinism under a seed is the contract.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn seed_from_u64(seed: u64) -> Self {
        let mut r = Rng {
            state: seed.wrapping_add(0x9e37_79b9_7f4a_7c15),
        };
        // warm up so small seeds decorrelate
        r.next_u64();
        r.next_u64();
        r
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // splitmix64
        self.state = self.state.wrapping_add(0x9e37_79b9_7f4a_7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
        z ^ (z >> 31)
    }

    /// Uniform in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[lo, hi)` (`hi > lo`).
    #[inline]
    pub fn gen_range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.next_u64() % (hi - lo)
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn gen_index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Pick a random element.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.gen_index(xs.len())]
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_index(i + 1);
            xs.swap(i, j);
        }
    }

    /// Standard normal via Box-Muller.
    pub fn gen_normal(&mut self) -> f64 {
        let u1 = self.gen_f64().max(f64::EPSILON);
        let u2 = self.gen_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Minimal wall-clock benchmark harness for `harness = false` benches
/// (criterion is not vendored in this environment). Runs `f` in batches
/// until `budget` elapses (at least `min_iters`), reports mean/min.
pub struct Bench {
    pub name: String,
    budget: std::time::Duration,
    min_iters: u32,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench {
            name: name.to_string(),
            budget: std::time::Duration::from_millis(400),
            min_iters: 3,
        }
    }

    pub fn budget_ms(mut self, ms: u64) -> Self {
        self.budget = std::time::Duration::from_millis(ms);
        self
    }

    /// Time `f`, printing a criterion-like line. Returns mean seconds.
    pub fn run<T, F: FnMut() -> T>(&self, mut f: F) -> f64 {
        // warm-up
        let t0 = std::time::Instant::now();
        std::hint::black_box(f());
        let first = t0.elapsed();
        let mut times = vec![first.as_secs_f64()];
        let start = std::time::Instant::now();
        let mut iters = 1u32;
        while (start.elapsed() < self.budget || iters < self.min_iters) && iters < 10_000 {
            let t = std::time::Instant::now();
            std::hint::black_box(f());
            times.push(t.elapsed().as_secs_f64());
            iters += 1;
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
        println!(
            "bench {:<44} iters {:>5}  mean {:>12}  min {:>12}",
            self.name,
            times.len(),
            fmt_time(mean),
            fmt_time(min)
        );
        mean
    }
}

/// Human-readable seconds.
pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.2} s", s)
    }
}

/// Format a float with engineering suffixes for report tables.
pub fn fmt_eng(x: f64) -> String {
    let ax = x.abs();
    if ax == 0.0 {
        "0".into()
    } else if ax >= 1e12 {
        format!("{:.2}T", x / 1e12)
    } else if ax >= 1e9 {
        format!("{:.2}G", x / 1e9)
    } else if ax >= 1e6 {
        format!("{:.2}M", x / 1e6)
    } else if ax >= 1e3 {
        format!("{:.2}K", x / 1e3)
    } else if ax >= 1.0 {
        format!("{:.2}", x)
    } else {
        format!("{:.3e}", x)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic_per_seed() {
        let mut a = Rng::seed_from_u64(42);
        let mut b = Rng::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::seed_from_u64(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn gen_f64_in_unit_interval() {
        let mut r = Rng::seed_from_u64(1);
        for _ in 0..10_000 {
            let x = r.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_range_respects_bounds_and_covers() {
        let mut r = Rng::seed_from_u64(2);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let x = r.gen_range(5, 15);
            assert!((5..15).contains(&x));
            seen[(x - 5) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_mean_and_var_sane() {
        let mut r = Rng::seed_from_u64(3);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_preserves_elements() {
        let mut r = Rng::seed_from_u64(4);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>()); // overwhelmingly likely
    }

    #[test]
    fn fmt_helpers() {
        assert!(fmt_time(2.5e-9).contains("ns"));
        assert!(fmt_time(0.002).contains("ms"));
        assert!(fmt_eng(2_500_000.0).contains('M'));
    }
}

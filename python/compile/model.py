"""L2: the GP surrogate compute graph of Compass' hardware sampling engine.

The paper updates the Bayesian-optimization model parameters on an
accelerator (A100 in their testbed); here that compute is expressed in JAX,
calls the L1 Pallas kernels for the Gram hot-spot, and is AOT-lowered by
aot.py into HLO artifacts that the Rust coordinator executes via PJRT:

  composite_gram : Eq. 2  K = K_sys * (1 + I(shape=shape')) * K_layout
  gram_diag      : K(z, z) for EI variance
  gp_fit         : masked Cholesky fit  -> (alpha, L, mll)
  gp_ei          : posterior mean/var + Expected Improvement (minimisation)

All shapes are fixed (constants.py) and masked so one compiled executable
serves the entire BO run. Masked training rows are replaced by identity
rows in K so the Cholesky stays well-posed and masked entries contribute
nothing to mean/var/mll.
"""

import jax
import jax.numpy as jnp

from . import kernels
from .constants import BLOCK_N, BLOCK_Q


# -- plain-HLO linear algebra -------------------------------------------
# jax.lax.linalg.{cholesky,triangular_solve} lower to LAPACK FFI
# custom-calls on CPU (lapack_spotrf_ffi / lapack_strsm_ffi) which the
# runtime's xla_extension 0.5.1 cannot execute. These loop-based
# implementations lower to pure HLO (while + dynamic slices); n is small
# (TRAIN_N = 128) so the sequential loop is immaterial.


def cholesky_hlo(a):
    """Lower-Cholesky of a PD matrix, Cholesky-Crout column order."""
    n = a.shape[0]
    rows = jnp.arange(n)

    def body(j, l):
        lj = l[j]  # row j (columns >= j are still zero)
        ljj = jnp.sqrt(jnp.maximum(a[j, j] - jnp.dot(lj, lj), 1e-20))
        col = (a[:, j] - l @ lj) / ljj
        col = jnp.where(rows > j, col, 0.0)
        l = l + col[:, None] * (rows == j)[None, :].astype(a.dtype)
        return l.at[j, j].set(ljj)

    return jax.lax.fori_loop(0, n, body, jnp.zeros_like(a))


def solve_lower_hlo(l, b):
    """Solve L x = b for lower-triangular L; b may be (n,) or (n, q)."""
    n = l.shape[0]
    x0 = jnp.zeros_like(b)

    def body(i, x):
        xi = (b[i] - l[i] @ x) / l[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, n, body, x0)


def solve_upper_t_hlo(l, b):
    """Solve L^T x = b (backward substitution)."""
    n = l.shape[0]
    x0 = jnp.zeros_like(b)

    def body(k, x):
        i = n - 1 - k
        xi = (b[i] - l[:, i] @ x) / l[i, i]
        return x.at[i].set(xi)

    return jax.lax.fori_loop(0, n, body, x0)


def composite_gram(xsys, ysys, inv_ls, a, b, w, sa, sb, sigma2):
    """Hardware-aware composite kernel (Eq. 2).

    xsys: (Q, D) system-parameter features     ysys: (N, D)
    inv_ls: (D,) inverse lengthscales (0 disables padded dims)
    a: (Q, S, T) one-hot layouts               b: (N, S, T)
    w: (S, S) Manhattan weights (Eq. 4, built by the coordinator)
    sa: (Q, 2) (H, W) array dims               sb: (N, 2)
    sigma2: () layout-kernel variance
    -> (Q, N)
    """
    k_sys = kernels.rbf_gram(xsys, ysys, inv_ls, BLOCK_Q, BLOCK_N)
    k_lay = kernels.layout_gram(a, b, w, 1.0, BLOCK_Q, BLOCK_N)
    eq = jnp.all(sa[:, None, :] == sb[None, :, :], axis=-1)
    ind = 1.0 + eq.astype(xsys.dtype)
    return (k_sys * ind * k_lay * sigma2,)


def gram_diag(a, w, sigma2):
    """K(z, z) under Eq. 2: K_sys(z,z)=1, indicator=2, layout diag."""
    d = kernels.layout_gram_diag(a, w, 1.0, BLOCK_Q)
    return (2.0 * sigma2 * d,)


def gp_fit(k, y, mask, noise):
    """Masked GP fit.

    k: (N, N) train Gram, y: (N,) observations (standardised by rust),
    mask: (N,) {0,1}, noise: () observation noise variance.
    Returns alpha: (N,), L: (N, N) lower Cholesky, mll: ().
    """
    n = k.shape[0]
    mm = mask[:, None] * mask[None, :]
    eye = jnp.eye(n, dtype=k.dtype)
    # masked rows/cols -> identity; active diagonal gets noise + jitter
    km = k * mm + eye * (1.0 - mask)[None, :] * (1.0 - mask)[:, None]
    km = km + eye * (mask * (noise + 1e-6))[None, :]
    # keep strictly: identity on masked diag, k+noise on active diag
    chol = cholesky_hlo(km)
    ym = y * mask
    z = solve_lower_hlo(chol, ym)
    alpha = solve_upper_t_hlo(chol, z)
    n_act = jnp.sum(mask)
    logdet = 2.0 * jnp.sum(jnp.log(jnp.diagonal(chol)) * mask)
    mll = -0.5 * jnp.sum(ym * alpha) - 0.5 * logdet - 0.5 * n_act * jnp.log(
        2.0 * jnp.pi
    )
    return alpha, chol, mll


_SQRT2 = 1.4142135623730951
_INV_SQRT_2PI = 0.3989422804014327


def erf_hlo(x):
    """Abramowitz-Stegun 7.1.26 erf (|err| < 1.5e-7): the `erf` HLO
    opcode postdates the runtime's xla_extension 0.5.1 text parser, so
    the CDF is built from elementary ops instead of jax.lax.erf."""
    sign = jnp.sign(x)
    ax = jnp.abs(x)
    t = 1.0 / (1.0 + 0.3275911 * ax)
    poly = ((((1.061405429 * t - 1.453152027) * t + 1.421413741) * t
             - 0.284496736) * t + 0.254829592) * t
    return sign * (1.0 - poly * jnp.exp(-ax * ax))


def gp_ei_fused(
    xsys_c, a_c, s_c, xsys_t, a_t, s_t, inv_ls, w, sigma2, chol, alpha, mask, f_best
):
    """Fused acquisition step: candidate-vs-train composite Gram, prior
    variances, posterior and EI in ONE executable — one PJRT dispatch per
    SA step instead of three (gram_cross + gram_diag + gp_ei), and the
    intermediate (Q, N) Gram never leaves the device (see EXPERIMENTS.md
    #Perf, L2)."""
    k_cross = composite_gram(xsys_c, xsys_t, inv_ls, a_c, a_t, w, s_c, s_t, sigma2)[0]
    k_diag = gram_diag(a_c, w, sigma2)[0]
    return gp_ei(k_cross, k_diag, chol, alpha, mask, f_best)


def gp_ei(k_cross, k_diag, chol, alpha, mask, f_best):
    """Posterior + Expected Improvement for minimisation.

    k_cross: (Q, N) candidate-vs-train Gram, k_diag: (Q,) prior variances,
    chol/alpha/mask from gp_fit, f_best: () incumbent (standardised).
    Returns mean: (Q,), var: (Q,), ei: (Q,).
    """
    kc = k_cross * mask[None, :]
    mean = kc @ alpha
    v = solve_lower_hlo(chol, kc.T)  # (N, Q)
    var = jnp.maximum(k_diag - jnp.sum(v * v, axis=0), 1e-10)
    sd = jnp.sqrt(var)
    zz = (f_best - mean) / sd
    cdf = 0.5 * (1.0 + erf_hlo(zz / _SQRT2))
    pdf = _INV_SQRT_2PI * jnp.exp(-0.5 * zz * zz)
    ei = sd * (zz * cdf + pdf)
    return mean, var, jnp.maximum(ei, 0.0)
